//! Data-analytics workload: k-means where the distance computation is a
//! GEMM (the standard ||x-c||^2 = ||x||^2 - 2 x.c + ||c||^2 expansion),
//! so each Lloyd iteration's hot spot offloads to the PMCA.
//!
//! Synthetic blobs with known centers; the example reports inertia per
//! iteration (must decrease monotonically), recovered-center error, and
//! host vs offload timing.
//!
//! ```sh
//! cargo run --release --example kmeans
//! ```

use hero_blas::blas::{DispatchPolicy, HeroBlas};
use hero_blas::config::DispatchMode;
use hero_blas::npy::NdArray;
use hero_blas::util::rng::Rng;

const K: usize = 4;
const DIM: usize = 64;
const POINTS: usize = 256;
const ITERS: usize = 8;

/// Blobs around K well-separated centers.
fn make_blobs(rng: &mut Rng) -> (NdArray<f64>, Vec<Vec<f64>>) {
    let mut centers = Vec::new();
    for k in 0..K {
        let mut c = vec![0.0; DIM];
        // each cluster occupies its own block of dimensions -> separation
        // ~ 8*sqrt(DIM/K) >> cluster std
        for d in 0..DIM {
            c[d] = if d % K == k { 8.0 } else { 0.0 };
        }
        centers.push(c);
    }
    let mut data = vec![0.0; POINTS * DIM];
    for p in 0..POINTS {
        let c = &centers[p % K];
        for d in 0..DIM {
            data[p * DIM + d] = c[d] + 0.3 * rng.next_normal();
        }
    }
    (NdArray::from_vec(data, &[POINTS, DIM]).unwrap(), centers)
}

/// One Lloyd step; returns (new centroids, inertia).
fn lloyd_step(
    x: &NdArray<f64>,
    centroids: &NdArray<f64>,
    blas: &mut HeroBlas,
) -> anyhow::Result<(NdArray<f64>, f64)> {
    // cross term via GEMM: G = X @ C^T  (POINTS x K) — the offloaded call
    let g = x.matmul(&centroids.t()?, blas)?;
    let xsq: Vec<f64> = (0..POINTS)
        .map(|p| x.row(p).iter().map(|v| v * v).sum())
        .collect();
    let csq: Vec<f64> = (0..K)
        .map(|k| centroids.row(k).iter().map(|v| v * v).sum())
        .collect();

    let mut assign = vec![0usize; POINTS];
    let mut inertia = 0.0;
    for p in 0..POINTS {
        let (mut best_k, mut best_d) = (0, f64::INFINITY);
        for k in 0..K {
            let d = xsq[p] - 2.0 * g.get2(p, k) + csq[k];
            if d < best_d {
                best_d = d;
                best_k = k;
            }
        }
        assign[p] = best_k;
        inertia += best_d;
    }

    let mut sums = vec![0.0; K * DIM];
    let mut counts = vec![0usize; K];
    for p in 0..POINTS {
        counts[assign[p]] += 1;
        for d in 0..DIM {
            sums[assign[p] * DIM + d] += x.get2(p, d);
        }
    }
    for k in 0..K {
        let c = counts[k].max(1) as f64;
        for d in 0..DIM {
            sums[k * DIM + d] /= c;
        }
    }
    Ok((NdArray::from_vec(sums, &[K, DIM])?, inertia))
}

fn run(x: &NdArray<f64>, init: &NdArray<f64>, blas: &mut HeroBlas)
       -> anyhow::Result<(NdArray<f64>, Vec<f64>, f64)> {
    let f = blas.engine.freq_hz();
    blas.reset_run();
    let mut centroids = init.clone();
    let mut history = Vec::new();
    for _ in 0..ITERS {
        let (c, inertia) = lloyd_step(x, &centroids, blas)?;
        centroids = c;
        history.push(inertia);
    }
    let secs = blas.trace().grand_total().to_secs(f);
    Ok((centroids, history, secs))
}

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(0xB10B5);
    let (x, true_centers) = make_blobs(&mut rng);
    // k-means++ lite: init from the first K points (one per true cluster)
    let mut init_data = Vec::with_capacity(K * DIM);
    for p in 0..K {
        init_data.extend_from_slice(x.row(p));
    }
    let init = NdArray::from_vec(init_data, &[K, DIM])?;
    let mut blas = HeroBlas::from_env(DispatchMode::Auto)?;

    println!("k-means: {POINTS} points, dim {DIM}, k={K}, {ITERS} iterations\n");

    blas.policy = DispatchPolicy::with_mode(DispatchMode::HostOnly);
    let (c_host, hist_host, host_s) = run(&x, &init, &mut blas)?;
    blas.policy = DispatchPolicy::with_mode(DispatchMode::DeviceOnly);
    let (c_dev, hist_dev, dev_s) = run(&x, &init, &mut blas)?;

    println!("inertia per iteration (host):   {}",
             hist_host.iter().map(|v| format!("{v:.0}")).collect::<Vec<_>>().join(" -> "));
    println!("inertia per iteration (device): {}",
             hist_dev.iter().map(|v| format!("{v:.0}")).collect::<Vec<_>>().join(" -> "));
    assert!(
        hist_host.windows(2).all(|w| w[1] <= w[0] + 1e-6),
        "inertia must not increase"
    );
    assert!(c_host.max_abs_diff(&c_dev) < 1e-8, "paths must agree");

    // recovered centers close to the truth (match greedily)
    let mut worst = 0.0f64;
    for tc in &true_centers {
        let best = (0..K)
            .map(|k| {
                c_dev.row(k)
                    .iter()
                    .zip(tc.iter())
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum::<f64>()
                    .sqrt()
            })
            .fold(f64::INFINITY, f64::min);
        worst = worst.max(best);
    }
    println!("\nworst recovered-center distance: {worst:.3} (cluster std 0.3)");
    println!(
        "total virtual time: host {:.1} ms, offload {:.1} ms ({:.2}x)",
        host_s * 1e3,
        dev_s * 1e3,
        host_s / dev_s
    );
    println!(
        "\nlesson: the k-means cross-term GEMM is thin (n=k={K}), so the copy\n\
         of X every iteration dominates — offload loses here even though it\n\
         wins 2.7x on square GEMMs. A smarter dispatch would weigh FLOPs per\n\
         copied byte, not max dimension — see the ablation table in\n\
         `cargo bench --bench fig3_gemm`."
    );
    Ok(())
}
