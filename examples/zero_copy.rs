//! The paper's future work, implemented: zero-copy offloading through
//! the open-source RISC-V IOMMU.  Side-by-side comparison of the three
//! execution paths across sizes, showing the data-copy region collapsing
//! into PTE setup.
//!
//! ```sh
//! cargo run --release --example zero_copy
//! ```

use hero_blas::blas::{DispatchPolicy, HeroBlas};
use hero_blas::config::DispatchMode;
use hero_blas::harness::report::{ms, ratio, Table};
use hero_blas::npy::NdArray;
use hero_blas::soc::trace::RegionClass;
use hero_blas::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let mut blas = HeroBlas::from_env(DispatchMode::Auto)?;
    let f = blas.engine.freq_hz();

    println!("copy-based vs IOMMU zero-copy offload, f64 GEMM\n");
    let mut table = Table::new(&[
        "n", "mode", "copy/map_ms", "total_ms", "speedup_vs_host", "iommu_pages",
    ]);

    for &n in &[64usize, 128, 256] {
        let mut rng = Rng::new(n as u64 ^ 0x2C);
        let a = NdArray::<f64>::randn(&mut rng, &[n, n]);
        let b = NdArray::<f64>::randn(&mut rng, &[n, n]);

        let mut host_total = 0.0;
        let mut reference: Option<NdArray<f64>> = None;
        for mode in [
            DispatchMode::HostOnly,
            DispatchMode::DeviceOnly,
            DispatchMode::DeviceZeroCopy,
        ] {
            blas.policy = DispatchPolicy::with_mode(mode);
            let pages_before = blas.engine.metrics.iommu_pages_mapped;
            blas.reset_run();
            let c = a.matmul(&b, &mut blas)?;
            let total = blas.trace().grand_total().to_secs(f);
            if mode == DispatchMode::HostOnly {
                host_total = total;
                reference = Some(c);
            } else if let Some(r) = &reference {
                assert!(r.max_abs_diff(&c) < 1e-9, "paths must agree");
            }
            table.row(vec![
                n.to_string(),
                mode.to_string(),
                ms(blas.trace().total(RegionClass::DataCopy).to_secs(f)),
                ms(total),
                ratio(host_total / total),
                (blas.engine.metrics.iommu_pages_mapped - pages_before).to_string(),
            ]);
        }
    }
    print!("{}", table.render());
    println!(
        "\npaper: PTE creation ~7.5x faster than copying at n=128, projecting\n\
         a 4.7x total speedup — the table above regenerates that projection\n\
         from an implemented IOMMU path (IOTLB misses show up in compute)."
    );
    Ok(())
}
