//! Quickstart: the paper's pitch in 30 lines.
//!
//! A "user application" builds two matrices and multiplies them; the
//! NumPy-style frontend routes the call through the accelerated BLAS,
//! which offloads to the Snitch PMCA.  Run with:
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use hero_blas::blas::HeroBlas;
use hero_blas::config::DispatchMode;
use hero_blas::npy::NdArray;
use hero_blas::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    // one session = NumPy linked against the heterogeneous OpenBLAS
    let mut blas = HeroBlas::from_env(DispatchMode::Auto)?;
    let mut rng = Rng::new(0x5EED);

    let n = 128;
    let a = NdArray::<f64>::randn(&mut rng, &[n, n]);
    let b = NdArray::<f64>::randn(&mut rng, &[n, n]);

    blas.reset_run();
    let c = a.matmul(&b, &mut blas)?; // dispatch decides: 128 >= threshold -> PMCA

    println!("c[0,0] = {:.6}, checksum = {:.6}", c.get2(0, 0), c.sum());
    println!("\nwhere did the time go (virtual time on the 50 MHz SoC)?");
    for (region, secs) in blas.region_secs() {
        println!("  {:<12} {:>9.3} ms", region.label(), secs * 1e3);
    }
    let offload_total = blas.trace().grand_total();
    println!("\n{}", blas.metrics().summary());

    // same call forced onto the host, for contrast
    let mut host = HeroBlas::from_env(DispatchMode::HostOnly)?;
    host.reset_run();
    let c_host = a.matmul(&b, &mut host)?;
    println!(
        "\nhost-only would take {:>9.3} ms (offload was {:.2}x faster); \
         results agree to {:.1e}",
        host.trace().grand_total().to_secs(host.engine.freq_hz()) * 1e3,
        host.trace().grand_total().0 as f64 / offload_total.0 as f64,
        c.max_abs_diff(&c_host),
    );
    Ok(())
}
