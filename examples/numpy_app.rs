//! The paper's "Python test application" (Figure 2 ⑤), reproduced:
//! a plain array program that multiplies float64 matrices of growing
//! size, run once without and once with device offloading — regenerating
//! Figure 3's stacked regions from application level.
//!
//! ```sh
//! cargo run --release --example numpy_app
//! ```

use hero_blas::blas::{DispatchPolicy, HeroBlas};
use hero_blas::config::DispatchMode;
use hero_blas::harness::report::{ms, pct, ratio, Table};
use hero_blas::npy::NdArray;
use hero_blas::soc::trace::RegionClass;
use hero_blas::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let mut blas = HeroBlas::from_env(DispatchMode::Auto)?;
    let sizes = [16usize, 32, 64, 128, 256];

    println!("numpy_app: c = a @ b, float64, measured from the application\n");
    let mut table = Table::new(&[
        "n", "host_ms", "offload_ms", "speedup", "copy", "fork/join", "compute",
    ]);

    for &n in &sizes {
        let mut rng = Rng::new(n as u64);
        let a = NdArray::<f64>::randn(&mut rng, &[n, n]);
        let b = NdArray::<f64>::randn(&mut rng, &[n, n]);
        let f = blas.engine.freq_hz();

        // without offloading
        blas.policy = DispatchPolicy::with_mode(DispatchMode::HostOnly);
        blas.reset_run();
        let c_host = a.matmul(&b, &mut blas)?;
        let host_s = blas.trace().grand_total().to_secs(f);

        // with offloading
        blas.policy = DispatchPolicy::with_mode(DispatchMode::DeviceOnly);
        blas.reset_run();
        let c_dev = a.matmul(&b, &mut blas)?;
        let dev_s = blas.trace().grand_total().to_secs(f);

        assert!(c_host.max_abs_diff(&c_dev) < 1e-9, "results must agree");

        let t = blas.trace();
        table.row(vec![
            n.to_string(),
            ms(host_s),
            ms(dev_s),
            ratio(host_s / dev_s),
            pct(t.share(RegionClass::DataCopy)),
            pct(t.share(RegionClass::ForkJoin)),
            pct(t.share(RegionClass::Compute)),
        ]);
    }
    print!("{}", table.render());
    println!("\n(the paper reports 2.71x at n=128 with ~47% of time in data copy)");
    Ok(())
}
