//! ML-framework workload (the paper's motivation: "leveraging
//! heterogeneous RISC-V SoCs in high-level applications such as ML
//! frameworks"): batched MLP inference where the WHOLE forward pass goes
//! down as one chained BLAS submission — `relu(xW1 + b1)` feeds the next
//! layer without ever returning to host DRAM (the lazy `Expr` builder
//! lowers the operator sequence onto `blas::device::gemm_chain_stage`).
//!
//! 784 -> 256 -> 128 -> 10 MLP with ReLU, batch 128 — the classic MNIST
//! shape, weights synthetic.  Compares host-only vs chained offload
//! end-to-end latency, checks the paths agree numerically, and reports
//! how many intermediate bytes the chain kept on the device.
//!
//! ```sh
//! cargo run --release --example mlp_inference
//! ```

use hero_blas::blas::{DispatchPolicy, HeroBlas};
use hero_blas::config::DispatchMode;
use hero_blas::npy::NdArray;
use hero_blas::util::rng::Rng;

struct Mlp {
    weights: Vec<NdArray<f64>>, // layer i: (in_i x out_i)
    biases: Vec<NdArray<f64>>,
}

impl Mlp {
    fn new(rng: &mut Rng, dims: &[usize]) -> Self {
        let mut weights = Vec::new();
        let mut biases = Vec::new();
        for w in dims.windows(2) {
            // Xavier-ish scaling keeps activations sane
            let scale = (2.0 / w[0] as f64).sqrt();
            weights.push(NdArray::<f64>::randn(rng, &[w[0], w[1]]).scale(scale));
            biases.push(NdArray::<f64>::zeros(&[w[1]]));
        }
        Mlp { weights, biases }
    }

    /// Forward pass: x (batch x in) -> logits (batch x out), built as ONE
    /// lazy expression — every layer's matmul + bias (+ ReLU on hidden
    /// layers) chains onto the previous layer's device-resident output.
    fn forward(&self, x: &NdArray<f64>, blas: &mut HeroBlas) -> anyhow::Result<NdArray<f64>> {
        let mut e = x.lazy();
        let last = self.weights.len() - 1;
        for (i, (w, b)) in self.weights.iter().zip(self.biases.iter()).enumerate() {
            e = e.matmul(w).add(b);
            if i < last {
                e = e.relu();
            }
        }
        Ok(e.eval(blas)?)
    }
}

fn argmax_rows(logits: &NdArray<f64>) -> Vec<usize> {
    let (rows, cols) = logits.dims2();
    (0..rows)
        .map(|r| {
            (0..cols)
                .max_by(|&a, &b| logits.get2(r, a).total_cmp(&logits.get2(r, b)))
                .unwrap()
        })
        .collect()
}

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(0x11A);
    let mlp = Mlp::new(&mut rng, &[784, 256, 128, 10]);
    let batch = NdArray::<f64>::randn(&mut rng, &[128, 784]);
    let mut blas = HeroBlas::from_env(DispatchMode::Auto)?;
    let f = blas.engine.freq_hz();

    println!("MLP 784->256->128->10, batch 128, f64 — one chained submission\n");
    let mut results = Vec::new();
    for mode in [DispatchMode::HostOnly, DispatchMode::DeviceOnly] {
        blas.policy = DispatchPolicy::with_mode(mode);
        let offloads_before = blas.engine.metrics.offloads;
        let elided_before = blas.engine.metrics.chain_bytes_elided;
        blas.reset_run();
        let logits = mlp.forward(&batch, &mut blas)?;
        let secs = blas.trace().grand_total().to_secs(f);
        println!(
            "  {:<18} {:>10.3} ms   ({} offloads, {} intermediate B kept on-device)",
            mode.to_string(),
            secs * 1e3,
            blas.engine.metrics.offloads - offloads_before,
            blas.engine.metrics.chain_bytes_elided - elided_before,
        );
        results.push((mode, logits, secs));
    }

    // the chained offload must make the same predictions as the host
    let preds: Vec<Vec<usize>> = results.iter().map(|(_, l, _)| argmax_rows(l)).collect();
    assert_eq!(preds[0], preds[1], "host vs chained-device predictions diverge");
    let err01 = results[0].1.max_abs_diff(&results[1].1);
    println!(
        "\npredictions identical across paths; max |host - device| = {err01:.2e}"
    );
    // the chain pays ONE fork-join for the 3-layer pass and keeps both
    // hidden activations (128x256 + 128x128 f64, both directions) in the
    // device DRAM partition
    println!(
        "end-to-end chained-offload speedup: {:.2}x",
        results[0].2 / results[1].2,
    );
    Ok(())
}
