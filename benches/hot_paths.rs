//! Bench: wall-clock microbenchmarks of every coordinator hot path
//! (the §Perf working set — see EXPERIMENTS.md).
//!
//! ```sh
//! cargo bench --bench hot_paths
//! ```

use std::time::Duration;

use hero_blas::blas::host;
use hero_blas::config::PlatformConfig;
use hero_blas::hero::allocator::Arena;
use hero_blas::runtime::literal::{lit_2d, to_vec_f64};
use hero_blas::runtime::ArtifactRegistry;
use hero_blas::soc::clock::Cycles;
use hero_blas::soc::dma::DmaModel;
use hero_blas::soc::trace::{RegionClass, Trace};
use hero_blas::util::bench::Bench;
use hero_blas::util::json_lite::Json;
use hero_blas::util::rng::Rng;

fn main() {
    let mut bench = Bench::with_budget(Duration::from_millis(1000), 20_000);
    let mut rng = Rng::new(0xB3);

    // ---- host GEMM kernels (the no-offload baseline's numerics) ----
    for n in [64usize, 128, 256] {
        let a = rng.normal_vec(n * n);
        let b = rng.normal_vec(n * n);
        let mut c = vec![0.0; n * n];
        bench.run(&format!("host/gemm_packed_n{n}"), || {
            host::gemm(n, n, n, 1.0, &a, &b, 0.0, &mut c);
            c[0]
        });
        if n <= 128 {
            bench.run(&format!("host/gemm_naive_n{n}"), || {
                host::naive_gemm(n, n, n, 1.0, &a, &b, 0.0, &mut c);
                c[0]
            });
        }
    }
    {
        let n = 1 << 16;
        let x = rng.normal_vec(n);
        let mut y = rng.normal_vec(n);
        bench.run("host/axpy_64k", || {
            host::axpy(1.0001, &x, &mut y);
            y[0]
        });
        bench.run("host/dot_64k", || host::dot(&x, &y));
    }

    // ---- allocator ----
    bench.run("alloc/arena_alloc_free_pairs", || {
        let mut a = Arena::new("b", 0, 1 << 20, 64);
        let mut live = Vec::new();
        for i in 0..64 {
            live.push(a.alloc(1024 + i * 64).unwrap());
        }
        for x in live {
            a.free(x).unwrap();
        }
        a.free_bytes()
    });

    // ---- SoC cost models (called once per tile step on the hot loop) ----
    let mut dma = DmaModel::new(PlatformConfig::default().dma);
    bench.run("soc/dma_cost_2d", || dma.cost_2d(64, 512));
    bench.run("soc/trace_record_1k", || {
        let mut t = Trace::new();
        for i in 0..1000 {
            t.record(RegionClass::Compute, Cycles(i), Cycles(1), "tile");
        }
        t.grand_total()
    });

    // ---- PJRT execution (the real wall-clock hot spot) ----
    let dir = hero_blas::find_artifacts_dir().expect("run `make artifacts`");
    let mut reg = ArtifactRegistry::open(&dir).unwrap();
    reg.warm_up().unwrap();
    let acc = vec![0.0f64; 64 * 64];
    let at = rng.normal_vec(64 * 64);
    let bt = rng.normal_vec(64 * 64);
    bench.run("pjrt/tile_accum_64", || {
        reg.exec(
            "gemm_tile_accum_f64",
            &[
                lit_2d(&acc, 64, 64).unwrap(),
                lit_2d(&at, 64, 64).unwrap(),
                lit_2d(&bt, 64, 64).unwrap(),
            ],
        )
        .unwrap()
    });
    let a128 = rng.normal_vec(128 * 128);
    let b128 = rng.normal_vec(128 * 128);
    let c128 = vec![0.0f64; 128 * 128];
    bench.run("pjrt/gemm_fixed_128", || {
        reg.exec(
            "gemm_f64_n128",
            &[
                lit_2d(&a128, 128, 128).unwrap(),
                lit_2d(&b128, 128, 128).unwrap(),
                lit_2d(&c128, 128, 128).unwrap(),
                hero_blas::runtime::literal::lit_1d(&[1.0f64]),
                hero_blas::runtime::literal::lit_1d(&[0.0f64]),
            ],
        )
        .unwrap()
    });

    // ---- literal conversion (feeds every PJRT call) ----
    bench.run("lit/roundtrip_64x64_f64", || {
        let l = lit_2d(&at, 64, 64).unwrap();
        to_vec_f64(&l).unwrap().len()
    });

    // ---- manifest/json parsing (startup path) ----
    let manifest_text = std::fs::read_to_string(dir.join("manifest.json")).unwrap();
    bench.run("json/parse_manifest", || Json::parse(&manifest_text).unwrap());

    println!("\n{} benchmarks complete", bench.results().len());
}
