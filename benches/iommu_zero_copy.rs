//! Bench: regenerates **R3** (the IOMMU zero-copy projection: PTE
//! creation 7.5x cheaper than copying => ~4.7x total speedup) across
//! sizes, plus **D1** (lower precision), and micro-benchmarks the IOMMU
//! model's wall-clock hot paths.
//!
//! ```sh
//! cargo bench --bench iommu_zero_copy
//! ```

use std::time::Duration;

use hero_blas::config::PlatformConfig;
use hero_blas::harness;
use hero_blas::soc::iommu::Iommu;
use hero_blas::util::bench::Bench;

fn main() {
    let artifacts = hero_blas::find_artifacts_dir().expect("run `make artifacts` first");

    // ---- R3 across sizes (virtual time) ----
    for n in [64usize, 128, 256] {
        let r = harness::run_zero_copy(PlatformConfig::default(), &artifacts, n, 7)
            .expect("zero-copy run");
        print!("{}", r.render());
        println!();
    }
    println!(
        "paper targets @128: PTE-vs-copy {:.1}x, total {:.1}x\n",
        harness::projections::PAPER_PTE_VS_COPY,
        harness::projections::PAPER_ZERO_COPY_SPEEDUP,
    );

    // ---- D1: lower-precision projection ----
    let p = harness::run_f32_projection(PlatformConfig::default(), &artifacts, 128, 7)
        .expect("f32 projection");
    print!("{}", p.render());

    // ---- IOMMU model wall-clock microbenches ----
    println!("\n== IOMMU model wall-clock hot paths ==\n");
    let mut bench = Bench::with_budget(Duration::from_millis(800), 5_000);
    let cfg = PlatformConfig::default().iommu;

    bench.run("iommu/map_unmap_128KiB", || {
        let mut i = Iommu::new(cfg.clone());
        let (m, c) = i.map(0x10_0000, 128 * 1024).unwrap();
        let t = i.unmap(&m);
        (c, t)
    });

    let mut warm = Iommu::new(cfg.clone());
    let (mapping, _) = warm.map(0x10_0000, 1 << 20).unwrap();
    bench.run("iommu/translate_hit", || {
        warm.translate(mapping.iova + 64).unwrap()
    });
    bench.run("iommu/stream_256_pages", || {
        warm.stream_translate_cost(mapping.iova, 1 << 20).unwrap()
    });
}
