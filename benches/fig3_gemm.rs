//! Bench: regenerates the paper's **Figure 3** (and headline R1/R2) and
//! benchmarks the coordinator wall-clock per point, plus a dispatch
//! ablation (DESIGN.md §4).
//!
//! Virtual time (the figure) is deterministic; wall time tells us what
//! the Rust coordinator + PJRT execution itself costs on this machine —
//! the perf pass (EXPERIMENTS.md §Perf) tracks the latter.
//!
//! ```sh
//! cargo bench --bench fig3_gemm
//! ```

use std::time::Duration;

use hero_blas::blas::{DispatchPolicy, HeroBlas};
use hero_blas::config::{DispatchMode, PlatformConfig};
use hero_blas::harness;
use hero_blas::npy::NdArray;
use hero_blas::util::bench::Bench;
use hero_blas::util::rng::Rng;

fn artifacts() -> std::path::PathBuf {
    hero_blas::find_artifacts_dir().expect("run `make artifacts` first")
}

fn main() {
    let sizes = [16usize, 32, 64, 128, 256];

    // ---- the figure itself (virtual time) ----
    println!("== Figure 3 (virtual time on the calibrated SoC) ==\n");
    let report = harness::run_fig3(
        PlatformConfig::default(),
        &artifacts(),
        &sizes,
        &[DispatchMode::HostOnly, DispatchMode::DeviceOnly],
        0x5EED,
    )
    .expect("fig3 sweep");
    print!("{}", report.render());
    print!("{}", report.summary());

    // ---- wall-clock of the coordinator per point ----
    println!("\n== coordinator wall-clock (this machine, not the SoC) ==\n");
    let mut blas = HeroBlas::new(
        PlatformConfig::default(),
        &artifacts(),
        DispatchPolicy::with_mode(DispatchMode::DeviceOnly),
    )
    .unwrap();
    blas.registry.warm_up().unwrap();
    let mut bench = Bench::with_budget(Duration::from_millis(1500), 200);
    for &n in &sizes {
        let mut rng = Rng::new(n as u64);
        let a = NdArray::<f64>::randn(&mut rng, &[n, n]);
        let b = NdArray::<f64>::randn(&mut rng, &[n, n]);
        bench.run(&format!("fig3/offload_gemm_n{n}"), || {
            blas.reset_run();
            a.matmul(&b, &mut blas).unwrap()
        });
    }
    // mode only — a wholesale policy replacement would strip the cost
    // model the ablation's Auto column below must dispatch on
    blas.policy.mode = DispatchMode::HostOnly;
    for &n in &[64usize, 128, 256] {
        let mut rng = Rng::new(n as u64);
        let a = NdArray::<f64>::randn(&mut rng, &[n, n]);
        let b = NdArray::<f64>::randn(&mut rng, &[n, n]);
        bench.run(&format!("fig3/host_gemm_n{n}"), || {
            blas.reset_run();
            a.matmul(&b, &mut blas).unwrap()
        });
    }

    // ---- ablation: dispatch policy choices (virtual time) ----
    println!("\n== ablation: dispatch policy (virtual ms; lower is better) ==\n");
    println!("{:<26} {:>10} {:>10} {:>10}", "workload", "host", "device", "auto");
    let f = blas.engine.freq_hz();
    for (label, m, n, k) in [
        ("square_32", 32usize, 32usize, 32usize),
        ("square_128", 128, 128, 128),
        ("thin_kmeans_256x4x64", 256, 4, 64),
        ("tall_512x64x64", 512, 64, 64),
    ] {
        let mut rng = Rng::new(7);
        let a = rng.normal_vec(m * k);
        let b = rng.normal_vec(k * n);
        let mut row = format!("{label:<26}");
        for mode in [DispatchMode::HostOnly, DispatchMode::DeviceOnly, DispatchMode::Auto] {
            // mode only: the Auto column must dispatch on the session's
            // cost model, not the static-threshold fallback
            blas.policy.mode = mode;
            let mut c = vec![0.0; m * n];
            blas.reset_run();
            blas.gemm(
                hero_blas::blas::Transpose::No,
                hero_blas::blas::Transpose::No,
                1.0,
                &a,
                (m, k),
                &b,
                (k, n),
                0.0,
                &mut c,
                (m, n),
            )
            .unwrap();
            let msv = blas.trace().grand_total().to_secs(f) * 1e3;
            row.push_str(&format!(" {msv:>9.2}"));
        }
        println!("{row}");
    }
    println!(
        "\nauto picks host below the crossover and device above it; the thin\n\
         k-means GEMM shows where a max-dim threshold mispredicts (see\n\
         examples/kmeans.rs)."
    );
}
