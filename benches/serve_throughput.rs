//! Bench: serving throughput vs device-pool size and batching.
//!
//! Spins the full TCP server up in-process at pool sizes 1/2/4 with
//! batching off/on and drives it with concurrent clients issuing 64x64
//! `device_only` GEMM requests (64 is *below* the paper's Figure-3
//! crossover — exactly where the batcher's fork-join amortization and
//! the pool's parallelism must earn their keep).  One JSON object per
//! line, like the fig3 harness reports (ISSUE 1 acceptance: pool 4 +
//! batching >= 2x the serial seed-style loop).
//!
//! ```sh
//! cargo bench --bench serve_throughput
//! ```

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::{mpsc, Arc, Barrier};
use std::time::{Duration, Instant};

use hero_blas::config::PlatformConfig;

const N: usize = 64;

struct Point {
    pool: u32,
    batching: bool,
    clients: usize,
    per_client: usize,
    wall: Duration,
    retries: u64,
}

impl Point {
    fn rps(&self) -> f64 {
        (self.clients * self.per_client) as f64 / self.wall.as_secs_f64()
    }

    fn json(&self, speedup_vs_serial: f64) -> String {
        format!(
            "{{\"bench\": \"serve_throughput\", \"n\": {N}, \"pool\": {}, \
             \"batching\": {}, \"clients\": {}, \"requests\": {}, \
             \"wall_ms\": {:.1}, \"rps\": {:.1}, \"retries\": {}, \
             \"speedup_vs_serial\": {:.2}}}",
            self.pool,
            self.batching,
            self.clients,
            self.clients * self.per_client,
            self.wall.as_secs_f64() * 1e3,
            self.rps(),
            self.retries,
            speedup_vs_serial,
        )
    }
}

/// Serve with the given scheduler knobs and hammer it with clients.
fn run_point(pool: u32, batching: bool, clients: usize, per_client: usize) -> Point {
    let mut cfg = PlatformConfig::default();
    cfg.sched.pool_clusters = pool;
    cfg.sched.queue_capacity = 256;
    cfg.sched.batch_window_ms = if batching { 2 } else { 0 };
    cfg.sched.batch_max = if batching { 8 } else { 1 };

    let dir = hero_blas::find_artifacts_dir().expect("run `make artifacts` first");
    let (tx, rx) = mpsc::channel();
    let server =
        std::thread::spawn(move || hero_blas::serve::serve(cfg, &dir, 0, Some(tx)));
    let port = rx.recv_timeout(Duration::from_secs(300)).expect("server ready");

    let barrier = Arc::new(Barrier::new(clients + 1));
    let workers: Vec<_> = (0..clients)
        .map(|c| {
            let barrier = Arc::clone(&barrier);
            std::thread::spawn(move || {
                let mut stream = TcpStream::connect(("127.0.0.1", port)).unwrap();
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                barrier.wait();
                let mut retries = 0u64;
                let mut done = 0usize;
                while done < per_client {
                    let seed = (c * per_client + done) as u64;
                    let line = format!(
                        "{{\"op\": \"gemm\", \"n\": {N}, \"mode\": \"device_only\", \
                         \"seed\": {seed}}}\n"
                    );
                    stream.write_all(line.as_bytes()).unwrap();
                    stream.flush().unwrap();
                    let mut resp = String::new();
                    reader.read_line(&mut resp).unwrap();
                    if resp.contains("\"ok\": true") {
                        done += 1;
                    } else if resp.contains("retry_after_ms") {
                        retries += 1;
                        std::thread::sleep(Duration::from_millis(2));
                    } else {
                        panic!("request failed: {resp}");
                    }
                }
                retries
            })
        })
        .collect();

    barrier.wait();
    let t0 = Instant::now();
    let retries = workers.into_iter().map(|w| w.join().unwrap()).sum();
    let wall = t0.elapsed();

    // stop the server
    let mut stream = TcpStream::connect(("127.0.0.1", port)).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    stream.write_all(b"{\"op\": \"shutdown\"}\n").unwrap();
    stream.flush().unwrap();
    let mut resp = String::new();
    let _ = reader.read_line(&mut resp);
    server.join().unwrap().unwrap();

    Point { pool, batching, clients, per_client, wall, retries }
}

fn main() {
    println!("== serve throughput: 64x64 device_only GEMM requests/sec ==\n");

    // the serial seed-style loop: one cluster, one client, no batching —
    // functionally the old single-session accept loop
    let serial = run_point(1, false, 1, 40);
    let base = serial.rps();
    println!("{}", serial.json(1.0));

    for pool in [1u32, 2, 4] {
        for batching in [false, true] {
            if pool == 1 && !batching {
                continue; // already measured as the serial baseline
            }
            let p = run_point(pool, batching, 8, 25);
            println!("{}", p.json(p.rps() / base));
        }
    }

    println!(
        "\npool parallelism scales wall-clock across clusters; batching\n\
         coalesces queued same-shape requests so the fork-join overhead —\n\
         dominant below the Figure-3 crossover — is paid once per batch.\n\
         Acceptance: pool=4 batching=true must show speedup_vs_serial >= 2.0."
    );
}
