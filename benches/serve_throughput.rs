//! Bench: serving throughput vs device-pool size, batching, operand
//! cache and staging pipeline.
//!
//! Spins the full TCP server up in-process and drives it with concurrent
//! clients issuing 64x64 `device_only` GEMM requests (64 is *below* the
//! paper's Figure-3 crossover — exactly where the batcher's fork-join
//! amortization and the pool's parallelism must earn their keep).  Two
//! sweeps, one JSON object per line:
//!
//! 1. pool 1/2/4 x batching off/on over the classic private-operand
//!    workload (ISSUE 1 acceptance: pool 4 + batching >= 2x the serial
//!    seed-style loop);
//! 2. cache off/on x pipeline off/on over the *shared-B reuse* workload
//!    (every request carries the same `b_seed`, the reused-weight
//!    serving pattern) — each point also reports the scheduler's
//!    simulated data-movement counters, so the copy-byte cut and the
//!    map-in/compute overlap are directly visible in the JSON (ISSUE 2
//!    acceptance: cache+pipeline cuts host->device bytes >= 2x vs the
//!    cache-off baseline, with `cache_hits > 0`).
//!
//! ```sh
//! cargo bench --bench serve_throughput            # full sweep
//! cargo bench --bench serve_throughput -- --quick # CI smoke (small)
//! ```

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::{mpsc, Arc, Barrier};
use std::time::{Duration, Instant};

use hero_blas::config::PlatformConfig;
use hero_blas::util::json_lite::Json;

const N: usize = 64;

/// One server configuration under test.
#[derive(Clone, Copy)]
struct Knobs {
    pool: u32,
    batching: bool,
    cache: bool,
    pipeline: bool,
    /// All clients share one B matrix (`b_seed`) — the cache hot path.
    shared_b: bool,
    /// Placement router: affinity routing + work stealing on/off (off =
    /// PR 1's round-robin-equivalent any-worker dequeue).
    placement: bool,
    /// Mixed-size `auto`-mode workload (sizes straddling the Figure-3
    /// crossover) instead of fixed-size device_only requests — the
    /// dispatch-model sweep.
    auto_mixed: bool,
    /// Online cost-model calibration (`[cost] calibrate`) on/off.
    calibrate: bool,
    /// Flight-recorder rings (`[sched.trace] enabled`) on/off — the
    /// tracing-overhead sweep toggles this to price the recorder.
    tracing: bool,
    /// Shape-specialized kernel registry (`[kernel] enabled`) on/off —
    /// the kernel-specialization sweep toggles this to compare the
    /// generic interpreted walk against promoted fast-path plans.
    kernel: bool,
    /// Issue every request as a fan-out `dag` graph (one trunk, two
    /// heads, shared weights) instead of a plain GEMM — the DAG-executor
    /// serving sweep.
    dag: bool,
}

/// Scheduler counters scraped over the wire before shutdown.
#[derive(Default, Clone, Copy)]
struct Counters {
    bytes_to_device: u64,
    bytes_copy_elided: u64,
    cache_hits: u64,
    pipelined_batches: u64,
    overlap_hidden_us: u64,
    stolen: u64,
    affine_routed: u64,
    /// Live calibrated crossover estimates scraped from the metrics op.
    crossover_gemm_n: u64,
    crossover_gemm_warm_n: u64,
    /// End-to-end latency percentiles (all op classes merged) from the
    /// scheduler's log-scale histograms.
    p50_us: u64,
    p99_us: u64,
    p999_us: u64,
    /// Aggregate span breakdown (total microseconds per stage).
    span_queue_us: u64,
    span_route_us: u64,
    span_linger_us: u64,
    span_stage_us: u64,
    span_execute_us: u64,
    span_finish_us: u64,
    /// Kernel-registry counters: plans compiled, fast-path walks, and
    /// generic-walk fallbacks taken while the registry was enabled.
    kernel_specialized: u64,
    kernel_hits: u64,
    kernel_fallbacks: u64,
    /// Specialized-walk gemm crossover estimate (dual line to gemm_n).
    crossover_gemm_spec_n: u64,
    /// DAG-executor counters: graphs served, nodes executed, interior
    /// edge bytes that never returned to host, cross-request splices.
    dags: u64,
    dag_nodes: u64,
    dag_bytes_elided: u64,
    dag_fused_requests: u64,
}

struct Point {
    knobs: Knobs,
    clients: usize,
    per_client: usize,
    wall: Duration,
    retries: u64,
    counters: Counters,
}

impl Point {
    fn rps(&self) -> f64 {
        (self.clients * self.per_client) as f64 / self.wall.as_secs_f64()
    }

    fn json(&self, speedup_vs_serial: f64) -> String {
        let k = &self.knobs;
        let c = &self.counters;
        format!(
            "{{\"bench\": \"serve_throughput\", \"n\": {N}, \"pool\": {}, \
             \"batching\": {}, \"cache\": {}, \"pipeline\": {}, \
             \"shared_b\": {}, \"placement\": {}, \"auto_mixed\": {}, \
             \"calibrate\": {}, \"tracing\": {}, \"kernel\": {}, \
             \"dag\": {}, \"clients\": {}, \"requests\": {}, \
             \"wall_ms\": {:.1}, \"rps\": {:.1}, \"retries\": {}, \
             \"bytes_to_device\": {}, \"bytes_copy_elided\": {}, \
             \"cache_hits\": {}, \"pipelined_batches\": {}, \
             \"overlap_hidden_us\": {}, \"stolen\": {}, \
             \"affine_routed\": {}, \"kernel_specialized\": {}, \
             \"kernel_hits\": {}, \"kernel_fallbacks\": {}, \
             \"dags\": {}, \"dag_nodes\": {}, \"dag_bytes_elided\": {}, \
             \"dag_fused_requests\": {}, \
             \"crossover_estimate\": {{\"gemm_n\": {}, \"gemm_warm_n\": {}, \
             \"gemm_spec_n\": {}}}, \
             \"p50_us\": {}, \"p99_us\": {}, \"p999_us\": {}, \
             \"spans\": {{\"queue_us\": {}, \"route_us\": {}, \
             \"linger_us\": {}, \"stage_us\": {}, \"execute_us\": {}, \
             \"finish_us\": {}}}, \
             \"speedup_vs_serial\": {:.2}}}",
            k.pool,
            k.batching,
            k.cache,
            k.pipeline,
            k.shared_b,
            k.placement,
            k.auto_mixed,
            k.calibrate,
            k.tracing,
            k.kernel,
            k.dag,
            self.clients,
            self.clients * self.per_client,
            self.wall.as_secs_f64() * 1e3,
            self.rps(),
            self.retries,
            c.bytes_to_device,
            c.bytes_copy_elided,
            c.cache_hits,
            c.pipelined_batches,
            c.overlap_hidden_us,
            c.stolen,
            c.affine_routed,
            c.kernel_specialized,
            c.kernel_hits,
            c.kernel_fallbacks,
            c.dags,
            c.dag_nodes,
            c.dag_bytes_elided,
            c.dag_fused_requests,
            c.crossover_gemm_n,
            c.crossover_gemm_warm_n,
            c.crossover_gemm_spec_n,
            c.p50_us,
            c.p99_us,
            c.p999_us,
            c.span_queue_us,
            c.span_route_us,
            c.span_linger_us,
            c.span_stage_us,
            c.span_execute_us,
            c.span_finish_us,
            speedup_vs_serial,
        )
    }
}

/// Sizes of the mixed `auto`-mode workload: straddling the Figure-3
/// crossover, so the dispatch model splits them host/device.
const MIXED_SIZES: [usize; 4] = [32, 64, 96, 128];

fn request_line(client: usize, per_client: usize, done: usize, knobs: &Knobs) -> String {
    let seed = (client * per_client + done) as u64;
    if knobs.dag {
        // fan-out graph: one 256->128 trunk feeding two 128->64 heads,
        // all weights shared across clients — the trunk is staged once
        // and its output pinned for both consumers
        return format!(
            "{{\"op\": \"dag\", \"m\": {N}, \"d0\": 256, \"nodes\": [\
             {{\"op\": \"gemm\", \"n\": 128, \"b_seed\": 7}}, \
             {{\"op\": \"gemm\", \"n\": 64, \"src\": 0, \"b_seed\": 8}}, \
             {{\"op\": \"gemm\", \"n\": 64, \"src\": 0, \"b_seed\": 9}}], \
             \"mode\": \"device_only\", \"seed\": {seed}}}\n"
        );
    }
    if knobs.auto_mixed {
        let n = MIXED_SIZES[done % MIXED_SIZES.len()];
        return format!(
            "{{\"op\": \"gemm\", \"n\": {n}, \"mode\": \"auto\", \
             \"seed\": {seed}}}\n"
        );
    }
    if knobs.shared_b {
        format!(
            "{{\"op\": \"gemm\", \"n\": {N}, \"mode\": \"device_only\", \
             \"seed\": {seed}, \"b_seed\": 42}}\n"
        )
    } else {
        format!(
            "{{\"op\": \"gemm\", \"n\": {N}, \"mode\": \"device_only\", \
             \"seed\": {seed}}}\n"
        )
    }
}

/// Serve with the given scheduler knobs and hammer it with clients.
fn run_point(knobs: Knobs, clients: usize, per_client: usize) -> Point {
    let mut cfg = PlatformConfig::default();
    cfg.sched.pool_clusters = knobs.pool;
    cfg.sched.queue_capacity = 256;
    cfg.sched.batch_window_ms = if knobs.batching { 2 } else { 0 };
    cfg.sched.batch_max = if knobs.batching { 8 } else { 1 };
    cfg.sched.cache.cache_frac = if knobs.cache { 0.4 } else { 0.0 };
    cfg.sched.cache.cache_max_entries = 64;
    cfg.sched.cache.pipeline_depth = if knobs.pipeline { 2 } else { 1 };
    cfg.sched.placement.affinity = knobs.placement;
    cfg.sched.placement.steal = knobs.placement;
    cfg.cost.calibrate = knobs.calibrate;
    cfg.sched.trace.enabled = knobs.tracing;
    cfg.kernel.enabled = knobs.kernel;
    // low enough that the bench's per-shape launch counts cross it and
    // promotion fires mid-run (the default is sized for long services)
    cfg.kernel.promote_after = 4;

    let dir = hero_blas::find_artifacts_dir().expect("run `make artifacts` first");
    let (tx, rx) = mpsc::channel();
    let server =
        std::thread::spawn(move || hero_blas::serve::serve(cfg, &dir, 0, Some(tx)));
    let port = rx.recv_timeout(Duration::from_secs(300)).expect("server ready");

    let barrier = Arc::new(Barrier::new(clients + 1));
    let workers: Vec<_> = (0..clients)
        .map(|c| {
            let barrier = Arc::clone(&barrier);
            std::thread::spawn(move || {
                let mut stream = TcpStream::connect(("127.0.0.1", port)).unwrap();
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                barrier.wait();
                let mut retries = 0u64;
                let mut done = 0usize;
                while done < per_client {
                    let line = request_line(c, per_client, done, &knobs);
                    stream.write_all(line.as_bytes()).unwrap();
                    stream.flush().unwrap();
                    let mut resp = String::new();
                    reader.read_line(&mut resp).unwrap();
                    if resp.contains("\"ok\": true") {
                        done += 1;
                    } else if resp.contains("retry_after_ms") {
                        retries += 1;
                        std::thread::sleep(Duration::from_millis(2));
                    } else {
                        panic!("request failed: {resp}");
                    }
                }
                retries
            })
        })
        .collect();

    barrier.wait();
    let t0 = Instant::now();
    let retries = workers.into_iter().map(|w| w.join().unwrap()).sum();
    let wall = t0.elapsed();

    // scrape the data-movement counters, then stop the server
    let mut stream = TcpStream::connect(("127.0.0.1", port)).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    stream.write_all(b"{\"op\": \"metrics\"}\n").unwrap();
    stream.flush().unwrap();
    let mut resp = String::new();
    reader.read_line(&mut resp).unwrap();
    let m = Json::parse(resp.trim()).expect("metrics JSON");
    let get = |k: &str| m.get(k).and_then(|v| v.as_u64()).unwrap_or(0);
    let xget = |k: &str| {
        m.get("crossover_estimate")
            .and_then(|x| x.get(k))
            .and_then(|v| v.as_u64())
            .unwrap_or(0)
    };
    let sget = |k: &str| {
        m.get("spans").and_then(|x| x.get(k)).and_then(|v| v.as_u64()).unwrap_or(0)
    };
    let counters = Counters {
        bytes_to_device: get("bytes_to_device"),
        bytes_copy_elided: get("bytes_copy_elided"),
        cache_hits: get("cache_hits"),
        pipelined_batches: get("pipelined_batches"),
        overlap_hidden_us: get("overlap_hidden_us"),
        stolen: get("stolen"),
        affine_routed: get("affine_routed"),
        crossover_gemm_n: xget("gemm_n"),
        crossover_gemm_warm_n: xget("gemm_warm_n"),
        p50_us: get("p50_us"),
        p99_us: get("p99_us"),
        p999_us: get("p999_us"),
        span_queue_us: sget("queue_us"),
        span_route_us: sget("route_us"),
        span_linger_us: sget("linger_us"),
        span_stage_us: sget("stage_us"),
        span_execute_us: sget("execute_us"),
        span_finish_us: sget("finish_us"),
        kernel_specialized: get("kernel_specialized"),
        kernel_hits: get("kernel_hits"),
        kernel_fallbacks: get("kernel_fallbacks"),
        crossover_gemm_spec_n: xget("gemm_spec_n"),
        dags: get("dags"),
        dag_nodes: get("dag_nodes"),
        dag_bytes_elided: get("dag_bytes_elided"),
        dag_fused_requests: get("dag_fused_requests"),
    };
    stream.write_all(b"{\"op\": \"shutdown\"}\n").unwrap();
    stream.flush().unwrap();
    let mut resp = String::new();
    let _ = reader.read_line(&mut resp);
    server.join().unwrap().unwrap();

    Point { knobs, clients, per_client, wall, retries, counters }
}

/// The MLP-shaped chain sweep (sweep 5): every request runs the same
/// 64x[256->128->64] layer stack with shared weights (`b_seeds`) and a
/// private activation.  `chained = false` issues the links as separate
/// per-op offloads (the paper's one-call-at-a-time behavior);
/// `chained = true` runs them as ONE submission with device-resident
/// intermediates.  Returns the wall time, the scraped data-movement
/// counters and every request's checksum keyed by seed — the two modes
/// must agree bit-for-bit.
fn run_chain_point(
    chained: bool,
    clients: usize,
    per_client: usize,
) -> (Duration, u64, u64, u64, BTreeMap<u64, String>) {
    let mut cfg = PlatformConfig::default();
    cfg.sched.pool_clusters = 2;
    cfg.sched.queue_capacity = 256;
    cfg.sched.batch_window_ms = 0;
    cfg.sched.batch_max = 8;
    cfg.sched.cache.cache_frac = 0.4;
    cfg.sched.cache.cache_max_entries = 64;

    let dir = hero_blas::find_artifacts_dir().expect("run `make artifacts` first");
    let (tx, rx) = mpsc::channel();
    let server =
        std::thread::spawn(move || hero_blas::serve::serve(cfg, &dir, 0, Some(tx)));
    let port = rx.recv_timeout(Duration::from_secs(300)).expect("server ready");

    let barrier = Arc::new(Barrier::new(clients + 1));
    let workers: Vec<_> = (0..clients)
        .map(|c| {
            let barrier = Arc::clone(&barrier);
            std::thread::spawn(move || {
                let mut stream = TcpStream::connect(("127.0.0.1", port)).unwrap();
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                barrier.wait();
                let mut sums = BTreeMap::new();
                let mut done = 0usize;
                while done < per_client {
                    let seed = (c * per_client + done) as u64;
                    let line = format!(
                        "{{\"op\": \"chain\", \"m\": 64, \"dims\": [256, 128, 64], \
                         \"mode\": \"device_only\", \"seed\": {seed}, \
                         \"b_seeds\": [7, 8], \"chained\": {chained}}}\n"
                    );
                    stream.write_all(line.as_bytes()).unwrap();
                    stream.flush().unwrap();
                    let mut resp = String::new();
                    reader.read_line(&mut resp).unwrap();
                    if resp.contains("\"ok\": true") {
                        let j = Json::parse(resp.trim()).expect("chain response");
                        // compare the exact textual f64 (bit-identity proxy)
                        let sum = format!(
                            "{:?}",
                            j.get("checksum").and_then(|v| v.as_f64()).unwrap()
                        );
                        sums.insert(seed, sum);
                        done += 1;
                    } else if resp.contains("retry_after_ms") {
                        std::thread::sleep(Duration::from_millis(2));
                    } else {
                        panic!("chain request failed: {resp}");
                    }
                }
                sums
            })
        })
        .collect();

    barrier.wait();
    let t0 = Instant::now();
    let mut sums = BTreeMap::new();
    for w in workers {
        sums.extend(w.join().unwrap());
    }
    let wall = t0.elapsed();

    let mut stream = TcpStream::connect(("127.0.0.1", port)).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    stream.write_all(b"{\"op\": \"metrics\"}\n").unwrap();
    stream.flush().unwrap();
    let mut resp = String::new();
    reader.read_line(&mut resp).unwrap();
    let m = Json::parse(resp.trim()).expect("metrics JSON");
    let get = |k: &str| m.get(k).and_then(|v| v.as_u64()).unwrap_or(0);
    let (bytes, elided, chains) =
        (get("bytes_to_device"), get("chain_bytes_elided"), get("chains"));
    stream.write_all(b"{\"op\": \"shutdown\"}\n").unwrap();
    stream.flush().unwrap();
    let mut resp = String::new();
    let _ = reader.read_line(&mut resp);
    server.join().unwrap().unwrap();

    (wall, bytes, elided, chains, sums)
}

/// The DAG-vs-chain point (sweep 9): the same 64x[256->128->64] MLP
/// stack as sweep 5, issued either as the classic `chain` op
/// (`as_dag = false`) or as the equivalent linear two-node `dag` graph
/// (`as_dag = true`).  A linear single-consumer DAG lowers to the
/// chain's exact charge sequence, so the two modes must agree
/// bit-for-bit and the dag points must elide the same interior bytes.
/// Returns the wall time, bytes_to_device, the mode's elision counter
/// (`chain_bytes_elided` / `dag_bytes_elided`), the graph count
/// (`chains` / `dags`) and every request's checksum keyed by seed.
fn run_dag_point(
    as_dag: bool,
    clients: usize,
    per_client: usize,
) -> (Duration, u64, u64, u64, BTreeMap<u64, String>) {
    let mut cfg = PlatformConfig::default();
    cfg.sched.pool_clusters = 2;
    cfg.sched.queue_capacity = 256;
    cfg.sched.batch_window_ms = 0;
    cfg.sched.batch_max = 8;
    cfg.sched.cache.cache_frac = 0.4;
    cfg.sched.cache.cache_max_entries = 64;

    let dir = hero_blas::find_artifacts_dir().expect("run `make artifacts` first");
    let (tx, rx) = mpsc::channel();
    let server =
        std::thread::spawn(move || hero_blas::serve::serve(cfg, &dir, 0, Some(tx)));
    let port = rx.recv_timeout(Duration::from_secs(300)).expect("server ready");

    let barrier = Arc::new(Barrier::new(clients + 1));
    let workers: Vec<_> = (0..clients)
        .map(|c| {
            let barrier = Arc::clone(&barrier);
            std::thread::spawn(move || {
                let mut stream = TcpStream::connect(("127.0.0.1", port)).unwrap();
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                barrier.wait();
                let mut sums = BTreeMap::new();
                let mut done = 0usize;
                while done < per_client {
                    let seed = (c * per_client + done) as u64;
                    let line = if as_dag {
                        format!(
                            "{{\"op\": \"dag\", \"m\": 64, \"d0\": 256, \"nodes\": [\
                             {{\"op\": \"gemm\", \"n\": 128, \"b_seed\": 7}}, \
                             {{\"op\": \"gemm\", \"n\": 64, \"src\": 0, \"b_seed\": 8}}], \
                             \"mode\": \"device_only\", \"seed\": {seed}}}\n"
                        )
                    } else {
                        format!(
                            "{{\"op\": \"chain\", \"m\": 64, \"dims\": [256, 128, 64], \
                             \"mode\": \"device_only\", \"seed\": {seed}, \
                             \"b_seeds\": [7, 8], \"chained\": true}}\n"
                        )
                    };
                    stream.write_all(line.as_bytes()).unwrap();
                    stream.flush().unwrap();
                    let mut resp = String::new();
                    reader.read_line(&mut resp).unwrap();
                    if resp.contains("\"ok\": true") {
                        let j = Json::parse(resp.trim()).expect("dag response");
                        // compare the exact textual f64 (bit-identity proxy)
                        let sum = format!(
                            "{:?}",
                            j.get("checksum").and_then(|v| v.as_f64()).unwrap()
                        );
                        sums.insert(seed, sum);
                        done += 1;
                    } else if resp.contains("retry_after_ms") {
                        std::thread::sleep(Duration::from_millis(2));
                    } else {
                        panic!("dag request failed: {resp}");
                    }
                }
                sums
            })
        })
        .collect();

    barrier.wait();
    let t0 = Instant::now();
    let mut sums = BTreeMap::new();
    for w in workers {
        sums.extend(w.join().unwrap());
    }
    let wall = t0.elapsed();

    let mut stream = TcpStream::connect(("127.0.0.1", port)).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    stream.write_all(b"{\"op\": \"metrics\"}\n").unwrap();
    stream.flush().unwrap();
    let mut resp = String::new();
    reader.read_line(&mut resp).unwrap();
    let m = Json::parse(resp.trim()).expect("metrics JSON");
    let get = |k: &str| m.get(k).and_then(|v| v.as_u64()).unwrap_or(0);
    let (bytes, elided, graphs) = if as_dag {
        (get("bytes_to_device"), get("dag_bytes_elided"), get("dags"))
    } else {
        (get("bytes_to_device"), get("chain_bytes_elided"), get("chains"))
    };
    stream.write_all(b"{\"op\": \"shutdown\"}\n").unwrap();
    stream.flush().unwrap();
    let mut resp = String::new();
    let _ = reader.read_line(&mut resp);
    server.join().unwrap().unwrap();

    (wall, bytes, elided, graphs, sums)
}

/// The fault-matrix point (sweep 6): same shared-B GEMM workload, but
/// with `[sched.fault]` ON and cluster 0 failing half its launches at
/// the staging seam.  Every request must still complete `ok: true`
/// (retried onto cluster 1, or host-fallback `degraded: true`); the
/// point reports the recovery counters.  Emitted as a `summary` line so
/// `tools/bench_compare` keeps gating the fault-FREE sweeps only —
/// recovery wall time is not a perf trajectory.
fn run_fault_point(clients: usize, per_client: usize) -> (Duration, u64, String) {
    let mut cfg = PlatformConfig::default();
    cfg.sched.pool_clusters = 2;
    cfg.sched.queue_capacity = 256;
    cfg.sched.batch_window_ms = 0;
    cfg.sched.batch_max = 8;
    cfg.sched.cache.cache_frac = 0.4;
    cfg.sched.cache.cache_max_entries = 64;
    cfg.sched.fault.enabled = true;
    cfg.sched.fault.seed = 1;
    cfg.sched.fault.staging_rate = 0.5;
    cfg.sched.fault.target_cluster = 0;
    cfg.sched.fault.backoff_base_ms = 1;
    cfg.sched.fault.quarantine_threshold = 3;
    cfg.sched.fault.probe_interval = 16;

    let dir = hero_blas::find_artifacts_dir().expect("run `make artifacts` first");
    let (tx, rx) = mpsc::channel();
    let server =
        std::thread::spawn(move || hero_blas::serve::serve(cfg, &dir, 0, Some(tx)));
    let port = rx.recv_timeout(Duration::from_secs(300)).expect("server ready");

    let barrier = Arc::new(Barrier::new(clients + 1));
    let workers: Vec<_> = (0..clients)
        .map(|c| {
            let barrier = Arc::clone(&barrier);
            std::thread::spawn(move || {
                let mut stream = TcpStream::connect(("127.0.0.1", port)).unwrap();
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                barrier.wait();
                let mut degraded = 0u64;
                let mut done = 0usize;
                while done < per_client {
                    let seed = (c * per_client + done) as u64;
                    let line = format!(
                        "{{\"op\": \"gemm\", \"n\": {N}, \"mode\": \"device_only\", \
                         \"seed\": {seed}, \"b_seed\": 42}}\n"
                    );
                    stream.write_all(line.as_bytes()).unwrap();
                    stream.flush().unwrap();
                    let mut resp = String::new();
                    reader.read_line(&mut resp).unwrap();
                    if resp.contains("\"ok\": true") {
                        if resp.contains("\"degraded\": true") {
                            degraded += 1;
                        }
                        done += 1;
                    } else if resp.contains("retry_after_ms") {
                        std::thread::sleep(Duration::from_millis(2));
                    } else {
                        panic!("fault-matrix request failed: {resp}");
                    }
                }
                degraded
            })
        })
        .collect();

    barrier.wait();
    let t0 = Instant::now();
    let degraded: u64 = workers.into_iter().map(|w| w.join().unwrap()).sum();
    let wall = t0.elapsed();

    let mut stream = TcpStream::connect(("127.0.0.1", port)).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    stream.write_all(b"{\"op\": \"metrics\"}\n").unwrap();
    stream.flush().unwrap();
    let mut resp = String::new();
    reader.read_line(&mut resp).unwrap();
    let m = Json::parse(resp.trim()).expect("metrics JSON");
    let get = |k: &str| m.get(k).and_then(|v| v.as_u64()).unwrap_or(0);
    let counters = format!(
        "\"faults_injected\": {}, \"retries\": {}, \"quarantined\": {}, \
         \"host_fallbacks\": {}, \"cache_invalidated_bytes\": {}, \
         \"pin_leaks\": {}, \"failed\": {}, \"degraded_replies\": {degraded}",
        get("faults_injected"),
        get("retries"),
        get("quarantined"),
        get("host_fallbacks"),
        get("cache_invalidated_bytes"),
        get("pin_leaks"),
        get("failed"),
    );
    let faults = get("faults_injected");
    stream.write_all(b"{\"op\": \"shutdown\"}\n").unwrap();
    stream.flush().unwrap();
    let mut resp = String::new();
    let _ = reader.read_line(&mut resp);
    server.join().unwrap().unwrap();

    (wall, faults, counters)
}

/// Snapshot sink: every JSON line goes to stdout and (with `--out FILE`)
/// to a JSONL file `tools/bench_compare` can diff against a committed
/// baseline such as `BENCH_6.json`.
struct Snapshot {
    file: Option<std::fs::File>,
}

impl Snapshot {
    fn emit(&mut self, line: String) {
        println!("{line}");
        if let Some(f) = &mut self.file {
            writeln!(f, "{line}").expect("write snapshot line");
        }
    }
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--quick");
    let out_path = args
        .iter()
        .position(|a| a == "--out")
        .and_then(|i| args.get(i + 1))
        .cloned();
    let mut snap = Snapshot {
        file: out_path
            .as_deref()
            .map(|p| std::fs::File::create(p).expect("create snapshot file")),
    };
    let (clients, per_client, serial_reqs) =
        if quick { (4, 6, 12) } else { (8, 25, 40) };

    println!("== serve throughput: {N}x{N} device_only GEMM requests/sec ==\n");

    // the serial seed-style loop: one cluster, one client, no batching —
    // functionally the old single-session accept loop
    let base_knobs = Knobs {
        pool: 1,
        batching: false,
        cache: false,
        pipeline: false,
        shared_b: false,
        placement: false,
        auto_mixed: false,
        calibrate: false,
        tracing: true, // the recorder's default-ON posture
        kernel: true,  // the registry's default-ON posture
        dag: false,
    };
    let serial = run_point(base_knobs, 1, serial_reqs);
    let base = serial.rps();
    snap.emit(serial.json(1.0));

    // sweep 1: pool x batching (private operands, as in ISSUE 1)
    for pool in [1u32, 2, 4] {
        for batching in [false, true] {
            if pool == 1 && !batching {
                continue; // already measured as the serial baseline
            }
            let p = run_point(
                Knobs { pool, batching, ..base_knobs },
                clients,
                per_client,
            );
            snap.emit(p.json(p.rps() / base));
        }
    }

    // sweep 2: cache x pipeline on the shared-B reuse workload — the
    // copy-byte column is the headline (simulated bytes, not wall time)
    println!();
    let mut baseline_bytes = 0u64;
    for (cache, pipeline) in [(false, false), (true, false), (false, true), (true, true)]
    {
        let p = run_point(
            Knobs {
                pool: 2,
                batching: true,
                cache,
                pipeline,
                shared_b: true,
                ..base_knobs
            },
            clients,
            per_client,
        );
        if !cache && !pipeline {
            baseline_bytes = p.counters.bytes_to_device;
        }
        snap.emit(p.json(p.rps() / base));
        if cache && pipeline && baseline_bytes > 0 {
            let cut = baseline_bytes as f64 / p.counters.bytes_to_device.max(1) as f64;
            snap.emit(format!(
                "{{\"bench\": \"serve_throughput\", \"summary\": \
                 \"copy_bytes_cut\", \"value\": {cut:.2}}}"
            ));
        }
    }

    // sweep 3: placement off/on with the cache on (shared-B workload) —
    // affinity routes every same-B request at one warm cluster instead
    // of warming each cluster separately, and stealing keeps the other
    // clusters busy; the placement-on point should show affine_routed >
    // 0 and fewer bytes_to_device than placement-off at the same knobs
    println!();
    let mut off_bytes = 0u64;
    for placement in [false, true] {
        let p = run_point(
            Knobs {
                pool: 2,
                batching: true,
                cache: true,
                pipeline: true,
                shared_b: true,
                placement,
                ..base_knobs
            },
            clients,
            per_client,
        );
        if !placement {
            off_bytes = p.counters.bytes_to_device;
        }
        snap.emit(p.json(p.rps() / base));
        if placement && off_bytes > 0 {
            let cut = off_bytes as f64 / p.counters.bytes_to_device.max(1) as f64;
            snap.emit(format!(
                "{{\"bench\": \"serve_throughput\", \"summary\": \
                 \"placement_bytes_cut\", \"value\": {cut:.2}}}"
            ));
        }
    }

    // sweep 4: dispatch-model threshold sweep — a mixed-size auto-mode
    // workload (sizes straddling the Figure-3 crossover) with the cost
    // model static vs online-calibrated; every point reports the live
    // crossover_estimate the serve metrics op exposes
    println!();
    for calibrate in [false, true] {
        let p = run_point(
            Knobs {
                pool: 2,
                batching: true,
                auto_mixed: true,
                calibrate,
                ..base_knobs
            },
            clients,
            per_client,
        );
        snap.emit(p.json(p.rps() / base));
    }

    // sweep 5: chained vs per-op execution of an MLP-shaped dependent
    // sequence (64x[256->128->64], shared weights, private activations).
    // The chained points must cut bytes_to_device (intermediates never
    // round-trip) with checksums bit-identical to per-op execution.
    println!();
    let (uw, ub, ue, uc, usums) = run_chain_point(false, clients, per_client);
    snap.emit(format!(
        "{{\"bench\": \"serve_throughput\", \"workload\": \"chain_mlp\", \
         \"chained\": false, \"requests\": {}, \"wall_ms\": {:.1}, \
         \"bytes_to_device\": {ub}, \"chain_bytes_elided\": {ue}, \
         \"chains\": {uc}}}",
        clients * per_client,
        uw.as_secs_f64() * 1e3,
    ));
    let (cw, cb, ce, cc, csums) = run_chain_point(true, clients, per_client);
    snap.emit(format!(
        "{{\"bench\": \"serve_throughput\", \"workload\": \"chain_mlp\", \
         \"chained\": true, \"requests\": {}, \"wall_ms\": {:.1}, \
         \"bytes_to_device\": {cb}, \"chain_bytes_elided\": {ce}, \
         \"chains\": {cc}}}",
        clients * per_client,
        cw.as_secs_f64() * 1e3,
    ));
    let identical = usums == csums;
    let bytes_cut = ub as f64 / cb.max(1) as f64;
    snap.emit(format!(
        "{{\"bench\": \"serve_throughput\", \"summary\": \"chain_bytes_cut\", \
         \"value\": {bytes_cut:.2}, \"chain_bytes_elided\": {ce}, \
         \"checksums_identical\": {identical}}}"
    ));
    assert!(
        identical,
        "chained checksums diverged from per-op execution"
    );
    assert!(
        ce > 0,
        "chained run elided no intermediate bytes (chain_bytes_elided = 0)"
    );
    assert!(
        cb < ub,
        "chained bytes_to_device {cb} not below unchained {ub}"
    );

    // sweep 6: flight-recorder overhead — the pool x batch point with
    // the trace rings OFF vs ON.  The recorder is lock-free and
    // fixed-capacity; it must cost < 5% rps on the hot path.
    println!();
    let mut rps_off = 0.0;
    for tracing in [false, true] {
        let p = run_point(
            Knobs { pool: 2, batching: true, tracing, ..base_knobs },
            clients,
            per_client,
        );
        snap.emit(p.json(p.rps() / base));
        if !tracing {
            rps_off = p.rps();
        } else {
            let overhead_pct = (rps_off - p.rps()) / rps_off * 100.0;
            snap.emit(format!(
                "{{\"bench\": \"serve_throughput\", \"summary\": \
                 \"tracing_overhead\", \"rps_off\": {rps_off:.1}, \
                 \"rps_on\": {:.1}, \"overhead_pct\": {overhead_pct:.2}}}",
                p.rps(),
            ));
            // quick mode's request counts are too small for a stable
            // percentage; the full run enforces the budget
            if !quick {
                assert!(
                    overhead_pct < 5.0,
                    "flight recorder costs {overhead_pct:.2}% rps (budget 5%)"
                );
            }
        }
    }

    // sweep 7: kernel specialization — the same fixed-shape device_only
    // workload with the shape-specialized registry OFF vs ON.  With the
    // registry on, the hot (gemm, f64, 64-pad) key crosses promote_after
    // early and the rest of the run takes the compiled fast-path walk
    // (bit-identical numerics, leaner virtual-time charge schedule) —
    // the ON point must show kernel_specialized > 0 and kernel_hits > 0
    // and must not lose throughput to the registry's bookkeeping.
    println!();
    let mut rps_generic = 0.0;
    for kernel in [false, true] {
        let p = run_point(
            Knobs { pool: 2, batching: true, kernel, ..base_knobs },
            clients,
            per_client,
        );
        snap.emit(p.json(p.rps() / base));
        if !kernel {
            rps_generic = p.rps();
            assert_eq!(
                p.counters.kernel_hits, 0,
                "registry OFF must record no fast-path hits"
            );
        } else {
            snap.emit(format!(
                "{{\"bench\": \"serve_throughput\", \"summary\": \
                 \"kernel_specialization\", \"rps_generic\": {rps_generic:.1}, \
                 \"rps_specialized\": {:.1}, \"kernel_specialized\": {}, \
                 \"kernel_hits\": {}, \"kernel_fallbacks\": {}, \
                 \"gemm_spec_n\": {}}}",
                p.rps(),
                p.counters.kernel_specialized,
                p.counters.kernel_hits,
                p.counters.kernel_fallbacks,
                p.counters.crossover_gemm_spec_n,
            ));
            assert!(
                p.counters.kernel_specialized > 0,
                "registry ON promoted no kernels (promote_after 4)"
            );
            assert!(
                p.counters.kernel_hits > 0,
                "registry ON served no fast-path walks"
            );
            // the walks are bit-identical and the registry adds one
            // bounded map lookup per stage, so throughput must hold;
            // quick mode's request counts are too small for a stable
            // wall-clock ratio, so only the full run gates on it
            if !quick {
                assert!(
                    p.rps() >= rps_generic * 0.9,
                    "specialized rps {:.1} fell >10% below generic {rps_generic:.1}",
                    p.rps(),
                );
            }
        }
    }

    // sweep 8: the fault matrix — cluster 0 failing half its launches.
    // Every request must still complete; the summary line carries the
    // recovery counters (and, being a summary, is NOT gated by
    // bench_compare: fault-injected wall time is not a perf trajectory).
    println!();
    let (fw, faults, fault_counters) = run_fault_point(clients, per_client);
    snap.emit(format!(
        "{{\"bench\": \"serve_throughput\", \"summary\": \"fault_matrix\", \
         \"requests\": {}, \"wall_ms\": {:.1}, {fault_counters}}}",
        clients * per_client,
        fw.as_secs_f64() * 1e3,
    ));
    assert!(
        faults >= 1,
        "fault matrix injected no faults (cluster 0 at staging_rate 0.5)"
    );

    // sweep 9: dag vs chain — the sweep-5 MLP stack issued as the
    // classic `chain` op vs the equivalent linear `dag` graph.  A
    // linear single-consumer DAG lowers to the chain's exact charge
    // sequence, so checksums must be bit-identical and the dag points
    // must elide interior bytes just like the chain does.
    println!();
    let (qw, qb, qe, qg, qsums) = run_dag_point(false, clients, per_client);
    snap.emit(format!(
        "{{\"bench\": \"serve_throughput\", \"workload\": \"dag_mlp\", \
         \"dag\": false, \"requests\": {}, \"wall_ms\": {:.1}, \
         \"bytes_to_device\": {qb}, \"bytes_elided\": {qe}, \
         \"graphs\": {qg}}}",
        clients * per_client,
        qw.as_secs_f64() * 1e3,
    ));
    let (gw, gb, ge, gg, gsums) = run_dag_point(true, clients, per_client);
    snap.emit(format!(
        "{{\"bench\": \"serve_throughput\", \"workload\": \"dag_mlp\", \
         \"dag\": true, \"requests\": {}, \"wall_ms\": {:.1}, \
         \"bytes_to_device\": {gb}, \"bytes_elided\": {ge}, \
         \"graphs\": {gg}}}",
        clients * per_client,
        gw.as_secs_f64() * 1e3,
    ));
    let dag_identical = qsums == gsums;
    snap.emit(format!(
        "{{\"bench\": \"serve_throughput\", \"summary\": \"dag_vs_chain\", \
         \"checksums_identical\": {dag_identical}, \
         \"dag_bytes_elided\": {ge}, \"dags\": {gg}}}"
    ));
    assert!(
        dag_identical,
        "linear dag checksums diverged from the equivalent chain"
    );
    assert!(
        ge > 0,
        "dag run elided no interior bytes (dag_bytes_elided = 0)"
    );
    assert_eq!(
        gg as usize,
        clients * per_client,
        "every request should have run as one dag"
    );

    // the fan-out serving point: every request a 3-node trunk+2-head
    // graph with shared weights, through the full router (the `dag`
    // knob point in the perf trajectory)
    let p = run_point(
        Knobs { pool: 2, cache: true, placement: true, dag: true, ..base_knobs },
        clients,
        per_client,
    );
    snap.emit(p.json(p.rps() / base));
    assert_eq!(
        p.counters.dags as usize,
        clients * per_client,
        "fan-out point: every request should have run as one dag"
    );
    assert!(
        p.counters.dag_bytes_elided > 0,
        "fan-out point elided no interior bytes"
    );

    println!(
        "\npool parallelism scales wall-clock across clusters; batching\n\
         coalesces queued same-shape requests so the fork-join overhead —\n\
         dominant below the Figure-3 crossover — is paid once per batch.\n\
         On the shared-B workload the operand cache turns repeat map-ins\n\
         into refcount bumps, the pipeline hides the rest of the map-in\n\
         under the previous batch's compute, and the placement router\n\
         routes every same-B request at the one warm cluster (stealing\n\
         keeps the rest of the pool busy).\n\
         Acceptance: pool=4 batching=true must show speedup_vs_serial >= 2.0;\n\
         cache=true pipeline=true must show cache_hits > 0 and\n\
         copy_bytes_cut >= 2.0 vs the cache-off point; placement=true must\n\
         show affine_routed > 0; the chain_mlp chained=true point must cut\n\
         bytes_to_device vs chained=false with chain_bytes_elided > 0 and\n\
         bit-identical checksums; the kernel=true point must show\n\
         kernel_specialized > 0 and kernel_hits > 0 without losing rps to\n\
         the registry's bookkeeping; the fault_matrix point must complete\n\
         every request (retry or host fallback) with faults_injected > 0\n\
         and failed = 0; the dag_mlp dag=true point must match the chain\n\
         run bit-for-bit with dag_bytes_elided > 0, and the fan-out dag\n\
         point must stage each shared trunk once (dags = requests,\n\
         dag_bytes_elided > 0)."
    );
}
