//! Ablation bench: PMCA cluster scaling (the paper's natural "what
//! next" after zero-copy) — does adding Snitch clusters help when the
//! data-copy region already dominates?
//!
//! Sweeps 1/2/4/8 clusters on the Carfield timing model at several GEMM
//! sizes, in both copy and zero-copy offload modes, and reports where
//! Amdahl bites.
//!
//! ```sh
//! cargo bench --bench cluster_scaling
//! ```

use hero_blas::blas::{DispatchPolicy, HeroBlas};
use hero_blas::config::{DispatchMode, PlatformConfig};
use hero_blas::harness::report::{ms, ratio, Table};
use hero_blas::npy::NdArray;
use hero_blas::soc::trace::RegionClass;
use hero_blas::util::rng::Rng;

fn main() {
    let artifacts = hero_blas::find_artifacts_dir().expect("run `make artifacts` first");
    let cluster_counts = [1u32, 2, 4, 8];
    let sizes = [128usize, 256];

    for mode in [DispatchMode::DeviceOnly, DispatchMode::DeviceZeroCopy] {
        println!("== cluster scaling, mode = {mode} ==\n");
        let mut t = Table::new(&[
            "n", "clusters", "compute_ms", "total_ms", "speedup_vs_1c", "host_speedup",
        ]);
        for &n in &sizes {
            let mut rng = Rng::new(n as u64);
            let a = NdArray::<f64>::randn(&mut rng, &[n, n]);
            let b = NdArray::<f64>::randn(&mut rng, &[n, n]);
            let mut base_total = 0.0;
            let mut host_total = 0.0;
            for &clusters in &cluster_counts {
                let mut cfg = PlatformConfig::default();
                cfg.cluster.clusters = clusters;
                let mut blas =
                    HeroBlas::new(cfg, &artifacts, DispatchPolicy::with_mode(mode)).unwrap();
                let f = blas.engine.freq_hz();

                if clusters == 1 {
                    // host baseline once per size
                    blas.policy = DispatchPolicy::with_mode(DispatchMode::HostOnly);
                    blas.reset_run();
                    a.matmul(&b, &mut blas).unwrap();
                    host_total = blas.trace().grand_total().to_secs(f);
                    blas.policy = DispatchPolicy::with_mode(mode);
                }

                blas.reset_run();
                let _c = a.matmul(&b, &mut blas).unwrap();
                let total = blas.trace().grand_total().to_secs(f);
                let compute = blas.trace().total(RegionClass::Compute).to_secs(f);
                if clusters == 1 {
                    base_total = total;
                }
                t.row(vec![
                    n.to_string(),
                    clusters.to_string(),
                    ms(compute),
                    ms(total),
                    ratio(base_total / total),
                    ratio(host_total / total),
                ]);
            }
        }
        print!("{}", t.render());
        println!();
    }
    println!(
        "Amdahl in action: once data copy + fork/join dominate, extra\n\
         clusters stop paying — zero-copy moves the ceiling, which is why\n\
         the paper chases the IOMMU before more compute."
    );
}
