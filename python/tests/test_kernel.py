"""L1 gemv + level-1 Pallas kernels vs the oracle (exact tile multiples)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gemv as gvk
from compile.kernels import level1, ref
from compile.kernels.gemv import gemv_tiled


def _rand(key, shape, dt=jnp.float64):
    return jax.random.normal(key, shape, dtype=dt)


@settings(max_examples=15, deadline=None)
@given(gm=st.integers(1, 4), gn=st.integers(1, 4),
       seed=st.integers(0, 2**31 - 1))
def test_gemv_tiled(gm, gn, seed):
    m, n = gm * gvk.TILE_ROWS, gn * gvk.TILE_COLS
    ka, kx = jax.random.split(jax.random.PRNGKey(seed))
    a, x = _rand(ka, (m, n)), _rand(kx, (n,))
    np.testing.assert_allclose(gemv_tiled(a, x), a @ x, rtol=1e-9, atol=1e-9)


def test_gemv_rejects_bad_shapes():
    with pytest.raises(ValueError, match="mismatch"):
        gemv_tiled(jnp.zeros((64, 64)), jnp.zeros((128,)))
    with pytest.raises(ValueError, match="not a multiple"):
        gemv_tiled(jnp.zeros((65, 64)), jnp.zeros((64,)))


@settings(max_examples=15, deadline=None)
@given(panels=st.integers(1, 8), alpha=st.floats(-3, 3),
       seed=st.integers(0, 2**31 - 1))
def test_level1_tiled(panels, alpha, seed):
    n = panels * level1.TILE
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    x, y = _rand(kx, (n,)), _rand(ky, (n,))
    a1 = jnp.array([alpha], jnp.float64)
    np.testing.assert_allclose(level1.axpy_tiled(a1, x, y),
                               ref.axpy(alpha, x, y), rtol=1e-9, atol=1e-9)
    np.testing.assert_allclose(level1.scal_tiled(a1, x),
                               ref.scal(alpha, x), rtol=1e-9, atol=1e-9)
    np.testing.assert_allclose(level1.dot_tiled(x, y)[0], ref.dot(x, y),
                               rtol=1e-9, atol=1e-9)
    np.testing.assert_allclose(level1.asum_tiled(x)[0], ref.asum(x),
                               rtol=1e-9, atol=1e-9)
    np.testing.assert_allclose(level1.nrm2_tiled(x)[0], ref.nrm2(x),
                               rtol=1e-9, atol=1e-9)


def test_level1_rejects_non_multiples():
    with pytest.raises(ValueError, match="not a multiple"):
        level1.dot_tiled(jnp.zeros((100,)), jnp.zeros((100,)))


@pytest.mark.parametrize("dt", [jnp.float32, jnp.float64])
def test_level1_dtypes(dt):
    n = level1.TILE
    x = jnp.linspace(-1, 1, n, dtype=dt)
    y = jnp.linspace(1, 2, n, dtype=dt)
    a1 = jnp.array([0.5], dt)
    tol = dict(rtol=1e-5) if dt == jnp.float32 else dict(rtol=1e-12)
    np.testing.assert_allclose(level1.axpy_tiled(a1, x, y),
                               0.5 * x + y, **tol)
    assert level1.axpy_tiled(a1, x, y).dtype == dt
