"""f32 through every layer (the paper's lower-precision future work)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref
from compile.kernels.gemm import matmul_accum_tile, matmul_tiled


def _rand(key, shape):
    return jax.random.normal(key, shape, dtype=jnp.float32)


@settings(max_examples=10, deadline=None)
@given(m=st.integers(1, 80), n=st.integers(1, 80), k=st.integers(1, 80),
       seed=st.integers(0, 2**31 - 1))
def test_f32_gemm_model(m, n, k, seed):
    ka, kb, kc = jax.random.split(jax.random.PRNGKey(seed), 3)
    a, b, c = _rand(ka, (m, k)), _rand(kb, (k, n)), _rand(kc, (m, n))
    got = model.gemm(a, b, c, 1.5, -0.5)
    want = ref.gemm(a, b, c, alpha=1.5, beta=-0.5)
    assert got.dtype == jnp.float32
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_f32_outputs_stay_f32():
    a = jnp.ones((64, 64), jnp.float32)
    out = matmul_tiled(a, a)
    assert out.dtype == jnp.float32
    acc = matmul_accum_tile(jnp.zeros((64, 64), jnp.float32), a, a)
    assert acc.dtype == jnp.float32
    np.testing.assert_allclose(acc, 64.0 * jnp.ones((64, 64)), rtol=1e-6)


@pytest.mark.parametrize("n", [16, 64, 128])
def test_catalog_sized_gemm_f32(n):
    """The exact shapes emitted to artifacts/ must be correct in f32."""
    ka, kb = jax.random.split(jax.random.PRNGKey(n))
    a, b = _rand(ka, (n, n)), _rand(kb, (n, n))
    c = jnp.zeros((n, n), jnp.float32)
    got = model.gemm(a, b, c, 1.0, 0.0)
    np.testing.assert_allclose(got, a @ b, rtol=2e-4, atol=2e-4)
