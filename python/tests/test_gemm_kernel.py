"""L1 GEMM kernel vs pure-jnp oracle — the core correctness signal."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gemm as gk
from compile.kernels import ref
from compile.kernels.gemm import matmul_accum_tile, matmul_tiled

DTYPES = [jnp.float32, jnp.float64]


def _tol(dt):
    return dict(rtol=1e-4, atol=1e-4) if dt == jnp.float32 else dict(rtol=1e-9, atol=1e-9)


def _rand(key, shape, dt):
    return jax.random.normal(key, shape, dtype=dt)


@pytest.mark.parametrize("dt", DTYPES)
@pytest.mark.parametrize("grid", [(1, 1, 1), (2, 1, 1), (1, 2, 1), (1, 1, 2), (2, 2, 2)])
def test_matmul_tiled_exact_multiples(dt, grid):
    gm, gn, gk_ = grid
    m, n, k = gm * gk.TILE_M, gn * gk.TILE_N, gk_ * gk.TILE_K
    k1, k2 = jax.random.split(jax.random.PRNGKey(hash(grid) % 2**31))
    a, b = _rand(k1, (m, k), dt), _rand(k2, (k, n), dt)
    np.testing.assert_allclose(matmul_tiled(a, b), a @ b, **_tol(dt))


@settings(max_examples=20, deadline=None)
@given(
    gm=st.integers(1, 3), gn=st.integers(1, 3), gkk=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_tiled_property(gm, gn, gkk, seed):
    m, n, k = gm * gk.TILE_M, gn * gk.TILE_N, gkk * gk.TILE_K
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    a, b = _rand(k1, (m, k), jnp.float64), _rand(k2, (k, n), jnp.float64)
    np.testing.assert_allclose(matmul_tiled(a, b), a @ b, rtol=1e-9, atol=1e-9)


@pytest.mark.parametrize("dt", DTYPES)
def test_accum_tile_matches_ref(dt):
    key = jax.random.PRNGKey(7)
    kc, ka, kb = jax.random.split(key, 3)
    c = _rand(kc, (gk.TILE_M, gk.TILE_N), dt)
    a = _rand(ka, (gk.TILE_M, gk.TILE_K), dt)
    b = _rand(kb, (gk.TILE_K, gk.TILE_N), dt)
    np.testing.assert_allclose(matmul_accum_tile(c, a, b), c + a @ b, **_tol(dt))


def test_accum_tile_chain_equals_full_matmul():
    """Composing the per-tile artifact over a K loop == full GEMM —
    this is exactly the loop the Rust device runtime executes."""
    key = jax.random.PRNGKey(3)
    ka, kb = jax.random.split(key)
    k_panels = 3
    a = _rand(ka, (gk.TILE_M, k_panels * gk.TILE_K), jnp.float64)
    b = _rand(kb, (k_panels * gk.TILE_K, gk.TILE_N), jnp.float64)
    c = jnp.zeros((gk.TILE_M, gk.TILE_N), jnp.float64)
    for p in range(k_panels):
        ap = a[:, p * gk.TILE_K:(p + 1) * gk.TILE_K]
        bp = b[p * gk.TILE_K:(p + 1) * gk.TILE_K, :]
        c = matmul_accum_tile(c, ap, bp)
    np.testing.assert_allclose(c, a @ b, rtol=1e-9, atol=1e-9)


def test_matmul_tiled_rejects_non_multiples():
    a = jnp.zeros((65, 64)); b = jnp.zeros((64, 64))
    with pytest.raises(ValueError, match="not a multiple"):
        matmul_tiled(a, b)


def test_matmul_tiled_rejects_contraction_mismatch():
    a = jnp.zeros((64, 64)); b = jnp.zeros((128, 64))
    with pytest.raises(ValueError, match="mismatch"):
        matmul_tiled(a, b)


def test_matmul_tiled_rejects_dtype_mismatch():
    a = jnp.zeros((64, 64), jnp.float32)
    b = jnp.zeros((64, 64), jnp.float64)
    with pytest.raises(ValueError, match="dtype"):
        matmul_tiled(a, b)


def test_spm_budget():
    """The chosen tile set must fit the paper's 128 KiB L1 SPM (f64)."""
    assert gk.spm_bytes(itemsize=8) <= 128 * 1024
    # and leave room for one double-buffered A-panel refill
    assert gk.spm_bytes(itemsize=8) + gk.TILE_M * gk.TILE_K * 8 <= 160 * 1024


def test_ref_gemm_semantics():
    key = jax.random.PRNGKey(11)
    ka, kb, kc = jax.random.split(key, 3)
    a, b = _rand(ka, (5, 7), jnp.float64), _rand(kb, (7, 4), jnp.float64)
    c = _rand(kc, (5, 4), jnp.float64)
    out = ref.gemm(a, b, c, alpha=2.0, beta=-0.5)
    np.testing.assert_allclose(out, 2.0 * (a @ b) - 0.5 * c, rtol=1e-12)
    out_t = ref.gemm(b, a, None, trans_a=True, trans_b=True)
    np.testing.assert_allclose(out_t, (a @ b).T, rtol=1e-12)
