"""AOT path: lowering produces loadable HLO text + a consistent manifest."""

import json
import os
import re
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels.gemm import TILE_K, TILE_M, TILE_N


def test_to_hlo_text_smoke():
    spec = jax.ShapeDtypeStruct((4, 4), jnp.float64)
    lowered = jax.jit(lambda a, b: (a @ b,)).lower(spec, spec)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text and "ENTRY" in text
    assert "f64" in text


def test_catalog_shapes_consistent():
    cat = aot.build_catalog((16, 64), (128,), (1024,))
    names = [c[0] for c in cat]
    assert "gemm_tile_accum_f64" in names
    assert "gemm_f64_n64" in names and "gemm_f32_n16" in names
    assert "gemv_f64_n128" in names and "dot_f64_n1024" in names
    for name, fn, specs, meta in cat:
        # every catalog fn must trace with its own specs and return a 1-tuple
        out = jax.eval_shape(fn, *specs)
        assert isinstance(out, tuple) and len(out) == 1, name


def test_gemm_artifact_numerics_via_jit():
    """Execute the exact catalog fn (the thing that gets lowered) and check
    numerics — what the Rust runtime will see at the artifact boundary."""
    cat = {c[0]: c for c in aot.build_catalog((16,), (), ())}
    name, fn, specs, meta = cat["gemm_f64_n16"]
    key = jax.random.PRNGKey(0)
    ka, kb, kc = jax.random.split(key, 3)
    a = jax.random.normal(ka, (16, 16), jnp.float64)
    b = jax.random.normal(kb, (16, 16), jnp.float64)
    c = jax.random.normal(kc, (16, 16), jnp.float64)
    alpha = jnp.array([2.0]); beta = jnp.array([-1.0])
    (out,) = jax.jit(fn)(a, b, c, alpha, beta)
    np.testing.assert_allclose(out, 2.0 * (a @ b) - c, rtol=1e-9)


def test_tile_accum_artifact_numerics():
    cat = {c[0]: c for c in aot.build_catalog((), (), ())}
    name, fn, specs, meta = cat["gemm_tile_accum_f64"]
    assert meta == {"op": "gemm_tile_accum", "dtype": "f64",
                    "m": TILE_M, "n": TILE_N, "k": TILE_K}
    c = jnp.ones((TILE_M, TILE_N), jnp.float64)
    a = jnp.full((TILE_M, TILE_K), 0.5, jnp.float64)
    b = jnp.full((TILE_K, TILE_N), 2.0, jnp.float64)
    (out,) = jax.jit(fn)(c, a, b)
    np.testing.assert_allclose(out, 1.0 + TILE_K * 1.0, rtol=1e-12)


def _rust_const_sizes(source: str, name: str) -> tuple:
    """Parse `pub const NAME: [usize; N] = [a, b, ...];` out of Rust source."""
    m = re.search(
        rf"pub const {name}: \[usize; \d+\] = \[([0-9, ]+)\];", source)
    assert m, f"{name} not found in kernel/mod.rs"
    return tuple(int(s) for s in m.group(1).split(","))


def test_prewarm_tables_match_rust_constants():
    """The kernel registry prewarms exactly the AOT size tables: the Rust
    PREWARM_* constants must stay in lockstep with the catalog defaults,
    or `[kernel] prewarm` would specialize shapes no artifact serves."""
    repo = os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))))
    src = open(os.path.join(repo, "rust", "src", "kernel", "mod.rs")).read()
    assert _rust_const_sizes(src, "PREWARM_GEMM_SIZES") == aot.DEFAULT_GEMM_SIZES
    assert _rust_const_sizes(src, "PREWARM_GEMV_SIZES") == aot.DEFAULT_GEMV_SIZES


@pytest.mark.slow
def test_aot_cli_end_to_end(tmp_path):
    """Run the real CLI with a tiny catalog; validate files + manifest."""
    env = dict(os.environ)
    cwd = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(tmp_path),
         "--gemm-sizes", "16", "--gemv-sizes", "128", "--vec-sizes", "1024"],
        cwd=cwd, env=env, check=True, capture_output=True,
    )
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest["tile"] == {"m": TILE_M, "n": TILE_N, "k": TILE_K}
    assert len(manifest["source_hash"]) == 16
    for e in manifest["entries"]:
        text = (tmp_path / e["file"]).read_text()
        assert "HloModule" in text and "ENTRY" in text, e["name"]
        assert len(e["arg_shapes"]) == len(e["arg_dtypes"])
    ops = {e["op"] for e in manifest["entries"]}
    assert ops == {"gemm_tile_accum", "gemm", "gemv", "axpy", "dot"}
