"""L2 BLAS graphs (full CBLAS semantics, arbitrary shapes) vs the oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def _rand(key, shape, dt=jnp.float64):
    return jax.random.normal(key, shape, dtype=dt)


def _keys(seed, n):
    return jax.random.split(jax.random.PRNGKey(seed), n)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 100), n=st.integers(1, 100), k=st.integers(1, 100),
    alpha=st.floats(-2, 2), beta=st.floats(-2, 2),
    trans_a=st.booleans(), trans_b=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_gemm_arbitrary_shapes(m, n, k, alpha, beta, trans_a, trans_b, seed):
    ka, kb, kc = _keys(seed, 3)
    a = _rand(ka, (k, m) if trans_a else (m, k))
    b = _rand(kb, (n, k) if trans_b else (k, n))
    c = _rand(kc, (m, n))
    got = model.gemm(a, b, c, alpha, beta, trans_a=trans_a, trans_b=trans_b)
    want = ref.gemm(a, b, c, alpha=alpha, beta=beta,
                    trans_a=trans_a, trans_b=trans_b)
    np.testing.assert_allclose(got, want, rtol=1e-9, atol=1e-9)


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(1, 120), n=st.integers(1, 120),
    alpha=st.floats(-2, 2), beta=st.floats(-2, 2),
    trans=st.booleans(), seed=st.integers(0, 2**31 - 1),
)
def test_gemv_arbitrary_shapes(m, n, alpha, beta, trans, seed):
    ka, kx, ky = _keys(seed, 3)
    a = _rand(ka, (m, n))
    xlen, ylen = (m, n) if trans else (n, m)
    x, y = _rand(kx, (xlen,)), _rand(ky, (ylen,))
    got = model.gemv(a, x, y, alpha, beta, trans=trans)
    want = ref.gemv(a, x, y, alpha=alpha, beta=beta, trans=trans)
    np.testing.assert_allclose(got, want, rtol=1e-9, atol=1e-9)


@pytest.mark.parametrize("trans", [False, True])
@pytest.mark.parametrize("lower", [False, True])
def test_syrk_triangles(trans, lower):
    ka, kc = _keys(21, 2)
    n, k = 37, 19
    a = _rand(ka, (k, n) if trans else (n, k))
    c = _rand(kc, (n, n))
    got = model.syrk(a, c, 1.5, -0.25, trans=trans, lower=lower)
    want = ref.syrk(a, c, alpha=1.5, beta=-0.25, trans=trans, lower=lower)
    np.testing.assert_allclose(got, want, rtol=1e-9, atol=1e-9)
    # untouched triangle must be byte-identical to c
    rows = np.arange(n)[:, None]; cols = np.arange(n)[None, :]
    untouched = ~(rows >= cols if lower else rows <= cols)
    np.testing.assert_array_equal(np.asarray(got)[untouched],
                                  np.asarray(c)[untouched])


def test_ger():
    ka, kx, ky = _keys(5, 3)
    a, x, y = _rand(ka, (13, 9)), _rand(kx, (13,)), _rand(ky, (9,))
    np.testing.assert_allclose(model.ger(a, x, y, 0.75),
                               ref.ger(a, x, y, alpha=0.75), rtol=1e-12)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 2000), alpha=st.floats(-3, 3),
       seed=st.integers(0, 2**31 - 1))
def test_level1_ops(n, alpha, seed):
    kx, ky = _keys(seed, 2)
    x, y = _rand(kx, (n,)), _rand(ky, (n,))
    np.testing.assert_allclose(model.axpy(alpha, x, y),
                               ref.axpy(alpha, x, y), rtol=1e-9, atol=1e-9)
    np.testing.assert_allclose(model.scal(alpha, x),
                               ref.scal(alpha, x), rtol=1e-9, atol=1e-9)
    np.testing.assert_allclose(model.dot(x, y)[0], ref.dot(x, y),
                               rtol=1e-9, atol=1e-9)
    np.testing.assert_allclose(model.asum(x)[0], ref.asum(x),
                               rtol=1e-9, atol=1e-9)
    np.testing.assert_allclose(model.nrm2(x)[0], ref.nrm2(x),
                               rtol=1e-9, atol=1e-9)


def test_gemm_beta_zero_ignores_c_nans():
    """BLAS semantics nuance we *don't* implement (beta=0 must still read
    c in our graph) — document the deviation: padding is sliced before the
    beta multiply, so NaN*0 = NaN propagates like jnp, unlike CBLAS."""
    a = jnp.eye(4); b = jnp.eye(4)
    c = jnp.full((4, 4), jnp.nan)
    out = model.gemm(a, b, c, 1.0, 0.0)
    assert bool(jnp.isnan(out).any())  # documented deviation from CBLAS
