"""L2 — JAX BLAS compute graphs assembled around the L1 Pallas kernels.

Each public function here is a full CBLAS-semantics operation (alpha,
beta, transposes) whose inner hot loop is the SPM-tiled Pallas kernel
from ``compile.kernels``.  ``compile.aot`` lowers jitted instances of
these graphs, per (op, dtype, shape), to HLO text artifacts that the Rust
runtime executes via PJRT — Python never runs at request time.

Padding: the device DMA engine only moves whole tiles, so arbitrary
problem sizes are zero-padded up to tile multiples here (beta/alpha math
is applied after slicing back, so padding never leaks into results).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels import gemm as gemm_kernels
from .kernels import gemv as gemv_kernels
from .kernels import level1
from .kernels.gemm import matmul_tiled
from .kernels.gemv import gemv_tiled

jax.config.update("jax_enable_x64", True)


def _round_up(n: int, m: int) -> int:
    return (n + m - 1) // m * m


def _pad2(x: jax.Array, rows: int, cols: int) -> jax.Array:
    m, n = x.shape
    if m == rows and n == cols:
        return x
    return jnp.pad(x, ((0, rows - m), (0, cols - n)))


def _pad1(x: jax.Array, n: int) -> jax.Array:
    (m,) = x.shape
    if m == n:
        return x
    return jnp.pad(x, (0, n - m))


# ---------------------------------------------------------------------------
# Level 3
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("trans_a", "trans_b"))
def gemm(a, b, c, alpha, beta, *, trans_a: bool = False,
         trans_b: bool = False):
    """xGEMM: ``alpha * op(a) @ op(b) + beta * c`` via the tiled kernel.

    ``alpha``/``beta`` are traced scalars so one artifact per shape serves
    every coefficient pair.
    """
    opa = a.T if trans_a else a
    opb = b.T if trans_b else b
    m, k = opa.shape
    k2, n = opb.shape
    if k != k2:
        raise ValueError(f"gemm contraction mismatch: {opa.shape} @ {opb.shape}")

    tm, tn, tk = gemm_kernels.TILE_M, gemm_kernels.TILE_N, gemm_kernels.TILE_K
    mp, np_, kp = _round_up(m, tm), _round_up(n, tn), _round_up(k, tk)
    prod = matmul_tiled(_pad2(opa, mp, kp), _pad2(opb, kp, np_))[:m, :n]
    return alpha * prod + beta * c


@functools.partial(jax.jit, static_argnames=("trans", "lower"))
def syrk(a, c, alpha, beta, *, trans: bool = False, lower: bool = False):
    """xSYRK: rank-k update on one triangle, via the tiled GEMM kernel.

    The paper compiles syrk host-only; we still provide the device graph
    so the Rust dispatch policy (not artifact availability) is what keeps
    it on the host — and so the ablation bench can flip that choice.
    """
    opa = a.T if trans else a
    n, k = opa.shape
    tm, tn, tk = gemm_kernels.TILE_M, gemm_kernels.TILE_N, gemm_kernels.TILE_K
    np_, kp = _round_up(n, tm), _round_up(k, tk)
    pad_a = _pad2(opa, np_, kp)
    full = matmul_tiled(pad_a, _pad2(opa.T, kp, _round_up(n, tn)))[:n, :n]
    full = alpha * full + beta * c
    rows = jnp.arange(n)[:, None]
    cols = jnp.arange(n)[None, :]
    mask = rows >= cols if lower else rows <= cols
    return jnp.where(mask, full, c)


# ---------------------------------------------------------------------------
# Level 2
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("trans",))
def gemv(a, x, y, alpha, beta, *, trans: bool = False):
    """xGEMV: ``alpha * op(a) @ x + beta * y`` via the row-panel kernel."""
    opa = a.T if trans else a
    m, n = opa.shape
    tr, tc = gemv_kernels.TILE_ROWS, gemv_kernels.TILE_COLS
    mp, np_ = _round_up(m, tr), _round_up(n, tc)
    prod = gemv_tiled(_pad2(opa, mp, np_), _pad1(x, np_))[:m]
    return alpha * prod + beta * y


@jax.jit
def ger(a, x, y, alpha):
    """xGER: ``a + alpha * outer(x, y)`` (outer product is pure streaming —
    expressed directly, XLA fuses it into a single pass)."""
    return a + alpha * jnp.outer(x, y)


# ---------------------------------------------------------------------------
# Level 1
# ---------------------------------------------------------------------------

def _padded_len(n: int) -> int:
    return _round_up(n, level1.TILE)


@jax.jit
def axpy(alpha, x, y):
    """xAXPY: ``alpha * x + y``."""
    (n,) = x.shape
    np_ = _padded_len(n)
    alpha1 = jnp.reshape(alpha, (1,)).astype(x.dtype)
    return level1.axpy_tiled(alpha1, _pad1(x, np_), _pad1(y, np_))[:n]


@jax.jit
def scal(alpha, x):
    """xSCAL: ``alpha * x``."""
    (n,) = x.shape
    alpha1 = jnp.reshape(alpha, (1,)).astype(x.dtype)
    return level1.scal_tiled(alpha1, _pad1(x, _padded_len(n)))[:n]


@jax.jit
def dot(x, y):
    """xDOT → shape-(1,)."""
    (n,) = x.shape
    np_ = _padded_len(n)
    return level1.dot_tiled(_pad1(x, np_), _pad1(y, np_))


@jax.jit
def asum(x):
    """xASUM → shape-(1,)."""
    (n,) = x.shape
    return level1.asum_tiled(_pad1(x, _padded_len(n)))


@jax.jit
def nrm2(x):
    """xNRM2 → shape-(1,) (zero padding does not change the norm)."""
    (n,) = x.shape
    return level1.nrm2_tiled(_pad1(x, _padded_len(n)))
