"""AOT compile path: lower every (op, dtype, shape) variant to HLO text.

Run once by ``make artifacts``; the Rust runtime loads the resulting
``artifacts/*.hlo.txt`` through ``xla::HloModuleProto::from_text_file``
and never touches Python again.

Interchange format is HLO **text**, not ``.serialize()``: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension
0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids
and round-trips cleanly (see /opt/xla-example/README.md).

Every artifact returns a 1-tuple (``return_tuple=True``) so the Rust side
unwraps with ``to_tuple1()``.

Usage::

    python -m compile.aot --out-dir ../artifacts [--sizes 16,32,...]
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels.gemm import TILE_M, TILE_N, TILE_K, matmul_accum_tile

jax.config.update("jax_enable_x64", True)

# Problem sizes for the fixed-shape "hand-crafted" GEMM artifacts.  These
# are the x-axis of the paper's Figure 3 (plus 256 to show the asymptote).
DEFAULT_GEMM_SIZES = (16, 32, 64, 128, 256)
DEFAULT_GEMV_SIZES = (128, 256)
DEFAULT_VEC_SIZES = (1024, 4096)
DTYPES = {"f32": jnp.float32, "f64": jnp.float64}


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the 0.5.1-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _scalar1(dtype):
    # Coefficients travel as shape-(1,) arrays: rank-0 literals are awkward
    # to build through the xla crate, rank-1 is uniform everywhere.
    return _spec((1,), dtype)


def build_catalog(gemm_sizes, gemv_sizes, vec_sizes):
    """Return [(name, fn, arg_specs, meta)] for every artifact to emit."""
    catalog = []

    for dname, dt in DTYPES.items():
        t = (TILE_M, TILE_N)
        # Per-tile accumulate primitive: the Rust device runtime owns the
        # DMA grid and calls this once per (i, j, k) tile step.
        catalog.append((
            f"gemm_tile_accum_{dname}",
            lambda c, a, b: (matmul_accum_tile(c, a, b),),
            [_spec(t, dt), _spec((TILE_M, TILE_K), dt), _spec((TILE_K, TILE_N), dt)],
            {"op": "gemm_tile_accum", "dtype": dname,
             "m": TILE_M, "n": TILE_N, "k": TILE_K},
        ))

        for n in gemm_sizes:
            catalog.append((
                f"gemm_{dname}_n{n}",
                lambda a, b, c, alpha, beta: (
                    model.gemm(a, b, c, alpha[0], beta[0]),),
                [_spec((n, n), dt), _spec((n, n), dt), _spec((n, n), dt),
                 _scalar1(dt), _scalar1(dt)],
                {"op": "gemm", "dtype": dname, "m": n, "n": n, "k": n},
            ))

    dt = jnp.float64
    for n in gemv_sizes:
        catalog.append((
            f"gemv_f64_n{n}",
            lambda a, x, y, alpha, beta: (
                model.gemv(a, x, y, alpha[0], beta[0]),),
            [_spec((n, n), dt), _spec((n,), dt), _spec((n,), dt),
             _scalar1(dt), _scalar1(dt)],
            {"op": "gemv", "dtype": "f64", "m": n, "n": n},
        ))

    for n in vec_sizes:
        catalog.append((
            f"axpy_f64_n{n}",
            lambda alpha, x, y: (model.axpy(alpha[0], x, y),),
            [_scalar1(dt), _spec((n,), dt), _spec((n,), dt)],
            {"op": "axpy", "dtype": "f64", "n": n},
        ))
        catalog.append((
            f"dot_f64_n{n}",
            lambda x, y: (model.dot(x, y),),
            [_spec((n,), dt), _spec((n,), dt)],
            {"op": "dot", "dtype": "f64", "n": n},
        ))
    return catalog


def content_hash(paths) -> str:
    """Hash of the compile-path sources — lets `make artifacts` no-op when
    nothing changed (recorded in the manifest)."""
    h = hashlib.sha256()
    for p in sorted(paths):
        with open(p, "rb") as f:
            h.update(f.read())
    return h.hexdigest()[:16]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--gemm-sizes",
                    default=",".join(map(str, DEFAULT_GEMM_SIZES)))
    ap.add_argument("--gemv-sizes",
                    default=",".join(map(str, DEFAULT_GEMV_SIZES)))
    ap.add_argument("--vec-sizes",
                    default=",".join(map(str, DEFAULT_VEC_SIZES)))
    args = ap.parse_args()

    gemm_sizes = [int(s) for s in args.gemm_sizes.split(",") if s]
    gemv_sizes = [int(s) for s in args.gemv_sizes.split(",") if s]
    vec_sizes = [int(s) for s in args.vec_sizes.split(",") if s]

    os.makedirs(args.out_dir, exist_ok=True)
    catalog = build_catalog(gemm_sizes, gemv_sizes, vec_sizes)

    manifest = {"tile": {"m": TILE_M, "n": TILE_N, "k": TILE_K},
                "entries": []}
    for name, fn, specs, meta in catalog:
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        path = os.path.join(args.out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        entry = dict(meta)
        entry.update({
            "name": name,
            "file": fname,
            "arg_shapes": [list(s.shape) for s in specs],
            "arg_dtypes": [str(s.dtype) for s in specs],
        })
        manifest["entries"].append(entry)
        print(f"  {fname:36s} {len(text):>9d} chars")

    src_dir = os.path.dirname(os.path.abspath(__file__))
    srcs = [os.path.join(src_dir, f) for f in ("model.py", "aot.py")]
    srcs += [os.path.join(src_dir, "kernels", f)
             for f in os.listdir(os.path.join(src_dir, "kernels"))
             if f.endswith(".py")]
    manifest["source_hash"] = content_hash(srcs)

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {len(manifest['entries'])} artifacts + manifest.json "
          f"to {args.out_dir}")


if __name__ == "__main__":
    main()
