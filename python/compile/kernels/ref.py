"""Pure-jnp oracles for every kernel and every L2 BLAS graph.

These are the correctness ground truth: no Pallas, no tiling, just the
textbook definition.  ``python/tests`` asserts kernels == ref under
hypothesis-swept shapes/dtypes, and the Rust integration tests compare
the artifact outputs against the same semantics re-implemented in Rust.
"""

from __future__ import annotations

import jax.numpy as jnp


def gemm(a, b, c=None, *, alpha=1.0, beta=0.0, trans_a=False, trans_b=False):
    """CBLAS xGEMM: ``alpha * op(a) @ op(b) + beta * c``."""
    opa = a.T if trans_a else a
    opb = b.T if trans_b else b
    out = alpha * (opa @ opb)
    if c is not None:
        out = out + beta * c
    return out


def syrk(a, c=None, *, alpha=1.0, beta=0.0, trans=False, lower=False):
    """CBLAS xSYRK: ``alpha * op(a) @ op(a).T + beta * c`` on one triangle.

    Returns the full matrix with the untouched triangle taken from ``c``
    (matching what a BLAS caller observes in memory).
    """
    opa = a.T if trans else a
    full = alpha * (opa @ opa.T)
    if c is None:
        c = jnp.zeros_like(full)
    full = full + beta * c
    n = full.shape[0]
    rows = jnp.arange(n)[:, None]
    cols = jnp.arange(n)[None, :]
    mask = rows >= cols if lower else rows <= cols
    return jnp.where(mask, full, c)


def gemv(a, x, y=None, *, alpha=1.0, beta=0.0, trans=False):
    """CBLAS xGEMV: ``alpha * op(a) @ x + beta * y``."""
    opa = a.T if trans else a
    out = alpha * (opa @ x)
    if y is not None:
        out = out + beta * y
    return out


def ger(a, x, y, *, alpha=1.0):
    """CBLAS xGER: ``a + alpha * outer(x, y)``."""
    return a + alpha * jnp.outer(x, y)


def axpy(alpha, x, y):
    return alpha * x + y


def scal(alpha, x):
    return alpha * x


def dot(x, y):
    return jnp.sum(x * y)


def asum(x):
    return jnp.sum(jnp.abs(x))


def nrm2(x):
    return jnp.sqrt(jnp.sum(x * x))
