"""L1 — Pallas kernels for the BLAS hot-spots offloaded to the PMCA.

Each kernel mirrors the Snitch cluster's execution scheme: the BlockSpec
grid is the DMA HBM<->SPM schedule (tiles sized to fit the 128 KiB L1
scratch-pad), the kernel body is what the eight FPU-equipped cores do on
resident tiles.  All kernels are lowered with ``interpret=True`` — the CPU
PJRT plugin cannot execute Mosaic custom-calls (see DESIGN.md §2).
"""

from .gemm import matmul_tiled, TILE_M, TILE_N, TILE_K, spm_bytes
from .gemv import gemv_tiled
from .level1 import axpy_tiled, dot_tiled, scal_tiled, asum_tiled, nrm2_tiled

__all__ = [
    "matmul_tiled",
    "gemv_tiled",
    "axpy_tiled",
    "dot_tiled",
    "scal_tiled",
    "asum_tiled",
    "nrm2_tiled",
    "TILE_M",
    "TILE_N",
    "TILE_K",
    "spm_bytes",
]
