"""L1 Pallas kernel: SPM-tiled GEMV (y = A @ x) for the Snitch PMCA.

BLAS level-2 traffic is memory-bound: each A element is used once, so the
DMA schedule streams row-panels of A through the scratch-pad while the
x vector stays resident (x is small: n*8 bytes).  Grid walks (M/TM, N/TN);
the partial dot products accumulate in the resident output block, the
same scheme the Snitch cluster would use with its DMA engine.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_ROWS = 64
TILE_COLS = 64


def _gemv_kernel(a_ref, x_ref, o_ref):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], x_ref[...], preferred_element_type=o_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("tr", "tc"))
def gemv_tiled(a: jax.Array, x: jax.Array, *, tr: int = TILE_ROWS,
               tc: int = TILE_COLS) -> jax.Array:
    """``a @ x`` for a 2-D ``a`` and 1-D ``x`` via row-panel streaming.

    Shapes must be multiples of the tile sizes (pad at L2).
    """
    m, n = a.shape
    if x.shape != (n,):
        raise ValueError(f"gemv mismatch: {a.shape} @ {x.shape}")
    if m % tr or n % tc:
        raise ValueError(
            f"shape ({m},{n}) not a multiple of tile ({tr},{tc}); pad at L2"
        )

    grid = (m // tr, n // tc)
    return pl.pallas_call(
        _gemv_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tr, tc), lambda i, j: (i, j)),
            pl.BlockSpec((tc,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((tr,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), a.dtype),
        interpret=True,
    )(a, x)
