"""L1 Pallas kernels: BLAS level-1 primitives (axpy, dot, scal, asum, nrm2).

Level-1 ops are pure streaming: the DMA schedule is a 1-D walk of
vector panels through the scratch-pad.  Reductions (dot/asum/nrm2)
accumulate into a single resident scalar block across the grid, which is
exactly how the cluster would hold a partial sum in SPM while panels
stream past.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 256  # elements per streamed panel


def _check_1d(x: jax.Array, tile: int, name: str) -> None:
    (n,) = x.shape
    if n % tile:
        raise ValueError(f"{name}: length {n} not a multiple of {tile}; pad at L2")


def _axpy_kernel(alpha_ref, x_ref, y_ref, o_ref):
    o_ref[...] = alpha_ref[0] * x_ref[...] + y_ref[...]


@functools.partial(jax.jit, static_argnames=("tile",))
def axpy_tiled(alpha: jax.Array, x: jax.Array, y: jax.Array, *,
               tile: int = TILE) -> jax.Array:
    """``alpha * x + y`` with alpha a shape-(1,) array (kept traced so one
    artifact serves all alphas)."""
    _check_1d(x, tile, "axpy")
    (n,) = x.shape
    return pl.pallas_call(
        _axpy_kernel,
        grid=(n // tile,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=True,
    )(alpha, x, y)


def _scal_kernel(alpha_ref, x_ref, o_ref):
    o_ref[...] = alpha_ref[0] * x_ref[...]


@functools.partial(jax.jit, static_argnames=("tile",))
def scal_tiled(alpha: jax.Array, x: jax.Array, *, tile: int = TILE) -> jax.Array:
    """``alpha * x``."""
    _check_1d(x, tile, "scal")
    (n,) = x.shape
    return pl.pallas_call(
        _scal_kernel,
        grid=(n // tile,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=True,
    )(alpha, x)


def _make_reduce_kernel(panel_fn):
    """Reduction kernel factory: accumulate panel_fn(panels) into o_ref[0]."""

    def kernel(x_ref, *rest):
        # rest is (y_ref, o_ref) for dot, (o_ref,) for unary reductions.
        o_ref = rest[-1]

        @pl.when(pl.program_id(0) == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        o_ref[...] += panel_fn(x_ref, *rest[:-1])

    return kernel


_dot_kernel = _make_reduce_kernel(
    lambda x_ref, y_ref: jnp.sum(x_ref[...] * y_ref[...], keepdims=True)
)
_asum_kernel = _make_reduce_kernel(
    lambda x_ref: jnp.sum(jnp.abs(x_ref[...]), keepdims=True)
)
_sumsq_kernel = _make_reduce_kernel(
    lambda x_ref: jnp.sum(x_ref[...] * x_ref[...], keepdims=True)
)


def _reduce_call(kernel, args, tile):
    (n,) = args[0].shape
    in_specs = [pl.BlockSpec((tile,), lambda i: (i,)) for _ in args]
    return pl.pallas_call(
        kernel,
        grid=(n // tile,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((1,), args[0].dtype),
        interpret=True,
    )(*args)


@functools.partial(jax.jit, static_argnames=("tile",))
def dot_tiled(x: jax.Array, y: jax.Array, *, tile: int = TILE) -> jax.Array:
    """``sum(x * y)`` as a shape-(1,) array."""
    _check_1d(x, tile, "dot")
    if x.shape != y.shape:
        raise ValueError(f"dot mismatch: {x.shape} vs {y.shape}")
    return _reduce_call(_dot_kernel, (x, y), tile)


@functools.partial(jax.jit, static_argnames=("tile",))
def asum_tiled(x: jax.Array, *, tile: int = TILE) -> jax.Array:
    """``sum(|x|)`` as a shape-(1,) array."""
    _check_1d(x, tile, "asum")
    return _reduce_call(_asum_kernel, (x,), tile)


@functools.partial(jax.jit, static_argnames=("tile",))
def nrm2_tiled(x: jax.Array, *, tile: int = TILE) -> jax.Array:
    """``sqrt(sum(x^2))`` as a shape-(1,) array (sqrt applied outside the
    grid, on the resident accumulator)."""
    _check_1d(x, tile, "nrm2")
    return jnp.sqrt(_reduce_call(_sumsq_kernel, (x,), tile))
