"""L1 Pallas kernel: SPM-tiled GEMM for the Snitch PMCA.

Hardware adaptation (DESIGN.md §2): the paper's accelerator is a Snitch
cluster with a 128 KiB L1 scratch-pad refilled by a DMA engine — the exact
role Pallas' BlockSpec pipeline plays for VMEM on TPU.  We therefore
express the paper's device GEMM as a Pallas kernel whose grid is the DMA
schedule:

  * grid = (M/TM, N/TN, K/TK) — outer two dims walk output tiles, the
    inner dim streams K-panels through the scratch-pad,
  * the C tile stays resident across the K loop (accumulation in o_ref),
    matching the cluster keeping the output block in SPM while A/B panels
    are double-buffered in,
  * tile sizes are chosen so the resident set fits the 128 KiB SPM:
    f64 64x64 tiles -> 3 * 64*64*8 B = 96 KiB  (<= 128 KiB, leaving room
    for the double buffer of one panel).

``interpret=True`` is mandatory on this image: real-TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot run.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile geometry shared with the Rust device model (rust/src/blas/device_gemm.rs
# and configs/carfield.toml must agree with these).
TILE_M = 64
TILE_N = 64
TILE_K = 64


def spm_bytes(tm: int = TILE_M, tn: int = TILE_N, tk: int = TILE_K,
              itemsize: int = 8) -> int:
    """Resident scratch-pad footprint of one (A, B, C) tile set in bytes.

    This is the quantity the 128 KiB L1 SPM constraint applies to; the
    rust SoC model charges DMA time for exactly these refills.
    """
    return (tm * tk + tk * tn + tm * tn) * itemsize


def _matmul_kernel(x_ref, y_ref, o_ref):
    """Inner kernel: accumulate one K-panel into the resident C tile."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=o_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("tm", "tn", "tk"))
def matmul_tiled(x: jax.Array, y: jax.Array, *, tm: int = TILE_M,
                 tn: int = TILE_N, tk: int = TILE_K) -> jax.Array:
    """``x @ y`` via the SPM-tiled Pallas kernel.

    Shapes must be multiples of the tile sizes; the L2 wrapper
    (``compile.model``) pads arbitrary shapes up to tile multiples and
    slices the result back, exactly like the device runtime does before
    DMA-ing panels into the scratch-pad.
    """
    m, k = x.shape
    k2, n = y.shape
    if k != k2:
        raise ValueError(f"contraction mismatch: {x.shape} @ {y.shape}")
    if m % tm or n % tn or k % tk:
        raise ValueError(
            f"shape ({m},{k})x({k2},{n}) not a multiple of tile "
            f"({tm},{tn},{tk}); pad at L2 first"
        )
    if x.dtype != y.dtype:
        raise ValueError(f"dtype mismatch: {x.dtype} vs {y.dtype}")

    grid = (m // tm, n // tn, k // tk)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tk, tn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, y)


def _matmul_accum_kernel(c_ref, x_ref, y_ref, o_ref):
    """C-accumulating variant: o = c + x @ y (one tile, no grid).

    This is the per-tile artifact the Rust device runtime executes once
    per (i, j, kk) step of its own DMA loop — the Rust side owns the grid,
    the kernel owns one resident-tile FMA burst.
    """
    o_ref[...] = c_ref[...] + jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=o_ref.dtype
    )


@jax.jit
def matmul_accum_tile(c: jax.Array, x: jax.Array, y: jax.Array) -> jax.Array:
    """Single-tile accumulate: ``c + x @ y`` with all operands tile-shaped."""
    return pl.pallas_call(
        _matmul_accum_kernel,
        out_shape=jax.ShapeDtypeStruct(c.shape, c.dtype),
        interpret=True,
    )(c, x, y)
