//! A tiny request loop: the "high-level application" path as a service.
//!
//! Demonstrates the coordinator role: the rust binary owns a long-lived
//! [`HeroBlas`] session (PJRT executables stay compiled and warm, the
//! device stays booted) and serves line-delimited JSON requests over TCP.
//! Python never appears at request time — the paper's build-time/run-time
//! split, taken to a serving setting.
//!
//! Request  (one line):  {"op": "gemm", "n": 128, "mode": "device_only"}
//! Response (one line):  {"ok": true, "n": 128, "mode": "device_only",
//!                        "total_ms": ..., "data_copy_ms": ...,
//!                        "fork_join_ms": ..., "compute_ms": ...,
//!                        "checksum": ...}
//! A request {"op": "shutdown"} stops the server (used by tests).

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::path::Path;

use crate::blas::{DispatchPolicy, HeroBlas};
use crate::config::{DispatchMode, PlatformConfig};
use crate::error::{Error, Result};
use crate::npy::NdArray;
use crate::soc::trace::RegionClass;
use crate::util::json_lite::Json;
use crate::util::rng::Rng;

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect::<BTreeMap<_, _>>())
}

fn err_line(msg: &str) -> String {
    let mut j = obj(vec![("ok", Json::Bool(false)), ("error", Json::Str(msg.into()))]);
    compact(&mut j)
}

/// One-line JSON (the pretty writer is multi-line; flatten it).
fn compact(j: &mut Json) -> String {
    j.to_string_pretty()
        .lines()
        .map(str::trim)
        .collect::<Vec<_>>()
        .join(" ")
}

/// Handle one request line; returns (response, shutdown?).
fn handle(blas: &mut HeroBlas, rng: &mut Rng, line: &str) -> (String, bool) {
    let req = match Json::parse(line) {
        Ok(j) => j,
        Err(e) => return (err_line(&format!("bad json: {e}")), false),
    };
    let op = req.get("op").and_then(|o| o.as_str()).unwrap_or("");
    match op {
        "shutdown" => (err_line("shutting down"), true),
        "ping" => {
            let mut j = obj(vec![("ok", Json::Bool(true)), ("pong", Json::Bool(true))]);
            (compact(&mut j), false)
        }
        "gemm" => {
            let n = req.get("n").and_then(|v| v.as_u64()).unwrap_or(128) as usize;
            if n == 0 || n > 2048 {
                return (err_line("n must be in 1..=2048"), false);
            }
            let mode: DispatchMode = match req
                .get("mode")
                .and_then(|v| v.as_str())
                .unwrap_or("auto")
                .parse()
            {
                Ok(m) => m,
                Err(e) => return (err_line(&e.to_string()), false),
            };
            blas.policy = DispatchPolicy::with_mode(mode);
            let a = NdArray::<f64>::randn(rng, &[n, n]);
            let b = NdArray::<f64>::randn(rng, &[n, n]);
            blas.reset_run();
            let c = match a.matmul(&b, blas) {
                Ok(c) => c,
                Err(e) => return (err_line(&e.to_string()), false),
            };
            let f = blas.engine.freq_hz();
            let t = &blas.engine.trace;
            let ms = |c: RegionClass| Json::Num(t.total(c).to_ns(f) / 1e6);
            let total =
                Json::Num(t.grand_total().to_ns(f) / 1e6);
            let checksum: f64 = c.data().iter().sum();
            let mut j = obj(vec![
                ("ok", Json::Bool(true)),
                ("n", Json::Num(n as f64)),
                ("mode", Json::Str(mode.to_string())),
                ("data_copy_ms", ms(RegionClass::DataCopy)),
                ("fork_join_ms", ms(RegionClass::ForkJoin)),
                ("compute_ms", ms(RegionClass::Compute)),
                ("host_compute_ms", ms(RegionClass::HostCompute)),
                ("total_ms", total),
                ("checksum", Json::Num(checksum)),
            ]);
            (compact(&mut j), false)
        }
        other => (err_line(&format!("unknown op '{other}'")), false),
    }
}

fn serve_conn(blas: &mut HeroBlas, rng: &mut Rng, stream: TcpStream) -> Result<bool> {
    let peer = stream.peer_addr().map(|a| a.to_string()).unwrap_or_default();
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let (resp, shutdown) = handle(blas, rng, &line);
        writer.write_all(resp.as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
        if shutdown {
            eprintln!("serve: shutdown requested by {peer}");
            return Ok(true);
        }
    }
    Ok(false)
}

/// Run the server until a shutdown request arrives.
/// `ready` (if given) receives the bound port once listening — lets tests
/// bind port 0 and discover the ephemeral port.
pub fn serve(
    cfg: PlatformConfig,
    artifacts: &Path,
    port: u16,
    ready: Option<std::sync::mpsc::Sender<u16>>,
) -> Result<()> {
    let mut blas = HeroBlas::new(cfg, artifacts, DispatchPolicy::default())?;
    blas.registry.warm_up()?; // no compile latency on first request
    let mut rng = Rng::new(0xC0FFEE);

    let listener = TcpListener::bind(("127.0.0.1", port))
        .map_err(|e| Error::Runtime(format!("bind 127.0.0.1:{port}: {e}")))?;
    let bound = listener.local_addr()?.port();
    eprintln!(
        "hero-blas serve: listening on 127.0.0.1:{bound} ({} artifacts warm)",
        blas.registry.resident()
    );
    if let Some(tx) = ready {
        let _ = tx.send(bound);
    }
    for stream in listener.incoming() {
        match stream {
            Ok(s) => {
                if serve_conn(&mut blas, &mut rng, s)? {
                    return Ok(());
                }
            }
            Err(e) => eprintln!("serve: accept error: {e}"),
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compact_is_single_line() {
        let mut j = obj(vec![("a", Json::Num(1.0)), ("b", Json::Str("x".into()))]);
        let s = compact(&mut j);
        assert!(!s.contains('\n'));
        assert!(Json::parse(&s).is_ok());
    }

    #[test]
    fn err_line_is_json() {
        let e = err_line("boom");
        let j = Json::parse(&e).unwrap();
        assert_eq!(j.get("ok"), Some(&Json::Bool(false)));
    }
}
