//! Concurrent request loop: the "high-level application" path as a
//! service, on top of the [`crate::sched`] multi-cluster scheduler.
//!
//! The coordinator boots a pool of simulated PMCA clusters (each with a
//! warm PJRT registry, its own mailbox and DRAM partition) and serves
//! line-delimited JSON over TCP.  Every connection gets its own handler
//! thread; requests flow into the bounded work queue and complete
//! asynchronously on the pool — same-shape GEMMs that meet in the queue
//! share one fork-join launch (see [`crate::sched::batcher`]).  Python
//! never appears at request time — the paper's build-time/run-time
//! split, taken to a serving setting.
//!
//! Request  (one line):  {"op": "gemm", "n": 128, "mode": "device_only",
//!                        "priority": "high", "seed": 7, "b_seed": 42}
//!                   or:  {"op": "gemv", "m": 256, "n": 256,
//!                        "mode": "device_only", "seed": 7}
//!                   or:  {"op": "axpy", "n": 4096, "alpha": 1.5,
//!                        "mode": "device_only", "seed": 7}
//!                   or:  {"op": "dot", "n": 4096, "seed": 7}
//!                   or:  {"op": "chain", "m": 64, "dims": [256, 128, 64],
//!                        "b_seeds": [42, null], "seed": 7,
//!                        "chained": true}  (a dependent GEMM sequence run
//!                        as ONE submission with device-resident
//!                        intermediates; "chained": false = per-op oracle)
//!                   or:  {"op": "dag", "m": 64, "d0": 256, "nodes":
//!                        [{"op": "gemm", "n": 128, "bias": true,
//!                          "relu": true, "b_seed": 42},
//!                         {"op": "gemm", "n": 128, "src": 0},
//!                         {"op": "axpy", "src": 0, "src2": 1}],
//!                        "seed": 7}  (a dataflow graph run as ONE
//!                        submission: fan-out trunks promoted once,
//!                        fan-in over resident branches; an absent
//!                        "src" consumes the external input x.
//!                        "publish_key" pins the sink output for the
//!                        fuse window; a follow-up naming it as
//!                        "input_key" splices onto the resident bytes)
//! Response (one line):  {"ok": true, "op": "gemm", "m": 128, "n": 128,
//!                        "mode": "device_only",
//!                        "total_ms": ..., "data_copy_ms": ...,
//!                        "fork_join_ms": ..., "compute_ms": ...,
//!                        "host_compute_ms": ..., "checksum": ...,
//!                        "cluster": ..., "batch_size": ...,
//!                        "queue_ms": ...}
//!
//! `seed` defaults to a stable function of the shape, so identical
//! requests return identical checksums.  `b_seed` (gemm only, optional)
//! draws B from its own stream: requests sharing a `b_seed` share a
//! bit-identical B matrix, which the scheduler's operand cache keeps
//! device-resident (the reused-weight serving pattern).  Malformed or
//! unknown requests always get an `{"ok": false, "error": ...}` line
//! back and the connection stays usable.  When the bounded queue is full
//! the response carries a backpressure hint: {"ok": false, "error":
//! "queue full", "retry_after_ms": ...}.  A request whose reply times
//! out at this layer (`[serve] reply_timeout_ms`, or `--reply-timeout-ms`)
//! cancels its job — so the pool never launches work for a dropped
//! receiver — and its error reply carries the same `retry_after_ms`
//! hint.  Replies served through fault recovery additionally carry
//! `"attempts"` (failed device attempts) and `"degraded": true` when
//! the pool fell back to the host BLAS path (checksum-identical by
//! construction).  `{"op": "metrics"}` reports the scheduler
//! counters — pool aggregates plus per-op-class p50/p99/p999 latency
//! percentiles, an aggregate serving-path `spans` breakdown, and a
//! `clusters` array with each cluster's run-queue depth, cache hits and
//! stolen / affinity-routed job counts; `{"op": "top"}` emits a compact
//! live view (per-cluster depth / hits / steals / inflight / pin leaks);
//! `{"op": "trace_dump"}` exports the flight recorder's ring buffers as
//! Chrome trace-event JSON (open the reply in Perfetto); `{"op":
//! "metrics_prom"}` renders every counter and latency histogram in the
//! Prometheus text exposition format (as an escaped `body` string);
//! `{"op": "watch"}` turns the connection into a stream of `top` frames
//! every `[sched.trace] watch_interval_ms` (or the request's own
//! `interval_ms`) until the client disconnects;
//! `{"op": "shutdown"}` stops the server (used by tests).
//!
//! Two cross-cutting request fields: `"req_id"` (string or number) is
//! echoed verbatim on every reply frame — success, error and
//! backpressure alike — so a client multiplexing requests can correlate
//! them (absent, the server assigns `"srv-<seq>"`); `"trace": true` on
//! any compute op adds the request's span breakdown (`queue -> route ->
//! stage -> execute -> finish`, wall-clock microseconds) to its reply,
//! whose named stages sum exactly to the reported `latency_us`.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::config::{DispatchMode, PlatformConfig};
use crate::dag::{DagNodeShape, DagOp, DagShape};
use crate::error::{Error, Result};
use crate::metrics::OP_CLASSES;
use crate::sched::{
    ChainRequest, DagRequest, GemmOutcome, GemmRequest, GemvRequest, JobPayload,
    Level1Op, Level1Request, Priority, Scheduler, SubmitError,
};
use crate::util::json_lite::Json;

/// How often parked connection readers wake to check for shutdown.
const READ_POLL: Duration = Duration::from_millis(100);
/// Hard bound on one request line, bytes.  A client that streams an
/// unbounded line (malicious or buggy) gets an `ok: false` reply and the
/// rest of the line discarded — the connection stays usable and the
/// server never buffers more than this per reader.
const MAX_LINE_BYTES: usize = 64 * 1024;

/// Server-assigned request correlation token (`srv-<seq>`), used when a
/// line carries no `req_id` — or could not be parsed at all.
static REQ_SEQ: AtomicU64 = AtomicU64::new(1);

fn srv_rid() -> Json {
    Json::Str(format!("srv-{}", REQ_SEQ.fetch_add(1, Ordering::Relaxed)))
}

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect::<BTreeMap<_, _>>())
}

fn err_line(msg: &str) -> String {
    let mut j = obj(vec![("ok", Json::Bool(false)), ("error", Json::Str(msg.into()))]);
    compact(&mut j)
}

/// One-line JSON (the pretty writer is multi-line; flatten it).
fn compact(j: &mut Json) -> String {
    j.to_string_pretty()
        .lines()
        .map(str::trim)
        .collect::<Vec<_>>()
        .join(" ")
}

/// Backpressure response: reject-with-retry-after.
fn backpressure_line(depth: usize, retry_after_ms: u64) -> String {
    let mut j = obj(vec![
        ("ok", Json::Bool(false)),
        ("error", Json::Str("queue full".into())),
        ("queue_depth", Json::Num(depth as f64)),
        ("retry_after_ms", Json::Num(retry_after_ms as f64)),
    ]);
    compact(&mut j)
}

fn gemm_response(o: &GemmOutcome, trace: bool) -> String {
    let mut pairs = vec![
        ("ok", Json::Bool(true)),
        ("op", Json::Str(o.op.into())),
        ("m", Json::Num(o.m as f64)),
        ("n", Json::Num(o.n as f64)),
        ("mode", Json::Str(o.mode.to_string())),
        ("data_copy_ms", Json::Num(o.data_copy_ms)),
        ("fork_join_ms", Json::Num(o.fork_join_ms)),
        ("compute_ms", Json::Num(o.compute_ms)),
        ("host_compute_ms", Json::Num(o.host_compute_ms)),
        ("total_ms", Json::Num(o.total_ms)),
        ("checksum", Json::Num(o.checksum)),
        ("cluster", Json::Num(o.cluster as f64)),
        ("batch_size", Json::Num(o.batch_size as f64)),
        ("queue_ms", Json::Num(o.queue_ms)),
    ];
    // fault recovery is opt-in on the wire: a clean reply (no faulted
    // attempts) is byte-for-byte the pre-fault response shape
    if o.degraded || o.attempts > 0 {
        pairs.push(("degraded", Json::Bool(o.degraded)));
        pairs.push(("attempts", Json::Num(o.attempts as f64)));
    }
    if trace {
        let s = &o.spans;
        // contract: the five named stages sum exactly to latency_us
        pairs.push(("latency_us", Json::Num(s.total_us as f64)));
        let mut span_pairs = vec![
            ("queue_us", Json::Num(s.queue_us as f64)),
            ("route_us", Json::Num(s.route_us as f64)),
            ("linger_us", Json::Num(s.linger_us as f64)),
            ("stage_us", Json::Num(s.stage_us as f64)),
            ("execute_us", Json::Num(s.execute_us as f64)),
            ("finish_us", Json::Num(s.finish_us as f64)),
            ("total_us", Json::Num(s.total_us as f64)),
        ];
        // like linger: a sub-span outside the telescoping sum, emitted
        // only when a faulted attempt actually consumed wall time
        if s.retry_us > 0 {
            span_pairs.push(("retry_us", Json::Num(s.retry_us as f64)));
        }
        pairs.push(("spans", obj(span_pairs)));
    }
    let mut j = obj(pairs);
    compact(&mut j)
}

/// Echo the request's correlation token onto a reply frame (every frame
/// is a JSON object; non-object lines pass through untouched).
fn with_req_id(resp: String, rid: &Json) -> String {
    match Json::parse(&resp) {
        Ok(Json::Obj(mut map)) => {
            map.insert("req_id".into(), rid.clone());
            compact(&mut Json::Obj(map))
        }
        _ => resp,
    }
}

/// Shared request fields: dispatch mode + priority.
fn parse_mode_priority(req: &Json)
                       -> std::result::Result<(DispatchMode, Priority), String> {
    let mode: DispatchMode = req
        .get("mode")
        .and_then(|v| v.as_str())
        .unwrap_or("auto")
        .parse()
        .map_err(|e: Error| e.to_string())?;
    let priority: Priority = req
        .get("priority")
        .and_then(|v| v.as_str())
        .unwrap_or("normal")
        .parse()
        .map_err(|e: Error| e.to_string())?;
    Ok((mode, priority))
}

/// Parse a gemm request line into a job payload + priority.
fn parse_gemm(req: &Json) -> std::result::Result<(GemmRequest, Priority), String> {
    let n = req.get("n").and_then(|v| v.as_u64()).unwrap_or(128) as usize;
    if n == 0 || n > 2048 {
        return Err("n must be in 1..=2048".into());
    }
    let (mode, priority) = parse_mode_priority(req)?;
    // Stable default seed: identical requests serve identical workloads
    // (and batch members stay individually verifiable by checksum).
    let seed = req
        .get("seed")
        .and_then(|v| v.as_u64())
        .unwrap_or(0xC0FFEE ^ n as u64);
    // Optional shared-B stream: requests carrying the same b_seed reuse a
    // bit-identical B matrix (the operand-cache hot path).
    let b_seed = req.get("b_seed").and_then(|v| v.as_u64());
    Ok((GemmRequest { n, mode, seed, b_seed }, priority))
}

/// Parse a level-1 request line (axpy or dot) into a payload + priority.
fn parse_level1(
    op: Level1Op,
    req: &Json,
) -> std::result::Result<(Level1Request, Priority), String> {
    let n = req.get("n").and_then(|v| v.as_u64()).unwrap_or(4096) as usize;
    if n == 0 || n > 1 << 20 {
        return Err("n must be in 1..=1048576".into());
    }
    let (mode, priority) = parse_mode_priority(req)?;
    let seed = req
        .get("seed")
        .and_then(|v| v.as_u64())
        .unwrap_or(0xACE ^ n as u64 ^ ((op as u64) << 32));
    let alpha = req.get("alpha").and_then(|v| v.as_f64()).unwrap_or(1.0);
    if !alpha.is_finite() {
        return Err("alpha must be finite".into());
    }
    Ok((Level1Request { op, n, mode, seed, alpha }, priority))
}

/// Parse a chain request line: `{"op": "chain", "m": 64, "dims": [256,
/// 128, 64], "seed": 7, "b_seeds": [42, null], "chained": true}` — a
/// dependent GEMM sequence executed as ONE submission whose
/// intermediates stay device-resident (`chained: false` runs the same
/// links as separate per-op offloads, the regression/bench baseline).
/// `b_seeds[i]`, when set, draws link i's weights from a shared stream
/// so chains (and plain gemms) carrying the same seed reuse one
/// device-resident matrix.
fn parse_chain(req: &Json) -> std::result::Result<(ChainRequest, Priority), String> {
    let m = req.get("m").and_then(|v| v.as_u64()).unwrap_or(64) as usize;
    if m == 0 || m > 2048 {
        return Err("m must be in 1..=2048".into());
    }
    let dims: Vec<usize> = match req.get("dims").and_then(|v| v.as_arr()) {
        Some(arr) => {
            let mut out = Vec::with_capacity(arr.len());
            for v in arr {
                match v.as_u64() {
                    Some(d) if (1..=2048).contains(&d) => out.push(d as usize),
                    _ => return Err("dims entries must be in 1..=2048".into()),
                }
            }
            out
        }
        None => return Err("chain needs a dims array".into()),
    };
    if dims.len() < 2 {
        return Err("chain needs at least 2 dims (1 link)".into());
    }
    let links = dims.len() - 1;
    let (mode, priority) = parse_mode_priority(req)?;
    if mode == DispatchMode::DeviceZeroCopy {
        return Err(
            "chain does not support zero_copy (device-resident intermediates \
             are a copy-mode technique)"
                .into(),
        );
    }
    let seed = req
        .get("seed")
        .and_then(|v| v.as_u64())
        .unwrap_or(0xC4A1 ^ ((m as u64) << 16) ^ links as u64);
    let b_seeds = match req.get("b_seeds").and_then(|v| v.as_arr()) {
        Some(arr) => {
            if arr.len() != links {
                return Err(format!(
                    "b_seeds has {} entries for {links} links",
                    arr.len()
                ));
            }
            arr.iter().map(|v| v.as_u64()).collect()
        }
        None => vec![None; links],
    };
    let chained = req
        .get("chained")
        .and_then(|v| match v {
            Json::Bool(b) => Some(*b),
            _ => None,
        })
        .unwrap_or(true);
    Ok((ChainRequest { m, dims, mode, seed, b_seeds, chained }, priority))
}

/// Parse a dag request line: `{"op": "dag", "m": 64, "d0": 256,
/// "nodes": [{"op": "gemm", "n": 128, "b_seed": 42, "bias": true,
/// "relu": true}, {"op": "gemm", "n": 128, "src": 0}, {"op": "axpy",
/// "src": 0, "src2": 1}], "seed": 7}` — a dataflow graph executed as
/// ONE submission.  Node order IS topological order: `src`/`src2` name
/// earlier node indices (absent = the external input x, m x d0).
/// `b_seed` on a gemm/gemv node draws that node's weights from its own
/// stream (the shared-weight affinity key); `bias`/`relu` fuse the
/// usual epilogues.  `publish_key`/`input_key` opt into cross-request
/// fusion through the worker's resident sink output.
fn parse_dag(req: &Json) -> std::result::Result<(DagRequest, Priority), String> {
    let m = req.get("m").and_then(|v| v.as_u64()).unwrap_or(64) as usize;
    if m == 0 || m > 2048 {
        return Err("m must be in 1..=2048".into());
    }
    let d0 = req.get("d0").and_then(|v| v.as_u64()).unwrap_or(64) as usize;
    if d0 == 0 || d0 > 2048 {
        return Err("d0 must be in 1..=2048".into());
    }
    let arr = match req.get("nodes").and_then(|v| v.as_arr()) {
        Some(arr) if !arr.is_empty() => arr,
        Some(_) => return Err("dag needs at least 1 node".into()),
        None => return Err("dag needs a nodes array".into()),
    };
    let mut nodes = Vec::with_capacity(arr.len());
    let mut b_seeds = Vec::with_capacity(arr.len());
    for (i, nj) in arr.iter().enumerate() {
        let op_name = nj
            .get("op")
            .and_then(|v| v.as_str())
            .ok_or_else(|| format!("node {i}: missing op"))?;
        let op = DagOp::from_name(op_name)
            .ok_or_else(|| format!("node {i}: unknown op '{op_name}'"))?;
        // only gemm carries an output width; the rest derive theirs
        let n = match op {
            DagOp::Gemm => match nj.get("n").and_then(|v| v.as_u64()) {
                Some(n) if (1..=2048).contains(&n) => n as usize,
                _ => return Err(format!("node {i}: gemm needs n in 1..=2048")),
            },
            _ => 0,
        };
        let src = nj.get("src").and_then(|v| v.as_u64()).map(|s| s as usize);
        let src2 = nj.get("src2").and_then(|v| v.as_u64()).map(|s| s as usize);
        let bias = matches!(nj.get("bias"), Some(Json::Bool(true)));
        let relu = matches!(nj.get("relu"), Some(Json::Bool(true)));
        b_seeds.push(nj.get("b_seed").and_then(|v| v.as_u64()));
        nodes.push(DagNodeShape { op, src, src2, n, bias, relu });
    }
    let (mode, priority) = parse_mode_priority(req)?;
    if mode == DispatchMode::DeviceZeroCopy {
        return Err(
            "dag does not support zero_copy (device-resident intermediates \
             are a copy-mode technique)"
                .into(),
        );
    }
    let seed = req
        .get("seed")
        .and_then(|v| v.as_u64())
        .unwrap_or(0xDA6 ^ ((m as u64) << 16) ^ nodes.len() as u64);
    let publish_key = req.get("publish_key").and_then(|v| v.as_u64());
    let input_key = req.get("input_key").and_then(|v| v.as_u64());
    let shape = DagShape { m, d0, nodes };
    Ok((
        DagRequest { shape, mode, seed, b_seeds, publish_key, input_key },
        priority,
    ))
}

/// Parse a gemv request line into a job payload + priority.
fn parse_gemv(req: &Json) -> std::result::Result<(GemvRequest, Priority), String> {
    let m = req.get("m").and_then(|v| v.as_u64()).unwrap_or(128) as usize;
    let n = req.get("n").and_then(|v| v.as_u64()).unwrap_or(128) as usize;
    if m == 0 || m > 2048 || n == 0 || n > 2048 {
        return Err("m and n must be in 1..=2048".into());
    }
    let (mode, priority) = parse_mode_priority(req)?;
    let seed = req
        .get("seed")
        .and_then(|v| v.as_u64())
        .unwrap_or(0xBEEF ^ ((m as u64) << 16) ^ n as u64);
    Ok((GemvRequest { m, n, mode, seed }, priority))
}

/// Handle one request line; returns (response, shutdown?).  Every reply
/// frame — success, error and backpressure alike — carries a `req_id`:
/// the request's own token echoed back (string or number), or a
/// server-assigned `srv-<seq>` when absent or the line failed to parse.
fn handle_line(
    sched: &Scheduler,
    line: &str,
    reply_timeout: Duration,
) -> (String, bool) {
    let parsed = Json::parse(line);
    let rid = match parsed.as_ref().ok().and_then(|r| r.get("req_id")) {
        Some(v) if matches!(v, Json::Str(_) | Json::Num(_)) => v.clone(),
        _ => srv_rid(),
    };
    let (resp, shut) = match parsed {
        Ok(req) => dispatch_op(sched, &req, reply_timeout),
        Err(e) => (err_line(&format!("bad json: {e}")), false),
    };
    (with_req_id(resp, &rid), shut)
}

/// Route one parsed request to its op handler.
fn dispatch_op(
    sched: &Scheduler,
    req: &Json,
    reply_timeout: Duration,
) -> (String, bool) {
    let op = req.get("op").and_then(|o| o.as_str()).unwrap_or("");
    // opt-in per-request span breakdown on the reply
    let trace = matches!(req.get("trace"), Some(Json::Bool(true)));
    match op {
        "shutdown" => (err_line("shutting down"), true),
        "ping" => {
            let mut j = obj(vec![
                ("ok", Json::Bool(true)),
                ("pong", Json::Bool(true)),
                ("pool", Json::Num(sched.pool_size() as f64)),
                ("queue_depth", Json::Num(sched.queue_depth() as f64)),
            ]);
            (compact(&mut j), false)
        }
        "metrics" => {
            let m = sched.metrics();
            // live calibrated crossovers (0 = the device never wins
            // inside the serve-protocol shape bounds on that op)
            let x = sched.cost_model().crossovers();
            let xn = |v: Option<usize>| Json::Num(v.unwrap_or(0) as f64);
            let crossover = obj(vec![
                ("gemm_n", xn(x.gemm_n)),
                ("gemm_warm_n", xn(x.gemm_warm_n)),
                ("gemv_n", xn(x.gemv_n)),
                ("level1_n", xn(x.level1_n)),
                // dual crossover lines: the same ops through a
                // registry-specialized walk (promoted hot shapes
                // offload at or below the generic flip point)
                ("gemm_spec_n", xn(x.gemm_spec_n)),
                ("gemv_spec_n", xn(x.gemv_spec_n)),
                ("level1_spec_n", xn(x.level1_spec_n)),
            ]);
            let clusters: Vec<Json> = m
                .clusters
                .iter()
                .map(|c| {
                    obj(vec![
                        ("cluster", Json::Num(c.cluster as f64)),
                        ("queue_depth", Json::Num(c.queue_depth as f64)),
                        ("inflight", Json::Num(c.inflight as f64)),
                        ("completed", Json::Num(c.completed as f64)),
                        ("batches", Json::Num(c.batches as f64)),
                        ("stolen", Json::Num(c.stolen as f64)),
                        ("affine_routed", Json::Num(c.affine_routed as f64)),
                        ("prefetched", Json::Num(c.prefetched as f64)),
                        ("cache_hits", Json::Num(c.cache_hits as f64)),
                        ("cache_misses", Json::Num(c.cache_misses as f64)),
                        ("bytes_to_device", Json::Num(c.bytes_to_device as f64)),
                        ("p50_us", Json::Num(c.p50_us as f64)),
                        ("p99_us", Json::Num(c.p99_us as f64)),
                        ("p999_us", Json::Num(c.p999_us as f64)),
                        ("quarantined", Json::Bool(sched.is_quarantined(c.cluster))),
                    ])
                })
                .collect();
            // per-op-class latency percentiles (log-bucket histograms:
            // each quantile reports its bucket's upper bound)
            let lat = |l: &crate::metrics::OpClassLatency| {
                obj(vec![
                    ("count", Json::Num(l.count as f64)),
                    ("p50_us", Json::Num(l.p50_us as f64)),
                    ("p99_us", Json::Num(l.p99_us as f64)),
                    ("p999_us", Json::Num(l.p999_us as f64)),
                ])
            };
            let latency = obj(
                OP_CLASSES
                    .iter()
                    .zip(m.latency.iter())
                    .map(|(name, l)| (*name, lat(l)))
                    .collect(),
            );
            let spans = obj(vec![
                ("queue_us", Json::Num(m.spans.queue_us as f64)),
                ("route_us", Json::Num(m.spans.route_us as f64)),
                ("linger_us", Json::Num(m.spans.linger_us as f64)),
                ("stage_us", Json::Num(m.spans.stage_us as f64)),
                ("execute_us", Json::Num(m.spans.execute_us as f64)),
                ("finish_us", Json::Num(m.spans.finish_us as f64)),
                ("retry_us", Json::Num(m.spans.retry_us as f64)),
            ]);
            let mut j = obj(vec![
                ("ok", Json::Bool(true)),
                ("submitted", Json::Num(m.submitted as f64)),
                ("completed", Json::Num(m.completed as f64)),
                ("rejected", Json::Num(m.rejected as f64)),
                ("failed", Json::Num(m.failed as f64)),
                ("cancelled", Json::Num(m.cancelled as f64)),
                ("batches", Json::Num(m.batches as f64)),
                ("batched_jobs", Json::Num(m.batched_jobs as f64)),
                ("pipelined_batches", Json::Num(m.pipelined_batches as f64)),
                ("overlap_hidden_us", Json::Num(m.overlap_hidden_us as f64)),
                ("cache_hits", Json::Num(m.cache_hits as f64)),
                ("cache_misses", Json::Num(m.cache_misses as f64)),
                ("cache_evictions", Json::Num(m.cache_evictions as f64)),
                ("bytes_to_device", Json::Num(m.bytes_to_device as f64)),
                ("bytes_copy_elided", Json::Num(m.bytes_copy_elided as f64)),
                ("stolen", Json::Num(m.stolen as f64)),
                ("affine_routed", Json::Num(m.affine_routed as f64)),
                ("big_shape_routed", Json::Num(m.big_shape_routed as f64)),
                ("prefetched", Json::Num(m.prefetched as f64)),
                ("rehomed", Json::Num(m.rehomed as f64)),
                ("chains", Json::Num(m.chains as f64)),
                ("chain_bytes_elided", Json::Num(m.chain_bytes_elided as f64)),
                ("dags", Json::Num(m.dags as f64)),
                ("dag_nodes", Json::Num(m.dag_nodes as f64)),
                ("dag_bytes_elided", Json::Num(m.dag_bytes_elided as f64)),
                ("dag_fused_requests", Json::Num(m.dag_fused_requests as f64)),
                ("faults_injected", Json::Num(m.faults_injected as f64)),
                ("retries", Json::Num(m.retries as f64)),
                ("quarantined", Json::Num(m.quarantined as f64)),
                ("host_fallbacks", Json::Num(m.host_fallbacks as f64)),
                ("cache_invalidated_bytes", Json::Num(m.cache_invalidated_bytes as f64)),
                ("pin_leaks", Json::Num(m.pin_leaks as f64)),
                ("kernel_specialized", Json::Num(m.kernel_specialized as f64)),
                ("kernel_hits", Json::Num(m.kernel_hits as f64)),
                ("kernel_fallbacks", Json::Num(m.kernel_fallbacks as f64)),
                ("kernel_evictions", Json::Num(m.kernel_evictions as f64)),
                ("kernel_entries", Json::Num(m.kernel_entries as f64)),
                ("crossover_estimate", crossover),
                ("latency", latency),
                ("p50_us", Json::Num(m.overall.p50_us as f64)),
                ("p99_us", Json::Num(m.overall.p99_us as f64)),
                ("p999_us", Json::Num(m.overall.p999_us as f64)),
                ("spans", spans),
                ("queue_depth_peak", Json::Num(m.queue_depth_peak as f64)),
                ("pool", Json::Num(sched.pool_size() as f64)),
                ("clusters", Json::Arr(clusters)),
            ]);
            (compact(&mut j), false)
        }
        "top" => (top_line(sched), false),
        "trace_dump" => {
            // the flight recorder's Chrome trace-event export; the whole
            // reply IS the trace file (plus ok/enabled/req_id), so a
            // client can pipe it straight into Perfetto
            match Json::parse(&sched.trace().chrome_json()) {
                Ok(Json::Obj(mut map)) => {
                    map.insert("ok".into(), Json::Bool(true));
                    map.insert(
                        "enabled".into(),
                        Json::Bool(sched.trace().enabled()),
                    );
                    map.insert(
                        "recorded".into(),
                        Json::Num(sched.trace().recorded() as f64),
                    );
                    (compact(&mut Json::Obj(map)), false)
                }
                _ => (err_line("trace export failed"), false),
            }
        }
        "metrics_prom" => {
            // Prometheus text exposition, shipped as an escaped string
            // body so the reply stays one JSON line (and carries req_id
            // like every other frame); clients unescape and scrape
            let mut j = obj(vec![
                ("ok", Json::Bool(true)),
                ("op", Json::Str("metrics_prom".into())),
                (
                    "content_type",
                    Json::Str("text/plain; version=0.0.4".into()),
                ),
                ("body", Json::Str(sched.prometheus_text())),
            ]);
            (compact(&mut j), false)
        }
        // `watch` never reaches here: serve_conn intercepts it before
        // dispatch because streaming needs the connection's writer
        "watch" => (err_line("watch requires a streaming connection"), false),
        "gemm" => {
            let (gemm, priority) = match parse_gemm(req) {
                Ok(p) => p,
                Err(msg) => return (err_line(&msg), false),
            };
            submit_and_wait(sched, priority, JobPayload::Gemm(gemm), trace, reply_timeout)
        }
        "gemv" => {
            let (gemv, priority) = match parse_gemv(req) {
                Ok(p) => p,
                Err(msg) => return (err_line(&msg), false),
            };
            submit_and_wait(sched, priority, JobPayload::Gemv(gemv), trace, reply_timeout)
        }
        "chain" => {
            let (chain, priority) = match parse_chain(req) {
                Ok(p) => p,
                Err(msg) => return (err_line(&msg), false),
            };
            // capacity preflight: a chain whose resident footprint no
            // cluster slice can hold fails HERE with a clear error
            // instead of wedging in staging retries on a worker
            if let Err(msg) = sched.validate_chain(&chain) {
                return (err_line(&msg), false);
            }
            submit_and_wait(sched, priority, JobPayload::Chain(chain), trace, reply_timeout)
        }
        "dag" => {
            let (dag, priority) = match parse_dag(req) {
                Ok(p) => p,
                Err(msg) => return (err_line(&msg), false),
            };
            // same preflight as chains, plus graph structure: a cyclic,
            // over-wide, over-deep or over-capacity DAG fails HERE with
            // the offending node named, not in staging on a worker
            if let Err(msg) = sched.validate_dag(&dag) {
                return (err_line(&msg), false);
            }
            submit_and_wait(sched, priority, JobPayload::Dag(dag), trace, reply_timeout)
        }
        "axpy" | "dot" => {
            let l1op = if op == "axpy" { Level1Op::Axpy } else { Level1Op::Dot };
            let (l1, priority) = match parse_level1(l1op, req) {
                Ok(p) => p,
                Err(msg) => return (err_line(&msg), false),
            };
            submit_and_wait(sched, priority, JobPayload::Level1(l1), trace, reply_timeout)
        }
        other => (err_line(&format!("unknown op '{other}'")), false),
    }
}

/// The `top` frame: a compact live view of what each cluster is doing
/// right now.  Shared by the one-shot `top` op and the `watch` stream.
fn top_line(sched: &Scheduler) -> String {
    let m = sched.metrics();
    let clusters: Vec<Json> = m
        .clusters
        .iter()
        .map(|c| {
            obj(vec![
                ("cluster", Json::Num(c.cluster as f64)),
                ("queue_depth", Json::Num(c.queue_depth as f64)),
                ("inflight", Json::Num(c.inflight as f64)),
                ("completed", Json::Num(c.completed as f64)),
                ("cache_hits", Json::Num(c.cache_hits as f64)),
                ("stolen", Json::Num(c.stolen as f64)),
                ("pin_leaks", Json::Num(c.pin_leaks as f64)),
                ("p99_us", Json::Num(c.p99_us as f64)),
                ("quarantined", Json::Bool(sched.is_quarantined(c.cluster))),
            ])
        })
        .collect();
    // hottest kernel keys by launch count — the per-key view of the
    // registry's promotion feed (`specialized` marks a resident plan)
    let kernels: Vec<Json> = sched
        .kernel_registry()
        .top_keys(8)
        .into_iter()
        .map(|(key, launches, specialized)| {
            obj(vec![
                ("key", Json::Str(format!("{key:016x}"))),
                ("launches", Json::Num(launches as f64)),
                ("specialized", Json::Bool(specialized)),
            ])
        })
        .collect();
    let mut j = obj(vec![
        ("ok", Json::Bool(true)),
        ("op", Json::Str("top".into())),
        ("queue_depth", Json::Num(sched.queue_depth() as f64)),
        ("completed", Json::Num(m.completed as f64)),
        ("pin_leaks", Json::Num(m.pin_leaks as f64)),
        ("dag_fused_requests", Json::Num(m.dag_fused_requests as f64)),
        ("kernel_hits", Json::Num(m.kernel_hits as f64)),
        ("kernel_entries", Json::Num(m.kernel_entries as f64)),
        ("kernels", Json::Arr(kernels)),
        ("clusters", Json::Arr(clusters)),
    ]);
    compact(&mut j)
}

/// Recognize a `watch` request line: returns its correlation token and
/// frame interval (the request's `interval_ms` clamped to 1..=60000, or
/// the configured default) when `op` is `"watch"`.
fn watch_request(line: &str, default_interval: Duration) -> Option<(Json, Duration)> {
    let req = Json::parse(line).ok()?;
    if req.get("op").and_then(|o| o.as_str()) != Some("watch") {
        return None;
    }
    let rid = match req.get("req_id") {
        Some(v) if matches!(v, Json::Str(_) | Json::Num(_)) => v.clone(),
        _ => srv_rid(),
    };
    let interval = req
        .get("interval_ms")
        .and_then(|v| v.as_u64())
        .map(|ms| Duration::from_millis(ms.clamp(1, 60_000)))
        .unwrap_or(default_interval);
    Some((rid, interval))
}

/// Stream the `top` view as newline-delimited JSON frames until the
/// client disconnects (write failure) or the server shuts down.  Every
/// frame echoes the watch request's `req_id` so a client multiplexing a
/// watch with other traffic on separate connections can correlate them.
fn run_watch(
    sched: &Scheduler,
    writer: &mut TcpStream,
    rid: &Json,
    interval: Duration,
    shutdown: &AtomicBool,
) {
    loop {
        if shutdown.load(Ordering::Acquire) {
            return;
        }
        let frame = with_req_id(top_line(sched), rid);
        if !write_line(writer, &frame) {
            return; // peer gone
        }
        // sleep in READ_POLL steps so shutdown is noticed promptly even
        // under a long frame interval
        let mut slept = Duration::ZERO;
        while slept < interval {
            if shutdown.load(Ordering::Acquire) {
                return;
            }
            let step = READ_POLL.min(interval - slept);
            std::thread::sleep(step);
            slept += step;
        }
    }
}

/// Submit a job and block on its reply.  A timeout cancels the job (via
/// [`crate::sched::Submission::recv_timeout`]) so a worker never
/// launches it for this already-gone receiver.  The timed-out reply
/// carries the same `retry_after_ms` hint as a backpressure rejection —
/// from the client's side both mean "the pool is saturated, come back".
fn submit_and_wait(
    sched: &Scheduler,
    priority: Priority,
    payload: JobPayload,
    trace: bool,
    reply_timeout: Duration,
) -> (String, bool) {
    match sched.submit(priority, payload) {
        Ok(submission) => match submission.recv_timeout(reply_timeout) {
            Ok(Ok(outcome)) => (gemm_response(&outcome, trace), false),
            Ok(Err(msg)) => (err_line(&msg), false),
            Err(_) => {
                let mut j = obj(vec![
                    ("ok", Json::Bool(false)),
                    ("error", Json::Str("worker unavailable".into())),
                    (
                        "retry_after_ms",
                        Json::Num(sched.current_retry_hint_ms() as f64),
                    ),
                ]);
                (compact(&mut j), false)
            }
        },
        Err(SubmitError::Backpressure { depth, retry_after_ms }) => {
            (backpressure_line(depth, retry_after_ms), false)
        }
        Err(SubmitError::ShuttingDown) => (err_line("shutting down"), false),
    }
}

/// Write one reply line; false when the peer is gone.
fn write_line(writer: &mut TcpStream, resp: &str) -> bool {
    writer
        .write_all(resp.as_bytes())
        .and_then(|_| writer.write_all(b"\n"))
        .and_then(|_| writer.flush())
        .is_ok()
}

/// One connection: read lines (with a poll timeout so shutdown is
/// noticed), answer each, never drop the connection on a bad request.
/// Lines are read bytewise under a hard [`MAX_LINE_BYTES`] bound: an
/// oversized line is answered with `ok: false` and discarded to its
/// newline, a non-UTF-8 line likewise — the connection stays usable
/// either way, and the server never buffers more than the bound.
fn serve_conn(
    sched: &Scheduler,
    stream: TcpStream,
    shutdown: &AtomicBool,
    port: u16,
    reply_timeout: Duration,
    watch_interval: Duration,
) {
    let peer = stream.peer_addr().map(|a| a.to_string()).unwrap_or_default();
    let _ = stream.set_read_timeout(Some(READ_POLL));
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(e) => {
            eprintln!("serve: clone stream for {peer}: {e}");
            return;
        }
    };
    let mut reader = BufReader::new(stream);
    let mut buf: Vec<u8> = Vec::new();
    // true while skipping the tail of a line that blew the bound
    let mut discarding = false;
    loop {
        if shutdown.load(Ordering::Acquire) {
            return;
        }
        // Read at most one byte past the bound per attempt: crossing it
        // proves the line is oversized without buffering the rest.
        let room = if discarding {
            MAX_LINE_BYTES + 1
        } else {
            (MAX_LINE_BYTES + 1).saturating_sub(buf.len())
        };
        match (&mut reader).take(room as u64).read_until(b'\n', &mut buf) {
            Ok(0) => return, // EOF (a partial line is dropped, as before)
            Ok(_) => {
                let complete = buf.last() == Some(&b'\n');
                if complete {
                    buf.pop();
                    if buf.last() == Some(&b'\r') {
                        buf.pop();
                    }
                }
                if discarding {
                    if complete {
                        // tail of the oversized line finally drained
                        discarding = false;
                        let resp = with_req_id(err_line("line too long"), &srv_rid());
                        if !write_line(&mut writer, &resp) {
                            return;
                        }
                    }
                    buf.clear();
                    continue;
                }
                if !complete {
                    if buf.len() > MAX_LINE_BYTES {
                        // bound crossed mid-line: reply once the newline
                        // arrives, discard everything until then
                        discarding = true;
                        buf.clear();
                    }
                    // else: partial line, keep accumulating
                    continue;
                }
                let resp_shut = match std::str::from_utf8(&buf) {
                    Ok(line) => {
                        let trimmed = line.trim();
                        if trimmed.is_empty() {
                            None
                        } else if let Some((rid, interval)) =
                            watch_request(trimmed, watch_interval)
                        {
                            // streaming op: takes over this connection's
                            // writer until disconnect or shutdown
                            run_watch(sched, &mut writer, &rid, interval, shutdown);
                            return;
                        } else {
                            Some(handle_line(sched, trimmed, reply_timeout))
                        }
                    }
                    Err(_) => Some((
                        with_req_id(err_line("invalid utf-8"), &srv_rid()),
                        false,
                    )),
                };
                if let Some((resp, shut)) = resp_shut {
                    if !write_line(&mut writer, &resp) {
                        return;
                    }
                    if shut {
                        eprintln!("serve: shutdown requested by {peer}");
                        shutdown.store(true, Ordering::Release);
                        // unblock the accept loop so it can observe the flag
                        let _ = TcpStream::connect(("127.0.0.1", port));
                        return;
                    }
                }
                buf.clear();
            }
            // poll timeout: partial input (if any) stays in `buf`
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {}
            Err(_) => return,
        }
    }
}

/// Run the server until a shutdown request arrives.
/// `ready` (if given) receives the bound port once listening — lets tests
/// bind port 0 and discover the ephemeral port.
pub fn serve(
    cfg: PlatformConfig,
    artifacts: &Path,
    port: u16,
    ready: Option<std::sync::mpsc::Sender<u16>>,
) -> Result<()> {
    let sched = Arc::new(Scheduler::new(&cfg, artifacts)?);
    // floor of 1ms: a zero would turn every reply into an instant cancel
    let reply_timeout = Duration::from_millis(cfg.serve.reply_timeout_ms.max(1));
    let watch_interval =
        Duration::from_millis(cfg.sched.trace.watch_interval_ms.max(1));

    let listener = TcpListener::bind(("127.0.0.1", port))
        .map_err(|e| Error::Runtime(format!("bind 127.0.0.1:{port}: {e}")))?;
    let bound = listener.local_addr()?.port();
    let cap = sched.capacity();
    let xing = sched.cost_model().crossovers();
    let show = |v: Option<usize>| match v {
        Some(n) => format!("n>={n}"),
        None => "never".into(),
    };
    eprintln!(
        "hero-blas serve: listening on 127.0.0.1:{bound} \
         (pool {} clusters x {} tiles, queue {} deep, batch <= {}, \
         big-shape lane: {})",
        sched.pool_size(),
        cap.tiles_per_cluster,
        cfg.sched.queue_capacity,
        cfg.sched.batch_max,
        match cap.big {
            Some(c) => format!("cluster {c} ({} B)", cap.max_slice()),
            None => "off".into(),
        },
    );
    eprintln!(
        "hero-blas serve: cost model crossovers — gemm {} (warm-B {}), \
         gemv {}, level-1 {}; calibration {}",
        show(xing.gemm_n),
        show(xing.gemm_warm_n),
        show(xing.gemv_n),
        show(xing.level1_n),
        if cfg.cost.calibrate { "on" } else { "off" },
    );
    if cfg.kernel.enabled {
        eprintln!(
            "hero-blas serve: kernel registry ON — promote after {}, \
             {} entries max, prewarm {}; specialized crossovers — \
             gemm {}, gemv {}, level-1 {}",
            cfg.kernel.promote_after,
            cfg.kernel.max_entries,
            if cfg.kernel.prewarm { "on" } else { "off" },
            show(xing.gemm_spec_n),
            show(xing.gemv_spec_n),
            show(xing.level1_spec_n),
        );
    }
    if cfg.sched.fault.enabled {
        let f = &cfg.sched.fault;
        eprintln!(
            "hero-blas serve: fault injection ON — seed {}, rates \
             staging {} / mailbox {} / poison {}, target cluster {}, \
             max {} attempts, quarantine after {}",
            f.seed,
            f.staging_rate,
            f.mailbox_rate,
            f.poison_rate,
            if f.target_cluster < 0 { "any".to_string() } else { f.target_cluster.to_string() },
            f.max_attempts,
            f.quarantine_threshold,
        );
    }
    if cfg.sched.trace.enabled {
        eprintln!(
            "hero-blas serve: flight recorder ON — {} events/cluster ring, \
             watch frames every {} ms (trace_dump / metrics_prom / watch)",
            cfg.sched.trace.ring_capacity, cfg.sched.trace.watch_interval_ms,
        );
    }
    if let Some(tx) = ready {
        let _ = tx.send(bound);
    }

    let shutdown = Arc::new(AtomicBool::new(false));
    let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
    for stream in listener.incoming() {
        if shutdown.load(Ordering::Acquire) {
            break;
        }
        match stream {
            Ok(s) => {
                let sched = Arc::clone(&sched);
                let shutdown = Arc::clone(&shutdown);
                // spawn failure (thread exhaustion under a connect flood)
                // drops this one connection; the server keeps serving
                match std::thread::Builder::new()
                    .name("serve-conn".into())
                    .spawn(move || {
                        serve_conn(
                            &sched, s, &shutdown, bound, reply_timeout,
                            watch_interval,
                        )
                    })
                {
                    Ok(h) => conns.push(h),
                    Err(e) => eprintln!("serve: spawn connection handler: {e}"),
                }
                // reap finished handlers so long-lived servers don't
                // accumulate joinable threads
                conns.retain(|h| !h.is_finished());
            }
            Err(e) => eprintln!("serve: accept error: {e}"),
        }
    }
    for h in conns {
        let _ = h.join();
    }
    sched.shutdown();
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compact_is_single_line() {
        let mut j = obj(vec![("a", Json::Num(1.0)), ("b", Json::Str("x".into()))]);
        let s = compact(&mut j);
        assert!(!s.contains('\n'));
        assert!(Json::parse(&s).is_ok());
    }

    #[test]
    fn err_line_is_json() {
        let e = err_line("boom");
        let j = Json::parse(&e).unwrap();
        assert_eq!(j.get("ok"), Some(&Json::Bool(false)));
    }

    #[test]
    fn backpressure_line_carries_retry_hint() {
        let j = Json::parse(&backpressure_line(17, 42)).unwrap();
        assert_eq!(j.get("ok"), Some(&Json::Bool(false)));
        assert_eq!(j.get("error").and_then(|v| v.as_str()), Some("queue full"));
        assert_eq!(j.get("queue_depth").and_then(|v| v.as_u64()), Some(17));
        assert_eq!(j.get("retry_after_ms").and_then(|v| v.as_u64()), Some(42));
    }

    #[test]
    fn parse_gemm_defaults_and_limits() {
        let req = Json::parse(r#"{"op": "gemm"}"#).unwrap();
        let (g, p) = parse_gemm(&req).unwrap();
        assert_eq!(g.n, 128);
        assert_eq!(g.mode, DispatchMode::Auto);
        assert_eq!(p, Priority::Normal);
        // stable default seed: same request, same workload
        let (g2, _) = parse_gemm(&req).unwrap();
        assert_eq!(g.seed, g2.seed);

        let req = Json::parse(
            r#"{"op": "gemm", "n": 64, "mode": "device_only",
                "priority": "high", "seed": 9}"#,
        )
        .unwrap();
        let (g, p) = parse_gemm(&req).unwrap();
        assert_eq!((g.n, g.seed), (64, 9));
        assert_eq!(g.mode, DispatchMode::DeviceOnly);
        assert_eq!(p, Priority::High);

        let req = Json::parse(r#"{"op": "gemm", "n": 99999}"#).unwrap();
        assert!(parse_gemm(&req).is_err());
        let req = Json::parse(r#"{"op": "gemm", "mode": "warp_drive"}"#).unwrap();
        assert!(parse_gemm(&req).unwrap_err().contains("warp_drive"));
        let req = Json::parse(r#"{"op": "gemm", "priority": "urgent"}"#).unwrap();
        assert!(parse_gemm(&req).unwrap_err().contains("urgent"));
    }

    #[test]
    fn parse_gemm_b_seed_optional() {
        let req = Json::parse(r#"{"op": "gemm", "n": 64}"#).unwrap();
        let (g, _) = parse_gemm(&req).unwrap();
        assert_eq!(g.b_seed, None, "absent b_seed keeps classic synthesis");
        let req =
            Json::parse(r#"{"op": "gemm", "n": 64, "seed": 1, "b_seed": 42}"#).unwrap();
        let (g, _) = parse_gemm(&req).unwrap();
        assert_eq!(g.b_seed, Some(42));
        assert_eq!(g.seed, 1);
    }

    #[test]
    fn parse_chain_specs_and_limits() {
        let req = Json::parse(
            r#"{"op": "chain", "m": 64, "dims": [256, 128, 64], "seed": 7,
                "b_seeds": [42, null], "mode": "device_only"}"#,
        )
        .unwrap();
        let (c, p) = parse_chain(&req).unwrap();
        assert_eq!((c.m, c.seed), (64, 7));
        assert_eq!(c.dims, vec![256, 128, 64]);
        assert_eq!(c.b_seeds, vec![Some(42), None]);
        assert!(c.chained, "chained defaults on");
        assert_eq!(c.links(), 2);
        assert_eq!(c.mode, DispatchMode::DeviceOnly);
        assert_eq!(p, Priority::Normal);

        // the unchained oracle knob
        let req = Json::parse(
            r#"{"op": "chain", "dims": [64, 64], "chained": false}"#,
        )
        .unwrap();
        let (c, _) = parse_chain(&req).unwrap();
        assert!(!c.chained);
        assert_eq!(c.b_seeds, vec![None], "absent b_seeds default to None");
        // stable default seed
        let (c2, _) = parse_chain(&req).unwrap();
        assert_eq!(c.seed, c2.seed);

        // malformed specs fail with clear errors, not wedged submits
        let bad = |s: &str| parse_chain(&Json::parse(s).unwrap()).unwrap_err();
        assert!(bad(r#"{"op": "chain"}"#).contains("dims"));
        assert!(bad(r#"{"op": "chain", "dims": [64]}"#).contains("at least 2"));
        assert!(bad(r#"{"op": "chain", "dims": [64, 0]}"#).contains("1..=2048"));
        assert!(bad(r#"{"op": "chain", "dims": [64, 9999]}"#).contains("1..=2048"));
        assert!(bad(r#"{"op": "chain", "m": 0, "dims": [64, 64]}"#).contains("m must"));
        assert!(
            bad(r#"{"op": "chain", "dims": [64, 64], "b_seeds": [1, 2]}"#)
                .contains("b_seeds")
        );
        assert!(
            bad(r#"{"op": "chain", "dims": [64, 64], "mode": "zero_copy"}"#)
                .contains("zero_copy")
        );
    }

    #[test]
    fn parse_dag_specs_and_limits() {
        let req = Json::parse(
            r#"{"op": "dag", "m": 64, "d0": 256, "seed": 7,
                "mode": "device_only", "publish_key": 99,
                "nodes": [
                  {"op": "gemm", "n": 128, "b_seed": 42, "bias": true,
                   "relu": true},
                  {"op": "gemm", "n": 128, "src": 0},
                  {"op": "axpy", "src": 0, "src2": 1}
                ]}"#,
        )
        .unwrap();
        let (d, p) = parse_dag(&req).unwrap();
        assert_eq!((d.shape.m, d.shape.d0, d.seed), (64, 256, 7));
        assert_eq!(d.shape.nodes.len(), 3);
        assert_eq!(d.shape.nodes[0].op, DagOp::Gemm);
        assert_eq!(d.shape.nodes[0].n, 128);
        assert!(d.shape.nodes[0].bias && d.shape.nodes[0].relu);
        assert_eq!(d.shape.nodes[0].src, None, "absent src = external x");
        assert_eq!(d.shape.nodes[1].src, Some(0));
        assert_eq!(d.shape.nodes[2].op, DagOp::Axpy);
        assert_eq!((d.shape.nodes[2].src, d.shape.nodes[2].src2), (Some(0), Some(1)));
        assert_eq!(d.b_seeds, vec![Some(42), None, None]);
        assert_eq!(d.publish_key, Some(99));
        assert_eq!(d.input_key, None);
        assert_eq!(d.mode, DispatchMode::DeviceOnly);
        assert_eq!(p, Priority::Normal);

        // stable default seed: same request, same workload
        let req = Json::parse(
            r#"{"op": "dag", "nodes": [{"op": "gemv"}]}"#,
        )
        .unwrap();
        let (d, _) = parse_dag(&req).unwrap();
        let (d2, _) = parse_dag(&req).unwrap();
        assert_eq!(d.seed, d2.seed);
        assert_eq!((d.shape.m, d.shape.d0), (64, 64), "m and d0 default to 64");
        assert_eq!(d.shape.nodes[0].n, 0, "non-gemm nodes carry no width");

        // malformed specs fail with the node named, not wedged submits
        let bad = |s: &str| parse_dag(&Json::parse(s).unwrap()).unwrap_err();
        assert!(bad(r#"{"op": "dag"}"#).contains("nodes"));
        assert!(bad(r#"{"op": "dag", "nodes": []}"#).contains("at least 1"));
        assert!(bad(r#"{"op": "dag", "m": 0, "nodes": [{"op": "gemv"}]}"#)
            .contains("m must"));
        assert!(bad(r#"{"op": "dag", "d0": 9999, "nodes": [{"op": "gemv"}]}"#)
            .contains("d0 must"));
        assert!(bad(r#"{"op": "dag", "nodes": [{"n": 64}]}"#)
            .contains("node 0: missing op"));
        assert!(bad(r#"{"op": "dag", "nodes": [{"op": "conv"}]}"#)
            .contains("node 0: unknown op 'conv'"));
        assert!(bad(r#"{"op": "dag", "nodes": [{"op": "gemm"}]}"#)
            .contains("node 0: gemm needs n"));
        assert!(bad(r#"{"op": "dag", "nodes": [{"op": "gemm", "n": 9999}]}"#)
            .contains("1..=2048"));
        assert!(
            bad(r#"{"op": "dag", "nodes": [{"op": "gemv"}], "mode": "zero_copy"}"#)
                .contains("zero_copy")
        );
    }

    #[test]
    fn parse_gemv_defaults_and_limits() {
        let req = Json::parse(r#"{"op": "gemv"}"#).unwrap();
        let (g, p) = parse_gemv(&req).unwrap();
        assert_eq!((g.m, g.n), (128, 128));
        assert_eq!(g.mode, DispatchMode::Auto);
        assert_eq!(p, Priority::Normal);
        // stable default seed, shape-dependent
        let req2 = Json::parse(r#"{"op": "gemv", "m": 256}"#).unwrap();
        let (g2, _) = parse_gemv(&req2).unwrap();
        assert_ne!(g.seed, g2.seed);

        let req = Json::parse(
            r#"{"op": "gemv", "m": 32, "n": 64, "mode": "device_only",
                "priority": "high", "seed": 9}"#,
        )
        .unwrap();
        let (g, p) = parse_gemv(&req).unwrap();
        assert_eq!((g.m, g.n, g.seed), (32, 64, 9));
        assert_eq!(g.mode, DispatchMode::DeviceOnly);
        assert_eq!(p, Priority::High);

        let req = Json::parse(r#"{"op": "gemv", "m": 99999}"#).unwrap();
        assert!(parse_gemv(&req).is_err());
        let req = Json::parse(r#"{"op": "gemv", "n": 0}"#).unwrap();
        assert!(parse_gemv(&req).is_err());
    }

    #[test]
    fn parse_level1_defaults_and_limits() {
        let req = Json::parse(r#"{"op": "axpy"}"#).unwrap();
        let (l1, p) = parse_level1(Level1Op::Axpy, &req).unwrap();
        assert_eq!((l1.op, l1.n), (Level1Op::Axpy, 4096));
        assert_eq!(l1.alpha, 1.0);
        assert_eq!(p, Priority::Normal);
        // stable default seed, op-dependent so axpy/dot don't collide
        let (dot, _) = parse_level1(Level1Op::Dot, &req).unwrap();
        assert_ne!(l1.seed, dot.seed);

        let req = Json::parse(
            r#"{"op": "axpy", "n": 1024, "alpha": 2.5, "seed": 9,
                "mode": "device_only", "priority": "high"}"#,
        )
        .unwrap();
        let (l1, p) = parse_level1(Level1Op::Axpy, &req).unwrap();
        assert_eq!((l1.n, l1.seed), (1024, 9));
        assert_eq!(l1.alpha, 2.5);
        assert_eq!(l1.mode, DispatchMode::DeviceOnly);
        assert_eq!(p, Priority::High);

        let req = Json::parse(r#"{"op": "dot", "n": 0}"#).unwrap();
        assert!(parse_level1(Level1Op::Dot, &req).is_err());
        let req = Json::parse(r#"{"op": "dot", "n": 9999999}"#).unwrap();
        assert!(parse_level1(Level1Op::Dot, &req).is_err());
    }

    fn outcome() -> GemmOutcome {
        GemmOutcome {
            op: "gemm",
            m: 64,
            n: 64,
            mode: DispatchMode::DeviceOnly,
            checksum: 1.25,
            data_copy_ms: 1.0,
            fork_join_ms: 2.0,
            compute_ms: 3.0,
            host_compute_ms: 0.0,
            total_ms: 6.0,
            cluster: 2,
            batch_size: 4,
            queue_ms: 0.5,
            spans: crate::sched::SpanBreakdown {
                queue_us: 100,
                route_us: 20,
                linger_us: 5,
                retry_us: 0,
                stage_us: 30,
                execute_us: 800,
                finish_us: 50,
                total_us: 1000,
            },
            degraded: false,
            attempts: 0,
        }
    }

    #[test]
    fn gemm_response_shape() {
        let j = Json::parse(&gemm_response(&outcome(), false)).unwrap();
        assert_eq!(j.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(j.get("op").and_then(|v| v.as_str()), Some("gemm"));
        assert_eq!(j.get("m").and_then(|v| v.as_u64()), Some(64));
        assert_eq!(j.get("cluster").and_then(|v| v.as_u64()), Some(2));
        assert_eq!(j.get("batch_size").and_then(|v| v.as_u64()), Some(4));
        let sum = ["data_copy_ms", "fork_join_ms", "compute_ms", "host_compute_ms"]
            .iter()
            .map(|k| j.get(k).and_then(|v| v.as_f64()).unwrap())
            .sum::<f64>();
        assert!((sum - j.get("total_ms").and_then(|v| v.as_f64()).unwrap()).abs() < 1e-9);
        // spans are opt-in: absent without trace
        assert_eq!(j.get("spans"), None);
        assert_eq!(j.get("latency_us"), None);
    }

    #[test]
    fn traced_response_stages_sum_to_latency() {
        let j = Json::parse(&gemm_response(&outcome(), true)).unwrap();
        let latency = j.get("latency_us").and_then(|v| v.as_u64()).unwrap();
        let spans = j.get("spans").expect("trace: true adds a spans object");
        // the five NAMED stages (linger is a sub-span of stage) sum
        // exactly to the reported latency — the trace contract
        let sum: u64 = ["queue_us", "route_us", "stage_us", "execute_us", "finish_us"]
            .iter()
            .map(|k| spans.get(k).and_then(|v| v.as_u64()).unwrap())
            .sum();
        assert_eq!(sum, latency);
        assert_eq!(latency, 1000);
        assert_eq!(spans.get("linger_us").and_then(|v| v.as_u64()), Some(5));
        assert_eq!(spans.get("total_us").and_then(|v| v.as_u64()), Some(1000));
    }

    #[test]
    fn degraded_response_carries_attempts() {
        // clean outcome: the fault-recovery keys are absent entirely, so
        // a fault-free deployment's replies are byte-identical to before
        let j = Json::parse(&gemm_response(&outcome(), false)).unwrap();
        assert_eq!(j.get("degraded"), None);
        assert_eq!(j.get("attempts"), None);

        // a host-fallback reply reports both
        let mut o = outcome();
        o.degraded = true;
        o.attempts = 2;
        let j = Json::parse(&gemm_response(&o, false)).unwrap();
        assert_eq!(j.get("degraded"), Some(&Json::Bool(true)));
        assert_eq!(j.get("attempts").and_then(|v| v.as_u64()), Some(2));

        // a retried-but-recovered reply (not degraded) still shows the
        // failed attempts
        let mut o = outcome();
        o.attempts = 1;
        let j = Json::parse(&gemm_response(&o, false)).unwrap();
        assert_eq!(j.get("degraded"), Some(&Json::Bool(false)));
        assert_eq!(j.get("attempts").and_then(|v| v.as_u64()), Some(1));
    }

    #[test]
    fn traced_retry_span_is_opt_in() {
        // zero retry wall: no retry_us key, trace output unchanged
        let j = Json::parse(&gemm_response(&outcome(), true)).unwrap();
        assert_eq!(j.get("spans").unwrap().get("retry_us"), None);

        let mut o = outcome();
        o.spans.retry_us = 123;
        let j = Json::parse(&gemm_response(&o, true)).unwrap();
        let spans = j.get("spans").unwrap();
        assert_eq!(spans.get("retry_us").and_then(|v| v.as_u64()), Some(123));
        // retry stays OUTSIDE the telescoping sum
        let sum: u64 = ["queue_us", "route_us", "stage_us", "execute_us", "finish_us"]
            .iter()
            .map(|k| spans.get(k).and_then(|v| v.as_u64()).unwrap())
            .sum();
        assert_eq!(sum, j.get("latency_us").and_then(|v| v.as_u64()).unwrap());
    }

    #[test]
    fn watch_request_parses_token_and_interval() {
        let dflt = Duration::from_millis(500);
        // not a watch: other ops and garbage pass through to dispatch
        assert!(watch_request(r#"{"op": "top"}"#, dflt).is_none());
        assert!(watch_request("not json", dflt).is_none());

        // bare watch: server-assigned token, configured interval
        let (rid, iv) = watch_request(r#"{"op": "watch"}"#, dflt).unwrap();
        assert!(matches!(rid, Json::Str(s) if s.starts_with("srv-")));
        assert_eq!(iv, dflt);

        // client token + interval override, clamped to 1..=60000 ms
        let (rid, iv) = watch_request(
            r#"{"op": "watch", "req_id": "w1", "interval_ms": 25}"#,
            dflt,
        )
        .unwrap();
        assert_eq!(rid, Json::Str("w1".into()));
        assert_eq!(iv, Duration::from_millis(25));
        let (_, iv) = watch_request(
            r#"{"op": "watch", "interval_ms": 9999999}"#,
            dflt,
        )
        .unwrap();
        assert_eq!(iv, Duration::from_millis(60_000));
        let (_, iv) =
            watch_request(r#"{"op": "watch", "interval_ms": 0}"#, dflt).unwrap();
        assert_eq!(iv, Duration::from_millis(1));
    }

    #[test]
    fn prom_body_survives_json_line_roundtrip() {
        // the metrics_prom reply ships multi-line Prometheus text as an
        // escaped JSON string: it must stay one line on the wire and
        // round-trip exactly
        let body = "# HELP x y\n# TYPE x counter\nx 1\n";
        let mut j = obj(vec![
            ("ok", Json::Bool(true)),
            ("body", Json::Str(body.into())),
        ]);
        let line = compact(&mut j);
        assert!(!line.contains('\n'));
        let back = Json::parse(&line).unwrap();
        assert_eq!(back.get("body").and_then(|v| v.as_str()), Some(body));
    }

    #[test]
    fn req_id_echoes_onto_every_frame_shape() {
        // client token (string) echoed verbatim on success-shaped frames
        let r = with_req_id(gemm_response(&outcome(), false), &Json::Str("abc-7".into()));
        let j = Json::parse(&r).unwrap();
        assert_eq!(j.get("req_id").and_then(|v| v.as_str()), Some("abc-7"));
        assert_eq!(j.get("ok"), Some(&Json::Bool(true)));
        // numeric tokens round-trip too, on error and backpressure frames
        let r = with_req_id(err_line("boom"), &Json::Num(42.0));
        let j = Json::parse(&r).unwrap();
        assert_eq!(j.get("req_id").and_then(|v| v.as_u64()), Some(42));
        assert_eq!(j.get("error").and_then(|v| v.as_str()), Some("boom"));
        let r = with_req_id(backpressure_line(3, 10), &Json::Num(9.0));
        let j = Json::parse(&r).unwrap();
        assert_eq!(j.get("req_id").and_then(|v| v.as_u64()), Some(9));
        assert_eq!(j.get("error").and_then(|v| v.as_str()), Some("queue full"));
    }
}
