//! NumPy-operator -> BLAS bindings (NumPy's `dot`/`matmul` going through
//! its linked CBLAS, exactly the hook the paper exploits), plus the lazy
//! [`Expr`] builder that lowers an operator *sequence* onto the chained
//! offload path (`blas::device::gemm_chain_stage`) so intermediates stay
//! device-resident instead of round-tripping through host DRAM per op.

use crate::blas::{ChainLink, Elem, HeroBlas, Transpose};
use crate::error::{Error, Result};

use super::array::NdArray;

impl<T: Elem> NdArray<T> {
    /// `self @ rhs` (2-D x 2-D), routed through xGEMM.
    pub fn matmul(&self, rhs: &Self, blas: &mut HeroBlas) -> Result<Self> {
        let (m, k) = match self.shape() {
            [m, k] => (*m, *k),
            s => return Err(Error::shape(format!("matmul lhs must be 2-D, got {s:?}"))),
        };
        let (k2, n) = match rhs.shape() {
            [k2, n] => (*k2, *n),
            s => return Err(Error::shape(format!("matmul rhs must be 2-D, got {s:?}"))),
        };
        if k != k2 {
            return Err(Error::shape(format!(
                "matmul: ({m},{k}) @ ({k2},{n}) mismatch"
            )));
        }
        let mut out = NdArray::<T>::zeros(&[m, n]);
        blas.gemm(
            Transpose::No,
            Transpose::No,
            T::one(),
            self.data(),
            (m, k),
            rhs.data(),
            (k, n),
            T::zero(),
            out.data_mut(),
            (m, n),
        )?;
        Ok(out)
    }

    /// `self @ x` for 2-D x 1-D, routed through xGEMV.
    pub fn matvec(&self, x: &Self, blas: &mut HeroBlas) -> Result<Self> {
        let (m, n) = match self.shape() {
            [m, n] => (*m, *n),
            s => return Err(Error::shape(format!("matvec lhs must be 2-D, got {s:?}"))),
        };
        if x.shape() != [n] {
            return Err(Error::shape(format!(
                "matvec: ({m},{n}) @ {:?} mismatch",
                x.shape()
            )));
        }
        let mut y = NdArray::<T>::zeros(&[m]);
        blas.gemv(
            Transpose::No,
            T::one(),
            self.data(),
            (m, n),
            x.data(),
            T::zero(),
            y.data_mut(),
        )?;
        Ok(y)
    }
}

/// One deferred link of a lazy expression: a matmul with an optional
/// bias-add and ReLU fused onto its output.
struct ExprLink<'a, T: Elem> {
    w: &'a NdArray<T>,
    bias: Option<&'a NdArray<T>>,
    relu: bool,
}

/// A lazy operator chain: `x.lazy().matmul(w1).add(b1).relu().matmul(w2)`
/// builds the expression without computing anything; [`Expr::eval`]
/// lowers the whole sequence to ONE chained BLAS submission whose
/// intermediates stay resident in device DRAM (`y = relu(xW1 + b1)W2`
/// pays the offload tax once, not per op).  Shape errors are detected as
/// the expression is built but surface at `eval`, like NumPy raising at
/// the call.
pub struct Expr<'a, T: Elem> {
    input: &'a NdArray<T>,
    links: Vec<ExprLink<'a, T>>,
    err: Option<Error>,
    /// Column count of the expression so far (shape tracking).
    cols: usize,
}

impl<T: Elem> NdArray<T> {
    /// Begin a lazy operator chain on a 2-D array (see [`Expr`]).
    pub fn lazy(&self) -> Expr<'_, T> {
        let (err, cols) = match self.shape() {
            [_, c] => (None, *c),
            s => (
                Some(Error::shape(format!("lazy: input must be 2-D, got {s:?}"))),
                0,
            ),
        };
        Expr { input: self, links: Vec::new(), err, cols }
    }
}

impl<'a, T: Elem> Expr<'a, T> {
    fn fail(mut self, e: Error) -> Self {
        if self.err.is_none() {
            self.err = Some(e);
        }
        self
    }

    /// Append `@ w` (2-D weights) to the chain.
    pub fn matmul(mut self, w: &'a NdArray<T>) -> Self {
        if self.err.is_some() {
            return self;
        }
        let (k, n) = match w.shape() {
            [k, n] => (*k, *n),
            s => {
                return self
                    .fail(Error::shape(format!("matmul rhs must be 2-D, got {s:?}")))
            }
        };
        if k != self.cols {
            return self.fail(Error::shape(format!(
                "matmul: expression yields {} columns, rhs consumes {k}",
                self.cols
            )));
        }
        self.links.push(ExprLink { w, bias: None, relu: false });
        self.cols = n;
        self
    }

    /// Add a per-row bias (1-D, length = current column count) to the
    /// last matmul's output.
    pub fn add(mut self, bias: &'a NdArray<T>) -> Self {
        if self.err.is_some() {
            return self;
        }
        if bias.shape() != [self.cols] {
            return self.fail(Error::shape(format!(
                "add: bias shape {:?} does not match {} columns",
                bias.shape(),
                self.cols
            )));
        }
        let ok = self
            .links
            .last()
            .is_some_and(|l| l.bias.is_none() && !l.relu);
        if !ok {
            return self.fail(Error::shape(
                "add: one bias per matmul, attached right after it (before relu)",
            ));
        }
        self.links.last_mut().expect("checked non-empty").bias = Some(bias);
        self
    }

    /// Apply max(x, 0) element-wise to the last matmul's output.
    pub fn relu(mut self) -> Self {
        if self.err.is_some() {
            return self;
        }
        let ok = self.links.last().is_some_and(|l| !l.relu);
        if !ok {
            return self.fail(Error::shape(
                "relu: activates the latest matmul's output, at most once",
            ));
        }
        self.links.last_mut().expect("checked non-empty").relu = true;
        self
    }

    /// Number of deferred links.
    pub fn len(&self) -> usize {
        self.links.len()
    }

    pub fn is_empty(&self) -> bool {
        self.links.is_empty()
    }

    /// Lower the chain to ONE BLAS submission and run it: the dispatch
    /// policy decides whether the whole sequence offloads as a chain
    /// (device-resident intermediates) or runs link by link.
    pub fn eval(self, blas: &mut HeroBlas) -> Result<NdArray<T>> {
        if let Some(e) = self.err {
            return Err(e);
        }
        let m = self.input.shape()[0];
        if self.links.is_empty() {
            return Ok(self.input.clone());
        }
        let links: Vec<ChainLink<'_, T>> = self
            .links
            .iter()
            .map(|l| {
                let (k, n) = (l.w.shape()[0], l.w.shape()[1]);
                ChainLink {
                    b: l.w.data(),
                    dims: (k, n),
                    bias: l.bias.map(|b| b.data()),
                    relu: l.relu,
                }
            })
            .collect();
        let mut out = NdArray::<T>::zeros(&[m, self.cols]);
        blas.chain(m, self.input.data(), &links, out.data_mut())?;
        Ok(out)
    }
}

/// f64-only NumPy conveniences that ride on level-1 BLAS.
impl NdArray<f64> {
    /// `numpy.dot` for 1-D arrays.
    pub fn vdot(&self, rhs: &Self, blas: &mut HeroBlas) -> Result<f64> {
        if self.ndim() != 1 || rhs.ndim() != 1 {
            return Err(Error::shape("vdot: 1-D arrays only"));
        }
        blas.dot(self.data(), rhs.data())
    }

    /// `numpy.linalg.norm` (2-norm) for 1-D arrays.
    pub fn norm(&self, blas: &mut HeroBlas) -> Result<f64> {
        blas.nrm2(self.data())
    }

    /// In-place `self += alpha * rhs` via dAXPY.
    pub fn axpy_from(&mut self, alpha: f64, rhs: &Self, blas: &mut HeroBlas) -> Result<()> {
        if self.shape() != rhs.shape() {
            return Err(Error::shape("axpy_from: shape mismatch"));
        }
        blas.axpy(alpha, rhs.data(), self.data_mut())
    }
}

// Integration tests that exercise these against real artifacts live in
// rust/tests/ (they need `make artifacts`).
