//! NumPy-operator -> BLAS bindings (NumPy's `dot`/`matmul` going through
//! its linked CBLAS, exactly the hook the paper exploits), plus the lazy
//! [`Expr`] builder that lowers an operator *sequence* onto the chained
//! offload path (`blas::device::gemm_chain_stage`) so intermediates stay
//! device-resident instead of round-tripping through host DRAM per op.

use crate::blas::{ChainLink, DagNode, Elem, HeroBlas, Transpose};
use crate::dag::{DagNodeShape, DagOp, DagShape};
use crate::error::{Error, Result};

use super::array::NdArray;

impl<T: Elem> NdArray<T> {
    /// `self @ rhs` (2-D x 2-D), routed through xGEMM.
    pub fn matmul(&self, rhs: &Self, blas: &mut HeroBlas) -> Result<Self> {
        let (m, k) = match self.shape() {
            [m, k] => (*m, *k),
            s => return Err(Error::shape(format!("matmul lhs must be 2-D, got {s:?}"))),
        };
        let (k2, n) = match rhs.shape() {
            [k2, n] => (*k2, *n),
            s => return Err(Error::shape(format!("matmul rhs must be 2-D, got {s:?}"))),
        };
        if k != k2 {
            return Err(Error::shape(format!(
                "matmul: ({m},{k}) @ ({k2},{n}) mismatch"
            )));
        }
        let mut out = NdArray::<T>::zeros(&[m, n]);
        blas.gemm(
            Transpose::No,
            Transpose::No,
            T::one(),
            self.data(),
            (m, k),
            rhs.data(),
            (k, n),
            T::zero(),
            out.data_mut(),
            (m, n),
        )?;
        Ok(out)
    }

    /// `self @ x` for 2-D x 1-D, routed through xGEMV.
    pub fn matvec(&self, x: &Self, blas: &mut HeroBlas) -> Result<Self> {
        let (m, n) = match self.shape() {
            [m, n] => (*m, *n),
            s => return Err(Error::shape(format!("matvec lhs must be 2-D, got {s:?}"))),
        };
        if x.shape() != [n] {
            return Err(Error::shape(format!(
                "matvec: ({m},{n}) @ {:?} mismatch",
                x.shape()
            )));
        }
        let mut y = NdArray::<T>::zeros(&[m]);
        blas.gemv(
            Transpose::No,
            T::one(),
            self.data(),
            (m, n),
            x.data(),
            T::zero(),
            y.data_mut(),
        )?;
        Ok(y)
    }
}

/// One deferred node of a lazy expression: a matmul (with optional
/// fused bias/ReLU epilogues) or an element-wise fan-in add of two
/// earlier nodes.
#[derive(Clone, Copy)]
struct ExprNode<'a, T: Elem> {
    /// `Some` = matmul against these weights; `None` = fan-in add.
    w: Option<&'a NdArray<T>>,
    bias: Option<&'a NdArray<T>>,
    relu: bool,
    /// First input: an earlier node, or `None` for the external input.
    src: Option<usize>,
    /// Second input (fan-in nodes only).
    src2: Option<usize>,
    /// Output column count.
    cols: usize,
}

/// Structural identity: same operands (by reference), same wiring.
/// Used to recognize the shared trunk when two branches merge.
fn same_node<T: Elem>(a: &ExprNode<'_, T>, b: &ExprNode<'_, T>) -> bool {
    let ptr_eq = |x: Option<&NdArray<T>>, y: Option<&NdArray<T>>| match (x, y) {
        (None, None) => true,
        (Some(x), Some(y)) => std::ptr::eq(x, y),
        _ => false,
    };
    ptr_eq(a.w, b.w)
        && ptr_eq(a.bias, b.bias)
        && a.relu == b.relu
        && a.src == b.src
        && a.src2 == b.src2
        && a.cols == b.cols
}

/// A lazy operator graph: `x.lazy().matmul(w1).add(b1).relu().matmul(w2)`
/// builds the expression without computing anything; [`Expr::eval`]
/// lowers the whole sequence to ONE chained BLAS submission whose
/// intermediates stay resident in device DRAM (`y = relu(xW1 + b1)W2`
/// pays the offload tax once, not per op).  [`Expr::branch`] forks the
/// expression into two suffixes sharing everything built so far, and
/// [`Expr::fanin`] joins two branches with an element-wise add — a
/// fan-out/fan-in graph that lowers to ONE dag submission whose shared
/// trunk is computed exactly once (`y = relu(xW0)W1 + relu(xW0)W2`
/// stages the trunk once, not per branch).  Shape errors are detected
/// as the expression is built but surface at `eval`, like NumPy raising
/// at the call.
pub struct Expr<'a, T: Elem> {
    input: &'a NdArray<T>,
    nodes: Vec<ExprNode<'a, T>>,
    /// The expression's current tip (`None` = the bare input).
    head: Option<usize>,
    err: Option<Error>,
    /// Column count of the expression so far (shape tracking).
    cols: usize,
}

impl<T: Elem> NdArray<T> {
    /// Begin a lazy operator chain on a 2-D array (see [`Expr`]).
    pub fn lazy(&self) -> Expr<'_, T> {
        let (err, cols) = match self.shape() {
            [_, c] => (None, *c),
            s => (
                Some(Error::shape(format!("lazy: input must be 2-D, got {s:?}"))),
                0,
            ),
        };
        Expr { input: self, nodes: Vec::new(), head: None, err, cols }
    }
}

impl<'a, T: Elem> Expr<'a, T> {
    fn fail(mut self, e: Error) -> Self {
        if self.err.is_none() {
            self.err = Some(e);
        }
        self
    }

    /// Append `@ w` (2-D weights) to this branch of the expression.
    pub fn matmul(mut self, w: &'a NdArray<T>) -> Self {
        if self.err.is_some() {
            return self;
        }
        let (k, n) = match w.shape() {
            [k, n] => (*k, *n),
            s => {
                return self
                    .fail(Error::shape(format!("matmul rhs must be 2-D, got {s:?}")))
            }
        };
        if k != self.cols {
            return self.fail(Error::shape(format!(
                "matmul: expression yields {} columns, rhs consumes {k}",
                self.cols
            )));
        }
        self.nodes.push(ExprNode {
            w: Some(w),
            bias: None,
            relu: false,
            src: self.head,
            src2: None,
            cols: n,
        });
        self.head = Some(self.nodes.len() - 1);
        self.cols = n;
        self
    }

    /// Add a per-row bias (1-D, length = current column count) to the
    /// last matmul's output.
    pub fn add(mut self, bias: &'a NdArray<T>) -> Self {
        if self.err.is_some() {
            return self;
        }
        if bias.shape() != [self.cols] {
            return self.fail(Error::shape(format!(
                "add: bias shape {:?} does not match {} columns",
                bias.shape(),
                self.cols
            )));
        }
        let ok = self
            .head
            .map(|h| self.nodes[h])
            .is_some_and(|l| l.w.is_some() && l.bias.is_none() && !l.relu);
        if !ok {
            return self.fail(Error::shape(
                "add: one bias per matmul, attached right after it (before relu)",
            ));
        }
        let h = self.head.expect("checked non-empty");
        self.nodes[h].bias = Some(bias);
        self
    }

    /// Apply max(x, 0) element-wise to the last matmul's output.
    pub fn relu(mut self) -> Self {
        if self.err.is_some() {
            return self;
        }
        let ok = self
            .head
            .map(|h| self.nodes[h])
            .is_some_and(|l| l.w.is_some() && !l.relu);
        if !ok {
            return self.fail(Error::shape(
                "relu: activates the latest matmul's output, at most once",
            ));
        }
        let h = self.head.expect("checked non-empty");
        self.nodes[h].relu = true;
        self
    }

    /// Fork the expression into two branches that share everything
    /// built so far.  The shared trunk is computed ONCE on the device
    /// — its output is promoted and pinned until both branches have
    /// consumed it — when the branches are later joined by [`fanin`]
    /// and evaluated.
    ///
    /// [`fanin`]: Expr::fanin
    pub fn branch(self) -> (Self, Self) {
        // Error is not Clone, but every builder error here is a shape
        // error — duplicate it through its message so BOTH branches
        // surface the failure at eval, whichever one is used.
        let err = self.err.as_ref().map(|e| Error::shape(e.to_string()));
        let twin = Expr {
            input: self.input,
            nodes: self.nodes.clone(),
            head: self.head,
            err,
            cols: self.cols,
        };
        (twin, self)
    }

    /// Join two branches with an element-wise add (fan-in).  Both must
    /// fork off the same lazy input — normally one [`branch`] call —
    /// and yield the same column count.
    ///
    /// [`branch`]: Expr::branch
    pub fn fanin(mut self, other: Self) -> Self {
        if self.err.is_some() {
            return self;
        }
        if let Some(e) = other.err {
            return self.fail(e);
        }
        if !std::ptr::eq(self.input, other.input) {
            return self
                .fail(Error::shape("fanin: branches must share one lazy input"));
        }
        if self.cols != other.cols {
            return self.fail(Error::shape(format!(
                "fanin: branches yield {} and {} columns",
                self.cols, other.cols
            )));
        }
        // Merge the graphs: the common prefix (the shared trunk —
        // identical by construction after branch()) is kept once;
        // other's tail is appended with its node indices remapped.
        let common = self
            .nodes
            .iter()
            .zip(other.nodes.iter())
            .take_while(|(a, b)| same_node(a, b))
            .count();
        let base = self.nodes.len();
        let remap =
            |s: Option<usize>| s.map(|j| if j < common { j } else { j - common + base });
        for node in &other.nodes[common..] {
            let mut node = *node;
            node.src = remap(node.src);
            node.src2 = remap(node.src2);
            self.nodes.push(node);
        }
        let src2 = remap(other.head);
        let cols = self.cols;
        self.nodes.push(ExprNode {
            w: None,
            bias: None,
            relu: false,
            src: self.head,
            src2,
            cols,
        });
        self.head = Some(self.nodes.len() - 1);
        self
    }

    /// Number of deferred nodes.
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Lower the expression to ONE BLAS submission and run it.  A
    /// linear expression (no branch/fanin) takes the classic chained
    /// lowering, identical to before; a graph lowers through the dag
    /// executor, whose fan-out trunk is staged and computed exactly
    /// once.  Either way the dispatch policy decides whether the whole
    /// thing offloads (device-resident intermediates) or runs on host.
    pub fn eval(self, blas: &mut HeroBlas) -> Result<NdArray<T>> {
        if let Some(e) = self.err {
            return Err(e);
        }
        let m = self.input.shape()[0];
        if self.nodes.is_empty() {
            return Ok(self.input.clone());
        }
        let linear = self.head == Some(self.nodes.len() - 1)
            && self.nodes.iter().enumerate().all(|(i, l)| {
                l.w.is_some()
                    && l.src2.is_none()
                    && l.src == if i == 0 { None } else { Some(i - 1) }
            });
        if linear {
            let links: Vec<ChainLink<'_, T>> = self
                .nodes
                .iter()
                .map(|l| {
                    let w = l.w.expect("linear nodes are matmuls");
                    ChainLink {
                        b: w.data(),
                        dims: (w.shape()[0], w.shape()[1]),
                        bias: l.bias.map(|b| b.data()),
                        relu: l.relu,
                    }
                })
                .collect();
            let mut out = NdArray::<T>::zeros(&[m, self.cols]);
            blas.chain(m, self.input.data(), &links, out.data_mut())?;
            return Ok(out);
        }
        let shape = DagShape {
            m,
            d0: self.input.shape()[1],
            nodes: self
                .nodes
                .iter()
                .map(|l| DagNodeShape {
                    op: if l.w.is_some() { DagOp::Gemm } else { DagOp::Axpy },
                    src: l.src,
                    src2: l.src2,
                    n: if l.w.is_some() { l.cols } else { 0 },
                    bias: l.bias.is_some(),
                    relu: l.relu,
                })
                .collect(),
        };
        let specs: Vec<DagNode<'_, T>> = self
            .nodes
            .iter()
            .map(|l| DagNode {
                b: l.w.map(|w| w.data()),
                bias: l.bias.map(|b| b.data()),
            })
            .collect();
        // by construction every non-head node has a consumer, so the
        // head is a sink; tolerate extra sinks by evaluating them all
        // and returning the head's buffer
        let sinks = shape.sinks();
        let head = self.head.expect("non-empty expression has a head");
        let mut bufs: Vec<Vec<T>> = sinks
            .iter()
            .map(|&s| {
                let (r, c) = shape.out_dims(s);
                vec![T::zero(); r * c]
            })
            .collect();
        {
            let mut refs: Vec<&mut [T]> =
                bufs.iter_mut().map(|b| b.as_mut_slice()).collect();
            blas.dag(&shape, self.input.data(), &specs, &mut refs)?;
        }
        let pos = sinks
            .iter()
            .position(|&s| s == head)
            .ok_or_else(|| Error::shape("fanin: expression head must be a sink"))?;
        let mut out = NdArray::<T>::zeros(&[m, self.cols]);
        out.data_mut().copy_from_slice(&bufs[pos]);
        Ok(out)
    }
}

/// f64-only NumPy conveniences that ride on level-1 BLAS.
impl NdArray<f64> {
    /// `numpy.dot` for 1-D arrays.
    pub fn vdot(&self, rhs: &Self, blas: &mut HeroBlas) -> Result<f64> {
        if self.ndim() != 1 || rhs.ndim() != 1 {
            return Err(Error::shape("vdot: 1-D arrays only"));
        }
        blas.dot(self.data(), rhs.data())
    }

    /// `numpy.linalg.norm` (2-norm) for 1-D arrays.
    pub fn norm(&self, blas: &mut HeroBlas) -> Result<f64> {
        blas.nrm2(self.data())
    }

    /// In-place `self += alpha * rhs` via dAXPY.
    pub fn axpy_from(&mut self, alpha: f64, rhs: &Self, blas: &mut HeroBlas) -> Result<()> {
        if self.shape() != rhs.shape() {
            return Err(Error::shape("axpy_from: shape mismatch"));
        }
        blas.axpy(alpha, rhs.data(), self.data_mut())
    }
}

// Integration tests that exercise these against real artifacts live in
// rust/tests/ (they need `make artifacts`).

#[cfg(test)]
mod tests {
    use super::*;

    fn arr(shape: &[usize]) -> NdArray<f64> {
        NdArray::<f64>::zeros(shape)
    }

    #[test]
    fn branch_fanin_shares_the_trunk_once() {
        let x = arr(&[4, 8]);
        let w0 = arr(&[8, 16]);
        let w1 = arr(&[16, 32]);
        let w2 = arr(&[16, 32]);
        let (a, b) = x.lazy().matmul(&w0).relu().branch();
        let e = a.matmul(&w1).fanin(b.matmul(&w2));
        // trunk node + 2 branch matmuls + 1 fan-in add — NOT 2 trunks
        assert_eq!(e.len(), 4);
        assert!(e.err.is_none());
        assert_eq!(e.cols, 32);
        // the fan-in head consumes both branch heads; both branches
        // consume the one shared trunk node (fan-out)
        assert_eq!(e.nodes[1].src, Some(0));
        assert_eq!(e.nodes[2].src, Some(0));
        assert_eq!((e.nodes[3].src, e.nodes[3].src2), (Some(1), Some(2)));
        assert!(e.nodes[3].w.is_none(), "fan-in is an add, not a matmul");
    }

    #[test]
    fn fanin_on_bare_branches_consumes_the_input_twice() {
        let x = arr(&[4, 8]);
        let w1 = arr(&[8, 8]);
        let w2 = arr(&[8, 8]);
        let (a, b) = x.lazy().branch();
        let e = a.matmul(&w1).fanin(b.matmul(&w2));
        assert_eq!(e.len(), 3);
        assert_eq!(e.nodes[0].src, None, "branch off the external input");
        assert_eq!(e.nodes[1].src, None);
        assert_eq!((e.nodes[2].src, e.nodes[2].src2), (Some(0), Some(1)));
    }

    #[test]
    fn fanin_rejects_mismatched_branches() {
        let x = arr(&[4, 8]);
        let y = arr(&[4, 8]);
        let w1 = arr(&[8, 16]);
        let w2 = arr(&[8, 32]);
        // different column counts
        let (a, b) = x.lazy().branch();
        let e = a.matmul(&w1).fanin(b.matmul(&w2));
        assert!(e.err.as_ref().is_some_and(|m| m.to_string().contains("columns")));
        // different lazy inputs
        let e = x.lazy().matmul(&w1).fanin(y.lazy().matmul(&w1));
        assert!(e.err.as_ref().is_some_and(|m| m.to_string().contains("share")));
    }

    #[test]
    fn branch_duplicates_a_pending_error_to_both_sides() {
        let x = arr(&[4, 8]);
        let bad = arr(&[3, 16]); // 8 != 3: shape error recorded
        let (a, b) = x.lazy().matmul(&bad).branch();
        assert!(a.err.is_some(), "twin branch carries the error");
        assert!(b.err.is_some(), "original branch carries the error");
    }

    #[test]
    fn linear_expressions_stay_linear() {
        let x = arr(&[4, 8]);
        let w0 = arr(&[8, 16]);
        let w1 = arr(&[16, 4]);
        let e = x.lazy().matmul(&w0).relu().matmul(&w1);
        assert_eq!(e.len(), 2);
        assert_eq!(e.nodes[0].src, None);
        assert_eq!(e.nodes[1].src, Some(0));
        assert!(e.nodes.iter().all(|n| n.src2.is_none()));
    }
}
