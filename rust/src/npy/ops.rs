//! NumPy-operator -> BLAS bindings (NumPy's `dot`/`matmul` going through
//! its linked CBLAS, exactly the hook the paper exploits).

use crate::blas::{Elem, HeroBlas, Transpose};
use crate::error::{Error, Result};

use super::array::NdArray;

impl<T: Elem> NdArray<T> {
    /// `self @ rhs` (2-D x 2-D), routed through xGEMM.
    pub fn matmul(&self, rhs: &Self, blas: &mut HeroBlas) -> Result<Self> {
        let (m, k) = match self.shape() {
            [m, k] => (*m, *k),
            s => return Err(Error::shape(format!("matmul lhs must be 2-D, got {s:?}"))),
        };
        let (k2, n) = match rhs.shape() {
            [k2, n] => (*k2, *n),
            s => return Err(Error::shape(format!("matmul rhs must be 2-D, got {s:?}"))),
        };
        if k != k2 {
            return Err(Error::shape(format!(
                "matmul: ({m},{k}) @ ({k2},{n}) mismatch"
            )));
        }
        let mut out = NdArray::<T>::zeros(&[m, n]);
        blas.gemm(
            Transpose::No,
            Transpose::No,
            T::one(),
            self.data(),
            (m, k),
            rhs.data(),
            (k, n),
            T::zero(),
            out.data_mut(),
            (m, n),
        )?;
        Ok(out)
    }

    /// `self @ x` for 2-D x 1-D, routed through xGEMV.
    pub fn matvec(&self, x: &Self, blas: &mut HeroBlas) -> Result<Self> {
        let (m, n) = match self.shape() {
            [m, n] => (*m, *n),
            s => return Err(Error::shape(format!("matvec lhs must be 2-D, got {s:?}"))),
        };
        if x.shape() != [n] {
            return Err(Error::shape(format!(
                "matvec: ({m},{n}) @ {:?} mismatch",
                x.shape()
            )));
        }
        let mut y = NdArray::<T>::zeros(&[m]);
        blas.gemv(
            Transpose::No,
            T::one(),
            self.data(),
            (m, n),
            x.data(),
            T::zero(),
            y.data_mut(),
        )?;
        Ok(y)
    }
}

/// f64-only NumPy conveniences that ride on level-1 BLAS.
impl NdArray<f64> {
    /// `numpy.dot` for 1-D arrays.
    pub fn vdot(&self, rhs: &Self, blas: &mut HeroBlas) -> Result<f64> {
        if self.ndim() != 1 || rhs.ndim() != 1 {
            return Err(Error::shape("vdot: 1-D arrays only"));
        }
        blas.dot(self.data(), rhs.data())
    }

    /// `numpy.linalg.norm` (2-norm) for 1-D arrays.
    pub fn norm(&self, blas: &mut HeroBlas) -> Result<f64> {
        blas.nrm2(self.data())
    }

    /// In-place `self += alpha * rhs` via dAXPY.
    pub fn axpy_from(&mut self, alpha: f64, rhs: &Self, blas: &mut HeroBlas) -> Result<()> {
        if self.shape() != rhs.shape() {
            return Err(Error::shape("axpy_from: shape mismatch"));
        }
        blas.axpy(alpha, rhs.data(), self.data_mut())
    }
}

// Integration tests that exercise these against real artifacts live in
// rust/tests/ (they need `make artifacts`).
