//! The ndarray container: row-major, 1-D or 2-D (what BLAS consumes).

use crate::blas::Elem;
use crate::error::{Error, Result};
use crate::util::rng::Rng;

/// A dense row-major array (rank 1 or 2).
#[derive(Debug, Clone, PartialEq)]
pub struct NdArray<T: Elem> {
    data: Vec<T>,
    shape: Vec<usize>,
}

impl<T: Elem> NdArray<T> {
    // ------------------------------------------------------------------
    // constructors
    // ------------------------------------------------------------------

    pub fn from_vec(data: Vec<T>, shape: &[usize]) -> Result<Self> {
        let numel: usize = shape.iter().product();
        if shape.is_empty() || shape.len() > 2 {
            return Err(Error::shape(format!(
                "rank {} unsupported (1-D and 2-D only)",
                shape.len()
            )));
        }
        if numel != data.len() {
            return Err(Error::shape(format!(
                "shape {shape:?} wants {numel} elements, got {}",
                data.len()
            )));
        }
        Ok(NdArray { data, shape: shape.to_vec() })
    }

    pub fn zeros(shape: &[usize]) -> Self {
        let numel = shape.iter().product();
        NdArray { data: vec![T::zero(); numel], shape: shape.to_vec() }
    }

    pub fn ones(shape: &[usize]) -> Self {
        Self::full(shape, T::one())
    }

    pub fn full(shape: &[usize], v: T) -> Self {
        let numel = shape.iter().product();
        NdArray { data: vec![v; numel], shape: shape.to_vec() }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        let mut a = Self::zeros(&[n, n]);
        for i in 0..n {
            a.data[i * n + i] = T::one();
        }
        a
    }

    /// `n` evenly spaced points over [lo, hi] (inclusive, like NumPy).
    pub fn linspace(lo: f64, hi: f64, n: usize) -> Self {
        let step = if n > 1 { (hi - lo) / (n - 1) as f64 } else { 0.0 };
        let data = (0..n).map(|i| T::from_f64_lossy(lo + step * i as f64)).collect();
        NdArray { data, shape: vec![n] }
    }

    /// Standard-normal array from the deterministic RNG.
    pub fn randn(rng: &mut Rng, shape: &[usize]) -> Self {
        let numel = shape.iter().product();
        let data = (0..numel).map(|_| T::from_f64_lossy(rng.next_normal())).collect();
        NdArray { data, shape: shape.to_vec() }
    }

    // ------------------------------------------------------------------
    // shape & access
    // ------------------------------------------------------------------

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn data(&self) -> &[T] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [T] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<T> {
        self.data
    }

    /// 2-D stored dims (rank-1 treated as a row vector).
    pub fn dims2(&self) -> (usize, usize) {
        match self.shape.as_slice() {
            [n] => (1, *n),
            [r, c] => (*r, *c),
            _ => unreachable!("rank checked at construction"),
        }
    }

    pub fn get2(&self, r: usize, c: usize) -> T {
        let (_, cols) = self.dims2();
        self.data[r * cols + c]
    }

    pub fn set2(&mut self, r: usize, c: usize, v: T) {
        let (_, cols) = self.dims2();
        self.data[r * cols + c] = v;
    }

    pub fn reshape(mut self, shape: &[usize]) -> Result<Self> {
        let numel: usize = shape.iter().product();
        if numel != self.data.len() || shape.is_empty() || shape.len() > 2 {
            return Err(Error::shape(format!(
                "cannot reshape {:?} ({} elements) to {shape:?}",
                self.shape,
                self.data.len()
            )));
        }
        self.shape = shape.to_vec();
        Ok(self)
    }

    /// Materialized transpose (2-D).
    pub fn t(&self) -> Result<Self> {
        match self.shape.as_slice() {
            [r, c] => {
                let (r, c) = (*r, *c);
                let mut out = Self::zeros(&[c, r]);
                for i in 0..r {
                    for j in 0..c {
                        out.data[j * r + i] = self.data[i * c + j];
                    }
                }
                Ok(out)
            }
            _ => Err(Error::shape("t(): rank-2 only")),
        }
    }

    /// Row view of a 2-D array.
    pub fn row(&self, r: usize) -> &[T] {
        let (rows, cols) = self.dims2();
        assert!(r < rows, "row {r} out of {rows}");
        &self.data[r * cols..(r + 1) * cols]
    }

    /// Copy of rows `r0..r1` (NumPy `a[r0:r1]`; materialized — the BLAS
    /// layer consumes dense buffers).
    pub fn slice_rows(&self, r0: usize, r1: usize) -> Result<Self> {
        let (rows, cols) = self.dims2();
        if r0 > r1 || r1 > rows {
            return Err(Error::shape(format!(
                "slice_rows {r0}..{r1} out of {rows}"
            )));
        }
        let data = self.data[r0 * cols..r1 * cols].to_vec();
        if self.ndim() == 1 {
            NdArray::from_vec(data, &[r1 - r0])
        } else {
            NdArray::from_vec(data, &[r1 - r0, cols])
        }
    }

    /// Copy of the rectangular block `[r0..r1, c0..c1]` (NumPy
    /// `a[r0:r1, c0:c1]`).
    pub fn sub_matrix(&self, r0: usize, r1: usize, c0: usize, c1: usize) -> Result<Self> {
        if self.ndim() != 2 {
            return Err(Error::shape("sub_matrix: rank-2 only"));
        }
        let (rows, cols) = self.dims2();
        if r0 > r1 || r1 > rows || c0 > c1 || c1 > cols {
            return Err(Error::shape(format!(
                "sub_matrix [{r0}..{r1}, {c0}..{c1}] out of [{rows}, {cols}]"
            )));
        }
        let mut data = Vec::with_capacity((r1 - r0) * (c1 - c0));
        for r in r0..r1 {
            data.extend_from_slice(&self.data[r * cols + c0..r * cols + c1]);
        }
        NdArray::from_vec(data, &[r1 - r0, c1 - c0])
    }

    /// Column `j` as a 1-D array.
    pub fn col(&self, j: usize) -> Result<Self> {
        let (rows, cols) = self.dims2();
        if self.ndim() != 2 || j >= cols {
            return Err(Error::shape(format!("col {j} out of {cols}")));
        }
        let data = (0..rows).map(|r| self.data[r * cols + j]).collect();
        NdArray::from_vec(data, &[rows])
    }

    /// Stack 1-D arrays (or equal-width 2-D arrays) vertically
    /// (NumPy `vstack`).
    pub fn vstack(parts: &[&Self]) -> Result<Self> {
        let first = parts
            .first()
            .ok_or_else(|| Error::shape("vstack: empty input"))?;
        let width = first.dims2().1;
        let mut data = Vec::new();
        let mut rows = 0;
        for p in parts {
            if p.dims2().1 != width {
                return Err(Error::shape(format!(
                    "vstack: width mismatch {} vs {width}",
                    p.dims2().1
                )));
            }
            rows += p.dims2().0;
            data.extend_from_slice(&p.data);
        }
        NdArray::from_vec(data, &[rows, width])
    }

    // ------------------------------------------------------------------
    // elementwise (host-side, like NumPy ufuncs without BLAS)
    // ------------------------------------------------------------------

    fn zip(&self, rhs: &Self, f: impl Fn(T, T) -> T, what: &str) -> Result<Self> {
        if self.shape != rhs.shape {
            return Err(Error::shape(format!(
                "{what}: shape mismatch {:?} vs {:?}",
                self.shape, rhs.shape
            )));
        }
        let data = self
            .data
            .iter()
            .zip(rhs.data.iter())
            .map(|(a, b)| f(*a, *b))
            .collect();
        Ok(NdArray { data, shape: self.shape.clone() })
    }

    pub fn add(&self, rhs: &Self) -> Result<Self> {
        self.zip(rhs, |a, b| a + b, "add")
    }

    pub fn sub(&self, rhs: &Self) -> Result<Self> {
        self.zip(rhs, |a, b| a - b, "sub")
    }

    pub fn mul(&self, rhs: &Self) -> Result<Self> {
        self.zip(rhs, |a, b| a * b, "mul")
    }

    pub fn scale(&self, s: T) -> Self {
        NdArray {
            data: self.data.iter().map(|v| *v * s).collect(),
            shape: self.shape.clone(),
        }
    }

    pub fn map(&self, f: impl Fn(T) -> T) -> Self {
        NdArray {
            data: self.data.iter().map(|v| f(*v)).collect(),
            shape: self.shape.clone(),
        }
    }

    pub fn sum(&self) -> T {
        self.data.iter().fold(T::zero(), |a, v| a + *v)
    }

    /// Max |a - b| against another array (test/diagnostic helper).
    pub fn max_abs_diff(&self, rhs: &Self) -> f64 {
        self.data
            .iter()
            .zip(rhs.data.iter())
            .map(|(a, b)| (a.to_f64_lossy() - b.to_f64_lossy()).abs())
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors() {
        let z = NdArray::<f64>::zeros(&[2, 3]);
        assert_eq!(z.shape(), &[2, 3]);
        assert_eq!(z.numel(), 6);
        let e = NdArray::<f64>::eye(3);
        assert_eq!(e.get2(1, 1), 1.0);
        assert_eq!(e.get2(0, 1), 0.0);
        let l = NdArray::<f64>::linspace(0.0, 1.0, 5);
        assert_eq!(l.data(), &[0.0, 0.25, 0.5, 0.75, 1.0]);
    }

    #[test]
    fn from_vec_validates() {
        assert!(NdArray::from_vec(vec![1.0f64; 6], &[2, 3]).is_ok());
        assert!(NdArray::from_vec(vec![1.0f64; 5], &[2, 3]).is_err());
        assert!(NdArray::from_vec(vec![1.0f64; 8], &[2, 2, 2]).is_err());
    }

    #[test]
    fn transpose_and_reshape() {
        let a = NdArray::from_vec(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]).unwrap();
        let at = a.t().unwrap();
        assert_eq!(at.shape(), &[3, 2]);
        assert_eq!(at.get2(0, 1), 4.0);
        let r = a.clone().reshape(&[3, 2]).unwrap();
        assert_eq!(r.get2(2, 1), 6.0);
        assert!(a.clone().reshape(&[7]).is_err());
    }

    #[test]
    fn elementwise() {
        let a = NdArray::from_vec(vec![1.0, 2.0], &[2]).unwrap();
        let b = NdArray::from_vec(vec![10.0, 20.0], &[2]).unwrap();
        assert_eq!(a.add(&b).unwrap().data(), &[11.0, 22.0]);
        assert_eq!(b.sub(&a).unwrap().data(), &[9.0, 18.0]);
        assert_eq!(a.mul(&b).unwrap().data(), &[10.0, 40.0]);
        assert_eq!(a.scale(3.0).data(), &[3.0, 6.0]);
        assert_eq!(a.sum(), 3.0);
        let c = NdArray::from_vec(vec![1.0], &[1]).unwrap();
        assert!(a.add(&c).is_err());
    }

    #[test]
    fn slicing_and_stacking() {
        let a = NdArray::from_vec((1..=12).map(|i| i as f64).collect(), &[3, 4]).unwrap();
        let mid = a.slice_rows(1, 2).unwrap();
        assert_eq!(mid.shape(), &[1, 4]);
        assert_eq!(mid.data(), &[5.0, 6.0, 7.0, 8.0]);
        let block = a.sub_matrix(0, 2, 1, 3).unwrap();
        assert_eq!(block.shape(), &[2, 2]);
        assert_eq!(block.data(), &[2.0, 3.0, 6.0, 7.0]);
        let c = a.col(3).unwrap();
        assert_eq!(c.data(), &[4.0, 8.0, 12.0]);
        let back = NdArray::vstack(&[&a.slice_rows(0, 1).unwrap(),
                                     &a.slice_rows(1, 3).unwrap()]).unwrap();
        assert_eq!(back, a);
        // errors
        assert!(a.slice_rows(2, 1).is_err());
        assert!(a.sub_matrix(0, 4, 0, 1).is_err());
        assert!(a.col(9).is_err());
        let b = NdArray::<f64>::zeros(&[2, 3]);
        assert!(NdArray::vstack(&[&a, &b]).is_err());
        assert!(NdArray::<f64>::vstack(&[]).is_err());
    }

    #[test]
    fn randn_deterministic() {
        let mut r1 = Rng::new(5);
        let mut r2 = Rng::new(5);
        let a = NdArray::<f64>::randn(&mut r1, &[4, 4]);
        let b = NdArray::<f64>::randn(&mut r2, &[4, 4]);
        assert_eq!(a, b);
    }

    #[test]
    fn f32_arrays() {
        let a = NdArray::<f32>::ones(&[3]);
        assert_eq!(a.sum(), 3.0f32);
    }
}
