//! NumPy-style ndarray frontend — arrows (4)+(5) of the paper's Figure 2.
//!
//! The paper's point is that a plain Python application using NumPy gets
//! accelerated *transparently* because NumPy is linked against the
//! modified OpenBLAS.  [`NdArray`] plays NumPy's role here: high-level
//! array code (`a.matmul(&b, &mut session)`) that never mentions the
//! device, with every linear-algebra call routed through [`crate::blas`]
//! where the dispatch decides host vs PMCA.
//!
//! Operator *sequences* build a lazy [`Expr`]
//! (`x.lazy().matmul(&w1).add(&b1).relu().matmul(&w2).eval(&mut s)`)
//! that lowers to ONE chained submission with device-resident
//! intermediates — the `y = relu(xW1)W2` pattern pays the offload tax
//! once instead of per op.

pub mod array;
pub mod ops;

pub use array::NdArray;
pub use ops::Expr;
