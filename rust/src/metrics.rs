//! Run-time counters for the coordinator (reported by `hero-blas serve`
//! and the harness alongside virtual-time results).



/// Aggregate counters across one engine lifetime.
#[derive(Debug, Default, Clone, Copy)]
pub struct Metrics {
    /// Completed offloads (device launches that joined).
    pub offloads: u64,
    /// BLAS calls served on the host path.
    pub host_calls: u64,
    /// Bytes copied host -> device DRAM.
    pub bytes_to_device: u64,
    /// Bytes copied device DRAM -> host.
    pub bytes_from_device: u64,
    /// IO-PTEs created (zero-copy path).
    pub iommu_pages_mapped: u64,
    /// Device tile-kernel invocations (artifact executions).
    pub tile_kernel_calls: u64,
    /// Wall-clock microseconds spent inside PJRT execution (host side,
    /// not virtual time — used by the perf pass).
    pub pjrt_wall_us: u64,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics::default()
    }

    /// Render a compact single-line summary.
    pub fn summary(&self) -> String {
        format!(
            "offloads={} host_calls={} to_dev={}B from_dev={}B \
             iommu_pages={} tile_calls={} pjrt_wall={}us",
            self.offloads,
            self.host_calls,
            self.bytes_to_device,
            self.bytes_from_device,
            self.iommu_pages_mapped,
            self.tile_kernel_calls,
            self.pjrt_wall_us,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_contains_counters() {
        let mut m = Metrics::new();
        m.offloads = 3;
        m.bytes_to_device = 1024;
        let s = m.summary();
        assert!(s.contains("offloads=3"));
        assert!(s.contains("to_dev=1024B"));
    }

    #[test]
    fn default_is_zero() {
        let m = Metrics::new();
        assert_eq!(m.offloads, 0);
        assert_eq!(m.pjrt_wall_us, 0);
    }
}
