//! Run-time counters for the coordinator (reported by `hero-blas serve`
//! and the harness alongside virtual-time results).
//!
//! Two families live here: [`Metrics`], the per-engine counters each
//! offload session accumulates, and [`SchedCounters`], the shared
//! thread-safe counters of the [`crate::sched`] scheduler (one set per
//! scheduler, updated by every worker and by the submit path).

use std::sync::atomic::{AtomicU64, Ordering};

/// Aggregate counters across one engine lifetime.
#[derive(Debug, Default, Clone, Copy)]
pub struct Metrics {
    /// Completed offloads (device launches that joined).
    pub offloads: u64,
    /// BLAS calls served on the host path.
    pub host_calls: u64,
    /// Bytes copied host -> device DRAM.
    pub bytes_to_device: u64,
    /// Bytes copied device DRAM -> host.
    pub bytes_from_device: u64,
    /// IO-PTEs created (zero-copy path).
    pub iommu_pages_mapped: u64,
    /// Device tile-kernel invocations (artifact executions).
    pub tile_kernel_calls: u64,
    /// Wall-clock microseconds spent inside PJRT execution (host side,
    /// not virtual time — used by the perf pass).
    pub pjrt_wall_us: u64,
    /// Operand-cache hits: `map(to:)` of bytes already device-resident
    /// (refcount bump, no copy).
    pub cache_hits: u64,
    /// Operand-cache misses on cacheable `map(to:)` operands.
    pub cache_misses: u64,
    /// Cache entries evicted (LRU or OOM reclaim; never pinned ones).
    pub cache_evictions: u64,
    /// Host->device bytes NOT copied thanks to cache hits and
    /// `map(alloc:)` output staging (compare with `bytes_to_device`).
    pub bytes_copy_elided: u64,
    /// Intermediate bytes that never crossed the host/device boundary
    /// because a chained producer's output stayed device-resident for the
    /// next link: the elided `map(from:)` at promotion plus the elided
    /// `map(to:)` at consumption (see `OffloadEngine::promote_output`).
    pub chain_bytes_elided: u64,
    /// Interior-edge bytes elided by DAG execution: a promoted node
    /// output consumed in place by every fan-out consumer instead of a
    /// host round trip per edge (see `OffloadEngine::promote_output_dag`).
    pub dag_bytes_elided: u64,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics::default()
    }

    /// Render a compact single-line summary.
    pub fn summary(&self) -> String {
        format!(
            "offloads={} host_calls={} to_dev={}B from_dev={}B \
             iommu_pages={} tile_calls={} pjrt_wall={}us \
             cache_hits={} cache_misses={} cache_evictions={} elided={}B \
             chain_elided={}B dag_elided={}B",
            self.offloads,
            self.host_calls,
            self.bytes_to_device,
            self.bytes_from_device,
            self.iommu_pages_mapped,
            self.tile_kernel_calls,
            self.pjrt_wall_us,
            self.cache_hits,
            self.cache_misses,
            self.cache_evictions,
            self.bytes_copy_elided,
            self.chain_bytes_elided,
            self.dag_bytes_elided,
        )
    }
}

/// Bucket count of the fixed log-scale latency histograms: power-of-two
/// microsecond buckets cover [0, 2^30) us (~18 minutes) exactly, with
/// the last bucket absorbing anything larger.
pub const HIST_BUCKETS: usize = 32;

/// Fixed-bucket log-scale latency histogram (microseconds).
///
/// The hot path is one relaxed `fetch_add` on a preallocated bucket —
/// no allocation, no lock, no sort.  Bucket `0` holds exactly the value
/// `0`; bucket `i >= 1` holds `[2^(i-1), 2^i)`; the last bucket is
/// open-ended.  Quantiles are read from a [`HistogramSnapshot`], which
/// reports the *upper bound* of the bucket containing the target rank —
/// a conservative (never under-reporting) estimate with power-of-two
/// resolution, the standard trade for an allocation-free histogram.
#[derive(Debug, Default)]
pub struct LatencyHistogram {
    buckets: [AtomicU64; HIST_BUCKETS],
    /// Exact sum of every recorded value — the Prometheus `_sum` series
    /// (quantiles stay bucket-resolution; the sum is lossless).
    sum_us: AtomicU64,
}

impl LatencyHistogram {
    pub fn new() -> LatencyHistogram {
        LatencyHistogram::default()
    }

    /// The bucket a microsecond value lands in.
    pub fn bucket_index(us: u64) -> usize {
        if us == 0 {
            0
        } else {
            (64 - us.leading_zeros() as usize).min(HIST_BUCKETS - 1)
        }
    }

    /// Inclusive upper bound of a bucket (`u64::MAX` for the last).
    pub fn bucket_upper(idx: usize) -> u64 {
        if idx >= HIST_BUCKETS - 1 {
            u64::MAX
        } else {
            (1u64 << idx) - 1
        }
    }

    /// Record one latency sample.  Allocation-free and lock-free.
    pub fn record(&self, us: u64) {
        self.buckets[Self::bucket_index(us)].fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
    }

    /// Plain-value copy for quantile reads and cross-cluster merges.
    pub fn snapshot(&self) -> HistogramSnapshot {
        let mut out = HistogramSnapshot::default();
        for (i, b) in self.buckets.iter().enumerate() {
            out.buckets[i] = b.load(Ordering::Relaxed);
        }
        out.sum = self.sum_us.load(Ordering::Relaxed);
        out
    }
}

/// Plain-value copy of a [`LatencyHistogram`].
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct HistogramSnapshot {
    pub buckets: [u64; HIST_BUCKETS],
    /// Exact sum of the recorded values (`_sum` in the Prometheus
    /// exposition; merges add it losslessly).
    pub sum: u64,
}

impl HistogramSnapshot {
    /// Total recorded samples.
    pub fn count(&self) -> u64 {
        self.buckets.iter().sum()
    }

    /// Fold another snapshot in (e.g. merge per-cluster histograms into
    /// a pool-wide view).
    pub fn merge(&mut self, other: &HistogramSnapshot) {
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += *b;
        }
        self.sum += other.sum;
    }

    /// The quantile `q` in [0, 1]: upper bound of the bucket holding the
    /// rank-`ceil(q * count)` sample (0 for an empty histogram).
    pub fn quantile(&self, q: f64) -> u64 {
        let count = self.count();
        if count == 0 {
            return 0;
        }
        let q = q.clamp(0.0, 1.0);
        let target = ((q * count as f64).ceil() as u64).clamp(1, count);
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b;
            if seen >= target {
                return LatencyHistogram::bucket_upper(i);
            }
        }
        LatencyHistogram::bucket_upper(HIST_BUCKETS - 1)
    }

    pub fn p50(&self) -> u64 {
        self.quantile(0.50)
    }

    pub fn p99(&self) -> u64 {
        self.quantile(0.99)
    }

    pub fn p999(&self) -> u64 {
        self.quantile(0.999)
    }
}

/// Op-class labels of the per-class latency histograms, in index order
/// (axpy/dot jobs share the `level1` class; dag jobs share the
/// multi-op `chain` class).
pub const OP_CLASSES: [&str; 4] = ["gemm", "gemv", "level1", "chain"];

/// Histogram index for a serve op name.
pub fn op_class_idx(op: &str) -> usize {
    match op {
        "gemm" => 0,
        "gemv" => 1,
        "chain" | "dag" => 3,
        // axpy, dot and anything the level-1 path serves
        _ => 2,
    }
}

/// Percentile summary of one op class (plain values, serializable).
#[derive(Debug, Default, Clone, Copy)]
pub struct OpClassLatency {
    pub count: u64,
    pub p50_us: u64,
    pub p99_us: u64,
    pub p999_us: u64,
}

impl OpClassLatency {
    fn from_hist(h: &HistogramSnapshot) -> OpClassLatency {
        OpClassLatency {
            count: h.count(),
            p50_us: h.p50(),
            p99_us: h.p99(),
            p999_us: h.p999(),
        }
    }
}

/// Pool-wide serving-path span totals in microseconds (one bucket per
/// span stage; see `sched::span`).  `linger_us` is the portion of
/// `stage_us` spent in the batcher's linger window, reported separately
/// but not added twice.
#[derive(Debug, Default, Clone, Copy)]
pub struct SpanTotals {
    pub queue_us: u64,
    pub route_us: u64,
    pub linger_us: u64,
    /// Wall time failed device attempts consumed before their jobs'
    /// final (replied) attempt — a sub-span like `linger_us`, outside
    /// the five-stage telescoping sum.
    pub retry_us: u64,
    pub stage_us: u64,
    pub execute_us: u64,
    pub finish_us: u64,
}

/// Per-cluster scheduler counters: one set per pool cluster, updated by
/// the cluster's worker and the placement router, reported by the serve
/// `metrics` op so operators see skew, affinity warmth and steal traffic
/// per lane instead of pool aggregates only.
#[derive(Debug, Default)]
pub struct ClusterCounters {
    /// Jobs completed on this cluster.
    pub completed: AtomicU64,
    /// Fork-join launches this cluster issued.
    pub batches: AtomicU64,
    /// Jobs this cluster's worker stole from a peer's run queue.
    pub stolen: AtomicU64,
    /// Jobs the placement router routed here by operand affinity.
    pub affine_routed: AtomicU64,
    /// Shared operands this cluster's worker pre-staged into its cache
    /// during the batcher's linger window (directory-driven prefetch).
    pub prefetched: AtomicU64,
    /// Operand-cache hits on this cluster's engine.
    pub cache_hits: AtomicU64,
    /// Operand-cache misses on this cluster's engine.
    pub cache_misses: AtomicU64,
    /// Host->device bytes this cluster's engine actually copied.
    pub bytes_to_device: AtomicU64,
    /// Jobs claimed by this cluster's worker and not yet replied to
    /// (live gauge, not a monotone counter — the serve `top` op reads
    /// it for the dashboard poll loop).
    pub inflight: AtomicU64,
    /// Pin-drain check failures on this cluster (stranded operand-cache
    /// pins caught after the pipeline quiesced).
    pub pin_leaks: AtomicU64,
    /// End-to-end request latency served by this cluster.
    pub latency: LatencyHistogram,
}

/// Plain-value snapshot of one cluster's counters (plus the router's
/// live run-queue depth, filled in by the scheduler).
#[derive(Debug, Default, Clone, Copy)]
pub struct ClusterMetrics {
    pub cluster: u32,
    pub queue_depth: u64,
    pub completed: u64,
    pub batches: u64,
    pub stolen: u64,
    pub affine_routed: u64,
    pub prefetched: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub bytes_to_device: u64,
    pub inflight: u64,
    pub pin_leaks: u64,
    pub p50_us: u64,
    pub p99_us: u64,
    pub p999_us: u64,
    /// Raw latency histogram for this cluster — the source of the
    /// Prometheus `hero_cluster_latency_us` series.
    pub latency_hist: HistogramSnapshot,
}

/// Thread-safe scheduler counters, shared between the submit path and
/// every pool worker.  Read with [`SchedCounters::snapshot`].
#[derive(Debug, Default)]
pub struct SchedCounters {
    /// Jobs accepted into the work queue.
    pub submitted: AtomicU64,
    /// Jobs rejected at submit time (queue full — backpressure).
    pub rejected: AtomicU64,
    /// Jobs that completed and replied successfully.
    pub completed: AtomicU64,
    /// Jobs that replied with an error.
    pub failed: AtomicU64,
    /// Fork-join launches issued by workers (batched or not).
    pub batches: AtomicU64,
    /// Jobs that shared a launch with at least one other job.
    pub batched_jobs: AtomicU64,
    /// Deepest queue observed at submit time.
    pub queue_depth_peak: AtomicU64,
    /// EWMA of per-job wall service time in microseconds (drives the
    /// retry-after hint on rejected submits).
    pub service_us_ewma: AtomicU64,
    /// Jobs skipped at dequeue because the submitter cancelled (its
    /// serve-layer reply receiver timed out and was dropped).
    pub cancelled: AtomicU64,
    /// Batches whose map-in was staged while the previous batch's
    /// compute was still in flight (software pipelining).
    pub pipelined_batches: AtomicU64,
    /// Virtual microseconds of map-in hidden under the previous batch's
    /// compute window across all workers.
    pub overlap_hidden_us: AtomicU64,
    /// Operand-cache hits across all pool workers' engines.
    pub cache_hits: AtomicU64,
    /// Operand-cache misses across all pool workers' engines.
    pub cache_misses: AtomicU64,
    /// Operand-cache evictions across all pool workers' engines.
    pub cache_evictions: AtomicU64,
    /// Host->device bytes actually copied across all workers' engines.
    pub bytes_to_device: AtomicU64,
    /// Host->device bytes elided (cache hits + alloc-only output
    /// staging) across all workers' engines.
    pub bytes_copy_elided: AtomicU64,
    /// Jobs taken from a peer cluster's run queue by an idle worker.
    pub stolen: AtomicU64,
    /// Jobs placed by operand affinity (warm cluster or hash-home).
    pub affine_routed: AtomicU64,
    /// Jobs routed to the big-shape lane because their staged footprint
    /// exceeds a small cluster's slice.
    pub big_shape_routed: AtomicU64,
    /// Shared operands pre-staged into a cold home's cache during the
    /// batcher's linger window (directory-driven prefetch).
    pub prefetched: AtomicU64,
    /// Affine operand keys re-homed by the steal-fairness load balancer
    /// (home cluster saturated for `rebalance_drains` drain passes).
    pub rehomed: AtomicU64,
    /// Chain jobs completed (a chain counts once however many links it
    /// runs; each chain also counts once in `completed`).
    pub chains: AtomicU64,
    /// Intermediate bytes elided by chained execution across all workers'
    /// engines (device-resident hand-off instead of a host round trip).
    pub chain_bytes_elided: AtomicU64,
    /// DAG jobs completed (a DAG counts once however many nodes it
    /// runs; each DAG also counts once in `completed`).
    pub dags: AtomicU64,
    /// Nodes executed across all completed DAG jobs.
    pub dag_nodes: AtomicU64,
    /// Interior-edge bytes elided by DAG execution across all workers'
    /// engines (promoted fan-out outputs consumed in place).
    pub dag_bytes_elided: AtomicU64,
    /// Requests spliced onto a just-published DAG output still resident
    /// within the `[sched.dag]` fuse window (cross-request fusion).
    pub dag_fused_requests: AtomicU64,
    /// End-to-end latency histograms, one per op class (see
    /// [`OP_CLASSES`]): gemm / gemv / level1 / chain.
    pub latency: [LatencyHistogram; 4],
    /// Injected faults fired by the seeded fault plan (one per faulted
    /// batch launch, whatever the seam).
    pub faults_injected: AtomicU64,
    /// Jobs resubmitted to a different cluster after a fault.
    pub retries: AtomicU64,
    /// Clusters that crossed the fault threshold and entered quarantine
    /// (counts quarantine *events*, so a probe/re-fault cycle counts
    /// each re-entry).
    pub quarantined: AtomicU64,
    /// Jobs that exhausted device attempts (or eligible clusters) and
    /// completed on the host BLAS path with `degraded: true`.
    pub host_fallbacks: AtomicU64,
    /// Operand-cache bytes released when a faulted cluster's resident
    /// entries were invalidated.
    pub cache_invalidated_bytes: AtomicU64,
    /// Operand-cache pins found stranded at a worker quiesce point (the
    /// release-mode form of the pins-drained invariant; must stay 0).
    pub pin_leaks: AtomicU64,
    /// Pool-wide serving-path span totals (microseconds per stage,
    /// accumulated per completed request).
    pub span_queue_us: AtomicU64,
    pub span_route_us: AtomicU64,
    pub span_linger_us: AtomicU64,
    pub span_retry_us: AtomicU64,
    pub span_stage_us: AtomicU64,
    pub span_execute_us: AtomicU64,
    pub span_finish_us: AtomicU64,
    /// One [`ClusterCounters`] per pool cluster (empty under
    /// `Default` — tests that never ask for per-cluster data).
    pub per_cluster: Vec<ClusterCounters>,
}

impl SchedCounters {
    /// Counters for a pool of `clusters` (per-cluster sets included).
    pub fn new(clusters: usize) -> SchedCounters {
        SchedCounters {
            per_cluster: (0..clusters).map(|_| ClusterCounters::default()).collect(),
            ..SchedCounters::default()
        }
    }

    /// The per-cluster counter set, when the pool size covers `cluster`.
    pub fn cluster(&self, cluster: u32) -> Option<&ClusterCounters> {
        self.per_cluster.get(cluster as usize)
    }

    /// Record the queue depth seen after a successful push.
    pub fn note_queue_depth(&self, depth: u64) {
        self.queue_depth_peak.fetch_max(depth, Ordering::Relaxed);
    }

    /// Fold one per-job service time into the EWMA (alpha = 1/8).
    pub fn note_service_us(&self, us: u64) {
        // Racy read-modify-write is fine: this is a smoothed hint, not an
        // exact accumulator.
        let old = self.service_us_ewma.load(Ordering::Relaxed);
        let new = if old == 0 { us } else { (old * 7 + us) / 8 };
        self.service_us_ewma.store(new, Ordering::Relaxed);
    }

    /// Record one request's end-to-end latency into the op-class
    /// histogram and the serving cluster's histogram.
    pub fn note_latency_us(&self, op: &str, cluster: u32, us: u64) {
        self.latency[op_class_idx(op)].record(us);
        if let Some(pc) = self.cluster(cluster) {
            pc.latency.record(us);
        }
    }

    /// Accumulate one request's span breakdown into the pool-wide
    /// per-stage totals (`linger` is the sub-span of `stage` spent in
    /// the batcher's linger window).
    pub fn note_span_us(
        &self,
        queue: u64,
        route: u64,
        linger: u64,
        stage: u64,
        execute: u64,
        finish: u64,
    ) {
        self.span_queue_us.fetch_add(queue, Ordering::Relaxed);
        self.span_route_us.fetch_add(route, Ordering::Relaxed);
        self.span_linger_us.fetch_add(linger, Ordering::Relaxed);
        self.span_stage_us.fetch_add(stage, Ordering::Relaxed);
        self.span_execute_us.fetch_add(execute, Ordering::Relaxed);
        self.span_finish_us.fetch_add(finish, Ordering::Relaxed);
    }

    /// Accumulate one recovered request's retry sub-span (wall time its
    /// failed device attempts consumed; outside the telescoping sum,
    /// like `linger`).
    pub fn note_retry_us(&self, us: u64) {
        self.span_retry_us.fetch_add(us, Ordering::Relaxed);
    }

    /// Consistent-enough point-in-time copy.
    pub fn snapshot(&self) -> SchedMetrics {
        let ld = |a: &AtomicU64| a.load(Ordering::Relaxed);
        let latency = [
            self.latency[0].snapshot(),
            self.latency[1].snapshot(),
            self.latency[2].snapshot(),
            self.latency[3].snapshot(),
        ];
        let mut overall = HistogramSnapshot::default();
        for h in &latency {
            overall.merge(h);
        }
        SchedMetrics {
            submitted: ld(&self.submitted),
            rejected: ld(&self.rejected),
            completed: ld(&self.completed),
            failed: ld(&self.failed),
            batches: ld(&self.batches),
            batched_jobs: ld(&self.batched_jobs),
            queue_depth_peak: ld(&self.queue_depth_peak),
            service_us_ewma: ld(&self.service_us_ewma),
            cancelled: ld(&self.cancelled),
            pipelined_batches: ld(&self.pipelined_batches),
            overlap_hidden_us: ld(&self.overlap_hidden_us),
            cache_hits: ld(&self.cache_hits),
            cache_misses: ld(&self.cache_misses),
            cache_evictions: ld(&self.cache_evictions),
            bytes_to_device: ld(&self.bytes_to_device),
            bytes_copy_elided: ld(&self.bytes_copy_elided),
            stolen: ld(&self.stolen),
            affine_routed: ld(&self.affine_routed),
            big_shape_routed: ld(&self.big_shape_routed),
            prefetched: ld(&self.prefetched),
            rehomed: ld(&self.rehomed),
            chains: ld(&self.chains),
            chain_bytes_elided: ld(&self.chain_bytes_elided),
            dags: ld(&self.dags),
            dag_nodes: ld(&self.dag_nodes),
            dag_bytes_elided: ld(&self.dag_bytes_elided),
            dag_fused_requests: ld(&self.dag_fused_requests),
            faults_injected: ld(&self.faults_injected),
            retries: ld(&self.retries),
            quarantined: ld(&self.quarantined),
            host_fallbacks: ld(&self.host_fallbacks),
            cache_invalidated_bytes: ld(&self.cache_invalidated_bytes),
            pin_leaks: ld(&self.pin_leaks),
            // the kernel registry keeps its own counters; the scheduler
            // overlays them on this snapshot (see `Scheduler::metrics`)
            kernel_specialized: 0,
            kernel_hits: 0,
            kernel_fallbacks: 0,
            kernel_evictions: 0,
            kernel_entries: 0,
            latency: [
                OpClassLatency::from_hist(&latency[0]),
                OpClassLatency::from_hist(&latency[1]),
                OpClassLatency::from_hist(&latency[2]),
                OpClassLatency::from_hist(&latency[3]),
            ],
            overall: OpClassLatency::from_hist(&overall),
            latency_hist: latency,
            spans: SpanTotals {
                queue_us: ld(&self.span_queue_us),
                route_us: ld(&self.span_route_us),
                linger_us: ld(&self.span_linger_us),
                retry_us: ld(&self.span_retry_us),
                stage_us: ld(&self.span_stage_us),
                execute_us: ld(&self.span_execute_us),
                finish_us: ld(&self.span_finish_us),
            },
            clusters: self
                .per_cluster
                .iter()
                .enumerate()
                .map(|(i, c)| {
                    let h = c.latency.snapshot();
                    ClusterMetrics {
                        cluster: i as u32,
                        queue_depth: 0, // live depth filled in by the scheduler
                        completed: ld(&c.completed),
                        batches: ld(&c.batches),
                        stolen: ld(&c.stolen),
                        affine_routed: ld(&c.affine_routed),
                        prefetched: ld(&c.prefetched),
                        cache_hits: ld(&c.cache_hits),
                        cache_misses: ld(&c.cache_misses),
                        bytes_to_device: ld(&c.bytes_to_device),
                        inflight: ld(&c.inflight),
                        pin_leaks: ld(&c.pin_leaks),
                        p50_us: h.p50(),
                        p99_us: h.p99(),
                        p999_us: h.p999(),
                        latency_hist: h,
                    }
                })
                .collect(),
        }
    }

    /// Fold the per-engine metric growth from one batch into the shared
    /// counters — aggregate and `cluster`'s own set (workers call this
    /// after each batch with the delta between two [`Metrics`]
    /// snapshots).
    pub fn absorb_engine_delta(&self, cluster: u32, before: &Metrics, after: &Metrics) {
        let add = |c: &AtomicU64, b: u64, a: u64| {
            c.fetch_add(a.saturating_sub(b), Ordering::Relaxed);
        };
        add(&self.cache_hits, before.cache_hits, after.cache_hits);
        add(&self.cache_misses, before.cache_misses, after.cache_misses);
        add(&self.cache_evictions, before.cache_evictions, after.cache_evictions);
        add(&self.bytes_to_device, before.bytes_to_device, after.bytes_to_device);
        add(
            &self.bytes_copy_elided,
            before.bytes_copy_elided,
            after.bytes_copy_elided,
        );
        add(
            &self.chain_bytes_elided,
            before.chain_bytes_elided,
            after.chain_bytes_elided,
        );
        add(
            &self.dag_bytes_elided,
            before.dag_bytes_elided,
            after.dag_bytes_elided,
        );
        if let Some(pc) = self.cluster(cluster) {
            add(&pc.cache_hits, before.cache_hits, after.cache_hits);
            add(&pc.cache_misses, before.cache_misses, after.cache_misses);
            add(&pc.bytes_to_device, before.bytes_to_device, after.bytes_to_device);
        }
    }
}

/// Plain-value snapshot of [`SchedCounters`].
#[derive(Debug, Default, Clone)]
pub struct SchedMetrics {
    pub submitted: u64,
    pub rejected: u64,
    pub completed: u64,
    pub failed: u64,
    pub batches: u64,
    pub batched_jobs: u64,
    pub queue_depth_peak: u64,
    pub service_us_ewma: u64,
    pub cancelled: u64,
    pub pipelined_batches: u64,
    pub overlap_hidden_us: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub cache_evictions: u64,
    pub bytes_to_device: u64,
    pub bytes_copy_elided: u64,
    pub stolen: u64,
    pub affine_routed: u64,
    pub big_shape_routed: u64,
    pub prefetched: u64,
    pub rehomed: u64,
    pub chains: u64,
    pub chain_bytes_elided: u64,
    pub dags: u64,
    pub dag_nodes: u64,
    pub dag_bytes_elided: u64,
    pub dag_fused_requests: u64,
    pub faults_injected: u64,
    pub retries: u64,
    pub quarantined: u64,
    pub host_fallbacks: u64,
    pub cache_invalidated_bytes: u64,
    pub pin_leaks: u64,
    /// Specialized kernel plans compiled (promotions + prewarm inserts)
    /// across the pool-shared kernel registry.
    pub kernel_specialized: u64,
    /// Device walks that took a specialized fast-path plan.
    pub kernel_hits: u64,
    /// Device walks that ran the generic interpreted walk while the
    /// registry was enabled (no resident plan for their key).
    pub kernel_fallbacks: u64,
    /// Specialized plans LRU-evicted or explicitly dropped.
    pub kernel_evictions: u64,
    /// Specialized plans currently resident (gauge).
    pub kernel_entries: u64,
    /// Percentile latency per op class, indexed like [`OP_CLASSES`].
    pub latency: [OpClassLatency; 4],
    /// Percentiles over every op class merged.
    pub overall: OpClassLatency,
    /// Raw per-op-class histogram snapshots (bucket counts plus exact
    /// sums), indexed like [`OP_CLASSES`] — what the Prometheus
    /// exposition renders as cumulative `_bucket`/`_sum`/`_count`.
    pub latency_hist: [HistogramSnapshot; 4],
    /// Pool-wide serving-path span totals (microseconds per stage).
    pub spans: SpanTotals,
    /// Per-cluster breakdown, indexed by cluster id (empty when the
    /// counters were built with `Default` instead of `new`).
    pub clusters: Vec<ClusterMetrics>,
}

impl SchedMetrics {
    /// Render a compact single-line summary (mirrors [`Metrics::summary`]).
    pub fn summary(&self) -> String {
        format!(
            "submitted={} completed={} rejected={} failed={} cancelled={} \
             batches={} batched_jobs={} pipelined={} overlap={}us \
             queue_peak={} service_ewma={}us cache_hits={} cache_misses={} \
             cache_evictions={} to_dev={}B elided={}B stolen={} affine={} \
             big_shape={} prefetched={} rehomed={} chains={} chain_elided={}B \
             dags={} dag_nodes={} dag_elided={}B dag_fused={} \
             faults={} retries={} quarantined={} host_fallbacks={} \
             cache_invalidated={}B pin_leaks={} kernel_specialized={} \
             kernel_hits={} kernel_fallbacks={}",
            self.submitted,
            self.completed,
            self.rejected,
            self.failed,
            self.cancelled,
            self.batches,
            self.batched_jobs,
            self.pipelined_batches,
            self.overlap_hidden_us,
            self.queue_depth_peak,
            self.service_us_ewma,
            self.cache_hits,
            self.cache_misses,
            self.cache_evictions,
            self.bytes_to_device,
            self.bytes_copy_elided,
            self.stolen,
            self.affine_routed,
            self.big_shape_routed,
            self.prefetched,
            self.rehomed,
            self.chains,
            self.chain_bytes_elided,
            self.dags,
            self.dag_nodes,
            self.dag_bytes_elided,
            self.dag_fused_requests,
            self.faults_injected,
            self.retries,
            self.quarantined,
            self.host_fallbacks,
            self.cache_invalidated_bytes,
            self.pin_leaks,
            self.kernel_specialized,
            self.kernel_hits,
            self.kernel_fallbacks,
        )
    }
}

/// One `# HELP`/`# TYPE` header plus a single unlabelled sample line.
fn prom_scalar(out: &mut String, name: &str, kind: &str, help: &str, v: u64) {
    use std::fmt::Write;
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} {kind}");
    let _ = writeln!(out, "{name} {v}");
}

/// Cumulative `_bucket{{le=...}}` series plus `_sum`/`_count` for one
/// histogram under an optional extra label set (e.g. `op="gemm"`).
fn prom_hist(out: &mut String, name: &str, labels: &str, h: &HistogramSnapshot) {
    use std::fmt::Write;
    let mut cum = 0u64;
    for (i, b) in h.buckets.iter().enumerate() {
        cum += *b;
        let le = if i == HIST_BUCKETS - 1 {
            "+Inf".to_string()
        } else {
            LatencyHistogram::bucket_upper(i).to_string()
        };
        if labels.is_empty() {
            let _ = writeln!(out, "{name}_bucket{{le=\"{le}\"}} {cum}");
        } else {
            let _ = writeln!(out, "{name}_bucket{{{labels},le=\"{le}\"}} {cum}");
        }
    }
    if labels.is_empty() {
        let _ = writeln!(out, "{name}_sum {}", h.sum);
        let _ = writeln!(out, "{name}_count {cum}");
    } else {
        let _ = writeln!(out, "{name}_sum{{{labels}}} {}", h.sum);
        let _ = writeln!(out, "{name}_count{{{labels}}} {cum}");
    }
}

/// Render a [`SchedMetrics`] snapshot in the Prometheus text exposition
/// format (0.0.4): every pool counter and gauge, the span-stage totals,
/// per-cluster series labelled `{cluster="N"}`, and the end-to-end
/// latency histograms as cumulative `_bucket`/`_sum`/`_count` series
/// whose `le` edges are the log2 bucket upper bounds.  This is the body
/// of the serve layer's `metrics_prom` op.
pub fn prometheus_text(m: &SchedMetrics) -> String {
    use std::fmt::Write;
    let mut out = String::with_capacity(16 * 1024);

    let counters: [(&str, &str, u64); 35] = [
        ("hero_jobs_submitted_total", "Jobs accepted into the work queue.", m.submitted),
        ("hero_jobs_rejected_total", "Jobs rejected at submit (backpressure).", m.rejected),
        ("hero_jobs_completed_total", "Jobs completed and replied successfully.", m.completed),
        ("hero_jobs_failed_total", "Jobs that replied with an error.", m.failed),
        ("hero_jobs_cancelled_total", "Jobs skipped at dequeue after client cancel.", m.cancelled),
        ("hero_batches_total", "Fork-join launches issued by workers.", m.batches),
        ("hero_batched_jobs_total", "Jobs that shared a launch with another job.", m.batched_jobs),
        ("hero_pipelined_batches_total", "Batches staged under the previous compute.", m.pipelined_batches),
        ("hero_overlap_hidden_us_total", "Staging microseconds hidden by pipelining.", m.overlap_hidden_us),
        ("hero_cache_hits_total", "Operand-cache hits.", m.cache_hits),
        ("hero_cache_misses_total", "Operand-cache misses.", m.cache_misses),
        ("hero_cache_evictions_total", "Operand-cache evictions.", m.cache_evictions),
        ("hero_bytes_to_device_total", "Host-to-device bytes actually copied.", m.bytes_to_device),
        ("hero_bytes_copy_elided_total", "Host-to-device bytes elided by the cache.", m.bytes_copy_elided),
        ("hero_jobs_stolen_total", "Jobs taken from another cluster's queue.", m.stolen),
        ("hero_affine_routed_total", "Jobs routed to their operand-affine cluster.", m.affine_routed),
        ("hero_big_shape_routed_total", "Jobs routed by the big-shape policy.", m.big_shape_routed),
        ("hero_prefetched_total", "Shared operands prefetched ahead of claim.", m.prefetched),
        ("hero_rehomed_total", "Jobs re-homed off a quarantined cluster.", m.rehomed),
        ("hero_chains_total", "Chained multi-op requests executed.", m.chains),
        ("hero_chain_bytes_elided_total", "Intermediate bytes kept device-resident.", m.chain_bytes_elided),
        ("hero_dags_total", "DAG multi-op requests executed.", m.dags),
        ("hero_dag_nodes_total", "Nodes executed across completed DAGs.", m.dag_nodes),
        ("hero_dag_bytes_elided_total", "Interior-edge bytes kept device-resident.", m.dag_bytes_elided),
        ("hero_dag_fused_requests_total", "Requests fused onto a resident DAG output.", m.dag_fused_requests),
        ("hero_faults_injected_total", "Device faults injected by the fault plan.", m.faults_injected),
        ("hero_retries_total", "Faulted jobs requeued for another attempt.", m.retries),
        ("hero_quarantined_total", "Cluster quarantine transitions.", m.quarantined),
        ("hero_host_fallbacks_total", "Jobs degraded to the host BLAS path.", m.host_fallbacks),
        ("hero_cache_invalidated_bytes_total", "Cache bytes dropped on fault invalidation.", m.cache_invalidated_bytes),
        ("hero_pin_leaks_total", "Operand pins released by the leak sweeper.", m.pin_leaks),
        ("hero_kernel_specialized_total", "Specialized kernel plans compiled.", m.kernel_specialized),
        ("hero_kernel_hits_total", "Walks served by a specialized fast-path plan.", m.kernel_hits),
        ("hero_kernel_fallbacks_total", "Walks on the generic path with the registry on.", m.kernel_fallbacks),
        ("hero_kernel_evictions_total", "Specialized plans evicted from the registry.", m.kernel_evictions),
    ];
    for (name, help, v) in counters {
        prom_scalar(&mut out, name, "counter", help, v);
    }
    prom_scalar(
        &mut out,
        "hero_queue_depth_peak",
        "gauge",
        "Deepest work queue observed at submit time.",
        m.queue_depth_peak,
    );
    prom_scalar(
        &mut out,
        "hero_service_us_ewma",
        "gauge",
        "EWMA of per-job wall service time (microseconds).",
        m.service_us_ewma,
    );
    prom_scalar(
        &mut out,
        "hero_kernel_entries",
        "gauge",
        "Specialized kernel plans currently resident.",
        m.kernel_entries,
    );

    let spans: [(&str, u64); 7] = [
        ("queue", m.spans.queue_us),
        ("route", m.spans.route_us),
        ("linger", m.spans.linger_us),
        ("retry", m.spans.retry_us),
        ("stage", m.spans.stage_us),
        ("execute", m.spans.execute_us),
        ("finish", m.spans.finish_us),
    ];
    let _ = writeln!(out, "# HELP hero_span_us_total Serving-path microseconds per span stage.");
    let _ = writeln!(out, "# TYPE hero_span_us_total counter");
    for (stage, v) in spans {
        let _ = writeln!(out, "hero_span_us_total{{stage=\"{stage}\"}} {v}");
    }

    // Per-cluster families: one HELP/TYPE header, one labelled line per
    // cluster.
    let per_cluster: [(&str, &str, &str, fn(&ClusterMetrics) -> u64); 11] = [
        ("hero_cluster_completed_total", "counter", "Jobs completed per cluster.", |c| c.completed),
        ("hero_cluster_batches_total", "counter", "Launches issued per cluster.", |c| c.batches),
        ("hero_cluster_stolen_total", "counter", "Jobs stolen per cluster.", |c| c.stolen),
        ("hero_cluster_affine_routed_total", "counter", "Affine-routed jobs per cluster.", |c| c.affine_routed),
        ("hero_cluster_prefetched_total", "counter", "Prefetches per cluster.", |c| c.prefetched),
        ("hero_cluster_cache_hits_total", "counter", "Operand-cache hits per cluster.", |c| c.cache_hits),
        ("hero_cluster_cache_misses_total", "counter", "Operand-cache misses per cluster.", |c| c.cache_misses),
        ("hero_cluster_bytes_to_device_total", "counter", "Bytes copied to device per cluster.", |c| c.bytes_to_device),
        ("hero_cluster_pin_leaks_total", "counter", "Stranded-pin sweeps per cluster.", |c| c.pin_leaks),
        ("hero_cluster_inflight", "gauge", "Claimed-but-unreplied jobs per cluster.", |c| c.inflight),
        ("hero_cluster_queue_depth", "gauge", "Live run-queue depth per cluster.", |c| c.queue_depth),
    ];
    for (name, kind, help, get) in per_cluster {
        let _ = writeln!(out, "# HELP {name} {help}");
        let _ = writeln!(out, "# TYPE {name} {kind}");
        for c in &m.clusters {
            let _ = writeln!(out, "{name}{{cluster=\"{}\"}} {}", c.cluster, get(c));
        }
    }

    let _ = writeln!(
        out,
        "# HELP hero_request_latency_us End-to-end request latency per op class."
    );
    let _ = writeln!(out, "# TYPE hero_request_latency_us histogram");
    for (i, h) in m.latency_hist.iter().enumerate() {
        let labels = format!("op=\"{}\"", OP_CLASSES[i]);
        prom_hist(&mut out, "hero_request_latency_us", &labels, h);
    }

    let _ = writeln!(
        out,
        "# HELP hero_cluster_latency_us End-to-end request latency per serving cluster."
    );
    let _ = writeln!(out, "# TYPE hero_cluster_latency_us histogram");
    for c in &m.clusters {
        let labels = format!("cluster=\"{}\"", c.cluster);
        prom_hist(&mut out, "hero_cluster_latency_us", &labels, &c.latency_hist);
    }

    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_contains_counters() {
        let mut m = Metrics::new();
        m.offloads = 3;
        m.bytes_to_device = 1024;
        let s = m.summary();
        assert!(s.contains("offloads=3"));
        assert!(s.contains("to_dev=1024B"));
    }

    #[test]
    fn default_is_zero() {
        let m = Metrics::new();
        assert_eq!(m.offloads, 0);
        assert_eq!(m.pjrt_wall_us, 0);
    }

    #[test]
    fn sched_counters_snapshot_and_summary() {
        let c = SchedCounters::default();
        c.submitted.fetch_add(5, Ordering::Relaxed);
        c.completed.fetch_add(4, Ordering::Relaxed);
        c.rejected.fetch_add(1, Ordering::Relaxed);
        c.note_queue_depth(3);
        c.note_queue_depth(2); // peak keeps the max
        let s = c.snapshot();
        assert_eq!(s.submitted, 5);
        assert_eq!(s.queue_depth_peak, 3);
        assert!(s.summary().contains("rejected=1"));
    }

    #[test]
    fn absorb_engine_delta_accumulates_growth_only() {
        let c = SchedCounters::new(2);
        let mut before = Metrics::new();
        before.cache_hits = 2;
        before.bytes_to_device = 100;
        let mut after = before;
        after.cache_hits = 5;
        after.cache_misses = 1;
        after.bytes_to_device = 164;
        after.bytes_copy_elided = 32;
        after.dag_bytes_elided = 48;
        c.absorb_engine_delta(1, &before, &after);
        c.absorb_engine_delta(1, &after, &after); // zero delta is a no-op
        let s = c.snapshot();
        assert_eq!(s.dag_bytes_elided, 48);
        assert_eq!(s.cache_hits, 3);
        assert_eq!(s.cache_misses, 1);
        assert_eq!(s.bytes_to_device, 64);
        assert_eq!(s.bytes_copy_elided, 32);
        assert!(s.summary().contains("cache_hits=3"));
        // the delta also lands on the owning cluster's set, and only there
        assert_eq!(s.clusters.len(), 2);
        assert_eq!(s.clusters[1].cache_hits, 3);
        assert_eq!(s.clusters[1].bytes_to_device, 64);
        assert_eq!(s.clusters[0].cache_hits, 0);
        // default-built counters (no per-cluster sets) stay safe
        let d = SchedCounters::default();
        d.absorb_engine_delta(7, &before, &after);
        assert!(d.snapshot().clusters.is_empty());
    }

    #[test]
    fn per_cluster_counters_snapshot_independently() {
        let c = SchedCounters::new(3);
        c.cluster(0).unwrap().completed.fetch_add(2, Ordering::Relaxed);
        c.cluster(2).unwrap().stolen.fetch_add(1, Ordering::Relaxed);
        c.cluster(2).unwrap().affine_routed.fetch_add(4, Ordering::Relaxed);
        assert!(c.cluster(3).is_none(), "out-of-pool cluster id");
        let s = c.snapshot();
        assert_eq!(s.clusters[0].completed, 2);
        assert_eq!(s.clusters[1].completed, 0);
        assert_eq!(s.clusters[2].stolen, 1);
        assert_eq!(s.clusters[2].affine_routed, 4);
        assert_eq!(s.clusters[2].cluster, 2);
    }

    #[test]
    fn histogram_bucket_edges() {
        // 0 is its own bucket; each power of two starts a new bucket
        assert_eq!(LatencyHistogram::bucket_index(0), 0);
        assert_eq!(LatencyHistogram::bucket_index(1), 1);
        assert_eq!(LatencyHistogram::bucket_index(2), 2);
        assert_eq!(LatencyHistogram::bucket_index(3), 2);
        assert_eq!(LatencyHistogram::bucket_index(4), 3);
        assert_eq!(LatencyHistogram::bucket_index((1 << 30) - 1), 30);
        // everything >= 2^30 lands in the open-ended last bucket
        assert_eq!(LatencyHistogram::bucket_index(1 << 30), HIST_BUCKETS - 1);
        assert_eq!(LatencyHistogram::bucket_index(u64::MAX), HIST_BUCKETS - 1);
        // upper bounds are inclusive and ordered
        assert_eq!(LatencyHistogram::bucket_upper(0), 0);
        assert_eq!(LatencyHistogram::bucket_upper(1), 1);
        assert_eq!(LatencyHistogram::bucket_upper(2), 3);
        assert_eq!(LatencyHistogram::bucket_upper(HIST_BUCKETS - 1), u64::MAX);
        for i in 0..HIST_BUCKETS {
            let upper = LatencyHistogram::bucket_upper(i);
            assert_eq!(
                LatencyHistogram::bucket_index(upper),
                i,
                "upper bound of bucket {i} must land in bucket {i}"
            );
        }
    }

    #[test]
    fn histogram_quantiles_match_sorted_oracle() {
        // quantile(q) must equal the upper bound of the bucket holding
        // the rank-ceil(q*n) sample of the sorted data — the tightest
        // guarantee a fixed-bucket histogram can give
        let data: Vec<u64> = (0..1000u64).map(|i| (i * 37) % 5000).collect();
        let h = LatencyHistogram::new();
        for &v in &data {
            h.record(v);
        }
        let mut sorted = data.clone();
        sorted.sort_unstable();
        let snap = h.snapshot();
        assert_eq!(snap.count(), 1000);
        for q in [0.0, 0.1, 0.5, 0.9, 0.99, 0.999, 1.0] {
            let rank = ((q * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len());
            let oracle = sorted[rank - 1];
            let expect =
                LatencyHistogram::bucket_upper(LatencyHistogram::bucket_index(oracle));
            assert_eq!(
                snap.quantile(q),
                expect,
                "q={q}: histogram bucket disagrees with sorted oracle {oracle}"
            );
            // the histogram answer never under-reports the true quantile
            assert!(snap.quantile(q) >= oracle);
        }
    }

    #[test]
    fn histogram_empty_and_single_sample() {
        let h = LatencyHistogram::new();
        assert_eq!(h.snapshot().quantile(0.5), 0);
        assert_eq!(h.snapshot().count(), 0);
        h.record(700);
        let s = h.snapshot();
        // one sample: every quantile is that sample's bucket upper bound
        let expect = LatencyHistogram::bucket_upper(LatencyHistogram::bucket_index(700));
        assert_eq!(s.quantile(0.0), expect);
        assert_eq!(s.quantile(0.5), expect);
        assert_eq!(s.quantile(1.0), expect);
    }

    #[test]
    fn histogram_merge_across_clusters_matches_single_histogram() {
        let a = LatencyHistogram::new();
        let b = LatencyHistogram::new();
        let all = LatencyHistogram::new();
        for v in 0..500u64 {
            let v = v * 13 % 3000;
            if v % 2 == 0 {
                a.record(v);
            } else {
                b.record(v);
            }
            all.record(v);
        }
        let mut merged = a.snapshot();
        merged.merge(&b.snapshot());
        assert_eq!(merged, all.snapshot());
        assert_eq!(merged.p99(), all.snapshot().p99());
    }

    #[test]
    fn latency_lands_on_op_class_and_cluster() {
        let c = SchedCounters::new(2);
        c.note_latency_us("gemm", 0, 100);
        c.note_latency_us("gemm", 0, 200);
        c.note_latency_us("dot", 1, 50);
        c.note_latency_us("chain", 9, 400); // out-of-pool cluster: pool hist only
        c.note_latency_us("dag", 9, 300); // dag shares the multi-op chain class
        let s = c.snapshot();
        assert_eq!(s.latency[op_class_idx("gemm")].count, 2);
        assert_eq!(s.latency[op_class_idx("axpy")].count, 1, "dot shares level1");
        assert_eq!(s.latency[op_class_idx("chain")].count, 2);
        assert_eq!(op_class_idx("dag"), op_class_idx("chain"));
        assert_eq!(s.overall.count, 5);
        assert!(s.latency[0].p50_us <= s.latency[0].p99_us);
        assert!(s.latency[0].p99_us <= s.latency[0].p999_us);
        assert_eq!(s.clusters[0].p99_us, LatencyHistogram::bucket_upper(8)); // 200 -> [128,256)
        assert_eq!(s.clusters[1].p50_us, LatencyHistogram::bucket_upper(6)); // 50 -> [32,64)
    }

    #[test]
    fn span_totals_accumulate() {
        let c = SchedCounters::default();
        c.note_span_us(10, 2, 1, 5, 20, 3);
        c.note_span_us(10, 2, 1, 5, 20, 3);
        let s = c.snapshot().spans;
        assert_eq!(s.queue_us, 20);
        assert_eq!(s.route_us, 4);
        assert_eq!(s.linger_us, 2);
        assert_eq!(s.stage_us, 10);
        assert_eq!(s.execute_us, 40);
        assert_eq!(s.finish_us, 6);
    }

    #[test]
    fn inflight_gauge_rises_and_falls() {
        let c = SchedCounters::new(1);
        let pc = c.cluster(0).unwrap();
        pc.inflight.fetch_add(3, Ordering::Relaxed);
        assert_eq!(c.snapshot().clusters[0].inflight, 3);
        pc.inflight.fetch_sub(3, Ordering::Relaxed);
        assert_eq!(c.snapshot().clusters[0].inflight, 0);
    }

    #[test]
    fn service_ewma_converges() {
        let c = SchedCounters::default();
        c.note_service_us(800);
        assert_eq!(c.snapshot().service_us_ewma, 800);
        for _ in 0..64 {
            c.note_service_us(100);
        }
        let v = c.snapshot().service_us_ewma;
        assert!(v >= 100 && v < 200, "ewma drifted to {v}");
    }

    #[test]
    fn merged_quantiles_match_sorted_oracle_over_the_union() {
        // Merging per-cluster snapshots must answer quantiles exactly
        // as one histogram over the union of samples would: the
        // bucket-wise sum is lossless, so the only rounding is the
        // shared bucket-upper resolution — never an edge bias
        // introduced by the merge itself.
        let per_cluster: Vec<LatencyHistogram> =
            (0..3).map(|_| LatencyHistogram::default()).collect();
        let mut union: Vec<u64> = Vec::new();
        for i in 0..900u64 {
            let v = (i * 7919) % 100_000; // crosses many bucket edges
            per_cluster[(i % 3) as usize].record(v);
            union.push(v);
        }
        // exact powers of two sit on bucket edges — the spot where an
        // off-by-one in the upper-bound interpolation would show up
        for v in [1u64, 2, 4, 1024, 65_536] {
            per_cluster[0].record(v);
            union.push(v);
        }

        let mut merged = per_cluster[0].snapshot();
        for h in &per_cluster[1..] {
            merged.merge(&h.snapshot());
        }
        union.sort_unstable();
        assert_eq!(merged.count(), union.len() as u64);
        assert_eq!(merged.sum, union.iter().sum::<u64>());

        for q in [0.0, 0.25, 0.5, 0.9, 0.99, 0.999, 1.0] {
            let rank = ((q * union.len() as f64).ceil() as usize)
                .clamp(1, union.len());
            let oracle = union[rank - 1];
            let expect = LatencyHistogram::bucket_upper(
                LatencyHistogram::bucket_index(oracle),
            );
            let got = merged.quantile(q);
            assert_eq!(got, expect, "q={q}: merged={got} oracle bucket={expect}");
            assert!(got >= oracle, "q={q}: {got} under-reports oracle {oracle}");
        }
    }

    #[test]
    fn prometheus_text_renders_counters_and_histograms() {
        let c = SchedCounters::new(2);
        c.submitted.fetch_add(7, Ordering::Relaxed);
        c.completed.fetch_add(6, Ordering::Relaxed);
        c.note_latency_us("gemm", 0, 100);
        c.note_latency_us("gemm", 0, 3_000);
        c.note_latency_us("dot", 1, 50);
        c.cluster(1).unwrap().inflight.fetch_add(2, Ordering::Relaxed);
        let text = prometheus_text(&c.snapshot());

        assert!(text.contains("# TYPE hero_jobs_submitted_total counter"));
        assert!(text.contains("hero_jobs_submitted_total 7"));
        assert!(text.contains("hero_cluster_inflight{cluster=\"1\"} 2"));
        assert!(text.contains("hero_span_us_total{stage=\"execute\"} 0"));
        assert!(text.contains("# TYPE hero_kernel_hits_total counter"));
        assert!(text.contains("hero_kernel_hits_total 0"));
        assert!(text.contains("# TYPE hero_kernel_entries gauge"));
        assert!(text.contains("# TYPE hero_dags_total counter"));
        assert!(text.contains("hero_dag_nodes_total 0"));
        assert!(text.contains("hero_dag_bytes_elided_total 0"));
        assert!(text.contains("hero_dag_fused_requests_total 0"));

        // histogram series: terminal +Inf bucket equals _count, _sum is
        // the exact sample sum
        assert!(text.contains("hero_request_latency_us_bucket{op=\"gemm\",le=\"+Inf\"} 2"));
        assert!(text.contains("hero_request_latency_us_sum{op=\"gemm\"} 3100"));
        assert!(text.contains("hero_request_latency_us_count{op=\"gemm\"} 2"));
        assert!(text.contains("hero_cluster_latency_us_count{cluster=\"1\"} 1"));

        // buckets are cumulative (monotone non-decreasing)
        let mut prev = 0u64;
        let mut seen = 0usize;
        for line in text
            .lines()
            .filter(|l| l.starts_with("hero_request_latency_us_bucket{op=\"gemm\""))
        {
            let v: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(v >= prev, "cumulative counts regressed: {line}");
            prev = v;
            seen += 1;
        }
        assert_eq!(seen, HIST_BUCKETS);
        assert_eq!(prev, 2);

        // exposition hygiene: no empty lines, every line is a comment
        // or `name[{labels}] value`
        for line in text.lines() {
            assert!(!line.trim().is_empty());
            assert!(
                line.starts_with('#') || line.split(' ').count() == 2,
                "malformed line: {line}"
            );
        }
    }
}
