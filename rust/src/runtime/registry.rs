//! Executable registry: manifest entry -> compiled PJRT executable.
//!
//! Compilation happens once per artifact (lazily, or eagerly via
//! [`ArtifactRegistry::warm_up`]); execution is the request-path hot
//! call, so the registry also tracks wall-clock spent inside PJRT for
//! the perf pass.

use std::collections::HashMap;
use std::path::Path;
use std::time::Instant;

use xla::{Literal, PjRtClient, PjRtLoadedExecutable};

use crate::error::{Error, Result};

use super::manifest::Manifest;

/// Wall-clock counters (host-side, not virtual time).
#[derive(Debug, Default, Clone, Copy)]
pub struct RegistryStats {
    pub compiles: u64,
    pub compile_wall_us: u64,
    pub execs: u64,
    pub exec_wall_us: u64,
}

/// The registry.
pub struct ArtifactRegistry {
    client: PjRtClient,
    manifest: Manifest,
    cache: HashMap<String, PjRtLoadedExecutable>,
    stats: RegistryStats,
}

impl std::fmt::Debug for ArtifactRegistry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ArtifactRegistry")
            .field("artifacts", &self.manifest.entries.len())
            .field("compiled", &self.cache.len())
            .field("stats", &self.stats)
            .finish()
    }
}

impl ArtifactRegistry {
    /// Open the registry over an artifacts directory (must contain
    /// `manifest.json`; see `make artifacts`).
    pub fn open(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = PjRtClient::cpu()?;
        Ok(ArtifactRegistry {
            client,
            manifest,
            cache: HashMap::new(),
            stats: RegistryStats::default(),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn stats(&self) -> RegistryStats {
        self.stats
    }

    /// Compile one artifact if not already resident.
    fn ensure_compiled(&mut self, name: &str) -> Result<()> {
        if self.cache.contains_key(name) {
            return Ok(());
        }
        let entry = self.manifest.entry(name)?.clone();
        let path = self.manifest.path_of(&entry);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| {
                Error::Runtime(format!("non-utf8 path {}", path.display()))
            })?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        self.stats.compiles += 1;
        self.stats.compile_wall_us += t0.elapsed().as_micros() as u64;
        self.cache.insert(name.to_string(), exe);
        Ok(())
    }

    /// Eagerly compile every artifact (used by `hero-blas serve` so the
    /// first request doesn't pay compile latency).
    pub fn warm_up(&mut self) -> Result<()> {
        let names: Vec<String> =
            self.manifest.entries.iter().map(|e| e.name.clone()).collect();
        for n in names {
            self.ensure_compiled(&n)?;
        }
        Ok(())
    }

    /// Execute an artifact. All our artifacts return a 1-tuple (lowered
    /// with `return_tuple=True`), unwrapped here.
    pub fn exec(&mut self, name: &str, args: &[Literal]) -> Result<Literal> {
        self.ensure_compiled(name)?;
        let entry = self.manifest.entry(name)?;
        if args.len() != entry.arg_shapes.len() {
            return Err(Error::Runtime(format!(
                "{name}: {} args given, artifact takes {}",
                args.len(),
                entry.arg_shapes.len()
            )));
        }
        let exe = self.cache.get(name).expect("ensured above");
        let t0 = Instant::now();
        let result = exe.execute::<Literal>(args)?[0][0].to_literal_sync()?;
        self.stats.execs += 1;
        self.stats.exec_wall_us += t0.elapsed().as_micros() as u64;
        Ok(result.to_tuple1()?)
    }

    /// Number of compiled (resident) executables.
    pub fn resident(&self) -> usize {
        self.cache.len()
    }
}

// NOTE: integration tests for the registry live in rust/tests/ — they
// need real artifacts produced by `make artifacts`.
