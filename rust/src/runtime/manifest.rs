//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the Rust runtime.

use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::util::json_lite::Json;

/// One AOT artifact as described by `manifest.json`.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub name: String,
    /// File name relative to the artifacts directory.
    pub file: String,
    /// Operation family: "gemm", "gemm_tile_accum", "gemv", "axpy", "dot".
    pub op: String,
    /// "f32" or "f64".
    pub dtype: String,
    /// Problem dims; semantics depend on `op` (m/n/k for gemm-family).
    pub m: Option<usize>,
    pub n: Option<usize>,
    pub k: Option<usize>,
    /// Argument shapes in call order (e.g. [[128,128],[128,128],[1]]).
    pub arg_shapes: Vec<Vec<usize>>,
    pub arg_dtypes: Vec<String>,
}

impl ArtifactEntry {
    fn from_json(j: &Json) -> Result<Self> {
        let shapes = j
            .req("arg_shapes")?
            .as_arr()
            .ok_or_else(|| Error::Config("manifest: arg_shapes not an array".into()))?
            .iter()
            .map(|s| {
                s.as_arr()
                    .ok_or_else(|| Error::Config("manifest: shape not an array".into()))
                    .map(|dims| {
                        dims.iter()
                            .filter_map(|d| d.as_u64().map(|u| u as usize))
                            .collect::<Vec<_>>()
                    })
            })
            .collect::<Result<Vec<_>>>()?;
        let dtypes = j
            .req("arg_dtypes")?
            .as_arr()
            .ok_or_else(|| Error::Config("manifest: arg_dtypes not an array".into()))?
            .iter()
            .map(|d| {
                d.as_str()
                    .map(str::to_string)
                    .ok_or_else(|| Error::Config("manifest: dtype not a string".into()))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(ArtifactEntry {
            name: j.req_str("name")?.to_string(),
            file: j.req_str("file")?.to_string(),
            op: j.req_str("op")?.to_string(),
            dtype: j.req_str("dtype")?.to_string(),
            m: j.get("m").and_then(|v| v.as_u64()).map(|v| v as usize),
            n: j.get("n").and_then(|v| v.as_u64()).map(|v| v as usize),
            k: j.get("k").and_then(|v| v.as_u64()).map(|v| v as usize),
            arg_shapes: shapes,
            arg_dtypes: dtypes,
        })
    }
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Device tile geometry (must agree with the Rust SPM tiling loop).
    pub tile_m: usize,
    pub tile_n: usize,
    pub tile_k: usize,
    pub entries: Vec<ArtifactEntry>,
    pub source_hash: String,
    /// Directory the manifest was loaded from.
    pub dir: PathBuf,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Config(format!(
                "{}: {e} — run `make artifacts` first",
                path.display()
            ))
        })?;
        let j = Json::parse(&text)?;
        let tile = j.req("tile")?;
        let entries = j
            .req("entries")?
            .as_arr()
            .ok_or_else(|| Error::Config("manifest: entries not an array".into()))?
            .iter()
            .map(ArtifactEntry::from_json)
            .collect::<Result<Vec<_>>>()?;
        if entries.is_empty() {
            return Err(Error::Config("manifest: no entries".into()));
        }
        Ok(Manifest {
            tile_m: tile.req_u64("m")? as usize,
            tile_n: tile.req_u64("n")? as usize,
            tile_k: tile.req_u64("k")? as usize,
            entries,
            source_hash: j.req_str("source_hash")?.to_string(),
            dir: dir.to_path_buf(),
        })
    }

    /// Find an entry by exact name.
    pub fn entry(&self, name: &str) -> Result<&ArtifactEntry> {
        self.entries.iter().find(|e| e.name == name).ok_or_else(|| {
            Error::Runtime(format!(
                "artifact '{name}' not in manifest (have: {})",
                self.entries
                    .iter()
                    .map(|e| e.name.as_str())
                    .collect::<Vec<_>>()
                    .join(", ")
            ))
        })
    }

    /// Find the fixed-size artifact for (op, dtype, n), if any.
    pub fn find_sized(&self, op: &str, dtype: &str, n: usize) -> Option<&ArtifactEntry> {
        self.entries
            .iter()
            .find(|e| e.op == op && e.dtype == dtype && e.n == Some(n))
    }

    /// Full path of an entry's HLO file.
    pub fn path_of(&self, e: &ArtifactEntry) -> PathBuf {
        self.dir.join(&e.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), body).unwrap();
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("hero_blas_manifest_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    const MINI: &str = r#"{
      "tile": {"m": 64, "n": 64, "k": 64},
      "entries": [
        {"name": "gemm_f64_n128", "file": "gemm_f64_n128.hlo.txt",
         "op": "gemm", "dtype": "f64", "m": 128, "n": 128, "k": 128,
         "arg_shapes": [[128,128],[128,128],[128,128],[1],[1]],
         "arg_dtypes": ["float64","float64","float64","float64","float64"]},
        {"name": "gemm_tile_accum_f64", "file": "t.hlo.txt",
         "op": "gemm_tile_accum", "dtype": "f64", "m": 64, "n": 64, "k": 64,
         "arg_shapes": [[64,64],[64,64],[64,64]],
         "arg_dtypes": ["float64","float64","float64"]}
      ],
      "source_hash": "deadbeefcafebabe"
    }"#;

    #[test]
    fn loads_and_queries() {
        let dir = tmpdir("ok");
        write_manifest(&dir, MINI);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!((m.tile_m, m.tile_n, m.tile_k), (64, 64, 64));
        assert_eq!(m.entries.len(), 2);
        let e = m.entry("gemm_f64_n128").unwrap();
        assert_eq!(e.arg_shapes.len(), 5);
        assert_eq!(e.arg_shapes[3], vec![1]);
        assert!(m.find_sized("gemm", "f64", 128).is_some());
        assert!(m.find_sized("gemm", "f64", 999).is_none());
        assert!(m.find_sized("gemm", "f32", 128).is_none());
        assert!(m.path_of(e).ends_with("gemm_f64_n128.hlo.txt"));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn unknown_entry_lists_available() {
        let dir = tmpdir("unknown");
        write_manifest(&dir, MINI);
        let m = Manifest::load(&dir).unwrap();
        let err = m.entry("nope").unwrap_err().to_string();
        assert!(err.contains("gemm_f64_n128"), "{err}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn missing_manifest_mentions_make_artifacts() {
        let dir = tmpdir("missing");
        std::fs::create_dir_all(&dir).unwrap();
        let err = Manifest::load(&dir).unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "{err}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn empty_entries_rejected() {
        let dir = tmpdir("empty");
        write_manifest(
            &dir,
            r#"{"tile": {"m":64,"n":64,"k":64}, "entries": [], "source_hash": "x"}"#,
        );
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
