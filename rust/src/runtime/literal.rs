//! NdArray-side <-> [`xla::Literal`] conversions.
//!
//! The xla crate builds literals from flat slices (`vec1`) and reshapes;
//! all our artifacts take row-major f32/f64 tensors plus shape-(1,)
//! coefficient arrays (rank-0 scalars are awkward through the C API).

use xla::{ArrayElement, Literal, NativeType};

use crate::error::Result;

/// 1-D literal from a flat slice.
pub fn lit_1d<T: NativeType>(data: &[T]) -> Literal {
    Literal::vec1(data)
}

/// Row-major 2-D literal.
pub fn lit_2d<T: NativeType>(data: &[T], rows: usize, cols: usize) -> Result<Literal> {
    assert_eq!(data.len(), rows * cols, "lit_2d: data/shape mismatch");
    Ok(Literal::vec1(data).reshape(&[rows as i64, cols as i64])?)
}

/// Shape-(1,) coefficient literal (alpha/beta).
pub fn lit_scalar1<T: NativeType>(v: T) -> Literal {
    Literal::vec1(&[v])
}

/// Flatten a literal back to f64s.
pub fn to_vec_f64(lit: &Literal) -> Result<Vec<f64>> {
    Ok(lit.to_vec::<f64>()?)
}

/// Flatten a literal back to f32s.
pub fn to_vec_f32(lit: &Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// Element count from a literal's shape.
pub fn element_count(lit: &Literal) -> usize {
    lit.element_count()
}

/// Sanity helper for tests: dtype marker of T as the manifest spells it.
pub fn dtype_name<T: ArrayElement>() -> &'static str {
    match std::any::type_name::<T>() {
        "f32" => "f32",
        "f64" => "f64",
        other => other,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_f64_2d() {
        let data: Vec<f64> = (0..12).map(|i| i as f64).collect();
        let lit = lit_2d(&data, 3, 4).unwrap();
        assert_eq!(element_count(&lit), 12);
        assert_eq!(to_vec_f64(&lit).unwrap(), data);
    }

    #[test]
    fn roundtrip_f32_1d() {
        let data: Vec<f32> = vec![1.5, -2.5, 3.25];
        let lit = lit_1d(&data);
        assert_eq!(to_vec_f32(&lit).unwrap(), data);
    }

    #[test]
    fn scalar1_is_len1() {
        let lit = lit_scalar1(2.5f64);
        assert_eq!(to_vec_f64(&lit).unwrap(), vec![2.5]);
    }

    #[test]
    #[should_panic(expected = "data/shape mismatch")]
    fn shape_mismatch_panics() {
        let _ = lit_2d(&[1.0f64; 5], 2, 3);
    }
}
