//! PJRT runtime: load and execute the AOT artifacts from Rust.
//!
//! This is the only place the stack touches XLA.  `make artifacts` (the
//! one-time Python compile path) produces `artifacts/*.hlo.txt` plus a
//! `manifest.json`; [`ArtifactRegistry`] loads the manifest, compiles
//! each HLO module on the PJRT CPU client on first use, and executes it
//! with [`xla::Literal`] arguments.  Python never runs at request time.
//!
//! Interchange is HLO **text** — xla_extension 0.5.1 rejects jax>=0.5's
//! serialized protos (64-bit instruction ids); the text parser reassigns
//! ids (see `python/compile/aot.py` and /opt/xla-example/README.md).

pub mod literal;
pub mod manifest;
pub mod registry;

pub use literal::{lit_1d, lit_2d, lit_scalar1, to_vec_f32, to_vec_f64};
pub use manifest::{ArtifactEntry, Manifest};
pub use registry::{ArtifactRegistry, RegistryStats};
