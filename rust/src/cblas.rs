//! CBLAS-compatible C ABI — the literal linking surface of the paper.
//!
//! The paper's trick is that NumPy calls `cblas_dgemm` and never knows a
//! PMCA is behind it.  This module exports the same symbols from our
//! library, backed by a per-thread [`HeroBlas`] session, so an actual
//! `numpy` build (or any CBLAS consumer) could `dlopen` the cdylib and
//! get the simulated heterogeneous stack.
//!
//! Scope: the subset NumPy's `dot`/`matmul` actually uses (dgemm/sgemm,
//! dgemv, daxpy, ddot, dnrm2, dscal, dasum, idamax), with proper
//! `lda`/`incx` handling — including negative increments (walking the
//! vector backwards from the end, the reference convention for the
//! two-vector routines; the single-vector routines nrm2/asum/idamax
//! deliberately apply the same rule instead of netlib's silent
//! return-0-for-`incx <= 0`) and column-major dgemm/sgemm/dgemv via the
//! transpose identity (the same bytes read row-major ARE the
//! transposes, so col-major calls swap operand roles and recurse; no
//! copy, no silently wrong product).  Unsupported layout/transpose
//! values produce an explicit error and leave outputs untouched.
//! Sessions are per-thread (`CblasInit` per thread) because PJRT client
//! handles are not `Send`.

use std::cell::RefCell;
use std::ffi::CStr;
use std::os::raw::{c_char, c_double, c_float, c_int};

use crate::blas::{DispatchPolicy, HeroBlas, Transpose};
use crate::config::{DispatchMode, PlatformConfig};
use crate::error::Result;

thread_local! {
    static SESSION: RefCell<Option<HeroBlas>> = const { RefCell::new(None) };
}

/// CBLAS enums (values fixed by the CBLAS standard).
pub const CBLAS_ROW_MAJOR: c_int = 101;
pub const CBLAS_COL_MAJOR: c_int = 102;
pub const CBLAS_NO_TRANS: c_int = 111;
pub const CBLAS_TRANS: c_int = 112;
/// Conjugate transpose — identical to plain transpose on real data.
pub const CBLAS_CONJ_TRANS: c_int = 113;

fn trans_of(v: c_int) -> Option<Transpose> {
    match v {
        CBLAS_NO_TRANS => Some(Transpose::No),
        CBLAS_TRANS | CBLAS_CONJ_TRANS => Some(Transpose::Yes),
        _ => None,
    }
}

/// Initialize this thread's session.  `artifacts` may be NULL to use the
/// `HERO_BLAS_ARTIFACTS`/walk-up discovery; mode: 0=auto, 1=host-only,
/// 2=device-only, 3=zero-copy.  Returns 0 on success.
///
/// # Safety
/// `artifacts`, if non-NULL, must point to a valid NUL-terminated string.
#[no_mangle]
pub unsafe extern "C" fn hero_blas_init(artifacts: *const c_char, mode: c_int) -> c_int {
    let mode = match mode {
        0 => DispatchMode::Auto,
        1 => DispatchMode::HostOnly,
        2 => DispatchMode::DeviceOnly,
        3 => DispatchMode::DeviceZeroCopy,
        _ => return -1,
    };
    let build = || -> Result<HeroBlas> {
        let dir = if artifacts.is_null() {
            crate::find_artifacts_dir()?
        } else {
            std::path::PathBuf::from(
                CStr::from_ptr(artifacts).to_string_lossy().into_owned(),
            )
        };
        HeroBlas::new(PlatformConfig::default(), &dir, DispatchPolicy::with_mode(mode))
    };
    match build() {
        Ok(s) => {
            SESSION.with(|cell| *cell.borrow_mut() = Some(s));
            0
        }
        Err(e) => {
            eprintln!("hero_blas_init: {e}");
            -2
        }
    }
}

/// Tear down this thread's session. Idempotent.
#[no_mangle]
pub extern "C" fn hero_blas_shutdown() {
    SESSION.with(|cell| *cell.borrow_mut() = None);
}

fn with_session<R>(f: impl FnOnce(&mut HeroBlas) -> Result<R>) -> Option<R> {
    SESSION.with(|cell| {
        let mut guard = cell.borrow_mut();
        match guard.as_mut() {
            Some(s) => match f(s) {
                Ok(r) => Some(r),
                Err(e) => {
                    eprintln!("hero-blas cblas: {e}");
                    None
                }
            },
            None => {
                eprintln!("hero-blas cblas: call hero_blas_init first");
                None
            }
        }
    })
}

/// Copy a possibly-padded (lda > cols) row-major matrix into a dense one.
unsafe fn gather(ptr: *const c_double, rows: usize, cols: usize, lda: usize) -> Vec<f64> {
    let mut out = Vec::with_capacity(rows * cols);
    for r in 0..rows {
        out.extend_from_slice(std::slice::from_raw_parts(ptr.add(r * lda), cols));
    }
    out
}

unsafe fn scatter(data: &[f64], ptr: *mut c_double, rows: usize, cols: usize, lda: usize) {
    for r in 0..rows {
        std::slice::from_raw_parts_mut(ptr.add(r * lda), cols)
            .copy_from_slice(&data[r * cols..(r + 1) * cols]);
    }
}

/// Element offset of logical element `i` in an `n`-element CBLAS strided
/// vector.  Reference CBLAS defines a negative increment as walking the
/// vector *backwards from the end*: element i lives at
/// `(i - (n-1)) * |incx|` relative to the pointer, i.e. the pointer
/// addresses the LAST logical element and earlier elements sit at higher
/// addresses.  (The old `i * incx` indexed before the buffer — wrong
/// values at best, out-of-bounds reads at worst.)
fn stride_offset(i: usize, n: usize, inc: isize) -> isize {
    if inc >= 0 {
        i as isize * inc
    } else {
        (i as isize - (n as isize - 1)) * inc
    }
}

/// Strided vector gather (CBLAS `incx`, negative = backwards from the end).
unsafe fn gather_vec(ptr: *const c_double, n: usize, inc: isize) -> Vec<f64> {
    (0..n).map(|i| *ptr.offset(stride_offset(i, n, inc))).collect()
}

unsafe fn scatter_vec(data: &[f64], ptr: *mut c_double, inc: isize) {
    let n = data.len();
    for (i, v) in data.iter().enumerate() {
        *ptr.offset(stride_offset(i, n, inc)) = *v;
    }
}

/// cblas_dgemm — row-major natively; column-major via the transpose
/// identity `C^T = op(B)^T op(A)^T` (the same bytes read as row-major
/// ARE the transposes, so the col-major call swaps the operand roles and
/// the output dims and recurses into the row-major path — no copies, no
/// silently wrong product).  Unsupported layout/transpose values get an
/// explicit error and leave C untouched.
///
/// # Safety
/// Pointers must reference matrices of the advertised dimensions/lda.
#[no_mangle]
#[allow(clippy::too_many_arguments)]
pub unsafe extern "C" fn cblas_dgemm(
    order: c_int,
    trans_a: c_int,
    trans_b: c_int,
    m: c_int,
    n: c_int,
    k: c_int,
    alpha: c_double,
    a: *const c_double,
    lda: c_int,
    b: *const c_double,
    ldb: c_int,
    beta: c_double,
    c: *mut c_double,
    ldc: c_int,
) {
    if order == CBLAS_COL_MAJOR {
        // col-major C (m x n, ldc) read row-major is C^T (n x m, ldc):
        // compute C^T = alpha * op(B)^T @ op(A)^T + beta * C^T by
        // swapping the operands and flipping the output dims; each
        // operand keeps its own transpose flag (its row-major view is
        // already the transpose)
        return cblas_dgemm(
            CBLAS_ROW_MAJOR, trans_b, trans_a, n, m, k, alpha, b, ldb, a, lda,
            beta, c, ldc,
        );
    }
    if order != CBLAS_ROW_MAJOR {
        eprintln!("cblas_dgemm: unsupported layout {order} (expected 101/102)");
        return;
    }
    let (Some(ta), Some(tb)) = (trans_of(trans_a), trans_of(trans_b)) else {
        eprintln!(
            "cblas_dgemm: unsupported transpose flags ({trans_a}, {trans_b})"
        );
        return;
    };
    if m < 0 || n < 0 || k < 0 {
        eprintln!("cblas_dgemm: negative dimension");
        return;
    }
    if m == 0 || n == 0 {
        return;
    }
    let (m, n, k) = (m as usize, n as usize, k as usize);
    // stored dims of A and B (row-major)
    let a_dims = if ta.is_trans() { (k, m) } else { (m, k) };
    let b_dims = if tb.is_trans() { (n, k) } else { (k, n) };
    let av = gather(a, a_dims.0, a_dims.1, lda as usize);
    let bv = gather(b, b_dims.0, b_dims.1, ldb as usize);
    let mut cv = gather(c, m, n, ldc as usize);
    if with_session(|s| {
        s.gemm(ta, tb, alpha, &av, a_dims, &bv, b_dims, beta, &mut cv, (m, n))
    })
    .is_some()
    {
        scatter(&cv, c, m, n, ldc as usize);
    }
}

/// cblas_sgemm — row-major natively, column-major via the transpose
/// identity (see [`cblas_dgemm`]).
///
/// # Safety
/// Pointers must reference matrices of the advertised dimensions/lda.
#[no_mangle]
#[allow(clippy::too_many_arguments)]
pub unsafe extern "C" fn cblas_sgemm(
    order: c_int,
    trans_a: c_int,
    trans_b: c_int,
    m: c_int,
    n: c_int,
    k: c_int,
    alpha: c_float,
    a: *const c_float,
    lda: c_int,
    b: *const c_float,
    ldb: c_int,
    beta: c_float,
    c: *mut c_float,
    ldc: c_int,
) {
    if order == CBLAS_COL_MAJOR {
        return cblas_sgemm(
            CBLAS_ROW_MAJOR, trans_b, trans_a, n, m, k, alpha, b, ldb, a, lda,
            beta, c, ldc,
        );
    }
    if order != CBLAS_ROW_MAJOR {
        eprintln!("cblas_sgemm: unsupported layout {order} (expected 101/102)");
        return;
    }
    let (Some(ta), Some(tb)) = (trans_of(trans_a), trans_of(trans_b)) else {
        eprintln!(
            "cblas_sgemm: unsupported transpose flags ({trans_a}, {trans_b})"
        );
        return;
    };
    if m < 0 || n < 0 || k < 0 {
        eprintln!("cblas_sgemm: negative dimension");
        return;
    }
    if m == 0 || n == 0 {
        return;
    }
    let (m, n, k) = (m as usize, n as usize, k as usize);
    let a_dims = if ta.is_trans() { (k, m) } else { (m, k) };
    let b_dims = if tb.is_trans() { (n, k) } else { (k, n) };
    let gat = |p: *const c_float, rows: usize, cols: usize, ld: usize| -> Vec<f32> {
        let mut out = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            out.extend_from_slice(std::slice::from_raw_parts(p.add(r * ld), cols));
        }
        out
    };
    let av = gat(a, a_dims.0, a_dims.1, lda as usize);
    let bv = gat(b, b_dims.0, b_dims.1, ldb as usize);
    let mut cv = gat(c, m, n, ldc as usize);
    if with_session(|s| {
        s.gemm(ta, tb, alpha, &av, a_dims, &bv, b_dims, beta, &mut cv, (m, n))
    })
    .is_some()
    {
        for r in 0..m {
            std::slice::from_raw_parts_mut(c.add(r * ldc as usize), n)
                .copy_from_slice(&cv[r * n..(r + 1) * n]);
        }
    }
}

/// cblas_dgemv (row-major only).
///
/// # Safety
/// Pointers must reference buffers of the advertised dimensions/strides.
#[no_mangle]
#[allow(clippy::too_many_arguments)]
pub unsafe extern "C" fn cblas_dgemv(
    order: c_int,
    trans: c_int,
    m: c_int,
    n: c_int,
    alpha: c_double,
    a: *const c_double,
    lda: c_int,
    x: *const c_double,
    incx: c_int,
    beta: c_double,
    y: *mut c_double,
    incy: c_int,
) {
    if order == CBLAS_COL_MAJOR {
        // the col-major (m x n, lda) matrix read row-major is its
        // transpose (n x m, lda): flip the transpose flag and swap the
        // dims — x/y lengths follow the op shape and stay put
        let flipped = match trans_of(trans) {
            Some(Transpose::No) => CBLAS_TRANS,
            Some(Transpose::Yes) => CBLAS_NO_TRANS,
            None => {
                eprintln!("cblas_dgemv: unsupported transpose flag {trans}");
                return;
            }
        };
        return cblas_dgemv(
            CBLAS_ROW_MAJOR, flipped, n, m, alpha, a, lda, x, incx, beta, y,
            incy,
        );
    }
    if order != CBLAS_ROW_MAJOR {
        eprintln!("cblas_dgemv: unsupported layout {order} (expected 101/102)");
        return;
    }
    let Some(t) = trans_of(trans) else {
        eprintln!("cblas_dgemv: unsupported transpose flag {trans}");
        return;
    };
    if m <= 0 || n <= 0 {
        if m < 0 || n < 0 {
            eprintln!("cblas_dgemv: negative dimension");
        }
        return;
    }
    let (m, n) = (m as usize, n as usize);
    let (xlen, ylen) = if t.is_trans() { (m, n) } else { (n, m) };
    let av = gather(a, m, n, lda as usize);
    let xv = gather_vec(x, xlen, incx as isize);
    let mut yv = gather_vec(y, ylen, incy as isize);
    if with_session(|s| s.gemv(t, alpha, &av, (m, n), &xv, beta, &mut yv)).is_some() {
        scatter_vec(&yv, y, incy as isize);
    }
}

/// cblas_daxpy.
///
/// # Safety
/// Pointers must reference `n`-element strided vectors.
#[no_mangle]
pub unsafe extern "C" fn cblas_daxpy(
    n: c_int,
    alpha: c_double,
    x: *const c_double,
    incx: c_int,
    y: *mut c_double,
    incy: c_int,
) {
    if n <= 0 {
        return;
    }
    let xv = gather_vec(x, n as usize, incx as isize);
    let mut yv = gather_vec(y, n as usize, incy as isize);
    if with_session(|s| s.axpy(alpha, &xv, &mut yv)).is_some() {
        scatter_vec(&yv, y, incy as isize);
    }
}

/// cblas_ddot.
///
/// # Safety
/// Pointers must reference `n`-element strided vectors.
#[no_mangle]
pub unsafe extern "C" fn cblas_ddot(
    n: c_int,
    x: *const c_double,
    incx: c_int,
    y: *const c_double,
    incy: c_int,
) -> c_double {
    if n <= 0 {
        return 0.0;
    }
    let xv = gather_vec(x, n as usize, incx as isize);
    let yv = gather_vec(y, n as usize, incy as isize);
    with_session(|s| s.dot(&xv, &yv)).unwrap_or(f64::NAN)
}

/// cblas_dnrm2.
///
/// # Safety
/// `x` must reference an `n`-element strided vector.
#[no_mangle]
pub unsafe extern "C" fn cblas_dnrm2(n: c_int, x: *const c_double, incx: c_int) -> c_double {
    if n <= 0 {
        return 0.0;
    }
    let xv = gather_vec(x, n as usize, incx as isize);
    with_session(|s| s.nrm2(&xv)).unwrap_or(f64::NAN)
}

/// cblas_dasum.
///
/// # Safety
/// `x` must reference an `n`-element strided vector.
#[no_mangle]
pub unsafe extern "C" fn cblas_dasum(n: c_int, x: *const c_double, incx: c_int) -> c_double {
    if n <= 0 {
        return 0.0;
    }
    let xv = gather_vec(x, n as usize, incx as isize);
    with_session(|s| s.asum(&xv)).unwrap_or(f64::NAN)
}

/// cblas_dscal.
///
/// # Safety
/// `x` must reference an `n`-element strided vector.
#[no_mangle]
pub unsafe extern "C" fn cblas_dscal(n: c_int, alpha: c_double, x: *mut c_double, incx: c_int) {
    // reference DSCAL is a no-op for non-positive n or stride
    if n <= 0 || incx <= 0 {
        return;
    }
    let mut xv = gather_vec(x, n as usize, incx as isize);
    if with_session(|s| s.scal(alpha, &mut xv)).is_some() {
        scatter_vec(&xv, x, incx as isize);
    }
}

/// cblas_idamax (returns 0 for n <= 0, like reference CBLAS).
///
/// # Safety
/// `x` must reference an `n`-element strided vector.
#[no_mangle]
pub unsafe extern "C" fn cblas_idamax(n: c_int, x: *const c_double, incx: c_int) -> c_int {
    if n <= 0 {
        return 0;
    }
    // Negative incx: the gather walks backwards from the end (the
    // two-vector routines' convention) and the returned index is in that
    // traversal order.  Deliberate deviation from netlib, whose
    // single-vector routines (idamax/nrm2/asum) early-return 0 for
    // incx <= 0 — discarding the caller's data silently; here a negative
    // stride means what it means everywhere else in the API.
    let xv = gather_vec(x, n as usize, incx as isize);
    with_session(|s| s.iamax(&xv)).map(|i| i as c_int).unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stride_offsets_walk_backwards_for_negative_increments() {
        // positive strides index forward from the pointer
        assert_eq!(stride_offset(0, 4, 2), 0);
        assert_eq!(stride_offset(3, 4, 2), 6);
        // negative strides: logical element 0 is the FARTHEST stored
        // element ((n-1)*|inc|), the last logical element sits at the
        // pointer — reference CBLAS' backwards walk
        assert_eq!(stride_offset(0, 4, -2), 6);
        assert_eq!(stride_offset(1, 4, -2), 4);
        assert_eq!(stride_offset(3, 4, -2), 0);
        // unit negative stride is a plain reversal
        assert_eq!(stride_offset(0, 3, -1), 2);
        assert_eq!(stride_offset(2, 3, -1), 0);
        // every offset stays inside [0, (n-1)*|inc|]
        for n in 1..6usize {
            for inc in [-3isize, -1, 1, 3] {
                for i in 0..n {
                    let off = stride_offset(i, n, inc);
                    assert!(off >= 0, "negative offset reads before the buffer");
                    assert!(off <= (n as isize - 1) * inc.abs());
                }
            }
        }
    }

    #[test]
    fn gather_scatter_roundtrip_negative_strides() {
        let src = [10.0, 11.0, 12.0, 13.0, 14.0, 15.0, 16.0];
        // n=4, inc=-2: logical order walks 16, 14, 12, 10
        let got = unsafe { gather_vec(src.as_ptr(), 4, -2) };
        assert_eq!(got, vec![16.0, 14.0, 12.0, 10.0]);
        // scatter inverts the gather: same slots, same logical order
        let mut dst = [0.0f64; 7];
        unsafe { scatter_vec(&got, dst.as_mut_ptr(), -2) };
        assert_eq!(dst, [10.0, 0.0, 12.0, 0.0, 14.0, 0.0, 16.0]);
        // inc=1 stays the identity
        let got = unsafe { gather_vec(src.as_ptr(), 3, 1) };
        assert_eq!(got, vec![10.0, 11.0, 12.0]);
    }

    #[test]
    fn conj_trans_maps_to_plain_transpose() {
        assert_eq!(trans_of(CBLAS_NO_TRANS), Some(Transpose::No));
        assert_eq!(trans_of(CBLAS_TRANS), Some(Transpose::Yes));
        assert_eq!(trans_of(CBLAS_CONJ_TRANS), Some(Transpose::Yes));
        assert_eq!(trans_of(999), None);
    }
}
