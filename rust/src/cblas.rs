//! CBLAS-compatible C ABI — the literal linking surface of the paper.
//!
//! The paper's trick is that NumPy calls `cblas_dgemm` and never knows a
//! PMCA is behind it.  This module exports the same symbols from our
//! library, backed by a per-thread [`HeroBlas`] session, so an actual
//! `numpy` build (or any CBLAS consumer) could `dlopen` the cdylib and
//! get the simulated heterogeneous stack.
//!
//! Scope: the row-major subset NumPy's `dot`/`matmul` actually uses
//! (dgemm/sgemm, dgemv, daxpy, ddot, dnrm2, dscal, dasum, idamax), with
//! proper `lda`/`incx` handling.  Sessions are per-thread (`CblasInit`
//! per thread) because PJRT client handles are not `Send`.

use std::cell::RefCell;
use std::ffi::CStr;
use std::os::raw::{c_char, c_double, c_float, c_int};

use crate::blas::{DispatchPolicy, HeroBlas, Transpose};
use crate::config::{DispatchMode, PlatformConfig};
use crate::error::Result;

thread_local! {
    static SESSION: RefCell<Option<HeroBlas>> = const { RefCell::new(None) };
}

/// CBLAS enums (values fixed by the CBLAS standard).
pub const CBLAS_ROW_MAJOR: c_int = 101;
pub const CBLAS_COL_MAJOR: c_int = 102;
pub const CBLAS_NO_TRANS: c_int = 111;
pub const CBLAS_TRANS: c_int = 112;

fn trans_of(v: c_int) -> Option<Transpose> {
    match v {
        CBLAS_NO_TRANS => Some(Transpose::No),
        CBLAS_TRANS => Some(Transpose::Yes),
        _ => None,
    }
}

/// Initialize this thread's session.  `artifacts` may be NULL to use the
/// `HERO_BLAS_ARTIFACTS`/walk-up discovery; mode: 0=auto, 1=host-only,
/// 2=device-only, 3=zero-copy.  Returns 0 on success.
///
/// # Safety
/// `artifacts`, if non-NULL, must point to a valid NUL-terminated string.
#[no_mangle]
pub unsafe extern "C" fn hero_blas_init(artifacts: *const c_char, mode: c_int) -> c_int {
    let mode = match mode {
        0 => DispatchMode::Auto,
        1 => DispatchMode::HostOnly,
        2 => DispatchMode::DeviceOnly,
        3 => DispatchMode::DeviceZeroCopy,
        _ => return -1,
    };
    let build = || -> Result<HeroBlas> {
        let dir = if artifacts.is_null() {
            crate::find_artifacts_dir()?
        } else {
            std::path::PathBuf::from(
                CStr::from_ptr(artifacts).to_string_lossy().into_owned(),
            )
        };
        HeroBlas::new(PlatformConfig::default(), &dir, DispatchPolicy::with_mode(mode))
    };
    match build() {
        Ok(s) => {
            SESSION.with(|cell| *cell.borrow_mut() = Some(s));
            0
        }
        Err(e) => {
            eprintln!("hero_blas_init: {e}");
            -2
        }
    }
}

/// Tear down this thread's session. Idempotent.
#[no_mangle]
pub extern "C" fn hero_blas_shutdown() {
    SESSION.with(|cell| *cell.borrow_mut() = None);
}

fn with_session<R>(f: impl FnOnce(&mut HeroBlas) -> Result<R>) -> Option<R> {
    SESSION.with(|cell| {
        let mut guard = cell.borrow_mut();
        match guard.as_mut() {
            Some(s) => match f(s) {
                Ok(r) => Some(r),
                Err(e) => {
                    eprintln!("hero-blas cblas: {e}");
                    None
                }
            },
            None => {
                eprintln!("hero-blas cblas: call hero_blas_init first");
                None
            }
        }
    })
}

/// Copy a possibly-padded (lda > cols) row-major matrix into a dense one.
unsafe fn gather(ptr: *const c_double, rows: usize, cols: usize, lda: usize) -> Vec<f64> {
    let mut out = Vec::with_capacity(rows * cols);
    for r in 0..rows {
        out.extend_from_slice(std::slice::from_raw_parts(ptr.add(r * lda), cols));
    }
    out
}

unsafe fn scatter(data: &[f64], ptr: *mut c_double, rows: usize, cols: usize, lda: usize) {
    for r in 0..rows {
        std::slice::from_raw_parts_mut(ptr.add(r * lda), cols)
            .copy_from_slice(&data[r * cols..(r + 1) * cols]);
    }
}

/// Strided vector gather (CBLAS `incx`).
unsafe fn gather_vec(ptr: *const c_double, n: usize, inc: isize) -> Vec<f64> {
    (0..n).map(|i| *ptr.offset(i as isize * inc)).collect()
}

unsafe fn scatter_vec(data: &[f64], ptr: *mut c_double, inc: isize) {
    for (i, v) in data.iter().enumerate() {
        *ptr.offset(i as isize * inc) = *v;
    }
}

/// cblas_dgemm (row-major only — what NumPy uses).
///
/// # Safety
/// Pointers must reference matrices of the advertised dimensions/lda.
#[no_mangle]
#[allow(clippy::too_many_arguments)]
pub unsafe extern "C" fn cblas_dgemm(
    order: c_int,
    trans_a: c_int,
    trans_b: c_int,
    m: c_int,
    n: c_int,
    k: c_int,
    alpha: c_double,
    a: *const c_double,
    lda: c_int,
    b: *const c_double,
    ldb: c_int,
    beta: c_double,
    c: *mut c_double,
    ldc: c_int,
) {
    if order != CBLAS_ROW_MAJOR {
        eprintln!("cblas_dgemm: only row-major supported");
        return;
    }
    let (Some(ta), Some(tb)) = (trans_of(trans_a), trans_of(trans_b)) else {
        eprintln!("cblas_dgemm: bad transpose flag");
        return;
    };
    let (m, n, k) = (m as usize, n as usize, k as usize);
    // stored dims of A and B (row-major)
    let a_dims = if ta.is_trans() { (k, m) } else { (m, k) };
    let b_dims = if tb.is_trans() { (n, k) } else { (k, n) };
    let av = gather(a, a_dims.0, a_dims.1, lda as usize);
    let bv = gather(b, b_dims.0, b_dims.1, ldb as usize);
    let mut cv = gather(c, m, n, ldc as usize);
    if with_session(|s| {
        s.gemm(ta, tb, alpha, &av, a_dims, &bv, b_dims, beta, &mut cv, (m, n))
    })
    .is_some()
    {
        scatter(&cv, c, m, n, ldc as usize);
    }
}

/// cblas_sgemm (row-major only).
///
/// # Safety
/// Pointers must reference matrices of the advertised dimensions/lda.
#[no_mangle]
#[allow(clippy::too_many_arguments)]
pub unsafe extern "C" fn cblas_sgemm(
    order: c_int,
    trans_a: c_int,
    trans_b: c_int,
    m: c_int,
    n: c_int,
    k: c_int,
    alpha: c_float,
    a: *const c_float,
    lda: c_int,
    b: *const c_float,
    ldb: c_int,
    beta: c_float,
    c: *mut c_float,
    ldc: c_int,
) {
    if order != CBLAS_ROW_MAJOR {
        eprintln!("cblas_sgemm: only row-major supported");
        return;
    }
    let (Some(ta), Some(tb)) = (trans_of(trans_a), trans_of(trans_b)) else {
        return;
    };
    let (m, n, k) = (m as usize, n as usize, k as usize);
    let a_dims = if ta.is_trans() { (k, m) } else { (m, k) };
    let b_dims = if tb.is_trans() { (n, k) } else { (k, n) };
    let gat = |p: *const c_float, rows: usize, cols: usize, ld: usize| -> Vec<f32> {
        let mut out = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            out.extend_from_slice(std::slice::from_raw_parts(p.add(r * ld), cols));
        }
        out
    };
    let av = gat(a, a_dims.0, a_dims.1, lda as usize);
    let bv = gat(b, b_dims.0, b_dims.1, ldb as usize);
    let mut cv = gat(c, m, n, ldc as usize);
    if with_session(|s| {
        s.gemm(ta, tb, alpha, &av, a_dims, &bv, b_dims, beta, &mut cv, (m, n))
    })
    .is_some()
    {
        for r in 0..m {
            std::slice::from_raw_parts_mut(c.add(r * ldc as usize), n)
                .copy_from_slice(&cv[r * n..(r + 1) * n]);
        }
    }
}

/// cblas_dgemv (row-major only).
///
/// # Safety
/// Pointers must reference buffers of the advertised dimensions/strides.
#[no_mangle]
#[allow(clippy::too_many_arguments)]
pub unsafe extern "C" fn cblas_dgemv(
    order: c_int,
    trans: c_int,
    m: c_int,
    n: c_int,
    alpha: c_double,
    a: *const c_double,
    lda: c_int,
    x: *const c_double,
    incx: c_int,
    beta: c_double,
    y: *mut c_double,
    incy: c_int,
) {
    if order != CBLAS_ROW_MAJOR {
        return;
    }
    let Some(t) = trans_of(trans) else { return };
    let (m, n) = (m as usize, n as usize);
    let (xlen, ylen) = if t.is_trans() { (m, n) } else { (n, m) };
    let av = gather(a, m, n, lda as usize);
    let xv = gather_vec(x, xlen, incx as isize);
    let mut yv = gather_vec(y, ylen, incy as isize);
    if with_session(|s| s.gemv(t, alpha, &av, (m, n), &xv, beta, &mut yv)).is_some() {
        scatter_vec(&yv, y, incy as isize);
    }
}

/// cblas_daxpy.
///
/// # Safety
/// Pointers must reference `n`-element strided vectors.
#[no_mangle]
pub unsafe extern "C" fn cblas_daxpy(
    n: c_int,
    alpha: c_double,
    x: *const c_double,
    incx: c_int,
    y: *mut c_double,
    incy: c_int,
) {
    let xv = gather_vec(x, n as usize, incx as isize);
    let mut yv = gather_vec(y, n as usize, incy as isize);
    if with_session(|s| s.axpy(alpha, &xv, &mut yv)).is_some() {
        scatter_vec(&yv, y, incy as isize);
    }
}

/// cblas_ddot.
///
/// # Safety
/// Pointers must reference `n`-element strided vectors.
#[no_mangle]
pub unsafe extern "C" fn cblas_ddot(
    n: c_int,
    x: *const c_double,
    incx: c_int,
    y: *const c_double,
    incy: c_int,
) -> c_double {
    let xv = gather_vec(x, n as usize, incx as isize);
    let yv = gather_vec(y, n as usize, incy as isize);
    with_session(|s| s.dot(&xv, &yv)).unwrap_or(f64::NAN)
}

/// cblas_dnrm2.
///
/// # Safety
/// `x` must reference an `n`-element strided vector.
#[no_mangle]
pub unsafe extern "C" fn cblas_dnrm2(n: c_int, x: *const c_double, incx: c_int) -> c_double {
    let xv = gather_vec(x, n as usize, incx as isize);
    with_session(|s| s.nrm2(&xv)).unwrap_or(f64::NAN)
}

/// cblas_dasum.
///
/// # Safety
/// `x` must reference an `n`-element strided vector.
#[no_mangle]
pub unsafe extern "C" fn cblas_dasum(n: c_int, x: *const c_double, incx: c_int) -> c_double {
    let xv = gather_vec(x, n as usize, incx as isize);
    with_session(|s| s.asum(&xv)).unwrap_or(f64::NAN)
}

/// cblas_dscal.
///
/// # Safety
/// `x` must reference an `n`-element strided vector.
#[no_mangle]
pub unsafe extern "C" fn cblas_dscal(n: c_int, alpha: c_double, x: *mut c_double, incx: c_int) {
    let mut xv = gather_vec(x, n as usize, incx as isize);
    if with_session(|s| s.scal(alpha, &mut xv)).is_some() {
        scatter_vec(&xv, x, incx as isize);
    }
}

/// cblas_idamax (returns 0 for n <= 0, like reference CBLAS).
///
/// # Safety
/// `x` must reference an `n`-element strided vector.
#[no_mangle]
pub unsafe extern "C" fn cblas_idamax(n: c_int, x: *const c_double, incx: c_int) -> c_int {
    if n <= 0 {
        return 0;
    }
    let xv = gather_vec(x, n as usize, incx as isize);
    with_session(|s| s.iamax(&xv)).map(|i| i as c_int).unwrap_or(0)
}
