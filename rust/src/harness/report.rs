//! Plain-text table + CSV rendering for harness reports.

/// Fixed-width table builder.
#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    /// Render with right-aligned numeric-ish columns.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for i in 0..ncol {
                if i > 0 {
                    line.push_str("  ");
                }
                // left-align first column, right-align the rest
                if i == 0 {
                    line.push_str(&format!("{:<w$}", cells[i], w = widths[i]));
                } else {
                    line.push_str(&format!("{:>w$}", cells[i], w = widths[i]));
                }
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.header, &widths));
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncol - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }

    /// CSV rendering (no quoting needed for our content).
    pub fn csv(&self) -> String {
        let mut out = self.header.join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }
}

/// Milliseconds with 3 decimals.
pub fn ms(v_secs: f64) -> String {
    format!("{:.3}", v_secs * 1e3)
}

/// Ratio with 2 decimals and an 'x'.
pub fn ratio(v: f64) -> String {
    format!("{v:.2}x")
}

/// Percentage with 1 decimal.
pub fn pct(v: f64) -> String {
    format!("{:.1}%", v * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.row(vec!["a".into(), "1.0".into()]);
        t.row(vec!["long-name".into(), "123.456".into()]);
        let s = t.render();
        assert!(s.contains("name"));
        assert!(s.lines().count() == 4);
        // right-aligned second column
        assert!(s.lines().last().unwrap().ends_with("123.456"));
    }

    #[test]
    fn csv_roundtrip_shape() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        assert_eq!(t.csv(), "a,b\n1,2\n");
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn formatters() {
        assert_eq!(ms(0.001234), "1.234");
        assert_eq!(ratio(2.714), "2.71x");
        assert_eq!(pct(0.472), "47.2%");
    }
}
