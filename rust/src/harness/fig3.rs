//! Figure 3: execution time of a float64 matrix multiplication with and
//! without offloading, split into data-copy / fork-join / compute.
//!
//! The paper measures from Python with `os.time()` on the FPGA; we
//! measure in virtual time on the calibrated SoC model.  Targets
//! (headline R1/R2): 2.71x speedup at N=128, data copy ~47% of the
//! offloaded runtime.

use crate::blas::{DispatchPolicy, HeroBlas};
use crate::config::{DispatchMode, PlatformConfig};
use crate::error::Result;
use crate::npy::NdArray;
use crate::soc::trace::RegionClass;
use crate::util::rng::Rng;

use super::report::{ms, pct, ratio, Table};

/// Paper headline targets (Results section).
pub const PAPER_SPEEDUP_N128: f64 = 2.71;
pub const PAPER_COPY_SHARE_N128: f64 = 0.47;

/// One measured point of the figure.
#[derive(Debug, Clone)]
pub struct Fig3Point {
    pub n: usize,
    pub mode: DispatchMode,
    /// Virtual seconds per region.
    pub data_copy_s: f64,
    pub fork_join_s: f64,
    pub compute_s: f64,
    pub host_compute_s: f64,
    /// Max |device - host-reference| of the result matrix.
    pub max_abs_err: f64,
}

impl Fig3Point {
    pub fn total_s(&self) -> f64 {
        self.data_copy_s + self.fork_join_s + self.compute_s + self.host_compute_s
    }

    pub fn copy_share(&self) -> f64 {
        let t = self.total_s();
        if t == 0.0 {
            0.0
        } else {
            self.data_copy_s / t
        }
    }
}

/// The full sweep.
#[derive(Debug, Clone)]
pub struct Fig3Report {
    pub points: Vec<Fig3Point>,
    /// The cost model's predicted cold f64 GEMM crossover (the size the
    /// measured curves should cross between — the paper puts it between
    /// 64 and 128).  `None` when the session carries no model.
    pub model_crossover_n: Option<usize>,
}

/// Run one (n, mode) point on an existing session.
pub fn run_point(blas: &mut HeroBlas, n: usize, mode: DispatchMode,
                 seed: u64) -> Result<Fig3Point> {
    let mut rng = Rng::new(seed ^ (n as u64) << 1);
    let a = NdArray::<f64>::randn(&mut rng, &[n, n]);
    let b = NdArray::<f64>::randn(&mut rng, &[n, n]);

    // host-kernel reference for the correctness column
    let mut c_ref = vec![0.0; n * n];
    crate::blas::host::naive_gemm(n, n, n, 1.0, a.data(), b.data(), 0.0, &mut c_ref);

    // mode only — replacing the whole policy would strip the cost model
    // this report's summary advertises (Auto points must dispatch on it)
    blas.policy.mode = mode;
    blas.reset_run();
    let c = a.matmul(&b, blas)?;

    let f = blas.engine.freq_hz();
    let t = &blas.engine.trace;
    let err = c
        .data()
        .iter()
        .zip(c_ref.iter())
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f64::max);
    Ok(Fig3Point {
        n,
        mode,
        data_copy_s: t.total(RegionClass::DataCopy).to_secs(f),
        fork_join_s: t.total(RegionClass::ForkJoin).to_secs(f),
        compute_s: t.total(RegionClass::Compute).to_secs(f),
        host_compute_s: t.total(RegionClass::HostCompute).to_secs(f),
        max_abs_err: err,
    })
}

/// Run the full Figure 3 sweep.
pub fn run_fig3(
    cfg: PlatformConfig,
    artifacts: &std::path::Path,
    sizes: &[usize],
    modes: &[DispatchMode],
    seed: u64,
) -> Result<Fig3Report> {
    let mut blas = HeroBlas::new(cfg, artifacts, DispatchPolicy::default())?;
    let model_crossover_n = blas
        .policy
        .model
        .as_ref()
        .and_then(|m| m.crossovers().gemm_n);
    let mut points = Vec::new();
    for &n in sizes {
        for &mode in modes {
            points.push(run_point(&mut blas, n, mode, seed)?);
        }
    }
    Ok(Fig3Report { points, model_crossover_n })
}

impl Fig3Report {
    fn find(&self, n: usize, mode: DispatchMode) -> Option<&Fig3Point> {
        self.points.iter().find(|p| p.n == n && p.mode == mode)
    }

    /// Offload speedup vs host at size n (None if either point missing).
    pub fn speedup(&self, n: usize, mode: DispatchMode) -> Option<f64> {
        let host = self.find(n, DispatchMode::HostOnly)?;
        let dev = self.find(n, mode)?;
        Some(host.total_s() / dev.total_s())
    }

    /// Render the paper-figure table.
    pub fn render(&self) -> String {
        let mut t = Table::new(&[
            "n", "mode", "data_copy_ms", "fork_join_ms", "compute_ms",
            "total_ms", "speedup", "copy_share", "max_err",
        ]);
        for p in &self.points {
            let speed = self
                .speedup(p.n, p.mode)
                .filter(|_| p.mode != DispatchMode::HostOnly)
                .map(ratio)
                .unwrap_or_else(|| "-".into());
            let share = if p.mode == DispatchMode::HostOnly {
                "-".into()
            } else {
                pct(p.copy_share())
            };
            let compute = p.compute_s + p.host_compute_s;
            t.row(vec![
                p.n.to_string(),
                p.mode.to_string(),
                ms(p.data_copy_s),
                ms(p.fork_join_s),
                ms(compute),
                ms(p.total_s()),
                speed,
                share,
                format!("{:.2e}", p.max_abs_err),
            ]);
        }
        t.render()
    }

    /// CSV for plotting.
    pub fn csv(&self) -> String {
        let mut t = Table::new(&[
            "n", "mode", "data_copy_s", "fork_join_s", "compute_s",
            "host_compute_s", "total_s",
        ]);
        for p in &self.points {
            t.row(vec![
                p.n.to_string(),
                p.mode.to_string(),
                format!("{:.9}", p.data_copy_s),
                format!("{:.9}", p.fork_join_s),
                format!("{:.9}", p.compute_s),
                format!("{:.9}", p.host_compute_s),
                format!("{:.9}", p.total_s()),
            ]);
        }
        t.csv()
    }

    /// Compare the headline point against the paper (R1/R2); returns
    /// (measured_speedup, measured_copy_share) at N=128.
    pub fn headline(&self) -> Option<(f64, f64)> {
        let s = self.speedup(128, DispatchMode::DeviceOnly)?;
        let share = self.find(128, DispatchMode::DeviceOnly)?.copy_share();
        Some((s, share))
    }

    /// Summary block comparing to the paper.
    pub fn summary(&self) -> String {
        let headline = match self.headline() {
            Some((s, share)) => format!(
                "headline @ N=128: speedup {} (paper {}), copy share {} (paper {})\n",
                ratio(s),
                ratio(PAPER_SPEEDUP_N128),
                pct(share),
                pct(PAPER_COPY_SHARE_N128),
            ),
            None => "headline @ N=128: not measured (need host_only + device_only at 128)\n"
                .to_string(),
        };
        match self.model_crossover_n {
            Some(n) => format!(
                "{headline}cost-model crossover: offload wins from n>={n} \
                 (paper: between 64 and 128)\n"
            ),
            None => headline,
        }
    }
}
