//! `hero-blas` — the coordinator CLI.
//!
//! Subcommands:
//!   run      one GEMM with a chosen dispatch mode; prints the region trace
//!   fig3     regenerate the paper's Figure 3 sweep (+ headline R1/R2)
//!   project  regenerate R3 (IOMMU zero-copy) and D1 (f32) projections
//!   inspect  print the platform: memory map, timing constants, artifacts
//!   serve    accept line-delimited JSON gemm requests on a TCP port
//!
//! Global flags: --platform <toml>  --artifacts <dir>  --seed <u64>

use std::path::PathBuf;
use std::process::ExitCode;

use hero_blas::blas::{DispatchPolicy, HeroBlas};
use hero_blas::config::{DispatchMode, PlatformConfig};
use hero_blas::harness;
use hero_blas::npy::NdArray;
use hero_blas::util::rng::Rng;
use hero_blas::{Error, Result};

struct Args {
    platform: Option<PathBuf>,
    artifacts: Option<PathBuf>,
    seed: u64,
    rest: Vec<String>,
}

fn usage() -> String {
    "usage: hero-blas [--platform cfg.toml] [--artifacts dir] [--seed N] <cmd>\n\
     commands:\n\
       run [--size N] [--mode host|device|zero_copy|auto] [--dtype f64|f32]\n\
           [--trace-out trace.json]\n\
       fig3 [--sizes 16,32,64,128,256] [--size N] [--csv]\n\
       project [--size N] [--dtype f32]\n\
       inspect\n\
       serve [--port 7744] [--pool N] [--queue N] [--batch-window-ms N]\n\
             [--batch-max N] [--cache-frac F] [--cache-max-entries N]\n\
             [--pipeline-depth N] [--no-affinity] [--no-steal]\n\
             [--big-shape-frac F] [--reply-timeout-ms N]\n\
             [--no-trace] [--trace-ring N] [--watch-interval-ms N]\n\
             [--no-kernel] [--kernel-promote-after N]\n\
             [--kernel-max-entries N] [--kernel-prewarm]\n"
        .to_string()
}

fn parse_args() -> Result<Args> {
    let mut platform = None;
    let mut artifacts = None;
    let mut seed = 0x5EED;
    let mut rest = Vec::new();
    let mut it = std::env::args().skip(1).peekable();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--platform" => {
                platform = Some(PathBuf::from(it.next().ok_or_else(|| {
                    Error::Config("--platform needs a path".into())
                })?))
            }
            "--artifacts" => {
                artifacts = Some(PathBuf::from(it.next().ok_or_else(|| {
                    Error::Config("--artifacts needs a path".into())
                })?))
            }
            "--seed" => {
                seed = it
                    .next()
                    .and_then(|s| s.parse().ok())
                    .ok_or_else(|| Error::Config("--seed needs a u64".into()))?
            }
            other => rest.push(other.to_string()),
        }
    }
    Ok(Args { platform, artifacts, seed, rest })
}

fn flag_value(rest: &[String], name: &str) -> Option<String> {
    rest.iter()
        .position(|a| a == name)
        .and_then(|i| rest.get(i + 1))
        .cloned()
}

fn has_flag(rest: &[String], name: &str) -> bool {
    rest.iter().any(|a| a == name)
}

fn load_platform(args: &Args) -> Result<PlatformConfig> {
    match &args.platform {
        Some(p) => PlatformConfig::from_toml_file(p),
        None => Ok(PlatformConfig::default()),
    }
}

fn artifacts_dir(args: &Args) -> Result<PathBuf> {
    match &args.artifacts {
        Some(p) => Ok(p.clone()),
        None => hero_blas::find_artifacts_dir(),
    }
}

fn cmd_run(args: &Args) -> Result<()> {
    let n: usize = flag_value(&args.rest, "--size")
        .map(|s| s.parse().map_err(|_| Error::Config("--size: not a number".into())))
        .transpose()?
        .unwrap_or(128);
    let mode: DispatchMode = flag_value(&args.rest, "--mode")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(DispatchMode::Auto);
    let dtype = flag_value(&args.rest, "--dtype").unwrap_or_else(|| "f64".into());

    let cfg = load_platform(args)?;
    let mut blas = HeroBlas::new(cfg, &artifacts_dir(args)?, DispatchPolicy::with_mode(mode))?;
    let mut rng = Rng::new(args.seed);

    println!("gemm n={n} dtype={dtype} mode={mode}");
    macro_rules! run_typed {
        ($t:ty) => {{
            let a = NdArray::<$t>::randn(&mut rng, &[n, n]);
            let b = NdArray::<$t>::randn(&mut rng, &[n, n]);
            blas.reset_run();
            let _c = a.matmul(&b, &mut blas)?;
        }};
    }
    match dtype.as_str() {
        "f64" => run_typed!(f64),
        "f32" => run_typed!(f32),
        other => return Err(Error::Config(format!("unknown dtype '{other}'"))),
    }

    let f = blas.engine.freq_hz();
    println!("virtual-time breakdown ({}):", blas.engine.platform.cfg.name);
    for (class, cyc) in blas.engine.trace.breakdown() {
        println!(
            "  {:<13} {:>12.3} ms  ({} cycles)",
            class.label(),
            cyc.to_ns(f) / 1e6,
            cyc.0
        );
    }
    println!(
        "  {:<13} {:>12.3} ms",
        "total",
        blas.engine.trace.grand_total().to_ns(f) / 1e6
    );
    println!("{}", blas.metrics().summary());
    if let Some(path) = flag_value(&args.rest, "--trace-out") {
        std::fs::write(&path, blas.engine.trace.to_chrome_trace(f))?;
        println!("chrome trace written to {path} (open in chrome://tracing)");
    }
    Ok(())
}

fn cmd_fig3(args: &Args) -> Result<()> {
    // workload file (sizes/modes/seed) < explicit flags
    let workload = flag_value(&args.rest, "--workload")
        .map(|p| hero_blas::config::WorkloadConfig::from_toml_file(std::path::Path::new(&p)))
        .transpose()?;
    let mut sizes: Vec<usize> = workload
        .as_ref()
        .map(|w| w.sweep.sizes.clone())
        .unwrap_or_else(|| vec![16, 32, 64, 128, 256]);
    let mut modes: Vec<DispatchMode> = workload
        .as_ref()
        .map(|w| w.sweep.modes.clone())
        .unwrap_or_else(|| vec![DispatchMode::HostOnly, DispatchMode::DeviceOnly]);
    let seed = workload.as_ref().map(|w| w.seed).unwrap_or(args.seed);
    if let Some(s) = flag_value(&args.rest, "--sizes") {
        sizes = s
            .split(',')
            .map(|x| x.parse().map_err(|_| Error::Config(format!("bad size '{x}'"))))
            .collect::<Result<_>>()?;
    } else if let Some(s) = flag_value(&args.rest, "--size") {
        sizes = vec![s
            .parse()
            .map_err(|_| Error::Config(format!("bad size '{s}'")))?];
    }
    if !modes.contains(&DispatchMode::HostOnly) {
        modes.insert(0, DispatchMode::HostOnly); // speedups need the baseline
    }
    let cfg = load_platform(args)?;
    let report = harness::run_fig3(cfg, &artifacts_dir(args)?, &sizes, &modes, seed)?;
    if let Some(path) = flag_value(&args.rest, "--out") {
        std::fs::write(&path, report.csv())?;
        eprintln!("wrote {path} (plot with tools/plot_fig3.py)");
    }
    if has_flag(&args.rest, "--csv") {
        print!("{}", report.csv());
    } else {
        println!("Figure 3 — f64 GEMM, host vs offload (virtual time)\n");
        print!("{}", report.render());
        println!();
        print!("{}", report.summary());
    }
    Ok(())
}

fn cmd_project(args: &Args) -> Result<()> {
    let n: usize = flag_value(&args.rest, "--size")
        .map(|s| s.parse().map_err(|_| Error::Config("--size: not a number".into())))
        .transpose()?
        .unwrap_or(128);
    let cfg = load_platform(args)?;
    let dir = artifacts_dir(args)?;
    if flag_value(&args.rest, "--dtype").as_deref() == Some("f32") {
        let p = harness::run_f32_projection(cfg, &dir, n, args.seed)?;
        print!("{}", p.render());
    } else {
        let r = harness::run_zero_copy(cfg, &dir, n, args.seed)?;
        print!("{}", r.render());
    }
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let cfg = load_platform(args)?;
    println!("platform: {}", cfg.name);
    println!("clock:    {} MHz", cfg.clock.freq_hz as f64 / 1e6);
    println!(
        "host:     CVA6 rv64g, {:.2} f64 FLOP/cycle, copy {:.3} B/cycle",
        cfg.host.flops_per_cycle, cfg.host.copy_bytes_per_cycle
    );
    println!(
        "cluster:  {} Snitch cores, peak {} f64 FLOP/cycle, efficiency {:.0}%",
        cfg.cluster.cores,
        cfg.cluster_peak_flops_per_cycle(false),
        cfg.cluster.efficiency * 100.0
    );
    let platform = hero_blas::soc::Platform::new(cfg);
    print!("{}", platform.map.render());
    match artifacts_dir(args) {
        Ok(dir) => {
            let manifest = hero_blas::runtime::Manifest::load(&dir)?;
            println!(
                "artifacts: {} entries, tile {}x{}x{}, source {}",
                manifest.entries.len(),
                manifest.tile_m,
                manifest.tile_n,
                manifest.tile_k,
                manifest.source_hash
            );
            for e in &manifest.entries {
                println!("  {:<28} {:>6} [{}]", e.name, e.op, e.dtype);
            }
        }
        Err(e) => println!("artifacts: {e}"),
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let port: u16 = flag_value(&args.rest, "--port")
        .map(|s| s.parse().map_err(|_| Error::Config("--port: not a u16".into())))
        .transpose()?
        .unwrap_or(7744);
    let mut cfg = load_platform(args)?;
    // scheduler knobs: CLI overrides on top of the platform's [sched]
    let num = |name: &str| -> Result<Option<u64>> {
        flag_value(&args.rest, name)
            .map(|s| {
                s.parse()
                    .map_err(|_| Error::Config(format!("{name}: not a number")))
            })
            .transpose()
    };
    let narrow = |name: &str, v: u64| -> Result<u32> {
        u32::try_from(v).map_err(|_| Error::Config(format!("{name}: out of range")))
    };
    if let Some(v) = num("--pool")? {
        cfg.sched.pool_clusters = narrow("--pool", v)?;
    }
    if let Some(v) = num("--queue")? {
        cfg.sched.queue_capacity = narrow("--queue", v)?;
    }
    if let Some(v) = num("--batch-window-ms")? {
        cfg.sched.batch_window_ms = v;
    }
    if let Some(v) = num("--batch-max")? {
        cfg.sched.batch_max = narrow("--batch-max", v)?;
    }
    // data-movement knobs ([sched.cache]): operand cache + pipelining
    if let Some(s) = flag_value(&args.rest, "--cache-frac") {
        cfg.sched.cache.cache_frac = s
            .parse()
            .map_err(|_| Error::Config("--cache-frac: not a number".into()))?;
    }
    if let Some(v) = num("--cache-max-entries")? {
        cfg.sched.cache.cache_max_entries = narrow("--cache-max-entries", v)?;
    }
    if let Some(v) = num("--pipeline-depth")? {
        cfg.sched.cache.pipeline_depth = narrow("--pipeline-depth", v)?;
    }
    // placement knobs ([sched.placement]): affinity / stealing / lanes
    if has_flag(&args.rest, "--no-affinity") {
        cfg.sched.placement.affinity = false;
    }
    if has_flag(&args.rest, "--no-steal") {
        cfg.sched.placement.steal = false;
    }
    if let Some(s) = flag_value(&args.rest, "--big-shape-frac") {
        cfg.sched.placement.big_shape_frac = s
            .parse()
            .map_err(|_| Error::Config("--big-shape-frac: not a number".into()))?;
    }
    // flight-recorder knobs ([sched.trace]): ring size + watch cadence
    if has_flag(&args.rest, "--no-trace") {
        cfg.sched.trace.enabled = false;
    }
    if let Some(v) = num("--trace-ring")? {
        cfg.sched.trace.ring_capacity = v;
    }
    if let Some(v) = num("--watch-interval-ms")? {
        cfg.sched.trace.watch_interval_ms = v;
    }
    // serving-layer knob ([serve]): reply-channel wait before cancelling
    if let Some(v) = num("--reply-timeout-ms")? {
        cfg.serve.reply_timeout_ms = v;
    }
    // kernel-registry knobs ([kernel]): shape-specialized fast paths
    if has_flag(&args.rest, "--no-kernel") {
        cfg.kernel.enabled = false;
    }
    if let Some(v) = num("--kernel-promote-after")? {
        cfg.kernel.promote_after = narrow("--kernel-promote-after", v)?;
    }
    if let Some(v) = num("--kernel-max-entries")? {
        cfg.kernel.max_entries = narrow("--kernel-max-entries", v)?;
    }
    if has_flag(&args.rest, "--kernel-prewarm") {
        cfg.kernel.prewarm = true;
    }
    cfg.validate()?;
    let dir = artifacts_dir(args)?;
    hero_blas::serve::serve(cfg, &dir, port, None)
}

fn main() -> ExitCode {
    let args = match parse_args() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n{}", usage());
            return ExitCode::FAILURE;
        }
    };
    let cmd = args.rest.first().cloned().unwrap_or_default();
    let r = match cmd.as_str() {
        "run" => cmd_run(&args),
        "fig3" => cmd_fig3(&args),
        "project" => cmd_project(&args),
        "inspect" => cmd_inspect(&args),
        "serve" => cmd_serve(&args),
        "" | "help" | "--help" | "-h" => {
            print!("{}", usage());
            Ok(())
        }
        other => Err(Error::Config(format!("unknown command '{other}'\n{}", usage()))),
    };
    match r {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}
