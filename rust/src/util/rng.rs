//! Deterministic RNG for synthetic workloads (SplitMix64 + Box-Muller).
//!
//! Every experiment in the harness is seeded, so runs are exactly
//! reproducible; we avoid an external rand dependency by implementing the
//! two primitives we need.

/// SplitMix64: tiny, fast, passes BigCrush when used as a stream.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    /// Cached second output of the last Box-Muller pair.
    spare_normal: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed, spare_normal: None }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        // 53 high bits -> [0,1) with full double precision
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // modulo bias is irrelevant for our workload sizes
        self.next_u64() % n
    }

    /// Standard normal via Box-Muller (pairs cached).
    pub fn next_normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // u in (0,1] to avoid ln(0)
        let u = 1.0 - self.next_f64();
        let v = self.next_f64();
        let r = (-2.0 * u.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * v;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.next_normal()).collect()
    }

    /// Vector of uniforms in [lo, hi).
    pub fn uniform_vec(&mut self, n: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..n).map(|_| lo + (hi - lo) * self.next_f64()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_in_range_and_roughly_uniform() {
        let mut r = Rng::new(7);
        let v = r.uniform_vec(10_000, -1.0, 1.0);
        assert!(v.iter().all(|&x| (-1.0..1.0).contains(&x)));
        let mean = v.iter().sum::<f64>() / v.len() as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(1);
        let v = r.normal_vec(20_000);
        let mean = v.iter().sum::<f64>() / v.len() as f64;
        let var = v.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / v.len() as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }
}
