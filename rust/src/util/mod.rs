//! In-tree utility substrates.
//!
//! The build is fully offline, so everything a typical project pulls from
//! crates.io beyond the XLA bindings is implemented here: a deterministic
//! RNG ([`rng`]), a TOML-subset parser for platform/workload configs
//! ([`toml_lite`]), a JSON parser/writer for the artifact manifest and
//! harness reports ([`json_lite`]), and a micro-benchmark harness used by
//! `cargo bench` ([`bench`]).

pub mod bench;
pub mod json_lite;
pub mod rng;
pub mod toml_lite;
