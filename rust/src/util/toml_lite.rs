//! Minimal TOML-subset parser (offline substitute for the toml crate).
//!
//! Supports what our config files need: `[section]` and `[a.b]` headers,
//! `key = value` with integers (decimal/hex/underscores), floats, bools,
//! strings, and homogeneous inline arrays (`[1, 2, 3]`), plus `#`
//! comments.  Produces a flat map from dotted path to [`TomlValue`].

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// A parsed scalar or array value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Int(i64),
    Float(f64),
    Bool(bool),
    Str(String),
    Array(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            TomlValue::Int(i) if *i >= 0 => Some(*i as u64),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[TomlValue]> {
        match self {
            TomlValue::Array(a) => Some(a),
            _ => None,
        }
    }
}

/// A parsed document: dotted-path -> value.
#[derive(Debug, Default, Clone, PartialEq)]
pub struct TomlDoc {
    values: BTreeMap<String, TomlValue>,
}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<TomlDoc> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            let at = |m: &str| Error::Config(format!("toml line {}: {m}", lineno + 1));
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| at("unterminated section header"))?
                    .trim();
                if name.is_empty() {
                    return Err(at("empty section name"));
                }
                section = name.to_string();
                continue;
            }
            let eq = line.find('=').ok_or_else(|| at("expected key = value"))?;
            let key = line[..eq].trim();
            if key.is_empty() {
                return Err(at("empty key"));
            }
            let val = parse_value(line[eq + 1..].trim())
                .map_err(|m| at(&m))?;
            let path = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            if doc.values.insert(path.clone(), val).is_some() {
                return Err(at(&format!("duplicate key '{path}'")));
            }
        }
        Ok(doc)
    }

    pub fn get(&self, path: &str) -> Option<&TomlValue> {
        self.values.get(path)
    }

    /// Required typed getters with path-qualified errors.
    pub fn req_u64(&self, path: &str) -> Result<u64> {
        self.get(path)
            .and_then(|v| v.as_u64())
            .ok_or_else(|| Error::Config(format!("config: missing/invalid integer '{path}'")))
    }

    pub fn req_f64(&self, path: &str) -> Result<f64> {
        self.get(path)
            .and_then(|v| v.as_f64())
            .ok_or_else(|| Error::Config(format!("config: missing/invalid number '{path}'")))
    }

    pub fn req_str(&self, path: &str) -> Result<&str> {
        self.get(path)
            .and_then(|v| v.as_str())
            .ok_or_else(|| Error::Config(format!("config: missing/invalid string '{path}'")))
    }

    /// Required array access with a path-qualified [`Error`] — config
    /// consumers get a proper error for a missing/mistyped array instead
    /// of reaching for a panicking match.
    pub fn req_array(&self, path: &str) -> Result<&[TomlValue]> {
        self.get(path)
            .and_then(|v| v.as_array())
            .ok_or_else(|| Error::Config(format!("config: missing/invalid array '{path}'")))
    }

    /// Optional getters (fall back to a default at the call site).
    pub fn opt_f64(&self, path: &str) -> Option<f64> {
        self.get(path).and_then(|v| v.as_f64())
    }

    pub fn opt_u64(&self, path: &str) -> Option<u64> {
        self.get(path).and_then(|v| v.as_u64())
    }

    pub fn opt_i64(&self, path: &str) -> Option<i64> {
        self.get(path).and_then(|v| v.as_i64())
    }

    pub fn opt_str(&self, path: &str) -> Option<&str> {
        self.get(path).and_then(|v| v.as_str())
    }

    pub fn opt_bool(&self, path: &str) -> Option<bool> {
        self.get(path).and_then(|v| v.as_bool())
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(|s| s.as_str())
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' inside a quoted string must not start a comment
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(text: &str) -> std::result::Result<TomlValue, String> {
    if text.is_empty() {
        return Err("empty value".into());
    }
    if let Some(inner) = text.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| "unterminated array".to_string())?
            .trim();
        if inner.is_empty() {
            return Ok(TomlValue::Array(Vec::new()));
        }
        let items = split_top_level(inner)
            .into_iter()
            .map(|s| parse_value(s.trim()))
            .collect::<std::result::Result<Vec<_>, _>>()?;
        return Ok(TomlValue::Array(items));
    }
    if let Some(inner) = text.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or_else(|| "unterminated string".to_string())?;
        return Ok(TomlValue::Str(inner.to_string()));
    }
    match text {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    let clean: String = text.chars().filter(|&c| c != '_').collect();
    if let Some(hex) = clean.strip_prefix("0x").or_else(|| clean.strip_prefix("0X")) {
        return i64::from_str_radix(hex, 16)
            .map(TomlValue::Int)
            .map_err(|_| format!("bad hex integer '{text}'"));
    }
    if !clean.contains('.') && !clean.contains('e') && !clean.contains('E') {
        if let Ok(i) = clean.parse::<i64>() {
            return Ok(TomlValue::Int(i));
        }
    }
    clean
        .parse::<f64>()
        .map(TomlValue::Float)
        .map_err(|_| format!("bad value '{text}'"))
}

fn split_top_level(s: &str) -> Vec<&str> {
    // arrays of scalars only — no nesting needed for our configs
    s.split(',').collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = TomlDoc::parse(
            r#"
            name = "carfield"     # inline comment
            [clock]
            freq_hz = 50_000_000
            [host]
            flops_per_cycle = 0.4
            fast = true
            base = 0xA000_0000
            sizes = [16, 32, 64]
            "#,
        )
        .unwrap();
        assert_eq!(doc.req_str("name").unwrap(), "carfield");
        assert_eq!(doc.req_u64("clock.freq_hz").unwrap(), 50_000_000);
        assert_eq!(doc.req_f64("host.flops_per_cycle").unwrap(), 0.4);
        assert_eq!(doc.get("host.fast").unwrap().as_bool(), Some(true));
        assert_eq!(doc.req_u64("host.base").unwrap(), 0xA000_0000);
        let arr = doc.req_array("host.sizes").unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].as_u64(), Some(64));
    }

    #[test]
    fn req_array_errors_name_the_path() {
        let doc = TomlDoc::parse("[host]\nsizes = [1, 2]\nscalar = 3").unwrap();
        assert_eq!(doc.req_array("host.sizes").unwrap().len(), 2);
        // missing and mistyped both come back as config errors, not panics
        let e = doc.req_array("host.missing").unwrap_err().to_string();
        assert!(e.contains("host.missing"), "{e}");
        let e = doc.req_array("host.scalar").unwrap_err().to_string();
        assert!(e.contains("host.scalar"), "{e}");
    }

    #[test]
    fn int_vs_float() {
        let doc = TomlDoc::parse("a = 3\nb = 3.0\nc = 1e3").unwrap();
        assert_eq!(doc.get("a").unwrap(), &TomlValue::Int(3));
        assert_eq!(doc.get("b").unwrap(), &TomlValue::Float(3.0));
        assert_eq!(doc.get("c").unwrap(), &TomlValue::Float(1000.0));
        // ints coerce to f64 on demand
        assert_eq!(doc.req_f64("a").unwrap(), 3.0);
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let doc = TomlDoc::parse("s = \"a#b\"").unwrap();
        assert_eq!(doc.req_str("s").unwrap(), "a#b");
    }

    #[test]
    fn rejects_malformed() {
        assert!(TomlDoc::parse("[unclosed").is_err());
        assert!(TomlDoc::parse("novalue").is_err());
        assert!(TomlDoc::parse("k = ").is_err());
        assert!(TomlDoc::parse("k = \"open").is_err());
        assert!(TomlDoc::parse("k = 1\nk = 2").is_err());
        assert!(TomlDoc::parse("[]").is_err());
    }

    #[test]
    fn missing_key_errors_name_the_path() {
        let doc = TomlDoc::parse("[a]\nb = 1").unwrap();
        let e = doc.req_u64("a.c").unwrap_err().to_string();
        assert!(e.contains("a.c"), "{e}");
    }
}
