//! Micro-benchmark harness (offline substitute for criterion).
//!
//! `cargo bench` targets use [`Bench`] to time closures with warm-up,
//! adaptive iteration counts and simple statistics, printing one line per
//! benchmark:
//!
//! ```text
//! fig3/gemm_offload_n128        median 1.234 ms   mean 1.240 ms ± 0.012   (64 iters)
//! ```

use std::time::{Duration, Instant};

/// Result statistics for one benchmark.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: u64,
    pub median: Duration,
    pub mean: Duration,
    pub stddev: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl BenchStats {
    pub fn line(&self) -> String {
        format!(
            "{:<44} median {:>10}   mean {:>10} ± {:<9} ({} iters)",
            self.name,
            fmt_dur(self.median),
            fmt_dur(self.mean),
            fmt_dur(self.stddev),
            self.iters
        )
    }
}

fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1_000.0 {
        format!("{ns:.0} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.3} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// The harness: collects results, prints as it goes.
pub struct Bench {
    /// Target total measurement time per benchmark.
    pub budget: Duration,
    /// Hard cap on iterations (useful for slow end-to-end benches).
    pub max_iters: u64,
    results: Vec<BenchStats>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench::new()
    }
}

impl Bench {
    pub fn new() -> Self {
        Bench {
            budget: Duration::from_secs(2),
            max_iters: 10_000,
            results: Vec::new(),
        }
    }

    pub fn with_budget(budget: Duration, max_iters: u64) -> Self {
        Bench { budget, max_iters, results: Vec::new() }
    }

    /// Time `f`, which must return something observable (prevents the
    /// optimizer from deleting the work; the value is black-boxed).
    pub fn run<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchStats {
        // warm-up: one call, also used to size the iteration count
        let t0 = Instant::now();
        std::hint::black_box(f());
        let once = t0.elapsed().max(Duration::from_nanos(20));

        let iters = ((self.budget.as_nanos() / once.as_nanos().max(1)) as u64)
            .clamp(5, self.max_iters);
        let mut samples = Vec::with_capacity(iters as usize);
        for _ in 0..iters {
            let t = Instant::now();
            std::hint::black_box(f());
            samples.push(t.elapsed());
        }
        samples.sort_unstable();
        let median = samples[samples.len() / 2];
        let mean_ns =
            samples.iter().map(|d| d.as_nanos() as f64).sum::<f64>() / samples.len() as f64;
        let var = samples
            .iter()
            .map(|d| {
                let x = d.as_nanos() as f64 - mean_ns;
                x * x
            })
            .sum::<f64>()
            / samples.len() as f64;
        let stats = BenchStats {
            name: name.to_string(),
            iters,
            median,
            mean: Duration::from_nanos(mean_ns as u64),
            stddev: Duration::from_nanos(var.sqrt() as u64),
            min: samples[0],
            max: *samples.last().unwrap(),
        };
        println!("{}", stats.line());
        self.results.push(stats);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchStats] {
        &self.results
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let mut b = Bench::with_budget(Duration::from_millis(20), 100);
        let s = b.run("spin", || {
            let mut acc = 0u64;
            for i in 0..1000 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(s.median.as_nanos() > 0);
        assert!(s.iters >= 5);
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn ordering_of_stats() {
        let mut b = Bench::with_budget(Duration::from_millis(10), 50);
        let s = b.run("noop", || 1u8).clone();
        assert!(s.min <= s.median && s.median <= s.max);
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_dur(Duration::from_nanos(500)), "500 ns");
        assert_eq!(fmt_dur(Duration::from_micros(1500)), "1.500 ms");
        assert!(fmt_dur(Duration::from_secs(2)).ends_with(" s"));
    }
}
