//! Minimal JSON parser + writer (offline substitute for serde_json).
//!
//! Parses the artifact `manifest.json` written by `python/compile/aot.py`
//! and serializes harness reports.  Supports the full JSON value grammar
//! except exotic number forms; strings handle the common escapes.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{Error, Result};

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after JSON value"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as u64)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Required-field helpers with good error messages.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| Error::Config(format!("manifest: missing key '{key}'")))
    }

    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| Error::Config(format!("manifest: '{key}' not a string")))
    }

    pub fn req_u64(&self, key: &str) -> Result<u64> {
        self.req(key)?
            .as_u64()
            .ok_or_else(|| Error::Config(format!("manifest: '{key}' not an integer")))
    }

    // -- writer ----------------------------------------------------------

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0);
        out
    }

    fn write(&self, out: &mut String, indent: usize) {
        let pad = "  ".repeat(indent);
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => {
                let _ = write!(out, "{b}");
            }
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    let _ = write!(out, "\n{pad}  ");
                    item.write(out, indent + 1);
                }
                let _ = write!(out, "\n{pad}]");
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, val)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    let _ = write!(out, "\n{pad}  ");
                    write_escaped(out, k);
                    out.push_str(": ");
                    val.write(out, indent + 1);
                }
                let _ = write!(out, "\n{pad}}}");
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Config(format!("json at byte {}: {msg}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| self.err("bad \\u escape"))?,
                                16,
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(
                                char::from_u32(code)
                                    .ok_or_else(|| self.err("bad \\u code point"))?,
                            );
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // copy one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let ch = rest.chars().next().unwrap();
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || c == b'.' || c == b'e' || c == b'E' || c == b'+' || c == b'-')
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err(&format!("bad number '{text}'")))
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let text = r#"{
          "tile": {"m": 64, "n": 64, "k": 64},
          "entries": [
            {"name": "gemm_f64_n128", "file": "gemm_f64_n128.hlo.txt",
             "op": "gemm", "dtype": "f64", "m": 128, "n": 128, "k": 128,
             "arg_shapes": [[128, 128], [1]], "arg_dtypes": ["float64"]}
          ],
          "source_hash": "abc123"
        }"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(j.get("tile").unwrap().req_u64("m").unwrap(), 64);
        let entries = j.get("entries").unwrap().as_arr().unwrap();
        assert_eq!(entries[0].req_str("name").unwrap(), "gemm_f64_n128");
        let shapes = entries[0].get("arg_shapes").unwrap().as_arr().unwrap();
        assert_eq!(shapes[0].as_arr().unwrap().len(), 2);
    }

    #[test]
    fn roundtrip_pretty() {
        let text = r#"{"a": [1, 2.5, -3], "b": "x\"y", "c": null, "d": true}"#;
        let j = Json::parse(text).unwrap();
        let back = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn escapes_roundtrip() {
        let j = Json::Str("line1\nline2\t\"q\"\\".into());
        let back = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("-1.5e3").unwrap().as_f64(), Some(-1500.0));
        assert_eq!(Json::parse("42").unwrap().as_u64(), Some(42));
        assert_eq!(Json::parse("-1").unwrap().as_u64(), None);
        assert_eq!(Json::parse("1.5").unwrap().as_u64(), None);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            Json::parse("\"\\u0041\\u00e9\"").unwrap().as_str(),
            Some("Aé")
        );
    }
}
