//! The registry: promotion policy + bounded LRU over specialized plans.
//!
//! Launch counts arrive from the scheduler's per-key outcome stream
//! ([`KernelRegistry::note_launch`]); once a key crosses
//! `[kernel] promote_after`, the next stage that sees it
//! ([`KernelRegistry::wants_specialize`]) builds its plan from the
//! resolved geometry and inserts it.  Resident plans are LRU-bounded by
//! `[kernel] max_entries` so a shape-diverse adversarial stream cannot
//! grow the registry without bound; entries pinned by an in-flight walk
//! are never evicted (the opcache pin/stamp idiom).  The launch-count
//! map is bounded too — coldest-count eviction at a small multiple of
//! `max_entries`.
//!
//! Counter totals ride atomics (scraped by the serve `metrics`/`top`
//! ops and the Prometheus exposition); individual transitions fire the
//! installed event hook, which the scheduler bridges into the flight
//! recorder so promotions and fast-path hits show up in `trace_dump`.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::config::KernelConfig;
use crate::cost::tile::round_up;
use crate::soc::{DmaModel, SnitchCluster};

use super::plan::{kernel_key, Epilogue, KernelOp, KernelPlan};
use super::{PREWARM_GEMM_SIZES, PREWARM_GEMV_SIZES};

/// Point-in-time registry statistics (accumulated since construction).
#[derive(Debug, Default, Clone, Copy)]
pub struct KernelStats {
    /// Plans compiled (promotions + prewarms).
    pub specialized: u64,
    /// Launches that took a specialized fast-path walk.
    pub hits: u64,
    /// Launches that ran the generic interpreted walk with the
    /// registry enabled.
    pub fallbacks: u64,
    /// Plans reclaimed by the LRU bound.
    pub evictions: u64,
    /// Resident plans right now.
    pub entries: usize,
    /// Keys with tracked launch counts right now.
    pub tracked_keys: usize,
}

/// One observable registry transition, delivered synchronously to the
/// installed hook (the flight-recorder bridge — same shape as the
/// operand cache's `CacheEvent`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelEvent {
    /// A key crossed `promote_after` and its plan entered the registry.
    Promote { key: u64, launches: u32 },
    /// A launch took the specialized fast path.
    Hit { key: u64 },
}

/// Boxed observer with a hand-written `Debug` so the registry keeps its
/// derived `Debug` (closures have none).
struct EventHook(Box<dyn Fn(KernelEvent) + Send + Sync>);

impl std::fmt::Debug for EventHook {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("EventHook(..)")
    }
}

/// One resident specialized plan.
#[derive(Debug)]
struct Entry {
    plan: Arc<KernelPlan>,
    /// In-flight walks currently executing against this plan (one pin
    /// per acquire); pinned entries are never evicted.
    pins: u32,
    /// Monotone LRU stamp (bumped on every acquire / insert).
    stamp: u64,
    hits: u64,
}

#[derive(Debug, Default)]
struct Inner {
    entries: HashMap<u64, Entry>,
    /// Per-key launch counts (the promotion feed).
    launches: HashMap<u64, u32>,
    clock: u64,
    hook: Option<EventHook>,
}

/// The shape-specialized kernel registry.  Shared across the whole pool
/// via `Arc` — like the cost model's calibration, one registry learns
/// the hot keys of all workers.
#[derive(Debug)]
pub struct KernelRegistry {
    enabled: bool,
    promote_after: u32,
    max_entries: usize,
    /// Manifest tile geometry (pads exactly like the staging path).
    tile: (usize, usize, usize),
    /// Largest level-1 artifact length (the device chunk size).
    level1_chunk: usize,
    inner: Mutex<Inner>,
    specialized: AtomicU64,
    hits: AtomicU64,
    fallbacks: AtomicU64,
    evictions: AtomicU64,
}

impl KernelRegistry {
    /// Build from the `[kernel]` config plus the manifest-derived
    /// geometry (tile shape, largest level-1 artifact).
    pub fn new(
        cfg: &KernelConfig,
        tile: (usize, usize, usize),
        level1_chunk: usize,
    ) -> KernelRegistry {
        KernelRegistry {
            enabled: cfg.enabled,
            promote_after: cfg.promote_after,
            max_entries: cfg.max_entries as usize,
            tile,
            level1_chunk: level1_chunk.max(1),
            inner: Mutex::new(Inner::default()),
            specialized: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            fallbacks: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    pub fn promote_after(&self) -> u32 {
        self.promote_after
    }

    /// Install the transition observer (replaces any previous one).
    /// Events fire synchronously from the mutating call, so the hook
    /// must be cheap and reentrancy-free — the flight recorder's
    /// lock-free append qualifies.
    pub fn set_event_hook(
        &self,
        hook: impl Fn(KernelEvent) + Send + Sync + 'static,
    ) {
        self.inner.lock().unwrap().hook = Some(EventHook(Box::new(hook)));
    }

    /// The key a serve-protocol (op, dtype, dims, epilogue) tuple
    /// specializes under — pads with the same manifest tile geometry
    /// the staging path uses, so the scheduler's launch-count feed and
    /// the device's stage-time lookup agree byte for byte.  Dims follow
    /// the serve convention: gemm `(m, n, k)`, gemv `(m, n, _)`,
    /// axpy/dot `(n, _, _)`.
    pub fn key_for(
        &self,
        op: &str,
        dtype: &str,
        dims: (usize, usize, usize),
        epi: Epilogue,
    ) -> Option<u64> {
        let kop = KernelOp::from_name(op)?;
        let (tm, tn, tk) = self.tile;
        let (tile, padded) = match kop {
            KernelOp::Gemm => (
                self.tile,
                (round_up(dims.0, tm), round_up(dims.1, tn), round_up(dims.2, tk)),
            ),
            KernelOp::Gemv => {
                (self.tile, (round_up(dims.0, tm), round_up(dims.1, tk), 0))
            }
            KernelOp::Axpy | KernelOp::Dot => (
                (self.level1_chunk, 0, 0),
                (round_up(dims.0, self.level1_chunk), 0, 0),
            ),
        };
        Some(kernel_key(kop, dtype, tile, padded, epi))
    }

    /// Launch-count feed (the worker's outcome stream): bump the key's
    /// count.  The map is bounded — at capacity the coldest tracked key
    /// makes room — so untracked shape churn cannot grow it.
    pub fn note_launch(&self, key: u64) {
        if !self.enabled {
            return;
        }
        let mut g = self.inner.lock().unwrap();
        let cap = self.max_entries.saturating_mul(8).max(64);
        if g.launches.len() >= cap && !g.launches.contains_key(&key) {
            if let Some(cold) =
                g.launches.iter().min_by_key(|(_, &c)| c).map(|(&k, _)| k)
            {
                g.launches.remove(&cold);
            }
        }
        let c = g.launches.entry(key).or_insert(0);
        *c = c.saturating_add(1);
    }

    /// Has this key crossed the promotion threshold without a resident
    /// plan?  The stage that sees `true` builds the plan from its
    /// resolved geometry and [`KernelRegistry::insert`]s it.
    pub fn wants_specialize(&self, key: u64) -> bool {
        if !self.enabled {
            return false;
        }
        let g = self.inner.lock().unwrap();
        !g.entries.contains_key(&key)
            && g.launches.get(&key).copied().unwrap_or(0) >= self.promote_after
    }

    /// Is a specialized plan resident (no pin, no counter)?  The
    /// dispatch policy asks this to pick the specialized crossover.
    pub fn has_plan(&self, key: u64) -> bool {
        self.enabled && self.inner.lock().unwrap().entries.contains_key(&key)
    }

    /// Fast-path lookup at walk time: pins the entry for the duration
    /// of the in-flight walk (pair with [`KernelRegistry::release`]),
    /// bumps the LRU stamp and counts a hit.
    pub fn acquire(&self, key: u64) -> Option<Arc<KernelPlan>> {
        if !self.enabled {
            return None;
        }
        let mut g = self.inner.lock().unwrap();
        g.clock += 1;
        let stamp = g.clock;
        let plan = {
            let e = g.entries.get_mut(&key)?;
            e.pins += 1;
            e.stamp = stamp;
            e.hits += 1;
            e.plan.clone()
        };
        self.hits.fetch_add(1, Ordering::Relaxed);
        if let Some(h) = &g.hook {
            (h.0)(KernelEvent::Hit { key });
        }
        Some(plan)
    }

    /// Drop one in-flight pin.
    pub fn release(&self, key: u64) {
        let mut g = self.inner.lock().unwrap();
        if let Some(e) = g.entries.get_mut(&key) {
            e.pins = e.pins.saturating_sub(1);
        }
    }

    /// Count a generic-walk launch taken while the registry is enabled
    /// (no resident plan for the key — the always-correct fallback).
    pub fn note_fallback(&self) {
        if self.enabled {
            self.fallbacks.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Insert a freshly specialized plan (promotion or prewarm).
    /// LRU-evicts an unpinned entry when full; refuses — `false`, the
    /// caller stays on the generic walk — when every resident entry is
    /// pinned by an in-flight walk.
    pub fn insert(&self, plan: KernelPlan) -> bool {
        if !self.enabled {
            return false;
        }
        let mut g = self.inner.lock().unwrap();
        if g.entries.contains_key(&plan.key) {
            return true; // racing promotion of the same key
        }
        while g.entries.len() >= self.max_entries {
            let victim = g
                .entries
                .iter()
                .filter(|(_, e)| e.pins == 0)
                .min_by_key(|(_, e)| e.stamp)
                .map(|(&k, _)| k);
            match victim {
                Some(k) => {
                    g.entries.remove(&k);
                    self.evictions.fetch_add(1, Ordering::Relaxed);
                }
                None => return false,
            }
        }
        g.clock += 1;
        let stamp = g.clock;
        let key = plan.key;
        let launches = g.launches.get(&key).copied().unwrap_or(0);
        g.entries.insert(
            key,
            Entry { plan: Arc::new(plan), pins: 0, stamp, hits: 0 },
        );
        self.specialized.fetch_add(1, Ordering::Relaxed);
        if let Some(h) = &g.hook {
            (h.0)(KernelEvent::Promote { key, launches });
        }
        true
    }

    /// Explicit eviction; refused (`false`) while the entry is pinned
    /// by an in-flight walk.
    pub fn evict(&self, key: u64) -> bool {
        let mut g = self.inner.lock().unwrap();
        match g.entries.get(&key) {
            Some(e) if e.pins == 0 => {
                g.entries.remove(&key);
                self.evictions.fetch_add(1, Ordering::Relaxed);
                true
            }
            _ => false,
        }
    }

    /// Point-in-time statistics.
    pub fn stats(&self) -> KernelStats {
        let g = self.inner.lock().unwrap();
        KernelStats {
            specialized: self.specialized.load(Ordering::Relaxed),
            hits: self.hits.load(Ordering::Relaxed),
            fallbacks: self.fallbacks.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            entries: g.entries.len(),
            tracked_keys: g.launches.len(),
        }
    }

    /// Hottest tracked keys by launch count:
    /// `(key, launches, specialized?)`, hottest first — the per-key
    /// view the serve `top` op prints.
    pub fn top_keys(&self, n: usize) -> Vec<(u64, u32, bool)> {
        let g = self.inner.lock().unwrap();
        let mut v: Vec<(u64, u32, bool)> = g
            .launches
            .iter()
            .map(|(&k, &c)| (k, c, g.entries.contains_key(&k)))
            .collect();
        v.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        v.truncate(n);
        v
    }

    /// Pre-specialize the `aot.py` size tables (`[kernel] prewarm`):
    /// every (op, dtype, size) in [`PREWARM_GEMM_SIZES`] /
    /// [`PREWARM_GEMV_SIZES`] gets a plan at pool boot, so the paper's
    /// Figure-3 shapes take the fast path from the first launch.
    /// Returns the number of plans inserted.
    pub fn prewarm(&self, dma: &DmaModel, cluster: &SnitchCluster) -> usize {
        if !self.enabled {
            return 0;
        }
        let (tm, tn, tk) = self.tile;
        let mut inserted = 0;
        for dtype in ["f32", "f64"] {
            for &n in &PREWARM_GEMM_SIZES {
                let padded = (round_up(n, tm), round_up(n, tn), round_up(n, tk));
                let plan = KernelPlan::specialize(
                    dma,
                    cluster,
                    KernelOp::Gemm,
                    dtype,
                    self.tile,
                    padded,
                    Epilogue::None,
                );
                if self.insert(plan) {
                    inserted += 1;
                }
            }
            for &n in &PREWARM_GEMV_SIZES {
                let padded = (round_up(n, tm), round_up(n, tk), 0);
                let plan = KernelPlan::specialize(
                    dma,
                    cluster,
                    KernelOp::Gemv,
                    dtype,
                    self.tile,
                    padded,
                    Epilogue::None,
                );
                if self.insert(plan) {
                    inserted += 1;
                }
            }
        }
        inserted
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PlatformConfig;

    fn cfg(promote_after: u32, max_entries: u32) -> KernelConfig {
        KernelConfig { enabled: true, promote_after, max_entries, prewarm: false }
    }

    fn registry(promote_after: u32, max_entries: u32) -> KernelRegistry {
        KernelRegistry::new(&cfg(promote_after, max_entries), (64, 64, 64), 4096)
    }

    fn plan_for(reg: &KernelRegistry, n: usize) -> KernelPlan {
        let pc = PlatformConfig::default();
        let dma = DmaModel::new(pc.dma.clone());
        let cluster = SnitchCluster::new(pc.cluster.clone(), pc.memory.l1_spm_bytes);
        KernelPlan::specialize(
            &dma,
            &cluster,
            KernelOp::Gemm,
            "f64",
            (64, 64, 64),
            (round_up(n, 64), round_up(n, 64), round_up(n, 64)),
            Epilogue::None,
        )
    }

    #[test]
    fn promotion_under_the_threshold_never_fires() {
        let reg = registry(4, 8);
        let key = reg.key_for("gemm", "f64", (128, 128, 128), Epilogue::None).unwrap();
        for _ in 0..3 {
            reg.note_launch(key);
            assert!(!reg.wants_specialize(key), "under threshold");
        }
        reg.note_launch(key);
        assert!(reg.wants_specialize(key), "threshold crossed");
        assert!(reg.insert(plan_for(&reg, 128)));
        assert!(!reg.wants_specialize(key), "already resident");
        assert!(reg.has_plan(key));
    }

    #[test]
    fn eviction_of_a_pinned_in_flight_kernel_is_refused() {
        let reg = registry(1, 8);
        let plan = plan_for(&reg, 128);
        let key = plan.key;
        assert!(reg.insert(plan));
        let held = reg.acquire(key).expect("resident plan");
        assert_eq!(held.padded, (128, 128, 128));
        assert!(!reg.evict(key), "pinned entry must not evict");
        reg.release(key);
        assert!(reg.evict(key), "unpinned entry evicts");
        assert!(!reg.has_plan(key));
        assert_eq!(reg.stats().evictions, 1);
    }

    #[test]
    fn lru_bounds_resident_plans_and_insert_refuses_when_all_pinned() {
        let reg = registry(1, 2);
        let (p1, p2, p3) = (plan_for(&reg, 64), plan_for(&reg, 128), plan_for(&reg, 256));
        let (k1, k2) = (p1.key, p2.key);
        assert!(reg.insert(p1));
        assert!(reg.insert(p2));
        // touch k2 so k1 is the LRU victim
        reg.acquire(k2).unwrap();
        reg.release(k2);
        assert!(reg.insert(p3));
        assert_eq!(reg.stats().entries, 2);
        assert!(!reg.has_plan(k1), "LRU victim was the stale key");
        assert!(reg.has_plan(k2));
        // with every resident entry pinned, insertion is refused
        reg.acquire(k2).unwrap();
        let p3b = plan_for(&reg, 256);
        reg.acquire(p3b.key).unwrap();
        assert!(!reg.insert(plan_for(&reg, 64)), "all pinned: refuse");
    }

    #[test]
    fn launch_count_map_is_bounded_against_shape_churn() {
        let reg = registry(2, 4); // cap = max(4*8, 64) = 64
        for n in 0..1000usize {
            let key = reg
                .key_for("gemm", "f64", (64 * (n + 1), 64, 64), Epilogue::None)
                .unwrap();
            reg.note_launch(key);
        }
        assert!(reg.stats().tracked_keys <= 64, "launch map must stay bounded");
    }

    #[test]
    fn disabled_registry_is_inert() {
        let mut c = cfg(1, 8);
        c.enabled = false;
        let reg = KernelRegistry::new(&c, (64, 64, 64), 4096);
        let key = reg.key_for("gemm", "f64", (128, 128, 128), Epilogue::None).unwrap();
        reg.note_launch(key);
        assert!(!reg.wants_specialize(key));
        assert!(!reg.insert(plan_for(&reg, 128)));
        assert!(reg.acquire(key).is_none());
        reg.note_fallback();
        let s = reg.stats();
        assert_eq!((s.specialized, s.hits, s.fallbacks, s.tracked_keys), (0, 0, 0, 0));
    }

    #[test]
    fn prewarm_specializes_the_aot_size_tables() {
        let reg = registry(1, 64);
        let pc = PlatformConfig::default();
        let dma = DmaModel::new(pc.dma.clone());
        let cluster = SnitchCluster::new(pc.cluster.clone(), pc.memory.l1_spm_bytes);
        let want = 2 * (PREWARM_GEMM_SIZES.len() + PREWARM_GEMV_SIZES.len());
        assert_eq!(reg.prewarm(&dma, &cluster), want);
        assert_eq!(reg.stats().entries, want);
        // the prewarmed gemm keys answer stage-time lookups
        let key = reg.key_for("gemm", "f64", (128, 128, 128), Epilogue::None).unwrap();
        assert!(reg.has_plan(key));
        let key32 = reg.key_for("gemv", "f32", (256, 256, 0), Epilogue::None).unwrap();
        assert!(reg.has_plan(key32));
    }

    #[test]
    fn top_keys_rank_by_launch_count_and_hits_fire_events() {
        let reg = registry(2, 8);
        let hot = reg.key_for("gemm", "f64", (128, 128, 128), Epilogue::None).unwrap();
        let cold = reg.key_for("gemv", "f64", (128, 128, 0), Epilogue::None).unwrap();
        let events = Arc::new(Mutex::new(Vec::new()));
        let sink = events.clone();
        reg.set_event_hook(move |ev| sink.lock().unwrap().push(ev));
        for _ in 0..3 {
            reg.note_launch(hot);
        }
        reg.note_launch(cold);
        let top = reg.top_keys(8);
        assert_eq!(top[0], (hot, 3, false));
        assert_eq!(top[1], (cold, 1, false));
        assert!(reg.insert(plan_for(&reg, 128)));
        reg.acquire(hot).unwrap();
        reg.release(hot);
        let evs = events.lock().unwrap();
        assert_eq!(evs[0], KernelEvent::Promote { key: hot, launches: 3 });
        assert_eq!(evs[1], KernelEvent::Hit { key: hot });
        assert_eq!(reg.top_keys(8)[0], (hot, 3, true));
    }
}
