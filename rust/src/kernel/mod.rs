//! Shape-specialized kernel registry: compile hot (op, dtype,
//! tile-shape) keys into fused fast-path walks.
//!
//! Every device launch interprets the generic tile walk against the
//! manifest — per-tile bounds checks, stride arithmetic and epilogue
//! dispatch are re-derived even for the handful of shapes that dominate
//! real serving traffic (the `aot.py` size tables are the x-axis of the
//! paper's Figure 3).  This module closes ROADMAP item 3: a
//! content-keyed cache — FNV-1a over (op, dtype, tile shape, padded
//! problem dims, epilogue), keyed like the operand cache — of
//! **specialized compute walks**: unrolled tile loops, baked strides and
//! padded dims, and fused bias/ReLU epilogues, generated at runtime from
//! the same manifest geometry the generic walk reads.
//!
//! Three pieces:
//!
//! * [`plan`] — the specializer: [`KernelPlan`] bakes one key's loop
//!   schedule and per-step cycle charges from the shared
//!   [`crate::cost::tile`] specialized-walk formulas, so the execution
//!   charges and the cost model's estimates can never drift;
//! * [`registry`] — [`KernelRegistry`]: the promotion policy (the
//!   scheduler's per-key launch counts cross `[kernel] promote_after`
//!   and the next stage specializes the key), the bounded LRU over
//!   resident plans (`max_entries`, pinned in-flight entries are never
//!   evicted), and the counters/events the serve ops surface;
//! * the walks themselves live in `blas::device`, which consults the
//!   registry at stage time for single gemms, batches and chains alike —
//!   a specialized walk issues the *exact same* kernel executions in the
//!   same order on the same padded data, so it is bit-identical to the
//!   generic interpreted walk by construction (checksum-pinned in
//!   `rust/tests/integration_kernel.rs`); only the virtual-time charges
//!   differ.

pub mod plan;
pub mod registry;

pub use plan::{kernel_key, Epilogue, KernelOp, KernelPlan};
pub use registry::{KernelEvent, KernelRegistry, KernelStats};

/// GEMM edge lengths specialized at pool boot when `[kernel] prewarm`
/// is on.  MUST match `DEFAULT_GEMM_SIZES` in `python/compile/aot.py`
/// (pinned by `python/tests/test_aot.py`).
pub const PREWARM_GEMM_SIZES: [usize; 5] = [16, 32, 64, 128, 256];

/// GEMV sizes specialized at pool boot.  MUST match
/// `DEFAULT_GEMV_SIZES` in `python/compile/aot.py` (pinned by
/// `python/tests/test_aot.py`).
pub const PREWARM_GEMV_SIZES: [usize; 2] = [128, 256];
