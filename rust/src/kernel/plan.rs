//! The specializer: bake one hot key's walk into a [`KernelPlan`].
//!
//! A plan is the "compiled" form of one (op, dtype, tile shape, padded
//! problem dims, epilogue) key: the grid a walk will cover and the
//! per-step virtual-time charges of the specialized schedule — unrolled
//! tile loops (the per-tile interpreter overhead folds out of the FPU
//! burst, see [`tile::SPECIALIZED_FPU_GAIN`]) and the epilogue fused
//! into the C write-back pass instead of a separate stream pass.  The
//! charges come from the shared [`crate::cost::tile`] specialized-walk
//! formulas — the same functions [`crate::cost::CostModel`] sums when
//! estimating, so execution and estimation cannot drift.
//!
//! Plans carry **no numerics**: `blas::device` drives the identical
//! kernel executions either way and consults the plan only for the
//! charge schedule, which is what makes the fast path bit-identical to
//! the generic interpreted walk by construction.

use crate::cost::tile::{
    self, specialized_gemm_tile_costs, specialized_gemv_panel_costs,
    specialized_level1_chunk_costs,
};
use crate::omp::opcache::fnv1a;
use crate::soc::clock::Cycles;
use crate::soc::{DmaModel, SnitchCluster};

/// Op families the registry specializes (serve-protocol names).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelOp {
    Gemm,
    Gemv,
    Axpy,
    Dot,
}

impl KernelOp {
    /// Key-encoding tag.
    pub fn tag(self) -> u8 {
        match self {
            KernelOp::Gemm => 0,
            KernelOp::Gemv => 1,
            KernelOp::Axpy => 2,
            KernelOp::Dot => 3,
        }
    }

    /// Serve-protocol op name.
    pub fn name(self) -> &'static str {
        match self {
            KernelOp::Gemm => "gemm",
            KernelOp::Gemv => "gemv",
            KernelOp::Axpy => "axpy",
            KernelOp::Dot => "dot",
        }
    }

    /// Family of a serve-protocol op name.
    pub fn from_name(op: &str) -> Option<KernelOp> {
        match op {
            "gemm" => Some(KernelOp::Gemm),
            "gemv" => Some(KernelOp::Gemv),
            "axpy" => Some(KernelOp::Axpy),
            "dot" => Some(KernelOp::Dot),
            _ => None,
        }
    }
}

/// Fused epilogue variant baked into a specialized walk — the chain
/// epilogues that already exist in `blas::device::chain_epilogue`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Epilogue {
    None,
    Bias,
    Relu,
    BiasRelu,
}

impl Epilogue {
    /// Variant for a chain link's (bias?, relu?) pair.
    pub fn of(bias: bool, relu: bool) -> Epilogue {
        match (bias, relu) {
            (false, false) => Epilogue::None,
            (true, false) => Epilogue::Bias,
            (false, true) => Epilogue::Relu,
            (true, true) => Epilogue::BiasRelu,
        }
    }

    /// Key-encoding tag.
    pub fn tag(self) -> u8 {
        match self {
            Epilogue::None => 0,
            Epilogue::Bias => 1,
            Epilogue::Relu => 2,
            Epilogue::BiasRelu => 3,
        }
    }

    /// Does the walk carry a fused element-wise pass?
    pub fn is_fused(self) -> bool {
        self != Epilogue::None
    }
}

/// Content key of one specializable walk: 64-bit FNV-1a over the
/// (op, dtype, tile shape, padded problem dims, epilogue) tuple —
/// the same hash the operand cache keys staged bytes with.
pub fn kernel_key(
    op: KernelOp,
    dtype: &str,
    tile: (usize, usize, usize),
    padded: (usize, usize, usize),
    epi: Epilogue,
) -> u64 {
    let mut buf = Vec::with_capacity(64);
    buf.push(op.tag());
    buf.extend_from_slice(dtype.as_bytes());
    for d in [tile.0, tile.1, tile.2, padded.0, padded.1, padded.2] {
        buf.extend_from_slice(&(d as u64).to_le_bytes());
    }
    buf.push(epi.tag());
    fnv1a(&buf)
}

/// One specialized compute walk: the baked loop schedule and per-step
/// charges for a single hot key.
#[derive(Debug, Clone, PartialEq)]
pub struct KernelPlan {
    pub key: u64,
    pub op: KernelOp,
    pub dtype: String,
    /// Manifest tile geometry the walk was specialized against (the
    /// level-1 chunk length rides in `.0` for axpy/dot).
    pub tile: (usize, usize, usize),
    /// Padded problem dims (baked — a plan serves exactly one shape).
    pub padded: (usize, usize, usize),
    /// Tile/panel/chunk grid the walk covers.
    pub grid: (usize, usize, usize),
    pub epilogue: Epilogue,
    /// Exposed first step of a walk (DMA refill + FPU serialized).
    pub first_step: Cycles,
    /// Steady double-buffered step (max of refill and burst).
    pub steady_step: Cycles,
    /// C-tile map-in charge when beta != 0 (gemm only).
    pub c_in: Cycles,
    /// Fused epilogue + C write-back pass (gemm only; gemv/level-1
    /// outputs ride the panel/chunk step, exactly like the generic
    /// walk).
    pub c_pass: Cycles,
}

impl KernelPlan {
    /// Specialize one key from the same SoC models and manifest
    /// geometry the generic walk reads.  `tile` is the manifest tile
    /// shape (for level-1: `(chunk, 0, 0)`); `padded` the tile-padded
    /// problem dims (gemm `(mp, np, kp)`, gemv `(mp, np, 0)`, level-1
    /// `(chunk-padded n, 0, 0)`).
    pub fn specialize(
        dma: &DmaModel,
        cluster: &SnitchCluster,
        op: KernelOp,
        dtype: &str,
        tile: (usize, usize, usize),
        padded: (usize, usize, usize),
        epi: Epilogue,
    ) -> KernelPlan {
        let key = kernel_key(op, dtype, tile, padded, epi);
        let f32_path = dtype == "f32";
        let esz = if f32_path { 4 } else { 8 };
        let (first_step, steady_step, c_in, c_pass, grid) = match op {
            KernelOp::Gemm => {
                let s = specialized_gemm_tile_costs(dma, cluster, tile, esz, f32_path);
                // a fused bias/ReLU pass rides the same C write-back
                // streaming window the alpha/beta epilogue does: no
                // extra charge, the pass is bounded by max(stream, DMA)
                (
                    s.dma_ab + s.fpu,
                    s.dma_ab.max(s.fpu),
                    s.dma_c,
                    s.c_pass,
                    (padded.0 / tile.0, padded.1 / tile.1, padded.2 / tile.2),
                )
            }
            KernelOp::Gemv => {
                let p = specialized_gemv_panel_costs(
                    dma,
                    cluster,
                    (tile.0, tile.2),
                    esz,
                    f32_path,
                );
                let step = p.dma_panel.max(p.fpu);
                (
                    step,
                    step,
                    Cycles::ZERO,
                    Cycles::ZERO,
                    (padded.0 / tile.0, padded.1 / tile.2, 0),
                )
            }
            KernelOp::Axpy | KernelOp::Dot => {
                let c = specialized_level1_chunk_costs(dma, cluster, tile.0);
                let step = c.dma.max(c.fpu) + c.dma;
                (
                    step,
                    step,
                    Cycles::ZERO,
                    Cycles::ZERO,
                    (padded.0.div_ceil(tile.0.max(1)), 0, 0),
                )
            }
        };
        KernelPlan {
            key,
            op,
            dtype: dtype.to_string(),
            tile,
            padded,
            grid,
            epilogue: epi,
            first_step,
            steady_step,
            c_in,
            c_pass,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PlatformConfig;
    use crate::cost::tile::gemm_tile_costs;

    fn models() -> (DmaModel, SnitchCluster) {
        let cfg = PlatformConfig::default();
        (
            DmaModel::new(cfg.dma.clone()),
            SnitchCluster::new(cfg.cluster.clone(), cfg.memory.l1_spm_bytes),
        )
    }

    #[test]
    fn keys_separate_every_tuple_component() {
        let tile = (64, 64, 64);
        let base = kernel_key(KernelOp::Gemm, "f64", tile, (128, 128, 128), Epilogue::None);
        assert_eq!(
            base,
            kernel_key(KernelOp::Gemm, "f64", tile, (128, 128, 128), Epilogue::None)
        );
        for other in [
            kernel_key(KernelOp::Gemv, "f64", tile, (128, 128, 128), Epilogue::None),
            kernel_key(KernelOp::Gemm, "f32", tile, (128, 128, 128), Epilogue::None),
            kernel_key(KernelOp::Gemm, "f64", (32, 32, 32), (128, 128, 128), Epilogue::None),
            kernel_key(KernelOp::Gemm, "f64", tile, (128, 128, 192), Epilogue::None),
            kernel_key(KernelOp::Gemm, "f64", tile, (128, 128, 128), Epilogue::BiasRelu),
        ] {
            assert_ne!(base, other);
        }
    }

    #[test]
    fn epilogue_variants_cover_the_flag_pairs() {
        assert_eq!(Epilogue::of(false, false), Epilogue::None);
        assert_eq!(Epilogue::of(true, false), Epilogue::Bias);
        assert_eq!(Epilogue::of(false, true), Epilogue::Relu);
        assert_eq!(Epilogue::of(true, true), Epilogue::BiasRelu);
        assert!(!Epilogue::None.is_fused());
        assert!(Epilogue::BiasRelu.is_fused());
        assert_eq!(KernelOp::from_name("gemm"), Some(KernelOp::Gemm));
        assert_eq!(KernelOp::from_name("fence"), None);
        assert_eq!(KernelOp::Dot.name(), "dot");
    }

    #[test]
    fn specialized_gemm_plan_undercuts_the_generic_charges() {
        let (dma, cluster) = models();
        let tile = (64, 64, 64);
        let p = KernelPlan::specialize(
            &dma,
            &cluster,
            KernelOp::Gemm,
            "f64",
            tile,
            (128, 128, 192),
            Epilogue::None,
        );
        assert_eq!(p.grid, (2, 2, 3));
        let g = gemm_tile_costs(&dma, &cluster, tile, 8, false);
        assert!(p.first_step < g.dma_ab + g.fpu);
        assert!(p.steady_step <= g.dma_ab.max(g.fpu));
        assert!(p.c_pass < g.epilogue + g.dma_c, "epilogue must fuse into the C pass");
        assert_eq!(p.c_in, g.dma_c);
    }

    #[test]
    fn gemv_and_level1_plans_shape_their_grids() {
        let (dma, cluster) = models();
        let v = KernelPlan::specialize(
            &dma,
            &cluster,
            KernelOp::Gemv,
            "f64",
            (64, 64, 64),
            (256, 128, 0),
            Epilogue::None,
        );
        assert_eq!(v.grid, (4, 2, 0));
        assert_eq!(v.c_pass, Cycles::ZERO);
        let l = KernelPlan::specialize(
            &dma,
            &cluster,
            KernelOp::Axpy,
            "f64",
            (4096, 0, 0),
            (12288, 0, 0),
            Epilogue::None,
        );
        assert_eq!(l.grid, (3, 0, 0));
        assert_eq!(l.first_step, l.steady_step);
    }
}
