//! RISC-V IOMMU model — the paper's future-work zero-copy path.
//!
//! With the IOMMU enabled, shared buffers no longer need to be copied
//! into the device-managed DRAM partition: the host creates IO page-table
//! entries mapping the Linux pages into the device's IOVA space, and the
//! cluster DMA accesses them directly (paying IOTLB miss walks).  The
//! paper cites a prior study on the same platform: creating the IO-PTEs
//! for the N=128 working set is ~7.5x faster than copying it, projecting
//! a 4.7x total speedup — our `harness::projections` regenerates that
//! number from this model.

use std::collections::{HashMap, VecDeque};

use super::clock::Cycles;
use crate::config::IommuConfig;
use crate::error::{Error, Result};

/// One live IOVA mapping.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Mapping {
    pub iova: u64,
    pub host_addr: u64,
    pub len: u64,
    pub pages: u64,
}

/// IOMMU with a FIFO IOTLB.
#[derive(Debug)]
pub struct Iommu {
    cfg: IommuConfig,
    /// iova (page-aligned) -> host page address, for every mapped page.
    ptes: HashMap<u64, u64>,
    /// Resident IOTLB tags (page-aligned IOVAs), FIFO replacement.
    iotlb: VecDeque<u64>,
    next_iova: u64,
    pub hits: u64,
    pub misses: u64,
}

impl Iommu {
    pub fn new(cfg: IommuConfig) -> Self {
        Iommu {
            cfg,
            ptes: HashMap::new(),
            iotlb: VecDeque::new(),
            next_iova: 0x4000_0000, // IOVA window base
            hits: 0,
            misses: 0,
        }
    }

    pub fn page_bytes(&self) -> u64 {
        self.cfg.page_bytes
    }

    /// Number of pages needed for `len` bytes starting at `host_addr`.
    pub fn pages_for(&self, host_addr: u64, len: u64) -> u64 {
        if len == 0 {
            return 0;
        }
        let p = self.cfg.page_bytes;
        let first = host_addr / p;
        let last = (host_addr + len - 1) / p;
        last - first + 1
    }

    /// Map `len` bytes at `host_addr` into device IOVA space.
    /// Returns the mapping and the host-side cost of creating the PTEs —
    /// this is the "data copy" replacement in the zero-copy path.
    pub fn map(&mut self, host_addr: u64, len: u64) -> Result<(Mapping, Cycles)> {
        if len == 0 {
            return Err(Error::Offload("cannot map zero-length range".into()));
        }
        let pages = self.pages_for(host_addr, len);
        let p = self.cfg.page_bytes;
        let iova = self.next_iova;
        self.next_iova += pages * p;
        let host_page0 = host_addr / p * p;
        for i in 0..pages {
            self.ptes.insert(iova + i * p, host_page0 + i * p);
        }
        let cost = Cycles(pages * self.cfg.pte_create_cycles);
        Ok((Mapping { iova, host_addr, len, pages }, cost))
    }

    /// Tear down a mapping; returns the host-side teardown cost.
    pub fn unmap(&mut self, m: &Mapping) -> Cycles {
        let p = self.cfg.page_bytes;
        for i in 0..m.pages {
            self.ptes.remove(&(m.iova + i * p));
            if let Some(pos) = self.iotlb.iter().position(|&t| t == m.iova + i * p) {
                self.iotlb.remove(pos);
            }
        }
        Cycles(m.pages * self.cfg.pte_teardown_cycles)
    }

    /// Translate a device access; returns (host address, lookup cost).
    /// Hits are free (pipelined); misses pay a page-table walk.
    pub fn translate(&mut self, iova: u64) -> Result<(u64, Cycles)> {
        let p = self.cfg.page_bytes;
        let tag = iova / p * p;
        let host_page = *self.ptes.get(&tag).ok_or_else(|| {
            Error::Offload(format!("IOMMU fault: unmapped iova 0x{iova:x}"))
        })?;
        let cost = if self.iotlb.contains(&tag) {
            self.hits += 1;
            Cycles::ZERO
        } else {
            self.misses += 1;
            if self.iotlb.len() as u32 >= self.cfg.iotlb_entries {
                self.iotlb.pop_front();
            }
            self.iotlb.push_back(tag);
            Cycles(self.cfg.iotlb_miss_cycles)
        };
        Ok((host_page + (iova % p), cost))
    }

    /// Cost for the cluster DMA to stream `len` bytes through the IOMMU:
    /// one IOTLB lookup per page touched (sequential access pattern).
    pub fn stream_translate_cost(&mut self, iova: u64, len: u64) -> Result<Cycles> {
        let mut total = Cycles::ZERO;
        let p = self.cfg.page_bytes;
        let pages = self.pages_for(iova, len);
        for i in 0..pages {
            let (_, c) = self.translate(iova + i * p)?;
            total += c;
        }
        Ok(total)
    }

    pub fn live_pages(&self) -> usize {
        self.ptes.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PlatformConfig;

    fn iommu() -> Iommu {
        Iommu::new(PlatformConfig::default().iommu)
    }

    #[test]
    fn pages_for_spans() {
        let i = iommu();
        assert_eq!(i.pages_for(0, 4096), 1);
        assert_eq!(i.pages_for(0, 4097), 2);
        assert_eq!(i.pages_for(4095, 2), 2); // crosses a boundary
        assert_eq!(i.pages_for(123, 0), 0);
    }

    #[test]
    fn map_cost_is_per_page() {
        let mut i = iommu();
        let (m, c) = i.map(0x1000_0000, 128 * 1024).unwrap();
        assert_eq!(m.pages, 32);
        assert_eq!(c, Cycles(32 * 2025));
        assert_eq!(i.live_pages(), 32);
    }

    #[test]
    fn translate_hit_after_miss() {
        let mut i = iommu();
        let (m, _) = i.map(0x2000_0100, 100).unwrap();
        let (h1, c1) = i.translate(m.iova + 4).unwrap();
        assert_eq!(c1, Cycles(120)); // miss: walk
        let (h2, c2) = i.translate(m.iova + 8).unwrap();
        assert_eq!(c2, Cycles::ZERO); // hit: same page
        assert_eq!(h2 - h1, 4);
        // translation preserves the page offset relative to the mapped base
        assert_eq!(h1 % i.page_bytes(), (m.iova + 4) % i.page_bytes());
    }

    #[test]
    fn unmapped_access_faults() {
        let mut i = iommu();
        assert!(i.translate(0x4000_0000).is_err());
    }

    #[test]
    fn unmap_removes_ptes_and_faults_after() {
        let mut i = iommu();
        let (m, _) = i.map(0x3000_0000, 8192).unwrap();
        i.translate(m.iova).unwrap();
        let c = i.unmap(&m);
        assert_eq!(c, Cycles(2 * 427));
        assert_eq!(i.live_pages(), 0);
        assert!(i.translate(m.iova).is_err());
    }

    #[test]
    fn iotlb_evicts_fifo() {
        let mut i = iommu();
        // map more pages than IOTLB entries (32) and touch them all
        let (m, _) = i.map(0x5000_0000, 40 * 4096).unwrap();
        let c = i.stream_translate_cost(m.iova, m.len).unwrap();
        assert_eq!(i.misses, 40);
        assert_eq!(c, Cycles(40 * 120));
        // first page was evicted: touching it again misses
        let (_, c0) = i.translate(m.iova).unwrap();
        assert_eq!(c0, Cycles(120));
    }

    #[test]
    fn zero_length_map_rejected() {
        let mut i = iommu();
        assert!(i.map(0x1000, 0).is_err());
    }
}
