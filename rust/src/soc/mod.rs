//! SoC simulator substrate.
//!
//! This module is our stand-in for the paper's FPGA-emulated
//! Cheshire/Carfield platform (DESIGN.md §1): an event/cost model of the
//! CVA6 host, the Snitch PMCA cluster with its DMA-fed 128 KiB L1 SPM,
//! the memory map, the mailbox, and the RISC-V IOMMU.  It answers *how
//! long* things take in virtual time; numerics come from the AOT
//! artifacts executed by [`crate::runtime`].

pub mod clock;
pub mod cva6;
pub mod dma;
pub mod iommu;
pub mod mailbox;
pub mod memory;
pub mod snitch;
pub mod trace;

pub use clock::{Cycles, SimClock};
pub use cva6::Cva6Model;
pub use dma::DmaModel;
pub use iommu::Iommu;
pub use mailbox::Mailbox;
pub use memory::{MemoryMap, Region, RegionKind};
pub use snitch::SnitchCluster;
pub use trace::{RegionClass, Trace, TraceEvent};

use crate::config::PlatformConfig;

/// Bundle of all per-platform models, built once from a config.
#[derive(Debug)]
pub struct Platform {
    pub cfg: PlatformConfig,
    pub map: MemoryMap,
    pub host: Cva6Model,
    pub cluster: SnitchCluster,
    pub dma: DmaModel,
}

impl Platform {
    /// Build all models from a validated platform config.
    pub fn new(cfg: PlatformConfig) -> Self {
        let map = MemoryMap::from_config(&cfg.memory);
        let host = Cva6Model::new(cfg.host.clone());
        let cluster = SnitchCluster::new(cfg.cluster.clone(), cfg.memory.l1_spm_bytes);
        let dma = DmaModel::new(cfg.dma.clone());
        Platform { cfg, map, host, cluster, dma }
    }

    /// Fresh IOMMU instance (stateful: owns its IOTLB).
    pub fn iommu(&self) -> Iommu {
        Iommu::new(self.cfg.iommu.clone())
    }

    /// Fresh mailbox instance.
    pub fn mailbox(&self) -> Mailbox {
        Mailbox::new(self.cfg.forkjoin.doorbell_cycles)
    }
}
