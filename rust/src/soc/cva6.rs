//! CVA6 rv64g host-core cost model.
//!
//! Two things run on the host in the paper's flow: the OpenBLAS host
//! kernels (the "without offloading" baseline) and the data copies
//! between the Linux-managed and device-managed DRAM partitions (the
//! "data copy" region).  Both are bandwidth/throughput models of the
//! in-order scalar core — CVA6 has no FREP/SSR, so its sustained FLOP
//! rate is far below the cluster's.

use super::clock::Cycles;
use crate::config::HostConfig;

/// Host-core model.
#[derive(Debug, Clone)]
pub struct Cva6Model {
    cfg: HostConfig,
}

impl Cva6Model {
    pub fn new(cfg: HostConfig) -> Self {
        Cva6Model { cfg }
    }

    fn flops_per_cycle(&self, f32_path: bool) -> f64 {
        if f32_path {
            self.cfg.flops_per_cycle * self.cfg.f32_speedup
        } else {
            self.cfg.flops_per_cycle
        }
    }

    /// Cycles for a host GEMM: 2*m*n*k FLOPs through the scalar FPU.
    pub fn gemm_cycles(&self, m: usize, n: usize, k: usize, f32_path: bool) -> Cycles {
        let flops = 2.0 * m as f64 * n as f64 * k as f64;
        Cycles::from_f64(flops / self.flops_per_cycle(f32_path))
    }

    /// Cycles for a host GEMV: 2*m*n FLOPs (memory-bound in reality, but
    /// on CVA6 the scalar FPU is still the limiter at these sizes).
    pub fn gemv_cycles(&self, m: usize, n: usize, f32_path: bool) -> Cycles {
        let flops = 2.0 * m as f64 * n as f64;
        Cycles::from_f64(flops / self.flops_per_cycle(f32_path))
    }

    /// Cycles for a level-1 op touching `n` elements with `flops_per_el`.
    pub fn level1_cycles(&self, n: usize, flops_per_el: f64, f32_path: bool) -> Cycles {
        Cycles::from_f64(n as f64 * flops_per_el / self.flops_per_cycle(f32_path))
    }

    /// Cycles to copy `bytes` between DRAM partitions (the paper's
    /// "data copy" region).
    pub fn memcpy_cycles(&self, bytes: u64) -> Cycles {
        Cycles::from_f64(
            self.cfg.memcpy_setup_cycles as f64
                + bytes as f64 / self.cfg.copy_bytes_per_cycle,
        )
    }

    /// Sustained copy bandwidth in bytes/cycle (for reporting).
    pub fn copy_bytes_per_cycle(&self) -> f64 {
        self.cfg.copy_bytes_per_cycle
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PlatformConfig;

    fn host() -> Cva6Model {
        Cva6Model::new(PlatformConfig::default().host)
    }

    #[test]
    fn gemm_cost_cubic() {
        let h = host();
        // 2*128^3 = 4.194e6 FLOP / 0.4 = 10.49e6 cycles
        let c = h.gemm_cycles(128, 128, 128, false);
        assert_eq!(c, Cycles((2.0 * 128f64.powi(3) / 0.4).ceil() as u64));
        // doubling one dim doubles cycles
        let c2 = h.gemm_cycles(256, 128, 128, false);
        assert_eq!(c2.0, 2 * c.0);
    }

    #[test]
    fn memcpy_cost_linear_plus_setup() {
        let h = host();
        let c1 = h.memcpy_cycles(0);
        assert_eq!(c1, Cycles(200));
        let c2 = h.memcpy_cycles(288);
        assert_eq!(c2, Cycles(200 + 1000));
    }

    #[test]
    fn f32_path_uses_multiplier() {
        let h = host();
        // default host f32_speedup = 1.0 -> same cost
        assert_eq!(
            h.gemm_cycles(64, 64, 64, true),
            h.gemm_cycles(64, 64, 64, false)
        );
    }

    #[test]
    fn gemv_and_level1_scale_linearly() {
        let h = host();
        assert_eq!(h.gemv_cycles(100, 50, false).0,
                   (2.0 * 100.0 * 50.0 / 0.4) as u64);
        assert_eq!(h.level1_cycles(1000, 2.0, false).0, 5000);
    }
}
