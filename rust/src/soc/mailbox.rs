//! Host<->cluster mailbox.
//!
//! The Hero runtime kicks the cluster by writing an offload-descriptor
//! pointer into a doorbell register; completion comes back the same way.
//! Functionally this is a small FIFO of 64-bit words; its latency is part
//! of the paper's "fork/join" region.

use std::collections::VecDeque;

use super::clock::Cycles;

/// One mailbox direction (we model the pair as two FIFOs in one struct).
#[derive(Debug, Default)]
struct Fifo {
    words: VecDeque<u64>,
}

/// Host<->device mailbox with doorbell latency.
#[derive(Debug)]
pub struct Mailbox {
    to_device: Fifo,
    to_host: Fifo,
    doorbell_cycles: u64,
    doorbells_rung: u64,
}

impl Mailbox {
    pub fn new(doorbell_cycles: u64) -> Self {
        Mailbox {
            to_device: Fifo::default(),
            to_host: Fifo::default(),
            doorbell_cycles,
            doorbells_rung: 0,
        }
    }

    /// Host posts a descriptor pointer; returns the doorbell latency.
    pub fn ring_device(&mut self, word: u64) -> Cycles {
        self.to_device.words.push_back(word);
        self.doorbells_rung += 1;
        Cycles(self.doorbell_cycles)
    }

    /// Device drains its FIFO (returns the oldest descriptor pointer).
    pub fn device_pop(&mut self) -> Option<u64> {
        self.to_device.words.pop_front()
    }

    /// Device posts completion status; returns the doorbell latency.
    pub fn ring_host(&mut self, word: u64) -> Cycles {
        self.to_host.words.push_back(word);
        self.doorbells_rung += 1;
        Cycles(self.doorbell_cycles)
    }

    /// Host drains completion words.
    pub fn host_pop(&mut self) -> Option<u64> {
        self.to_host.words.pop_front()
    }

    pub fn pending_for_device(&self) -> usize {
        self.to_device.words.len()
    }

    pub fn pending_for_host(&self) -> usize {
        self.to_host.words.len()
    }

    pub fn doorbells_rung(&self) -> u64 {
        self.doorbells_rung
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order_preserved() {
        let mut mb = Mailbox::new(5_000);
        mb.ring_device(0xA);
        mb.ring_device(0xB);
        assert_eq!(mb.pending_for_device(), 2);
        assert_eq!(mb.device_pop(), Some(0xA));
        assert_eq!(mb.device_pop(), Some(0xB));
        assert_eq!(mb.device_pop(), None);
    }

    #[test]
    fn doorbell_latency_and_count() {
        let mut mb = Mailbox::new(5_000);
        assert_eq!(mb.ring_device(1), Cycles(5_000));
        assert_eq!(mb.ring_host(2), Cycles(5_000));
        assert_eq!(mb.doorbells_rung(), 2);
        assert_eq!(mb.host_pop(), Some(2));
    }

    #[test]
    fn directions_are_independent() {
        let mut mb = Mailbox::new(1);
        mb.ring_device(7);
        assert_eq!(mb.pending_for_host(), 0);
        assert_eq!(mb.host_pop(), None);
        assert_eq!(mb.device_pop(), Some(7));
    }
}
