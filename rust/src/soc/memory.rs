//! SoC address map (Figure 1 of the paper).
//!
//! Three device-side regions matter to the stack: the cluster-local L1
//! SPM (DMA-fed working set), the dual-port L2 SPM (device instructions +
//! constants, where `libopenblas.so`'s device functions are copied before
//! the first offload), and the device-managed DRAM partition (physically
//! contiguous shared buffers, used when the IOMMU is off).



use crate::config::MemoryConfig;

/// What a region is used for.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RegionKind {
    L1Spm,
    L2Spm,
    DevDram,
}

impl RegionKind {
    pub fn label(self) -> &'static str {
        match self {
            RegionKind::L1Spm => "l1_spm",
            RegionKind::L2Spm => "l2_spm",
            RegionKind::DevDram => "dev_dram",
        }
    }
}

/// One mapped region.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Region {
    pub kind: RegionKind,
    pub base: u64,
    pub size: u64,
}

impl Region {
    pub fn end(&self) -> u64 {
        self.base + self.size
    }

    pub fn contains(&self, addr: u64) -> bool {
        addr >= self.base && addr < self.end()
    }

    /// Whole range [addr, addr+len) inside the region?
    pub fn contains_range(&self, addr: u64, len: u64) -> bool {
        self.contains(addr) && addr + len <= self.end()
    }
}

/// The full device-visible address map.
#[derive(Debug, Clone)]
pub struct MemoryMap {
    regions: Vec<Region>,
}

impl MemoryMap {
    pub fn from_config(cfg: &MemoryConfig) -> Self {
        let regions = vec![
            Region { kind: RegionKind::L1Spm, base: cfg.l1_spm_base, size: cfg.l1_spm_bytes },
            Region { kind: RegionKind::L2Spm, base: cfg.l2_spm_base, size: cfg.l2_spm_bytes },
            Region { kind: RegionKind::DevDram, base: cfg.dev_dram_base, size: cfg.dev_dram_bytes },
        ];
        MemoryMap { regions }
    }

    pub fn regions(&self) -> &[Region] {
        &self.regions
    }

    /// The region holding `addr`, if any.
    pub fn region_of(&self, addr: u64) -> Option<&Region> {
        self.regions.iter().find(|r| r.contains(addr))
    }

    /// The region of a given kind (each kind appears exactly once).
    pub fn region(&self, kind: RegionKind) -> &Region {
        self.regions
            .iter()
            .find(|r| r.kind == kind)
            .expect("all kinds present by construction")
    }

    /// Pretty-print for `hero-blas inspect`.
    pub fn render(&self) -> String {
        let mut out = String::from("address map:\n");
        for r in &self.regions {
            out.push_str(&format!(
                "  {:<9} 0x{:08x}..0x{:08x}  {:>10} B\n",
                r.kind.label(),
                r.base,
                r.end(),
                r.size
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PlatformConfig;

    fn map() -> MemoryMap {
        MemoryMap::from_config(&PlatformConfig::default().memory)
    }

    #[test]
    fn regions_present_and_disjoint() {
        let m = map();
        assert_eq!(m.regions().len(), 3);
        for (i, a) in m.regions().iter().enumerate() {
            for b in m.regions().iter().skip(i + 1) {
                assert!(a.end() <= b.base || b.end() <= a.base,
                        "{:?} overlaps {:?}", a, b);
            }
        }
    }

    #[test]
    fn region_lookup() {
        let m = map();
        let spm = m.region(RegionKind::L1Spm);
        assert!(m.region_of(spm.base).is_some());
        assert!(m.region_of(spm.base + spm.size - 1).is_some());
        assert!(m.region_of(spm.base + spm.size).map(|r| r.kind) != Some(RegionKind::L1Spm));
        assert!(m.region_of(0xDEAD_0000_0000).is_none());
    }

    #[test]
    fn contains_range_edges() {
        let r = Region { kind: RegionKind::DevDram, base: 0x1000, size: 0x100 };
        assert!(r.contains_range(0x1000, 0x100));
        assert!(!r.contains_range(0x1000, 0x101));
        assert!(!r.contains_range(0x0FFF, 2));
        assert!(r.contains_range(0x10FF, 1));
    }

    #[test]
    fn l1_spm_matches_paper() {
        // paper: "128 KiB of local scratch-pad memory"
        let m = map();
        assert_eq!(m.region(RegionKind::L1Spm).size, 128 * 1024);
    }

    #[test]
    fn render_mentions_all_regions() {
        let s = map().render();
        for k in ["l1_spm", "l2_spm", "dev_dram"] {
            assert!(s.contains(k), "{s}");
        }
    }
}
