//! Snitch PMCA cluster compute model.
//!
//! Eight worker cores with double-precision FPUs execute on SPM-resident
//! tiles; a ninth core drives the DMA (modelled separately in
//! [`super::dma`]).  The model answers: how many cycles does the cluster
//! need for one tile-level kernel burst, and does a tile set fit the
//! 128 KiB L1 SPM.

use super::clock::Cycles;
use crate::config::ClusterConfig;

/// Cluster model.
#[derive(Debug, Clone)]
pub struct SnitchCluster {
    cfg: ClusterConfig,
    l1_spm_bytes: u64,
}

impl SnitchCluster {
    pub fn new(cfg: ClusterConfig, l1_spm_bytes: u64) -> Self {
        SnitchCluster { cfg, l1_spm_bytes }
    }

    /// Peak FLOP/cycle of the whole cluster (FMA = 2 FLOPs).
    pub fn peak_flops_per_cycle(&self, f32_path: bool) -> f64 {
        let base = self.cfg.cores as f64 * self.cfg.fma_per_core_per_cycle * 2.0;
        if f32_path { base * self.cfg.f32_speedup } else { base }
    }

    /// Sustained FLOP/cycle after the efficiency derating.
    pub fn sustained_flops_per_cycle(&self, f32_path: bool) -> f64 {
        self.peak_flops_per_cycle(f32_path) * self.cfg.efficiency
    }

    /// Cycles for one GEMM tile burst: 2*tm*tn*tk FLOPs across the cores.
    pub fn gemm_tile_cycles(&self, tm: usize, tn: usize, tk: usize,
                            f32_path: bool) -> Cycles {
        let flops = 2.0 * tm as f64 * tn as f64 * tk as f64;
        Cycles::from_f64(flops / self.sustained_flops_per_cycle(f32_path))
    }

    /// Cycles for a streaming (level-1/2) burst over `n` elements.
    pub fn stream_cycles(&self, n: usize, flops_per_el: f64, f32_path: bool) -> Cycles {
        Cycles::from_f64(n as f64 * flops_per_el
            / self.sustained_flops_per_cycle(f32_path))
    }

    /// Does a resident set of `bytes` fit the L1 SPM?
    pub fn fits_spm(&self, bytes: u64) -> bool {
        bytes <= self.l1_spm_bytes
    }

    /// SPM capacity in bytes (128 KiB on the paper's platform).
    pub fn spm_bytes(&self) -> u64 {
        self.l1_spm_bytes
    }

    pub fn cores(&self) -> u32 {
        self.cfg.cores
    }

    pub fn efficiency(&self) -> f64 {
        self.cfg.efficiency
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PlatformConfig;

    fn cluster() -> SnitchCluster {
        let cfg = PlatformConfig::default();
        SnitchCluster::new(cfg.cluster, cfg.memory.l1_spm_bytes)
    }

    #[test]
    fn peak_matches_paper_platform() {
        let c = cluster();
        // 8 cores x 1 FMA/cycle x 2 FLOP = 16 FLOP/cycle f64
        assert_eq!(c.peak_flops_per_cycle(false), 16.0);
        // f32 SIMD future-work path doubles it
        assert_eq!(c.peak_flops_per_cycle(true), 32.0);
    }

    #[test]
    fn tile_cost() {
        let c = cluster();
        // 64^3 tile: 524288 FLOP / (16*0.35) = 93622.857 -> 93623
        let expect = (2.0 * 64f64.powi(3) / (16.0 * 0.35)).ceil() as u64;
        assert_eq!(c.gemm_tile_cycles(64, 64, 64, false), Cycles(expect));
    }

    #[test]
    fn f32_tile_twice_as_fast() {
        let c = cluster();
        let f64c = c.gemm_tile_cycles(64, 64, 64, false).0 as f64;
        let f32c = c.gemm_tile_cycles(64, 64, 64, true).0 as f64;
        assert!((f64c / f32c - 2.0).abs() < 1e-3);
    }

    #[test]
    fn spm_capacity() {
        let c = cluster();
        assert!(c.fits_spm(3 * 64 * 64 * 8)); // 96 KiB tile set
        assert!(!c.fits_spm(128 * 1024 + 1));
        assert_eq!(c.spm_bytes(), 128 * 1024);
    }

    #[test]
    fn stream_cost_linear() {
        let c = cluster();
        let a = c.stream_cycles(1000, 2.0, false).0;
        let b = c.stream_cycles(2000, 2.0, false).0;
        assert!((b as f64 / a as f64 - 2.0).abs() < 0.01);
    }
}
