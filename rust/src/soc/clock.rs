//! Virtual time: cycle counts on the shared SoC clock.
//!
//! The paper measures wall-clock seconds on a 50 MHz FPGA from Python's
//! `os.time()`; our unit of observation is the cycle, converted to
//! nanoseconds for reporting.  [`SimClock`] is a monotonically advancing
//! cycle counter shared by all models through the offload engine.

use std::ops::{Add, AddAssign};

/// A cycle count (always on the single shared SoC clock domain).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Default)]
pub struct Cycles(pub u64);

impl Cycles {
    pub const ZERO: Cycles = Cycles(0);

    /// Ceiling conversion from a fractional cycle cost. Fractional costs
    /// arise from bandwidth models (bytes / bytes-per-cycle); hardware
    /// always rounds up to a whole cycle.
    pub fn from_f64(c: f64) -> Cycles {
        debug_assert!(c >= 0.0 && c.is_finite(), "negative/NaN cycle cost: {c}");
        Cycles(c.ceil() as u64)
    }

    /// Nanoseconds at `freq_hz`.
    pub fn to_ns(self, freq_hz: u64) -> f64 {
        self.0 as f64 * 1e9 / freq_hz as f64
    }

    /// Seconds at `freq_hz`.
    pub fn to_secs(self, freq_hz: u64) -> f64 {
        self.0 as f64 / freq_hz as f64
    }

    pub fn saturating_sub(self, rhs: Cycles) -> Cycles {
        Cycles(self.0.saturating_sub(rhs.0))
    }

    pub fn max(self, rhs: Cycles) -> Cycles {
        Cycles(self.0.max(rhs.0))
    }
}

impl Add for Cycles {
    type Output = Cycles;
    fn add(self, rhs: Cycles) -> Cycles {
        Cycles(self.0 + rhs.0)
    }
}

impl AddAssign for Cycles {
    fn add_assign(&mut self, rhs: Cycles) {
        self.0 += rhs.0;
    }
}

impl std::iter::Sum for Cycles {
    fn sum<I: Iterator<Item = Cycles>>(iter: I) -> Cycles {
        iter.fold(Cycles::ZERO, |a, b| a + b)
    }
}

/// Monotonic virtual clock.
#[derive(Debug, Clone)]
pub struct SimClock {
    freq_hz: u64,
    now: Cycles,
}

impl SimClock {
    pub fn new(freq_hz: u64) -> Self {
        assert!(freq_hz > 0, "clock frequency must be > 0");
        SimClock { freq_hz, now: Cycles::ZERO }
    }

    /// Current virtual time in cycles since reset.
    pub fn now(&self) -> Cycles {
        self.now
    }

    pub fn freq_hz(&self) -> u64 {
        self.freq_hz
    }

    /// Advance virtual time; returns the new now.
    pub fn advance(&mut self, dur: Cycles) -> Cycles {
        self.now += dur;
        self.now
    }

    /// Current virtual time in nanoseconds.
    pub fn now_ns(&self) -> f64 {
        self.now.to_ns(self.freq_hz)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cycles_from_f64_ceils() {
        assert_eq!(Cycles::from_f64(0.0), Cycles(0));
        assert_eq!(Cycles::from_f64(0.1), Cycles(1));
        assert_eq!(Cycles::from_f64(7.0), Cycles(7));
        assert_eq!(Cycles::from_f64(7.0001), Cycles(8));
    }

    #[test]
    fn cycles_to_time() {
        let c = Cycles(50_000_000);
        assert_eq!(c.to_secs(50_000_000), 1.0);
        assert_eq!(c.to_ns(50_000_000), 1e9);
        assert_eq!(Cycles(1).to_ns(50_000_000), 20.0);
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut clk = SimClock::new(50_000_000);
        assert_eq!(clk.now(), Cycles::ZERO);
        clk.advance(Cycles(100));
        clk.advance(Cycles(23));
        assert_eq!(clk.now(), Cycles(123));
        assert_eq!(clk.now_ns(), 123.0 * 20.0);
    }

    #[test]
    fn cycles_sum_and_ops() {
        let total: Cycles = [Cycles(1), Cycles(2), Cycles(3)].into_iter().sum();
        assert_eq!(total, Cycles(6));
        assert_eq!(Cycles(5).saturating_sub(Cycles(9)), Cycles(0));
        assert_eq!(Cycles(5).max(Cycles(9)), Cycles(9));
    }

    #[test]
    #[should_panic(expected = "clock frequency")]
    fn zero_freq_panics() {
        SimClock::new(0);
    }
}
