//! Region-level execution trace.
//!
//! The paper's Figure 3 splits each run into three regions — "data copy",
//! "fork/join" and "compute" — measured from Python.  [`Trace`] records
//! exactly those regions (plus host-compute for the no-offload baseline)
//! against the virtual clock, and is the raw material for the Figure 3
//! harness.

use super::clock::Cycles;

/// Classification of a traced interval (the stacked-bar legend of Fig. 3).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum RegionClass {
    /// Host copies between Linux DRAM and the device DRAM partition
    /// (or IOMMU mapping work in the zero-copy path).
    DataCopy,
    /// OpenBLAS/OpenMP entry + exit, marshalling, doorbell, wake-up, join.
    ForkJoin,
    /// Device DMA + FPU work on SPM-resident tiles.
    Compute,
    /// Host-only compute (the "without offloading" bar has one region).
    HostCompute,
}

impl RegionClass {
    pub const ALL: [RegionClass; 4] = [
        RegionClass::DataCopy,
        RegionClass::ForkJoin,
        RegionClass::Compute,
        RegionClass::HostCompute,
    ];

    pub fn label(self) -> &'static str {
        match self {
            RegionClass::DataCopy => "data_copy",
            RegionClass::ForkJoin => "fork_join",
            RegionClass::Compute => "compute",
            RegionClass::HostCompute => "host_compute",
        }
    }
}

/// One traced interval.
#[derive(Debug, Clone)]
pub struct TraceEvent {
    pub class: RegionClass,
    /// Virtual start time (cycles since trace reset).
    pub start: Cycles,
    pub dur: Cycles,
    /// Human-readable site, e.g. "map_to(a)" or "tile(1,2,0)".
    pub label: String,
}

/// Append-only region trace against the virtual clock.
#[derive(Debug, Default)]
pub struct Trace {
    events: Vec<TraceEvent>,
}

impl Trace {
    pub fn new() -> Self {
        Trace { events: Vec::new() }
    }

    /// Record an interval that started at `start` and lasted `dur`.
    pub fn record(&mut self, class: RegionClass, start: Cycles, dur: Cycles,
                  label: impl Into<String>) {
        self.events.push(TraceEvent { class, start, dur, label: label.into() });
    }

    pub fn events(&self) -> &[TraceEvent] {
        &self.events
    }

    pub fn clear(&mut self) {
        self.events.clear();
    }

    /// Total cycles attributed to one region class.
    pub fn total(&self, class: RegionClass) -> Cycles {
        self.events
            .iter()
            .filter(|e| e.class == class)
            .map(|e| e.dur)
            .sum()
    }

    /// Total traced cycles across all classes.
    pub fn grand_total(&self) -> Cycles {
        self.events.iter().map(|e| e.dur).sum()
    }

    /// Fraction of the grand total spent in `class` (0 if empty).
    pub fn share(&self, class: RegionClass) -> f64 {
        let total = self.grand_total().0;
        if total == 0 {
            return 0.0;
        }
        self.total(class).0 as f64 / total as f64
    }

    /// Region breakdown as (class, cycles) for all non-zero classes.
    pub fn breakdown(&self) -> Vec<(RegionClass, Cycles)> {
        RegionClass::ALL
            .iter()
            .map(|&c| (c, self.total(c)))
            .filter(|(_, cyc)| cyc.0 > 0)
            .collect()
    }

    /// Export as Chrome trace-event JSON (load in chrome://tracing or
    /// Perfetto).  Virtual time is mapped to microseconds at `freq_hz`;
    /// each region class gets its own track (tid).
    pub fn to_chrome_trace(&self, freq_hz: u64) -> String {
        use std::fmt::Write as _;
        let tid = |c: RegionClass| match c {
            RegionClass::DataCopy => 1,
            RegionClass::ForkJoin => 2,
            RegionClass::Compute => 3,
            RegionClass::HostCompute => 4,
        };
        let us = |c: Cycles| c.to_ns(freq_hz) / 1e3;
        let mut out = String::from("[\n");
        for (i, e) in self.events.iter().enumerate() {
            if i > 0 {
                out.push_str(",\n");
            }
            // label is our own ASCII; escape the one char that could break
            let name = e.label.replace('"', "'");
            let _ = write!(
                out,
                "  {{\"name\": \"{}\", \"cat\": \"{}\", \"ph\": \"X\", \
                 \"ts\": {:.3}, \"dur\": {:.3}, \"pid\": 1, \"tid\": {}}}",
                name,
                e.class.label(),
                us(e.start),
                us(e.dur),
                tid(e.class),
            );
        }
        out.push_str("\n]\n");
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn totals_and_shares() {
        let mut t = Trace::new();
        t.record(RegionClass::DataCopy, Cycles(0), Cycles(47), "copy(a)");
        t.record(RegionClass::ForkJoin, Cycles(47), Cycles(30), "entry");
        t.record(RegionClass::Compute, Cycles(77), Cycles(23), "tiles");
        assert_eq!(t.total(RegionClass::DataCopy), Cycles(47));
        assert_eq!(t.grand_total(), Cycles(100));
        assert!((t.share(RegionClass::DataCopy) - 0.47).abs() < 1e-12);
        assert_eq!(t.breakdown().len(), 3);
    }

    #[test]
    fn empty_trace_is_zero() {
        let t = Trace::new();
        assert_eq!(t.grand_total(), Cycles::ZERO);
        assert_eq!(t.share(RegionClass::Compute), 0.0);
        assert!(t.breakdown().is_empty());
    }

    #[test]
    fn regions_sum_to_grand_total() {
        let mut t = Trace::new();
        for (i, c) in RegionClass::ALL.iter().enumerate() {
            t.record(*c, Cycles(i as u64 * 10), Cycles(10), "x");
        }
        let sum: Cycles = RegionClass::ALL.iter().map(|&c| t.total(c)).sum();
        assert_eq!(sum, t.grand_total());
    }

    #[test]
    fn chrome_trace_is_valid_json_with_all_events() {
        let mut t = Trace::new();
        t.record(RegionClass::DataCopy, Cycles(0), Cycles(100), "copy(\"a\")");
        t.record(RegionClass::Compute, Cycles(100), Cycles(50), "tile(0,0,0)");
        let json = t.to_chrome_trace(50_000_000);
        let parsed = crate::util::json_lite::Json::parse(&json).unwrap();
        let arr = parsed.as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].req_str("cat").unwrap(), "data_copy");
        // 100 cycles @ 50 MHz = 2 us
        assert_eq!(arr[0].get("dur").unwrap().as_f64(), Some(2.0));
        assert_eq!(arr[1].get("ts").unwrap().as_f64(), Some(2.0));
    }

    #[test]
    fn clear_resets() {
        let mut t = Trace::new();
        t.record(RegionClass::Compute, Cycles(0), Cycles(5), "x");
        t.clear();
        assert!(t.events().is_empty());
    }
}
