//! Cluster DMA engine (iDMA) cost model.
//!
//! The Snitch cluster refills its L1 SPM from DRAM with an autonomous DMA
//! engine; GEMM tiles are 2-D sub-matrices, so the engine's 2-D mode
//! (per-row address regeneration) is the common case.  Costs are cycles
//! on the shared clock.



use super::clock::Cycles;
use crate::config::DmaConfig;

/// Aggregate statistics (fed into [`crate::metrics`]).
#[derive(Debug, Default, Clone, Copy)]
pub struct DmaStats {
    pub transfers: u64,
    pub bytes: u64,
    pub cycles: u64,
}

/// DMA engine model.
#[derive(Debug, Clone)]
pub struct DmaModel {
    cfg: DmaConfig,
    stats: DmaStats,
}

impl DmaModel {
    pub fn new(cfg: DmaConfig) -> Self {
        DmaModel { cfg, stats: DmaStats::default() }
    }

    /// Cost of a 1-D burst of `bytes`.
    pub fn transfer_1d(&mut self, bytes: u64) -> Cycles {
        let stream = bytes as f64 / self.cfg.bytes_per_cycle;
        let total = Cycles::from_f64(self.cfg.setup_cycles as f64 + stream);
        self.account(bytes, total);
        total
    }

    /// Cost of a 2-D transfer: `rows` rows of `row_bytes` each
    /// (e.g. one 64x64 f64 tile = 64 rows x 512 B).
    pub fn transfer_2d(&mut self, rows: u64, row_bytes: u64) -> Cycles {
        let stream = (rows * row_bytes) as f64 / self.cfg.bytes_per_cycle;
        let total = Cycles::from_f64(
            self.cfg.setup_cycles as f64
                + (rows * self.cfg.per_row_cycles) as f64
                + stream,
        );
        self.account(rows * row_bytes, total);
        total
    }

    /// Pure cost query without accounting (for planning/what-if).
    pub fn cost_2d(&self, rows: u64, row_bytes: u64) -> Cycles {
        Cycles::from_f64(
            self.cfg.setup_cycles as f64
                + (rows * self.cfg.per_row_cycles) as f64
                + (rows * row_bytes) as f64 / self.cfg.bytes_per_cycle,
        )
    }

    fn account(&mut self, bytes: u64, cyc: Cycles) {
        self.stats.transfers += 1;
        self.stats.bytes += bytes;
        self.stats.cycles += cyc.0;
    }

    pub fn stats(&self) -> DmaStats {
        self.stats
    }

    pub fn reset_stats(&mut self) {
        self.stats = DmaStats::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PlatformConfig;

    fn model() -> DmaModel {
        DmaModel::new(PlatformConfig::default().dma)
    }

    #[test]
    fn transfer_1d_cost() {
        let mut d = model();
        // 8 bytes/cycle, 50 setup: 4096 B -> 50 + 512 = 562
        assert_eq!(d.transfer_1d(4096), Cycles(562));
        assert_eq!(d.stats().transfers, 1);
        assert_eq!(d.stats().bytes, 4096);
    }

    #[test]
    fn transfer_2d_adds_row_overhead() {
        let mut d = model();
        // one f64 64x64 tile: 64 rows x 512 B = 32 KiB
        // 50 + 64*4 + 32768/8 = 50 + 256 + 4096 = 4402
        assert_eq!(d.transfer_2d(64, 512), Cycles(4402));
    }

    #[test]
    fn cost_query_matches_transfer_without_accounting() {
        let mut d = model();
        let q = d.cost_2d(64, 512);
        assert_eq!(d.stats().transfers, 0);
        let t = d.transfer_2d(64, 512);
        assert_eq!(q, t);
        assert_eq!(d.stats().transfers, 1);
    }

    #[test]
    fn zero_bytes_costs_setup_only() {
        let mut d = model();
        assert_eq!(d.transfer_1d(0), Cycles(50));
    }

    #[test]
    fn stats_accumulate_and_reset() {
        let mut d = model();
        d.transfer_1d(100);
        d.transfer_2d(2, 50);
        assert_eq!(d.stats().transfers, 2);
        assert_eq!(d.stats().bytes, 200);
        d.reset_stats();
        assert_eq!(d.stats().transfers, 0);
    }
}
