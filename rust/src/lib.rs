//! # hero-blas
//!
//! NumPy-style linear algebra accelerated on a simulated open-source
//! RISC-V heterogeneous SoC — a full-system reproduction of
//! *"Work-In-Progress: Accelerating Numpy With OpenBLAS For Open-Source
//! RISC-V Chips"* (Koenig et al., CS.AR 2025).
//!
//! The stack mirrors the paper's Figure 2, top to bottom:
//!
//! | Paper layer | Module |
//! |---|---|
//! | ⑤ user application (Python) | [`npy`] + `examples/` |
//! | ④ NumPy | [`npy`] |
//! | ③ OpenBLAS (host + device kernels) | [`blas`] |
//! | ② OpenMP target runtime | [`omp`] |
//! | ① LibHero / kernel module | [`hero`] |
//! | platform (CVA6 + Snitch PMCA on VCU128) | [`soc`] |
//!
//! Above the paper's stack sits the serving layer: [`sched`] pools N
//! simulated clusters behind a bounded priority queue with request
//! batching, and [`serve`] feeds it from concurrent TCP connections.
//!
//! Device numerics execute AOT-compiled JAX/Pallas kernels through the
//! PJRT CPU client ([`runtime`]); device *timing* comes from the
//! calibrated SoC cost models ([`soc`]). See `DESIGN.md` for the
//! substitution table and the experiment index.

pub mod blas;
pub mod cblas;
pub mod config;
pub mod cost;
pub mod dag;
pub mod error;
pub mod harness;
pub mod hero;
pub mod kernel;
pub mod metrics;
pub mod npy;
pub mod omp;
pub mod runtime;
pub mod sched;
pub mod serve;
pub mod soc;
pub mod util;

pub use config::{DispatchMode, PlatformConfig, WorkloadConfig};
pub use error::{Error, Result};

/// Default location of the AOT artifacts relative to the repo root.
pub const DEFAULT_ARTIFACTS_DIR: &str = "artifacts";

/// Resolve the artifacts directory: `$HERO_BLAS_ARTIFACTS`, else walk up
/// from the current directory looking for `artifacts/manifest.json`.
pub fn find_artifacts_dir() -> Result<std::path::PathBuf> {
    if let Ok(p) = std::env::var("HERO_BLAS_ARTIFACTS") {
        let p = std::path::PathBuf::from(p);
        if p.join("manifest.json").is_file() {
            return Ok(p);
        }
        return Err(Error::Config(format!(
            "HERO_BLAS_ARTIFACTS={} has no manifest.json",
            p.display()
        )));
    }
    let mut dir = std::env::current_dir()?;
    loop {
        let cand = dir.join(DEFAULT_ARTIFACTS_DIR);
        if cand.join("manifest.json").is_file() {
            return Ok(cand);
        }
        if !dir.pop() {
            return Err(Error::Config(
                "artifacts/manifest.json not found — run `make artifacts`".into(),
            ));
        }
    }
}
