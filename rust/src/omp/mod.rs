//! OpenMP target-offload runtime — our analogue of libomptarget plus the
//! Hero plugin (arrow (2) in the paper's Figure 2).
//!
//! The paper's measured "fork/join" region is exactly this layer: entering
//! the OpenBLAS interface, building the target region, marshalling
//! arguments, the doorbell, and the join on the way out.  The "data copy"
//! region is [`engine::OffloadEngine::map_to`]/[`engine::OffloadEngine::map_from`]
//! in copy mode, or IO-PTE creation in zero-copy mode.

pub mod datamap;
pub mod engine;

pub use datamap::{DataMap, DeviceMapping};
pub use engine::{MappedBuf, OffloadEngine};
