//! OpenMP target-offload runtime — our analogue of libomptarget plus the
//! Hero plugin (arrow (2) in the paper's Figure 2).
//!
//! The paper's measured "fork/join" region is exactly this layer: entering
//! the OpenBLAS interface, building the target region, marshalling
//! arguments, the doorbell, and the join on the way out.  The "data copy"
//! region is [`engine::OffloadEngine::map_to`]/[`engine::OffloadEngine::map_from`]
//! in copy mode, or IO-PTE creation in zero-copy mode.

//! Repeated traffic additionally flows through the device-resident
//! operand cache ([`opcache`]): a `map(to:)` whose bytes are already
//! staged becomes a refcount bump instead of a copy.

pub mod datamap;
pub mod engine;
pub mod opcache;

pub use datamap::{DataMap, DeviceMapping};
pub use engine::{MappedBuf, OffloadEngine};
pub use opcache::{CacheEvent, CacheKey, CacheStats, OperandCache};
