//! The offload engine: orchestrates one OpenMP target region end-to-end
//! against the SoC models, charging every cost to the right Figure-3
//! region (data copy / fork-join / compute) on the virtual clock.
//!
//! Copy mode (paper's measured configuration): `map(to:)` allocates in
//! the device DRAM partition and *actually copies the bytes* into the
//! arena's backing store — the device kernel then reads its inputs from
//! there, so functional correctness exercises the same path the timing
//! model charges for.  Zero-copy mode (paper's future work): `map(to:)`
//! creates IO-PTEs instead and the device reads host memory through the
//! IOMMU, paying IOTLB walks during compute.
//!
//! Read-only operands may additionally route through the device-resident
//! operand cache ([`super::opcache`], [`OffloadEngine::map_to_operand`]):
//! when the `[sched.cache]` config enables it, a `map(to:)` whose exact
//! bytes are already staged becomes a refcount bump instead of a copy,
//! and a beta==0 output buffer is staged `map(alloc:)`-style
//! ([`OffloadEngine::map_alloc`]) without any host copy.  With the cache
//! disabled (the default) both fall back to the plain paths above,
//! bit-identically.

use crate::error::{Error, Result};
use crate::hero::device::Device;
use crate::hero::offload::OffloadDescriptor;
use crate::metrics::Metrics;
use crate::soc::clock::{Cycles, SimClock};
use crate::soc::iommu::{Iommu, Mapping};
use crate::soc::trace::{RegionClass, Trace};
use crate::soc::Platform;

use super::datamap::DataMap;
use super::opcache::{CacheKey, OperandCache};

/// A host buffer mapped into device space (one `map` clause instance).
#[derive(Debug)]
pub struct MappedBuf {
    pub host_addr: u64,
    pub len: u64,
    /// Copy mode: the device-DRAM allocation holding the staged bytes.
    backing: Option<crate::hero::allocator::Allocation>,
    /// Zero-copy mode: the live IOMMU mapping.
    mapping: Option<Mapping>,
    /// Zero-copy only: the host bytes (device accesses host memory
    /// directly; we keep a snapshot to model that access functionally).
    host_bytes: Option<Vec<u8>>,
    /// Set when the backing allocation is owned by the operand cache:
    /// this map holds one pin on the entry, the buffer is read-only to
    /// the device, and unmap releases the pin instead of freeing.
    cache_key: Option<CacheKey>,
}

impl MappedBuf {
    pub fn is_zero_copy(&self) -> bool {
        self.mapping.is_some()
    }

    /// Is the backing buffer owned by the operand cache (read-only)?
    pub fn is_cached(&self) -> bool {
        self.cache_key.is_some()
    }

    /// Cache identity of the backing buffer, when cache-owned — lets the
    /// scheduler tag resident operands for its affinity directory.
    pub fn cache_key(&self) -> Option<CacheKey> {
        self.cache_key
    }

    /// Device-visible address (dev-DRAM or IOVA).
    pub fn device_addr(&self) -> u64 {
        match (&self.backing, &self.mapping) {
            (Some(a), _) => a.addr,
            (_, Some(m)) => m.iova,
            _ => unreachable!("MappedBuf without backing or mapping"),
        }
    }
}

/// Offload engine: one per session; owns clock, trace, device and IOMMU.
#[derive(Debug)]
pub struct OffloadEngine {
    pub platform: Platform,
    clock: SimClock,
    pub trace: Trace,
    pub device: Device,
    pub iommu: Iommu,
    pub datamap: DataMap,
    pub metrics: Metrics,
    /// Device-resident operand cache (capacity from `[sched.cache]`;
    /// disabled — zero capacity — by default).
    pub opcache: OperandCache,
}

impl OffloadEngine {
    /// Build the engine and boot the device (binary copy to L2 + wake-up,
    /// traced as fork/join; Figure 3 measures warm calls, so harnesses
    /// call [`OffloadEngine::reset_run`] after construction).
    pub fn new(platform: Platform) -> Result<Self> {
        let mut device = Device::new(&platform.cfg);
        let iommu = platform.iommu();
        let mut clock = SimClock::new(platform.cfg.clock.freq_hz);
        let mut trace = Trace::new();

        // Device functions of libopenblas.so: ~200 KiB of rv32 text+rodata
        // copied through the host to the dual-port L2 SPM.
        let binary_bytes = 200 * 1024u64;
        let copy_cost = Cycles::from_f64(
            platform.cfg.host.memcpy_setup_cycles as f64
                + binary_bytes as f64 / platform.cfg.host.copy_bytes_per_cycle,
        );
        let boot_cost = device.boot(binary_bytes, copy_cost)?;
        let start = clock.now();
        clock.advance(boot_cost);
        trace.record(RegionClass::ForkJoin, start, boot_cost, "boot");

        let cc = &platform.cfg.sched.cache;
        let opcache = if cc.cache_enabled() {
            OperandCache::new(
                (platform.cfg.memory.dev_dram_bytes as f64 * cc.cache_frac) as u64,
                cc.cache_max_entries as usize,
            )
        } else {
            OperandCache::disabled()
        };

        Ok(OffloadEngine {
            platform,
            clock,
            trace,
            device,
            iommu,
            datamap: DataMap::new(),
            metrics: Metrics::new(),
            opcache,
        })
    }

    /// Is the operand cache (and the staging elisions it gates) active?
    pub fn cache_enabled(&self) -> bool {
        self.opcache.enabled()
    }

    /// Virtual now.
    pub fn now(&self) -> Cycles {
        self.clock.now()
    }

    pub fn freq_hz(&self) -> u64 {
        self.clock.freq_hz()
    }

    /// Clear the per-run trace (keeps device state and metrics).
    pub fn reset_run(&mut self) {
        self.trace.clear();
    }

    /// Charge `dur` to a region class at the current virtual time.
    pub fn charge(&mut self, class: RegionClass, dur: Cycles, label: &str) {
        let start = self.clock.now();
        self.clock.advance(dur);
        self.trace.record(class, start, dur, label);
    }

    // ------------------------------------------------------------------
    // Fork/join region
    // ------------------------------------------------------------------

    /// OpenBLAS interface-layer entry.
    pub fn blas_entry(&mut self) {
        let c = Cycles(self.platform.cfg.forkjoin.openblas_entry_cycles);
        self.charge(RegionClass::ForkJoin, c, "openblas_entry");
    }

    /// libomptarget entry + per-argument marshalling.
    pub fn target_begin(&mut self, nargs: usize) {
        let fj = &self.platform.cfg.forkjoin;
        let c = Cycles(fj.omp_entry_cycles + fj.per_arg_cycles * nargs as u64);
        self.charge(RegionClass::ForkJoin, c, "omp_target_entry");
    }

    /// Doorbell + device wake-up.
    pub fn launch(&mut self, desc: &OffloadDescriptor) -> Result<()> {
        let c = self.device.launch(desc)?;
        self.charge(RegionClass::ForkJoin, c, "launch");
        Ok(())
    }

    /// Device-side completion: the cluster posts its status word through
    /// the mailbox (doorbell back to the host).  After this, the
    /// completion is observable via the mailbox — the scheduler's workers
    /// poll it before joining, which is how one host thread overlaps with
    /// its cluster.
    pub fn device_complete(&mut self) -> Result<()> {
        let c = self.device.complete()?;
        self.charge(RegionClass::ForkJoin, c, "complete");
        Ok(())
    }

    /// Host-side join of an already-posted completion: drain the mailbox
    /// word and pay the return path through the kernel module.
    pub fn join_completed(&mut self) -> Result<()> {
        self.device.wait()?;
        let j = Cycles(self.platform.cfg.forkjoin.join_cycles);
        self.charge(RegionClass::ForkJoin, j, "join");
        self.metrics.offloads += 1;
        Ok(())
    }

    /// Device completion + host-side join (the synchronous path).
    pub fn join(&mut self) -> Result<()> {
        self.device_complete()?;
        self.join_completed()
    }

    /// libomptarget + OpenBLAS exit.
    pub fn target_end(&mut self) {
        let c = Cycles(self.platform.cfg.forkjoin.exit_cycles);
        self.charge(RegionClass::ForkJoin, c, "omp_target_exit");
    }

    // ------------------------------------------------------------------
    // Data-copy region
    // ------------------------------------------------------------------

    /// `map(to:)` — stage a host buffer for the device.
    pub fn map_to(&mut self, data: &[u8], zero_copy: bool, label: &str)
                  -> Result<MappedBuf> {
        self.map_to_charged(data, data.len() as u64, zero_copy, label)
    }

    /// `map(to:)` with an explicit *charged* byte count.
    ///
    /// The device kernel stages zero-padded buffers (tiles are whole), but
    /// the host only ever copies / maps the user's actual bytes — Figure 3's
    /// data-copy region scales with the user problem size, not the padding.
    pub fn map_to_charged(&mut self, data: &[u8], charged_bytes: u64,
                          zero_copy: bool, label: &str) -> Result<MappedBuf> {
        let host_addr = data.as_ptr() as u64;
        let len = data.len() as u64;
        if len == 0 {
            return Err(Error::Offload(format!("map_to({label}): empty buffer")));
        }
        let charged = charged_bytes.min(len).max(1);
        if zero_copy {
            let (mapping, _) = self.iommu.map(host_addr, len)?;
            let charged_pages = self.iommu.pages_for(host_addr, charged);
            let cost = Cycles(
                charged_pages * self.platform.cfg.iommu.pte_create_cycles,
            );
            self.datamap.map(host_addr, mapping.iova, len)?;
            self.charge(RegionClass::DataCopy, cost,
                        &format!("iommu_map({label})"));
            self.metrics.iommu_pages_mapped += charged_pages;
            Ok(MappedBuf {
                host_addr,
                len,
                backing: None,
                mapping: Some(mapping),
                host_bytes: Some(data.to_vec()),
                cache_key: None,
            })
        } else {
            let alloc = self.dram_alloc_reclaiming(len)?;
            self.device.dram.write(&alloc, data)?;
            self.datamap.map(host_addr, alloc.addr, len)?;
            let cost = self.platform.host.memcpy_cycles(charged);
            self.charge(RegionClass::DataCopy, cost,
                        &format!("copy_to({label})"));
            self.metrics.bytes_to_device += charged;
            Ok(MappedBuf {
                host_addr,
                len,
                backing: Some(alloc),
                mapping: None,
                host_bytes: None,
                cache_key: None,
            })
        }
    }

    /// `map(to:)` of a *read-only operand*, eligible for the operand
    /// cache: if the exact bytes are already device-resident the map
    /// degenerates to a refcount bump (one table insert, charged at the
    /// memcpy setup cost) instead of a copy.  With the cache disabled, or
    /// in zero-copy mode, this is exactly [`OffloadEngine::map_to_charged`].
    ///
    /// The caller must never write through the returned mapping
    /// ([`OffloadEngine::write_mapped`] enforces it): the backing buffer
    /// may be shared with other live mappings of the same content.
    pub fn map_to_operand(&mut self, data: &[u8], charged_bytes: u64,
                          zero_copy: bool, label: &str) -> Result<MappedBuf> {
        if zero_copy || !self.opcache.enabled() {
            return self.map_to_charged(data, charged_bytes, zero_copy, label);
        }
        let host_addr = data.as_ptr() as u64;
        let len = data.len() as u64;
        if len == 0 {
            return Err(Error::Offload(format!("map_to({label}): empty buffer")));
        }
        let charged = charged_bytes.min(len).max(1);
        let key = CacheKey::of(data);

        // Verified hit: the resident bytes must equal the incoming ones
        // (a hash collision degrades to a miss, never to wrong numerics).
        if let Some(alloc) = self.opcache.peek(&key) {
            if self.device.dram.read(&alloc, data.len())? == data {
                self.datamap.map(host_addr, alloc.addr, len)?;
                self.opcache.pin_hit(&key);
                let cost = Cycles(self.platform.cfg.host.memcpy_setup_cycles);
                self.charge(RegionClass::DataCopy, cost,
                            &format!("cache_hit({label})"));
                self.metrics.cache_hits += 1;
                self.metrics.bytes_copy_elided += charged;
                return Ok(MappedBuf {
                    host_addr,
                    len,
                    backing: Some(alloc),
                    mapping: None,
                    host_bytes: None,
                    cache_key: Some(key),
                });
            }
        }

        // Miss: stage like the plain path, then register the buffer as
        // resident so the next identical map hits.
        self.opcache.note_miss();
        self.metrics.cache_misses += 1;
        let alloc = self.dram_alloc_reclaiming(len)?;
        self.device.dram.write(&alloc, data)?;
        self.datamap.map(host_addr, alloc.addr, len)?;
        let cost = self.platform.host.memcpy_cycles(charged);
        self.charge(RegionClass::DataCopy, cost, &format!("copy_to({label})"));
        self.metrics.bytes_to_device += charged;
        let outcome = self.opcache.insert(key, alloc);
        self.free_evicted(outcome.evicted)?;
        Ok(MappedBuf {
            host_addr,
            len,
            backing: Some(alloc),
            mapping: None,
            host_bytes: None,
            cache_key: outcome.cached.then_some(key),
        })
    }

    /// `map(alloc:)` — stage an *output* buffer without copying host
    /// bytes: the device gets a zero-filled allocation of `data`'s size
    /// (only the allocation setup is charged).  Correct whenever the
    /// kernel never reads the buffer's incoming contents (beta == 0
    /// epilogues).  `charged_bytes` is what the elision saved, counted in
    /// `bytes_copy_elided`.
    pub fn map_alloc(&mut self, data: &[u8], charged_bytes: u64, label: &str)
                     -> Result<MappedBuf> {
        let host_addr = data.as_ptr() as u64;
        let len = data.len() as u64;
        if len == 0 {
            return Err(Error::Offload(format!("map_alloc({label}): empty buffer")));
        }
        let alloc = self.dram_alloc_reclaiming(len)?;
        self.device.dram.write_zeroes(&alloc)?;
        self.datamap.map(host_addr, alloc.addr, len)?;
        let cost = Cycles(self.platform.cfg.host.memcpy_setup_cycles);
        self.charge(RegionClass::DataCopy, cost, &format!("map_alloc({label})"));
        self.metrics.bytes_copy_elided += charged_bytes.min(len).max(1);
        Ok(MappedBuf {
            host_addr,
            len,
            backing: Some(alloc),
            mapping: None,
            host_bytes: None,
            cache_key: None,
        })
    }

    /// Promote a chained link's *output* into a device-resident input for
    /// the next link: the buffer never returns to the host — only the
    /// residency bookkeeping (one table insert, the same cost a cache hit
    /// charges) is paid, and the elided `map(from:)` bytes are counted in
    /// `chain_bytes_elided`.  The backing allocation is registered in the
    /// operand cache as a pinned entry ([`OperandCache::insert_resident`],
    /// which works regardless of the cache budgets), so from here on the
    /// buffer is read-only to the device ([`OffloadEngine::write_mapped`]
    /// rejects it) and its unmap releases the pin instead of freeing —
    /// with the cache enabled the intermediate stays resident for later
    /// identical maps, with it disabled the release at chain end reclaims
    /// it immediately.  Copy-mode only: a zero-copy output lives in host
    /// memory and has nothing device-resident to keep.
    pub fn promote_output(&mut self, buf: MappedBuf, elided_bytes: u64,
                          label: &str) -> Result<MappedBuf> {
        let charge_label = format!("chain_keep({label})");
        let buf = self.promote_to_resident(buf, &charge_label)?;
        self.metrics.chain_bytes_elided += elided_bytes.max(1);
        Ok(buf)
    }

    /// [`OffloadEngine::promote_output`] for a DAG node output with
    /// consumers: identical mechanics and charge, but the elided
    /// `map(from:)` is counted in `dag_bytes_elided` — a fan-out output
    /// is promoted exactly once however many nodes consume it.
    pub fn promote_output_dag(&mut self, buf: MappedBuf, elided_bytes: u64,
                              label: &str) -> Result<MappedBuf> {
        let charge_label = format!("dag_keep({label})");
        let buf = self.promote_to_resident(buf, &charge_label)?;
        self.metrics.dag_bytes_elided += elided_bytes.max(1);
        Ok(buf)
    }

    /// Publish a finished DAG sink for cross-request fusion: same
    /// residency mechanics as a promotion, but nothing was elided *this*
    /// request — the output still copies back to the host — so no
    /// elision counter moves.  A fused follow-up request's `map(to:)` of
    /// the identical bytes then verifies against this entry and becomes
    /// a refcount bump (`cache_hits`/`bytes_copy_elided` count it there).
    pub fn publish_output(&mut self, buf: MappedBuf, label: &str)
                          -> Result<MappedBuf> {
        let charge_label = format!("dag_publish({label})");
        self.promote_to_resident(buf, &charge_label)
    }

    /// Shared promotion core: register a copy-mode output buffer as a
    /// pinned operand-cache entry without any data movement, charging
    /// one table insert (the same cost a cache hit charges).
    fn promote_to_resident(&mut self, mut buf: MappedBuf, charge_label: &str)
                           -> Result<MappedBuf> {
        if buf.is_zero_copy() {
            return Err(Error::Offload(format!(
                "{charge_label}: zero-copy buffers cannot stay device-resident"
            )));
        }
        if buf.is_cached() {
            return Err(Error::Offload(format!(
                "{charge_label}: buffer is already cache-shared"
            )));
        }
        let alloc = *buf.backing.as_ref().expect("copy-mode buffer has backing");
        // Content key of the *device* bytes — host-side bookkeeping (the
        // buffer-identity tracking a real runtime would do), not charged.
        let bytes = self.device.dram.read(&alloc, buf.len as usize)?.to_vec();
        let key = CacheKey::of(&bytes);
        let cost = Cycles(self.platform.cfg.host.memcpy_setup_cycles);
        self.charge(RegionClass::DataCopy, cost, charge_label);
        let outcome = self.opcache.insert_resident(key, alloc);
        if outcome.cached {
            buf.cache_key = Some(key);
        }
        // a duplicate key keeps the buffer privately owned — the chain
        // reads it through its staged index either way, so numerics are
        // unaffected; only the post-chain residency is lost
        self.free_evicted(outcome.evicted)?;
        Ok(buf)
    }

    /// Account a chained link consuming the previous link's resident
    /// output as its input: the `map(to:)` is elided — only the mapping
    /// bookkeeping is charged — and the elided bytes are counted in
    /// `chain_bytes_elided`.
    pub fn note_chain_reuse(&mut self, elided_bytes: u64, label: &str) {
        let cost = Cycles(self.platform.cfg.host.memcpy_setup_cycles);
        self.charge(RegionClass::DataCopy, cost, &format!("chain_reuse({label})"));
        self.metrics.chain_bytes_elided += elided_bytes.max(1);
    }

    /// Account a DAG node consuming a promoted producer output in place:
    /// one `map(to:)` elided per interior edge, counted in
    /// `dag_bytes_elided` (charged once per consumer, so a two-way
    /// fan-out books the promotion plus two reuses).
    pub fn note_dag_reuse(&mut self, elided_bytes: u64, label: &str) {
        let cost = Cycles(self.platform.cfg.host.memcpy_setup_cycles);
        self.charge(RegionClass::DataCopy, cost, &format!("dag_reuse({label})"));
        self.metrics.dag_bytes_elided += elided_bytes.max(1);
    }

    /// Allocate device DRAM; on OOM, evict unpinned cache entries (LRU
    /// first) and retry once, so cache residency never fails a staging
    /// that would have succeeded without the cache.
    fn dram_alloc_reclaiming(&mut self, len: u64)
                             -> Result<crate::hero::allocator::Allocation> {
        match self.device.dram.alloc(len) {
            Ok(a) => Ok(a),
            Err(first) => {
                let evicted = self.opcache.evict_for(len);
                if evicted.is_empty() {
                    return Err(first);
                }
                self.free_evicted(evicted)?;
                self.device.dram.alloc(len)
            }
        }
    }

    /// Fault recovery: drop every unpinned operand-cache entry and return
    /// the freed allocations to the arena.  Returns the bytes reclaimed
    /// (the `cache_invalidated_bytes` counter feed).  Host-side
    /// bookkeeping — nothing is charged to the virtual clock; the real
    /// cost the fault path pays is re-staging everything on retry.
    pub fn invalidate_cache(&mut self) -> Result<u64> {
        let evicted = self.opcache.invalidate_all();
        let bytes: u64 = evicted.iter().map(|a| a.len).sum();
        self.free_evicted(evicted)?;
        Ok(bytes)
    }

    /// Return evicted cache allocations to the arena.
    fn free_evicted(&mut self, evicted: Vec<crate::hero::allocator::Allocation>)
                    -> Result<()> {
        for a in evicted {
            debug_assert_eq!(
                self.datamap.device_refs(a.addr),
                0,
                "evicted a device buffer with live mappings"
            );
            self.device.dram.free(a)?;
            self.metrics.cache_evictions += 1;
        }
        Ok(())
    }

    /// `map(from:)` — bring results back to the host buffer.
    pub fn map_from(&mut self, buf: &MappedBuf, out: &mut [u8], label: &str)
                    -> Result<()> {
        self.map_from_charged(buf, out, buf.len, label)
    }

    /// `map(from:)` with an explicit charged byte count (see
    /// [`OffloadEngine::map_to_charged`]).
    pub fn map_from_charged(&mut self, buf: &MappedBuf, out: &mut [u8],
                            charged_bytes: u64, label: &str) -> Result<()> {
        if out.len() as u64 != buf.len {
            return Err(Error::Offload(format!(
                "map_from({label}): length mismatch ({} vs {})",
                out.len(),
                buf.len
            )));
        }
        let charged = charged_bytes.min(buf.len).max(1);
        if let Some(alloc) = &buf.backing {
            let bytes = self.device.dram.read(alloc, out.len())?;
            out.copy_from_slice(bytes);
            let cost = self.platform.host.memcpy_cycles(charged);
            self.charge(RegionClass::DataCopy, cost,
                        &format!("copy_from({label})"));
            self.metrics.bytes_from_device += charged;
        } else {
            // zero-copy: the device already wrote host memory through the
            // IOMMU — the "copy back" is free.
            let bytes = buf.host_bytes.as_ref().ok_or_else(|| {
                Error::Offload(format!("map_from({label}): no device data"))
            })?;
            out.copy_from_slice(bytes);
        }
        Ok(())
    }

    /// Release a mapping (device DRAM free or IO-PTE teardown).  A
    /// cache-owned buffer is NOT freed: the map's pin on the cache entry
    /// is dropped and the bytes stay resident for the next identical
    /// `map(to:)` (LRU eviction reclaims them later).
    pub fn unmap(&mut self, buf: MappedBuf, label: &str) -> Result<()> {
        let released = self.datamap.unmap(buf.host_addr)?;
        if let Some(key) = buf.cache_key {
            // one pin per MappedBuf, regardless of datamap refcounts
            let evicted = self.opcache.release(&key);
            self.free_evicted(evicted)?;
            return Ok(());
        }
        if released.is_none() {
            return Ok(()); // still referenced elsewhere
        }
        if let Some(alloc) = buf.backing {
            self.device.dram.free(alloc)?;
        }
        if let Some(mapping) = buf.mapping {
            let cost = self.iommu.unmap(&mapping);
            self.charge(RegionClass::DataCopy, cost,
                        &format!("iommu_unmap({label})"));
        }
        Ok(())
    }

    // ------------------------------------------------------------------
    // Compute region (device-side access during the kernel)
    // ------------------------------------------------------------------

    /// Read the device-visible bytes of a mapped buffer (what the cluster
    /// DMA would fetch).  Copy mode: the dev-DRAM backing.  Zero-copy:
    /// host memory through the IOMMU.
    pub fn read_mapped(&mut self, buf: &MappedBuf, offset: usize, len: usize)
                       -> Result<Vec<u8>> {
        if (offset + len) as u64 > buf.len {
            return Err(Error::Offload(format!(
                "device read past end of mapping ({} + {} > {})",
                offset, len, buf.len
            )));
        }
        if let Some(alloc) = &buf.backing {
            Ok(self.device.dram.read_at(alloc, offset, len)?.to_vec())
        } else {
            let mapping = buf.mapping.as_ref().expect("zero-copy has mapping");
            let cost = self
                .iommu
                .stream_translate_cost(mapping.iova + offset as u64, len as u64)?;
            self.charge(RegionClass::Compute, cost, "iotlb");
            let bytes = buf.host_bytes.as_ref().expect("zero-copy snapshot");
            Ok(bytes[offset..offset + len].to_vec())
        }
    }

    /// Write device results into a mapped buffer.
    pub fn write_mapped(&mut self, buf: &mut MappedBuf, offset: usize,
                        data: &[u8]) -> Result<()> {
        if (offset + data.len()) as u64 > buf.len {
            return Err(Error::Offload("device write past end of mapping".into()));
        }
        if buf.is_cached() {
            // the backing may be shared with other mappings of the same
            // content — outputs must never stage through the cache
            return Err(Error::Offload(
                "device write to a cache-shared read-only mapping".into(),
            ));
        }
        if let Some(alloc) = &buf.backing {
            self.device.dram.write_at(alloc, offset, data)?;
            Ok(())
        } else {
            let mapping = buf.mapping.as_ref().expect("zero-copy has mapping");
            let cost = self.iommu.stream_translate_cost(
                mapping.iova + offset as u64,
                data.len() as u64,
            )?;
            self.charge(RegionClass::Compute, cost, "iotlb");
            let bytes = buf.host_bytes.as_mut().expect("zero-copy snapshot");
            bytes[offset..offset + data.len()].copy_from_slice(data);
            Ok(())
        }
    }

    /// Error-path recovery: abort any in-flight offload so the session
    /// stays usable after a failed device call (allocator OOM, fault).
    pub fn abort_offload(&mut self) {
        self.device.abort();
    }

    /// Charge device compute time (DMA-overlapped tile bursts).
    pub fn charge_compute(&mut self, dur: Cycles, label: &str) {
        self.charge(RegionClass::Compute, dur, label);
    }

    /// Charge host compute time (the no-offload baseline).
    pub fn charge_host_compute(&mut self, dur: Cycles, label: &str) {
        self.charge(RegionClass::HostCompute, dur, label);
        self.metrics.host_calls += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PlatformConfig;
    use crate::hero::offload::{OffloadDescriptor, OffloadKind};

    fn engine() -> OffloadEngine {
        let platform = Platform::new(PlatformConfig::default());
        OffloadEngine::new(platform).unwrap()
    }

    #[test]
    fn boot_is_traced_then_reset() {
        let mut e = engine();
        assert!(e.trace.grand_total().0 > 0);
        e.reset_run();
        assert_eq!(e.trace.grand_total(), Cycles::ZERO);
    }

    #[test]
    fn copy_mode_roundtrip_preserves_bytes() {
        let mut e = engine();
        e.reset_run();
        let data: Vec<u8> = (0..1024u32).map(|i| (i % 251) as u8).collect();
        let buf = e.map_to(&data, false, "a").unwrap();
        assert!(!buf.is_zero_copy());
        // device reads what the host staged
        assert_eq!(e.read_mapped(&buf, 100, 16).unwrap(), &data[100..116]);
        // device writes, host copies back
        let mut buf = buf;
        e.write_mapped(&mut buf, 0, &[9u8; 8]).unwrap();
        let mut out = vec![0u8; 1024];
        e.map_from(&buf, &mut out, "a").unwrap();
        assert_eq!(&out[..8], &[9u8; 8]);
        assert_eq!(&out[8..], &data[8..]);
        e.unmap(buf, "a").unwrap();
        // copies were charged to the DataCopy region
        assert!(e.trace.total(RegionClass::DataCopy).0 > 0);
        assert_eq!(e.metrics.bytes_to_device, 1024);
        assert_eq!(e.metrics.bytes_from_device, 1024);
    }

    #[test]
    fn zero_copy_roundtrip_charges_ptes_not_copies() {
        let mut e = engine();
        e.reset_run();
        let data = vec![7u8; 8192];
        let buf = e.map_to(&data, true, "a").unwrap();
        assert!(buf.is_zero_copy());
        let copy_region = e.trace.total(RegionClass::DataCopy);
        // PTE creation cost: ceil over pages touched
        let pages = e.iommu.pages_for(data.as_ptr() as u64, 8192);
        assert_eq!(copy_region, Cycles(pages * 2025));
        assert_eq!(e.metrics.bytes_to_device, 0);
        // device access pays IOTLB walks in the Compute region
        let before = e.trace.total(RegionClass::Compute);
        e.read_mapped(&buf, 0, 8192).unwrap();
        assert!(e.trace.total(RegionClass::Compute) > before);
        let mut out = vec![0u8; 8192];
        e.map_from(&buf, &mut out, "a").unwrap();
        assert_eq!(out, data);
        e.unmap(buf, "a").unwrap();
        assert_eq!(e.iommu.live_pages(), 0);
    }

    #[test]
    fn full_offload_sequence_regions() {
        let mut e = engine();
        e.reset_run();
        let a = vec![1u8; 512];
        e.blas_entry();
        e.target_begin(1);
        let buf = e.map_to(&a, false, "a").unwrap();
        let mut desc = OffloadDescriptor::new(OffloadKind::Gemm, (8, 8, 8), false);
        desc.push_arg(crate::hero::offload::OffloadArg {
            device_addr: buf.device_addr(),
            len: buf.len,
            via_iommu: false,
        });
        e.launch(&desc).unwrap();
        e.charge_compute(Cycles(1000), "tiles");
        e.join().unwrap();
        e.unmap(buf, "a").unwrap();
        e.target_end();

        let fj = e.trace.total(RegionClass::ForkJoin).0;
        let dc = e.trace.total(RegionClass::DataCopy).0;
        let cp = e.trace.total(RegionClass::Compute).0;
        assert!(fj > 0 && dc > 0 && cp == 1000);
        assert_eq!(e.trace.grand_total().0, fj + dc + cp);
        assert_eq!(e.metrics.offloads, 1);
    }

    #[test]
    fn split_join_exposes_completion_word() {
        let mut e = engine();
        e.reset_run();
        let desc = OffloadDescriptor::new(OffloadKind::Gemm, (8, 8, 8), false);
        e.launch(&desc).unwrap();
        assert_eq!(e.device.mailbox.pending_for_host(), 0);
        e.device_complete().unwrap();
        // the completion word is pollable before the host joins
        assert_eq!(e.device.mailbox.pending_for_host(), 1);
        e.join_completed().unwrap();
        assert_eq!(e.device.mailbox.pending_for_host(), 0);
        assert_eq!(e.metrics.offloads, 1);
        // joining again without a launch is an error, not a hang
        assert!(e.join_completed().is_err());
    }

    #[test]
    fn map_from_length_mismatch_rejected() {
        let mut e = engine();
        let data = vec![0u8; 64];
        let buf = e.map_to(&data, false, "x").unwrap();
        let mut out = vec![0u8; 32];
        assert!(e.map_from(&buf, &mut out, "x").is_err());
    }

    #[test]
    fn read_past_end_rejected() {
        let mut e = engine();
        let data = vec![0u8; 64];
        let buf = e.map_to(&data, false, "x").unwrap();
        assert!(e.read_mapped(&buf, 60, 8).is_err());
    }

    #[test]
    fn empty_map_rejected() {
        let mut e = engine();
        assert!(e.map_to(&[], false, "x").is_err());
        assert!(e.map_to_operand(&[], 0, false, "x").is_err());
        assert!(e.map_alloc(&[], 0, "x").is_err());
    }

    /// Engine over a small DRAM partition with the operand cache on.
    fn cached_engine(dev_dram_bytes: u64, frac: f64, max_entries: u32)
                     -> OffloadEngine {
        let mut cfg = PlatformConfig::default();
        cfg.memory.dev_dram_bytes = dev_dram_bytes;
        cfg.sched.cache.cache_frac = frac;
        cfg.sched.cache.cache_max_entries = max_entries;
        let mut e = OffloadEngine::new(Platform::new(cfg)).unwrap();
        e.reset_run();
        e
    }

    #[test]
    fn operand_cache_hit_is_refcount_bump_not_copy() {
        let mut e = cached_engine(1 << 20, 0.5, 8);
        let content: Vec<u8> = (0..4096u32).map(|i| (i % 251) as u8).collect();
        let other = content.clone(); // identical bytes, different host addr

        let b1 = e.map_to_operand(&content, 4096, false, "b").unwrap();
        assert!(b1.is_cached());
        assert_eq!(e.metrics.cache_misses, 1);
        assert_eq!(e.metrics.bytes_to_device, 4096);
        let copy_cost = e.trace.total(RegionClass::DataCopy);

        let b2 = e.map_to_operand(&other, 4096, false, "b").unwrap();
        assert!(b2.is_cached());
        assert_eq!(b1.device_addr(), b2.device_addr(), "hit reuses the buffer");
        assert_eq!(e.metrics.cache_hits, 1);
        assert_eq!(e.metrics.bytes_to_device, 4096, "no second copy");
        assert_eq!(e.metrics.bytes_copy_elided, 4096);
        // the hit charged only the table-insert setup cost
        let hit_cost = e.trace.total(RegionClass::DataCopy).0 - copy_cost.0;
        assert_eq!(hit_cost, e.platform.cfg.host.memcpy_setup_cycles);
        // both mappings read the same staged bytes
        assert_eq!(e.read_mapped(&b2, 100, 16).unwrap(), &content[100..116]);

        // unmap both: entry stays resident, next map still hits
        e.unmap(b1, "b").unwrap();
        e.unmap(b2, "b").unwrap();
        let b3 = e.map_to_operand(&content, 4096, false, "b").unwrap();
        assert_eq!(e.metrics.cache_hits, 2);
        e.unmap(b3, "b").unwrap();
        assert_eq!(e.metrics.cache_evictions, 0);
    }

    #[test]
    fn cache_disabled_is_bit_identical_to_plain_map() {
        // cache_frac = 0 (the default): map_to_operand must behave
        // exactly like map_to_charged, twice over
        let mut off = engine();
        off.reset_run();
        let data = vec![3u8; 8192];
        let copy = vec![3u8; 8192];
        let b1 = off.map_to_operand(&data, 8192, false, "a").unwrap();
        let b2 = off.map_to_operand(&copy, 8192, false, "a").unwrap();
        assert!(!b1.is_cached() && !b2.is_cached());
        assert_ne!(b1.device_addr(), b2.device_addr());
        assert_eq!(off.metrics.cache_hits, 0);
        assert_eq!(off.metrics.cache_misses, 0);
        assert_eq!(off.metrics.bytes_copy_elided, 0);
        assert_eq!(off.metrics.bytes_to_device, 2 * 8192);
        assert!(off.opcache.is_empty());

        let mut plain = engine();
        plain.reset_run();
        let p1 = plain.map_to_charged(&data, 8192, false, "a").unwrap();
        let p2 = plain.map_to_charged(&copy, 8192, false, "a").unwrap();
        assert_eq!(
            off.trace.total(RegionClass::DataCopy),
            plain.trace.total(RegionClass::DataCopy),
            "disabled cache must charge identical copy time"
        );
        off.unmap(b1, "a").unwrap();
        off.unmap(b2, "a").unwrap();
        plain.unmap(p1, "a").unwrap();
        plain.unmap(p2, "a").unwrap();
        assert_eq!(off.device.dram.stats().bytes_in_use, 0);
    }

    #[test]
    fn eviction_never_frees_live_mappings() {
        // capacity: two 64 KiB entries (256 KiB DRAM * 0.5)
        let mut e = cached_engine(256 << 10, 0.5, 8);
        let mk = |b: u8| vec![b; 64 << 10];
        let (da, db, dc) = (mk(1), mk(2), mk(3));

        let a = e.map_to_operand(&da, 1, false, "a").unwrap(); // pinned
        let b = e.map_to_operand(&db, 1, false, "b").unwrap(); // pinned
        // third operand overflows the cache budget, but a and b are
        // pinned by live mappings: nothing may be evicted
        let c = e.map_to_operand(&dc, 1, false, "c").unwrap();
        assert_eq!(e.metrics.cache_evictions, 0);
        assert!(e.datamap.device_refs(a.device_addr()) > 0);
        assert_eq!(e.read_mapped(&a, 0, 4).unwrap(), &da[..4]);

        // unmap a: it becomes evictable, and trimming back to budget
        // reclaims exactly the unpinned LRU entry
        let a_addr = a.device_addr();
        e.unmap(a, "a").unwrap();
        assert_eq!(e.metrics.cache_evictions, 1);
        assert_eq!(e.datamap.device_refs(a_addr), 0);
        // the still-live mappings are untouched
        assert_eq!(e.read_mapped(&b, 0, 4).unwrap(), &db[..4]);
        assert_eq!(e.read_mapped(&c, 0, 4).unwrap(), &dc[..4]);
        e.unmap(b, "b").unwrap();
        e.unmap(c, "c").unwrap();
        e.device.dram.check_invariants().unwrap();
    }

    #[test]
    fn oom_reclaims_unpinned_cache_entries() {
        // 256 KiB DRAM, cache may hold up to 0.9 of it
        let mut e = cached_engine(256 << 10, 0.9, 8);
        let big = vec![7u8; 128 << 10];
        let b = e.map_to_operand(&big, 1, false, "b").unwrap();
        e.unmap(b, "b").unwrap(); // resident, unpinned (fits 0.9 budget)
        assert_eq!(e.metrics.cache_evictions, 0);

        // a non-cacheable allocation needing more than the free space
        // forces the OOM-reclaim path to evict the resident entry
        let out = vec![0u8; 192 << 10];
        let buf = e.map_to_charged(&out, 1, false, "c").unwrap();
        assert_eq!(e.metrics.cache_evictions, 1);
        e.unmap(buf, "c").unwrap();
        assert_eq!(e.device.dram.stats().bytes_in_use, 0);
    }

    #[test]
    fn map_alloc_stages_zeroed_output_without_copy() {
        let mut e = cached_engine(1 << 20, 0.5, 8);
        let host_c = vec![9u8; 4096]; // nonzero host bytes, never copied
        let mut c = e.map_alloc(&host_c, 4096, "c").unwrap();
        assert!(!c.is_cached());
        assert_eq!(e.metrics.bytes_to_device, 0);
        assert_eq!(e.metrics.bytes_copy_elided, 4096);
        assert_eq!(e.read_mapped(&c, 0, 16).unwrap(), &[0u8; 16][..]);
        // outputs stay writable
        e.write_mapped(&mut c, 0, &[5u8; 8]).unwrap();
        let mut out = vec![0u8; 4096];
        e.map_from_charged(&c, &mut out, 4096, "c").unwrap();
        assert_eq!(&out[..8], &[5u8; 8]);
        e.unmap(c, "c").unwrap();
        assert_eq!(e.device.dram.stats().bytes_in_use, 0);
    }

    #[test]
    fn promote_output_keeps_bytes_resident_without_copy_back() {
        let mut e = cached_engine(1 << 20, 0.5, 8);
        let host_c = vec![0u8; 4096];
        let mut c = e.map_alloc(&host_c, 4096, "c").unwrap();
        e.write_mapped(&mut c, 0, &[7u8; 4096]).unwrap();
        let copies_before = e.metrics.bytes_from_device;
        let addr = c.device_addr();

        let kept = e.promote_output(c, 4096, "c").unwrap();
        assert!(kept.is_cached(), "promoted output registers in the cache");
        assert_eq!(kept.device_addr(), addr, "no data movement");
        assert_eq!(e.metrics.bytes_from_device, copies_before, "map(from:) elided");
        assert_eq!(e.metrics.chain_bytes_elided, 4096);
        assert_eq!(e.opcache.total_pins(), 1);
        // promoted buffers are inputs now: writes must be rejected
        let mut kept = kept;
        assert!(e.write_mapped(&mut kept, 0, &[1u8; 8]).is_err());
        // the device still reads the produced bytes
        assert_eq!(e.read_mapped(&kept, 0, 8).unwrap(), &[7u8; 8][..]);

        // chain end: unmap releases the pin; entry stays resident (cache
        // on) so an identical map(to:) hits without a copy
        let bytes = e.read_mapped(&kept, 0, 4096).unwrap();
        e.unmap(kept, "c").unwrap();
        assert_eq!(e.opcache.total_pins(), 0);
        let again = e.map_to_operand(&bytes, 4096, false, "c").unwrap();
        assert_eq!(e.metrics.cache_hits, 1, "resident intermediate is reusable");
        e.unmap(again, "c").unwrap();
    }

    #[test]
    fn promote_output_with_cache_disabled_reclaims_at_release() {
        let mut e = engine(); // cache_frac = 0
        e.reset_run();
        let host_c = vec![0u8; 1024];
        let c = e.map_to_charged(&host_c, 1024, false, "c").unwrap();
        let kept = e.promote_output(c, 1024, "c").unwrap();
        assert!(kept.is_cached(), "resident even with zero budgets");
        assert_eq!(e.opcache.total_pins(), 1);
        e.unmap(kept, "c").unwrap();
        assert_eq!(e.opcache.total_pins(), 0);
        assert!(e.opcache.is_empty(), "zero-budget cache reclaims at chain end");
        assert_eq!(e.device.dram.stats().bytes_in_use, 0);
        assert_eq!(e.metrics.chain_bytes_elided, 1024);
    }

    #[test]
    fn dag_promotion_counts_its_own_elisions_and_publish_counts_none() {
        let mut e = cached_engine(1 << 20, 0.5, 8);
        let host_c = vec![0u8; 4096];
        let mut c = e.map_alloc(&host_c, 4096, "c").unwrap();
        e.write_mapped(&mut c, 0, &[7u8; 4096]).unwrap();
        let kept = e.promote_output_dag(c, 4096, "c").unwrap();
        assert!(kept.is_cached());
        assert_eq!(e.metrics.dag_bytes_elided, 4096);
        assert_eq!(e.metrics.chain_bytes_elided, 0, "counters stay separate");
        // one reuse per consumer, same counter
        e.note_dag_reuse(4096, "a");
        e.note_dag_reuse(4096, "a");
        assert_eq!(e.metrics.dag_bytes_elided, 3 * 4096);
        e.unmap(kept, "c").unwrap();
        assert_eq!(e.opcache.total_pins(), 0);

        // publish: same residency, no elision counters — the fused
        // consumer's verified cache hit books the elision instead
        let mut d = e.map_alloc(&host_c, 4096, "d").unwrap();
        e.write_mapped(&mut d, 0, &[9u8; 4096]).unwrap();
        let produced = e.read_mapped(&d, 0, 4096).unwrap();
        let pub_buf = e.publish_output(d, "d").unwrap();
        assert!(pub_buf.is_cached());
        assert_eq!(e.metrics.dag_bytes_elided, 3 * 4096, "publish elides nothing");
        e.unmap(pub_buf, "d").unwrap(); // pin released, bytes stay resident
        let hits_before = e.metrics.cache_hits;
        let again = e.map_to_operand(&produced, 4096, false, "x").unwrap();
        assert_eq!(e.metrics.cache_hits, hits_before + 1, "fusion hit");
        e.unmap(again, "x").unwrap();
    }

    #[test]
    fn invalidate_cache_reclaims_resident_bytes() {
        let mut e = cached_engine(1 << 20, 0.5, 8);
        let data = vec![1u8; 4096];
        let b = e.map_to_operand(&data, 4096, false, "b").unwrap();
        e.unmap(b, "b").unwrap(); // resident, unpinned
        assert!(!e.opcache.is_empty());
        let bytes = e.invalidate_cache().unwrap();
        assert_eq!(bytes, 4096);
        assert!(e.opcache.is_empty());
        assert_eq!(e.device.dram.stats().bytes_in_use, 0);
        assert_eq!(e.metrics.cache_evictions, 1);
        // idempotent on an empty cache
        assert_eq!(e.invalidate_cache().unwrap(), 0);
        // the next identical map is a miss and re-stages from host bytes
        let b = e.map_to_operand(&data, 4096, false, "b").unwrap();
        assert_eq!(e.metrics.cache_hits, 0);
        assert_eq!(e.metrics.cache_misses, 2);
        e.unmap(b, "b").unwrap();
    }

    #[test]
    fn write_to_cached_mapping_rejected() {
        let mut e = cached_engine(1 << 20, 0.5, 8);
        let data = vec![1u8; 1024];
        let mut b = e.map_to_operand(&data, 1024, false, "b").unwrap();
        assert!(e.write_mapped(&mut b, 0, &[2u8; 4]).is_err());
        e.unmap(b, "b").unwrap();
    }
}
