//! Host-pointer -> device-pointer mapping table (libomptarget's
//! `DeviceTy::DataMap` equivalent).
//!
//! OpenMP `map(to:)`/`map(from:)` clauses are reference-counted: mapping
//! the same host range twice must reuse the device copy and only the
//! outermost unmap releases it.

use std::collections::HashMap;

use crate::error::{Error, Result};

/// One live host->device association.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DeviceMapping {
    pub device_addr: u64,
    pub len: u64,
    pub refcount: u32,
}

/// The mapping table.
#[derive(Debug, Default)]
pub struct DataMap {
    entries: HashMap<u64, DeviceMapping>,
}

impl DataMap {
    pub fn new() -> Self {
        DataMap { entries: HashMap::new() }
    }

    /// Register (or re-reference) a mapping. Returns `true` if this is a
    /// fresh mapping (i.e. the caller must actually move data / create
    /// PTEs), `false` if an existing one was re-referenced.
    pub fn map(&mut self, host_addr: u64, device_addr: u64, len: u64) -> Result<bool> {
        if let Some(e) = self.entries.get_mut(&host_addr) {
            if e.len != len {
                return Err(Error::Offload(format!(
                    "remap of host 0x{host_addr:x} with different length \
                     ({} vs {len})",
                    e.len
                )));
            }
            e.refcount += 1;
            return Ok(false);
        }
        self.entries.insert(
            host_addr,
            DeviceMapping { device_addr, len, refcount: 1 },
        );
        Ok(true)
    }

    /// Translate a host address (exact-base lookup, like libomptarget).
    pub fn lookup(&self, host_addr: u64) -> Option<&DeviceMapping> {
        self.entries.get(&host_addr)
    }

    /// Drop one reference. Returns the mapping if this released the last
    /// reference (the caller then frees device memory / tears down PTEs).
    pub fn unmap(&mut self, host_addr: u64) -> Result<Option<DeviceMapping>> {
        let e = self.entries.get_mut(&host_addr).ok_or_else(|| {
            Error::Offload(format!("unmap of unmapped host 0x{host_addr:x}"))
        })?;
        e.refcount -= 1;
        if e.refcount == 0 {
            return Ok(self.entries.remove(&host_addr));
        }
        Ok(None)
    }

    pub fn live_mappings(&self) -> usize {
        self.entries.len()
    }

    /// Summed refcounts of live mappings that target a device address.
    /// The operand cache shares one device buffer across *different* host
    /// addresses with identical content, so a device buffer may be
    /// referenced by several table entries at once — eviction safety
    /// checks (and tests) use this to assert a buffer with any live
    /// reference is never freed.
    pub fn device_refs(&self, device_addr: u64) -> u32 {
        self.entries
            .values()
            .filter(|e| e.device_addr == device_addr)
            .map(|e| e.refcount)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fresh_then_rereference() {
        let mut dm = DataMap::new();
        assert!(dm.map(0x1000, 0xA000_0000, 512).unwrap());
        assert!(!dm.map(0x1000, 0xDEAD, 512).unwrap()); // re-ref keeps old addr
        assert_eq!(dm.lookup(0x1000).unwrap().device_addr, 0xA000_0000);
        assert_eq!(dm.lookup(0x1000).unwrap().refcount, 2);
    }

    #[test]
    fn unmap_releases_only_at_zero() {
        let mut dm = DataMap::new();
        dm.map(0x1000, 0xA000_0000, 512).unwrap();
        dm.map(0x1000, 0xA000_0000, 512).unwrap();
        assert!(dm.unmap(0x1000).unwrap().is_none());
        let released = dm.unmap(0x1000).unwrap().unwrap();
        assert_eq!(released.device_addr, 0xA000_0000);
        assert_eq!(dm.live_mappings(), 0);
    }

    #[test]
    fn remap_with_different_len_rejected() {
        let mut dm = DataMap::new();
        dm.map(0x1000, 0xA000_0000, 512).unwrap();
        assert!(dm.map(0x1000, 0xA000_0000, 1024).is_err());
    }

    #[test]
    fn unmap_unknown_rejected() {
        let mut dm = DataMap::new();
        assert!(dm.unmap(0x42).is_err());
    }

    #[test]
    fn device_refs_sum_across_host_addresses() {
        let mut dm = DataMap::new();
        // two distinct host buffers share one cached device buffer
        dm.map(0x1000, 0xA000_0000, 512).unwrap();
        dm.map(0x2000, 0xA000_0000, 512).unwrap();
        dm.map(0x1000, 0xA000_0000, 512).unwrap(); // re-reference
        assert_eq!(dm.device_refs(0xA000_0000), 3);
        dm.unmap(0x1000).unwrap();
        dm.unmap(0x1000).unwrap();
        assert_eq!(dm.device_refs(0xA000_0000), 1);
        assert_eq!(dm.device_refs(0xB000_0000), 0);
    }

    #[test]
    fn distinct_hosts_independent() {
        let mut dm = DataMap::new();
        dm.map(0x1000, 0xA000_0000, 512).unwrap();
        dm.map(0x2000, 0xA000_0200, 512).unwrap();
        assert_eq!(dm.live_mappings(), 2);
        dm.unmap(0x1000).unwrap();
        assert!(dm.lookup(0x2000).is_some());
        assert!(dm.lookup(0x1000).is_none());
    }
}
