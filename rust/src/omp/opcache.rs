//! Device-resident operand cache: content/shape-keyed LRU over the
//! device-DRAM arena.
//!
//! The paper's Figure-3 crossover is set by offload overhead, and the
//! dominant per-request cost in the serving stack is data movement:
//! every GEMM re-stages its operands into the cluster's DRAM slice even
//! when the identical bytes (a reused weight matrix, the serving hot
//! path) were copied moments earlier for the previous request.  This
//! cache keeps `map(to:)` buffers resident after their outermost unmap
//! so a re-map of identical content becomes a refcount bump instead of a
//! copy — the HERO lesson that copy-based offload bandwidth, not FLOPs,
//! is the bottleneck on this class of SoC.
//!
//! Keying is by content (64-bit FNV-1a) *and* length; the engine
//! verifies the resident bytes against the incoming buffer before
//! declaring a hit, so a hash collision degrades to a miss, never to
//! wrong numerics.  (The hash stands in for the buffer-identity tracking
//! a real runtime would do — host-side bookkeeping, so it is not charged
//! to the virtual clock.)
//!
//! Entries referenced by a live [`super::datamap::DataMap`] mapping are
//! *pinned* (one pin per live `MappedBuf`); eviction — LRU, triggered by
//! the byte budget (`cache_frac` of the cluster's DRAM slice), the entry
//! cap, or an allocator OOM — only ever frees unpinned entries, so a
//! buffer the device may still read is never reclaimed.  The cache owns
//! no arena: it hands evicted [`Allocation`]s back to the caller, which
//! frees them against `hero::allocator` (the engine does this and counts
//! the eviction).

use crate::hero::allocator::Allocation;

/// Content/shape identity of one staged operand.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct CacheKey {
    pub len: u64,
    pub hash: u64,
}

impl CacheKey {
    /// Key a host buffer by length + FNV-1a content hash.
    pub fn of(data: &[u8]) -> CacheKey {
        CacheKey { len: data.len() as u64, hash: fnv1a(data) }
    }
}

/// 64-bit FNV-1a — cheap, dependency-free, good enough as a first-level
/// filter (the engine byte-verifies candidate hits).
pub fn fnv1a(data: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in data {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// One resident operand.
#[derive(Debug)]
struct Entry {
    key: CacheKey,
    alloc: Allocation,
    /// Live `MappedBuf`s referencing this entry (one pin per map).
    pins: u32,
    /// Monotone LRU stamp (bumped on every hit / insert).
    stamp: u64,
    /// Placement tag: the scheduler's affinity directory keys this entry
    /// by a request-level operand id (see `crate::sched::affinity`), so
    /// an eviction can be reported back and the cluster drops out of the
    /// directory's residency set for that operand.
    tag: Option<u64>,
}

/// Point-in-time cache statistics (accumulated since construction).
#[derive(Debug, Default, Clone, Copy)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub insertions: u64,
    pub evictions: u64,
}

/// One observable cache transition, delivered synchronously to the
/// installed event hook.  The cache layer stays ignorant of who is
/// listening — the scheduler's worker installs a hook that forwards
/// these into the pool's flight recorder with its own cluster id, so
/// the `omp` layer never grows a dependency on `sched`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CacheEvent {
    /// A verified, pinned hit (`bytes` = resident allocation length).
    Hit { bytes: u64 },
    /// A lookup that will stage from host bytes.
    Miss,
    /// An unpinned entry reclaimed by LRU/OOM/invalidate.
    Evict { bytes: u64 },
}

/// Boxed observer with a hand-written `Debug` so the cache keeps its
/// derived `Debug` (closures have none).
struct EventHook(Box<dyn Fn(CacheEvent) + Send + Sync>);

impl std::fmt::Debug for EventHook {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("EventHook(..)")
    }
}

/// The per-cluster operand cache.
#[derive(Debug)]
pub struct OperandCache {
    entries: Vec<Entry>,
    /// Byte budget (fraction of the cluster's DRAM slice); 0 disables.
    capacity_bytes: u64,
    /// Entry-count budget; 0 disables.
    max_entries: usize,
    clock: u64,
    stats: CacheStats,
    /// Placement tags of entries evicted since the last drain — the
    /// residency-change feed for the scheduler's affinity directory.
    evicted_tags: Vec<u64>,
    /// Optional transition observer (the flight-recorder bridge).
    hook: Option<EventHook>,
}

impl OperandCache {
    pub fn new(capacity_bytes: u64, max_entries: usize) -> OperandCache {
        OperandCache {
            entries: Vec::new(),
            capacity_bytes,
            max_entries,
            clock: 0,
            stats: CacheStats::default(),
            evicted_tags: Vec::new(),
            hook: None,
        }
    }

    /// Install the transition observer (replaces any previous one).
    /// Events fire synchronously from the mutating call, so the hook
    /// must be cheap and reentrancy-free — the flight recorder's
    /// lock-free append qualifies.
    pub fn set_event_hook(
        &mut self,
        hook: impl Fn(CacheEvent) + Send + Sync + 'static,
    ) {
        self.hook = Some(EventHook(Box::new(hook)));
    }

    fn emit(&self, ev: CacheEvent) {
        if let Some(h) = &self.hook {
            (h.0)(ev);
        }
    }

    /// A cache that never holds anything (cache_frac = 0).
    pub fn disabled() -> OperandCache {
        OperandCache::new(0, 0)
    }

    pub fn enabled(&self) -> bool {
        self.capacity_bytes > 0 && self.max_entries > 0
    }

    pub fn stats(&self) -> CacheStats {
        self.stats
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Bytes currently resident (pinned + unpinned).
    pub fn bytes_resident(&self) -> u64 {
        self.entries.iter().map(|e| e.alloc.len).sum()
    }

    /// Candidate lookup WITHOUT pinning or stats: the engine byte-verifies
    /// the resident allocation against the incoming buffer first.
    pub fn peek(&self, key: &CacheKey) -> Option<Allocation> {
        self.entries.iter().find(|e| e.key == *key).map(|e| e.alloc)
    }

    /// Commit a verified hit: pin the entry and refresh its LRU stamp.
    pub fn pin_hit(&mut self, key: &CacheKey) {
        self.clock += 1;
        let clock = self.clock;
        let mut hit_bytes = None;
        if let Some(e) = self.entries.iter_mut().find(|e| e.key == *key) {
            e.pins += 1;
            e.stamp = clock;
            self.stats.hits += 1;
            hit_bytes = Some(e.alloc.len);
        }
        if let Some(bytes) = hit_bytes {
            self.emit(CacheEvent::Hit { bytes });
        }
    }

    /// Record a miss (the caller stages the bytes itself).
    pub fn note_miss(&mut self) {
        self.stats.misses += 1;
        self.emit(CacheEvent::Miss);
    }

    /// Register a freshly staged allocation as resident, pinned once by
    /// the `MappedBuf` being created.  Returns allocations evicted to
    /// respect the byte/entry budgets — the caller must free them against
    /// the arena.  A duplicate key (two in-flight maps of identical
    /// content that both missed) leaves the older entry authoritative and
    /// tells the caller to treat the new allocation as uncached.
    #[must_use]
    pub fn insert(&mut self, key: CacheKey, alloc: Allocation) -> InsertOutcome {
        if !self.enabled() {
            return InsertOutcome { cached: false, evicted: Vec::new() };
        }
        if self.entries.iter().any(|e| e.key == key) {
            // Older entry wins; the caller keeps its private allocation.
            return InsertOutcome { cached: false, evicted: Vec::new() };
        }
        self.clock += 1;
        self.entries.push(Entry { key, alloc, pins: 1, stamp: self.clock, tag: None });
        self.stats.insertions += 1;
        InsertOutcome { cached: true, evicted: self.trim() }
    }

    /// Register a *chained output* as resident: unlike [`OperandCache::insert`]
    /// this works even when the cache budgets are zero, because chain
    /// residency is a correctness-neutral, explicitly short-lived state —
    /// the entry is born pinned (the producing link's `MappedBuf` holds
    /// the pin) and the pin is dropped at chain end, at which point a
    /// disabled/over-budget cache reclaims it on the very next trim.
    /// With the cache enabled the intermediate simply stays resident
    /// under normal LRU, so a later identical `map(to:)` can still hit.
    /// A duplicate key leaves the older entry authoritative (the caller
    /// keeps private ownership, exactly like `insert`).
    #[must_use]
    pub fn insert_resident(&mut self, key: CacheKey, alloc: Allocation) -> InsertOutcome {
        if self.entries.iter().any(|e| e.key == key) {
            return InsertOutcome { cached: false, evicted: Vec::new() };
        }
        self.clock += 1;
        self.entries.push(Entry { key, alloc, pins: 1, stamp: self.clock, tag: None });
        self.stats.insertions += 1;
        InsertOutcome { cached: true, evicted: self.trim() }
    }

    /// Live pins across all entries — zero whenever no mapping (staged
    /// batch, in-flight chain, prefetch) is outstanding.  The scheduler's
    /// workers assert this between batches so a cancelled or failed chain
    /// can never strand a pinned (hence unevictable) intermediate.
    pub fn total_pins(&self) -> u64 {
        self.entries.iter().map(|e| e.pins as u64).sum()
    }

    /// Attach a placement tag to a resident entry (no-op when the key is
    /// absent).  The scheduler's worker tags the entries backing tracked
    /// operands right after staging; when LRU/OOM eviction later drops a
    /// tagged entry, the tag lands in the residency-change feed
    /// ([`OperandCache::take_evicted_tags`]).
    pub fn set_tag(&mut self, key: &CacheKey, tag: u64) {
        if let Some(e) = self.entries.iter_mut().find(|e| e.key == *key) {
            e.tag = Some(tag);
        }
    }

    /// Drain the placement tags of entries evicted since the last call —
    /// the affinity directory clears those (cluster, operand) residency
    /// bits so routing stops steering requests at a cold cluster.
    pub fn take_evicted_tags(&mut self) -> Vec<u64> {
        std::mem::take(&mut self.evicted_tags)
    }

    /// Drop one pin (a cached `MappedBuf` was unmapped).  The entry stays
    /// resident; returns any allocations evicted while trimming back to
    /// budget now that the entry may be reclaimable.
    #[must_use]
    pub fn release(&mut self, key: &CacheKey) -> Vec<Allocation> {
        if let Some(e) = self.entries.iter_mut().find(|e| e.key == *key) {
            debug_assert!(e.pins > 0, "release of unpinned cache entry");
            e.pins = e.pins.saturating_sub(1);
        }
        self.trim()
    }

    /// Evict unpinned entries (LRU first) until at least `need_bytes` of
    /// allocation length has been reclaimed or nothing unpinned remains.
    /// Used on allocator OOM so the cache never turns a workload that fit
    /// yesterday into one that OOMs today.
    #[must_use]
    pub fn evict_for(&mut self, need_bytes: u64) -> Vec<Allocation> {
        let mut out = Vec::new();
        let mut freed = 0u64;
        while freed < need_bytes {
            match self.evict_lru_unpinned() {
                Some(a) => {
                    freed += a.len;
                    out.push(a);
                }
                None => break,
            }
        }
        out
    }

    /// Evict *every* unpinned entry — fault recovery: after a cluster
    /// faults mid-batch its resident operands are treated as suspect and
    /// dropped wholesale, so a retry elsewhere (or a later probe batch
    /// here) re-stages from host bytes instead of trusting device DRAM.
    /// Tagged entries land in the eviction feed as usual so the affinity
    /// directory stops advertising this cluster as warm.  Pinned entries
    /// survive (a live mapping may still reference them) — the worker
    /// abandons the staged batch *before* invalidating, so at the call
    /// site nothing is pinned.
    #[must_use]
    pub fn invalidate_all(&mut self) -> Vec<Allocation> {
        let mut out = Vec::new();
        while let Some(a) = self.evict_lru_unpinned() {
            out.push(a);
        }
        out
    }

    /// Evict LRU unpinned entries until the byte and entry budgets hold.
    /// Pinned entries never count as evictable, so a burst of live
    /// mappings may transiently overshoot the budgets.
    fn trim(&mut self) -> Vec<Allocation> {
        let mut out = Vec::new();
        loop {
            let over_bytes = self.bytes_resident() > self.capacity_bytes;
            let over_entries = self.entries.len() > self.max_entries;
            if !over_bytes && !over_entries {
                break;
            }
            match self.evict_lru_unpinned() {
                Some(a) => out.push(a),
                None => break, // everything left is pinned
            }
        }
        out
    }

    fn evict_lru_unpinned(&mut self) -> Option<Allocation> {
        let idx = self
            .entries
            .iter()
            .enumerate()
            .filter(|(_, e)| e.pins == 0)
            .min_by_key(|(_, e)| e.stamp)
            .map(|(i, _)| i)?;
        self.stats.evictions += 1;
        let entry = self.entries.remove(idx);
        if let Some(tag) = entry.tag {
            self.evicted_tags.push(tag);
        }
        self.emit(CacheEvent::Evict { bytes: entry.alloc.len });
        Some(entry.alloc)
    }

    /// Test/debug invariant: pins non-negative is structural; check no
    /// duplicate keys and that resident bytes match entry allocations.
    pub fn check_invariants(&self) -> bool {
        for (i, a) in self.entries.iter().enumerate() {
            for b in self.entries.iter().skip(i + 1) {
                if a.key == b.key {
                    return false;
                }
            }
        }
        true
    }

    /// Pins on a key (0 when absent) — lets tests assert pin accounting.
    pub fn pins(&self, key: &CacheKey) -> u32 {
        self.entries.iter().find(|e| e.key == *key).map_or(0, |e| e.pins)
    }
}

/// What [`OperandCache::insert`] did with the new allocation.
#[derive(Debug)]
pub struct InsertOutcome {
    /// True: the allocation is now cache-owned (free it only via
    /// eviction).  False: the caller keeps ownership (free on unmap).
    pub cached: bool,
    /// Allocations evicted to make room; the caller frees them.
    pub evicted: Vec<Allocation>,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn alloc(addr: u64, len: u64) -> Allocation {
        Allocation { offset: addr, len, addr }
    }

    fn key(b: u8) -> CacheKey {
        CacheKey::of(&[b; 64])
    }

    #[test]
    fn content_keying_distinguishes_bytes_and_lengths() {
        assert_eq!(CacheKey::of(&[1, 2, 3]), CacheKey::of(&[1, 2, 3]));
        assert_ne!(CacheKey::of(&[1, 2, 3]), CacheKey::of(&[1, 2, 4]));
        assert_ne!(CacheKey::of(&[0; 8]), CacheKey::of(&[0; 16]));
    }

    #[test]
    fn hit_miss_evict_sequence() {
        let mut c = OperandCache::new(128, 8); // room for two 64 B entries
        assert!(c.insert(key(1), alloc(0x100, 64)).cached);
        assert!(c.insert(key(2), alloc(0x200, 64)).cached);
        // release both pins: entries stay resident
        assert!(c.release(&key(1)).is_empty());
        assert!(c.release(&key(2)).is_empty());
        assert_eq!(c.len(), 2);

        // re-map of entry 1: verified hit refreshes LRU
        assert_eq!(c.peek(&key(1)).unwrap().addr, 0x100);
        c.pin_hit(&key(1));
        assert!(c.release(&key(1)).is_empty());

        // a third entry overflows the byte budget: LRU (entry 2) goes
        let out = c.insert(key(3), alloc(0x300, 64));
        assert!(out.cached);
        assert_eq!(out.evicted.len(), 1);
        assert_eq!(out.evicted[0].addr, 0x200);
        assert_eq!(c.stats().evictions, 1);
        assert_eq!(c.stats().hits, 1);
        assert!(c.peek(&key(2)).is_none());
        assert!(c.peek(&key(1)).is_some(), "recently hit entry survives");
        assert!(c.check_invariants());
    }

    #[test]
    fn pinned_entries_never_evicted() {
        let mut c = OperandCache::new(64, 1); // budget for one entry
        assert!(c.insert(key(1), alloc(0x100, 64)).cached); // pinned (live map)
        // inserting a second entry overflows both budgets, but entry 1 is
        // pinned and entry 2 is pinned: nothing evictable
        let out = c.insert(key(2), alloc(0x200, 64));
        assert!(out.cached);
        assert!(out.evicted.is_empty(), "pinned entries must not be evicted");
        assert_eq!(c.len(), 2); // transient overshoot is allowed

        // releasing entry 2 makes it the only evictable one; trim reclaims it
        let evicted = c.release(&key(2));
        assert_eq!(evicted.len(), 1);
        assert_eq!(evicted[0].addr, 0x200);
        assert_eq!(c.pins(&key(1)), 1);
        assert!(c.peek(&key(1)).is_some());

        // OOM-driven eviction also refuses pinned entries
        assert!(c.evict_for(64).is_empty());
        let _ = c.release(&key(1));
        assert_eq!(c.evict_for(64).len(), 1);
        assert!(c.is_empty());
    }

    #[test]
    fn duplicate_insert_keeps_older_entry() {
        let mut c = OperandCache::new(1024, 8);
        assert!(c.insert(key(1), alloc(0x100, 64)).cached);
        let out = c.insert(key(1), alloc(0x900, 64));
        assert!(!out.cached, "duplicate key: caller keeps its allocation");
        assert_eq!(c.peek(&key(1)).unwrap().addr, 0x100);
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn disabled_cache_caches_nothing() {
        let mut c = OperandCache::disabled();
        assert!(!c.enabled());
        let out = c.insert(key(1), alloc(0x100, 64));
        assert!(!out.cached && out.evicted.is_empty());
        assert!(c.peek(&key(1)).is_none());
        assert_eq!(c.stats().insertions, 0);
    }

    #[test]
    fn evicted_tags_feed_residency_changes() {
        let mut c = OperandCache::new(128, 8); // room for two 64 B entries
        assert!(c.insert(key(1), alloc(0x100, 64)).cached);
        c.set_tag(&key(1), 0xAA);
        c.set_tag(&key(9), 0xFF); // absent key: no-op
        assert!(c.insert(key(2), alloc(0x200, 64)).cached); // untagged
        assert!(c.release(&key(1)).is_empty());
        assert!(c.release(&key(2)).is_empty());

        // third entry evicts LRU (entry 1, tagged): its tag is reported
        let out = c.insert(key(3), alloc(0x300, 64));
        assert_eq!(out.evicted.len(), 1);
        assert_eq!(c.take_evicted_tags(), vec![0xAA]);
        assert!(c.take_evicted_tags().is_empty(), "drain clears the feed");

        // untagged evictions report nothing
        let _ = c.release(&key(3));
        let out = c.insert(key(4), alloc(0x400, 64));
        assert_eq!(out.evicted.len(), 1); // entry 2 (untagged LRU)
        assert!(c.take_evicted_tags().is_empty());
    }

    #[test]
    fn insert_resident_works_with_the_cache_disabled() {
        // chain residency must not depend on the [sched.cache] budgets:
        // the entry lives (pinned) for the duration of the chain and is
        // reclaimed on release when the budgets are zero
        let mut c = OperandCache::disabled();
        let out = c.insert_resident(key(1), alloc(0x100, 64));
        assert!(out.cached && out.evicted.is_empty(), "pinned entry survives trim");
        assert_eq!(c.len(), 1);
        assert_eq!(c.pins(&key(1)), 1);
        assert_eq!(c.total_pins(), 1);
        // chain end: the pin drops and the zero-budget cache reclaims it
        let evicted = c.release(&key(1));
        assert_eq!(evicted.len(), 1);
        assert_eq!(evicted[0].addr, 0x100);
        assert!(c.is_empty());
        assert_eq!(c.total_pins(), 0);
    }

    #[test]
    fn insert_resident_stays_resident_when_enabled() {
        let mut c = OperandCache::new(1024, 8);
        assert!(c.insert_resident(key(1), alloc(0x100, 64)).cached);
        assert!(c.release(&key(1)).is_empty(), "within budget: stays resident");
        assert_eq!(c.len(), 1);
        assert_eq!(c.total_pins(), 0);
        // the resident intermediate is now a plain LRU entry: a duplicate
        // insert keeps the older one authoritative
        let out = c.insert_resident(key(1), alloc(0x900, 64));
        assert!(!out.cached);
        assert_eq!(c.peek(&key(1)).unwrap().addr, 0x100);
    }

    #[test]
    fn invalidate_all_drops_unpinned_and_reports_tags() {
        let mut c = OperandCache::new(1024, 8);
        assert!(c.insert(key(1), alloc(0x100, 64)).cached);
        c.set_tag(&key(1), 0xAA);
        assert!(c.insert(key(2), alloc(0x200, 64)).cached);
        assert!(c.release(&key(2)).is_empty());
        // key 1 still pinned (a live mapping): it must survive
        let evicted = c.invalidate_all();
        assert_eq!(evicted.len(), 1);
        assert_eq!(evicted[0].addr, 0x200);
        assert!(c.peek(&key(1)).is_some());
        // after the pin drops, invalidation reclaims it and its tag feeds
        // the residency-change drain
        assert!(c.release(&key(1)).is_empty());
        let evicted = c.invalidate_all();
        assert_eq!(evicted.len(), 1);
        assert_eq!(c.take_evicted_tags(), vec![0xAA]);
        assert!(c.is_empty());
    }

    #[test]
    fn event_hook_observes_hits_misses_and_evictions() {
        use std::sync::{Arc, Mutex};
        let seen: Arc<Mutex<Vec<CacheEvent>>> = Arc::default();
        let mut c = OperandCache::new(128, 8);
        let sink = Arc::clone(&seen);
        c.set_event_hook(move |ev| sink.lock().unwrap().push(ev));

        c.note_miss();
        assert!(c.insert(key(1), alloc(0x100, 64)).cached);
        assert!(c.release(&key(1)).is_empty());
        c.pin_hit(&key(1));
        c.pin_hit(&key(9)); // absent: no event
        assert!(c.release(&key(1)).is_empty());
        assert!(c.insert(key(2), alloc(0x200, 64)).cached);
        // third entry overflows the byte budget: LRU eviction fires
        let out = c.insert(key(3), alloc(0x300, 64));
        assert_eq!(out.evicted.len(), 1);

        let got = seen.lock().unwrap().clone();
        assert_eq!(
            got,
            vec![
                CacheEvent::Miss,
                CacheEvent::Hit { bytes: 64 },
                CacheEvent::Evict { bytes: 64 },
            ]
        );
    }

    #[test]
    fn lru_order_follows_hits() {
        let mut c = OperandCache::new(192, 8); // three 64 B entries
        for b in 1..=3u8 {
            assert!(c.insert(key(b), alloc(0x100 * b as u64, 64)).cached);
            assert!(c.release(&key(b)).is_empty());
        }
        // touch 1 (oldest) so 2 becomes LRU
        c.pin_hit(&key(1));
        let _ = c.release(&key(1));
        let out = c.insert(key(4), alloc(0x400, 64));
        assert_eq!(out.evicted.len(), 1);
        assert_eq!(out.evicted[0].addr, 0x200);
    }
}
