//! Typed dataflow-graph IR for the serving DAG executor.
//!
//! [`DagShape`] is the shape-level description of one request: nodes are
//! gemm / gemv / axpy / dot ops with optional bias/ReLU epilogues, edges
//! are resident-buffer dependencies.  Node specs are **topologically
//! ordered by construction** — a node may only consume outputs of nodes
//! with a *smaller* index (or the external input `x`), so acyclicity is
//! structural: a backward or self edge is rejected as a cycle, never
//! walked.  Fan-out is a node output with several consumers (the
//! executor promotes it once and pins it until the last consumer ran);
//! fan-in is an axpy/dot node over two inputs.
//!
//! This module sits below both `blas` (lowering) and `cost`
//! (estimation) so the one IR is shared by validation, dispatch,
//! placement footprints and the device executor — it depends on
//! nothing else in the crate.

use std::fmt;

/// Node op kinds the executor lowers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DagOp {
    /// (m x k) @ (k x n) matmul; the only op carrying an output width.
    Gemm,
    /// (m x k) @ (k x 1): lowered through the gemm walk with n = 1.
    Gemv,
    /// Element-wise fan-in add of two same-width activations.
    Axpy,
    /// Fan-in reduction Σ a·b to one scalar; must be a sink.
    Dot,
}

impl DagOp {
    /// Serve-protocol name.
    pub fn name(self) -> &'static str {
        match self {
            DagOp::Gemm => "gemm",
            DagOp::Gemv => "gemv",
            DagOp::Axpy => "axpy",
            DagOp::Dot => "dot",
        }
    }

    /// Parse a serve-protocol name.
    pub fn from_name(s: &str) -> Option<DagOp> {
        match s {
            "gemm" => Some(DagOp::Gemm),
            "gemv" => Some(DagOp::Gemv),
            "axpy" => Some(DagOp::Axpy),
            "dot" => Some(DagOp::Dot),
            _ => None,
        }
    }

    /// Does this op stage a weight operand and run the gemm tile walk?
    pub fn is_matmul(self) -> bool {
        matches!(self, DagOp::Gemm | DagOp::Gemv)
    }
}

impl fmt::Display for DagOp {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// One node of a [`DagShape`].  `src`/`src2` are producer node indices;
/// `None` consumes the DAG's external input `x` (m x d0).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DagNodeShape {
    pub op: DagOp,
    /// First input: a smaller node index, or `None` for the external x.
    pub src: Option<usize>,
    /// Second input (axpy/dot only).
    pub src2: Option<usize>,
    /// Output width for gemm (ignored for gemv/axpy/dot).
    pub n: usize,
    /// Add a per-row bias before `relu` (gemm/gemv only).
    pub bias: bool,
    /// Clamp at zero after the bias (gemm/gemv only).
    pub relu: bool,
}

/// The shape of one DAG request: an (m x d0) external input and a
/// topologically-ordered node list.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DagShape {
    pub m: usize,
    pub d0: usize,
    pub nodes: Vec<DagNodeShape>,
}

impl DagShape {
    /// Output width of every node, in index order.  Robust against
    /// not-yet-validated specs: a non-forward edge falls back to `d0`
    /// (validation rejects it before anything consumes the number).
    pub fn widths(&self) -> Vec<usize> {
        let mut w = Vec::with_capacity(self.nodes.len());
        for (i, node) in self.nodes.iter().enumerate() {
            let input = |s: Option<usize>| -> usize {
                match s {
                    Some(j) if j < i => w[j],
                    _ => self.d0,
                }
            };
            w.push(match node.op {
                DagOp::Gemm => node.n,
                DagOp::Gemv | DagOp::Dot => 1,
                DagOp::Axpy => input(node.src),
            });
        }
        w
    }

    /// Width of node `i`'s first input (the activation a matmul walks).
    pub fn in_width(&self, i: usize) -> usize {
        let w = self.widths();
        match self.nodes[i].src {
            Some(j) if j < i => w[j],
            _ => self.d0,
        }
    }

    /// (rows, cols) of node `i`'s user-visible output.
    pub fn out_dims(&self, i: usize) -> (usize, usize) {
        match self.nodes[i].op {
            DagOp::Dot => (1, 1),
            _ => (self.m, self.widths()[i]),
        }
    }

    /// How many nodes consume each node's output (edges from `src` and
    /// `src2`; the external input is not counted).
    pub fn consumer_counts(&self) -> Vec<u32> {
        let mut counts = vec![0u32; self.nodes.len()];
        for (i, node) in self.nodes.iter().enumerate() {
            for s in [node.src, node.src2].into_iter().flatten() {
                if s < i {
                    counts[s] += 1;
                }
            }
        }
        counts
    }

    /// Nodes with no consumers, in index order — the DAG's outputs.
    pub fn sinks(&self) -> Vec<usize> {
        self.consumer_counts()
            .iter()
            .enumerate()
            .filter(|(_, &c)| c == 0)
            .map(|(i, _)| i)
            .collect()
    }

    /// Per-node depth (longest path from the external input, in nodes).
    pub fn depths(&self) -> Vec<u32> {
        let mut d = Vec::with_capacity(self.nodes.len());
        for (i, node) in self.nodes.iter().enumerate() {
            let of = |s: Option<usize>| -> u32 {
                match s {
                    Some(j) if j < i => d[j],
                    _ => 0,
                }
            };
            d.push(1 + of(node.src).max(of(node.src2)));
        }
        d
    }

    /// Longest path length in nodes.
    pub fn depth(&self) -> u32 {
        self.depths().into_iter().max().unwrap_or(0)
    }

    /// Is this a linear, gemm-only, single-consumer pipeline — i.e.
    /// exactly what `gemm_chain` expresses?  Such DAGs lower to the
    /// identical charge sequence as the chain path by construction.
    pub fn is_linear_gemm(&self) -> bool {
        !self.nodes.is_empty()
            && self.nodes.iter().enumerate().all(|(i, n)| {
                n.op == DagOp::Gemm
                    && n.src2.is_none()
                    && n.src == if i == 0 { None } else { Some(i - 1) }
            })
    }

    /// The equivalent chain layer-width list `[d0, n1, .., nL]` when
    /// this DAG is a linear gemm pipeline.
    pub fn chain_dims(&self) -> Option<Vec<usize>> {
        if !self.is_linear_gemm() {
            return None;
        }
        let mut dims = vec![self.d0];
        dims.extend(self.nodes.iter().map(|n| n.n));
        Some(dims)
    }

    /// Marshalled offload arguments: x plus 2 per matmul node (B + C)
    /// and 1 per axpy/dot node (C only).
    pub fn marshalled_args(&self) -> usize {
        1 + self
            .nodes
            .iter()
            .map(|n| if n.op.is_matmul() { 2 } else { 1 })
            .sum::<usize>()
    }

    /// Shape validation under the `[sched.dag]` bounds.  Every rejection
    /// names the offending node id, its op and the violated bound —
    /// unlike `validate_chain`'s anonymous errors.
    pub fn validate(
        &self,
        max_nodes: u32,
        max_width: u32,
        max_depth: u32,
    ) -> Result<(), String> {
        if self.nodes.is_empty() {
            return Err("dag has no nodes (need at least 1)".into());
        }
        if self.nodes.len() as u32 > max_nodes {
            return Err(format!(
                "dag has {} nodes; [sched.dag] max_nodes = {max_nodes}",
                self.nodes.len()
            ));
        }
        if self.m == 0 || self.d0 == 0 {
            return Err(format!(
                "dag input is {}x{}; m and d0 must be nonzero",
                self.m, self.d0
            ));
        }
        for (i, node) in self.nodes.iter().enumerate() {
            let op = node.op;
            for s in [node.src, node.src2].into_iter().flatten() {
                if s >= i {
                    return Err(format!(
                        "node {i} ({op}): edge from node {s} is not a forward \
                         edge — specs are topologically ordered, so this is a \
                         cycle"
                    ));
                }
                if self.nodes[s].op == DagOp::Dot {
                    return Err(format!(
                        "node {i} ({op}): consumes node {s} (dot), but dot \
                         yields a scalar and must be a sink"
                    ));
                }
            }
            match op {
                DagOp::Gemm => {
                    if node.n == 0 {
                        return Err(format!(
                            "node {i} (gemm): output width must be nonzero"
                        ));
                    }
                    if node.src2.is_some() {
                        return Err(format!(
                            "node {i} (gemm): src2 applies to fan-in \
                             (axpy/dot) nodes only"
                        ));
                    }
                }
                DagOp::Gemv => {
                    if node.src2.is_some() {
                        return Err(format!(
                            "node {i} (gemv): src2 applies to fan-in \
                             (axpy/dot) nodes only"
                        ));
                    }
                }
                DagOp::Axpy | DagOp::Dot => {
                    if node.bias || node.relu {
                        return Err(format!(
                            "node {i} ({op}): bias/relu epilogues are \
                             gemm/gemv-only"
                        ));
                    }
                    let w = self.widths();
                    let of = |s: Option<usize>| match s {
                        Some(j) => w[j],
                        None => self.d0,
                    };
                    let (a, b) = (of(node.src), of(node.src2));
                    if a != b {
                        return Err(format!(
                            "node {i} ({op}): fan-in inputs are {a} and {b} \
                             wide — they must match"
                        ));
                    }
                }
            }
        }
        let depths = self.depths();
        let mut per_level = std::collections::HashMap::new();
        for (i, &d) in depths.iter().enumerate() {
            let op = self.nodes[i].op;
            if d > max_depth {
                return Err(format!(
                    "node {i} ({op}): dag depth {d} exceeds [sched.dag] \
                     max_depth = {max_depth}"
                ));
            }
            let c = per_level.entry(d).or_insert(0u32);
            *c += 1;
            if *c > max_width {
                return Err(format!(
                    "node {i} ({op}): {c} nodes at depth {d} exceeds \
                     [sched.dag] max_width = {max_width}"
                ));
            }
        }
        Ok(())
    }
}

/// A linear gemm chain `[d0, n1, .., nL]` as a [`DagShape`] — the
/// promotion direction ROADMAP item 2 calls for, used by tests and the
/// chain-compatibility paths.
pub fn linear_gemm_shape(m: usize, dims: &[usize]) -> DagShape {
    let nodes = dims
        .windows(2)
        .enumerate()
        .map(|(i, w)| DagNodeShape {
            op: DagOp::Gemm,
            src: if i == 0 { None } else { Some(i - 1) },
            src2: None,
            n: w[1],
            bias: false,
            relu: false,
        })
        .collect();
    DagShape { m, d0: dims.first().copied().unwrap_or(0), nodes }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gemm(src: Option<usize>, n: usize) -> DagNodeShape {
        DagNodeShape { op: DagOp::Gemm, src, src2: None, n, bias: false, relu: false }
    }

    fn two_head() -> DagShape {
        // x -> trunk gemm -> {head a, head b} -> axpy fan-in
        DagShape {
            m: 8,
            d0: 16,
            nodes: vec![
                gemm(None, 32),
                gemm(Some(0), 8),
                gemm(Some(0), 8),
                DagNodeShape {
                    op: DagOp::Axpy,
                    src: Some(1),
                    src2: Some(2),
                    n: 0,
                    bias: false,
                    relu: false,
                },
            ],
        }
    }

    #[test]
    fn widths_sinks_and_depths_follow_the_edges() {
        let s = two_head();
        assert_eq!(s.widths(), vec![32, 8, 8, 8]);
        assert_eq!(s.in_width(0), 16);
        assert_eq!(s.in_width(1), 32);
        assert_eq!(s.consumer_counts(), vec![2, 1, 1, 0]);
        assert_eq!(s.sinks(), vec![3]);
        assert_eq!(s.depths(), vec![1, 2, 2, 3]);
        assert_eq!(s.depth(), 3);
        assert_eq!(s.out_dims(3), (8, 8));
        assert_eq!(s.marshalled_args(), 1 + 2 * 3 + 1);
        assert!(s.validate(16, 4, 8).is_ok());
        assert!(!s.is_linear_gemm());
        assert_eq!(s.chain_dims(), None);
    }

    #[test]
    fn linear_gemm_round_trips_to_chain_dims() {
        let s = linear_gemm_shape(64, &[64, 32, 16]);
        assert!(s.is_linear_gemm());
        assert_eq!(s.chain_dims(), Some(vec![64, 32, 16]));
        assert_eq!(s.sinks(), vec![1]);
        assert_eq!(s.depth(), 2);
        assert!(s.validate(16, 4, 8).is_ok());
    }

    #[test]
    fn rejections_name_the_node_op_and_bound() {
        let bad = |s: &DagShape, needle: &str| {
            let e = s.validate(4, 2, 3).unwrap_err();
            assert!(e.contains(needle), "{e:?} should contain {needle:?}");
        };
        // empty
        let s = DagShape { m: 8, d0: 8, nodes: vec![] };
        bad(&s, "no nodes");
        // too many nodes
        let s = DagShape {
            m: 8,
            d0: 8,
            nodes: (0..5)
                .map(|i| gemm(if i == 0 { None } else { Some(i - 1) }, 8))
                .collect(),
        };
        bad(&s, "[sched.dag] max_nodes = 4");
        // zero input dims
        let s = DagShape { m: 0, d0: 8, nodes: vec![gemm(None, 8)] };
        bad(&s, "must be nonzero");
        // backward edge = cycle, named with node id and op
        let s = DagShape { m: 8, d0: 8, nodes: vec![gemm(Some(0), 8)] };
        bad(&s, "node 0 (gemm)");
        bad(&s, "cycle");
        let s = DagShape {
            m: 8,
            d0: 8,
            nodes: vec![gemm(None, 8), gemm(Some(1), 8)],
        };
        bad(&s, "node 1 (gemm)");
        // zero-width gemm
        let s = DagShape { m: 8, d0: 8, nodes: vec![gemm(None, 0)] };
        bad(&s, "node 0 (gemm): output width");
        // src2 on a matmul node
        let mut n = gemm(None, 8);
        n.src2 = Some(0);
        let s = DagShape { m: 8, d0: 8, nodes: vec![gemm(None, 8), n] };
        bad(&s, "node 1 (gemm): src2");
        // epilogue on a fan-in node
        let s = DagShape {
            m: 8,
            d0: 8,
            nodes: vec![DagNodeShape {
                op: DagOp::Axpy,
                src: None,
                src2: None,
                n: 0,
                bias: false,
                relu: true,
            }],
        };
        bad(&s, "node 0 (axpy): bias/relu");
        // fan-in width mismatch
        let s = DagShape {
            m: 8,
            d0: 8,
            nodes: vec![
                gemm(None, 16),
                DagNodeShape {
                    op: DagOp::Axpy,
                    src: Some(0),
                    src2: None,
                    n: 0,
                    bias: false,
                    relu: false,
                },
            ],
        };
        bad(&s, "node 1 (axpy): fan-in inputs are 16 and 8");
        // consuming a dot
        let s = DagShape {
            m: 8,
            d0: 8,
            nodes: vec![
                DagNodeShape {
                    op: DagOp::Dot,
                    src: None,
                    src2: None,
                    n: 0,
                    bias: false,
                    relu: false,
                },
                DagNodeShape {
                    op: DagOp::Axpy,
                    src: Some(0),
                    src2: Some(0),
                    n: 0,
                    bias: false,
                    relu: false,
                },
            ],
        };
        bad(&s, "node 1 (axpy): consumes node 0 (dot)");
        // depth bound (max_depth = 3)
        let s = linear_gemm_shape(8, &[8, 8, 8, 8, 8]);
        bad(&s, "node 3 (gemm): dag depth 4 exceeds [sched.dag] max_depth = 3");
        // width bound (max_width = 2): three heads off one trunk
        let s = DagShape {
            m: 8,
            d0: 8,
            nodes: vec![
                gemm(None, 8),
                gemm(Some(0), 8),
                gemm(Some(0), 8),
                gemm(Some(0), 8),
            ],
        };
        bad(&s, "node 3 (gemm): 3 nodes at depth 2 exceeds [sched.dag] max_width = 2");
    }

    #[test]
    fn op_names_round_trip() {
        for op in [DagOp::Gemm, DagOp::Gemv, DagOp::Axpy, DagOp::Dot] {
            assert_eq!(DagOp::from_name(op.name()), Some(op));
        }
        assert_eq!(DagOp::from_name("fence"), None);
        assert!(DagOp::Gemm.is_matmul() && DagOp::Gemv.is_matmul());
        assert!(!DagOp::Axpy.is_matmul() && !DagOp::Dot.is_matmul());
        assert_eq!(format!("{}", DagOp::Gemv), "gemv");
    }
}
