//! Heterogeneous device kernels — the paper's contributed OpenBLAS
//! extension (the `#pragma omp target` region of its Figure 2 ③).
//!
//! Each kernel runs the full offload sequence against the SoC models:
//!
//! 1. fork: OpenBLAS entry, OpenMP target entry, argument marshalling;
//! 2. data copy: `map(to:)` A, B, C into the device DRAM partition
//!    (or IO-PTE creation in zero-copy mode);
//! 3. launch: mailbox doorbell + cluster wake-up;
//! 4. compute: the cluster walks SPM-sized tiles — for every tile step
//!    the DMA cost and FPU cost are charged (double-buffered: the
//!    steady-state charge is `max(dma, fpu)`), and the *numerics* of the
//!    very same tile step are produced by executing the AOT-compiled
//!    Pallas tile kernel through PJRT;
//! 5. join + `map(from:)` C + unmap + exit.
//!
//! The tile geometry comes from the artifact manifest, so the Rust DMA
//! loop and the Pallas BlockSpecs can never drift apart.  All per-tile
//! DMA/FPU cost arithmetic lives in [`crate::cost::tile`] — the same
//! functions the scheduler's [`crate::cost::CostModel`] sums while
//! *estimating*, so the charges made here and the estimates dispatch
//! compares can never drift either.
//!
//! **Error recovery**: any failure mid-offload (device-DRAM OOM, IOMMU
//! fault, artifact error) releases every mapping created so far and
//! aborts the in-flight launch, leaving the session fully usable — the
//! integration tests inject OOM to verify this.

use crate::cost::tile::{
    gemm_tile_costs, gemv_panel_costs, level1_chunk_costs, round_up,
};
use crate::dag::{DagOp, DagShape};
use crate::soc::trace::RegionClass;
// Staged-footprint formulas moved to the cost subsystem (the placement
// router reads them off the CostModel); re-exported here so existing
// callers keep working.
pub use crate::cost::tile::{gemm_staged_bytes_tiled, gemv_staged_bytes_tiled};
use crate::error::{Error, Result};
use crate::hero::offload::{OffloadArg, OffloadDescriptor, OffloadKind};
use crate::kernel::{kernel_key, Epilogue, KernelOp, KernelPlan, KernelRegistry};
use crate::omp::engine::{MappedBuf, OffloadEngine};
use crate::runtime::literal::{lit_1d, lit_2d};
use crate::runtime::ArtifactRegistry;
use crate::soc::clock::Cycles;

use std::sync::Arc;

use super::elem::Elem;

/// Zero-pad a row-major matrix to (rp x cp).
fn pad2<T: Elem>(x: &[T], rows: usize, cols: usize, rp: usize, cp: usize) -> Vec<T> {
    debug_assert_eq!(x.len(), rows * cols);
    if rows == rp && cols == cp {
        return x.to_vec();
    }
    let mut out = vec![T::zero(); rp * cp];
    for r in 0..rows {
        out[r * cp..r * cp + cols].copy_from_slice(&x[r * cols..(r + 1) * cols]);
    }
    out
}

/// Mappings created during one offload, so the error path can release
/// everything that was staged before the failure.
#[derive(Default)]
struct Staged {
    bufs: Vec<Option<MappedBuf>>,
}

impl Staged {
    fn push(&mut self, b: MappedBuf) -> usize {
        self.bufs.push(Some(b));
        self.bufs.len() - 1
    }

    fn get(&self, i: usize) -> &MappedBuf {
        self.bufs[i].as_ref().expect("staged buffer already taken")
    }

    fn get_mut(&mut self, i: usize) -> &mut MappedBuf {
        self.bufs[i].as_mut().expect("staged buffer already taken")
    }

    fn take(&mut self, i: usize) -> MappedBuf {
        self.bufs[i].take().expect("staged buffer already taken")
    }

    /// Put a buffer back into a taken slot (the chain path takes an
    /// output, promotes it to a device-resident input, and re-seats it).
    fn replace(&mut self, i: usize, buf: MappedBuf) {
        debug_assert!(self.bufs[i].is_none(), "replace into an occupied slot");
        self.bufs[i] = Some(buf);
    }

    /// Error-path teardown: release whatever is still mapped.
    fn release_all(&mut self, engine: &mut OffloadEngine) {
        for slot in self.bufs.drain(..) {
            if let Some(buf) = slot {
                let _ = engine.unmap(buf, "abort");
            }
        }
    }
}

/// Run `body` as an offload; on error release staged mappings and abort
/// the in-flight launch so the engine stays usable.
fn with_recovery<R>(
    engine: &mut OffloadEngine,
    body: impl FnOnce(&mut OffloadEngine, &mut Staged) -> Result<R>,
) -> Result<R> {
    let mut staged = Staged::default();
    match body(engine, &mut staged) {
        Ok(r) => Ok(r),
        Err(e) => {
            staged.release_all(engine);
            engine.abort_offload();
            engine.target_end();
            Err(e)
        }
    }
}

/// Gather one (rows x cols) tile from a padded row-major matrix staged in
/// a mapped buffer. `lead` is the padded row length in elements.
fn read_tile<T: Elem>(
    engine: &mut OffloadEngine,
    buf: &MappedBuf,
    row0: usize,
    col0: usize,
    rows: usize,
    cols: usize,
    lead: usize,
) -> Result<Vec<T>> {
    if cols == lead {
        // rows are contiguous: one device read for the whole tile
        let off = row0 * lead * T::SIZE;
        let bytes = engine.read_mapped(buf, off, rows * cols * T::SIZE)?;
        return Ok(T::bytes_to_vec(&bytes));
    }
    let mut out = Vec::with_capacity(rows * cols);
    for r in 0..rows {
        let off = ((row0 + r) * lead + col0) * T::SIZE;
        let bytes = engine.read_mapped(buf, off, cols * T::SIZE)?;
        out.extend(T::bytes_to_vec(&bytes));
    }
    Ok(out)
}

/// Scatter one tile back into a mapped padded matrix.
fn write_tile<T: Elem>(
    engine: &mut OffloadEngine,
    buf: &mut MappedBuf,
    tile: &[T],
    row0: usize,
    col0: usize,
    rows: usize,
    cols: usize,
    lead: usize,
) -> Result<()> {
    debug_assert_eq!(tile.len(), rows * cols);
    if cols == lead {
        let off = row0 * lead * T::SIZE;
        return engine.write_mapped(buf, off, &T::slice_to_bytes(tile));
    }
    for r in 0..rows {
        let off = ((row0 + r) * lead + col0) * T::SIZE;
        let bytes = T::slice_to_bytes(&tile[r * cols..(r + 1) * cols]);
        engine.write_mapped(buf, off, &bytes)?;
    }
    Ok(())
}

/// GEMM problem geometry shared by the single-call and batched paths:
/// user dims, padded dims and the manifest tile shape.
#[derive(Debug, Clone, Copy)]
struct GemmGeom {
    m: usize,
    n: usize,
    k: usize,
    mp: usize,
    np: usize,
    kp: usize,
    tm: usize,
    tn: usize,
    tk: usize,
}

impl GemmGeom {
    /// Resolve the geometry and run the shared preflight checks (tile
    /// artifact present, one tile set fits the L1 SPM).
    fn resolve<T: Elem>(
        engine: &OffloadEngine,
        registry: &ArtifactRegistry,
        m: usize,
        n: usize,
        k: usize,
    ) -> Result<GemmGeom> {
        let man = registry.manifest();
        let (tm, tn, tk) = (man.tile_m, man.tile_n, man.tile_k);
        man.entry(&format!("gemm_tile_accum_{}", T::DTYPE))?; // fail fast
        let tile_set = ((tm * tk + tk * tn + tm * tn) * T::SIZE) as u64;
        if !engine.platform.cluster.fits_spm(tile_set) {
            return Err(Error::Offload(format!(
                "tile set {tile_set} B exceeds L1 SPM ({} B)",
                engine.platform.cluster.spm_bytes()
            )));
        }
        Ok(GemmGeom {
            m,
            n,
            k,
            mp: round_up(m, tm),
            np: round_up(n, tn),
            kp: round_up(k, tk),
            tm,
            tn,
            tk,
        })
    }
}

/// Stage-time kernel-registry consultation for one walk: if the key's
/// launch count crossed `[kernel] promote_after`, compile its plan from
/// the very same SoC models and resolved geometry the generic walk
/// reads, insert it, then try to acquire the fast path (pinning the
/// entry for the duration of the walk — pair with `release`).  `None`
/// means the generic interpreted walk runs: always correct, and counted
/// as a fallback so the serve counters show both paths.
fn acquire_plan(
    engine: &OffloadEngine,
    kreg: Option<&KernelRegistry>,
    op: KernelOp,
    dtype: &str,
    tile: (usize, usize, usize),
    padded: (usize, usize, usize),
    epi: Epilogue,
) -> Option<Arc<KernelPlan>> {
    let reg = kreg?;
    if !reg.enabled() {
        return None;
    }
    let key = kernel_key(op, dtype, tile, padded, epi);
    if reg.wants_specialize(key) {
        let plan = KernelPlan::specialize(
            &engine.platform.dma,
            &engine.platform.cluster,
            op,
            dtype,
            tile,
            padded,
            epi,
        );
        reg.insert(plan);
    }
    let plan = reg.acquire(key);
    if plan.is_none() {
        reg.note_fallback();
    }
    plan
}

/// Compute phase of one GEMM offload: the DMA-scheduled tile walk (or the
/// one-shot catalog path) over already-staged buffers, with every burst
/// charged to the Compute region.  Shared by [`gemm`] and the batched
/// launch — the batch pays this once per member but forks/joins once.
///
/// When the kernel registry holds a specialized plan for this walk's
/// key, the *charge schedule* comes from the plan (leaner FPU bursts,
/// epilogue fused into the C pass) while the kernel executions stay
/// byte-for-byte those of the generic walk — bit-identical numerics by
/// construction.  Returns whether the specialized schedule ran (the
/// chain path uses this to skip its separately-charged epilogue pass).
#[allow(clippy::too_many_arguments)]
fn gemm_compute<T: Elem>(
    engine: &mut OffloadEngine,
    registry: &mut ArtifactRegistry,
    staged: &mut Staged,
    (ai, bi, ci): (usize, usize, usize),
    g: GemmGeom,
    alpha: T,
    beta: T,
    kreg: Option<&KernelRegistry>,
    epi: Epilogue,
) -> Result<bool> {
    let GemmGeom { mp, np, kp, tm, tn, tk, .. } = g;

    // per-tile costs from the shared kernel (one refill, one burst, one
    // C transfer, one epilogue) — the same function the CostModel sums
    let tc = gemm_tile_costs(
        &engine.platform.dma,
        &engine.platform.cluster,
        (tm, tn, tk),
        T::SIZE,
        T::F32_PATH,
    );
    let (dma_ab, fpu, dma_c, epilogue) = (tc.dma_ab, tc.fpu, tc.dma_c, tc.epilogue);

    // Specialized fast path: same executions, leaner charge schedule.
    let plan = acquire_plan(
        engine,
        kreg,
        KernelOp::Gemm,
        T::DTYPE,
        (tm, tn, tk),
        (mp, np, kp),
        epi,
    );
    let (first_charge, steady_charge, c_in_charge, c_out_charge) = match &plan {
        Some(p) => (p.first_step, p.steady_step, p.c_in, p.c_pass),
        None => (dma_ab + fpu, dma_ab.max(fpu), dma_c, epilogue + dma_c),
    };

    let r = gemm_walk::<T>(
        engine,
        registry,
        staged,
        (ai, bi, ci),
        g,
        alpha,
        beta,
        (first_charge, steady_charge, c_in_charge, c_out_charge),
    );
    // the pin lasts exactly as long as the in-flight walk, error or not
    if let (Some(reg), Some(p)) = (kreg, &plan) {
        reg.release(p.key);
    }
    r?;
    Ok(plan.is_some())
}

/// The tile walk of [`gemm_compute`]: identical kernel executions under
/// either charge schedule — the `charges` tuple (first k-step, steady
/// k-step, C map-in, C write-back pass) is the only thing a specialized
/// plan changes.
#[allow(clippy::too_many_arguments)]
fn gemm_walk<T: Elem>(
    engine: &mut OffloadEngine,
    registry: &mut ArtifactRegistry,
    staged: &mut Staged,
    (ai, bi, ci): (usize, usize, usize),
    g: GemmGeom,
    alpha: T,
    beta: T,
    charges: (Cycles, Cycles, Cycles, Cycles),
) -> Result<()> {
    let artifact = format!("gemm_tile_accum_{}", T::DTYPE);
    let GemmGeom { m, n, k, np, kp, tm, tn, tk, .. } = g;
    let gm = g.mp / tm;
    let gn = np / tn;
    let gk = kp / tk;
    let (first_charge, steady_charge, c_in_charge, c_out_charge) = charges;

    let beta_zero = beta == T::zero();
    // Output tiles are distributed round-robin across the PMCA's
    // clusters; with uniform tiles, wall time is the serial per-tile
    // cost once per batch of `clusters` tiles (DMA contention between
    // clusters is not modelled — see DESIGN.md §8).
    let clusters = engine.platform.cfg.cluster.clusters.max(1) as usize;

    // Fast numerics path (§Perf change L3-2): when the exact square
    // shape is in the artifact catalog, run ONE one-shot PJRT call on
    // the staged device bytes instead of gm*gn*gk tile calls.  The
    // timing charges below are identical either way (the tile
    // composition == one-shot equivalence is pinned by
    // rust/tests/integration_registry.rs), and data still flows
    // through the mapped buffers, so dev-DRAM/IOTLB semantics hold.
    let one_shot = if m == n && n == k {
        registry
            .manifest()
            .find_sized("gemm", T::DTYPE, m)
            .map(|e| e.name.clone())
    } else {
        None
    };
    if let Some(name) = &one_shot {
        let a_in: Vec<T> = read_tile(engine, staged.get(ai), 0, 0, m, k, kp)?;
        let b_in: Vec<T> = read_tile(engine, staged.get(bi), 0, 0, k, n, np)?;
        let c_in: Vec<T> = read_tile(engine, staged.get(ci), 0, 0, m, n, np)?;
        let out = registry.exec(
            name,
            &[
                lit_2d(&a_in, m, k)?,
                lit_2d(&b_in, k, n)?,
                lit_2d(&c_in, m, n)?,
                lit_1d(&[alpha]),
                lit_1d(&[beta]),
            ],
        )?;
        let out_vec = out.to_vec::<T>()?;
        engine.metrics.tile_kernel_calls += 1;
        write_tile(engine, staged.get_mut(ci), &out_vec, 0, 0, m, n, np)?;
    }
    for i in 0..gm {
        for j in 0..gn {
            let charge_this_tile = (i * gn + j) % clusters == 0;
            if let Some(_name) = &one_shot {
                // numerics already produced; charge the same tile-walk
                // timing the cluster would spend
                if charge_this_tile {
                    for kk in 0..gk {
                        let charge =
                            if kk == 0 { first_charge } else { steady_charge };
                        engine.charge_compute(charge, &format!("tile({i},{j},{kk})"));
                    }
                    if !beta_zero {
                        engine.charge_compute(c_in_charge, "c_in");
                    }
                    engine.charge_compute(c_out_charge, "c_out");
                }
                continue;
            }
            // acc tile resident in SPM across the K walk
            let mut acc = vec![T::zero(); tm * tn];
            for kk in 0..gk {
                let a_tile: Vec<T> =
                    read_tile(engine, staged.get(ai), i * tm, kk * tk, tm, tk, kp)?;
                let b_tile: Vec<T> =
                    read_tile(engine, staged.get(bi), kk * tk, j * tn, tk, tn, np)?;
                // numerics: the AOT Pallas tile kernel
                let out = registry.exec(
                    &artifact,
                    &[
                        lit_2d(&acc, tm, tn)?,
                        lit_2d(&a_tile, tm, tk)?,
                        lit_2d(&b_tile, tk, tn)?,
                    ],
                )?;
                acc = out.to_vec::<T>()?;
                engine.metrics.tile_kernel_calls += 1;

                // timing: first refill is exposed, steady state overlaps
                if charge_this_tile {
                    let charge = if kk == 0 { first_charge } else { steady_charge };
                    engine.charge_compute(charge, &format!("tile({i},{j},{kk})"));
                }
            }
            // epilogue: read C tile (if beta != 0), combine, write back
            let c_tile: Vec<T> = if beta_zero {
                vec![T::zero(); tm * tn]
            } else {
                if charge_this_tile {
                    engine.charge_compute(c_in_charge, "c_in");
                }
                read_tile(engine, staged.get(ci), i * tm, j * tn, tm, tn, np)?
            };
            let mut out_tile = vec![T::zero(); tm * tn];
            for idx in 0..tm * tn {
                out_tile[idx] = alpha * acc[idx] + beta * c_tile[idx];
            }
            write_tile(engine, staged.get_mut(ci), &out_tile, i * tm, j * tn, tm, tn, np)?;
            if charge_this_tile {
                engine.charge_compute(c_out_charge, "c_out");
            }
        }
    }
    Ok(())
}

/// Stage one padded (A, B, C) operand set; returns the staged indices.
///
/// A and B are read-only operands, so they route through the operand
/// cache ([`OffloadEngine::map_to_operand`]) — a re-map of identical
/// bytes (the serving hot path's shared weight matrix) skips the copy.
/// C is written by the kernel and never cached; when `beta == 0` (its
/// incoming contents are mathematically irrelevant) and the cache config
/// enables staging elisions, it is staged `map(alloc:)`-style with no
/// host copy at all.
#[allow(clippy::too_many_arguments)]
fn stage_gemm_operands(
    engine: &mut OffloadEngine,
    staged: &mut Staged,
    a_bytes: &[u8],
    b_bytes: &[u8],
    c_bytes: &[u8],
    user_bytes: (u64, u64, u64),
    zero_copy: bool,
    beta_zero: bool,
) -> Result<(usize, usize, usize)> {
    let ai = staged.push(engine.map_to_operand(a_bytes, user_bytes.0, zero_copy, "a")?);
    let bi = staged.push(engine.map_to_operand(b_bytes, user_bytes.1, zero_copy, "b")?);
    let ci = if beta_zero && !zero_copy && engine.cache_enabled() {
        staged.push(engine.map_alloc(c_bytes, user_bytes.2, "c")?)
    } else {
        staged.push(engine.map_to_charged(c_bytes, user_bytes.2, zero_copy, "c")?)
    };
    Ok((ai, bi, ci))
}

/// Heterogeneous GEMM: `C = alpha * A @ B + beta * C` over materialized
/// op(A) (m x k) and op(B) (k x n), row-major.
#[allow(clippy::too_many_arguments)]
pub fn gemm<T: Elem>(
    engine: &mut OffloadEngine,
    registry: &mut ArtifactRegistry,
    m: usize,
    n: usize,
    k: usize,
    alpha: T,
    a: &[T],
    b: &[T],
    beta: T,
    c: &mut [T],
    zero_copy: bool,
    kreg: Option<&KernelRegistry>,
) -> Result<()> {
    let g = GemmGeom::resolve::<T>(engine, registry, m, n, k)?;
    let a_pad = pad2(a, m, k, g.mp, g.kp);
    let b_pad = pad2(b, k, n, g.kp, g.np);
    let c_pad = pad2(c, m, n, g.mp, g.np);

    // ---- fork ----
    engine.blas_entry();
    engine.target_begin(3);

    let a_bytes = T::slice_to_bytes(&a_pad);
    let b_bytes = T::slice_to_bytes(&b_pad);
    let c_bytes = T::slice_to_bytes(&c_pad);

    let c_out_bytes = with_recovery(engine, |engine, staged| {
        // ---- data copy (charged at the user's byte counts) ----
        let (ai, bi, ci) = stage_gemm_operands(
            engine,
            staged,
            &a_bytes,
            &b_bytes,
            &c_bytes,
            (
                (m * k * T::SIZE) as u64,
                (k * n * T::SIZE) as u64,
                (m * n * T::SIZE) as u64,
            ),
            zero_copy,
            beta == T::zero(),
        )?;

        // ---- launch ----
        let mut desc = OffloadDescriptor::new(OffloadKind::Gemm, (m, n, k), T::F32_PATH);
        for i in [ai, bi, ci] {
            desc.push_arg(OffloadArg {
                device_addr: staged.get(i).device_addr(),
                len: staged.get(i).len,
                via_iommu: zero_copy,
            });
        }
        engine.launch(&desc)?;

        // ---- compute ----
        gemm_compute(
            engine, registry, staged, (ai, bi, ci), g, alpha, beta, kreg,
            Epilogue::None,
        )?;

        // ---- join + copy back ----
        engine.join()?;
        let mut c_out = vec![0u8; c_bytes.len()];
        engine.map_from_charged(staged.get(ci), &mut c_out, (m * n * T::SIZE) as u64, "c")?;
        engine.unmap(staged.take(ai), "a")?;
        engine.unmap(staged.take(bi), "b")?;
        engine.unmap(staged.take(ci), "c")?;
        engine.target_end();
        Ok(c_out)
    })?;

    // un-pad into the caller's C
    let c_full = T::bytes_to_vec(&c_out_bytes);
    for r in 0..m {
        c[r * n..(r + 1) * n].copy_from_slice(&c_full[r * g.np..r * g.np + n]);
    }
    Ok(())
}

/// One member of an in-flight coalesced GEMM launch.  Owns the padded
/// byte images so their addresses stay valid (they key the engine's
/// data-map) until the batch is unmapped at finish time.
#[derive(Debug)]
struct BatchMember {
    /// Never read back — held only so the A/B images outlive the unmap
    /// (the engine's data-map is keyed by their host addresses).
    #[allow(dead_code)]
    a_bytes: Vec<u8>,
    #[allow(dead_code)]
    b_bytes: Vec<u8>,
    c_bytes: Vec<u8>,
    ai: usize,
    bi: usize,
    ci: usize,
}

/// A coalesced same-shape GEMM launch between its doorbell and its join.
///
/// Produced by [`gemm_batch_launch`]; consumed by [`gemm_batch_finish`].
/// While one of these is live the device is `Running` and the completion
/// word is already posted in the cluster mailbox — the scheduler's
/// workers poll the mailbox and then finish.
#[derive(Debug)]
pub struct GemmBatchState {
    staged: Staged,
    members: Vec<BatchMember>,
    geom: GemmGeom,
    elem_size: usize,
}

impl GemmBatchState {
    /// Number of coalesced requests in this launch.
    pub fn len(&self) -> usize {
        self.members.len()
    }

    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }
}

impl std::fmt::Debug for Staged {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Staged").field("bufs", &self.bufs.len()).finish()
    }
}

/// A coalesced same-shape GEMM batch whose operands are staged in device
/// DRAM but whose doorbell has not rung yet: the map-in (data-copy
/// region) is paid, the launch + compute are pending.
///
/// Produced by [`gemm_batch_stage`]; consumed by [`gemm_batch_execute`].
/// This is the seam the scheduler's software pipelining threads through:
/// a worker stages batch k+1 here while batch k is still between its
/// launch and its finish, hiding k+1's map-in under k's compute window.
#[derive(Debug)]
pub struct GemmStagedBatch {
    staged: Staged,
    members: Vec<BatchMember>,
    geom: GemmGeom,
    elem_size: usize,
    zero_copy: bool,
}

impl GemmStagedBatch {
    /// Number of coalesced requests staged.
    pub fn len(&self) -> usize {
        self.members.len()
    }

    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }

    /// Per-member cache identity of the staged B operand (`None` when
    /// that member's B is not cache-resident).  The scheduler tags these
    /// entries in the operand cache and records residency in its
    /// affinity directory, so later same-B requests route to this
    /// cluster while the bytes stay warm.
    pub fn cached_b_keys(&self) -> Vec<Option<crate::omp::CacheKey>> {
        self.members
            .iter()
            .map(|m| self.staged.get(m.bi).cache_key())
            .collect()
    }

    /// Error-path teardown for a staged-but-never-executed batch.
    pub fn release(mut self, engine: &mut OffloadEngine) {
        self.staged.release_all(engine);
        engine.target_end();
    }
}

/// Stage a batch of same-shape GEMMs (`C_i = alpha * A_i @ B_i + beta *
/// C_i`, row-major, op(A) m x k / op(B) k x n) for ONE offload: one
/// OpenBLAS entry, one target region, `3 * batch` mapped arguments.
/// `beta_zero` must be `beta == 0` — it gates the `map(alloc:)` staging
/// elision for the outputs.  Any error releases everything staged so far
/// and exits the target region.
pub fn gemm_batch_stage<T: Elem>(
    engine: &mut OffloadEngine,
    registry: &mut ArtifactRegistry,
    (m, n, k): (usize, usize, usize),
    beta_zero: bool,
    inputs: &[(&[T], &[T], &[T])],
    zero_copy: bool,
) -> Result<GemmStagedBatch> {
    if inputs.is_empty() {
        return Err(Error::shape("gemm_batch: empty batch"));
    }
    for (a, b, c) in inputs {
        if a.len() != m * k || b.len() != k * n || c.len() != m * n {
            return Err(Error::shape(format!(
                "gemm_batch: member operand sizes {}x{}x{} don't match ({m}, {n}, {k})",
                a.len(),
                b.len(),
                c.len()
            )));
        }
    }
    let g = GemmGeom::resolve::<T>(engine, registry, m, n, k)?;

    // ---- fork (once for the whole batch) ----
    engine.blas_entry();
    engine.target_begin(3 * inputs.len());

    let mut staged = Staged::default();
    let r = (|| -> Result<Vec<BatchMember>> {
        let user_bytes = (
            (m * k * T::SIZE) as u64,
            (k * n * T::SIZE) as u64,
            (m * n * T::SIZE) as u64,
        );
        let mut members = Vec::with_capacity(inputs.len());
        for (a, b, c) in inputs {
            let a_bytes = T::slice_to_bytes(&pad2(a, m, k, g.mp, g.kp));
            let b_bytes = T::slice_to_bytes(&pad2(b, k, n, g.kp, g.np));
            let c_bytes = T::slice_to_bytes(&pad2(c, m, n, g.mp, g.np));
            let (ai, bi, ci) = stage_gemm_operands(
                engine, &mut staged, &a_bytes, &b_bytes, &c_bytes, user_bytes,
                zero_copy, beta_zero,
            )?;
            members.push(BatchMember { a_bytes, b_bytes, c_bytes, ai, bi, ci });
        }
        Ok(members)
    })();

    match r {
        Ok(members) => Ok(GemmStagedBatch {
            staged,
            members,
            geom: g,
            elem_size: T::SIZE,
            zero_copy,
        }),
        Err(e) => {
            staged.release_all(engine);
            engine.target_end();
            Err(e)
        }
    }
}

/// Execute a staged batch: one descriptor, one doorbell, the cluster
/// walks every member's tiles, and the completion word is posted.
///
/// On return the compute is done; call [`gemm_batch_finish`] (after
/// polling the mailbox, if overlapping) to join, copy results back and
/// release the mappings.  Any error releases the staged mappings and
/// aborts the launch, exactly like the single-call path.
pub fn gemm_batch_execute<T: Elem>(
    engine: &mut OffloadEngine,
    registry: &mut ArtifactRegistry,
    mut batch: GemmStagedBatch,
    alpha: T,
    beta: T,
    kreg: Option<&KernelRegistry>,
) -> Result<GemmBatchState> {
    let g = batch.geom;
    let r = (|| -> Result<()> {
        if T::SIZE != batch.elem_size {
            return Err(Error::shape("gemm_batch_execute: element type mismatch"));
        }
        // ---- one descriptor, one doorbell for the whole batch ----
        let mut desc =
            OffloadDescriptor::new(OffloadKind::Gemm, (g.m, g.n, g.k), T::F32_PATH);
        for mem in &batch.members {
            for i in [mem.ai, mem.bi, mem.ci] {
                desc.push_arg(OffloadArg {
                    device_addr: batch.staged.get(i).device_addr(),
                    len: batch.staged.get(i).len,
                    via_iommu: batch.zero_copy,
                });
            }
        }
        engine.launch(&desc)?;

        // ---- compute: the cluster walks every member's tiles ----
        for mem in &batch.members {
            gemm_compute(
                engine,
                registry,
                &mut batch.staged,
                (mem.ai, mem.bi, mem.ci),
                g,
                alpha,
                beta,
                kreg,
                Epilogue::None,
            )?;
        }

        // post the completion word (pollable via the mailbox; the host
        // join happens in gemm_batch_finish)
        engine.device_complete()?;
        Ok(())
    })();

    match r {
        Ok(()) => Ok(GemmBatchState {
            staged: batch.staged,
            members: batch.members,
            geom: g,
            elem_size: batch.elem_size,
        }),
        Err(e) => {
            batch.staged.release_all(engine);
            engine.abort_offload();
            engine.target_end();
            Err(e)
        }
    }
}

/// Launch a batch of same-shape GEMMs as ONE offload: stage + execute in
/// one call — the paper's fork/join overhead is paid once and amortized
/// across the batch, which moves the effective Figure-3 crossover below
/// the single-call size.  See [`gemm_batch_stage`] / [`gemm_batch_execute`]
/// for the split the pipelined scheduler uses.
#[allow(clippy::too_many_arguments)]
pub fn gemm_batch_launch<T: Elem>(
    engine: &mut OffloadEngine,
    registry: &mut ArtifactRegistry,
    dims: (usize, usize, usize),
    alpha: T,
    beta: T,
    inputs: &[(&[T], &[T], &[T])],
    zero_copy: bool,
    kreg: Option<&KernelRegistry>,
) -> Result<GemmBatchState> {
    let staged = gemm_batch_stage::<T>(
        engine, registry, dims, beta == T::zero(), inputs, zero_copy,
    )?;
    gemm_batch_execute(engine, registry, staged, alpha, beta, kreg)
}

/// Join a coalesced launch: drain the completion word, copy every
/// member's C back (un-padded into `outs`, one slice per member, in
/// launch order), release all mappings and exit the target region.
pub fn gemm_batch_finish<T: Elem>(
    engine: &mut OffloadEngine,
    mut state: GemmBatchState,
    outs: &mut [&mut [T]],
) -> Result<()> {
    let g = state.geom;
    let finish = (|| -> Result<()> {
        if outs.len() != state.members.len() {
            return Err(Error::shape(format!(
                "gemm_batch_finish: {} outputs for a batch of {}",
                outs.len(),
                state.members.len()
            )));
        }
        if T::SIZE != state.elem_size {
            return Err(Error::shape("gemm_batch_finish: element type mismatch"));
        }
        engine.join_completed()?;
        for (mem, out) in state.members.iter().zip(outs.iter_mut()) {
            if out.len() != g.m * g.n {
                return Err(Error::shape(format!(
                    "gemm_batch_finish: output len {} != {}x{}",
                    out.len(),
                    g.m,
                    g.n
                )));
            }
            let mut c_out = vec![0u8; mem.c_bytes.len()];
            engine.map_from_charged(
                state.staged.get(mem.ci),
                &mut c_out,
                (g.m * g.n * T::SIZE) as u64,
                "c",
            )?;
            let c_full = T::bytes_to_vec(&c_out);
            for r in 0..g.m {
                out[r * g.n..(r + 1) * g.n]
                    .copy_from_slice(&c_full[r * g.np..r * g.np + g.n]);
            }
        }
        for mem in &state.members {
            engine.unmap(state.staged.take(mem.ai), "a")?;
            engine.unmap(state.staged.take(mem.bi), "b")?;
            engine.unmap(state.staged.take(mem.ci), "c")?;
        }
        engine.target_end();
        Ok(())
    })();

    if let Err(e) = finish {
        state.staged.release_all(engine);
        engine.abort_offload();
        engine.target_end();
        return Err(e);
    }
    Ok(())
}

/// Device-DRAM bytes one staged batch member occupies for an (m, n, k)
/// GEMM — lets the scheduler cap a batch to what the cluster's DRAM
/// partition can hold before it commits to a coalesced launch.  The
/// formula itself lives in [`crate::cost::tile`], shared with the
/// placement router's shape estimates.
pub fn gemm_staged_bytes<T: Elem>(
    registry: &ArtifactRegistry,
    dims: (usize, usize, usize),
) -> u64 {
    let man = registry.manifest();
    gemm_staged_bytes_tiled((man.tile_m, man.tile_n, man.tile_k), dims, T::SIZE)
}

/// One link of a GEMM chain: `C_i = epilogue_i(C_{i-1} @ B_i)` with
/// `alpha = 1, beta = 0` (the additive case is the bias epilogue).  The
/// previous link's output is the input — it never leaves device DRAM.
#[derive(Debug, Clone, Copy)]
pub struct ChainLinkSpec<'a, T: Elem> {
    /// The link's weight matrix, row-major (k x n).
    pub b: &'a [T],
    /// (k, n): op(B) dims; `k` must equal the previous link's `n` (or the
    /// chain input's column count for the first link).
    pub dims: (usize, usize),
    /// Optional per-row bias (length n), added before `relu`.
    pub bias: Option<&'a [T]>,
    /// Apply max(x, 0) element-wise after the bias.
    pub relu: bool,
}

/// One staged chain link: geometry, staged indices, owned byte images
/// (their host addresses key the engine's data-map until unmap) and the
/// epilogue spec.
#[derive(Debug)]
struct ChainMember {
    geom: GemmGeom,
    bi: usize,
    ci: usize,
    #[allow(dead_code)]
    b_bytes: Vec<u8>,
    #[allow(dead_code)]
    c_bytes: Vec<u8>,
    /// Raw `T` bytes of the bias vector (length n), when present.
    bias: Option<Vec<u8>>,
    relu: bool,
}

/// A staged-but-not-executed GEMM chain: the input activation, every
/// link's weights and every link's output buffer are resident in the
/// cluster's device-DRAM slice, the doorbell has not rung.  Produced by
/// [`gemm_chain_stage`]; consumed by [`gemm_chain_execute`] — the same
/// stage/execute/finish seam the scheduler's software pipeline threads
/// gemm and gemv batches through, so chains ride it unchanged.
#[derive(Debug)]
pub struct GemmChainStaged {
    staged: Staged,
    members: Vec<ChainMember>,
    m: usize,
    /// Index of the chain input (link 1's A operand).
    ai: usize,
    #[allow(dead_code)]
    x_bytes: Vec<u8>,
    elem_size: usize,
}

impl GemmChainStaged {
    /// Number of links staged.
    pub fn len(&self) -> usize {
        self.members.len()
    }

    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }

    /// (rows, cols) of the chain's final output.
    pub fn out_dims(&self) -> (usize, usize) {
        let g = self.members.last().expect("staged chain is non-empty").geom;
        (self.m, g.n)
    }

    /// Per-link cache identity of the staged B operand (`None` when not
    /// cache-resident) — what the scheduler tags for its affinity
    /// directory, exactly like [`GemmStagedBatch::cached_b_keys`].
    pub fn cached_b_keys(&self) -> Vec<Option<crate::omp::CacheKey>> {
        self.members
            .iter()
            .map(|l| self.staged.get(l.bi).cache_key())
            .collect()
    }

    /// Error-path / cancellation teardown for a staged-but-never-executed
    /// chain: releases every mapping (operand-cache pins included) and
    /// exits the target region — a cancelled chain must not strand
    /// resident intermediates or `map(alloc:)` output buffers.
    pub fn release(mut self, engine: &mut OffloadEngine) {
        self.staged.release_all(engine);
        engine.target_end();
    }
}

/// An executed chain between its doorbell and its finish: every link's
/// compute is done, the completion word is posted, the final output is
/// still on the device.  Produced by [`gemm_chain_execute`]; consumed by
/// [`gemm_chain_finish`].
#[derive(Debug)]
pub struct GemmChainState {
    staged: Staged,
    members: Vec<ChainMember>,
    m: usize,
    /// The chain input's padded byte image: its host address keys the
    /// engine's data-map until finish-time unmap, so it must outlive the
    /// execute->finish window (a freed-and-reused heap address would
    /// alias the stale mapping and leak the device allocation).
    #[allow(dead_code)]
    x_bytes: Vec<u8>,
    elem_size: usize,
}

impl GemmChainState {
    pub fn len(&self) -> usize {
        self.members.len()
    }

    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }

    /// (rows, cols) of the chain's final output.
    pub fn out_dims(&self) -> (usize, usize) {
        let g = self.members.last().expect("executed chain is non-empty").geom;
        (self.m, g.n)
    }
}

/// Stage a GEMM chain for ONE offload: fork once, `map(to:)` the input
/// activation (m x k0) and every link's weights (cache-eligible
/// read-only operands), and stage every link's output `map(alloc:)`-style
/// (beta = 0 throughout, so no output ever copies host bytes in).  Any
/// error releases everything staged so far and exits the target region.
///
/// Chain legality: each link's `k` must equal its predecessor's `n`, and
/// the manifest tile geometry must pad them identically (`tile_n ==
/// tile_k`) so a link's padded output IS the next link's padded input —
/// that byte-level identity is what lets the intermediate stay resident
/// with bit-exact numerics.
pub fn gemm_chain_stage<T: Elem>(
    engine: &mut OffloadEngine,
    registry: &mut ArtifactRegistry,
    m: usize,
    x: &[T],
    links: &[ChainLinkSpec<'_, T>],
) -> Result<GemmChainStaged> {
    if links.is_empty() {
        return Err(Error::shape("gemm_chain: empty chain"));
    }
    let k0 = links[0].dims.0;
    if x.len() != m * k0 {
        return Err(Error::shape(format!(
            "gemm_chain: input has {} elements, link 1 wants {m}x{k0}",
            x.len()
        )));
    }
    let mut prev_n = k0;
    for (i, l) in links.iter().enumerate() {
        let (k, n) = l.dims;
        if k == 0 || n == 0 || l.b.len() != k * n {
            return Err(Error::shape(format!(
                "gemm_chain: link {i} weights have {} elements for ({k}, {n})",
                l.b.len()
            )));
        }
        if k != prev_n {
            return Err(Error::shape(format!(
                "gemm_chain: link {i} consumes {k} columns but its producer \
                 yields {prev_n}"
            )));
        }
        if let Some(bias) = l.bias {
            if bias.len() != n {
                return Err(Error::shape(format!(
                    "gemm_chain: link {i} bias has {} elements for n={n}",
                    bias.len()
                )));
            }
        }
        prev_n = n;
    }
    let geoms: Vec<GemmGeom> = links
        .iter()
        .map(|l| GemmGeom::resolve::<T>(engine, registry, m, l.dims.1, l.dims.0))
        .collect::<Result<_>>()?;
    // padded hand-off identity: producer C is (mp x np) with lead np, the
    // consumer reads A as (mp x kp) with lead kp — they must be the same
    // grid, which holds iff the tile pads n and k alike
    for w in geoms.windows(2) {
        if w[0].np != w[1].kp {
            return Err(Error::Offload(format!(
                "gemm_chain: tile geometry pads a {}-wide intermediate to {} \
                 as an output but {} as an input (tile_n != tile_k) — \
                 device-resident hand-off would change numerics",
                w[0].n, w[0].np, w[1].kp
            )));
        }
    }

    // ---- fork (once for the whole chain) ----
    engine.blas_entry();
    engine.target_begin(1 + 2 * links.len());

    let mut staged = Staged::default();
    let r = (|| -> Result<(usize, Vec<u8>, Vec<ChainMember>)> {
        let g0 = geoms[0];
        let x_bytes = T::slice_to_bytes(&pad2(x, m, k0, g0.mp, g0.kp));
        let ai = staged.push(engine.map_to_operand(
            &x_bytes,
            (m * k0 * T::SIZE) as u64,
            false,
            "x",
        )?);
        let mut members = Vec::with_capacity(links.len());
        for (l, g) in links.iter().zip(geoms.iter()) {
            let (k, n) = l.dims;
            let b_bytes = T::slice_to_bytes(&pad2(l.b, k, n, g.kp, g.np));
            let bi = staged.push(engine.map_to_operand(
                &b_bytes,
                (k * n * T::SIZE) as u64,
                false,
                "b",
            )?);
            // beta = 0 by construction: outputs stage map(alloc:)-style,
            // zero-filled on the device, no host copy
            let c_bytes = vec![0u8; g.mp * g.np * T::SIZE];
            let ci = staged.push(engine.map_alloc(
                &c_bytes,
                (m * n * T::SIZE) as u64,
                "c",
            )?);
            members.push(ChainMember {
                geom: *g,
                bi,
                ci,
                b_bytes,
                c_bytes,
                bias: l.bias.map(T::slice_to_bytes),
                relu: l.relu,
            });
        }
        Ok((ai, x_bytes, members))
    })();

    match r {
        Ok((ai, x_bytes, members)) => Ok(GemmChainStaged {
            staged,
            members,
            m,
            ai,
            x_bytes,
            elem_size: T::SIZE,
        }),
        Err(e) => {
            staged.release_all(engine);
            engine.target_end();
            Err(e)
        }
    }
}

/// Element-wise chain epilogue on a staged output: add the bias to every
/// row and/or clamp at zero, touching only the (m, n) user region so the
/// zero padding — which the next link reads as A padding — stays zero.
/// Charged like a level-1 chunk pass (stream in, FPU, stream out);
/// numerics are exact f64/f32 ops, identical to the host path's epilogue.
///
/// `charged = false` is the specialized-walk case: the link's plan fused
/// this pass into its C write-back charge, so the numerics still run
/// here but the separate stream pass is not charged again.
fn chain_epilogue<T: Elem>(
    engine: &mut OffloadEngine,
    staged: &mut Staged,
    ci: usize,
    g: GemmGeom,
    bias: Option<&[T]>,
    relu: bool,
    charged: bool,
) -> Result<()> {
    if bias.is_none() && !relu {
        return Ok(());
    }
    let (m, n, np) = (g.m, g.n, g.np);
    for r in 0..m {
        let off = r * np * T::SIZE;
        let mut row: Vec<T> = T::bytes_to_vec(&engine.read_mapped(
            staged.get(ci),
            off,
            n * T::SIZE,
        )?);
        if let Some(bias) = bias {
            for (v, b) in row.iter_mut().zip(bias) {
                *v = *v + *b;
            }
        }
        if relu {
            for v in row.iter_mut() {
                if *v < T::zero() {
                    *v = T::zero();
                }
            }
        }
        engine.write_mapped(staged.get_mut(ci), off, &T::slice_to_bytes(&row))?;
    }
    if charged {
        let cc =
            level1_chunk_costs(&engine.platform.dma, &engine.platform.cluster, m * n);
        engine.charge_compute(cc.dma.max(cc.fpu) + cc.dma, "chain_epilogue");
    }
    Ok(())
}

/// Execute a staged chain: one descriptor, one doorbell, then every
/// link's tile walk back to back — each intermediate output is promoted
/// to a device-resident input for its consumer
/// ([`OffloadEngine::promote_output`]), so the only interior data-copy
/// charges are bookkeeping setups.  The completion word is posted on
/// return; poll the mailbox and call [`gemm_chain_finish`].
pub fn gemm_chain_execute<T: Elem>(
    engine: &mut OffloadEngine,
    registry: &mut ArtifactRegistry,
    mut chain: GemmChainStaged,
    kreg: Option<&KernelRegistry>,
) -> Result<GemmChainState> {
    let r = (|| -> Result<()> {
        if T::SIZE != chain.elem_size {
            return Err(Error::shape("gemm_chain_execute: element type mismatch"));
        }
        let g0 = chain.members[0].geom;
        let mut desc = OffloadDescriptor::new(
            OffloadKind::Chain,
            (g0.m, g0.n, g0.k),
            T::F32_PATH,
        );
        let mut arg_indices = vec![chain.ai];
        for mem in &chain.members {
            arg_indices.push(mem.bi);
            arg_indices.push(mem.ci);
        }
        for i in arg_indices {
            desc.push_arg(OffloadArg {
                device_addr: chain.staged.get(i).device_addr(),
                len: chain.staged.get(i).len,
                via_iommu: false,
            });
        }
        engine.launch(&desc)?;

        let mut ai = chain.ai;
        let last = chain.members.len() - 1;
        let specs: Vec<(GemmGeom, usize, usize, Option<Vec<T>>, bool)> = chain
            .members
            .iter()
            .map(|mem| {
                (
                    mem.geom,
                    mem.bi,
                    mem.ci,
                    mem.bias.as_ref().map(|b| T::bytes_to_vec(b)),
                    mem.relu,
                )
            })
            .collect();
        for (li, (g, bi, ci, bias, relu)) in specs.into_iter().enumerate() {
            // the link's epilogue is part of its kernel key: a promoted
            // plan fuses the bias/ReLU pass into the C write-back charge
            let epi = Epilogue::of(bias.is_some(), relu);
            let specialized = gemm_compute(
                engine,
                registry,
                &mut chain.staged,
                (ai, bi, ci),
                g,
                T::one(),
                T::zero(),
                kreg,
                epi,
            )?;
            chain_epilogue::<T>(
                engine,
                &mut chain.staged,
                ci,
                g,
                bias.as_deref(),
                relu,
                !specialized,
            )?;
            if li < last {
                // the intermediate stays resident: no map(from:), and the
                // next link's map(to:) of the same bytes is elided
                let out = chain.staged.take(ci);
                let user_bytes = (g.m * g.n * T::SIZE) as u64;
                let kept = engine.promote_output(out, user_bytes, "c")?;
                chain.staged.replace(ci, kept);
                engine.note_chain_reuse(user_bytes, "a");
                ai = ci;
            }
        }
        engine.device_complete()?;
        Ok(())
    })();

    match r {
        Ok(()) => Ok(GemmChainState {
            staged: chain.staged,
            members: chain.members,
            m: chain.m,
            x_bytes: chain.x_bytes,
            elem_size: chain.elem_size,
        }),
        Err(e) => {
            chain.staged.release_all(engine);
            engine.abort_offload();
            engine.target_end();
            Err(e)
        }
    }
}

/// Join an executed chain: drain the completion word, copy ONLY the
/// final link's output back (un-padded into `out`), release every
/// mapping — cached intermediates drop their pins and stay resident
/// under normal LRU (or are reclaimed immediately when the cache is
/// disabled) — and exit the target region.
pub fn gemm_chain_finish<T: Elem>(
    engine: &mut OffloadEngine,
    mut state: GemmChainState,
    out: &mut [T],
) -> Result<()> {
    let finish = (|| -> Result<()> {
        if T::SIZE != state.elem_size {
            return Err(Error::shape("gemm_chain_finish: element type mismatch"));
        }
        let g = state.members.last().expect("staged chain is non-empty").geom;
        if out.len() != g.m * g.n {
            return Err(Error::shape(format!(
                "gemm_chain_finish: output len {} != {}x{}",
                out.len(),
                g.m,
                g.n
            )));
        }
        engine.join_completed()?;
        let ci = state.members.last().expect("non-empty").ci;
        let mut c_out = vec![0u8; g.mp * g.np * T::SIZE];
        engine.map_from_charged(
            state.staged.get(ci),
            &mut c_out,
            (g.m * g.n * T::SIZE) as u64,
            "c",
        )?;
        let c_full = T::bytes_to_vec(&c_out);
        for r in 0..g.m {
            out[r * g.n..(r + 1) * g.n]
                .copy_from_slice(&c_full[r * g.np..r * g.np + g.n]);
        }
        state.staged.release_all(engine);
        engine.target_end();
        Ok(())
    })();

    if let Err(e) = finish {
        state.staged.release_all(engine);
        engine.abort_offload();
        engine.target_end();
        return Err(e);
    }
    Ok(())
}

/// Device-DRAM bytes a staged chain occupies (input + every link's
/// weights and output — intermediates never leave, so everything is
/// resident at once).  `dims` is the layer-width list `[d0, .., dL]`.
pub fn chain_staged_bytes<T: Elem>(
    registry: &ArtifactRegistry,
    m: usize,
    dims: &[usize],
) -> u64 {
    let man = registry.manifest();
    crate::cost::tile::chain_staged_bytes_tiled(
        (man.tile_m, man.tile_n, man.tile_k),
        m,
        dims,
        T::SIZE,
    )
}

/// GEMV problem geometry shared by the single-call and batched paths.
#[derive(Debug, Clone, Copy)]
struct GemvGeom {
    m: usize,
    n: usize,
    mp: usize,
    np: usize,
    tm: usize,
    tn: usize,
    tk: usize,
}

impl GemvGeom {
    fn resolve<T: Elem>(registry: &ArtifactRegistry, m: usize, n: usize)
                        -> Result<GemvGeom> {
        let man = registry.manifest();
        let (tm, tn, tk) = (man.tile_m, man.tile_n, man.tile_k);
        man.entry(&format!("gemm_tile_accum_{}", T::DTYPE))?; // fail fast
        Ok(GemvGeom { m, n, mp: round_up(m, tm), np: round_up(n, tk), tm, tn, tk })
    }
}

/// Stage one member's (A, x, y) operands; x is laid out as a tile-width
/// matrix whose first column is x, so the numerics route through the
/// same Pallas tile kernel the cluster would run.  Returns the padded
/// byte images (kept alive until unmap) and the staged indices.
#[allow(clippy::too_many_arguments)]
fn stage_gemv_operands<T: Elem>(
    engine: &mut OffloadEngine,
    staged: &mut Staged,
    g: GemvGeom,
    a: &[T],
    x: &[T],
    y: &[T],
    zero_copy: bool,
    beta_zero: bool,
) -> Result<(Vec<u8>, Vec<u8>, Vec<u8>, usize, usize, usize)> {
    let GemvGeom { m, n, mp, np, tn, .. } = g;
    let a_bytes = T::slice_to_bytes(&pad2(a, m, n, mp, np));
    let mut xmat = vec![T::zero(); np * tn];
    for (i, &v) in x.iter().enumerate() {
        xmat[i * tn] = v;
    }
    let x_bytes = T::slice_to_bytes(&xmat);
    let y_bytes = T::slice_to_bytes(&pad2(y, 1, m, 1, mp));

    // A and x are read-only: cache-eligible (a serving workload reuses
    // the same weight matrix across requests).  y is written back.
    let ai = staged.push(engine.map_to_operand(
        &a_bytes, (m * n * T::SIZE) as u64, zero_copy, "a")?);
    let xi = staged.push(engine.map_to_operand(
        &x_bytes, (n * T::SIZE) as u64, zero_copy, "x")?);
    let yi = if beta_zero && !zero_copy && engine.cache_enabled() {
        staged.push(engine.map_alloc(&y_bytes, (m * T::SIZE) as u64, "y")?)
    } else {
        staged.push(engine.map_to_charged(
            &y_bytes, (m * T::SIZE) as u64, zero_copy, "y")?)
    };
    Ok((a_bytes, x_bytes, y_bytes, ai, xi, yi))
}

/// Compute phase of one GEMV: stream the A row-panels against the staged
/// x matrix, fold the epilogue into the staged y.  Shared by [`gemv`]
/// and [`gemv_batch`] — the batch pays this once per member but
/// forks/joins once.
#[allow(clippy::too_many_arguments)]
fn gemv_compute<T: Elem>(
    engine: &mut OffloadEngine,
    registry: &mut ArtifactRegistry,
    staged: &mut Staged,
    (ai, xi, yi): (usize, usize, usize),
    g: GemvGeom,
    alpha: T,
    beta: T,
    kreg: Option<&KernelRegistry>,
) -> Result<()> {
    let GemvGeom { mp, np, tm, tn, tk, .. } = g;
    // level-2 is DMA-bound: stream the A row-panels once (shared kernel)
    let pc = gemv_panel_costs(
        &engine.platform.dma,
        &engine.platform.cluster,
        (tm, tk),
        T::SIZE,
        T::F32_PATH,
    );
    // Specialized fast path: same executions, leaner panel step.
    let plan = acquire_plan(
        engine,
        kreg,
        KernelOp::Gemv,
        T::DTYPE,
        (tm, tn, tk),
        (mp, np, 0),
        Epilogue::None,
    );
    let step = match &plan {
        Some(p) => p.steady_step,
        None => pc.dma_panel.max(pc.fpu),
    };
    let r = gemv_walk::<T>(engine, registry, staged, (ai, xi, yi), g, alpha, beta, step);
    if let (Some(reg), Some(p)) = (kreg, &plan) {
        reg.release(p.key);
    }
    r
}

/// The panel walk of [`gemv_compute`]: identical kernel executions under
/// either per-panel charge.
#[allow(clippy::too_many_arguments)]
fn gemv_walk<T: Elem>(
    engine: &mut OffloadEngine,
    registry: &mut ArtifactRegistry,
    staged: &mut Staged,
    (ai, xi, yi): (usize, usize, usize),
    g: GemvGeom,
    alpha: T,
    beta: T,
    step: Cycles,
) -> Result<()> {
    let artifact = format!("gemm_tile_accum_{}", T::DTYPE);
    let GemvGeom { mp, np, tm, tn, tk, .. } = g;
    let gm = mp / tm;
    let gk = np / tk;

    for i in 0..gm {
        let mut acc = vec![T::zero(); tm * tn];
        for kk in 0..gk {
            let a_tile: Vec<T> =
                read_tile(engine, staged.get(ai), i * tm, kk * tk, tm, tk, np)?;
            let x_tile: Vec<T> =
                read_tile(engine, staged.get(xi), kk * tk, 0, tk, tn, tn)?;
            let out = registry.exec(
                &artifact,
                &[
                    lit_2d(&acc, tm, tn)?,
                    lit_2d(&a_tile, tm, tk)?,
                    lit_2d(&x_tile, tk, tn)?,
                ],
            )?;
            acc = out.to_vec::<T>()?;
            engine.metrics.tile_kernel_calls += 1;
            engine.charge_compute(step, &format!("gemv({i},{kk})"));
        }
        // y tile: column 0 of acc
        let y0 = i * tm;
        let y_old: Vec<T> = T::bytes_to_vec(
            &engine.read_mapped(staged.get(yi), y0 * T::SIZE, tm * T::SIZE)?,
        );
        let y_new: Vec<T> = (0..tm)
            .map(|r| alpha * acc[r * tn] + beta * y_old[r])
            .collect();
        engine.write_mapped(staged.get_mut(yi), y0 * T::SIZE,
                            &T::slice_to_bytes(&y_new))?;
    }
    Ok(())
}

/// Heterogeneous GEMV: `y = alpha * A @ x + beta * y` over materialized
/// op(A) (m x n).  The x vector is staged as a tile-width matrix whose
/// first column is x, so the numerics route through the same Pallas tile
/// kernel the cluster would run (column 0 of the result is A@x).
#[allow(clippy::too_many_arguments)]
pub fn gemv<T: Elem>(
    engine: &mut OffloadEngine,
    registry: &mut ArtifactRegistry,
    m: usize,
    n: usize,
    alpha: T,
    a: &[T],
    x: &[T],
    beta: T,
    y: &mut [T],
    zero_copy: bool,
    kreg: Option<&KernelRegistry>,
) -> Result<()> {
    let g = GemvGeom::resolve::<T>(registry, m, n)?;

    engine.blas_entry();
    engine.target_begin(3);

    let y_out = with_recovery(engine, |engine, staged| {
        let (_a_bytes, _x_bytes, y_bytes, ai, xi, yi) = stage_gemv_operands(
            engine, staged, g, a, x, y, zero_copy, beta == T::zero(),
        )?;

        let mut desc = OffloadDescriptor::new(OffloadKind::Gemv, (m, n, 0), T::F32_PATH);
        for i in [ai, xi, yi] {
            desc.push_arg(OffloadArg {
                device_addr: staged.get(i).device_addr(),
                len: staged.get(i).len,
                via_iommu: zero_copy,
            });
        }
        engine.launch(&desc)?;

        gemv_compute(engine, registry, staged, (ai, xi, yi), g, alpha, beta, kreg)?;

        engine.join()?;
        let mut y_out = vec![0u8; y_bytes.len()];
        engine.map_from_charged(staged.get(yi), &mut y_out, (m * T::SIZE) as u64, "y")?;
        engine.unmap(staged.take(ai), "a")?;
        engine.unmap(staged.take(xi), "x")?;
        engine.unmap(staged.take(yi), "y")?;
        engine.target_end();
        Ok(y_out)
    })?;

    let y_full = T::bytes_to_vec(&y_out);
    y.copy_from_slice(&y_full[..m]);
    Ok(())
}

/// One member of a coalesced GEMV launch.  Owns the padded byte images
/// (their host addresses key the engine's data-map) until unmap time.
#[derive(Debug)]
struct GemvMember {
    #[allow(dead_code)]
    a_bytes: Vec<u8>,
    #[allow(dead_code)]
    x_bytes: Vec<u8>,
    y_bytes: Vec<u8>,
    ai: usize,
    xi: usize,
    yi: usize,
}

/// A coalesced same-shape GEMV batch staged in device DRAM but not yet
/// launched — the level-2 analogue of [`GemmStagedBatch`], and the seam
/// the pipelined scheduler threads gemv batches through: a worker
/// stages batch k+1 here while batch k is still between its launch and
/// its finish, hiding k+1's map-in under k's compute window.
///
/// Produced by [`gemv_batch_stage`]; consumed by [`gemv_batch_execute`].
#[derive(Debug)]
pub struct GemvStagedBatch {
    staged: Staged,
    members: Vec<GemvMember>,
    geom: GemvGeom,
    elem_size: usize,
    zero_copy: bool,
}

impl GemvStagedBatch {
    pub fn len(&self) -> usize {
        self.members.len()
    }

    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }

    /// Error-path teardown for a staged-but-never-executed batch.
    pub fn release(mut self, engine: &mut OffloadEngine) {
        self.staged.release_all(engine);
        engine.target_end();
    }
}

/// A coalesced GEMV launch between its execute and its finish: the
/// completion word is posted, results are on the device, replies are
/// pending.  Produced by [`gemv_batch_execute`]; consumed by
/// [`gemv_batch_finish`].
#[derive(Debug)]
pub struct GemvBatchState {
    staged: Staged,
    members: Vec<GemvMember>,
    geom: GemvGeom,
    elem_size: usize,
}

impl GemvBatchState {
    pub fn len(&self) -> usize {
        self.members.len()
    }

    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }
}

/// Stage a batch of same-shape GEMVs (`y_i = alpha * A_i @ x_i + beta *
/// y_i`, op(A) m x n) for ONE offload: one OpenBLAS entry, one target
/// region, `3 * batch` mapped arguments.  `beta_zero` must be
/// `beta == 0` — it gates the `map(alloc:)` staging elision for y.  Any
/// error releases everything staged so far and exits the target region.
pub fn gemv_batch_stage<T: Elem>(
    engine: &mut OffloadEngine,
    registry: &mut ArtifactRegistry,
    (m, n): (usize, usize),
    beta_zero: bool,
    inputs: &[(&[T], &[T], &[T])],
    zero_copy: bool,
) -> Result<GemvStagedBatch> {
    if inputs.is_empty() {
        return Err(Error::shape("gemv_batch: empty batch"));
    }
    for (a, x, y) in inputs {
        if a.len() != m * n || x.len() != n || y.len() != m {
            return Err(Error::shape(format!(
                "gemv_batch: member operand sizes {}x{}x{} don't match ({m}, {n})",
                a.len(),
                x.len(),
                y.len()
            )));
        }
    }
    let g = GemvGeom::resolve::<T>(registry, m, n)?;

    // ---- fork (once for the whole batch) ----
    engine.blas_entry();
    engine.target_begin(3 * inputs.len());

    let mut staged = Staged::default();
    let r = (|| -> Result<Vec<GemvMember>> {
        let mut members = Vec::with_capacity(inputs.len());
        for (a, x, y) in inputs {
            let (a_bytes, x_bytes, y_bytes, ai, xi, yi) = stage_gemv_operands(
                engine, &mut staged, g, a, x, y, zero_copy, beta_zero,
            )?;
            members.push(GemvMember { a_bytes, x_bytes, y_bytes, ai, xi, yi });
        }
        Ok(members)
    })();

    match r {
        Ok(members) => Ok(GemvStagedBatch {
            staged,
            members,
            geom: g,
            elem_size: T::SIZE,
            zero_copy,
        }),
        Err(e) => {
            staged.release_all(engine);
            engine.target_end();
            Err(e)
        }
    }
}

/// Execute a staged GEMV batch: one descriptor, one doorbell, every
/// member's row-panel walk, completion word posted.  Poll the mailbox
/// and call [`gemv_batch_finish`] to join.
pub fn gemv_batch_execute<T: Elem>(
    engine: &mut OffloadEngine,
    registry: &mut ArtifactRegistry,
    mut batch: GemvStagedBatch,
    alpha: T,
    beta: T,
    kreg: Option<&KernelRegistry>,
) -> Result<GemvBatchState> {
    let g = batch.geom;
    let r = (|| -> Result<()> {
        if T::SIZE != batch.elem_size {
            return Err(Error::shape("gemv_batch_execute: element type mismatch"));
        }
        let mut desc =
            OffloadDescriptor::new(OffloadKind::Gemv, (g.m, g.n, 0), T::F32_PATH);
        for mem in &batch.members {
            for i in [mem.ai, mem.xi, mem.yi] {
                desc.push_arg(OffloadArg {
                    device_addr: batch.staged.get(i).device_addr(),
                    len: batch.staged.get(i).len,
                    via_iommu: batch.zero_copy,
                });
            }
        }
        engine.launch(&desc)?;

        for mem in &batch.members {
            gemv_compute(
                engine,
                registry,
                &mut batch.staged,
                (mem.ai, mem.xi, mem.yi),
                g,
                alpha,
                beta,
                kreg,
            )?;
        }
        engine.device_complete()?;
        Ok(())
    })();

    match r {
        Ok(()) => Ok(GemvBatchState {
            staged: batch.staged,
            members: batch.members,
            geom: g,
            elem_size: batch.elem_size,
        }),
        Err(e) => {
            batch.staged.release_all(engine);
            engine.abort_offload();
            engine.target_end();
            Err(e)
        }
    }
}

/// Join a coalesced GEMV launch: drain the completion word, copy every
/// member's y back (un-padded, launch order), release all mappings and
/// exit the target region.
pub fn gemv_batch_finish<T: Elem>(
    engine: &mut OffloadEngine,
    mut state: GemvBatchState,
    outs: &mut [&mut [T]],
) -> Result<()> {
    let g = state.geom;
    let finish = (|| -> Result<()> {
        if outs.len() != state.members.len() {
            return Err(Error::shape(format!(
                "gemv_batch_finish: {} outputs for a batch of {}",
                outs.len(),
                state.members.len()
            )));
        }
        if T::SIZE != state.elem_size {
            return Err(Error::shape("gemv_batch_finish: element type mismatch"));
        }
        engine.join_completed()?;
        for (mem, out) in state.members.iter().zip(outs.iter_mut()) {
            if out.len() != g.m {
                return Err(Error::shape(format!(
                    "gemv_batch_finish: output len {} != {}",
                    out.len(),
                    g.m
                )));
            }
            let mut y_out = vec![0u8; mem.y_bytes.len()];
            engine.map_from_charged(
                state.staged.get(mem.yi),
                &mut y_out,
                (g.m * T::SIZE) as u64,
                "y",
            )?;
            let y_full: Vec<T> = T::bytes_to_vec(&y_out);
            out.copy_from_slice(&y_full[..g.m]);
        }
        for mem in &state.members {
            engine.unmap(state.staged.take(mem.ai), "a")?;
            engine.unmap(state.staged.take(mem.xi), "x")?;
            engine.unmap(state.staged.take(mem.yi), "y")?;
        }
        engine.target_end();
        Ok(())
    })();

    if let Err(e) = finish {
        state.staged.release_all(engine);
        engine.abort_offload();
        engine.target_end();
        return Err(e);
    }
    Ok(())
}

/// A coalesced batch of same-shape GEMVs as ONE offload — stage +
/// execute + finish in one synchronous call (the level-2 analogue of
/// [`gemm_batch_launch`]).  GEMV is far below the Figure-3 crossover at
/// serving sizes, so amortizing the fork/join across a batch is what
/// makes offloading it pay at all; the scheduler uses the split pieces
/// directly to overlap map-in with the previous batch's compute.
#[allow(clippy::too_many_arguments)]
pub fn gemv_batch<T: Elem>(
    engine: &mut OffloadEngine,
    registry: &mut ArtifactRegistry,
    (m, n): (usize, usize),
    alpha: T,
    beta: T,
    inputs: &[(&[T], &[T], &[T])],
    zero_copy: bool,
    outs: &mut [&mut [T]],
    kreg: Option<&KernelRegistry>,
) -> Result<()> {
    if outs.len() != inputs.len() {
        return Err(Error::shape(format!(
            "gemv_batch: {} outputs for a batch of {}",
            outs.len(),
            inputs.len()
        )));
    }
    let staged = gemv_batch_stage::<T>(
        engine, registry, (m, n), beta == T::zero(), inputs, zero_copy,
    )?;
    let state = gemv_batch_execute(engine, registry, staged, alpha, beta, kreg)?;
    gemv_batch_finish(engine, state, outs)
}

/// Device-DRAM bytes one staged batch member occupies for an (m, n)
/// GEMV — the level-2 analogue of [`gemm_staged_bytes`].
pub fn gemv_staged_bytes<T: Elem>(
    registry: &ArtifactRegistry,
    dims: (usize, usize),
) -> u64 {
    let man = registry.manifest();
    gemv_staged_bytes_tiled((man.tile_m, man.tile_n, man.tile_k), dims, T::SIZE)
}

/// Pre-stage a shared GEMM B operand into the operand cache *outside*
/// any batch (directory-driven prefetch): pad exactly like the staging
/// path, route through the cache, release the pin — the bytes stay
/// resident, so the next batch's `map(to:)` of the same B is a hit and
/// the miss cost lands outside the batch's accounted regions.  Returns
/// the cache key when the bytes ended up resident (cache off / too big
/// => `None`).  No target region is entered: this is a host-side copy
/// into the device partition, not an offload.
pub fn prefetch_gemm_b<T: Elem>(
    engine: &mut OffloadEngine,
    registry: &ArtifactRegistry,
    n: usize,
    b: &[T],
) -> Result<Option<crate::omp::CacheKey>> {
    if b.len() != n * n {
        return Err(Error::shape(format!(
            "prefetch_gemm_b: {} elements for n={n}",
            b.len()
        )));
    }
    let man = registry.manifest();
    let (tn, tk) = (man.tile_n, man.tile_k);
    let b_bytes = T::slice_to_bytes(&pad2(b, n, n, round_up(n, tk), round_up(n, tn)));
    let buf = engine.map_to_operand(&b_bytes, (n * n * T::SIZE) as u64, false, "b_prefetch")?;
    let key = buf.cache_key();
    engine.unmap(buf, "b_prefetch")?;
    Ok(key)
}

/// Heterogeneous AXPY (f64 only — the artifact catalog carries f64
/// level-1 kernels; f32 level-1 stays on the host, like the paper).
pub fn axpy_f64(
    engine: &mut OffloadEngine,
    registry: &mut ArtifactRegistry,
    alpha: f64,
    x: &[f64],
    y: &mut [f64],
    zero_copy: bool,
    kreg: Option<&KernelRegistry>,
) -> Result<()> {
    if x.len() != y.len() {
        return Err(Error::shape(format!(
            "axpy: length mismatch {} vs {}",
            x.len(),
            y.len()
        )));
    }
    // A single-member batch: the chunk walk, staging choices and cost
    // charges are exactly the batched path's — one code path to
    // calibrate.  The y snapshot is safe because chunks are disjoint.
    let y_in = y.to_vec();
    level1_batch(
        engine,
        registry,
        OffloadKind::Axpy,
        &[(alpha, x, y_in.as_slice())],
        zero_copy,
        &mut [y],
        kreg,
    )
}

/// Heterogeneous DOT (f64 only). Returns the scalar.
pub fn dot_f64(
    engine: &mut OffloadEngine,
    registry: &mut ArtifactRegistry,
    x: &[f64],
    y: &[f64],
    zero_copy: bool,
    kreg: Option<&KernelRegistry>,
) -> Result<f64> {
    if x.len() != y.len() {
        return Err(Error::shape(format!(
            "dot: length mismatch {} vs {}",
            x.len(),
            y.len()
        )));
    }
    let mut out = [0.0f64];
    level1_batch(
        engine,
        registry,
        OffloadKind::Dot,
        &[(0.0, x, y)],
        zero_copy,
        &mut [&mut out],
        kreg,
    )?;
    Ok(out[0])
}

/// A coalesced batch of same-length level-1 calls (axpy or dot) as ONE
/// offload: one OpenBLAS entry, one target region, one descriptor, one
/// doorbell — then every member's chunk walk back to back.  Level-1 is
/// the furthest below the Figure-3 crossover of all device paths (it
/// was the last one paying the fork/join per call), so the batcher's
/// amortization matters most here.
///
/// `inputs` carries one `(alpha, x, y)` per member (alpha ignored for
/// dot — members keep their own scale, like gemm members keep their own
/// operands).  Results land in `outs` (launch order): axpy writes the
/// updated y (length n), dot writes the scalar into `outs[i][0]`.
/// Synchronous — level-1 chunks are DMA-bound and not worth pipeline
/// state.
pub fn level1_batch(
    engine: &mut OffloadEngine,
    registry: &mut ArtifactRegistry,
    kind: OffloadKind,
    inputs: &[(f64, &[f64], &[f64])],
    zero_copy: bool,
    outs: &mut [&mut [f64]],
    kreg: Option<&KernelRegistry>,
) -> Result<()> {
    let (op, is_axpy) = match kind {
        OffloadKind::Axpy => ("axpy", true),
        OffloadKind::Dot => ("dot", false),
        other => {
            return Err(Error::shape(format!(
                "level1_batch: unsupported kind {other:?}"
            )))
        }
    };
    if inputs.is_empty() {
        return Err(Error::shape("level1_batch: empty batch"));
    }
    if outs.len() != inputs.len() {
        return Err(Error::shape(format!(
            "level1_batch: {} outputs for a batch of {}",
            outs.len(),
            inputs.len()
        )));
    }
    let n = inputs[0].1.len();
    for (i, (_, x, y)) in inputs.iter().enumerate() {
        if x.len() != n || y.len() != n {
            return Err(Error::shape(format!(
                "level1_batch: member {i} lengths {}x{} don't match n={n}",
                x.len(),
                y.len()
            )));
        }
    }
    for (i, out) in outs.iter().enumerate() {
        let want = if is_axpy { n } else { 1 };
        if out.len() != want {
            return Err(Error::shape(format!(
                "level1_batch: output {i} len {} != {want}",
                out.len()
            )));
        }
    }

    // largest available artifact size for this op (same chunking as the
    // single-call path)
    let mut sizes: Vec<usize> = registry
        .manifest()
        .entries
        .iter()
        .filter(|e| e.op == op && e.dtype == "f64")
        .filter_map(|e| e.n)
        .collect();
    sizes.sort_unstable();
    let chunk = *sizes
        .last()
        .ok_or_else(|| Error::Runtime(format!("no {op} artifact in manifest")))?;
    let artifact = format!("{op}_f64_n{chunk}");

    // ---- fork (once for the whole batch) ----
    engine.blas_entry();
    engine.target_begin((if is_axpy { 3 } else { 2 }) * inputs.len());

    let cc = level1_chunk_costs(&engine.platform.dma, &engine.platform.cluster, chunk);

    // ---- one descriptor, one doorbell ----
    let mut desc = OffloadDescriptor::new(kind, (n, 0, 0), false);
    for _ in inputs {
        desc.push_arg(OffloadArg {
            device_addr: 0,
            len: (n * 8) as u64,
            via_iommu: zero_copy,
        });
    }
    engine.launch(&desc)?;

    // Specialized fast path: one key covers the whole same-length batch.
    let plan = acquire_plan(
        engine,
        kreg,
        if is_axpy { KernelOp::Axpy } else { KernelOp::Dot },
        "f64",
        (chunk, 0, 0),
        (round_up(n, chunk), 0, 0),
        Epilogue::None,
    );
    let step = match &plan {
        Some(p) => p.steady_step,
        None => cc.dma.max(cc.fpu) + cc.dma,
    };

    let r = with_recovery(engine, |engine, staged| {
        for ((alpha, x, y), out) in inputs.iter().zip(outs.iter_mut()) {
            let mut acc = 0.0;
            let mut i = 0;
            while i < x.len() {
                let take = chunk.min(x.len() - i);
                let mut xc = x[i..i + take].to_vec();
                let mut yc = y[i..i + take].to_vec();
                xc.resize(chunk, 0.0);
                yc.resize(chunk, 0.0);
                let xb = f64::slice_to_bytes(&xc);
                let yb = f64::slice_to_bytes(&yc);
                // x is read-only: cache-eligible; y is the in-out operand
                let xi = staged.push(engine.map_to_operand(
                    &xb, (take * 8) as u64, zero_copy, "x",
                )?);
                let yi = staged.push(engine.map_to_charged(
                    &yb, (take * 8) as u64, zero_copy, "y",
                )?);

                let args: Vec<xla::Literal> = if is_axpy {
                    vec![lit_1d(&[*alpha]), lit_1d(&xc), lit_1d(&yc)]
                } else {
                    vec![lit_1d(&xc), lit_1d(&yc)]
                };
                let res = registry.exec(&artifact, &args)?;
                let out_vec = res.to_vec::<f64>()?;
                engine.metrics.tile_kernel_calls += 1;
                engine.charge_compute(step, &format!("{op}[{i}..{}]", i + take));

                if is_axpy {
                    out[i..i + take].copy_from_slice(&out_vec[..take]);
                } else {
                    acc += out_vec[0];
                }

                engine.unmap(staged.take(xi), "x")?;
                engine.unmap(staged.take(yi), "y")?;
                i += take;
            }
            if !is_axpy {
                out[0] = acc;
            }
        }

        engine.join()?;
        engine.target_end();
        Ok(())
    });
    if let (Some(reg), Some(p)) = (kreg, &plan) {
        reg.release(p.key);
    }
    r
}

// ---------------------------------------------------------------------------
// DAG executor: the chain's stage/execute/finish seam generalized to a
// typed dataflow graph ([`crate::dag::DagShape`]) with fan-out (one
// promoted output, several consumers) and fan-in (axpy/dot over two
// inputs).  A linear gemm-only DAG lowers to the *identical* charge
// sequence as [`gemm_chain_stage`]/[`gemm_chain_execute`]/
// [`gemm_chain_finish`] by construction: same staging calls, same
// descriptor, same walk, same promote/reuse bookkeeping — only the
// charge labels differ ("dag_keep"/"dag_reuse" vs "chain_keep"/
// "chain_reuse"), so region totals and numerics are bit-identical.
// ---------------------------------------------------------------------------

/// Per-node operands for one staged DAG, aligned index-for-index with
/// the shape's node list (the shape carries the op/edges/epilogue
/// *structure*; this carries the *data*).
#[derive(Debug, Clone, Copy)]
pub struct DagNodeSpec<'a, T: Elem> {
    /// Weight operand for matmul nodes: gemm wants (k x n) row-major,
    /// gemv wants length k.  Must be `None` for axpy/dot.
    pub b: Option<&'a [T]>,
    /// Per-row bias (length = the node's output width); present iff the
    /// shape's node declares `bias`.
    pub bias: Option<&'a [T]>,
}

/// One staged DAG node: uniform gemm geometry (gemv is the gemm walk
/// with n = 1; axpy/dot get an (m x w) / (1 x 1) output grid), staged
/// indices and the owned byte images whose host addresses key the
/// engine's data-map until unmap.
#[derive(Debug)]
struct DagMember {
    geom: GemmGeom,
    op: DagOp,
    src: Option<usize>,
    src2: Option<usize>,
    /// Staged weight index (matmul nodes only).
    bi: Option<usize>,
    ci: usize,
    #[allow(dead_code)]
    b_bytes: Option<Vec<u8>>,
    #[allow(dead_code)]
    c_bytes: Vec<u8>,
    /// Raw `T` bytes of the bias vector, when present.
    bias: Option<Vec<u8>>,
    relu: bool,
}

/// A staged-but-not-executed DAG: the external input, every matmul
/// node's weights and every node's output buffer are resident in the
/// cluster's device-DRAM slice, the doorbell has not rung.  Produced by
/// [`dag_stage`]; consumed by [`dag_execute`] — the same seam the
/// scheduler's software pipeline threads batches and chains through.
#[derive(Debug)]
pub struct DagStaged {
    staged: Staged,
    members: Vec<DagMember>,
    shape: DagShape,
    /// Index of the staged external input x.
    ai: usize,
    /// Padded row length of the staged x, in elements.
    x_lead: usize,
    #[allow(dead_code)]
    x_bytes: Vec<u8>,
    elem_size: usize,
}

impl DagStaged {
    /// Number of nodes staged.
    pub fn len(&self) -> usize {
        self.members.len()
    }

    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }

    /// The shape this staging lowered.
    pub fn shape(&self) -> &DagShape {
        &self.shape
    }

    /// Per-node cache identity of the staged weight operand (`None` for
    /// fan-in nodes and non-resident weights) — what the scheduler tags
    /// for its affinity directory, like [`GemmChainStaged::cached_b_keys`].
    pub fn cached_b_keys(&self) -> Vec<Option<crate::omp::CacheKey>> {
        self.members
            .iter()
            .map(|mem| mem.bi.and_then(|bi| self.staged.get(bi).cache_key()))
            .collect()
    }

    /// Error-path / cancellation teardown for a staged-but-never-executed
    /// DAG: releases every mapping (operand-cache pins included) and
    /// exits the target region — a cancelled DAG must not strand resident
    /// intermediates or `map(alloc:)` output buffers.
    pub fn release(mut self, engine: &mut OffloadEngine) {
        self.staged.release_all(engine);
        engine.target_end();
    }
}

/// An executed DAG between its doorbell and its finish: every node's
/// compute is done, the completion word is posted, the sink outputs are
/// still on the device.  Produced by [`dag_execute`]; consumed by
/// [`dag_finish`].
#[derive(Debug)]
pub struct DagState {
    staged: Staged,
    members: Vec<DagMember>,
    shape: DagShape,
    /// Observed Compute-region cycles per node, in index order — the
    /// per-link attribution the calibrator folds into per-op scales.
    node_cycles: Vec<u64>,
    #[allow(dead_code)]
    x_bytes: Vec<u8>,
    elem_size: usize,
}

impl DagState {
    pub fn len(&self) -> usize {
        self.members.len()
    }

    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }

    /// The shape this execution lowered.
    pub fn shape(&self) -> &DagShape {
        &self.shape
    }

    /// Observed Compute-region cycles per node, in index order.
    pub fn node_cycles(&self) -> &[u64] {
        &self.node_cycles
    }

    /// (rows, cols) of every sink output, in sink index order — the
    /// sizes [`dag_finish`] expects its `outs` slices to have.
    pub fn sink_dims(&self) -> Vec<(usize, usize)> {
        self.shape
            .sinks()
            .into_iter()
            .map(|s| {
                let g = self.members[s].geom;
                (g.m, g.n)
            })
            .collect()
    }
}

/// Resolve every node's uniform gemm geometry: gemm is (m, n, k), gemv
/// is the gemm walk with n = 1, axpy gets an (m x w) output grid and
/// dot a (1 x 1) scalar cell.
fn dag_geoms<T: Elem>(
    engine: &OffloadEngine,
    registry: &ArtifactRegistry,
    shape: &DagShape,
) -> Result<Vec<GemmGeom>> {
    let widths = shape.widths();
    shape
        .nodes
        .iter()
        .enumerate()
        .map(|(i, node)| {
            let k = shape.in_width(i);
            match node.op {
                DagOp::Gemm => GemmGeom::resolve::<T>(engine, registry, shape.m, node.n, k),
                DagOp::Gemv => GemmGeom::resolve::<T>(engine, registry, shape.m, 1, k),
                DagOp::Axpy => {
                    GemmGeom::resolve::<T>(engine, registry, shape.m, widths[i], widths[i])
                }
                DagOp::Dot => GemmGeom::resolve::<T>(engine, registry, 1, 1, k),
            }
        })
        .collect()
}

/// Stage a DAG for ONE offload: fork once, `map(to:)` the external input
/// (m x d0) and every matmul node's weights (cache-eligible read-only
/// operands), and stage every node's output `map(alloc:)`-style (beta =
/// 0 throughout).  Any error releases everything staged so far and exits
/// the target region.
///
/// Hand-off legality: an edge into a matmul consumer requires the
/// producer's padded output to BE the consumer's padded input
/// (`producer.np == consumer.kp`, i.e. `tile_n == tile_k`), exactly like
/// the chain.  Fan-in (axpy/dot) consumers read rows through the
/// producer's own lead, so they carry no such constraint.
pub fn dag_stage<T: Elem>(
    engine: &mut OffloadEngine,
    registry: &mut ArtifactRegistry,
    shape: &DagShape,
    x: &[T],
    nodes: &[DagNodeSpec<'_, T>],
) -> Result<DagStaged> {
    // structural legality (acyclicity, fan-in widths, dot sinks) without
    // imposing the scheduler's [sched.dag] bounds — those are the
    // submission layer's to enforce
    shape
        .validate(u32::MAX, u32::MAX, u32::MAX)
        .map_err(|e| Error::shape(format!("dag: {e}")))?;
    if nodes.len() != shape.nodes.len() {
        return Err(Error::shape(format!(
            "dag: {} node specs for {} shape nodes",
            nodes.len(),
            shape.nodes.len()
        )));
    }
    if x.len() != shape.m * shape.d0 {
        return Err(Error::shape(format!(
            "dag: input has {} elements, the shape wants {}x{}",
            x.len(),
            shape.m,
            shape.d0
        )));
    }
    let widths = shape.widths();
    for (i, (ns, spec)) in shape.nodes.iter().zip(nodes).enumerate() {
        let op = ns.op;
        let k = shape.in_width(i);
        if op.is_matmul() {
            let n = widths[i];
            let b = spec.b.ok_or_else(|| {
                Error::shape(format!("dag: node {i} ({op}) is missing its weight operand"))
            })?;
            if b.len() != k * n {
                return Err(Error::shape(format!(
                    "dag: node {i} ({op}) weights have {} elements for ({k}, {n})",
                    b.len()
                )));
            }
        } else if spec.b.is_some() {
            return Err(Error::shape(format!(
                "dag: node {i} ({op}) does not take a weight operand"
            )));
        }
        match (ns.bias, spec.bias) {
            (true, Some(bias)) => {
                if bias.len() != widths[i] {
                    return Err(Error::shape(format!(
                        "dag: node {i} ({op}) bias has {} elements for n={}",
                        bias.len(),
                        widths[i]
                    )));
                }
            }
            (true, None) => {
                return Err(Error::shape(format!(
                    "dag: node {i} ({op}) declares a bias but none was provided"
                )))
            }
            (false, Some(_)) => {
                return Err(Error::shape(format!(
                    "dag: node {i} ({op}) got a bias but its shape declares none"
                )))
            }
            (false, None) => {}
        }
    }
    let geoms = dag_geoms::<T>(engine, registry, shape)?;
    // padded hand-off identity, matmul consumers only (see doc above)
    for (i, node) in shape.nodes.iter().enumerate() {
        if !node.op.is_matmul() {
            continue;
        }
        if let Some(s) = node.src {
            if geoms[s].np != geoms[i].kp {
                return Err(Error::Offload(format!(
                    "dag: node {i} ({}) reads node {s}'s {}-wide output padded \
                     to {} as an output but {} as an input (tile_n != tile_k) \
                     — device-resident hand-off would change numerics",
                    node.op, geoms[s].n, geoms[s].np, geoms[i].kp
                )));
            }
        }
    }

    // ---- fork (once for the whole DAG) ----
    engine.blas_entry();
    engine.target_begin(shape.marshalled_args());

    let man = registry.manifest();
    let (tm, tk) = (man.tile_m, man.tile_k);
    let mut staged = Staged::default();
    let r = (|| -> Result<(usize, usize, Vec<u8>, Vec<DagMember>)> {
        let mp = round_up(shape.m, tm);
        let x_lead = round_up(shape.d0, tk);
        let x_bytes = T::slice_to_bytes(&pad2(x, shape.m, shape.d0, mp, x_lead));
        let ai = staged.push(engine.map_to_operand(
            &x_bytes,
            (shape.m * shape.d0 * T::SIZE) as u64,
            false,
            "x",
        )?);
        let mut members = Vec::with_capacity(shape.nodes.len());
        for ((node, spec), g) in shape.nodes.iter().zip(nodes).zip(geoms.iter()) {
            let (bi, b_bytes) = match spec.b {
                Some(b) => {
                    let b_bytes = T::slice_to_bytes(&pad2(b, g.k, g.n, g.kp, g.np));
                    let bi = staged.push(engine.map_to_operand(
                        &b_bytes,
                        (g.k * g.n * T::SIZE) as u64,
                        false,
                        "b",
                    )?);
                    (Some(bi), Some(b_bytes))
                }
                None => (None, None),
            };
            // beta = 0 by construction: outputs stage map(alloc:)-style,
            // zero-filled on the device, no host copy
            let c_bytes = vec![0u8; g.mp * g.np * T::SIZE];
            let ci = staged.push(engine.map_alloc(
                &c_bytes,
                (g.m * g.n * T::SIZE) as u64,
                "c",
            )?);
            members.push(DagMember {
                geom: *g,
                op: node.op,
                src: node.src,
                src2: node.src2,
                bi,
                ci,
                b_bytes,
                c_bytes,
                bias: spec.bias.map(T::slice_to_bytes),
                relu: node.relu,
            });
        }
        Ok((ai, x_lead, x_bytes, members))
    })();

    match r {
        Ok((ai, x_lead, x_bytes, members)) => Ok(DagStaged {
            staged,
            members,
            shape: shape.clone(),
            ai,
            x_lead,
            x_bytes,
            elem_size: T::SIZE,
        }),
        Err(e) => {
            staged.release_all(engine);
            engine.target_end();
            Err(e)
        }
    }
}

/// Element-wise fan-in compute on staged activations: axpy streams both
/// (rows x w) inputs through their own leads and writes the sum into the
/// node's output grid; dot reduces Σ a·b into the scalar cell at offset
/// 0.  Charged like a level-1 chunk pass (stream in, FPU, stream out);
/// numerics are exact f64/f32 host-identical ops, like [`chain_epilogue`].
#[allow(clippy::too_many_arguments)]
fn dag_fanin<T: Elem>(
    engine: &mut OffloadEngine,
    staged: &mut Staged,
    op: DagOp,
    rows: usize,
    w: usize,
    (i1, lead1): (usize, usize),
    (i2, lead2): (usize, usize),
    ci: usize,
    out_lead: usize,
) -> Result<()> {
    let mut acc = T::zero();
    for r in 0..rows {
        let a: Vec<T> = T::bytes_to_vec(&engine.read_mapped(
            staged.get(i1),
            r * lead1 * T::SIZE,
            w * T::SIZE,
        )?);
        let b: Vec<T> = T::bytes_to_vec(&engine.read_mapped(
            staged.get(i2),
            r * lead2 * T::SIZE,
            w * T::SIZE,
        )?);
        match op {
            DagOp::Axpy => {
                let row: Vec<T> =
                    a.iter().zip(b.iter()).map(|(x, y)| *x + *y).collect();
                engine.write_mapped(
                    staged.get_mut(ci),
                    r * out_lead * T::SIZE,
                    &T::slice_to_bytes(&row),
                )?;
            }
            DagOp::Dot => {
                for (x, y) in a.iter().zip(b.iter()) {
                    acc = acc + (*x) * (*y);
                }
            }
            _ => unreachable!("dag_fanin lowers fan-in nodes only"),
        }
    }
    if op == DagOp::Dot {
        engine.write_mapped(staged.get_mut(ci), 0, &T::slice_to_bytes(&[acc]))?;
    }
    let cc = level1_chunk_costs(&engine.platform.dma, &engine.platform.cluster, rows * w);
    let label = if op == DagOp::Dot { "dag_dot" } else { "dag_axpy" };
    engine.charge_compute(cc.dma.max(cc.fpu) + cc.dma, label);
    Ok(())
}

/// Execute a staged DAG: one descriptor, one doorbell, then every node's
/// compute in topological (index) order.  A node output with consumers
/// is promoted to device-resident ONCE ([`OffloadEngine::promote_output_dag`]);
/// every consuming edge books its elided re-stage
/// ([`OffloadEngine::note_dag_reuse`]) — so a fan-out trunk with two
/// consumers elides three transfers (the skipped `map(from:)` plus both
/// skipped `map(to:)`s).  The completion word is posted on return; poll
/// the mailbox and call [`dag_finish`].
pub fn dag_execute<T: Elem>(
    engine: &mut OffloadEngine,
    registry: &mut ArtifactRegistry,
    mut dag: DagStaged,
    kreg: Option<&KernelRegistry>,
) -> Result<DagState> {
    let r = (|| -> Result<Vec<u64>> {
        if T::SIZE != dag.elem_size {
            return Err(Error::shape("dag_execute: element type mismatch"));
        }
        let g0 = dag.members[0].geom;
        let mut desc = OffloadDescriptor::new(
            OffloadKind::Chain,
            (g0.m, g0.n, g0.k),
            T::F32_PATH,
        );
        let mut arg_indices = vec![dag.ai];
        for mem in &dag.members {
            if let Some(bi) = mem.bi {
                arg_indices.push(bi);
            }
            arg_indices.push(mem.ci);
        }
        for i in arg_indices {
            desc.push_arg(OffloadArg {
                device_addr: dag.staged.get(i).device_addr(),
                len: dag.staged.get(i).len,
                via_iommu: false,
            });
        }
        engine.launch(&desc)?;

        let consumers = dag.shape.consumer_counts();
        let rows = dag.shape.m;
        let (x_buf, x_lead, d0) = (dag.ai, dag.x_lead, dag.shape.d0);
        // (buffer index, padded lead, user rows, user cols) per node output
        let node_out: Vec<(usize, usize, usize, usize)> = dag
            .members
            .iter()
            .map(|mem| (mem.ci, mem.geom.np, mem.geom.m, mem.geom.n))
            .collect();
        let input_of = |s: Option<usize>| -> (usize, usize, usize) {
            match s {
                Some(j) => (node_out[j].0, node_out[j].1, node_out[j].3),
                None => (x_buf, x_lead, d0),
            }
        };
        let specs: Vec<(GemmGeom, DagOp, Option<usize>, Option<usize>, Option<usize>, usize, Option<Vec<T>>, bool)> =
            dag.members
                .iter()
                .map(|mem| {
                    (
                        mem.geom,
                        mem.op,
                        mem.src,
                        mem.src2,
                        mem.bi,
                        mem.ci,
                        mem.bias.as_ref().map(|b| T::bytes_to_vec(b)),
                        mem.relu,
                    )
                })
                .collect();
        let mut node_cycles = Vec::with_capacity(specs.len());
        for (i, (g, op, src, src2, bi, ci, bias, relu)) in specs.into_iter().enumerate() {
            // book each consuming edge's elided re-stage of a promoted
            // interior output (the external x carries no such credit)
            for s in [src, src2].into_iter().flatten() {
                let (_, _, pm, pn) = node_out[s];
                engine.note_dag_reuse((pm * pn * T::SIZE) as u64, "a");
            }
            let before = engine.trace.total(RegionClass::Compute).0;
            match op {
                DagOp::Gemm | DagOp::Gemv => {
                    let (a_buf, _, _) = input_of(src);
                    let bi = bi.expect("matmul node staged a weight");
                    // the node's epilogue is part of its kernel key: a
                    // promoted plan fuses bias/ReLU into the C write-back
                    let epi = Epilogue::of(bias.is_some(), relu);
                    let specialized = gemm_compute(
                        engine,
                        registry,
                        &mut dag.staged,
                        (a_buf, bi, ci),
                        g,
                        T::one(),
                        T::zero(),
                        kreg,
                        epi,
                    )?;
                    chain_epilogue::<T>(
                        engine,
                        &mut dag.staged,
                        ci,
                        g,
                        bias.as_deref(),
                        relu,
                        !specialized,
                    )?;
                }
                DagOp::Axpy | DagOp::Dot => {
                    let (i1, lead1, w) = input_of(src);
                    let (i2, lead2, _) = input_of(src2);
                    dag_fanin::<T>(
                        engine,
                        &mut dag.staged,
                        op,
                        rows,
                        w,
                        (i1, lead1),
                        (i2, lead2),
                        ci,
                        g.np,
                    )?;
                }
            }
            let after = engine.trace.total(RegionClass::Compute).0;
            node_cycles.push(after.saturating_sub(before));
            if consumers[i] > 0 {
                // the output stays resident: no map(from:), and every
                // consumer's map(to:) of the same bytes is elided
                let out = dag.staged.take(ci);
                let user_bytes = (g.m * g.n * T::SIZE) as u64;
                let kept = engine.promote_output_dag(out, user_bytes, "c")?;
                dag.staged.replace(ci, kept);
            }
        }
        engine.device_complete()?;
        Ok(node_cycles)
    })();

    match r {
        Ok(node_cycles) => Ok(DagState {
            staged: dag.staged,
            members: dag.members,
            shape: dag.shape,
            node_cycles,
            x_bytes: dag.x_bytes,
            elem_size: dag.elem_size,
        }),
        Err(e) => {
            dag.staged.release_all(engine);
            engine.abort_offload();
            engine.target_end();
            Err(e)
        }
    }
}

/// Join an executed DAG: drain the completion word, copy every SINK
/// output back (un-padded into `outs`, sink index order), release every
/// mapping — promoted intermediates drop their pins and stay resident
/// under normal LRU — and exit the target region.
///
/// `publish = true` additionally registers the LAST sink's padded output
/// in the operand cache before release ([`OffloadEngine::publish_output`]):
/// the bytes stay resident (unpinned) so a cross-request fused consumer's
/// `map(to:)` of the same activation is a verified hit.  No elision is
/// counted at publish time — the fused consumer's hit books it.
pub fn dag_finish<T: Elem>(
    engine: &mut OffloadEngine,
    mut state: DagState,
    outs: &mut [&mut [T]],
    publish: bool,
) -> Result<()> {
    let finish = (|| -> Result<()> {
        if T::SIZE != state.elem_size {
            return Err(Error::shape("dag_finish: element type mismatch"));
        }
        let sinks = state.shape.sinks();
        if outs.len() != sinks.len() {
            return Err(Error::shape(format!(
                "dag_finish: {} outputs for a dag with {} sinks",
                outs.len(),
                sinks.len()
            )));
        }
        engine.join_completed()?;
        for (&s, out) in sinks.iter().zip(outs.iter_mut()) {
            let g = state.members[s].geom;
            if out.len() != g.m * g.n {
                return Err(Error::shape(format!(
                    "dag_finish: sink {s} output len {} != {}x{}",
                    out.len(),
                    g.m,
                    g.n
                )));
            }
            let ci = state.members[s].ci;
            let mut c_out = vec![0u8; g.mp * g.np * T::SIZE];
            engine.map_from_charged(
                state.staged.get(ci),
                &mut c_out,
                (g.m * g.n * T::SIZE) as u64,
                "c",
            )?;
            let c_full = T::bytes_to_vec(&c_out);
            for r in 0..g.m {
                out[r * g.n..(r + 1) * g.n]
                    .copy_from_slice(&c_full[r * g.np..r * g.np + g.n]);
            }
        }
        if publish {
            let s = *sinks.last().expect("validated dag has a sink");
            let ci = state.members[s].ci;
            let buf = state.staged.take(ci);
            let kept = engine.publish_output(buf, "c")?;
            state.staged.replace(ci, kept);
        }
        state.staged.release_all(engine);
        engine.target_end();
        Ok(())
    })();

    if let Err(e) = finish {
        state.staged.release_all(engine);
        engine.abort_offload();
        engine.target_end();
        return Err(e);
    }
    Ok(())
}

/// Device-DRAM bytes a staged DAG occupies (input + every matmul node's
/// weights + every node's output — everything is resident at once, the
/// DAG's live-footprint high-water mark).  The formula lives in
/// [`crate::cost::tile`], shared with the placement router's estimates.
pub fn dag_staged_bytes<T: Elem>(registry: &ArtifactRegistry, shape: &DagShape) -> u64 {
    let man = registry.manifest();
    crate::cost::tile::dag_staged_bytes_tiled(
        (man.tile_m, man.tile_n, man.tile_k),
        shape,
        T::SIZE,
    )
}

