//! Element-type abstraction: the two dtypes the platform supports.
//!
//! f64 is the paper's measured configuration; f32 is its future-work
//! "SIMD operations on lower precision data types" path (two lanes per
//! 64-bit Snitch FPU).

use xla::{ArrayElement, NativeType};

/// A BLAS element type.
pub trait Elem:
    Copy
    + Default
    + PartialOrd
    + std::fmt::Debug
    + std::fmt::Display
    + num_traits::Float
    + NativeType
    + ArrayElement
    + Send
    + Sync
    + 'static
{
    /// Manifest dtype tag ("f32"/"f64").
    const DTYPE: &'static str;
    /// Bytes per element.
    const SIZE: usize;
    /// Does the cluster take the double-rate f32 path for this type?
    const F32_PATH: bool;

    fn from_f64_lossy(v: f64) -> Self;
    fn to_f64_lossy(self) -> f64;

    /// Little-endian byte image of a slice (device DRAM representation).
    fn slice_to_bytes(s: &[Self]) -> Vec<u8>;
    /// Inverse of [`Elem::slice_to_bytes`].
    fn bytes_to_vec(b: &[u8]) -> Vec<Self>;
}

/// memcpy-based slice -> little-endian bytes (§Perf change L3-3: the
/// per-element `to_le_bytes` loop was a measurable cost on the offload
/// path at N=256).  The target is little-endian (x86/RISC-V), so the
/// in-memory representation *is* the LE byte image; the device-DRAM
/// backing store uses the same convention on both ends.
fn pod_to_bytes<T: Copy>(s: &[T]) -> Vec<u8> {
    let size = std::mem::size_of_val(s);
    let mut out = vec![0u8; size];
    // SAFETY: T is a plain f32/f64; any byte pattern is valid u8.
    unsafe {
        std::ptr::copy_nonoverlapping(s.as_ptr() as *const u8, out.as_mut_ptr(), size);
    }
    out
}

fn bytes_to_pod<T: Copy + Default>(b: &[u8], elem_size: usize) -> Vec<T> {
    assert_eq!(
        b.len() % elem_size,
        0,
        "byte length not a multiple of {elem_size}"
    );
    let n = b.len() / elem_size;
    let mut out = vec![T::default(); n];
    // SAFETY: out has exactly b.len() bytes of capacity; f32/f64 accept
    // any byte pattern (NaN payloads round-trip bit-exactly).
    unsafe {
        std::ptr::copy_nonoverlapping(b.as_ptr(), out.as_mut_ptr() as *mut u8, b.len());
    }
    out
}

impl Elem for f64 {
    const DTYPE: &'static str = "f64";
    const SIZE: usize = 8;
    const F32_PATH: bool = false;

    fn from_f64_lossy(v: f64) -> Self {
        v
    }

    fn to_f64_lossy(self) -> f64 {
        self
    }

    fn slice_to_bytes(s: &[Self]) -> Vec<u8> {
        pod_to_bytes(s)
    }

    fn bytes_to_vec(b: &[u8]) -> Vec<Self> {
        bytes_to_pod(b, 8)
    }
}

impl Elem for f32 {
    const DTYPE: &'static str = "f32";
    const SIZE: usize = 4;
    const F32_PATH: bool = true;

    fn from_f64_lossy(v: f64) -> Self {
        v as f32
    }

    fn to_f64_lossy(self) -> f64 {
        self as f64
    }

    fn slice_to_bytes(s: &[Self]) -> Vec<u8> {
        pod_to_bytes(s)
    }

    fn bytes_to_vec(b: &[u8]) -> Vec<Self> {
        bytes_to_pod(b, 4)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f64_byte_roundtrip() {
        let v = vec![1.5f64, -2.25, 0.0, f64::MAX];
        assert_eq!(f64::bytes_to_vec(&f64::slice_to_bytes(&v)), v);
    }

    #[test]
    fn f32_byte_roundtrip() {
        let v = vec![1.5f32, -2.25, 0.0, f32::MIN_POSITIVE];
        assert_eq!(f32::bytes_to_vec(&f32::slice_to_bytes(&v)), v);
    }

    #[test]
    fn constants() {
        assert_eq!(f64::DTYPE, "f64");
        assert_eq!(f32::DTYPE, "f32");
        assert_eq!(f64::SIZE, 8);
        assert_eq!(f32::SIZE, 4);
        assert!(f32::F32_PATH && !f64::F32_PATH);
    }

    #[test]
    #[should_panic(expected = "multiple of 8")]
    fn misaligned_bytes_panic() {
        f64::bytes_to_vec(&[0u8; 7]);
    }
}
