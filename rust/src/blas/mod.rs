//! The BLAS library — arrow (3) in the paper's Figure 2.
//!
//! Mirrors OpenBLAS' structure: an interface layer with CBLAS semantics
//! ([`api`]), host kernels hand-written for the CVA6 ([`host`]), the
//! heterogeneous device kernels contributed by the paper ([`device`]),
//! and the driver-level dispatch choosing between them ([`dispatch`]).
//!
//! The paper compiles GEMM for host **and** device, and kernels like
//! `syrk.c` host-only; our dispatch table encodes the same split (and an
//! ablation bench flips it).

pub mod api;
pub mod device;
pub mod dispatch;
pub mod elem;
pub mod host;
pub mod types;

pub use api::{
    ChainRun, ChainStagedRun, DagRun, DagStagedRun, GemmBatchRun,
    GemmStagedRun, GemvBatchRun, GemvStagedRun, HeroBlas,
};
pub use device::ChainLinkSpec as ChainLink;
pub use device::DagNodeSpec as DagNode;
pub use dispatch::{DispatchPolicy, ExecTarget};
pub use elem::Elem;
pub use types::{Side, Transpose, Uplo};
