//! Dispatch: which kernels may offload, and when it pays off.
//!
//! Mirrors the paper's build-time split (GEMM compiled for host+device,
//! `syrk.c` host-only).  The `Auto` mode decides *when* offload pays:
//! with a [`CostModel`] attached (every [`super::HeroBlas`] session gets
//! one), the decision is a calibrated device-vs-host cost comparison —
//! the paper's Figure-3 crossover derived from the platform description
//! instead of hard-coded, shape-exact instead of max-dim, and
//! *cache-aware*: a predicted operand-cache hit (B already resident on
//! the target cluster, per the scheduler's affinity directory) drops the
//! map-in cost from the estimate, so warm shared-B streams offload below
//! the cold crossover.  Without a model (plain policy values, unit
//! tests) the original static thresholds apply.

use std::sync::Arc;

use crate::config::DispatchMode;
use crate::cost::CostModel;
use crate::dag::DagShape;
use crate::hero::offload::OffloadKind;
use crate::kernel::{Epilogue, KernelRegistry};

/// Where one call will execute.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecTarget {
    Host,
    /// Offload via copies into the device DRAM partition.
    Device,
    /// Offload via IOMMU zero-copy mapping.
    DeviceZeroCopy,
}

/// The dispatch policy (one per session; ablation benches mutate it).
#[derive(Debug, Clone)]
pub struct DispatchPolicy {
    pub mode: DispatchMode,
    /// `Auto` fallback without a model: offload GEMM when
    /// max(m, n, k) >= this.
    pub gemm_threshold: usize,
    /// `Auto` fallback without a model: offload GEMV when m*n >= this
    /// (level-2 is memory-bound; the copy cost usually dwarfs the win,
    /// hence a high default).
    pub gemv_threshold: usize,
    /// `Auto` fallback without a model: offload level-1 ops when
    /// n >= this.
    pub level1_threshold: usize,
    /// Kernels allowed on the device at all (the paper's Makefile split).
    pub device_kernels: Vec<OffloadKind>,
    /// The calibrated cost estimator behind `Auto` — when present, the
    /// three thresholds above are ignored and every decision is a model
    /// comparison.  [`super::HeroBlas::new`] attaches one; the scheduler
    /// replaces it with the pool-shared (jointly calibrated) instance.
    pub model: Option<CostModel>,
    /// The shape-specialized kernel registry (pool-shared, attached by
    /// the scheduler).  When a promoted plan covers a shape's key, the
    /// `Auto` comparison uses the specialized-walk estimate — hot shapes
    /// offload below the generic crossover.  Dispatch-only: the
    /// specialized walk is bit-identical to the generic one.
    pub kernel: Option<Arc<KernelRegistry>>,
}

impl Default for DispatchPolicy {
    fn default() -> Self {
        DispatchPolicy {
            mode: DispatchMode::Auto,
            // Calibrated from the Figure 3 crossover (between 64 and 128).
            gemm_threshold: 96,
            gemv_threshold: 512 * 512,
            level1_threshold: 1 << 20,
            device_kernels: vec![
                OffloadKind::Gemm,
                OffloadKind::Gemv,
                OffloadKind::Axpy,
                OffloadKind::Dot,
            ],
            model: None,
            kernel: None,
        }
    }
}

impl DispatchPolicy {
    pub fn with_mode(mode: DispatchMode) -> Self {
        DispatchPolicy { mode, ..Default::default() }
    }

    fn kernel_allowed(&self, kind: OffloadKind) -> bool {
        self.device_kernels.contains(&kind)
    }

    /// Key of a resident specialized plan covering this serve-shape, if
    /// any.  Serve traffic is f64 and single calls carry no epilogue;
    /// this is a dispatch estimate, not numerics, so those defaults are
    /// the right (conservative) probe.
    fn spec_key(&self, op: &str, dims: (usize, usize, usize)) -> Option<u64> {
        let reg = self.kernel.as_deref()?;
        let key = reg.key_for(op, "f64", dims, Epilogue::None)?;
        reg.has_plan(key).then_some(key)
    }

    fn forced(&self) -> Option<ExecTarget> {
        match self.mode {
            DispatchMode::HostOnly => Some(ExecTarget::Host),
            DispatchMode::DeviceOnly => Some(ExecTarget::Device),
            DispatchMode::DeviceZeroCopy => Some(ExecTarget::DeviceZeroCopy),
            DispatchMode::Auto => None,
        }
    }

    /// Decide for a GEMM of op-shape (m, n, k), all operands cold.
    pub fn gemm(&self, m: usize, n: usize, k: usize) -> ExecTarget {
        self.gemm_warm(m, n, k, false)
    }

    /// Decide for a GEMM of op-shape (m, n, k).  `warm_b` predicts the B
    /// operand already device-resident (an operand-cache hit, per the
    /// scheduler's affinity directory) — warmth can only lower the
    /// offload estimate, so a warm stream offloads at sizes a cold one
    /// would keep on the host.
    pub fn gemm_warm(&self, m: usize, n: usize, k: usize, warm_b: bool) -> ExecTarget {
        if !self.kernel_allowed(OffloadKind::Gemm) {
            return ExecTarget::Host;
        }
        if let Some(t) = self.forced() {
            return t;
        }
        let wins = match &self.model {
            Some(cm) => match self.spec_key("gemm", (m, n, k)) {
                Some(key) => cm.device_wins_gemm_spec(m, n, k, warm_b, Some(key)),
                None => cm.device_wins_gemm(m, n, k, warm_b),
            },
            None => m.max(n).max(k) >= self.gemm_threshold,
        };
        if wins {
            ExecTarget::Device
        } else {
            ExecTarget::Host
        }
    }

    /// Decide for a GEMM *chain* over layer widths `dims = [d0, .., dL]`
    /// with `m` activation rows.  The chain's elided interior copies make
    /// the device win for chains whose individual links sit below the
    /// cold crossover — the model compares ONE chained launch against L
    /// host GEMMs.  Chained residency is a copy-mode technique, so a
    /// forced zero-copy mode still takes the copy-mode device path.
    pub fn chain(&self, m: usize, dims: &[usize]) -> ExecTarget {
        if !self.kernel_allowed(OffloadKind::Gemm) || dims.len() < 2 {
            return ExecTarget::Host;
        }
        match self.forced() {
            Some(ExecTarget::Host) => return ExecTarget::Host,
            Some(_) => return ExecTarget::Device,
            None => {}
        }
        let wins = match &self.model {
            Some(cm) => cm.device_wins_chain(m, dims),
            None => {
                // threshold fallback: offload when any link clears the
                // static gemm threshold (the model answers this better)
                dims.iter().copied().chain(std::iter::once(m)).max().unwrap_or(0)
                    >= self.gemm_threshold
            }
        };
        if wins {
            ExecTarget::Device
        } else {
            ExecTarget::Host
        }
    }

    /// Decide for a DAG request: ONE graph-shaped launch (interior edges
    /// device-resident) against every node dispatched individually on
    /// the host.  Like [`DispatchPolicy::chain`], residency is a
    /// copy-mode technique — a forced zero-copy mode still takes the
    /// copy-mode device path.  A linear gemm-only DAG decides exactly
    /// like the equivalent chain.
    pub fn dag(&self, shape: &DagShape) -> ExecTarget {
        if !self.kernel_allowed(OffloadKind::Gemm) || shape.nodes.is_empty() {
            return ExecTarget::Host;
        }
        match self.forced() {
            Some(ExecTarget::Host) => return ExecTarget::Host,
            Some(_) => return ExecTarget::Device,
            None => {}
        }
        let wins = match &self.model {
            Some(cm) => cm.device_wins_dag(shape),
            None => {
                // threshold fallback, like the chain's: offload when any
                // node dimension clears the static gemm threshold
                shape
                    .widths()
                    .into_iter()
                    .chain([shape.m, shape.d0])
                    .max()
                    .unwrap_or(0)
                    >= self.gemm_threshold
            }
        };
        if wins {
            ExecTarget::Device
        } else {
            ExecTarget::Host
        }
    }

    /// Decide for a GEMV of op-shape (m, n).
    pub fn gemv(&self, m: usize, n: usize) -> ExecTarget {
        if !self.kernel_allowed(OffloadKind::Gemv) {
            return ExecTarget::Host;
        }
        if let Some(t) = self.forced() {
            return t;
        }
        let wins = match &self.model {
            Some(cm) => match self.spec_key("gemv", (m, n, 0)) {
                Some(key) => cm.device_wins_gemv_spec(m, n, Some(key)),
                None => cm.device_wins_gemv(m, n),
            },
            None => m * n >= self.gemv_threshold,
        };
        if wins {
            ExecTarget::Device
        } else {
            ExecTarget::Host
        }
    }

    /// Decide for a level-1 op of length n.
    pub fn level1(&self, kind: OffloadKind, n: usize) -> ExecTarget {
        if !self.kernel_allowed(kind) {
            return ExecTarget::Host;
        }
        if let Some(t) = self.forced() {
            return t;
        }
        let is_axpy = kind == OffloadKind::Axpy;
        let wins = match &self.model {
            Some(cm) => {
                let op = if is_axpy { "axpy" } else { "dot" };
                match self.spec_key(op, (n, 0, 0)) {
                    Some(key) => cm.device_wins_level1_spec(n, is_axpy, Some(key)),
                    None => cm.device_wins_level1(n, is_axpy),
                }
            }
            None => n >= self.level1_threshold,
        };
        if wins {
            ExecTarget::Device
        } else {
            ExecTarget::Host
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PlatformConfig;

    #[test]
    fn auto_uses_threshold() {
        let p = DispatchPolicy::default();
        assert_eq!(p.gemm(64, 64, 64), ExecTarget::Host);
        assert_eq!(p.gemm(128, 128, 128), ExecTarget::Device);
        // any large dim triggers the offload
        assert_eq!(p.gemm(8, 8, 512), ExecTarget::Device);
    }

    #[test]
    fn forced_modes_override_size() {
        let host = DispatchPolicy::with_mode(DispatchMode::HostOnly);
        assert_eq!(host.gemm(4096, 4096, 4096), ExecTarget::Host);
        let dev = DispatchPolicy::with_mode(DispatchMode::DeviceOnly);
        assert_eq!(dev.gemm(2, 2, 2), ExecTarget::Device);
        let zc = DispatchPolicy::with_mode(DispatchMode::DeviceZeroCopy);
        assert_eq!(zc.gemm(2, 2, 2), ExecTarget::DeviceZeroCopy);
    }

    #[test]
    fn host_only_kernels_stay_host() {
        // syrk-style: not in device_kernels -> host even when forced device
        let mut p = DispatchPolicy::with_mode(DispatchMode::DeviceOnly);
        p.device_kernels = vec![OffloadKind::Gemm];
        assert_eq!(p.gemv(4096, 4096), ExecTarget::Host);
        assert_eq!(p.level1(OffloadKind::Axpy, 1 << 22), ExecTarget::Host);
        assert_eq!(p.gemm(2, 2, 2), ExecTarget::Device);
    }

    #[test]
    fn dispatch_is_total_and_deterministic() {
        let p = DispatchPolicy::default();
        for &m in &[1usize, 16, 96, 1000] {
            for &n in &[1usize, 64, 128] {
                for &k in &[1usize, 95, 96] {
                    assert_eq!(p.gemm(m, n, k), p.gemm(m, n, k));
                }
            }
        }
    }

    fn model_policy(cache_on: bool) -> DispatchPolicy {
        let mut cfg = PlatformConfig::default();
        if cache_on {
            cfg.sched.cache.cache_frac = 0.4;
        }
        DispatchPolicy {
            model: Some(CostModel::from_platform(&cfg, (64, 64, 64), 4096)),
            ..Default::default()
        }
    }

    #[test]
    fn model_auto_keeps_the_figure3_band() {
        let p = model_policy(false);
        // the model's crossover sits between the paper's measured points
        assert_eq!(p.gemm(64, 64, 64), ExecTarget::Host);
        assert_eq!(p.gemm(128, 128, 128), ExecTarget::Device);
        assert_eq!(p.gemm(16, 16, 16), ExecTarget::Host);
    }

    #[test]
    fn model_auto_is_shape_exact_not_max_dim() {
        // (8, 8, 512): the static threshold offloads on max-dim alone,
        // but 2*8*8*512 FLOPs cannot amortize the fixed fork-join — the
        // model keeps it on the host
        let p = model_policy(false);
        assert_eq!(p.gemm(8, 8, 512), ExecTarget::Host);
    }

    #[test]
    fn model_auto_gemv_and_level1_stay_host_cold() {
        // copy-mode level-2/level-1 never beat the host cold: the
        // partition copy alone outweighs the host FLOPs (the old static
        // thresholds claimed otherwise above 512x512 / 1M)
        let p = model_policy(false);
        assert_eq!(p.gemv(512, 512), ExecTarget::Host);
        assert_eq!(p.gemv(2048, 2048), ExecTarget::Host);
        assert_eq!(p.level1(OffloadKind::Axpy, 1 << 20), ExecTarget::Host);
        assert_eq!(p.level1(OffloadKind::Dot, 1 << 20), ExecTarget::Host);
    }

    #[test]
    fn chain_dispatch_wins_below_the_per_op_crossover() {
        let p = model_policy(false);
        // n=64 links lose individually, but a 3-link chain pays one
        // fork-join and no interior copies: the chain decision flips
        assert_eq!(p.gemm(64, 64, 64), ExecTarget::Host);
        assert_eq!(p.chain(64, &[64, 64]), ExecTarget::Host);
        assert_eq!(p.chain(64, &[64, 64, 64, 64]), ExecTarget::Device);
        // forced modes override; zero-copy forcing still runs the
        // copy-mode chain path
        let host = DispatchPolicy::with_mode(DispatchMode::HostOnly);
        assert_eq!(host.chain(64, &[512, 512, 512]), ExecTarget::Host);
        let zc = DispatchPolicy::with_mode(DispatchMode::DeviceZeroCopy);
        assert_eq!(zc.chain(16, &[16, 16]), ExecTarget::Device);
        // degenerate specs stay host
        assert_eq!(p.chain(64, &[64]), ExecTarget::Host);
        // gemm disabled for the device => chains can never offload
        let mut no_gemm = model_policy(false);
        no_gemm.device_kernels = vec![OffloadKind::Gemv];
        assert_eq!(no_gemm.chain(64, &[64, 64, 64, 64]), ExecTarget::Host);
    }

    #[test]
    fn linear_dag_dispatch_matches_the_chain_decision() {
        use crate::dag::linear_gemm_shape;
        let p = model_policy(false);
        for dims in [&[64usize, 64][..], &[64, 64, 64, 64], &[512, 512, 512]] {
            let shape = linear_gemm_shape(64, dims);
            assert_eq!(
                p.dag(&shape),
                p.chain(64, dims),
                "linear dag vs chain for dims {dims:?}"
            );
        }
        // forced modes override just like the chain's
        let host = DispatchPolicy::with_mode(DispatchMode::HostOnly);
        assert_eq!(
            host.dag(&linear_gemm_shape(64, &[512, 512, 512])),
            ExecTarget::Host
        );
        let zc = DispatchPolicy::with_mode(DispatchMode::DeviceZeroCopy);
        assert_eq!(
            zc.dag(&linear_gemm_shape(16, &[16, 16])),
            ExecTarget::Device
        );
        // gemm disabled for the device => dags can never offload
        let mut no_gemm = model_policy(false);
        no_gemm.device_kernels = vec![OffloadKind::Gemv];
        assert_eq!(
            no_gemm.dag(&linear_gemm_shape(64, &[64, 64, 64, 64])),
            ExecTarget::Host
        );
    }

    #[test]
    fn resident_plan_offloads_below_the_generic_crossover() {
        use crate::config::KernelConfig;
        use crate::kernel::{KernelOp, KernelPlan, KernelRegistry};
        use crate::soc::{DmaModel, SnitchCluster};

        let mut p = model_policy(false);
        let x = p.model.as_ref().unwrap().crossovers();
        let (spec, generic) = (x.gemm_spec_n.unwrap(), x.gemm_n.unwrap());
        assert!(
            spec < generic,
            "fused epilogue + FPU gain must buy a gap: spec {spec} vs {generic}"
        );
        // inside the gap, the generic comparison keeps the shape on host
        assert_eq!(p.gemm(spec, spec, spec), ExecTarget::Host);

        // promote the shape: a resident plan switches Auto to the
        // specialized estimate and the same call now offloads
        let cfg = PlatformConfig::default();
        let reg = KernelRegistry::new(
            &KernelConfig { promote_after: 1, ..KernelConfig::default() },
            (64, 64, 64),
            4096,
        );
        let dma = DmaModel::new(cfg.dma.clone());
        let cluster =
            SnitchCluster::new(cfg.cluster.clone(), cfg.memory.l1_spm_bytes);
        let r = |v: usize| v.div_ceil(64) * 64;
        reg.insert(KernelPlan::specialize(
            &dma,
            &cluster,
            KernelOp::Gemm,
            "f64",
            (64, 64, 64),
            (r(spec), r(spec), r(spec)),
            Epilogue::None,
        ));
        p.kernel = Some(Arc::new(reg));
        assert_eq!(p.gemm(spec, spec, spec), ExecTarget::Device);
    }

    #[test]
    fn warm_b_offloads_below_the_cold_crossover() {
        let p = model_policy(true);
        let cm = p.model.as_ref().unwrap();
        let x = cm.crossovers();
        let (cold, warm) = (x.gemm_n.unwrap(), x.gemm_warm_n.unwrap());
        assert!(warm < cold, "warm {warm} vs cold {cold}");
        // at a size inside the gap, warmth flips the decision
        assert_eq!(p.gemm_warm(warm, warm, warm, false), ExecTarget::Host);
        assert_eq!(p.gemm_warm(warm, warm, warm, true), ExecTarget::Device);
    }
}
