//! Dispatch: which kernels may offload, and when it pays off.
//!
//! Mirrors the paper's build-time split (GEMM compiled for host+device,
//! `syrk.c` host-only) plus a size threshold for the `Auto` mode — the
//! paper's Figure 3 shows offload *losing* below the crossover size, so a
//! production dispatch must pick the host for small problems.

use crate::config::DispatchMode;
use crate::hero::offload::OffloadKind;

/// Where one call will execute.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecTarget {
    Host,
    /// Offload via copies into the device DRAM partition.
    Device,
    /// Offload via IOMMU zero-copy mapping.
    DeviceZeroCopy,
}

/// The dispatch policy (one per session; ablation benches mutate it).
#[derive(Debug, Clone)]
pub struct DispatchPolicy {
    pub mode: DispatchMode,
    /// `Auto`: offload GEMM when max(m, n, k) >= this.
    pub gemm_threshold: usize,
    /// `Auto`: offload GEMV when m*n >= this (level-2 is memory-bound;
    /// the copy cost usually dwarfs the win, hence a high default).
    pub gemv_threshold: usize,
    /// `Auto`: offload level-1 ops when n >= this.
    pub level1_threshold: usize,
    /// Kernels allowed on the device at all (the paper's Makefile split).
    pub device_kernels: Vec<OffloadKind>,
}

impl Default for DispatchPolicy {
    fn default() -> Self {
        DispatchPolicy {
            mode: DispatchMode::Auto,
            // Calibrated from the Figure 3 crossover (between 64 and 128).
            gemm_threshold: 96,
            gemv_threshold: 512 * 512,
            level1_threshold: 1 << 20,
            device_kernels: vec![
                OffloadKind::Gemm,
                OffloadKind::Gemv,
                OffloadKind::Axpy,
                OffloadKind::Dot,
            ],
        }
    }
}

impl DispatchPolicy {
    pub fn with_mode(mode: DispatchMode) -> Self {
        DispatchPolicy { mode, ..Default::default() }
    }

    fn kernel_allowed(&self, kind: OffloadKind) -> bool {
        self.device_kernels.contains(&kind)
    }

    fn forced(&self) -> Option<ExecTarget> {
        match self.mode {
            DispatchMode::HostOnly => Some(ExecTarget::Host),
            DispatchMode::DeviceOnly => Some(ExecTarget::Device),
            DispatchMode::DeviceZeroCopy => Some(ExecTarget::DeviceZeroCopy),
            DispatchMode::Auto => None,
        }
    }

    /// Decide for a GEMM of op-shape (m, n, k).
    pub fn gemm(&self, m: usize, n: usize, k: usize) -> ExecTarget {
        if !self.kernel_allowed(OffloadKind::Gemm) {
            return ExecTarget::Host;
        }
        if let Some(t) = self.forced() {
            return t;
        }
        if m.max(n).max(k) >= self.gemm_threshold {
            ExecTarget::Device
        } else {
            ExecTarget::Host
        }
    }

    /// Decide for a GEMV of op-shape (m, n).
    pub fn gemv(&self, m: usize, n: usize) -> ExecTarget {
        if !self.kernel_allowed(OffloadKind::Gemv) {
            return ExecTarget::Host;
        }
        if let Some(t) = self.forced() {
            return t;
        }
        if m * n >= self.gemv_threshold {
            ExecTarget::Device
        } else {
            ExecTarget::Host
        }
    }

    /// Decide for a level-1 op of length n.
    pub fn level1(&self, kind: OffloadKind, n: usize) -> ExecTarget {
        if !self.kernel_allowed(kind) {
            return ExecTarget::Host;
        }
        if let Some(t) = self.forced() {
            return t;
        }
        if n >= self.level1_threshold {
            ExecTarget::Device
        } else {
            ExecTarget::Host
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn auto_uses_threshold() {
        let p = DispatchPolicy::default();
        assert_eq!(p.gemm(64, 64, 64), ExecTarget::Host);
        assert_eq!(p.gemm(128, 128, 128), ExecTarget::Device);
        // any large dim triggers the offload
        assert_eq!(p.gemm(8, 8, 512), ExecTarget::Device);
    }

    #[test]
    fn forced_modes_override_size() {
        let host = DispatchPolicy::with_mode(DispatchMode::HostOnly);
        assert_eq!(host.gemm(4096, 4096, 4096), ExecTarget::Host);
        let dev = DispatchPolicy::with_mode(DispatchMode::DeviceOnly);
        assert_eq!(dev.gemm(2, 2, 2), ExecTarget::Device);
        let zc = DispatchPolicy::with_mode(DispatchMode::DeviceZeroCopy);
        assert_eq!(zc.gemm(2, 2, 2), ExecTarget::DeviceZeroCopy);
    }

    #[test]
    fn host_only_kernels_stay_host() {
        // syrk-style: not in device_kernels -> host even when forced device
        let mut p = DispatchPolicy::with_mode(DispatchMode::DeviceOnly);
        p.device_kernels = vec![OffloadKind::Gemm];
        assert_eq!(p.gemv(4096, 4096), ExecTarget::Host);
        assert_eq!(p.level1(OffloadKind::Axpy, 1 << 22), ExecTarget::Host);
        assert_eq!(p.gemm(2, 2, 2), ExecTarget::Device);
    }

    #[test]
    fn dispatch_is_total_and_deterministic() {
        let p = DispatchPolicy::default();
        for &m in &[1usize, 16, 96, 1000] {
            for &n in &[1usize, 64, 128] {
                for &k in &[1usize, 95, 96] {
                    assert_eq!(p.gemm(m, n, k), p.gemm(m, n, k));
                }
            }
        }
    }
}
