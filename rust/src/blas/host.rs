//! Host-side kernels — the analogue of OpenBLAS' hand-crafted CVA6/rv64
//! kernels (plus `syrk.c` and friends that the paper compiles host-only).
//!
//! These run for real on the coordinator (they produce the baseline's
//! numerics) while [`crate::soc::cva6`] separately answers how long the
//! 50 MHz in-order core would take.  `gemm` is cache-blocked with packed
//! panels and a 4x4 register microkernel; everything else is a clean
//! streaming loop.  `naive_gemm` is the unoptimized oracle the tests
//! compare against.

use super::elem::Elem;
use super::types::{Transpose, Uplo};

/// Textbook triple loop (test oracle; also the shape the paper's host
/// baseline effectively runs through OpenBLAS' generic C kernel).
pub fn naive_gemm<T: Elem>(
    m: usize,
    n: usize,
    k: usize,
    alpha: T,
    a: &[T], // op(A) given row-major m x k
    b: &[T], // op(B) given row-major k x n
    beta: T,
    c: &mut [T], // row-major m x n
) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    for i in 0..m {
        for j in 0..n {
            let mut acc = T::zero();
            for p in 0..k {
                acc = acc + a[i * k + p] * b[p * n + j];
            }
            c[i * n + j] = alpha * acc + beta * c[i * n + j];
        }
    }
}

/// Materialize op(X) as a row-major dense buffer.
pub fn materialize_op<T: Elem>(x: &[T], rows: usize, cols: usize,
                               trans: Transpose) -> Vec<T> {
    assert_eq!(x.len(), rows * cols);
    match trans {
        Transpose::No => x.to_vec(),
        Transpose::Yes => {
            let mut out = vec![T::zero(); rows * cols];
            for r in 0..rows {
                for c in 0..cols {
                    out[c * rows + r] = x[r * cols + c];
                }
            }
            out
        }
    }
}

// Cache-blocking parameters for the packed GEMM (sized for typical L1/L2;
// revisited in the §Perf pass).
const MC: usize = 128;
const KC: usize = 256;
const NC: usize = 512;
const MR: usize = 4;
const NR: usize = 4;

/// Pack an MC x KC block of A into row-panels of height MR.
fn pack_a<T: Elem>(a: &[T], lda: usize, mc: usize, kc: usize, out: &mut [T]) {
    let mut idx = 0;
    let mut i0 = 0;
    while i0 < mc {
        let ib = MR.min(mc - i0);
        for p in 0..kc {
            for i in 0..ib {
                out[idx] = a[(i0 + i) * lda + p];
                idx += 1;
            }
            for _ in ib..MR {
                out[idx] = T::zero();
                idx += 1;
            }
        }
        i0 += MR;
    }
}

/// Pack a KC x NC block of B into column-panels of width NR.
fn pack_b<T: Elem>(b: &[T], ldb: usize, kc: usize, nc: usize, out: &mut [T]) {
    let mut idx = 0;
    let mut j0 = 0;
    while j0 < nc {
        let jb = NR.min(nc - j0);
        for p in 0..kc {
            for j in 0..jb {
                out[idx] = b[p * ldb + j0 + j];
                idx += 1;
            }
            for _ in jb..NR {
                out[idx] = T::zero();
                idx += 1;
            }
        }
        j0 += NR;
    }
}

/// 4x4 register microkernel: C[4x4] += Apanel(kc x 4) * Bpanel(kc x 4).
#[inline(always)]
fn microkernel<T: Elem>(kc: usize, ap: &[T], bp: &[T], acc: &mut [T; MR * NR]) {
    debug_assert!(ap.len() >= kc * MR && bp.len() >= kc * NR);
    for p in 0..kc {
        let a0 = ap[p * MR];
        let a1 = ap[p * MR + 1];
        let a2 = ap[p * MR + 2];
        let a3 = ap[p * MR + 3];
        let b0 = bp[p * NR];
        let b1 = bp[p * NR + 1];
        let b2 = bp[p * NR + 2];
        let b3 = bp[p * NR + 3];
        acc[0] = acc[0] + a0 * b0;
        acc[1] = acc[1] + a0 * b1;
        acc[2] = acc[2] + a0 * b2;
        acc[3] = acc[3] + a0 * b3;
        acc[4] = acc[4] + a1 * b0;
        acc[5] = acc[5] + a1 * b1;
        acc[6] = acc[6] + a1 * b2;
        acc[7] = acc[7] + a1 * b3;
        acc[8] = acc[8] + a2 * b0;
        acc[9] = acc[9] + a2 * b1;
        acc[10] = acc[10] + a2 * b2;
        acc[11] = acc[11] + a2 * b3;
        acc[12] = acc[12] + a3 * b0;
        acc[13] = acc[13] + a3 * b1;
        acc[14] = acc[14] + a3 * b2;
        acc[15] = acc[15] + a3 * b3;
    }
}

/// Blocked + packed GEMM over materialized op(A), op(B):
/// `C = alpha * A(m x k) @ B(k x n) + beta * C`.
pub fn gemm<T: Elem>(
    m: usize,
    n: usize,
    k: usize,
    alpha: T,
    a: &[T],
    b: &[T],
    beta: T,
    c: &mut [T],
) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);

    // beta pass first (so the accumulation below is pure +=)
    if beta != T::one() {
        if beta == T::zero() {
            for v in c.iter_mut() {
                *v = T::zero();
            }
        } else {
            for v in c.iter_mut() {
                *v = *v * beta;
            }
        }
    }
    if alpha == T::zero() {
        return;
    }

    let mut apack = vec![T::zero(); MC.div_ceil(MR) * MR * KC];
    let mut bpack = vec![T::zero(); NC.div_ceil(NR) * NR * KC];

    let mut j0 = 0;
    while j0 < n {
        let nc = NC.min(n - j0);
        let mut p0 = 0;
        while p0 < k {
            let kc = KC.min(k - p0);
            pack_b(&b[p0 * n + j0..], n, kc, nc, &mut bpack);
            let mut i0 = 0;
            while i0 < m {
                let mc = MC.min(m - i0);
                pack_a(&a[i0 * k + p0..], k, mc, kc, &mut apack);

                // macro-kernel over the packed block
                let mut jr = 0;
                while jr < nc {
                    let jb = NR.min(nc - jr);
                    let bp = &bpack[(jr / NR) * kc * NR..];
                    let mut ir = 0;
                    while ir < mc {
                        let ib = MR.min(mc - ir);
                        let ap = &apack[(ir / MR) * kc * MR..];
                        let mut acc = [T::zero(); MR * NR];
                        microkernel(kc, ap, bp, &mut acc);
                        for i in 0..ib {
                            for j in 0..jb {
                                let ci = (i0 + ir + i) * n + j0 + jr + j;
                                c[ci] = c[ci] + alpha * acc[i * NR + j];
                            }
                        }
                        ir += MR;
                    }
                    jr += NR;
                }
                i0 += MC;
            }
            p0 += KC;
        }
        j0 += NC;
    }
}

/// GEMV: `y = alpha * A(m x n) @ x + beta * y` over materialized op(A).
pub fn gemv<T: Elem>(m: usize, n: usize, alpha: T, a: &[T], x: &[T], beta: T,
                     y: &mut [T]) {
    assert_eq!(a.len(), m * n);
    assert_eq!(x.len(), n);
    assert_eq!(y.len(), m);
    for i in 0..m {
        let row = &a[i * n..(i + 1) * n];
        let mut acc = T::zero();
        for (av, xv) in row.iter().zip(x.iter()) {
            acc = acc + *av * *xv;
        }
        y[i] = alpha * acc + beta * y[i];
    }
}

/// SYRK (host-only in the paper): `C = alpha * op(A) @ op(A)^T + beta*C`
/// touching only the `uplo` triangle of C (n x n).
pub fn syrk<T: Elem>(n: usize, k: usize, alpha: T, a_op: &[T], beta: T,
                     c: &mut [T], uplo: Uplo) {
    assert_eq!(a_op.len(), n * k);
    assert_eq!(c.len(), n * n);
    for i in 0..n {
        let js: Box<dyn Iterator<Item = usize>> = match uplo {
            Uplo::Lower => Box::new(0..=i),
            Uplo::Upper => Box::new(i..n),
        };
        for j in js {
            let mut acc = T::zero();
            for p in 0..k {
                acc = acc + a_op[i * k + p] * a_op[j * k + p];
            }
            c[i * n + j] = alpha * acc + beta * c[i * n + j];
        }
    }
}

/// SYMM (host-only): `C = alpha * A @ B + beta * C` with A symmetric
/// (n x n), only the `uplo` triangle of A stored/read.
pub fn symm<T: Elem>(n: usize, m_cols: usize, alpha: T, a: &[T], b: &[T],
                     beta: T, c: &mut [T], uplo: Uplo) {
    assert_eq!(a.len(), n * n);
    assert_eq!(b.len(), n * m_cols);
    assert_eq!(c.len(), n * m_cols);
    let read_a = |i: usize, j: usize| -> T {
        // fold to the stored triangle
        let (r, s) = match uplo {
            Uplo::Lower => if i >= j { (i, j) } else { (j, i) },
            Uplo::Upper => if i <= j { (i, j) } else { (j, i) },
        };
        a[r * n + s]
    };
    for i in 0..n {
        for j in 0..m_cols {
            let mut acc = T::zero();
            for p in 0..n {
                acc = acc + read_a(i, p) * b[p * m_cols + j];
            }
            c[i * m_cols + j] = alpha * acc + beta * c[i * m_cols + j];
        }
    }
}

/// TRMM (host-only): `B = alpha * op(A) @ B` with A triangular (n x n).
pub fn trmm<T: Elem>(n: usize, m_cols: usize, alpha: T, a: &[T], b: &mut [T],
                     uplo: Uplo, unit_diag: bool) {
    assert_eq!(a.len(), n * n);
    assert_eq!(b.len(), n * m_cols);
    // row order that lets us update B in place
    let rows: Vec<usize> = match uplo {
        Uplo::Upper => (0..n).collect(),          // row i uses rows >= i
        Uplo::Lower => (0..n).rev().collect(),    // row i uses rows <= i
    };
    for &i in &rows {
        for j in 0..m_cols {
            let mut acc = if unit_diag {
                b[i * m_cols + j]
            } else {
                a[i * n + i] * b[i * m_cols + j]
            };
            let ps: Box<dyn Iterator<Item = usize>> = match uplo {
                Uplo::Upper => Box::new(i + 1..n),
                Uplo::Lower => Box::new(0..i),
            };
            for p in ps {
                acc = acc + a[i * n + p] * b[p * m_cols + j];
            }
            b[i * m_cols + j] = alpha * acc;
        }
    }
}

/// TRSM (host-only): solve `op(A) X = alpha * B` in place (X overwrites
/// B), A triangular (n x n), non-unit diagonal must be non-singular.
pub fn trsm<T: Elem>(n: usize, m_cols: usize, alpha: T, a: &[T], b: &mut [T],
                     uplo: Uplo, unit_diag: bool) {
    assert_eq!(a.len(), n * n);
    assert_eq!(b.len(), n * m_cols);
    if alpha != T::one() {
        for v in b.iter_mut() {
            *v = *v * alpha;
        }
    }
    let rows: Vec<usize> = match uplo {
        Uplo::Lower => (0..n).collect(),          // forward substitution
        Uplo::Upper => (0..n).rev().collect(),    // backward substitution
    };
    for &i in &rows {
        for j in 0..m_cols {
            let mut acc = b[i * m_cols + j];
            let ps: Box<dyn Iterator<Item = usize>> = match uplo {
                Uplo::Lower => Box::new(0..i),
                Uplo::Upper => Box::new(i + 1..n),
            };
            for p in ps {
                acc = acc - a[i * n + p] * b[p * m_cols + j];
            }
            b[i * m_cols + j] = if unit_diag { acc } else { acc / a[i * n + i] };
        }
    }
}

/// GER: `A += alpha * x y^T`.
pub fn ger<T: Elem>(m: usize, n: usize, alpha: T, x: &[T], y: &[T], a: &mut [T]) {
    assert_eq!(a.len(), m * n);
    assert_eq!(x.len(), m);
    assert_eq!(y.len(), n);
    for i in 0..m {
        let ax = alpha * x[i];
        for j in 0..n {
            a[i * n + j] = a[i * n + j] + ax * y[j];
        }
    }
}

// ---------------------------------------------------------------------
// Level 1
// ---------------------------------------------------------------------

pub fn axpy<T: Elem>(alpha: T, x: &[T], y: &mut [T]) {
    assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi = *yi + alpha * *xi;
    }
}

/// The chain epilogue on a row-major (m x n) buffer: add the per-column
/// bias (length n), then clamp at zero.  The SAME element-wise ops, in
/// the same order, as the device path's `chain_epilogue` — exact f64/f32
/// arithmetic, so the two paths agree bit-for-bit on the epilogue.
pub fn chain_epilogue<T: Elem>(c: &mut [T], n: usize, bias: Option<&[T]>, relu: bool) {
    for (i, v) in c.iter_mut().enumerate() {
        if let Some(b) = bias {
            *v = *v + b[i % n];
        }
        if relu && *v < T::zero() {
            *v = T::zero();
        }
    }
}

pub fn scal<T: Elem>(alpha: T, x: &mut [T]) {
    for v in x.iter_mut() {
        *v = *v * alpha;
    }
}

pub fn dot<T: Elem>(x: &[T], y: &[T]) -> T {
    assert_eq!(x.len(), y.len());
    let mut acc = T::zero();
    for (a, b) in x.iter().zip(y.iter()) {
        acc = acc + *a * *b;
    }
    acc
}

pub fn asum<T: Elem>(x: &[T]) -> T {
    x.iter().fold(T::zero(), |a, v| a + v.abs())
}

pub fn nrm2<T: Elem>(x: &[T]) -> T {
    dot(x, x).sqrt()
}

/// Index of max |x_i| (CBLAS iamax; first on ties).
pub fn iamax<T: Elem>(x: &[T]) -> usize {
    let mut best = 0;
    let mut bv = T::zero();
    for (i, v) in x.iter().enumerate() {
        let av = v.abs();
        if i == 0 || av > bv {
            best = i;
            bv = av;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f64> {
        rng.normal_vec(n)
    }

    fn assert_close(a: &[f64], b: &[f64], tol: f64) {
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
            assert!(
                (x - y).abs() <= tol * (1.0 + x.abs().max(y.abs())),
                "idx {i}: {x} vs {y}"
            );
        }
    }

    #[test]
    fn packed_gemm_matches_naive_various_shapes() {
        let mut rng = Rng::new(11);
        for &(m, n, k) in &[
            (1, 1, 1),
            (4, 4, 4),
            (5, 7, 3),
            (17, 13, 9),
            (64, 64, 64),
            (130, 70, 129),
            (257, 31, 300),
        ] {
            let a = rand_vec(&mut rng, m * k);
            let b = rand_vec(&mut rng, k * n);
            let c0 = rand_vec(&mut rng, m * n);
            let mut c1 = c0.clone();
            let mut c2 = c0.clone();
            naive_gemm(m, n, k, 1.3, &a, &b, -0.7, &mut c1);
            gemm(m, n, k, 1.3, &a, &b, -0.7, &mut c2);
            assert_close(&c1, &c2, 1e-12);
        }
    }

    #[test]
    fn gemm_beta_zero_overwrites_and_alpha_zero_scales() {
        let a = vec![1.0; 4];
        let b = vec![1.0; 4];
        let mut c = vec![f64::NAN; 4];
        // beta = 0 must not propagate NaNs from c (BLAS semantics)
        gemm(2, 2, 2, 1.0, &a, &b, 0.0, &mut c);
        assert_eq!(c, vec![2.0; 4]);
        // alpha = 0: pure beta scaling
        let mut c = vec![3.0; 4];
        gemm(2, 2, 2, 0.0, &a, &b, 0.5, &mut c);
        assert_eq!(c, vec![1.5; 4]);
    }

    #[test]
    fn materialize_transpose() {
        let x = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]; // 2x3
        let xt = materialize_op(&x, 2, 3, Transpose::Yes); // 3x2
        assert_eq!(xt, vec![1.0, 4.0, 2.0, 5.0, 3.0, 6.0]);
        assert_eq!(materialize_op(&x, 2, 3, Transpose::No), x);
    }

    #[test]
    fn gemv_matches_naive() {
        let mut rng = Rng::new(5);
        let (m, n) = (23, 17);
        let a = rand_vec(&mut rng, m * n);
        let x = rand_vec(&mut rng, n);
        let y0 = rand_vec(&mut rng, m);
        let mut y = y0.clone();
        gemv(m, n, 2.0, &a, &x, 0.5, &mut y);
        for i in 0..m {
            let dotv: f64 = (0..n).map(|j| a[i * n + j] * x[j]).sum();
            assert!((y[i] - (2.0 * dotv + 0.5 * y0[i])).abs() < 1e-10);
        }
    }

    #[test]
    fn syrk_touches_only_triangle() {
        let mut rng = Rng::new(9);
        let (n, k) = (8, 5);
        let a = rand_vec(&mut rng, n * k);
        let c0 = rand_vec(&mut rng, n * n);
        let mut c = c0.clone();
        syrk(n, k, 1.0, &a, 0.0, &mut c, Uplo::Lower);
        for i in 0..n {
            for j in 0..n {
                if j > i {
                    assert_eq!(c[i * n + j], c0[i * n + j], "upper must be untouched");
                } else {
                    let acc: f64 = (0..k).map(|p| a[i * k + p] * a[j * k + p]).sum();
                    assert!((c[i * n + j] - acc).abs() < 1e-10);
                }
            }
        }
    }

    #[test]
    fn level1_ops() {
        let x = vec![1.0, -2.0, 3.0];
        let mut y = vec![10.0, 20.0, 30.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, vec![12.0, 16.0, 36.0]);
        let mut z = vec![1.0, 2.0];
        scal(-3.0, &mut z);
        assert_eq!(z, vec![-3.0, -6.0]);
        assert_eq!(dot(&x, &x), 14.0);
        assert_eq!(asum(&x), 6.0);
        assert!((nrm2(&x) - 14f64.sqrt()).abs() < 1e-15);
        assert_eq!(iamax(&x), 2);
        assert_eq!(iamax(&[-5.0, 5.0, 1.0]), 0); // first on ties
    }

    #[test]
    fn symm_matches_explicit_symmetric_gemm() {
        let mut rng = Rng::new(41);
        let n = 9;
        let mc = 6;
        // build a full symmetric matrix, then blank the unread triangle
        let mut full = rand_vec(&mut rng, n * n);
        for i in 0..n {
            for j in 0..i {
                full[j * n + i] = full[i * n + j];
            }
        }
        let b = rand_vec(&mut rng, n * mc);
        let c0 = rand_vec(&mut rng, n * mc);

        let mut want = c0.clone();
        naive_gemm(n, mc, n, 1.5, &full, &b, -0.5, &mut want);

        for uplo in [Uplo::Lower, Uplo::Upper] {
            let mut a = full.clone();
            for i in 0..n {
                for j in 0..n {
                    let dead = match uplo {
                        Uplo::Lower => j > i,
                        Uplo::Upper => j < i,
                    };
                    if dead {
                        a[i * n + j] = f64::NAN; // must never be read
                    }
                }
            }
            let mut c = c0.clone();
            symm(n, mc, 1.5, &a, &b, -0.5, &mut c, uplo);
            assert_close(&c, &want, 1e-12);
        }
    }

    #[test]
    fn trmm_matches_gemm_with_triangle() {
        let mut rng = Rng::new(42);
        let n = 7;
        let mc = 5;
        for uplo in [Uplo::Lower, Uplo::Upper] {
            let mut a = rand_vec(&mut rng, n * n);
            for i in 0..n {
                for j in 0..n {
                    let dead = match uplo {
                        Uplo::Lower => j > i,
                        Uplo::Upper => j < i,
                    };
                    if dead {
                        a[i * n + j] = 0.0;
                    }
                }
            }
            let b0 = rand_vec(&mut rng, n * mc);
            let mut want = vec![0.0; n * mc];
            naive_gemm(n, mc, n, 2.0, &a, &b0, 0.0, &mut want);
            let mut b = b0.clone();
            trmm(n, mc, 2.0, &a, &mut b, uplo, false);
            assert_close(&b, &want, 1e-12);
        }
    }

    #[test]
    fn trsm_inverts_trmm() {
        let mut rng = Rng::new(43);
        let n = 8;
        let mc = 4;
        for uplo in [Uplo::Lower, Uplo::Upper] {
            for unit in [false, true] {
                let mut a = rand_vec(&mut rng, n * n);
                for i in 0..n {
                    for j in 0..n {
                        let dead = match uplo {
                            Uplo::Lower => j > i,
                            Uplo::Upper => j < i,
                        };
                        if dead {
                            a[i * n + j] = 0.0;
                        }
                    }
                    // well-conditioned diagonal
                    a[i * n + i] = 2.0 + i as f64 * 0.1;
                }
                let x0 = rand_vec(&mut rng, n * mc);
                let mut b = x0.clone();
                trmm(n, mc, 1.0, &a, &mut b, uplo, unit); // B = op(A) X
                trsm(n, mc, 1.0, &a, &mut b, uplo, unit); // solve back
                assert_close(&b, &x0, 1e-10);
            }
        }
    }

    #[test]
    fn ger_rank1() {
        let mut a = vec![0.0; 6];
        ger(2, 3, 2.0, &[1.0, -1.0], &[1.0, 2.0, 3.0], &mut a);
        assert_eq!(a, vec![2.0, 4.0, 6.0, -2.0, -4.0, -6.0]);
    }

    #[test]
    fn f32_gemm_works() {
        let a: Vec<f32> = vec![1.0, 2.0, 3.0, 4.0];
        let b: Vec<f32> = vec![1.0, 0.0, 0.0, 1.0];
        let mut c = vec![0.0f32; 4];
        gemm(2, 2, 2, 1.0f32, &a, &b, 0.0, &mut c);
        assert_eq!(c, a);
    }
}
