//! The session API — what NumPy (our [`crate::npy`]) links against.
//!
//! [`HeroBlas`] owns the whole vertical slice: offload engine (SoC
//! models + virtual clock + trace), the PJRT artifact registry, and the
//! dispatch policy.  Every public method has CBLAS semantics; dispatch
//! decides per call whether the CVA6 host kernels or the heterogeneous
//! device kernels run, exactly like OpenBLAS' interface layer.

use std::path::Path;

use crate::config::{DispatchMode, PlatformConfig};
use crate::cost::CostModel;
use crate::dag::{DagOp, DagShape};
use crate::error::Result;
use crate::hero::offload::OffloadKind;
use crate::metrics::Metrics;
use crate::omp::engine::OffloadEngine;
use crate::runtime::ArtifactRegistry;
use crate::soc::trace::{RegionClass, Trace};
use crate::soc::Platform;

use super::device;
use super::dispatch::{DispatchPolicy, ExecTarget};
use super::elem::Elem;
use super::host;
use super::types::{check_gemm_dims, check_gemv_dims, Transpose, Uplo};

/// One linked instance of the accelerated BLAS.
pub struct HeroBlas {
    pub engine: OffloadEngine,
    pub registry: ArtifactRegistry,
    pub policy: DispatchPolicy,
}

/// A coalesced same-shape GEMM batch in flight on this session's cluster
/// (see [`HeroBlas::gemm_batch_launch`]).
pub struct GemmBatchRun<T: Elem> {
    state: device::GemmBatchState,
    _elem: std::marker::PhantomData<T>,
}

impl<T: Elem> GemmBatchRun<T> {
    /// Number of coalesced requests in the launch.
    pub fn len(&self) -> usize {
        self.state.len()
    }

    pub fn is_empty(&self) -> bool {
        self.state.is_empty()
    }
}

/// A coalesced same-shape GEMM batch whose operands are staged (map-in
/// paid) but not yet executed (see [`HeroBlas::gemm_batch_stage`]) —
/// the handle the pipelined scheduler holds while the *previous* batch
/// is still between launch and finish.
pub struct GemmStagedRun<T: Elem> {
    state: device::GemmStagedBatch,
    alpha: T,
    beta: T,
}

impl<T: Elem> GemmStagedRun<T> {
    /// Number of coalesced requests staged.
    pub fn len(&self) -> usize {
        self.state.len()
    }

    pub fn is_empty(&self) -> bool {
        self.state.is_empty()
    }
}

/// A staged-but-not-executed GEMM chain (see [`HeroBlas::chain_stage`])
/// — the handle the pipelined scheduler holds, exactly like
/// [`GemmStagedRun`], while the previous batch is still in flight.
pub struct ChainStagedRun<T: Elem> {
    state: device::GemmChainStaged,
    _elem: std::marker::PhantomData<T>,
}

impl<T: Elem> ChainStagedRun<T> {
    /// Number of links staged.
    pub fn len(&self) -> usize {
        self.state.len()
    }

    pub fn is_empty(&self) -> bool {
        self.state.is_empty()
    }

    /// (rows, cols) of the chain's final output.
    pub fn out_dims(&self) -> (usize, usize) {
        self.state.out_dims()
    }
}

/// An executed GEMM chain between its doorbell and its finish (see
/// [`HeroBlas::chain_execute`]).
pub struct ChainRun<T: Elem> {
    state: device::GemmChainState,
    _elem: std::marker::PhantomData<T>,
}

impl<T: Elem> ChainRun<T> {
    pub fn len(&self) -> usize {
        self.state.len()
    }

    pub fn is_empty(&self) -> bool {
        self.state.is_empty()
    }

    /// (rows, cols) of the chain's final output.
    pub fn out_dims(&self) -> (usize, usize) {
        self.state.out_dims()
    }
}

/// A staged-but-not-executed DAG (see [`HeroBlas::dag_stage`]) — the
/// graph-shaped analogue of [`ChainStagedRun`], riding the same
/// pipelining seam.
pub struct DagStagedRun<T: Elem> {
    state: device::DagStaged,
    _elem: std::marker::PhantomData<T>,
}

impl<T: Elem> DagStagedRun<T> {
    /// Number of nodes staged.
    pub fn len(&self) -> usize {
        self.state.len()
    }

    pub fn is_empty(&self) -> bool {
        self.state.is_empty()
    }

    /// The shape this staging lowered.
    pub fn shape(&self) -> &DagShape {
        self.state.shape()
    }
}

/// An executed DAG between its doorbell and its finish (see
/// [`HeroBlas::dag_execute`]).
pub struct DagRun<T: Elem> {
    state: device::DagState,
    _elem: std::marker::PhantomData<T>,
}

impl<T: Elem> DagRun<T> {
    pub fn len(&self) -> usize {
        self.state.len()
    }

    pub fn is_empty(&self) -> bool {
        self.state.is_empty()
    }

    /// The shape this execution lowered.
    pub fn shape(&self) -> &DagShape {
        self.state.shape()
    }

    /// Observed Compute-region cycles per node, in index order — what
    /// the scheduler feeds the calibrator for per-link attribution.
    pub fn node_cycles(&self) -> &[u64] {
        self.state.node_cycles()
    }

    /// (rows, cols) of every sink output, in sink index order.
    pub fn sink_dims(&self) -> Vec<(usize, usize)> {
        self.state.sink_dims()
    }
}

/// A coalesced same-shape GEMV batch in flight on this session's
/// cluster (executed, completion word posted) — see
/// [`HeroBlas::gemv_batch_execute`].
pub struct GemvBatchRun<T: Elem> {
    state: device::GemvBatchState,
    _elem: std::marker::PhantomData<T>,
}

impl<T: Elem> GemvBatchRun<T> {
    pub fn len(&self) -> usize {
        self.state.len()
    }

    pub fn is_empty(&self) -> bool {
        self.state.is_empty()
    }
}

/// A coalesced same-shape GEMV batch staged but not yet executed — the
/// level-2 pipelining handle (see [`HeroBlas::gemv_batch_stage`]).
pub struct GemvStagedRun<T: Elem> {
    state: device::GemvStagedBatch,
    alpha: T,
    beta: T,
}

impl<T: Elem> GemvStagedRun<T> {
    pub fn len(&self) -> usize {
        self.state.len()
    }

    pub fn is_empty(&self) -> bool {
        self.state.is_empty()
    }
}

impl std::fmt::Debug for HeroBlas {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("HeroBlas")
            .field("platform", &self.engine.platform.cfg.name)
            .field("policy", &self.policy)
            .finish()
    }
}

impl HeroBlas {
    /// Build a session from a platform config + artifacts directory.
    /// Unless the given policy already carries one, a [`CostModel`] is
    /// attached from the platform description + manifest geometry, so
    /// `Auto` dispatch is a calibrated cost comparison from the first
    /// call (the scheduler swaps in its pool-shared instance instead).
    pub fn new(cfg: PlatformConfig, artifacts: &Path, mut policy: DispatchPolicy) -> Result<Self> {
        cfg.validate()?;
        let engine = OffloadEngine::new(Platform::new(cfg))?;
        let registry = ArtifactRegistry::open(artifacts)?;
        if policy.model.is_none() {
            policy.model =
                Some(CostModel::from_manifest(&engine.platform.cfg, registry.manifest()));
        }
        Ok(HeroBlas { engine, registry, policy })
    }

    /// Default platform, artifacts found via `HERO_BLAS_ARTIFACTS` or by
    /// walking up from the current directory.
    pub fn from_env(mode: DispatchMode) -> Result<Self> {
        let dir = crate::find_artifacts_dir()?;
        HeroBlas::new(
            PlatformConfig::default(),
            &dir,
            DispatchPolicy::with_mode(mode),
        )
    }

    /// Clear the per-run trace (Figure 3 measures warm calls).
    pub fn reset_run(&mut self) {
        self.engine.reset_run();
    }

    /// The region trace of everything since the last reset.
    pub fn trace(&self) -> &Trace {
        &self.engine.trace
    }

    /// Aggregate counters (incl. PJRT wall time synced from the registry).
    pub fn metrics(&mut self) -> Metrics {
        self.engine.metrics.pjrt_wall_us = self.registry.stats().exec_wall_us;
        self.engine.metrics
    }

    /// Virtual seconds since engine start.
    pub fn now_secs(&self) -> f64 {
        self.engine.now().to_secs(self.engine.freq_hz())
    }

    // ------------------------------------------------------------------
    // Level 3
    // ------------------------------------------------------------------

    /// Launch a coalesced batch of same-shape GEMMs (`C_i = alpha * A_i @
    /// B_i + beta * C_i`, row-major, no transposes) as one fork-join
    /// offload — the scheduler's batcher uses this to amortize the
    /// paper's per-call offload overhead across coalesced requests.
    ///
    /// Returns with compute done and the completion word posted in the
    /// cluster mailbox; poll [`HeroBlas::offload_completion_pending`] and
    /// then call [`HeroBlas::gemm_batch_finish`].  The dispatch policy is
    /// NOT consulted — the caller has already decided to offload (pass
    /// `zero_copy` for the IOMMU path).
    pub fn gemm_batch_launch<T: Elem>(
        &mut self,
        dims: (usize, usize, usize),
        alpha: T,
        beta: T,
        inputs: &[(&[T], &[T], &[T])],
        zero_copy: bool,
    ) -> Result<GemmBatchRun<T>> {
        device::gemm_batch_launch(
            &mut self.engine, &mut self.registry, dims, alpha, beta, inputs,
            zero_copy, self.policy.kernel.as_deref(),
        )
        .map(|state| GemmBatchRun { state, _elem: std::marker::PhantomData })
    }

    /// Join a batch launched with [`HeroBlas::gemm_batch_launch`]: copy
    /// every member's C back into `outs` (launch order) and release the
    /// device mappings.
    pub fn gemm_batch_finish<T: Elem>(
        &mut self,
        run: GemmBatchRun<T>,
        outs: &mut [&mut [T]],
    ) -> Result<()> {
        device::gemm_batch_finish(&mut self.engine, run.state, outs)
    }

    /// Stage a coalesced batch without launching it: the map-in
    /// (data-copy region) is paid now, the doorbell/compute later via
    /// [`HeroBlas::gemm_batch_execute`].  The pipelined scheduler stages
    /// batch k+1 here while batch k is between launch and finish, so
    /// k+1's map-in hides under k's compute window.
    pub fn gemm_batch_stage<T: Elem>(
        &mut self,
        dims: (usize, usize, usize),
        alpha: T,
        beta: T,
        inputs: &[(&[T], &[T], &[T])],
        zero_copy: bool,
    ) -> Result<GemmStagedRun<T>> {
        device::gemm_batch_stage::<T>(
            &mut self.engine, &mut self.registry, dims, beta == T::zero(), inputs,
            zero_copy,
        )
        .map(|state| GemmStagedRun { state, alpha, beta })
    }

    /// Execute a staged batch (doorbell + compute); the completion word
    /// is posted on return — poll [`HeroBlas::offload_completion_pending`]
    /// and then call [`HeroBlas::gemm_batch_finish`].
    pub fn gemm_batch_execute<T: Elem>(
        &mut self,
        staged: GemmStagedRun<T>,
    ) -> Result<GemmBatchRun<T>> {
        device::gemm_batch_execute(
            &mut self.engine, &mut self.registry, staged.state, staged.alpha,
            staged.beta, self.policy.kernel.as_deref(),
        )
        .map(|state| GemmBatchRun { state, _elem: std::marker::PhantomData })
    }

    /// Abandon a staged batch (error recovery): release its mappings and
    /// exit the target region without ever ringing the doorbell.
    pub fn gemm_batch_abandon<T: Elem>(&mut self, staged: GemmStagedRun<T>) {
        staged.state.release(&mut self.engine);
    }

    /// Per-member cache identity of a staged batch's B operands — what
    /// the scheduler tags in the operand cache to keep its affinity
    /// directory honest about residency.
    pub fn gemm_staged_b_keys<T: Elem>(
        &self,
        staged: &GemmStagedRun<T>,
    ) -> Vec<Option<crate::omp::CacheKey>> {
        staged.state.cached_b_keys()
    }

    /// Directory-driven prefetch: pre-stage a shared n x n GEMM B
    /// operand into the operand cache outside any batch, so the next
    /// coalesced launch's `map(to:)` of the same bytes is a hit and the
    /// miss cost lands outside the batch (the scheduler calls this
    /// during the batcher's linger window when affinity routed a
    /// request at a cold home).  Returns the cache key when resident.
    pub fn prefetch_gemm_b(&mut self, n: usize, b: &[f64]) -> Result<Option<crate::omp::CacheKey>> {
        device::prefetch_gemm_b(&mut self.engine, &self.registry, n, b)
    }

    // ------------------------------------------------------------------
    // Operation chaining (device-resident intermediates)
    // ------------------------------------------------------------------

    /// Stage a GEMM chain (`C_i = epilogue_i(C_{i-1} @ B_i)`, alpha = 1,
    /// beta = 0) as ONE offload whose intermediates never return to the
    /// host: fork once, map the input activation and every link's
    /// weights, stage every output `map(alloc:)`-style.  The dispatch
    /// policy is NOT consulted — the caller has already decided to
    /// offload (use [`HeroBlas::chain`] for the policy-dispatched
    /// one-shot).  Chains are copy-mode only: residency is the point.
    pub fn chain_stage<T: Elem>(
        &mut self,
        m: usize,
        x: &[T],
        links: &[device::ChainLinkSpec<'_, T>],
    ) -> Result<ChainStagedRun<T>> {
        device::gemm_chain_stage(&mut self.engine, &mut self.registry, m, x, links)
            .map(|state| ChainStagedRun { state, _elem: std::marker::PhantomData })
    }

    /// Execute a staged chain (doorbell, every link's tile walk with
    /// device-resident hand-off, completion word posted) — poll
    /// [`HeroBlas::offload_completion_pending`] and call
    /// [`HeroBlas::chain_finish`].
    pub fn chain_execute<T: Elem>(
        &mut self,
        staged: ChainStagedRun<T>,
    ) -> Result<ChainRun<T>> {
        device::gemm_chain_execute(
            &mut self.engine, &mut self.registry, staged.state,
            self.policy.kernel.as_deref(),
        )
        .map(|state| ChainRun { state, _elem: std::marker::PhantomData })
    }

    /// Join an executed chain: copy ONLY the final output back into
    /// `out` (row-major, the chain's [`ChainRun::out_dims`]) and release
    /// every mapping, intermediates' cache pins included.
    pub fn chain_finish<T: Elem>(&mut self, run: ChainRun<T>, out: &mut [T]) -> Result<()> {
        device::gemm_chain_finish(&mut self.engine, run.state, out)
    }

    /// Abandon a staged chain (cancellation / error recovery): release
    /// its mappings — operand-cache pins and `map(alloc:)` outputs — and
    /// exit the target region without ringing the doorbell.  A cancelled
    /// chain must never strand resident intermediates.
    pub fn chain_abandon<T: Elem>(&mut self, staged: ChainStagedRun<T>) {
        staged.state.release(&mut self.engine);
    }

    /// Per-link cache identity of a staged chain's B operands (affinity
    /// bookkeeping, like [`HeroBlas::gemm_staged_b_keys`]).
    pub fn chain_staged_b_keys<T: Elem>(
        &self,
        staged: &ChainStagedRun<T>,
    ) -> Vec<Option<crate::omp::CacheKey>> {
        staged.state.cached_b_keys()
    }

    /// Run a GEMM chain end-to-end, dispatching through the policy: the
    /// device target runs the chained offload (stage/execute/finish)
    /// with device-resident intermediates; when chaining does not pay,
    /// each link dispatches individually through [`HeroBlas::gemm`] (so
    /// a single link above the crossover may still offload on its own)
    /// with the epilogue applied host-side.  `out` must hold
    /// `m * n_last` elements.
    pub fn chain<T: Elem>(
        &mut self,
        m: usize,
        x: &[T],
        links: &[device::ChainLinkSpec<'_, T>],
        out: &mut [T],
    ) -> Result<()> {
        if links.is_empty() {
            return Err(crate::error::Error::shape("chain: empty chain"));
        }
        let mut dims = Vec::with_capacity(links.len() + 1);
        dims.push(links[0].dims.0);
        for l in links {
            dims.push(l.dims.1);
        }
        let n_last = dims[dims.len() - 1];
        if out.len() != m * n_last {
            return Err(crate::error::Error::shape(format!(
                "chain: output len {} != {m}x{n_last}",
                out.len()
            )));
        }
        match self.policy.chain(m, &dims) {
            ExecTarget::Host => {
                let mut h = x.to_vec();
                let mut cols = dims[0];
                for l in links {
                    let (k, n) = l.dims;
                    if k != cols {
                        return Err(crate::error::Error::shape(format!(
                            "chain: link consumes {k} columns, producer yields {cols}"
                        )));
                    }
                    let mut c = vec![T::zero(); m * n];
                    self.gemm(
                        Transpose::No, Transpose::No, T::one(), &h, (m, k), l.b,
                        (k, n), T::zero(), &mut c, (m, n),
                    )?;
                    if l.bias.is_some() || l.relu {
                        host::chain_epilogue(&mut c, n, l.bias, l.relu);
                        let cyc = self
                            .engine
                            .platform
                            .host
                            .level1_cycles(m * n, 2.0, T::F32_PATH);
                        self.engine.charge_host_compute(cyc, "host_chain_epilogue");
                    }
                    h = c;
                    cols = n;
                }
                out.copy_from_slice(&h);
                Ok(())
            }
            _ => {
                // chained residency is a copy-mode technique: forced
                // zero-copy still runs the copy-mode chain path
                let staged = self.chain_stage(m, x, links)?;
                let run = self.chain_execute(staged)?;
                self.chain_finish(run, out)
            }
        }
    }

    /// Staged device-DRAM footprint of a chain (`dims` = layer widths) —
    /// what callers bound chain length against a cluster slice with.
    pub fn chain_staged_bytes<T: Elem>(&self, m: usize, dims: &[usize]) -> u64 {
        device::chain_staged_bytes::<T>(&self.registry, m, dims)
    }

    // ------------------------------------------------------------------
    // DAG executor (fan-out/fan-in over device-resident intermediates)
    // ------------------------------------------------------------------

    /// Stage a DAG as ONE offload whose interior edges never return to
    /// the host: fork once, map the external input and every matmul
    /// node's weights, stage every output `map(alloc:)`-style.  The
    /// dispatch policy is NOT consulted — the caller has already decided
    /// to offload (use [`HeroBlas::dag`] for the policy-dispatched
    /// one-shot).  DAGs are copy-mode only, like chains: residency is
    /// the point.
    pub fn dag_stage<T: Elem>(
        &mut self,
        shape: &DagShape,
        x: &[T],
        nodes: &[device::DagNodeSpec<'_, T>],
    ) -> Result<DagStagedRun<T>> {
        device::dag_stage(&mut self.engine, &mut self.registry, shape, x, nodes)
            .map(|state| DagStagedRun { state, _elem: std::marker::PhantomData })
    }

    /// Execute a staged DAG (doorbell, every node's walk in topological
    /// order with promote-once/reuse-per-edge hand-off, completion word
    /// posted) — poll [`HeroBlas::offload_completion_pending`] and call
    /// [`HeroBlas::dag_finish`].
    pub fn dag_execute<T: Elem>(
        &mut self,
        staged: DagStagedRun<T>,
    ) -> Result<DagRun<T>> {
        device::dag_execute(
            &mut self.engine, &mut self.registry, staged.state,
            self.policy.kernel.as_deref(),
        )
        .map(|state| DagRun { state, _elem: std::marker::PhantomData })
    }

    /// Join an executed DAG: copy every sink output back into `outs`
    /// (sink index order, sizes per [`DagRun::sink_dims`]) and release
    /// every mapping.  `publish = true` additionally registers the last
    /// sink's padded output in the operand cache (unpinned) so a fused
    /// follow-up request's `map(to:)` of the same activation is a
    /// verified hit.
    pub fn dag_finish<T: Elem>(
        &mut self,
        run: DagRun<T>,
        outs: &mut [&mut [T]],
        publish: bool,
    ) -> Result<()> {
        device::dag_finish(&mut self.engine, run.state, outs, publish)
    }

    /// Abandon a staged DAG (cancellation / error recovery): release its
    /// mappings — operand-cache pins and `map(alloc:)` outputs — and
    /// exit the target region without ringing the doorbell.  A cancelled
    /// DAG must never strand resident intermediates.
    pub fn dag_abandon<T: Elem>(&mut self, staged: DagStagedRun<T>) {
        staged.state.release(&mut self.engine);
    }

    /// Per-node cache identity of a staged DAG's weight operands (`None`
    /// for fan-in nodes) — affinity bookkeeping, like
    /// [`HeroBlas::chain_staged_b_keys`].
    pub fn dag_staged_b_keys<T: Elem>(
        &self,
        staged: &DagStagedRun<T>,
    ) -> Vec<Option<crate::omp::CacheKey>> {
        staged.state.cached_b_keys()
    }

    /// Staged device-DRAM footprint of a DAG — the live resident
    /// high-water mark the placement router admits big-lane jobs by.
    pub fn dag_staged_bytes<T: Elem>(&self, shape: &DagShape) -> u64 {
        device::dag_staged_bytes::<T>(&self.registry, shape)
    }

    /// Run a DAG end-to-end, dispatching through the policy: the device
    /// target runs the graph-shaped offload (stage/execute/finish) with
    /// device-resident interior edges; when the graph does not pay, each
    /// node dispatches individually — gemm/gemv through their own policy
    /// gates (so a single large node may still offload on its own),
    /// fan-in ops host-side — in the same topological order, which is
    /// the per-op oracle the integration tests compare against.  `outs`
    /// gets one slice per sink, sink index order.
    pub fn dag<T: Elem>(
        &mut self,
        shape: &DagShape,
        x: &[T],
        nodes: &[device::DagNodeSpec<'_, T>],
        outs: &mut [&mut [T]],
    ) -> Result<()> {
        shape
            .validate(u32::MAX, u32::MAX, u32::MAX)
            .map_err(|e| crate::error::Error::shape(format!("dag: {e}")))?;
        if nodes.len() != shape.nodes.len() {
            return Err(crate::error::Error::shape(format!(
                "dag: {} node specs for {} shape nodes",
                nodes.len(),
                shape.nodes.len()
            )));
        }
        if x.len() != shape.m * shape.d0 {
            return Err(crate::error::Error::shape(format!(
                "dag: input has {} elements, the shape wants {}x{}",
                x.len(),
                shape.m,
                shape.d0
            )));
        }
        let widths = shape.widths();
        for (i, (node, spec)) in shape.nodes.iter().zip(nodes).enumerate() {
            let op = node.op;
            if op.is_matmul() {
                let b = spec.b.ok_or_else(|| {
                    crate::error::Error::shape(format!(
                        "dag: node {i} ({op}) is missing its weight operand"
                    ))
                })?;
                if b.len() != shape.in_width(i) * widths[i] {
                    return Err(crate::error::Error::shape(format!(
                        "dag: node {i} ({op}) weights have {} elements for \
                         ({}, {})",
                        b.len(),
                        shape.in_width(i),
                        widths[i]
                    )));
                }
            } else if spec.b.is_some() {
                return Err(crate::error::Error::shape(format!(
                    "dag: node {i} ({op}) does not take a weight operand"
                )));
            }
            if node.bias != spec.bias.is_some() {
                return Err(crate::error::Error::shape(format!(
                    "dag: node {i} ({op}) bias operand does not match its \
                     shape's bias flag"
                )));
            }
            if let Some(bias) = spec.bias {
                if bias.len() != widths[i] {
                    return Err(crate::error::Error::shape(format!(
                        "dag: node {i} ({op}) bias has {} elements for n={}",
                        bias.len(),
                        widths[i]
                    )));
                }
            }
        }
        let sinks = shape.sinks();
        if outs.len() != sinks.len() {
            return Err(crate::error::Error::shape(format!(
                "dag: {} outputs for a dag with {} sinks",
                outs.len(),
                sinks.len()
            )));
        }
        for (&s, out) in sinks.iter().zip(outs.iter()) {
            let (r, c) = shape.out_dims(s);
            if out.len() != r * c {
                return Err(crate::error::Error::shape(format!(
                    "dag: sink {s} output len {} != {r}x{c}",
                    out.len()
                )));
            }
        }
        match self.policy.dag(shape) {
            ExecTarget::Host => {
                let m = shape.m;
                let mut produced: Vec<Vec<T>> = Vec::with_capacity(shape.nodes.len());
                for (i, (node, spec)) in shape.nodes.iter().zip(nodes).enumerate() {
                    let k = shape.in_width(i);
                    let a: Vec<T> = match node.src {
                        Some(j) => produced[j].clone(),
                        None => x.to_vec(),
                    };
                    let out_v = match node.op {
                        DagOp::Gemm | DagOp::Gemv => {
                            let n = widths[i];
                            let b = spec.b.expect("validated: matmul has weights");
                            let mut c = vec![T::zero(); m * n];
                            if node.op == DagOp::Gemv {
                                self.gemv(
                                    Transpose::No, T::one(), &a, (m, k), b,
                                    T::zero(), &mut c,
                                )?;
                            } else {
                                self.gemm(
                                    Transpose::No, Transpose::No, T::one(), &a,
                                    (m, k), b, (k, n), T::zero(), &mut c, (m, n),
                                )?;
                            }
                            if spec.bias.is_some() || node.relu {
                                host::chain_epilogue(&mut c, n, spec.bias, node.relu);
                                let cyc = self
                                    .engine
                                    .platform
                                    .host
                                    .level1_cycles(m * n, 2.0, T::F32_PATH);
                                self.engine
                                    .charge_host_compute(cyc, "host_dag_epilogue");
                            }
                            c
                        }
                        DagOp::Axpy | DagOp::Dot => {
                            let b: Vec<T> = match node.src2 {
                                Some(j) => produced[j].clone(),
                                None => x.to_vec(),
                            };
                            let cyc = self
                                .engine
                                .platform
                                .host
                                .level1_cycles(m * k, 2.0, T::F32_PATH);
                            if node.op == DagOp::Axpy {
                                self.engine.charge_host_compute(cyc, "host_dag_axpy");
                                a.iter().zip(b.iter()).map(|(p, q)| *p + *q).collect()
                            } else {
                                self.engine.charge_host_compute(cyc, "host_dag_dot");
                                let mut acc = T::zero();
                                for (p, q) in a.iter().zip(b.iter()) {
                                    acc = acc + (*p) * (*q);
                                }
                                vec![acc]
                            }
                        }
                    };
                    produced.push(out_v);
                }
                for (&s, out) in sinks.iter().zip(outs.iter_mut()) {
                    out.copy_from_slice(&produced[s]);
                }
                Ok(())
            }
            _ => {
                // graph residency is a copy-mode technique: forced
                // zero-copy still runs the copy-mode DAG path
                let staged = self.dag_stage(shape, x, nodes)?;
                let run = self.dag_execute(staged)?;
                self.dag_finish(run, outs, false)
            }
        }
    }

    /// Stage a coalesced GEMV batch without launching it — the level-2
    /// analogue of [`HeroBlas::gemm_batch_stage`], giving the pipelined
    /// scheduler the same stage/execute/finish seam for gemv traffic.
    pub fn gemv_batch_stage<T: Elem>(
        &mut self,
        dims: (usize, usize),
        alpha: T,
        beta: T,
        inputs: &[(&[T], &[T], &[T])],
        zero_copy: bool,
    ) -> Result<GemvStagedRun<T>> {
        device::gemv_batch_stage::<T>(
            &mut self.engine, &mut self.registry, dims, beta == T::zero(), inputs,
            zero_copy,
        )
        .map(|state| GemvStagedRun { state, alpha, beta })
    }

    /// Execute a staged GEMV batch (doorbell + compute); the completion
    /// word is posted on return — poll
    /// [`HeroBlas::offload_completion_pending`] and then call
    /// [`HeroBlas::gemv_batch_finish`].
    pub fn gemv_batch_execute<T: Elem>(
        &mut self,
        staged: GemvStagedRun<T>,
    ) -> Result<GemvBatchRun<T>> {
        device::gemv_batch_execute(
            &mut self.engine, &mut self.registry, staged.state, staged.alpha,
            staged.beta, self.policy.kernel.as_deref(),
        )
        .map(|state| GemvBatchRun { state, _elem: std::marker::PhantomData })
    }

    /// Join an executed GEMV batch: copy every member's y back into
    /// `outs` (launch order) and release the device mappings.
    pub fn gemv_batch_finish<T: Elem>(
        &mut self,
        run: GemvBatchRun<T>,
        outs: &mut [&mut [T]],
    ) -> Result<()> {
        device::gemv_batch_finish(&mut self.engine, run.state, outs)
    }

    /// Abandon a staged GEMV batch (error recovery): release its
    /// mappings and exit the target region without ringing the doorbell.
    pub fn gemv_batch_abandon<T: Elem>(&mut self, staged: GemvStagedRun<T>) {
        staged.state.release(&mut self.engine);
    }

    /// Run a coalesced batch of same-length level-1 calls, dispatching
    /// through the policy: the host target loops the scalar kernels, the
    /// device targets coalesce every member into ONE fork-join launch
    /// (the last device path that used to pay the launch per call).
    /// `inputs` carries one `(alpha, x, y)` per member; axpy writes the
    /// updated y into `outs[i]` (length n), dot writes the scalar into
    /// `outs[i][0]`.
    pub fn level1_batch(
        &mut self,
        kind: OffloadKind,
        inputs: &[(f64, &[f64], &[f64])],
        outs: &mut [&mut [f64]],
    ) -> Result<()> {
        let is_axpy = match kind {
            OffloadKind::Axpy => true,
            OffloadKind::Dot => false,
            _ => {
                return Err(crate::error::Error::shape(
                    "level1_batch: unsupported kind",
                ))
            }
        };
        if inputs.is_empty() || inputs.len() != outs.len() {
            return Err(crate::error::Error::shape("level1_batch: ragged batch"));
        }
        // Validate member shapes up front so the host and device targets
        // fail identically (the device path re-checks internally).
        let n = inputs[0].1.len();
        for (i, (_, x, y)) in inputs.iter().enumerate() {
            if x.len() != n || y.len() != n {
                return Err(crate::error::Error::shape(format!(
                    "level1_batch: member {i} lengths {}x{} don't match n={n}",
                    x.len(),
                    y.len()
                )));
            }
        }
        let want = if is_axpy { n } else { 1 };
        for (i, out) in outs.iter().enumerate() {
            if out.len() != want {
                return Err(crate::error::Error::shape(format!(
                    "level1_batch: output {i} len {} != {want}",
                    out.len()
                )));
            }
        }
        match self.policy.level1(kind, n) {
            ExecTarget::Host => {
                for ((alpha, x, y), out) in inputs.iter().zip(outs.iter_mut()) {
                    if is_axpy {
                        out.copy_from_slice(y);
                        host::axpy(*alpha, x, out);
                        let cyc =
                            self.engine.platform.host.level1_cycles(n, 2.0, false);
                        self.engine.charge_host_compute(cyc, "host_axpy");
                    } else {
                        out[0] = host::dot(x, y);
                        let cyc =
                            self.engine.platform.host.level1_cycles(n, 2.0, false);
                        self.engine.charge_host_compute(cyc, "host_dot");
                    }
                }
                Ok(())
            }
            target => device::level1_batch(
                &mut self.engine,
                &mut self.registry,
                kind,
                inputs,
                target == ExecTarget::DeviceZeroCopy,
                outs,
                self.policy.kernel.as_deref(),
            ),
        }
    }

    /// Run a coalesced batch of same-shape GEMVs (`y_i = alpha * A_i @
    /// x_i + beta * y_i`) as ONE fork-join offload — the level-2
    /// analogue of [`HeroBlas::gemm_batch_launch`], synchronous.  The
    /// dispatch policy is NOT consulted; the caller has already decided
    /// to offload.
    pub fn gemv_batch_device<T: Elem>(
        &mut self,
        dims: (usize, usize),
        alpha: T,
        beta: T,
        inputs: &[(&[T], &[T], &[T])],
        zero_copy: bool,
        outs: &mut [&mut [T]],
    ) -> Result<()> {
        device::gemv_batch(
            &mut self.engine, &mut self.registry, dims, alpha, beta, inputs,
            zero_copy, outs, self.policy.kernel.as_deref(),
        )
    }

    /// Convenience: run a same-shape GEMV batch end-to-end, dispatching
    /// through the policy like [`HeroBlas::gemv`] (host target loops over
    /// the members; device targets coalesce into one launch).
    pub fn gemv_batch<T: Elem>(
        &mut self,
        dims: (usize, usize),
        alpha: T,
        beta: T,
        a_list: &[&[T]],
        x_list: &[&[T]],
        outs: &mut [&mut [T]],
    ) -> Result<()> {
        let (m, n) = dims;
        if a_list.len() != x_list.len() || a_list.len() != outs.len() {
            return Err(crate::error::Error::shape("gemv_batch: ragged batch"));
        }
        match self.policy.gemv(m, n) {
            ExecTarget::Host => {
                for ((a, x), y) in a_list.iter().zip(x_list).zip(outs.iter_mut()) {
                    self.gemv(Transpose::No, alpha, a, (m, n), x, beta, y)?;
                }
                Ok(())
            }
            target => {
                let zero_copy = target == ExecTarget::DeviceZeroCopy;
                // snapshot the incoming y values so `inputs` doesn't
                // borrow `outs` while the batch writes results into it
                let y_in: Vec<Vec<T>> = outs.iter().map(|y| y.to_vec()).collect();
                let inputs: Vec<(&[T], &[T], &[T])> = a_list
                    .iter()
                    .zip(x_list)
                    .zip(y_in.iter())
                    .map(|((a, x), y)| (*a, *x, y.as_slice()))
                    .collect();
                self.gemv_batch_device(dims, alpha, beta, &inputs, zero_copy, outs)
            }
        }
    }

    /// Is a completion word pending in the cluster mailbox?  Workers poll
    /// this between a batch launch and its finish.
    pub fn offload_completion_pending(&self) -> bool {
        self.engine.device.mailbox.pending_for_host() > 0
    }

    /// Convenience: run a same-shape GEMM batch end-to-end, dispatching
    /// through the policy like [`HeroBlas::gemm`] (host target loops over
    /// the members; device targets coalesce into one launch).
    pub fn gemm_batch<T: Elem>(
        &mut self,
        dims: (usize, usize, usize),
        alpha: T,
        beta: T,
        a_list: &[&[T]],
        b_list: &[&[T]],
        outs: &mut [&mut [T]],
    ) -> Result<()> {
        let (m, n, k) = dims;
        if a_list.len() != b_list.len() || a_list.len() != outs.len() {
            return Err(crate::error::Error::shape("gemm_batch: ragged batch"));
        }
        match self.policy.gemm(m, n, k) {
            ExecTarget::Host => {
                for ((a, b), c) in a_list.iter().zip(b_list).zip(outs.iter_mut()) {
                    self.gemm(
                        Transpose::No, Transpose::No, alpha, a, (m, k), b, (k, n),
                        beta, c, (m, n),
                    )?;
                }
                Ok(())
            }
            target => {
                let zero_copy = target == ExecTarget::DeviceZeroCopy;
                let run = {
                    let inputs: Vec<(&[T], &[T], &[T])> = a_list
                        .iter()
                        .zip(b_list)
                        .zip(outs.iter())
                        .map(|((a, b), c)| (*a, *b, &**c as &[T]))
                        .collect();
                    self.gemm_batch_launch(dims, alpha, beta, &inputs, zero_copy)?
                };
                self.gemm_batch_finish(run, outs)
            }
        }
    }

    /// xGEMM: `C = alpha * op(A) @ op(B) + beta * C`.
    /// `a`/`b` are stored row-major with the given stored dims.
    #[allow(clippy::too_many_arguments)]
    pub fn gemm<T: Elem>(
        &mut self,
        trans_a: Transpose,
        trans_b: Transpose,
        alpha: T,
        a: &[T],
        a_dims: (usize, usize),
        b: &[T],
        b_dims: (usize, usize),
        beta: T,
        c: &mut [T],
        c_dims: (usize, usize),
    ) -> Result<()> {
        let (m, n, k) = check_gemm_dims(trans_a, trans_b, a_dims, b_dims, c_dims)?;
        let a_op = host::materialize_op(a, a_dims.0, a_dims.1, trans_a);
        let b_op = host::materialize_op(b, b_dims.0, b_dims.1, trans_b);
        match self.policy.gemm(m, n, k) {
            ExecTarget::Host => {
                host::gemm(m, n, k, alpha, &a_op, &b_op, beta, c);
                let cyc = self.engine.platform.host.gemm_cycles(m, n, k, T::F32_PATH);
                self.engine.charge_host_compute(cyc, "host_gemm");
                Ok(())
            }
            ExecTarget::Device => device::gemm(
                &mut self.engine, &mut self.registry, m, n, k, alpha, &a_op,
                &b_op, beta, c, false, self.policy.kernel.as_deref(),
            ),
            ExecTarget::DeviceZeroCopy => device::gemm(
                &mut self.engine, &mut self.registry, m, n, k, alpha, &a_op,
                &b_op, beta, c, true, self.policy.kernel.as_deref(),
            ),
        }
    }

    /// xSYRK — host-only, like the paper's `syrk.c`.
    #[allow(clippy::too_many_arguments)]
    pub fn syrk<T: Elem>(
        &mut self,
        uplo: Uplo,
        trans: Transpose,
        alpha: T,
        a: &[T],
        a_dims: (usize, usize),
        beta: T,
        c: &mut [T],
        n_dim: usize,
    ) -> Result<()> {
        let (n, k) = trans.dims(a_dims.0, a_dims.1);
        if n != n_dim || c.len() != n * n {
            return Err(crate::error::Error::shape(format!(
                "syrk: op(A)={n}x{k}, C must be {n_dim}x{n_dim}"
            )));
        }
        let a_op = host::materialize_op(a, a_dims.0, a_dims.1, trans);
        host::syrk(n, k, alpha, &a_op, beta, c, uplo);
        // ~half the FLOPs of a full GEMM (one triangle)
        let cyc = self.engine.platform.host.gemm_cycles(n, n, k, T::F32_PATH);
        self.engine
            .charge_host_compute(crate::soc::clock::Cycles(cyc.0 / 2), "host_syrk");
        Ok(())
    }

    /// xSYMM — host-only: `C = alpha * A @ B + beta * C`, A symmetric
    /// (n x n, `uplo` triangle stored), B/C are n x m_cols.
    #[allow(clippy::too_many_arguments)]
    pub fn symm<T: Elem>(
        &mut self,
        uplo: Uplo,
        alpha: T,
        a: &[T],
        n: usize,
        b: &[T],
        m_cols: usize,
        beta: T,
        c: &mut [T],
    ) -> Result<()> {
        if a.len() != n * n || b.len() != n * m_cols || c.len() != n * m_cols {
            return Err(crate::error::Error::shape("symm: dimension mismatch"));
        }
        host::symm(n, m_cols, alpha, a, b, beta, c, uplo);
        let cyc = self.engine.platform.host.gemm_cycles(n, m_cols, n, T::F32_PATH);
        self.engine.charge_host_compute(cyc, "host_symm");
        Ok(())
    }

    /// xTRMM — host-only: `B = alpha * A @ B`, A triangular (n x n).
    #[allow(clippy::too_many_arguments)]
    pub fn trmm<T: Elem>(
        &mut self,
        uplo: Uplo,
        unit_diag: bool,
        alpha: T,
        a: &[T],
        n: usize,
        b: &mut [T],
        m_cols: usize,
    ) -> Result<()> {
        if a.len() != n * n || b.len() != n * m_cols {
            return Err(crate::error::Error::shape("trmm: dimension mismatch"));
        }
        host::trmm(n, m_cols, alpha, a, b, uplo, unit_diag);
        let cyc = self.engine.platform.host.gemm_cycles(n, m_cols, n, T::F32_PATH);
        self.engine
            .charge_host_compute(crate::soc::clock::Cycles(cyc.0 / 2), "host_trmm");
        Ok(())
    }

    /// xTRSM — host-only: solve `A X = alpha * B` in place.
    #[allow(clippy::too_many_arguments)]
    pub fn trsm<T: Elem>(
        &mut self,
        uplo: Uplo,
        unit_diag: bool,
        alpha: T,
        a: &[T],
        n: usize,
        b: &mut [T],
        m_cols: usize,
    ) -> Result<()> {
        if a.len() != n * n || b.len() != n * m_cols {
            return Err(crate::error::Error::shape("trsm: dimension mismatch"));
        }
        host::trsm(n, m_cols, alpha, a, b, uplo, unit_diag);
        let cyc = self.engine.platform.host.gemm_cycles(n, m_cols, n, T::F32_PATH);
        self.engine
            .charge_host_compute(crate::soc::clock::Cycles(cyc.0 / 2), "host_trsm");
        Ok(())
    }

    // ------------------------------------------------------------------
    // Level 2
    // ------------------------------------------------------------------

    /// xGEMV: `y = alpha * op(A) @ x + beta * y`.
    #[allow(clippy::too_many_arguments)]
    pub fn gemv<T: Elem>(
        &mut self,
        trans: Transpose,
        alpha: T,
        a: &[T],
        a_dims: (usize, usize),
        x: &[T],
        beta: T,
        y: &mut [T],
    ) -> Result<()> {
        let (m, n) = check_gemv_dims(trans, a_dims, x.len(), y.len())?;
        let a_op = host::materialize_op(a, a_dims.0, a_dims.1, trans);
        match self.policy.gemv(m, n) {
            ExecTarget::Host => {
                host::gemv(m, n, alpha, &a_op, x, beta, y);
                let cyc = self.engine.platform.host.gemv_cycles(m, n, T::F32_PATH);
                self.engine.charge_host_compute(cyc, "host_gemv");
                Ok(())
            }
            ExecTarget::Device => device::gemv(
                &mut self.engine, &mut self.registry, m, n, alpha, &a_op, x,
                beta, y, false, self.policy.kernel.as_deref(),
            ),
            ExecTarget::DeviceZeroCopy => device::gemv(
                &mut self.engine, &mut self.registry, m, n, alpha, &a_op, x,
                beta, y, true, self.policy.kernel.as_deref(),
            ),
        }
    }

    /// xGER: `A += alpha * x y^T` (host-only: rank-1 updates never win).
    pub fn ger<T: Elem>(
        &mut self,
        alpha: T,
        x: &[T],
        y: &[T],
        a: &mut [T],
        a_dims: (usize, usize),
    ) -> Result<()> {
        if a.len() != a_dims.0 * a_dims.1 || x.len() != a_dims.0 || y.len() != a_dims.1 {
            return Err(crate::error::Error::shape("ger: dimension mismatch"));
        }
        host::ger(a_dims.0, a_dims.1, alpha, x, y, a);
        let cyc = self
            .engine
            .platform
            .host
            .gemv_cycles(a_dims.0, a_dims.1, T::F32_PATH);
        self.engine.charge_host_compute(cyc, "host_ger");
        Ok(())
    }

    // ------------------------------------------------------------------
    // Level 1 (device path: f64 only, like the artifact catalog)
    // ------------------------------------------------------------------

    /// dAXPY.
    pub fn axpy(&mut self, alpha: f64, x: &[f64], y: &mut [f64]) -> Result<()> {
        if x.len() != y.len() {
            return Err(crate::error::Error::shape("axpy: length mismatch"));
        }
        match self.policy.level1(OffloadKind::Axpy, x.len()) {
            ExecTarget::Host => {
                host::axpy(alpha, x, y);
                let cyc = self.engine.platform.host.level1_cycles(x.len(), 2.0, false);
                self.engine.charge_host_compute(cyc, "host_axpy");
                Ok(())
            }
            ExecTarget::Device => device::axpy_f64(
                &mut self.engine, &mut self.registry, alpha, x, y, false,
                self.policy.kernel.as_deref(),
            ),
            ExecTarget::DeviceZeroCopy => device::axpy_f64(
                &mut self.engine, &mut self.registry, alpha, x, y, true,
                self.policy.kernel.as_deref(),
            ),
        }
    }

    /// dDOT.
    pub fn dot(&mut self, x: &[f64], y: &[f64]) -> Result<f64> {
        if x.len() != y.len() {
            return Err(crate::error::Error::shape("dot: length mismatch"));
        }
        match self.policy.level1(OffloadKind::Dot, x.len()) {
            ExecTarget::Host => {
                let r = host::dot(x, y);
                let cyc = self.engine.platform.host.level1_cycles(x.len(), 2.0, false);
                self.engine.charge_host_compute(cyc, "host_dot");
                Ok(r)
            }
            ExecTarget::Device => device::dot_f64(
                &mut self.engine, &mut self.registry, x, y, false,
                self.policy.kernel.as_deref(),
            ),
            ExecTarget::DeviceZeroCopy => device::dot_f64(
                &mut self.engine, &mut self.registry, x, y, true,
                self.policy.kernel.as_deref(),
            ),
        }
    }

    /// dSCAL (host streaming op).
    pub fn scal(&mut self, alpha: f64, x: &mut [f64]) -> Result<()> {
        host::scal(alpha, x);
        let cyc = self.engine.platform.host.level1_cycles(x.len(), 1.0, false);
        self.engine.charge_host_compute(cyc, "host_scal");
        Ok(())
    }

    /// dASUM.
    pub fn asum(&mut self, x: &[f64]) -> Result<f64> {
        let r = host::asum(x);
        let cyc = self.engine.platform.host.level1_cycles(x.len(), 1.0, false);
        self.engine.charge_host_compute(cyc, "host_asum");
        Ok(r)
    }

    /// dNRM2.
    pub fn nrm2(&mut self, x: &[f64]) -> Result<f64> {
        let r = host::nrm2(x);
        let cyc = self.engine.platform.host.level1_cycles(x.len(), 2.0, false);
        self.engine.charge_host_compute(cyc, "host_nrm2");
        Ok(r)
    }

    /// idAMAX.
    pub fn iamax(&mut self, x: &[f64]) -> Result<usize> {
        let r = host::iamax(x);
        let cyc = self.engine.platform.host.level1_cycles(x.len(), 1.0, false);
        self.engine.charge_host_compute(cyc, "host_iamax");
        Ok(r)
    }

    /// Convenience: total virtual time per region since last reset, in
    /// seconds (the Figure 3 stacked-bar values).
    pub fn region_secs(&self) -> Vec<(RegionClass, f64)> {
        let f = self.engine.freq_hz();
        self.engine
            .trace
            .breakdown()
            .into_iter()
            .map(|(c, cyc)| (c, cyc.to_secs(f)))
            .collect()
    }
}
