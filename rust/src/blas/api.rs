//! The session API — what NumPy (our [`crate::npy`]) links against.
//!
//! [`HeroBlas`] owns the whole vertical slice: offload engine (SoC
//! models + virtual clock + trace), the PJRT artifact registry, and the
//! dispatch policy.  Every public method has CBLAS semantics; dispatch
//! decides per call whether the CVA6 host kernels or the heterogeneous
//! device kernels run, exactly like OpenBLAS' interface layer.

use std::path::Path;

use crate::config::{DispatchMode, PlatformConfig};
use crate::error::Result;
use crate::hero::offload::OffloadKind;
use crate::metrics::Metrics;
use crate::omp::engine::OffloadEngine;
use crate::runtime::ArtifactRegistry;
use crate::soc::trace::{RegionClass, Trace};
use crate::soc::Platform;

use super::device;
use super::dispatch::{DispatchPolicy, ExecTarget};
use super::elem::Elem;
use super::host;
use super::types::{check_gemm_dims, check_gemv_dims, Transpose, Uplo};

/// One linked instance of the accelerated BLAS.
pub struct HeroBlas {
    pub engine: OffloadEngine,
    pub registry: ArtifactRegistry,
    pub policy: DispatchPolicy,
}

impl std::fmt::Debug for HeroBlas {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("HeroBlas")
            .field("platform", &self.engine.platform.cfg.name)
            .field("policy", &self.policy)
            .finish()
    }
}

impl HeroBlas {
    /// Build a session from a platform config + artifacts directory.
    pub fn new(cfg: PlatformConfig, artifacts: &Path, policy: DispatchPolicy) -> Result<Self> {
        cfg.validate()?;
        let engine = OffloadEngine::new(Platform::new(cfg))?;
        let registry = ArtifactRegistry::open(artifacts)?;
        Ok(HeroBlas { engine, registry, policy })
    }

    /// Default platform, artifacts found via `HERO_BLAS_ARTIFACTS` or by
    /// walking up from the current directory.
    pub fn from_env(mode: DispatchMode) -> Result<Self> {
        let dir = crate::find_artifacts_dir()?;
        HeroBlas::new(
            PlatformConfig::default(),
            &dir,
            DispatchPolicy::with_mode(mode),
        )
    }

    /// Clear the per-run trace (Figure 3 measures warm calls).
    pub fn reset_run(&mut self) {
        self.engine.reset_run();
    }

    /// The region trace of everything since the last reset.
    pub fn trace(&self) -> &Trace {
        &self.engine.trace
    }

    /// Aggregate counters (incl. PJRT wall time synced from the registry).
    pub fn metrics(&mut self) -> Metrics {
        self.engine.metrics.pjrt_wall_us = self.registry.stats().exec_wall_us;
        self.engine.metrics
    }

    /// Virtual seconds since engine start.
    pub fn now_secs(&self) -> f64 {
        self.engine.now().to_secs(self.engine.freq_hz())
    }

    // ------------------------------------------------------------------
    // Level 3
    // ------------------------------------------------------------------

    /// xGEMM: `C = alpha * op(A) @ op(B) + beta * C`.
    /// `a`/`b` are stored row-major with the given stored dims.
    #[allow(clippy::too_many_arguments)]
    pub fn gemm<T: Elem>(
        &mut self,
        trans_a: Transpose,
        trans_b: Transpose,
        alpha: T,
        a: &[T],
        a_dims: (usize, usize),
        b: &[T],
        b_dims: (usize, usize),
        beta: T,
        c: &mut [T],
        c_dims: (usize, usize),
    ) -> Result<()> {
        let (m, n, k) = check_gemm_dims(trans_a, trans_b, a_dims, b_dims, c_dims)?;
        let a_op = host::materialize_op(a, a_dims.0, a_dims.1, trans_a);
        let b_op = host::materialize_op(b, b_dims.0, b_dims.1, trans_b);
        match self.policy.gemm(m, n, k) {
            ExecTarget::Host => {
                host::gemm(m, n, k, alpha, &a_op, &b_op, beta, c);
                let cyc = self.engine.platform.host.gemm_cycles(m, n, k, T::F32_PATH);
                self.engine.charge_host_compute(cyc, "host_gemm");
                Ok(())
            }
            ExecTarget::Device => device::gemm(
                &mut self.engine, &mut self.registry, m, n, k, alpha, &a_op,
                &b_op, beta, c, false,
            ),
            ExecTarget::DeviceZeroCopy => device::gemm(
                &mut self.engine, &mut self.registry, m, n, k, alpha, &a_op,
                &b_op, beta, c, true,
            ),
        }
    }

    /// xSYRK — host-only, like the paper's `syrk.c`.
    #[allow(clippy::too_many_arguments)]
    pub fn syrk<T: Elem>(
        &mut self,
        uplo: Uplo,
        trans: Transpose,
        alpha: T,
        a: &[T],
        a_dims: (usize, usize),
        beta: T,
        c: &mut [T],
        n_dim: usize,
    ) -> Result<()> {
        let (n, k) = trans.dims(a_dims.0, a_dims.1);
        if n != n_dim || c.len() != n * n {
            return Err(crate::error::Error::shape(format!(
                "syrk: op(A)={n}x{k}, C must be {n_dim}x{n_dim}"
            )));
        }
        let a_op = host::materialize_op(a, a_dims.0, a_dims.1, trans);
        host::syrk(n, k, alpha, &a_op, beta, c, uplo);
        // ~half the FLOPs of a full GEMM (one triangle)
        let cyc = self.engine.platform.host.gemm_cycles(n, n, k, T::F32_PATH);
        self.engine
            .charge_host_compute(crate::soc::clock::Cycles(cyc.0 / 2), "host_syrk");
        Ok(())
    }

    /// xSYMM — host-only: `C = alpha * A @ B + beta * C`, A symmetric
    /// (n x n, `uplo` triangle stored), B/C are n x m_cols.
    #[allow(clippy::too_many_arguments)]
    pub fn symm<T: Elem>(
        &mut self,
        uplo: Uplo,
        alpha: T,
        a: &[T],
        n: usize,
        b: &[T],
        m_cols: usize,
        beta: T,
        c: &mut [T],
    ) -> Result<()> {
        if a.len() != n * n || b.len() != n * m_cols || c.len() != n * m_cols {
            return Err(crate::error::Error::shape("symm: dimension mismatch"));
        }
        host::symm(n, m_cols, alpha, a, b, beta, c, uplo);
        let cyc = self.engine.platform.host.gemm_cycles(n, m_cols, n, T::F32_PATH);
        self.engine.charge_host_compute(cyc, "host_symm");
        Ok(())
    }

    /// xTRMM — host-only: `B = alpha * A @ B`, A triangular (n x n).
    #[allow(clippy::too_many_arguments)]
    pub fn trmm<T: Elem>(
        &mut self,
        uplo: Uplo,
        unit_diag: bool,
        alpha: T,
        a: &[T],
        n: usize,
        b: &mut [T],
        m_cols: usize,
    ) -> Result<()> {
        if a.len() != n * n || b.len() != n * m_cols {
            return Err(crate::error::Error::shape("trmm: dimension mismatch"));
        }
        host::trmm(n, m_cols, alpha, a, b, uplo, unit_diag);
        let cyc = self.engine.platform.host.gemm_cycles(n, m_cols, n, T::F32_PATH);
        self.engine
            .charge_host_compute(crate::soc::clock::Cycles(cyc.0 / 2), "host_trmm");
        Ok(())
    }

    /// xTRSM — host-only: solve `A X = alpha * B` in place.
    #[allow(clippy::too_many_arguments)]
    pub fn trsm<T: Elem>(
        &mut self,
        uplo: Uplo,
        unit_diag: bool,
        alpha: T,
        a: &[T],
        n: usize,
        b: &mut [T],
        m_cols: usize,
    ) -> Result<()> {
        if a.len() != n * n || b.len() != n * m_cols {
            return Err(crate::error::Error::shape("trsm: dimension mismatch"));
        }
        host::trsm(n, m_cols, alpha, a, b, uplo, unit_diag);
        let cyc = self.engine.platform.host.gemm_cycles(n, m_cols, n, T::F32_PATH);
        self.engine
            .charge_host_compute(crate::soc::clock::Cycles(cyc.0 / 2), "host_trsm");
        Ok(())
    }

    // ------------------------------------------------------------------
    // Level 2
    // ------------------------------------------------------------------

    /// xGEMV: `y = alpha * op(A) @ x + beta * y`.
    #[allow(clippy::too_many_arguments)]
    pub fn gemv<T: Elem>(
        &mut self,
        trans: Transpose,
        alpha: T,
        a: &[T],
        a_dims: (usize, usize),
        x: &[T],
        beta: T,
        y: &mut [T],
    ) -> Result<()> {
        let (m, n) = check_gemv_dims(trans, a_dims, x.len(), y.len())?;
        let a_op = host::materialize_op(a, a_dims.0, a_dims.1, trans);
        match self.policy.gemv(m, n) {
            ExecTarget::Host => {
                host::gemv(m, n, alpha, &a_op, x, beta, y);
                let cyc = self.engine.platform.host.gemv_cycles(m, n, T::F32_PATH);
                self.engine.charge_host_compute(cyc, "host_gemv");
                Ok(())
            }
            ExecTarget::Device => device::gemv(
                &mut self.engine, &mut self.registry, m, n, alpha, &a_op, x,
                beta, y, false,
            ),
            ExecTarget::DeviceZeroCopy => device::gemv(
                &mut self.engine, &mut self.registry, m, n, alpha, &a_op, x,
                beta, y, true,
            ),
        }
    }

    /// xGER: `A += alpha * x y^T` (host-only: rank-1 updates never win).
    pub fn ger<T: Elem>(
        &mut self,
        alpha: T,
        x: &[T],
        y: &[T],
        a: &mut [T],
        a_dims: (usize, usize),
    ) -> Result<()> {
        if a.len() != a_dims.0 * a_dims.1 || x.len() != a_dims.0 || y.len() != a_dims.1 {
            return Err(crate::error::Error::shape("ger: dimension mismatch"));
        }
        host::ger(a_dims.0, a_dims.1, alpha, x, y, a);
        let cyc = self
            .engine
            .platform
            .host
            .gemv_cycles(a_dims.0, a_dims.1, T::F32_PATH);
        self.engine.charge_host_compute(cyc, "host_ger");
        Ok(())
    }

    // ------------------------------------------------------------------
    // Level 1 (device path: f64 only, like the artifact catalog)
    // ------------------------------------------------------------------

    /// dAXPY.
    pub fn axpy(&mut self, alpha: f64, x: &[f64], y: &mut [f64]) -> Result<()> {
        if x.len() != y.len() {
            return Err(crate::error::Error::shape("axpy: length mismatch"));
        }
        match self.policy.level1(OffloadKind::Axpy, x.len()) {
            ExecTarget::Host => {
                host::axpy(alpha, x, y);
                let cyc = self.engine.platform.host.level1_cycles(x.len(), 2.0, false);
                self.engine.charge_host_compute(cyc, "host_axpy");
                Ok(())
            }
            ExecTarget::Device => {
                device::axpy_f64(&mut self.engine, &mut self.registry, alpha, x, y, false)
            }
            ExecTarget::DeviceZeroCopy => {
                device::axpy_f64(&mut self.engine, &mut self.registry, alpha, x, y, true)
            }
        }
    }

    /// dDOT.
    pub fn dot(&mut self, x: &[f64], y: &[f64]) -> Result<f64> {
        if x.len() != y.len() {
            return Err(crate::error::Error::shape("dot: length mismatch"));
        }
        match self.policy.level1(OffloadKind::Dot, x.len()) {
            ExecTarget::Host => {
                let r = host::dot(x, y);
                let cyc = self.engine.platform.host.level1_cycles(x.len(), 2.0, false);
                self.engine.charge_host_compute(cyc, "host_dot");
                Ok(r)
            }
            ExecTarget::Device => {
                device::dot_f64(&mut self.engine, &mut self.registry, x, y, false)
            }
            ExecTarget::DeviceZeroCopy => {
                device::dot_f64(&mut self.engine, &mut self.registry, x, y, true)
            }
        }
    }

    /// dSCAL (host streaming op).
    pub fn scal(&mut self, alpha: f64, x: &mut [f64]) -> Result<()> {
        host::scal(alpha, x);
        let cyc = self.engine.platform.host.level1_cycles(x.len(), 1.0, false);
        self.engine.charge_host_compute(cyc, "host_scal");
        Ok(())
    }

    /// dASUM.
    pub fn asum(&mut self, x: &[f64]) -> Result<f64> {
        let r = host::asum(x);
        let cyc = self.engine.platform.host.level1_cycles(x.len(), 1.0, false);
        self.engine.charge_host_compute(cyc, "host_asum");
        Ok(r)
    }

    /// dNRM2.
    pub fn nrm2(&mut self, x: &[f64]) -> Result<f64> {
        let r = host::nrm2(x);
        let cyc = self.engine.platform.host.level1_cycles(x.len(), 2.0, false);
        self.engine.charge_host_compute(cyc, "host_nrm2");
        Ok(r)
    }

    /// idAMAX.
    pub fn iamax(&mut self, x: &[f64]) -> Result<usize> {
        let r = host::iamax(x);
        let cyc = self.engine.platform.host.level1_cycles(x.len(), 1.0, false);
        self.engine.charge_host_compute(cyc, "host_iamax");
        Ok(r)
    }

    /// Convenience: total virtual time per region since last reset, in
    /// seconds (the Figure 3 stacked-bar values).
    pub fn region_secs(&self) -> Vec<(RegionClass, f64)> {
        let f = self.engine.freq_hz();
        self.engine
            .trace
            .breakdown()
            .into_iter()
            .map(|(c, cyc)| (c, cyc.to_secs(f)))
            .collect()
    }
}
