//! CBLAS argument enums and shape helpers. Layout is row-major
//! throughout (NumPy's default, which is what the paper's stack sees).

use crate::error::{Error, Result};

/// Matrix transposition flag.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Transpose {
    No,
    Yes,
}

impl Transpose {
    pub fn is_trans(self) -> bool {
        self == Transpose::Yes
    }

    /// (rows, cols) of op(X) given the stored (rows, cols).
    pub fn dims(self, rows: usize, cols: usize) -> (usize, usize) {
        match self {
            Transpose::No => (rows, cols),
            Transpose::Yes => (cols, rows),
        }
    }
}

/// Which triangle a symmetric update touches.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Uplo {
    Upper,
    Lower,
}

/// Multiplication side for symm/trmm-style ops.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Side {
    Left,
    Right,
}

/// Validate GEMM shapes: op(A): m x k, op(B): k x n, C: m x n.
/// `a_dims`/`b_dims` are the *stored* shapes.
pub fn check_gemm_dims(
    trans_a: Transpose,
    trans_b: Transpose,
    a_dims: (usize, usize),
    b_dims: (usize, usize),
    c_dims: (usize, usize),
) -> Result<(usize, usize, usize)> {
    let (m, ka) = trans_a.dims(a_dims.0, a_dims.1);
    let (kb, n) = trans_b.dims(b_dims.0, b_dims.1);
    if ka != kb {
        return Err(Error::shape(format!(
            "gemm: contraction mismatch op(A)={m}x{ka} op(B)={kb}x{n}"
        )));
    }
    if c_dims != (m, n) {
        return Err(Error::shape(format!(
            "gemm: C is {}x{}, expected {m}x{n}",
            c_dims.0, c_dims.1
        )));
    }
    if m == 0 || n == 0 || ka == 0 {
        return Err(Error::shape("gemm: zero-sized dimension"));
    }
    Ok((m, n, ka))
}

/// Validate GEMV shapes: op(A): m x n, x: n, y: m.
pub fn check_gemv_dims(
    trans: Transpose,
    a_dims: (usize, usize),
    x_len: usize,
    y_len: usize,
) -> Result<(usize, usize)> {
    let (m, n) = trans.dims(a_dims.0, a_dims.1);
    if x_len != n || y_len != m {
        return Err(Error::shape(format!(
            "gemv: op(A)={m}x{n} with x[{x_len}], y[{y_len}]"
        )));
    }
    if m == 0 || n == 0 {
        return Err(Error::shape("gemv: zero-sized dimension"));
    }
    Ok((m, n))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transpose_dims() {
        assert_eq!(Transpose::No.dims(3, 5), (3, 5));
        assert_eq!(Transpose::Yes.dims(3, 5), (5, 3));
    }

    #[test]
    fn gemm_dims_ok() {
        let (m, n, k) =
            check_gemm_dims(Transpose::No, Transpose::No, (3, 4), (4, 5), (3, 5)).unwrap();
        assert_eq!((m, n, k), (3, 5, 4));
        // A^T: stored (4,3) -> op 3x4
        let (m, n, k) =
            check_gemm_dims(Transpose::Yes, Transpose::No, (4, 3), (4, 5), (3, 5)).unwrap();
        assert_eq!((m, n, k), (3, 5, 4));
    }

    #[test]
    fn gemm_dims_mismatch() {
        assert!(check_gemm_dims(Transpose::No, Transpose::No, (3, 4), (5, 5), (3, 5)).is_err());
        assert!(check_gemm_dims(Transpose::No, Transpose::No, (3, 4), (4, 5), (3, 6)).is_err());
        assert!(check_gemm_dims(Transpose::No, Transpose::No, (0, 4), (4, 5), (0, 5)).is_err());
    }

    #[test]
    fn gemv_dims() {
        assert_eq!(check_gemv_dims(Transpose::No, (3, 4), 4, 3).unwrap(), (3, 4));
        assert_eq!(check_gemv_dims(Transpose::Yes, (3, 4), 3, 4).unwrap(), (4, 3));
        assert!(check_gemv_dims(Transpose::No, (3, 4), 3, 4).is_err());
    }
}
