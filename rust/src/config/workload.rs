//! Workload/sweep configuration for the benchmark harness.



use crate::error::{Error, Result};

/// Where a BLAS call may execute.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DispatchMode {
    /// Always run on the CVA6 host (the paper's "without offloading").
    HostOnly,
    /// Always offload to the PMCA (the paper's "with offloading").
    DeviceOnly,
    /// Pick by the dispatch policy's size threshold.
    Auto,
    /// Offload through the IOMMU without copying (paper's future work).
    DeviceZeroCopy,
}

impl std::str::FromStr for DispatchMode {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "host" | "host_only" => Ok(DispatchMode::HostOnly),
            "device" | "device_only" | "offload" => Ok(DispatchMode::DeviceOnly),
            "auto" => Ok(DispatchMode::Auto),
            "zero_copy" | "device_zero_copy" => Ok(DispatchMode::DeviceZeroCopy),
            other => Err(Error::Config(format!("unknown dispatch mode '{other}'"))),
        }
    }
}

impl std::fmt::Display for DispatchMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            DispatchMode::HostOnly => "host_only",
            DispatchMode::DeviceOnly => "device_only",
            DispatchMode::Auto => "auto",
            DispatchMode::DeviceZeroCopy => "device_zero_copy",
        };
        f.write_str(s)
    }
}

/// One parameter sweep (the x-axis of a figure).
#[derive(Debug, Clone, PartialEq)]
pub struct SweepConfig {
    /// Square matrix sizes to sweep (paper's Figure 3 x-axis).
    pub sizes: Vec<usize>,
    /// Dispatch modes to compare.
    pub modes: Vec<DispatchMode>,
    /// Repetitions per point (virtual time is deterministic; reps > 1
    /// only matter for wall-clock noise in criterion).
    pub reps: u32,
}

impl Default for SweepConfig {
    fn default() -> Self {
        SweepConfig {
            sizes: vec![16, 32, 64, 128, 256],
            modes: vec![DispatchMode::HostOnly, DispatchMode::DeviceOnly],
            reps: 1,
        }
    }
}

/// Harness workload description (loadable from TOML for custom sweeps).
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadConfig {
    /// Operation under test ("gemm" for Figure 3).
    pub op: String,
    /// Element type: "f64" (paper) or "f32" (future-work projection).
    pub dtype: String,
    pub sweep: SweepConfig,
    /// RNG seed for synthetic operands (deterministic workloads).
    pub seed: u64,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        WorkloadConfig {
            op: "gemm".into(),
            dtype: "f64".into(),
            sweep: SweepConfig::default(),
            seed: 0x5EED,
        }
    }
}

impl WorkloadConfig {
    /// Load and validate from TOML.
    pub fn from_toml_file(path: &std::path::Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Config(format!("{}: {e}", path.display())))?;
        Self::from_toml_str(&text)
    }

    /// Parse from TOML text; unset fields fall back to the defaults.
    pub fn from_toml_str(text: &str) -> Result<Self> {
        use crate::util::toml_lite::TomlDoc;
        let d = TomlDoc::parse(text)?;
        let mut cfg = WorkloadConfig::default();
        if let Some(op) = d.opt_str("op") {
            cfg.op = op.to_string();
        }
        if let Some(dt) = d.opt_str("dtype") {
            cfg.dtype = dt.to_string();
        }
        if let Some(seed) = d.opt_u64("seed") {
            cfg.seed = seed;
        }
        if d.get("sweep.sizes").is_some() {
            cfg.sweep.sizes = d
                .req_array("sweep.sizes")?
                .iter()
                .map(|v| {
                    v.as_u64().map(|u| u as usize).ok_or_else(|| {
                        Error::Config("sweep.sizes: non-integer entry".into())
                    })
                })
                .collect::<Result<Vec<_>>>()?;
        }
        if d.get("sweep.modes").is_some() {
            cfg.sweep.modes = d
                .req_array("sweep.modes")?
                .iter()
                .map(|v| {
                    v.as_str()
                        .ok_or_else(|| Error::Config("sweep.modes: non-string".into()))
                        .and_then(|s| s.parse())
                })
                .collect::<Result<Vec<_>>>()?;
        }
        if let Some(reps) = d.opt_u64("sweep.reps") {
            cfg.sweep.reps = reps as u32;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Sanity checks.
    pub fn validate(&self) -> Result<()> {
        if self.sweep.sizes.is_empty() {
            return Err(Error::Config("sweep.sizes is empty".into()));
        }
        if self.sweep.sizes.iter().any(|&s| s == 0 || s > 4096) {
            return Err(Error::Config("sweep sizes must be in 1..=4096".into()));
        }
        match self.dtype.as_str() {
            "f32" | "f64" => {}
            other => return Err(Error::Config(format!("unsupported dtype '{other}'"))),
        }
        match self.op.as_str() {
            "gemm" | "gemv" | "axpy" | "dot" => {}
            other => return Err(Error::Config(format!("unsupported op '{other}'"))),
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::str::FromStr;

    #[test]
    fn default_workload_is_valid() {
        WorkloadConfig::default().validate().unwrap();
    }

    #[test]
    fn mode_parse_roundtrip() {
        for m in [
            DispatchMode::HostOnly,
            DispatchMode::DeviceOnly,
            DispatchMode::Auto,
            DispatchMode::DeviceZeroCopy,
        ] {
            assert_eq!(DispatchMode::from_str(&m.to_string()).unwrap(), m);
        }
        assert!(DispatchMode::from_str("bogus").is_err());
    }

    #[test]
    fn rejects_bad_sizes() {
        let mut w = WorkloadConfig::default();
        w.sweep.sizes = vec![0];
        assert!(w.validate().is_err());
        w.sweep.sizes = vec![8192];
        assert!(w.validate().is_err());
    }

    #[test]
    fn mistyped_sweep_array_is_an_error_not_ignored() {
        let e = WorkloadConfig::from_toml_str("[sweep]\nsizes = 64")
            .unwrap_err()
            .to_string();
        assert!(e.contains("sweep.sizes"), "{e}");
    }

    #[test]
    fn rejects_bad_dtype_and_op() {
        let mut w = WorkloadConfig::default();
        w.dtype = "f16".into();
        assert!(w.validate().is_err());
        let mut w = WorkloadConfig::default();
        w.op = "cholesky".into();
        assert!(w.validate().is_err());
    }
}
