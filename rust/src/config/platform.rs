//! Platform description: the Carfield-like heSoC of the paper.

use crate::error::{Error, Result};
use crate::util::toml_lite::TomlDoc;

/// System clock. The paper emulates the SoC on a Xilinx VCU128; Cheshire
/// bitstreams typically close timing around 50 MHz, and all of the
/// paper's absolute times are consistent with that.
#[derive(Debug, Clone, PartialEq)]
pub struct ClockConfig {
    /// Clock frequency shared by host, cluster and interconnect (Hz).
    pub freq_hz: u64,
}

/// CVA6 rv64g host-core model.
#[derive(Debug, Clone, PartialEq)]
pub struct HostConfig {
    /// Sustained double-precision FLOP/cycle of the OpenBLAS generic
    /// kernel on the in-order scalar FPU (no FREP/SSR on the host).
    pub flops_per_cycle: f64,
    /// Sustained copy bandwidth between the Linux-managed and the
    /// device-managed DRAM partitions, bytes/cycle (uncached stores
    /// through the LLC bypass — this is the paper's "data copy" region).
    pub copy_bytes_per_cycle: f64,
    /// Fixed cost to set up one memcpy call (function call, loop prologue).
    pub memcpy_setup_cycles: u64,
    /// f32 throughput multiplier vs f64 on the host (scalar FPU: ~same).
    pub f32_speedup: f64,
}

/// Snitch PMCA cluster model (one cluster, eight worker cores + DMA core).
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterConfig {
    /// Number of identical Snitch clusters in the PMCA (the paper's
    /// Carfield instance has one; Occamy-class parts have many — output
    /// tiles are distributed round-robin across clusters).
    pub clusters: u32,
    /// Worker cores with double-precision FPUs, per cluster.
    pub cores: u32,
    /// FMAs issued per core per cycle at peak (Snitch: 1).
    pub fma_per_core_per_cycle: f64,
    /// Fraction of peak sustained on SPM-resident GEMM tiles
    /// (rv32imafd without SSR-tuned asm: well below the >80% of
    /// hand-tuned Snitch kernels).
    pub efficiency: f64,
    /// f32 FLOP multiplier vs f64 (paper future-work: "SIMD operations on
    /// lower precision data types" — 2 f32 lanes per 64-bit FPU).
    pub f32_speedup: f64,
}

/// Memory map of the heSoC (Figure 1 of the paper).
#[derive(Debug, Clone, PartialEq)]
pub struct MemoryConfig {
    /// L1 scratch-pad memory inside the cluster (bytes). Paper: 128 KiB.
    pub l1_spm_bytes: u64,
    /// Dual-port L2 SPM holding device instructions + constants (bytes).
    pub l2_spm_bytes: u64,
    /// Device-managed DRAM partition (physically contiguous buffers).
    pub dev_dram_bytes: u64,
    /// Base addresses (documentation + map sanity checks).
    pub l1_spm_base: u64,
    pub l2_spm_base: u64,
    pub dev_dram_base: u64,
}

/// Cluster DMA engine (iDMA): refills L1 SPM from DRAM.
#[derive(Debug, Clone, PartialEq)]
pub struct DmaConfig {
    /// Payload bytes moved per cycle once streaming (64-bit AXI = 8).
    pub bytes_per_cycle: f64,
    /// Fixed per-transfer programming cost (config regs + launch).
    pub setup_cycles: u64,
    /// Extra cycles per 2-D row (address regeneration).
    pub per_row_cycles: u64,
}

/// Fork/join cost model: everything the paper's "fork/join" region
/// contains — entering OpenBLAS, entering the OpenMP target runtime,
/// marshalling the offload descriptor, the mailbox doorbell, device
/// wake-up, and the join/teardown on the way out. Costs are cycles on the
/// 50 MHz host; syscalls/ioctls through the Hero kernel module dominate.
#[derive(Debug, Clone, PartialEq)]
pub struct ForkJoinConfig {
    /// OpenBLAS interface-layer entry (dispatch tables, arg checks).
    pub openblas_entry_cycles: u64,
    /// libomptarget entry: ioctl into the Hero kernel module, building
    /// the target-region descriptor.
    pub omp_entry_cycles: u64,
    /// Per-mapped-argument marshalling cost.
    pub per_arg_cycles: u64,
    /// Mailbox doorbell write + IRQ delivery to the cluster.
    pub doorbell_cycles: u64,
    /// Cluster wake-up from clock-gated idle + kernel entry.
    pub device_wakeup_cycles: u64,
    /// Host-side join: completion poll/interrupt + return through the
    /// kernel module.
    pub join_cycles: u64,
    /// libomptarget + OpenBLAS exit path.
    pub exit_cycles: u64,
}

/// RISC-V IOMMU model (the paper's future-work zero-copy path, which we
/// implement — see DESIGN.md R3).
#[derive(Debug, Clone, PartialEq)]
pub struct IommuConfig {
    /// IO page size (Sv39x4 leaf: 4 KiB).
    pub page_bytes: u64,
    /// Cycles for the host to create + publish one IO-PTE
    /// (calibrated so PTE creation is ~7.5x faster than copying the same
    /// page, the ratio the paper cites from its prior study).
    pub pte_create_cycles: u64,
    /// IOTLB capacity (entries).
    pub iotlb_entries: u32,
    /// Page-table-walk penalty on IOTLB miss (cycles).
    pub iotlb_miss_cycles: u64,
    /// Cycles to tear down the mapping at unmap time, per page.
    pub pte_teardown_cycles: u64,
}

/// Data-movement knobs of the offload staging path (`[sched.cache]`).
///
/// Both features attack the same bottleneck — the paper's data-copy
/// region: the **operand cache** keeps `map(to:)` operands resident in
/// the cluster's device-DRAM slice so re-staging identical bytes becomes
/// a refcount bump instead of a copy, and **software pipelining** lets a
/// worker stage the next batch's map-in while the current batch's
/// compute is still in flight (double-buffered staging, enabled by the
/// `gemm_batch` stage/execute/finish split).  Both default OFF so the
/// plain offload path stays bit-identical to the paper's measured
/// configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct CacheConfig {
    /// Fraction of the cluster's device-DRAM slice the operand cache may
    /// keep resident (0.0 disables the cache AND the `map(alloc:)`
    /// beta==0 output-staging elision — staging is then bit-identical to
    /// the uncached path).  Live mappings are never evicted, so a burst
    /// of pinned operands may transiently exceed the fraction.
    pub cache_frac: f64,
    /// Hard cap on resident cache entries (0 also disables the cache).
    pub cache_max_entries: u32,
    /// Staging pipeline depth per worker: 1 = fully serial (today's
    /// behavior); >= 2 overlaps map-in of batch k+1 with compute of
    /// batch k (the implementation double-buffers, so depths above 2
    /// behave like 2).
    pub pipeline_depth: u32,
}

impl Default for CacheConfig {
    fn default() -> Self {
        CacheConfig { cache_frac: 0.0, cache_max_entries: 32, pipeline_depth: 1 }
    }
}

impl CacheConfig {
    /// Is the operand cache (and the staging elisions it gates) active?
    pub fn cache_enabled(&self) -> bool {
        self.cache_frac > 0.0 && self.cache_max_entries > 0
    }

    /// Is worker software pipelining active?
    pub fn pipelined(&self) -> bool {
        self.pipeline_depth >= 2
    }
}

/// Cost-model knobs (`[cost]`): the unified offload cost estimator
/// behind `DispatchPolicy::Auto`, the batcher's linger sizing, the
/// placement router's footprints and the pipelining overlap credit
/// (see [`crate::cost`]).
///
/// The analytical estimates are a pure function of the timing constants
/// above; `calibrate` additionally folds *observed* per-op batch
/// timings back in as EWMA-smoothed multiplicative corrections, clamped
/// to `[floor, ceiling]`.  Calibration never changes numerics — only
/// which path `Auto` picks and how long the batcher lingers — and it
/// defaults OFF so decisions stay a deterministic function of the
/// platform description.
#[derive(Debug, Clone, PartialEq)]
pub struct CostConfig {
    /// Fold observed timings back into the estimates (EWMA feedback).
    pub calibrate: bool,
    /// EWMA smoothing factor per observation, in (0, 1].
    pub alpha: f64,
    /// Lower clamp on every calibration scale (<= 1).
    pub floor: f64,
    /// Upper clamp on every calibration scale (>= 1).
    pub ceiling: f64,
}

impl Default for CostConfig {
    fn default() -> Self {
        CostConfig { calibrate: false, alpha: 0.125, floor: 0.25, ceiling: 4.0 }
    }
}

/// Placement-router knobs (`[sched.placement]`): how jobs are assigned
/// to pool clusters (see `crate::sched::placement`).
///
/// The router replaces the any-worker-takes-any-job dequeue with
/// locality-aware placement: **affinity** routes requests sharing an
/// operand (same `b_seed`) to the cluster whose operand cache already
/// holds it, so a shared weight matrix is staged once per pool instead
/// of once per cluster; **steal** lets an idle worker take queued work
/// from the most-loaded peer instead of idling under skew; and
/// **big_shape_frac** carves one big-shape cluster with a larger
/// device-DRAM slice out of the pool, restoring the large-GEMM range
/// that even partitioning caps (and keeping small requests out of its
/// queue, so they never sit behind a large launch).
#[derive(Debug, Clone, PartialEq)]
pub struct PlacementConfig {
    /// Route same-operand requests to the cache-warm cluster (with a
    /// deterministic hash-home fallback before anything is resident).
    pub affinity: bool,
    /// Idle workers steal queued jobs from the most-loaded peer.
    pub steal: bool,
    /// Fraction of the device-DRAM partition given to cluster 0 (the
    /// big-shape lane); the rest splits evenly across the other
    /// clusters.  0.0 keeps the even split (no big-shape lane).  Only
    /// meaningful for pools of >= 2 clusters.
    pub big_shape_frac: f64,
    /// Steal-fairness load balancing: re-home an operand key in the
    /// affinity directory when its home cluster's run-queue depth stays
    /// above the pool mean for this many consecutive (job-moving) drain
    /// passes, so a sustained affine skew stops queueing behind one
    /// cluster.  0 disables re-homing (stealing stays purely reactive).
    pub rebalance_drains: u32,
}

impl Default for PlacementConfig {
    fn default() -> Self {
        // Affinity and stealing change only *where* a job runs (numerics
        // are placement-invariant), so they default on; the heterogeneous
        // slicing changes per-cluster capacity, so it defaults off, and
        // re-homing changes steady-state placement, so it also defaults
        // off (turn it on for sustained-skew workloads).
        PlacementConfig {
            affinity: true,
            steal: true,
            big_shape_frac: 0.0,
            rebalance_drains: 0,
        }
    }
}

impl PlacementConfig {
    /// Is the heterogeneous big-shape slicing active for this pool size?
    pub fn big_lane(&self, pool_clusters: u32) -> bool {
        self.big_shape_frac > 0.0 && pool_clusters >= 2
    }
}

/// Operation-chaining knobs (`[sched.chain]`): bounds on the `chain`
/// serving op, which runs a dependent GEMM sequence as one submission
/// with device-resident intermediates (see `blas::device::gemm_chain_stage`).
///
/// A chain stages its input, every link's weights AND every link's
/// output at once (intermediates never leave the device), so its
/// footprint grows with length — `max_links` bounds the spec before the
/// capacity check against the cluster slice even runs.
#[derive(Debug, Clone, PartialEq)]
pub struct ChainConfig {
    /// Most links one chain request may carry (1..=32).
    pub max_links: u32,
}

impl Default for ChainConfig {
    fn default() -> Self {
        ChainConfig { max_links: 8 }
    }
}

/// DAG-executor knobs (`[sched.dag]`): bounds on the `dag` serving op,
/// which runs a typed dataflow graph (gemm/gemv/axpy/dot nodes with
/// fan-out and fan-in) as one submission with device-resident edges
/// (see `blas::device::dag_stage`).
///
/// Like a chain, a DAG stages its input, every matmul node's weights
/// AND every node's output at once, so `max_nodes` bounds the spec
/// before the capacity check against the cluster slice runs;
/// `max_width`/`max_depth` bound the graph's shape so validation errors
/// can name the exact node and level that blew the budget.
/// `fuse_window_ms` bounds cross-request fusion: a completed DAG that
/// declared a `publish_key` keeps its output resident that long, and a
/// request arriving within the window whose `input_key` matches splices
/// onto the resident buffer instead of a host round-trip (0 disables).
#[derive(Debug, Clone, PartialEq)]
pub struct DagConfig {
    /// Most nodes one dag request may carry (1..=64).
    pub max_nodes: u32,
    /// Most nodes at any one depth level (fan-out bound, 1..=16).
    pub max_width: u32,
    /// Longest dependency path through the graph (1..=32).
    pub max_depth: u32,
    /// Cross-request fusion window, milliseconds (<= 10000; 0 disables).
    pub fuse_window_ms: u64,
}

impl Default for DagConfig {
    fn default() -> Self {
        DagConfig {
            max_nodes: 16,
            max_width: 4,
            max_depth: 8,
            fuse_window_ms: 50,
        }
    }
}

/// Fault-injection and recovery knobs (`[sched.fault]`).
///
/// Default OFF: with the section absent (or `enabled = false`) no
/// fault ever fires, no deadline is armed, and the scheduler path is
/// bit-identical to a build without the subsystem.  When enabled, a
/// seeded [`crate::sched::fault::FaultPlan`] deterministically injects
/// failures at three seams of the staged device paths (staging/DMA
/// error, mailbox timeout, compute poison); the recovery machinery
/// (retry on a different cluster, quarantine, host fallback) is always
/// compiled in and is what these knobs tune.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultConfig {
    /// Master switch for injection AND the deadline detector.
    pub enabled: bool,
    /// Seed of the deterministic fault schedule.
    pub seed: u64,
    /// Per-launch probability of a staging/DMA fault, in [0, 1].
    pub staging_rate: f64,
    /// Per-launch probability of a mailbox hang (deadline trip), in [0, 1].
    pub mailbox_rate: f64,
    /// Per-launch probability of poisoned results, in [0, 1].
    pub poison_rate: f64,
    /// Restrict injection to one cluster id; -1 targets all clusters.
    pub target_cluster: i64,
    /// Batch deadline = this factor x the cost model's predicted cycles
    /// (>= 1; detection only — the simulated device still completes).
    pub deadline_factor: f64,
    /// Device attempts per job before the host fallback (>= 1).
    pub max_attempts: u32,
    /// Base of the bounded exponential retry backoff, milliseconds.
    pub backoff_base_ms: u64,
    /// Faults before a cluster is quarantined (>= 1).
    pub quarantine_threshold: u32,
    /// Router drain passes before a quarantined cluster is probed for
    /// re-admission (>= 1).
    pub probe_interval: u64,
}

impl Default for FaultConfig {
    fn default() -> Self {
        FaultConfig {
            enabled: false,
            seed: 1,
            staging_rate: 0.0,
            mailbox_rate: 0.0,
            poison_rate: 0.0,
            target_cluster: -1,
            deadline_factor: 4.0,
            max_attempts: 3,
            backoff_base_ms: 1,
            quarantine_threshold: 3,
            probe_interval: 16,
        }
    }
}

/// Flight-recorder knobs (`[sched.trace]`).
///
/// Default ON: the recorder is designed to be always-on (bounded
/// memory, lock-free writers, <5% throughput cost — the bench's
/// tracing-overhead sweep pins this), so a p999 spike or a quarantine
/// cascade can always be reconstructed after the fact with the serve
/// `trace_dump` op.  `enabled = false` drops every record call at one
/// branch for bit-identical-overhead runs.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceConfig {
    /// Master switch for event recording.
    pub enabled: bool,
    /// Events retained per ring (one ring per cluster plus the global
    /// ingress track); the oldest events are overwritten when full.
    pub ring_capacity: u64,
    /// Frame interval of the serve `watch` streaming op, milliseconds.
    pub watch_interval_ms: u64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            enabled: true,
            ring_capacity: 4096,
            watch_interval_ms: 500,
        }
    }
}

/// Shape-specialized kernel-registry knobs (`[kernel]`): the content-
/// keyed cache of specialized compute walks behind `blas::device` (see
/// [`crate::kernel`]).
///
/// Specialization never changes numerics — a specialized walk issues the
/// exact same device executions in the same order and differs only in
/// its charge schedule — so the registry defaults ON.  `promote_after`
/// keeps the first launches of every shape on the generic walk (both
/// paths stay exercised); `max_entries` bounds resident plans with
/// pinned-aware LRU eviction.
#[derive(Debug, Clone, PartialEq)]
pub struct KernelConfig {
    /// Master switch: false keeps every launch on the generic walk.
    pub enabled: bool,
    /// Launches of one (op, dtype, shape, epilogue) key before its
    /// specialized plan is compiled and promoted (1..=65536).
    pub promote_after: u32,
    /// Most specialized plans resident at once (1..=4096); beyond this
    /// the least-recently-hit unpinned plan is evicted.
    pub max_entries: u32,
    /// Compile plans for the AOT export size tables at pool boot, so
    /// the first request at a catalog shape already hits the fast path.
    pub prewarm: bool,
}

impl Default for KernelConfig {
    fn default() -> Self {
        KernelConfig {
            enabled: true,
            promote_after: 32,
            max_entries: 64,
            prewarm: false,
        }
    }
}

/// Serve-layer knobs (`[serve]`): the TCP line-protocol front end.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeConfig {
    /// How long a connection handler waits on the reply channel before
    /// cancelling the job and answering with a retry hint (ms).
    pub reply_timeout_ms: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { reply_timeout_ms: 300_000 }
    }
}

/// Offload-scheduler knobs (the [`crate::sched`] pool/queue/batcher).
///
/// These describe the *serving* layer on top of the SoC model: how many
/// simulated PMCA clusters the device pool boots, how deep the bounded
/// work queue is before backpressure kicks in, and how aggressively
/// same-shape requests are coalesced into one fork-join launch.
#[derive(Debug, Clone, PartialEq)]
pub struct SchedConfig {
    /// Simulated PMCA clusters in the device pool.  Each cluster gets its
    /// own worker thread, mailbox and device-DRAM partition (the 64 MiB
    /// partition is split evenly, page-aligned).  Note the tradeoff: a
    /// bigger pool means smaller slices, which lowers the largest GEMM a
    /// single offload can stage (pool 4 on the default platform caps
    /// device-path n around ~800 f64; oversized requests fail cleanly
    /// with an allocator error).
    pub pool_clusters: u32,
    /// Bounded work-queue capacity across all priority classes.  Pushes
    /// beyond it are rejected with a retry-after hint (backpressure).
    pub queue_capacity: u32,
    /// How long a worker waits for more same-shape requests to coalesce
    /// into one launch (0 = only batch what is already queued).
    pub batch_window_ms: u64,
    /// Max requests coalesced into one fork-join launch (1 = batching
    /// off; the launch overhead is then paid per request, as the paper
    /// measures it).
    pub batch_max: u32,
    /// Operand-cache + staging-pipeline knobs (`[sched.cache]`).
    pub cache: CacheConfig,
    /// Placement-router knobs (`[sched.placement]`).
    pub placement: PlacementConfig,
    /// Operation-chaining bounds (`[sched.chain]`).
    pub chain: ChainConfig,
    /// DAG-executor bounds (`[sched.dag]`).
    pub dag: DagConfig,
    /// Fault-injection and recovery knobs (`[sched.fault]`).
    pub fault: FaultConfig,
    /// Flight-recorder knobs (`[sched.trace]`).
    pub trace: TraceConfig,
}

impl Default for SchedConfig {
    fn default() -> Self {
        SchedConfig {
            pool_clusters: 4,
            queue_capacity: 64,
            batch_window_ms: 2,
            batch_max: 8,
            cache: CacheConfig::default(),
            placement: PlacementConfig::default(),
            chain: ChainConfig::default(),
            dag: DagConfig::default(),
            fault: FaultConfig::default(),
            trace: TraceConfig::default(),
        }
    }
}

/// Complete platform description.
#[derive(Debug, Clone, PartialEq)]
pub struct PlatformConfig {
    /// Human-readable platform name (shown by `hero-blas inspect`).
    pub name: String,
    pub clock: ClockConfig,
    pub host: HostConfig,
    pub cluster: ClusterConfig,
    pub memory: MemoryConfig,
    pub dma: DmaConfig,
    pub forkjoin: ForkJoinConfig,
    pub iommu: IommuConfig,
    pub sched: SchedConfig,
    pub cost: CostConfig,
    pub kernel: KernelConfig,
    pub serve: ServeConfig,
}

impl Default for PlatformConfig {
    /// The calibrated Carfield instance (same values as
    /// `configs/carfield.toml`). Calibration targets: Figure 3 shape,
    /// 2.71x offload speedup at N=128 with a 47% data-copy share.
    fn default() -> Self {
        PlatformConfig {
            name: "carfield-vcu128".into(),
            clock: ClockConfig { freq_hz: 50_000_000 },
            host: HostConfig {
                flops_per_cycle: 0.4,
                copy_bytes_per_cycle: 0.288,
                memcpy_setup_cycles: 200,
                f32_speedup: 1.0,
            },
            cluster: ClusterConfig {
                clusters: 1,
                cores: 8,
                fma_per_core_per_cycle: 1.0,
                efficiency: 0.35,
                f32_speedup: 2.0,
            },
            memory: MemoryConfig {
                l1_spm_bytes: 128 * 1024,
                l2_spm_bytes: 1024 * 1024,
                dev_dram_bytes: 64 * 1024 * 1024,
                l1_spm_base: 0x1000_0000,
                l2_spm_base: 0x7800_0000,
                dev_dram_base: 0xA000_0000,
            },
            dma: DmaConfig {
                bytes_per_cycle: 8.0,
                setup_cycles: 50,
                per_row_cycles: 4,
            },
            forkjoin: ForkJoinConfig {
                openblas_entry_cycles: 50_000,
                omp_entry_cycles: 300_000,
                per_arg_cycles: 10_000,
                doorbell_cycles: 5_000,
                device_wakeup_cycles: 150_000,
                join_cycles: 400_000,
                exit_cycles: 300_000,
            },
            iommu: IommuConfig {
                page_bytes: 4096,
                pte_create_cycles: 2_025,
                iotlb_entries: 32,
                iotlb_miss_cycles: 120,
                pte_teardown_cycles: 427,
            },
            sched: SchedConfig::default(),
            cost: CostConfig::default(),
            kernel: KernelConfig::default(),
            serve: ServeConfig::default(),
        }
    }
}

impl PlatformConfig {
    /// Load and validate a TOML platform description.
    pub fn from_toml_file(path: &std::path::Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Config(format!("{}: {e}", path.display())))?;
        Self::from_toml_str(&text)
    }

    /// Parse a TOML platform description. Every field is required — a
    /// platform description with silent defaults invites mis-calibration.
    pub fn from_toml_str(text: &str) -> Result<Self> {
        let d = TomlDoc::parse(text)?;
        let cfg = PlatformConfig {
            name: d.req_str("name")?.to_string(),
            clock: ClockConfig { freq_hz: d.req_u64("clock.freq_hz")? },
            host: HostConfig {
                flops_per_cycle: d.req_f64("host.flops_per_cycle")?,
                copy_bytes_per_cycle: d.req_f64("host.copy_bytes_per_cycle")?,
                memcpy_setup_cycles: d.req_u64("host.memcpy_setup_cycles")?,
                f32_speedup: d.req_f64("host.f32_speedup")?,
            },
            cluster: ClusterConfig {
                clusters: d.opt_u64("cluster.clusters").unwrap_or(1) as u32,
                cores: d.req_u64("cluster.cores")? as u32,
                fma_per_core_per_cycle: d.req_f64("cluster.fma_per_core_per_cycle")?,
                efficiency: d.req_f64("cluster.efficiency")?,
                f32_speedup: d.req_f64("cluster.f32_speedup")?,
            },
            memory: MemoryConfig {
                l1_spm_bytes: d.req_u64("memory.l1_spm_bytes")?,
                l2_spm_bytes: d.req_u64("memory.l2_spm_bytes")?,
                dev_dram_bytes: d.req_u64("memory.dev_dram_bytes")?,
                l1_spm_base: d.req_u64("memory.l1_spm_base")?,
                l2_spm_base: d.req_u64("memory.l2_spm_base")?,
                dev_dram_base: d.req_u64("memory.dev_dram_base")?,
            },
            dma: DmaConfig {
                bytes_per_cycle: d.req_f64("dma.bytes_per_cycle")?,
                setup_cycles: d.req_u64("dma.setup_cycles")?,
                per_row_cycles: d.req_u64("dma.per_row_cycles")?,
            },
            forkjoin: ForkJoinConfig {
                openblas_entry_cycles: d.req_u64("forkjoin.openblas_entry_cycles")?,
                omp_entry_cycles: d.req_u64("forkjoin.omp_entry_cycles")?,
                per_arg_cycles: d.req_u64("forkjoin.per_arg_cycles")?,
                doorbell_cycles: d.req_u64("forkjoin.doorbell_cycles")?,
                device_wakeup_cycles: d.req_u64("forkjoin.device_wakeup_cycles")?,
                join_cycles: d.req_u64("forkjoin.join_cycles")?,
                exit_cycles: d.req_u64("forkjoin.exit_cycles")?,
            },
            iommu: IommuConfig {
                page_bytes: d.req_u64("iommu.page_bytes")?,
                pte_create_cycles: d.req_u64("iommu.pte_create_cycles")?,
                iotlb_entries: d.req_u64("iommu.iotlb_entries")? as u32,
                iotlb_miss_cycles: d.req_u64("iommu.iotlb_miss_cycles")?,
                pte_teardown_cycles: d.req_u64("iommu.pte_teardown_cycles")?,
            },
            // Scheduler knobs are serving policy, not SoC calibration —
            // unlike the timing constants above they default when absent,
            // so pre-scheduler platform files keep parsing.
            sched: {
                let def = SchedConfig::default();
                SchedConfig {
                    pool_clusters: d
                        .opt_u64("sched.pool_clusters")
                        .unwrap_or(def.pool_clusters as u64)
                        as u32,
                    queue_capacity: d
                        .opt_u64("sched.queue_capacity")
                        .unwrap_or(def.queue_capacity as u64)
                        as u32,
                    batch_window_ms: d
                        .opt_u64("sched.batch_window_ms")
                        .unwrap_or(def.batch_window_ms),
                    batch_max: d.opt_u64("sched.batch_max").unwrap_or(def.batch_max as u64)
                        as u32,
                    cache: CacheConfig {
                        cache_frac: d
                            .opt_f64("sched.cache.cache_frac")
                            .unwrap_or(def.cache.cache_frac),
                        cache_max_entries: d
                            .opt_u64("sched.cache.cache_max_entries")
                            .unwrap_or(def.cache.cache_max_entries as u64)
                            as u32,
                        pipeline_depth: d
                            .opt_u64("sched.cache.pipeline_depth")
                            .unwrap_or(def.cache.pipeline_depth as u64)
                            as u32,
                    },
                    placement: PlacementConfig {
                        affinity: d
                            .opt_bool("sched.placement.affinity")
                            .unwrap_or(def.placement.affinity),
                        steal: d
                            .opt_bool("sched.placement.steal")
                            .unwrap_or(def.placement.steal),
                        big_shape_frac: d
                            .opt_f64("sched.placement.big_shape_frac")
                            .unwrap_or(def.placement.big_shape_frac),
                        rebalance_drains: d
                            .opt_u64("sched.placement.rebalance_drains")
                            .unwrap_or(def.placement.rebalance_drains as u64)
                            as u32,
                    },
                    chain: ChainConfig {
                        max_links: d
                            .opt_u64("sched.chain.max_links")
                            .unwrap_or(def.chain.max_links as u64)
                            as u32,
                    },
                    dag: DagConfig {
                        max_nodes: d
                            .opt_u64("sched.dag.max_nodes")
                            .unwrap_or(def.dag.max_nodes as u64)
                            as u32,
                        max_width: d
                            .opt_u64("sched.dag.max_width")
                            .unwrap_or(def.dag.max_width as u64)
                            as u32,
                        max_depth: d
                            .opt_u64("sched.dag.max_depth")
                            .unwrap_or(def.dag.max_depth as u64)
                            as u32,
                        fuse_window_ms: d
                            .opt_u64("sched.dag.fuse_window_ms")
                            .unwrap_or(def.dag.fuse_window_ms),
                    },
                    fault: FaultConfig {
                        enabled: d
                            .opt_bool("sched.fault.enabled")
                            .unwrap_or(def.fault.enabled),
                        seed: d.opt_u64("sched.fault.seed").unwrap_or(def.fault.seed),
                        staging_rate: d
                            .opt_f64("sched.fault.staging_rate")
                            .unwrap_or(def.fault.staging_rate),
                        mailbox_rate: d
                            .opt_f64("sched.fault.mailbox_rate")
                            .unwrap_or(def.fault.mailbox_rate),
                        poison_rate: d
                            .opt_f64("sched.fault.poison_rate")
                            .unwrap_or(def.fault.poison_rate),
                        target_cluster: d
                            .opt_i64("sched.fault.target_cluster")
                            .unwrap_or(def.fault.target_cluster),
                        deadline_factor: d
                            .opt_f64("sched.fault.deadline_factor")
                            .unwrap_or(def.fault.deadline_factor),
                        max_attempts: d
                            .opt_u64("sched.fault.max_attempts")
                            .unwrap_or(def.fault.max_attempts as u64)
                            as u32,
                        backoff_base_ms: d
                            .opt_u64("sched.fault.backoff_base_ms")
                            .unwrap_or(def.fault.backoff_base_ms),
                        quarantine_threshold: d
                            .opt_u64("sched.fault.quarantine_threshold")
                            .unwrap_or(def.fault.quarantine_threshold as u64)
                            as u32,
                        probe_interval: d
                            .opt_u64("sched.fault.probe_interval")
                            .unwrap_or(def.fault.probe_interval),
                    },
                    trace: TraceConfig {
                        enabled: d
                            .opt_bool("sched.trace.enabled")
                            .unwrap_or(def.trace.enabled),
                        ring_capacity: d
                            .opt_u64("sched.trace.ring_capacity")
                            .unwrap_or(def.trace.ring_capacity),
                        watch_interval_ms: d
                            .opt_u64("sched.trace.watch_interval_ms")
                            .unwrap_or(def.trace.watch_interval_ms),
                    },
                }
            },
            // Cost-model knobs are estimation policy, not SoC calibration
            // — like [sched] they default when absent.
            cost: {
                let def = CostConfig::default();
                CostConfig {
                    calibrate: d.opt_bool("cost.calibrate").unwrap_or(def.calibrate),
                    alpha: d.opt_f64("cost.alpha").unwrap_or(def.alpha),
                    floor: d.opt_f64("cost.floor").unwrap_or(def.floor),
                    ceiling: d.opt_f64("cost.ceiling").unwrap_or(def.ceiling),
                }
            },
            // Kernel-registry knobs are dispatch policy (specialization
            // never changes numerics) — like [sched] they default when
            // absent.
            kernel: {
                let def = KernelConfig::default();
                KernelConfig {
                    enabled: d.opt_bool("kernel.enabled").unwrap_or(def.enabled),
                    promote_after: d
                        .opt_u64("kernel.promote_after")
                        .unwrap_or(def.promote_after as u64)
                        as u32,
                    max_entries: d
                        .opt_u64("kernel.max_entries")
                        .unwrap_or(def.max_entries as u64)
                        as u32,
                    prewarm: d.opt_bool("kernel.prewarm").unwrap_or(def.prewarm),
                }
            },
            // Serve-layer knobs are front-end policy; they default too.
            serve: {
                let def = ServeConfig::default();
                ServeConfig {
                    reply_timeout_ms: d
                        .opt_u64("serve.reply_timeout_ms")
                        .unwrap_or(def.reply_timeout_ms),
                }
            },
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Render as TOML (inverse of [`PlatformConfig::from_toml_str`]).
    pub fn to_toml_string(&self) -> String {
        let c = self;
        format!(
            "name = \"{}\"\n\n\
             [clock]\nfreq_hz = {}\n\n\
             [host]\nflops_per_cycle = {}\ncopy_bytes_per_cycle = {}\n\
             memcpy_setup_cycles = {}\nf32_speedup = {}\n\n\
             [cluster]\nclusters = {}\ncores = {}\nfma_per_core_per_cycle = {}\n\
             efficiency = {}\nf32_speedup = {}\n\n\
             [memory]\nl1_spm_bytes = {}\nl2_spm_bytes = {}\ndev_dram_bytes = {}\n\
             l1_spm_base = 0x{:x}\nl2_spm_base = 0x{:x}\ndev_dram_base = 0x{:x}\n\n\
             [dma]\nbytes_per_cycle = {}\nsetup_cycles = {}\nper_row_cycles = {}\n\n\
             [forkjoin]\nopenblas_entry_cycles = {}\nomp_entry_cycles = {}\n\
             per_arg_cycles = {}\ndoorbell_cycles = {}\ndevice_wakeup_cycles = {}\n\
             join_cycles = {}\nexit_cycles = {}\n\n\
             [iommu]\npage_bytes = {}\npte_create_cycles = {}\niotlb_entries = {}\n\
             iotlb_miss_cycles = {}\npte_teardown_cycles = {}\n\n\
             [sched]\npool_clusters = {}\nqueue_capacity = {}\n\
             batch_window_ms = {}\nbatch_max = {}\n\n\
             [sched.cache]\ncache_frac = {}\ncache_max_entries = {}\n\
             pipeline_depth = {}\n\n\
             [sched.placement]\naffinity = {}\nsteal = {}\n\
             big_shape_frac = {}\nrebalance_drains = {}\n\n\
             [sched.chain]\nmax_links = {}\n\n\
             [sched.dag]\nmax_nodes = {}\nmax_width = {}\nmax_depth = {}\n\
             fuse_window_ms = {}\n\n\
             [sched.fault]\nenabled = {}\nseed = {}\nstaging_rate = {}\n\
             mailbox_rate = {}\npoison_rate = {}\ntarget_cluster = {}\n\
             deadline_factor = {}\nmax_attempts = {}\nbackoff_base_ms = {}\n\
             quarantine_threshold = {}\nprobe_interval = {}\n\n\
             [sched.trace]\nenabled = {}\nring_capacity = {}\n\
             watch_interval_ms = {}\n\n\
             [cost]\ncalibrate = {}\nalpha = {}\nfloor = {}\nceiling = {}\n\n\
             [kernel]\nenabled = {}\npromote_after = {}\nmax_entries = {}\n\
             prewarm = {}\n\n\
             [serve]\nreply_timeout_ms = {}\n",
            c.name,
            c.clock.freq_hz,
            fmt_f64(c.host.flops_per_cycle),
            fmt_f64(c.host.copy_bytes_per_cycle),
            c.host.memcpy_setup_cycles,
            fmt_f64(c.host.f32_speedup),
            c.cluster.clusters,
            c.cluster.cores,
            fmt_f64(c.cluster.fma_per_core_per_cycle),
            fmt_f64(c.cluster.efficiency),
            fmt_f64(c.cluster.f32_speedup),
            c.memory.l1_spm_bytes,
            c.memory.l2_spm_bytes,
            c.memory.dev_dram_bytes,
            c.memory.l1_spm_base,
            c.memory.l2_spm_base,
            c.memory.dev_dram_base,
            fmt_f64(c.dma.bytes_per_cycle),
            c.dma.setup_cycles,
            c.dma.per_row_cycles,
            c.forkjoin.openblas_entry_cycles,
            c.forkjoin.omp_entry_cycles,
            c.forkjoin.per_arg_cycles,
            c.forkjoin.doorbell_cycles,
            c.forkjoin.device_wakeup_cycles,
            c.forkjoin.join_cycles,
            c.forkjoin.exit_cycles,
            c.iommu.page_bytes,
            c.iommu.pte_create_cycles,
            c.iommu.iotlb_entries,
            c.iommu.iotlb_miss_cycles,
            c.iommu.pte_teardown_cycles,
            c.sched.pool_clusters,
            c.sched.queue_capacity,
            c.sched.batch_window_ms,
            c.sched.batch_max,
            fmt_f64(c.sched.cache.cache_frac),
            c.sched.cache.cache_max_entries,
            c.sched.cache.pipeline_depth,
            c.sched.placement.affinity,
            c.sched.placement.steal,
            fmt_f64(c.sched.placement.big_shape_frac),
            c.sched.placement.rebalance_drains,
            c.sched.chain.max_links,
            c.sched.dag.max_nodes,
            c.sched.dag.max_width,
            c.sched.dag.max_depth,
            c.sched.dag.fuse_window_ms,
            c.sched.fault.enabled,
            c.sched.fault.seed,
            fmt_f64(c.sched.fault.staging_rate),
            fmt_f64(c.sched.fault.mailbox_rate),
            fmt_f64(c.sched.fault.poison_rate),
            c.sched.fault.target_cluster,
            fmt_f64(c.sched.fault.deadline_factor),
            c.sched.fault.max_attempts,
            c.sched.fault.backoff_base_ms,
            c.sched.fault.quarantine_threshold,
            c.sched.fault.probe_interval,
            c.sched.trace.enabled,
            c.sched.trace.ring_capacity,
            c.sched.trace.watch_interval_ms,
            c.cost.calibrate,
            fmt_f64(c.cost.alpha),
            fmt_f64(c.cost.floor),
            fmt_f64(c.cost.ceiling),
            c.kernel.enabled,
            c.kernel.promote_after,
            c.kernel.max_entries,
            c.kernel.prewarm,
            c.serve.reply_timeout_ms,
        )
    }

    /// Reject physically meaningless configurations early.
    pub fn validate(&self) -> Result<()> {
        let err = |m: String| Err(Error::Config(m));
        if self.clock.freq_hz == 0 {
            return err("clock.freq_hz must be > 0".into());
        }
        if self.host.flops_per_cycle <= 0.0 || self.host.copy_bytes_per_cycle <= 0.0 {
            return err("host throughputs must be > 0".into());
        }
        if self.cluster.cores == 0 {
            return err("cluster.cores must be > 0".into());
        }
        if self.cluster.clusters == 0 {
            return err("cluster.clusters must be > 0".into());
        }
        if !(0.0..=1.0).contains(&self.cluster.efficiency) || self.cluster.efficiency == 0.0 {
            return err(format!(
                "cluster.efficiency must be in (0, 1], got {}",
                self.cluster.efficiency
            ));
        }
        if self.memory.l1_spm_bytes < 3 * 64 * 64 * 8 {
            return err(format!(
                "l1_spm_bytes={} cannot hold one f64 tile set (needs >= {})",
                self.memory.l1_spm_bytes,
                3 * 64 * 64 * 8
            ));
        }
        if !self.iommu.page_bytes.is_power_of_two() {
            return err("iommu.page_bytes must be a power of two".into());
        }
        if self.dma.bytes_per_cycle <= 0.0 {
            return err("dma.bytes_per_cycle must be > 0".into());
        }
        if self.sched.pool_clusters == 0 || self.sched.pool_clusters > 64 {
            return err(format!(
                "sched.pool_clusters must be in 1..=64, got {}",
                self.sched.pool_clusters
            ));
        }
        if self.sched.queue_capacity == 0 {
            return err("sched.queue_capacity must be > 0".into());
        }
        if self.sched.batch_max == 0 {
            return err("sched.batch_max must be > 0 (1 disables batching)".into());
        }
        if !(0.0..=0.9).contains(&self.sched.cache.cache_frac) {
            return err(format!(
                "sched.cache.cache_frac must be in [0, 0.9], got {}",
                self.sched.cache.cache_frac
            ));
        }
        if self.sched.cache.pipeline_depth == 0 || self.sched.cache.pipeline_depth > 8 {
            return err(format!(
                "sched.cache.pipeline_depth must be in 1..=8, got {}",
                self.sched.cache.pipeline_depth
            ));
        }
        if self.sched.chain.max_links == 0 || self.sched.chain.max_links > 32 {
            return err(format!(
                "sched.chain.max_links must be in 1..=32, got {}",
                self.sched.chain.max_links
            ));
        }
        let dg = &self.sched.dag;
        if dg.max_nodes == 0 || dg.max_nodes > 64 {
            return err(format!(
                "sched.dag.max_nodes must be in 1..=64, got {}",
                dg.max_nodes
            ));
        }
        if dg.max_width == 0 || dg.max_width > 16 {
            return err(format!(
                "sched.dag.max_width must be in 1..=16, got {}",
                dg.max_width
            ));
        }
        if dg.max_depth == 0 || dg.max_depth > 32 {
            return err(format!(
                "sched.dag.max_depth must be in 1..=32, got {}",
                dg.max_depth
            ));
        }
        if dg.fuse_window_ms > 10_000 {
            return err(format!(
                "sched.dag.fuse_window_ms must be <= 10000 (0 disables \
                 fusion), got {}",
                dg.fuse_window_ms
            ));
        }
        if !(0.0..=0.97).contains(&self.sched.placement.big_shape_frac) {
            return err(format!(
                "sched.placement.big_shape_frac must be in [0, 0.97], got {}",
                self.sched.placement.big_shape_frac
            ));
        }
        let f = &self.sched.fault;
        for (name, rate) in [
            ("staging_rate", f.staging_rate),
            ("mailbox_rate", f.mailbox_rate),
            ("poison_rate", f.poison_rate),
        ] {
            if !(0.0..=1.0).contains(&rate) {
                return err(format!(
                    "sched.fault.{name} must be in [0, 1], got {rate}"
                ));
            }
        }
        if f.target_cluster < -1 || f.target_cluster >= 64 {
            return err(format!(
                "sched.fault.target_cluster must be -1 (all) or a cluster id \
                 in 0..64, got {}",
                f.target_cluster
            ));
        }
        if f.deadline_factor < 1.0 {
            return err(format!(
                "sched.fault.deadline_factor must be >= 1, got {}",
                f.deadline_factor
            ));
        }
        if f.max_attempts == 0 || f.max_attempts > 8 {
            return err(format!(
                "sched.fault.max_attempts must be in 1..=8, got {}",
                f.max_attempts
            ));
        }
        if f.quarantine_threshold == 0 {
            return err("sched.fault.quarantine_threshold must be > 0".into());
        }
        if f.probe_interval == 0 {
            return err("sched.fault.probe_interval must be > 0".into());
        }
        let t = &self.sched.trace;
        if !(64..=1_048_576).contains(&t.ring_capacity) {
            return err(format!(
                "sched.trace.ring_capacity must be in 64..=1048576 (one ring \
                 per cluster plus the global track), got {}",
                t.ring_capacity
            ));
        }
        if t.watch_interval_ms == 0 || t.watch_interval_ms > 60_000 {
            return err(format!(
                "sched.trace.watch_interval_ms must be in 1..=60000, got {}",
                t.watch_interval_ms
            ));
        }
        if self.serve.reply_timeout_ms == 0 {
            return err("serve.reply_timeout_ms must be > 0".into());
        }
        if !(self.cost.alpha > 0.0 && self.cost.alpha <= 1.0) {
            return err(format!(
                "cost.alpha must be in (0, 1], got {}",
                self.cost.alpha
            ));
        }
        if !(self.cost.floor > 0.0 && self.cost.floor <= 1.0) {
            return err(format!(
                "cost.floor must be in (0, 1], got {}",
                self.cost.floor
            ));
        }
        if self.cost.ceiling < 1.0 {
            return err(format!(
                "cost.ceiling must be >= 1, got {}",
                self.cost.ceiling
            ));
        }
        if self.kernel.promote_after == 0 || self.kernel.promote_after > 65_536 {
            return err(format!(
                "kernel.promote_after must be in 1..=65536, got {}",
                self.kernel.promote_after
            ));
        }
        if self.kernel.max_entries == 0 || self.kernel.max_entries > 4_096 {
            return err(format!(
                "kernel.max_entries must be in 1..=4096, got {}",
                self.kernel.max_entries
            ));
        }
        // One capacity model: request-level pool clusters x intra-offload
        // compute clusters.  Cap the product so a typo'd pool cannot fan
        // out into thousands of simulated tiles.
        if self.sched.pool_clusters as u64 * self.cluster.clusters as u64 > 256 {
            return err(format!(
                "sched.pool_clusters ({}) x cluster.clusters ({}) exceeds the \
                 256-tile capacity model",
                self.sched.pool_clusters, self.cluster.clusters
            ));
        }
        // Address-map regions must not overlap.
        let m = &self.memory;
        let regions = [
            (m.l1_spm_base, m.l1_spm_bytes, "l1_spm"),
            (m.l2_spm_base, m.l2_spm_bytes, "l2_spm"),
            (m.dev_dram_base, m.dev_dram_bytes, "dev_dram"),
        ];
        for (i, a) in regions.iter().enumerate() {
            for b in regions.iter().skip(i + 1) {
                let (ab, asz, an) = *a;
                let (bb, bsz, bn) = *b;
                if ab < bb + bsz && bb < ab + asz {
                    return err(format!("memory regions {an} and {bn} overlap"));
                }
            }
        }
        Ok(())
    }

    /// Peak cluster FLOP/cycle for a dtype (FMA counts as 2 FLOPs).
    pub fn cluster_peak_flops_per_cycle(&self, f32_path: bool) -> f64 {
        let base =
            self.cluster.cores as f64 * self.cluster.fma_per_core_per_cycle * 2.0;
        if f32_path {
            base * self.cluster.f32_speedup
        } else {
            base
        }
    }

    /// Nanoseconds for a cycle count on the shared clock.
    pub fn cycles_to_ns(&self, cycles: f64) -> f64 {
        cycles * 1e9 / self.clock.freq_hz as f64
    }
}

/// Format an f64 so toml_lite reads it back as a float (always a '.').
fn fmt_f64(v: f64) -> String {
    if v.fract() == 0.0 {
        format!("{v:.1}")
    } else {
        format!("{v}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        PlatformConfig::default().validate().unwrap();
    }

    #[test]
    fn rejects_zero_freq() {
        let mut cfg = PlatformConfig::default();
        cfg.clock.freq_hz = 0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn rejects_bad_efficiency() {
        let mut cfg = PlatformConfig::default();
        cfg.cluster.efficiency = 1.5;
        assert!(cfg.validate().is_err());
        cfg.cluster.efficiency = 0.0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn rejects_tiny_spm() {
        let mut cfg = PlatformConfig::default();
        cfg.memory.l1_spm_bytes = 1024;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn rejects_overlapping_regions() {
        let mut cfg = PlatformConfig::default();
        cfg.memory.l2_spm_base = cfg.memory.dev_dram_base;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn peak_flops() {
        let cfg = PlatformConfig::default();
        assert_eq!(cfg.cluster_peak_flops_per_cycle(false), 16.0);
        assert_eq!(cfg.cluster_peak_flops_per_cycle(true), 32.0);
    }

    #[test]
    fn cycles_to_ns_at_50mhz() {
        let cfg = PlatformConfig::default();
        assert_eq!(cfg.cycles_to_ns(1.0), 20.0);
        assert_eq!(cfg.cycles_to_ns(50_000_000.0), 1e9);
    }

    #[test]
    fn toml_roundtrip() {
        let cfg = PlatformConfig::default();
        let text = cfg.to_toml_string();
        let back = PlatformConfig::from_toml_str(&text).unwrap();
        assert_eq!(cfg, back);
    }

    #[test]
    fn sched_section_defaults_when_absent() {
        let mut text = PlatformConfig::default().to_toml_string();
        let at = text.find("[sched]").unwrap();
        text.truncate(at);
        let cfg = PlatformConfig::from_toml_str(&text).unwrap();
        assert_eq!(cfg.sched, SchedConfig::default());
    }

    #[test]
    fn rejects_bad_sched() {
        let mut cfg = PlatformConfig::default();
        cfg.sched.pool_clusters = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = PlatformConfig::default();
        cfg.sched.queue_capacity = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = PlatformConfig::default();
        cfg.sched.batch_max = 0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn cache_section_parses_defaults_and_validates() {
        // absent [sched.cache] => defaults (cache off, pipeline serial)
        let mut text = PlatformConfig::default().to_toml_string();
        let at = text.find("[sched.cache]").unwrap();
        text.truncate(at);
        let cfg = PlatformConfig::from_toml_str(&text).unwrap();
        assert_eq!(cfg.sched.cache, CacheConfig::default());
        assert!(!cfg.sched.cache.cache_enabled());
        assert!(!cfg.sched.cache.pipelined());

        // explicit values round-trip
        let mut cfg = PlatformConfig::default();
        cfg.sched.cache.cache_frac = 0.25;
        cfg.sched.cache.cache_max_entries = 16;
        cfg.sched.cache.pipeline_depth = 2;
        let back = PlatformConfig::from_toml_str(&cfg.to_toml_string()).unwrap();
        assert_eq!(back.sched.cache, cfg.sched.cache);
        assert!(back.sched.cache.cache_enabled());
        assert!(back.sched.cache.pipelined());

        // out-of-range knobs rejected
        let mut cfg = PlatformConfig::default();
        cfg.sched.cache.cache_frac = 0.95;
        assert!(cfg.validate().is_err());
        let mut cfg = PlatformConfig::default();
        cfg.sched.cache.cache_frac = -0.1;
        assert!(cfg.validate().is_err());
        let mut cfg = PlatformConfig::default();
        cfg.sched.cache.pipeline_depth = 0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn placement_section_parses_defaults_and_validates() {
        // absent [sched.placement] => defaults (affinity+steal on, even split)
        let mut text = PlatformConfig::default().to_toml_string();
        let at = text.find("[sched.placement]").unwrap();
        text.truncate(at);
        let cfg = PlatformConfig::from_toml_str(&text).unwrap();
        assert_eq!(cfg.sched.placement, PlacementConfig::default());
        assert!(cfg.sched.placement.affinity && cfg.sched.placement.steal);
        assert!(!cfg.sched.placement.big_lane(4), "frac 0 keeps the even split");

        // explicit values round-trip
        let mut cfg = PlatformConfig::default();
        cfg.sched.placement.affinity = false;
        cfg.sched.placement.steal = false;
        cfg.sched.placement.big_shape_frac = 0.5;
        let back = PlatformConfig::from_toml_str(&cfg.to_toml_string()).unwrap();
        assert_eq!(back.sched.placement, cfg.sched.placement);
        assert!(back.sched.placement.big_lane(4));
        assert!(!back.sched.placement.big_lane(1), "pool of 1 has no big lane");

        // out-of-range knobs rejected
        let mut cfg = PlatformConfig::default();
        cfg.sched.placement.big_shape_frac = 0.99;
        assert!(cfg.validate().is_err());
        let mut cfg = PlatformConfig::default();
        cfg.sched.placement.big_shape_frac = -0.1;
        assert!(cfg.validate().is_err());
        // capacity-model product bound
        let mut cfg = PlatformConfig::default();
        cfg.sched.pool_clusters = 64;
        cfg.cluster.clusters = 8;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn chain_section_parses_defaults_and_validates() {
        // absent [sched.chain] => defaults
        let mut text = PlatformConfig::default().to_toml_string();
        let at = text.find("[sched.chain]").unwrap();
        text.truncate(at);
        let cfg = PlatformConfig::from_toml_str(&text).unwrap();
        assert_eq!(cfg.sched.chain, ChainConfig::default());
        assert_eq!(cfg.sched.chain.max_links, 8);

        // explicit values round-trip
        let mut cfg = PlatformConfig::default();
        cfg.sched.chain.max_links = 16;
        let back = PlatformConfig::from_toml_str(&cfg.to_toml_string()).unwrap();
        assert_eq!(back.sched.chain.max_links, 16);

        // out-of-range knobs rejected (0 would wedge every chain submit,
        // >32 would let one request stage an unbounded spec)
        let mut cfg = PlatformConfig::default();
        cfg.sched.chain.max_links = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = PlatformConfig::default();
        cfg.sched.chain.max_links = 33;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn dag_section_parses_defaults_and_validates() {
        // absent [sched.dag] => defaults
        let mut text = PlatformConfig::default().to_toml_string();
        let at = text.find("[sched.dag]").unwrap();
        text.truncate(at);
        let cfg = PlatformConfig::from_toml_str(&text).unwrap();
        assert_eq!(cfg.sched.dag, DagConfig::default());
        assert_eq!(cfg.sched.dag.max_nodes, 16);
        assert_eq!(cfg.sched.dag.max_width, 4);
        assert_eq!(cfg.sched.dag.max_depth, 8);
        assert_eq!(cfg.sched.dag.fuse_window_ms, 50);

        // explicit values round-trip (fuse_window_ms = 0 disables fusion)
        let mut cfg = PlatformConfig::default();
        cfg.sched.dag.max_nodes = 32;
        cfg.sched.dag.max_width = 8;
        cfg.sched.dag.max_depth = 16;
        cfg.sched.dag.fuse_window_ms = 0;
        let back = PlatformConfig::from_toml_str(&cfg.to_toml_string()).unwrap();
        assert_eq!(back.sched.dag, cfg.sched.dag);

        // out-of-range knobs rejected
        for mutate in [
            (|c: &mut PlatformConfig| c.sched.dag.max_nodes = 0) as fn(&mut _),
            |c| c.sched.dag.max_nodes = 65,
            |c| c.sched.dag.max_width = 0,
            |c| c.sched.dag.max_width = 17,
            |c| c.sched.dag.max_depth = 0,
            |c| c.sched.dag.max_depth = 33,
            |c| c.sched.dag.fuse_window_ms = 10_001,
        ] {
            let mut cfg = PlatformConfig::default();
            mutate(&mut cfg);
            assert!(cfg.validate().is_err());
        }
    }

    #[test]
    fn fault_section_parses_defaults_and_validates() {
        // absent [sched.fault] => defaults (injection off)
        let mut text = PlatformConfig::default().to_toml_string();
        let at = text.find("[sched.fault]").unwrap();
        text.truncate(at);
        let cfg = PlatformConfig::from_toml_str(&text).unwrap();
        assert_eq!(cfg.sched.fault, FaultConfig::default());
        assert!(!cfg.sched.fault.enabled);

        // explicit values round-trip (including a negative target)
        let mut cfg = PlatformConfig::default();
        cfg.sched.fault.enabled = true;
        cfg.sched.fault.seed = 7;
        cfg.sched.fault.staging_rate = 0.25;
        cfg.sched.fault.mailbox_rate = 0.1;
        cfg.sched.fault.poison_rate = 1.0;
        cfg.sched.fault.target_cluster = 2;
        cfg.sched.fault.deadline_factor = 8.0;
        cfg.sched.fault.max_attempts = 5;
        cfg.sched.fault.backoff_base_ms = 2;
        cfg.sched.fault.quarantine_threshold = 1;
        cfg.sched.fault.probe_interval = 4;
        let back = PlatformConfig::from_toml_str(&cfg.to_toml_string()).unwrap();
        assert_eq!(back.sched.fault, cfg.sched.fault);
        cfg.sched.fault.target_cluster = -1;
        let back = PlatformConfig::from_toml_str(&cfg.to_toml_string()).unwrap();
        assert_eq!(back.sched.fault.target_cluster, -1);

        // out-of-range knobs rejected
        let mut cfg = PlatformConfig::default();
        cfg.sched.fault.staging_rate = 1.5;
        assert!(cfg.validate().is_err());
        let mut cfg = PlatformConfig::default();
        cfg.sched.fault.poison_rate = -0.1;
        assert!(cfg.validate().is_err());
        let mut cfg = PlatformConfig::default();
        cfg.sched.fault.target_cluster = -2;
        assert!(cfg.validate().is_err());
        let mut cfg = PlatformConfig::default();
        cfg.sched.fault.deadline_factor = 0.5;
        assert!(cfg.validate().is_err());
        let mut cfg = PlatformConfig::default();
        cfg.sched.fault.max_attempts = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = PlatformConfig::default();
        cfg.sched.fault.quarantine_threshold = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = PlatformConfig::default();
        cfg.sched.fault.probe_interval = 0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn trace_section_parses_defaults_and_validates() {
        // absent [sched.trace] => defaults (recorder ON)
        let mut text = PlatformConfig::default().to_toml_string();
        let at = text.find("[sched.trace]").unwrap();
        text.truncate(at);
        let cfg = PlatformConfig::from_toml_str(&text).unwrap();
        assert_eq!(cfg.sched.trace, TraceConfig::default());
        assert!(cfg.sched.trace.enabled, "the flight recorder defaults ON");

        // explicit values round-trip
        let mut cfg = PlatformConfig::default();
        cfg.sched.trace.enabled = false;
        cfg.sched.trace.ring_capacity = 128;
        cfg.sched.trace.watch_interval_ms = 50;
        let back = PlatformConfig::from_toml_str(&cfg.to_toml_string()).unwrap();
        assert_eq!(back.sched.trace, cfg.sched.trace);

        // out-of-range knobs rejected
        let mut cfg = PlatformConfig::default();
        cfg.sched.trace.ring_capacity = 16;
        assert!(cfg.validate().is_err());
        let mut cfg = PlatformConfig::default();
        cfg.sched.trace.ring_capacity = 2_000_000;
        assert!(cfg.validate().is_err());
        let mut cfg = PlatformConfig::default();
        cfg.sched.trace.watch_interval_ms = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = PlatformConfig::default();
        cfg.sched.trace.watch_interval_ms = 120_000;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn serve_section_parses_defaults_and_validates() {
        // absent [serve] => default reply timeout
        let mut text = PlatformConfig::default().to_toml_string();
        let at = text.find("[serve]").unwrap();
        text.truncate(at);
        let cfg = PlatformConfig::from_toml_str(&text).unwrap();
        assert_eq!(cfg.serve, ServeConfig::default());
        assert_eq!(cfg.serve.reply_timeout_ms, 300_000);

        // explicit value round-trips
        let mut cfg = PlatformConfig::default();
        cfg.serve.reply_timeout_ms = 1_500;
        let back = PlatformConfig::from_toml_str(&cfg.to_toml_string()).unwrap();
        assert_eq!(back.serve.reply_timeout_ms, 1_500);

        // zero rejected (a zero timeout cancels every request instantly)
        let mut cfg = PlatformConfig::default();
        cfg.serve.reply_timeout_ms = 0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn cost_section_parses_defaults_and_validates() {
        // absent [cost] => defaults (calibration off)
        let mut text = PlatformConfig::default().to_toml_string();
        let at = text.find("[cost]").unwrap();
        text.truncate(at);
        let cfg = PlatformConfig::from_toml_str(&text).unwrap();
        assert_eq!(cfg.cost, CostConfig::default());
        assert!(!cfg.cost.calibrate);

        // explicit values round-trip
        let mut cfg = PlatformConfig::default();
        cfg.cost.calibrate = true;
        cfg.cost.alpha = 0.25;
        cfg.cost.floor = 0.5;
        cfg.cost.ceiling = 2.0;
        cfg.sched.placement.rebalance_drains = 4;
        let back = PlatformConfig::from_toml_str(&cfg.to_toml_string()).unwrap();
        assert_eq!(back.cost, cfg.cost);
        assert_eq!(back.sched.placement.rebalance_drains, 4);

        // out-of-range knobs rejected
        let mut cfg = PlatformConfig::default();
        cfg.cost.alpha = 0.0;
        assert!(cfg.validate().is_err());
        let mut cfg = PlatformConfig::default();
        cfg.cost.alpha = 1.5;
        assert!(cfg.validate().is_err());
        let mut cfg = PlatformConfig::default();
        cfg.cost.floor = 0.0;
        assert!(cfg.validate().is_err());
        let mut cfg = PlatformConfig::default();
        cfg.cost.ceiling = 0.5;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn kernel_section_parses_defaults_and_validates() {
        // absent [kernel] => defaults (registry ON, prewarm off)
        let mut text = PlatformConfig::default().to_toml_string();
        let at = text.find("[kernel]").unwrap();
        text.truncate(at);
        let cfg = PlatformConfig::from_toml_str(&text).unwrap();
        assert_eq!(cfg.kernel, KernelConfig::default());
        assert!(cfg.kernel.enabled, "specialization defaults ON");
        assert!(!cfg.kernel.prewarm);

        // explicit values round-trip
        let mut cfg = PlatformConfig::default();
        cfg.kernel.enabled = false;
        cfg.kernel.promote_after = 4;
        cfg.kernel.max_entries = 8;
        cfg.kernel.prewarm = true;
        let back = PlatformConfig::from_toml_str(&cfg.to_toml_string()).unwrap();
        assert_eq!(back.kernel, cfg.kernel);

        // out-of-range knobs rejected (promote_after 0 would promote a
        // never-launched key, max_entries 0 would wedge every insert)
        let mut cfg = PlatformConfig::default();
        cfg.kernel.promote_after = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = PlatformConfig::default();
        cfg.kernel.promote_after = 100_000;
        assert!(cfg.validate().is_err());
        let mut cfg = PlatformConfig::default();
        cfg.kernel.max_entries = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = PlatformConfig::default();
        cfg.kernel.max_entries = 5_000;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn toml_missing_field_names_path() {
        let text = PlatformConfig::default()
            .to_toml_string()
            .replace("pte_create_cycles = 2025\n", "");
        let err = PlatformConfig::from_toml_str(&text).unwrap_err().to_string();
        assert!(err.contains("iommu.pte_create_cycles"), "{err}");
    }
}
