//! Platform and workload configuration.
//!
//! The platform description ([`PlatformConfig`]) is the single source of
//! truth for every timing constant in the SoC model; it mirrors the
//! Cheshire/Carfield instance of the paper (CVA6 host @ 50 MHz on a
//! VCU128, one 8-core Snitch cluster with 128 KiB L1 SPM).  All constants
//! are calibrated against the paper's Figure 3 / Results section — see
//! `configs/carfield.toml` for the per-constant rationale.

mod platform;
mod workload;

pub use platform::{
    CacheConfig, ChainConfig, ClockConfig, ClusterConfig, CostConfig,
    DagConfig, DmaConfig, FaultConfig, ForkJoinConfig, HostConfig, IommuConfig,
    KernelConfig, MemoryConfig, PlacementConfig, PlatformConfig, SchedConfig,
    ServeConfig, TraceConfig,
};
pub use workload::{DispatchMode, SweepConfig, WorkloadConfig};
