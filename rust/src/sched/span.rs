//! Request-scoped serving-path spans.
//!
//! `soc/trace.rs` records the paper's Figure-3 regions (data-copy /
//! fork-join / compute) *inside* one offload against the virtual clock.
//! This module generalizes that idea to the whole serving path: every
//! [`crate::sched::Job`] carries wall-clock [`SpanStamps`] that the
//! ingress queue, the placement router and the batcher fill in as the
//! job moves through them, and the worker closes the record with the
//! batch-level stage/execute/finish marks.  The result is one
//! [`SpanBreakdown`] per request:
//!
//! ```text
//! queue -> route -> (linger) -> stage -> execute -> finish
//! ```
//!
//! * **queue**   — enqueued in the bounded ingress queue, waiting for the
//!   router's drain pass to pick it up;
//! * **route**   — routed onto a cluster's run queue, waiting for a
//!   worker (local drain, steal or batch peel) to claim it;
//! * **stage**   — claimed by a worker: batch assembly (the linger
//!   window, reported separately as `linger_us`) plus operand staging;
//! * **execute** — the fork-join launch until device completion is
//!   observed (under software pipelining this window overlaps the next
//!   batch's stage span — per *request* the spans stay disjoint);
//! * **finish**  — copy-out, accounting and the reply send.
//!
//! Durations are derived from adjacent timestamps, so the five named
//! stages telescope: `queue + route + stage + execute + finish` equals
//! the reported `total_us` *exactly* by construction (the `trace: true`
//! serve contract).

use std::time::{Duration, Instant};

/// Wall-clock progress stamps carried on a [`crate::sched::Job`].
///
/// `Default` (both `None`) means "not yet stamped"; the breakdown
/// computation degrades gracefully by collapsing missing stages to zero
/// width, so fence acks and synthetic test jobs never panic.
#[derive(Debug, Clone, Copy, Default)]
pub struct SpanStamps {
    /// Popped off the ingress queue and routed onto a cluster run queue.
    pub routed_at: Option<Instant>,
    /// Claimed by a worker (local drain, steal, orphan adoption or
    /// batch peel).
    pub claimed_at: Option<Instant>,
}

impl SpanStamps {
    /// Stamp the queue->route boundary (first stamp wins — a job is
    /// routed once).
    pub fn mark_routed(&mut self) {
        if self.routed_at.is_none() {
            self.routed_at = Some(Instant::now());
        }
    }

    /// Stamp the route->worker boundary (first stamp wins).
    pub fn mark_claimed(&mut self) {
        if self.claimed_at.is_none() {
            self.claimed_at = Some(Instant::now());
        }
    }
}

/// Batch-level timestamps the worker records once per fork-join launch;
/// combined with each member's [`SpanStamps`] they close the per-request
/// record.
#[derive(Debug, Clone, Copy)]
pub struct BatchMarks {
    /// Batch assembly (linger) done; operand staging begins.
    pub collected_at: Instant,
    /// Fork-join launch issued (stage span ends).
    pub exec_at: Instant,
    /// Device completion observed (finish span begins).
    pub done_at: Instant,
}

impl BatchMarks {
    /// All three marks at one instant — for synchronous host-path jobs
    /// whose stage/execute windows are measured separately.
    pub fn at(t: Instant) -> BatchMarks {
        BatchMarks { collected_at: t, exec_at: t, done_at: t }
    }
}

/// One request's serving-path breakdown, in wall-clock microseconds.
///
/// Invariant: `queue_us + route_us + stage_us + execute_us + finish_us
/// == total_us` (exactly; `total_us` is defined as that sum).
/// `linger_us` is the leading portion of `stage_us` spent in the
/// batcher's linger window — informational, never added twice.
/// `retry_us` is likewise outside the telescoping sum: it is the wall
/// time earlier *failed* device attempts consumed before this job was
/// requeued (fault recovery) — the five stages describe only the
/// attempt that replied.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SpanBreakdown {
    pub queue_us: u64,
    pub route_us: u64,
    pub linger_us: u64,
    pub retry_us: u64,
    pub stage_us: u64,
    pub execute_us: u64,
    pub finish_us: u64,
    pub total_us: u64,
}

impl SpanBreakdown {
    /// Close one member's record: adjacent-timestamp differences, with
    /// missing stamps collapsed onto the previous boundary so the
    /// telescoping sum always holds.
    pub fn compute(
        enqueued_at: Instant,
        stamps: SpanStamps,
        marks: BatchMarks,
        end: Instant,
    ) -> SpanBreakdown {
        let us = |d: Duration| d.as_micros() as u64;
        let routed = stamps.routed_at.unwrap_or(enqueued_at);
        let claimed = stamps.claimed_at.unwrap_or(routed);
        let queue_us = us(routed.saturating_duration_since(enqueued_at));
        let route_us = us(claimed.saturating_duration_since(routed));
        let linger_us = us(marks.collected_at.saturating_duration_since(claimed));
        let stage_us = us(marks.exec_at.saturating_duration_since(claimed));
        let execute_us = us(marks.done_at.saturating_duration_since(marks.exec_at));
        let finish_us = us(end.saturating_duration_since(marks.done_at));
        SpanBreakdown {
            queue_us,
            route_us,
            linger_us,
            retry_us: 0, // the worker fills this from the job's FaultState
            stage_us,
            execute_us,
            finish_us,
            total_us: queue_us + route_us + stage_us + execute_us + finish_us,
        }
    }

    /// The five named stages (linger excluded: it is a sub-span of
    /// stage), in serving-path order with their labels.
    pub fn stages(&self) -> [(&'static str, u64); 5] {
        [
            ("queue_us", self.queue_us),
            ("route_us", self.route_us),
            ("stage_us", self.stage_us),
            ("execute_us", self.execute_us),
            ("finish_us", self.finish_us),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(base: Instant, ms: u64) -> Instant {
        base + Duration::from_millis(ms)
    }

    #[test]
    fn stages_telescope_to_the_total_exactly() {
        let base = Instant::now();
        let stamps = SpanStamps {
            routed_at: Some(t(base, 2)),
            claimed_at: Some(t(base, 5)),
        };
        let marks = BatchMarks {
            collected_at: t(base, 6),
            exec_at: t(base, 9),
            done_at: t(base, 30),
        };
        let s = SpanBreakdown::compute(base, stamps, marks, t(base, 32));
        assert_eq!(s.queue_us, 2_000);
        assert_eq!(s.route_us, 3_000);
        assert_eq!(s.linger_us, 1_000);
        assert_eq!(s.stage_us, 4_000);
        assert_eq!(s.execute_us, 21_000);
        assert_eq!(s.finish_us, 2_000);
        let sum: u64 = s.stages().iter().map(|(_, us)| *us).sum();
        assert_eq!(sum, s.total_us, "named stages must sum to the total");
        assert_eq!(s.total_us, 32_000);
        assert!(s.linger_us <= s.stage_us, "linger is a sub-span of stage");
        assert_eq!(s.retry_us, 0, "retry is outside the telescoping sum");
    }

    #[test]
    fn missing_stamps_collapse_to_zero_width_stages() {
        let base = Instant::now();
        let marks = BatchMarks {
            collected_at: t(base, 1),
            exec_at: t(base, 2),
            done_at: t(base, 8),
        };
        // never routed/claimed (direct-execution test jobs): queue and
        // route collapse, stage absorbs the wait, the sum still holds
        let s = SpanBreakdown::compute(base, SpanStamps::default(), marks, t(base, 9));
        assert_eq!(s.queue_us, 0);
        assert_eq!(s.route_us, 0);
        assert_eq!(s.stage_us, 2_000);
        assert_eq!(s.execute_us, 6_000);
        assert_eq!(s.finish_us, 1_000);
        let sum: u64 = s.stages().iter().map(|(_, us)| *us).sum();
        assert_eq!(sum, s.total_us);
    }

    #[test]
    fn out_of_order_marks_saturate_instead_of_panicking() {
        let base = Instant::now();
        // claimed "before" routed (clock skew between stamping sites)
        let stamps = SpanStamps {
            routed_at: Some(t(base, 5)),
            claimed_at: Some(t(base, 3)),
        };
        let marks = BatchMarks::at(t(base, 4));
        let s = SpanBreakdown::compute(base, stamps, marks, t(base, 6));
        assert_eq!(s.route_us, 0, "negative width saturates to zero");
        let sum: u64 = s.stages().iter().map(|(_, us)| *us).sum();
        assert_eq!(sum, s.total_us);
    }

    #[test]
    fn pipelined_batches_keep_per_request_spans_disjoint() {
        // Batch k+1's stage overlaps batch k's execute wall-clock window
        // (software pipelining).  Per REQUEST the spans stay disjoint:
        // request B's stage span covers the overlap, its execute span
        // starts only at its own launch, and both telescoping sums hold.
        let base = Instant::now();
        let a = SpanStamps {
            routed_at: Some(t(base, 1)),
            claimed_at: Some(t(base, 2)),
        };
        let marks_a = BatchMarks {
            collected_at: t(base, 3),
            exec_at: t(base, 4),
            done_at: t(base, 20),
        };
        let sa = SpanBreakdown::compute(base, a, marks_a, t(base, 21));

        // B is staged at t=6..12, entirely inside A's execute window
        let b = SpanStamps {
            routed_at: Some(t(base, 5)),
            claimed_at: Some(t(base, 6)),
        };
        let marks_b = BatchMarks {
            collected_at: t(base, 7),
            exec_at: t(base, 12),
            done_at: t(base, 28),
        };
        let sb = SpanBreakdown::compute(base, b, marks_b, t(base, 29));

        assert_eq!(sa.execute_us, 16_000);
        assert_eq!(sb.stage_us, 6_000, "B's stage covers the overlapped window");
        assert_eq!(sb.execute_us, 16_000);
        for s in [&sa, &sb] {
            let sum: u64 = s.stages().iter().map(|(_, us)| *us).sum();
            assert_eq!(sum, s.total_us);
        }
    }
}
