//! Multi-cluster offload scheduler: concurrent serving with batching,
//! device pooling and backpressure.
//!
//! The paper offloads one BLAS call at a time through a synchronous
//! OpenMP fork-join, and the original `serve` loop mirrored that limit:
//! one session, one connection at a time.  HERO exposes the accelerator
//! as *multiple* clusters behind mailboxes, and ESP-style SoCs scale by
//! treating accelerators as a schedulable pool — this module builds that
//! layer.  Four pieces, each in its own file:
//!
//! | piece | file | role |
//! |---|---|---|
//! | device pool | [`pool`] | boots N simulated PMCA clusters, each with its own mailbox and a page-aligned slice of the device-DRAM partition (even, or heterogeneous under the big-shape lane — see [`pool::CapacityModel`]) |
//! | work queue | [`queue`] | bounded, three priority classes, rejects with a retry-after hint when full (backpressure) |
//! | placement router | [`placement`] | routes queued jobs into per-cluster run queues by operand affinity ([`affinity`]), shape and round-robin; idle workers steal from the most-loaded peer |
//! | batcher | [`batcher`] | coalesces same-shape GEMM/GEMV and same-length level-1 requests into ONE fork-join launch, amortizing the paper's offload overhead below the Figure-3 crossover |
//! | workers | [`worker`] | one thread per cluster: pull jobs from the router, consult the dispatch policy, launch, poll the cluster mailbox for completion, reply |
//!
//! [`Scheduler`] is the facade: `submit` enqueues a job and hands back a
//! [`Submission`] (result receiver + cancel token); connection handlers
//! block on the receiver while the pool completes requests out of band,
//! and a handler that stops waiting cancels its job so no worker ever
//! launches it for a dropped receiver.  Config knobs live in
//! [`crate::config::SchedConfig`] (`[sched]` in the platform TOML):
//! `pool_clusters`, `queue_capacity`, `batch_window_ms`, `batch_max`.
//!
//! Two data-movement optimizations ride the same worker loop, both
//! configured under `[sched.cache]` and both off by default: each
//! cluster session carries a device-resident **operand cache**
//! ([`crate::omp::opcache`]) that turns re-maps of identical bytes into
//! refcount bumps, and the worker **software-pipelines** coalesced gemm
//! *and gemv* launches (stage batch k+1's map-in while batch k
//! computes) through the stage/execute/finish splits — see [`worker`].
//! GEMM, GEMV and level-1 (axpy/dot) requests all coalesce (same
//! [`BatchKey`] => one fork-join launch).
//!
//! Between the queue and the workers sits the **placement router**
//! ([`placement`], knobs under `[sched.placement]`): jobs are routed
//! into per-cluster run queues by operand affinity (same-`b_seed`
//! requests chase the cache-warm cluster, via the [`affinity`]
//! directory fed by opcache residency changes), by shape (jobs too big
//! for a small DRAM slice take the big-shape lane that heterogeneous
//! slicing carves out — see [`pool::CapacityModel`]), and round-robin
//! otherwise; idle workers steal from the most-loaded peer.  Placement
//! changes only *where* a job runs, never its numerics.
//!
//! Each worker owns a full vertical slice (engine + artifact registry +
//! policy) built *on its own thread* — nothing session-internal crosses
//! threads, only [`Job`]s and their reply channels.
//!
//! One [`crate::cost::CostModel`] — built from the platform description
//! and the manifest geometry, online-calibrated from observed batch
//! timings when `[cost] calibrate` is on — is shared by every worker's
//! `Auto` dispatch (cache-aware via the affinity directory), the
//! router's shape/admission routing, and the batcher's linger sizing;
//! the serve layer reports its live crossover estimates.

pub mod affinity;
pub mod batcher;
pub mod fault;
pub mod placement;
pub mod pool;
pub mod queue;
pub mod span;
pub mod trace;
pub mod worker;

use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::config::{DagConfig, DispatchMode, PlatformConfig};
use crate::cost::CostModel;
use crate::dag::DagShape;
use crate::error::{Error, Result};
use crate::kernel::{KernelEvent, KernelRegistry};
use crate::metrics::{SchedCounters, SchedMetrics};

pub use batcher::{BatchKey, Batcher, JobSource};
pub use fault::{FaultKind, FaultPlan, FaultState};
pub use placement::PlacementRouter;
pub use pool::{CapacityModel, ClusterSpec, DevicePool};
pub use queue::{PushError, WorkQueue};
pub use span::{SpanBreakdown, SpanStamps};
pub use trace::{chrome_trace_json, EventKind, TraceEvent, TraceRecorder};

/// Priority class of a queued job (three lanes; higher pops first).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Priority {
    High,
    Normal,
    Low,
}

impl Priority {
    /// Lane index, highest priority first.
    pub fn lane(self) -> usize {
        match self {
            Priority::High => 0,
            Priority::Normal => 1,
            Priority::Low => 2,
        }
    }
}

impl std::str::FromStr for Priority {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "high" => Ok(Priority::High),
            "normal" => Ok(Priority::Normal),
            "low" => Ok(Priority::Low),
            other => Err(Error::Config(format!("unknown priority '{other}'"))),
        }
    }
}

impl std::fmt::Display for Priority {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Priority::High => "high",
            Priority::Normal => "normal",
            Priority::Low => "low",
        })
    }
}

/// One GEMM serving request: square n x n operands synthesized from a
/// deterministic seed (the serving protocol is workload-generating, like
/// the original serve loop — the checksum makes results verifiable).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GemmRequest {
    pub n: usize,
    pub mode: DispatchMode,
    /// Seed for the synthetic operands; identical (n, seed) requests are
    /// bit-identical, which is what lets the batcher coalesce safely and
    /// tests assert checksums.
    pub seed: u64,
    /// When set, B is drawn from its own RNG stream (`Rng::new(b_seed)`)
    /// instead of continuing A's — so requests that share a `b_seed`
    /// share a bit-identical B matrix, the reused-weight serving pattern
    /// the device-resident operand cache turns into refcount bumps.
    /// `None` keeps the original single-stream synthesis.
    pub b_seed: Option<u64>,
}

/// One GEMV serving request: an (m x n) matrix and length-n vector
/// synthesized from a deterministic seed; y starts at zero.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GemvRequest {
    pub m: usize,
    pub n: usize,
    pub mode: DispatchMode,
    pub seed: u64,
}

/// Which level-1 kernel a [`Level1Request`] runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Level1Op {
    Axpy,
    Dot,
}

impl Level1Op {
    /// Batch-key / serve-protocol name.
    pub fn name(self) -> &'static str {
        match self {
            Level1Op::Axpy => "axpy",
            Level1Op::Dot => "dot",
        }
    }
}

/// One level-1 serving request over length-n vectors synthesized from a
/// deterministic seed (x then y drawn from the request stream).
/// Same-length requests of the same op coalesce into one fork-join
/// launch — the last device path that used to pay it per call.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Level1Request {
    pub op: Level1Op,
    pub n: usize,
    pub mode: DispatchMode,
    pub seed: u64,
    /// axpy scale (ignored by dot).
    pub alpha: f64,
}

/// One chained serving request: a dependent GEMM sequence executed as
/// ONE submission whose intermediates stay resident in the serving
/// cluster's device-DRAM slice (`y = relu(x W1) W2 ...` without the
/// per-link offload tax).  `dims = [d0, .., dL]`: link i multiplies the
/// running (m x d_{i-1}) activation by a (d_{i-1} x d_i) weight, alpha =
/// 1, beta = 0.  The input activation is drawn from `seed`; link i's
/// weights come from `b_seeds[i]` when set (the shared-weight serving
/// pattern — chains sharing a `b_seed` share bit-identical weights and
/// route to the warm cluster) or continue the request stream.
#[derive(Debug, Clone, PartialEq)]
pub struct ChainRequest {
    pub m: usize,
    pub dims: Vec<usize>,
    pub mode: DispatchMode,
    pub seed: u64,
    pub b_seeds: Vec<Option<u64>>,
    /// `false` runs the same links as separate per-op GEMM offloads (the
    /// paper's one-call-at-a-time behavior) — the regression oracle the
    /// chained path must match bit-for-bit, and the bench baseline the
    /// `chain_bytes_elided` cut is measured against.
    pub chained: bool,
}

impl ChainRequest {
    /// Links in the chain (`dims` fenceposts).
    pub fn links(&self) -> usize {
        self.dims.len().saturating_sub(1)
    }
}

/// One DAG serving request: a typed dataflow graph of gemm/gemv/axpy/dot
/// nodes executed as ONE submission — fan-out pins a shared trunk output
/// until every consumer has read it, fan-in merges two resident branches
/// without either returning to host.  The external input activation is
/// drawn from `seed`; matmul node i's weights come from `b_seeds[i]`
/// when set (shared-weight requests route to the warm cluster) or
/// continue the request stream.
///
/// `publish_key` leaves the (last) sink output pinned in the serving
/// cluster's operand cache after the reply, tagged under the key, for
/// `[sched.dag] fuse_window_ms`; a follow-up request naming that key as
/// `input_key` splices onto the resident bytes instead of re-staging its
/// input — the cross-request fusion the `dag_fused_requests` counter
/// measures.
#[derive(Debug, Clone, PartialEq)]
pub struct DagRequest {
    pub shape: DagShape,
    pub mode: DispatchMode,
    pub seed: u64,
    /// One entry per node; `None` (and every fan-in node) continues the
    /// request stream.
    pub b_seeds: Vec<Option<u64>>,
    /// Pin the sink output under this key for the fuse window.
    pub publish_key: Option<u64>,
    /// Splice this request's input from a just-published sink output.
    pub input_key: Option<u64>,
}

/// What a job asks the pool to do.
#[derive(Debug)]
pub enum JobPayload {
    Gemm(GemmRequest),
    Gemv(GemvRequest),
    Level1(Level1Request),
    /// A dependent multi-op sequence: routed, stolen and executed as ONE
    /// unit — links never split across clusters, because the whole point
    /// is that the intermediates stay in one cluster's DRAM slice.
    Chain(ChainRequest),
    /// A dataflow graph: same one-unit rule as chains, for the same
    /// reason — the fan-out trunk and both fan-in branches live in one
    /// cluster's DRAM slice.
    Dag(DagRequest),
    /// Drain barrier: the worker that pops this parks until the sender
    /// releases (or drops) the channel.  Used by tests and benches to
    /// hold a cluster busy deterministically — e.g. to fill the queue
    /// and observe backpressure without racing the pool.
    Fence(mpsc::Receiver<()>),
}

/// Cooperative cancellation handle for a submitted job: the submitter
/// sets it when it stops waiting (serve-layer reply timeout), and the
/// worker checks it at dequeue so an orphaned job is skipped instead of
/// launched for nobody.
#[derive(Debug, Clone, Default)]
pub struct CancelToken(Arc<std::sync::atomic::AtomicBool>);

impl CancelToken {
    /// Mark the job as no longer wanted (idempotent).
    pub fn cancel(&self) {
        self.0.store(true, Ordering::Release);
    }

    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::Acquire)
    }
}

/// A unit of work in the queue.
#[derive(Debug)]
pub struct Job {
    pub id: u64,
    pub priority: Priority,
    pub payload: JobPayload,
    /// Where the worker sends the result; the submitting connection
    /// blocks on the paired receiver.
    pub reply: mpsc::Sender<JobResult>,
    /// Checked by workers at dequeue: a cancelled job is dropped, never
    /// launched.
    pub cancel: CancelToken,
    pub enqueued_at: Instant,
    /// Serving-path progress stamps (queue->route->claim boundaries),
    /// filled in by the router and closed into a [`SpanBreakdown`] by
    /// the worker at reply time.
    pub spans: SpanStamps,
    /// Fault-recovery state: how many device attempts already failed,
    /// which clusters failed them (the placement exclusion list), and
    /// the wall time those attempts burned (reported as the `retry`
    /// sub-span).  Default = a fresh, never-failed job.
    pub fault: FaultState,
}

impl Job {
    /// Coalescing key: jobs with equal keys may share one fork-join
    /// launch.  `None` never batches.
    pub fn batch_key(&self) -> Option<BatchKey> {
        match &self.payload {
            JobPayload::Gemm(r) => {
                Some(BatchKey { op: "gemm", dims: (r.n, r.n, r.n), mode: r.mode })
            }
            JobPayload::Gemv(r) => {
                Some(BatchKey { op: "gemv", dims: (r.m, r.n, 0), mode: r.mode })
            }
            // alpha is deliberately NOT part of the key: the device path
            // stages alpha per member, exactly like gemm members keep
            // their own operands
            JobPayload::Level1(r) => {
                Some(BatchKey { op: r.op.name(), dims: (r.n, 0, 0), mode: r.mode })
            }
            // chains and dags are internally sequential and already
            // amortize the fork-join across their nodes — never coalesce
            JobPayload::Chain(_) => None,
            JobPayload::Dag(_) => None,
            JobPayload::Fence(_) => None,
        }
    }
}

/// Successful completion of one job.
#[derive(Debug, Clone, Copy)]
pub struct GemmOutcome {
    /// Which operation ran ("gemm", "gemv" or "fence").
    pub op: &'static str,
    /// Result rows (GEMM: n; GEMV: m).
    pub m: usize,
    pub n: usize,
    pub mode: DispatchMode,
    /// Sum of the result matrix (verifiable against the seed).
    pub checksum: f64,
    /// Per-request share of the batch's virtual-time regions, ms.
    pub data_copy_ms: f64,
    pub fork_join_ms: f64,
    pub compute_ms: f64,
    pub host_compute_ms: f64,
    pub total_ms: f64,
    /// Which pool cluster served the request.
    pub cluster: u32,
    /// How many requests shared the fork-join launch.
    pub batch_size: usize,
    /// Wall-clock the job waited in the queue, ms.
    pub queue_ms: f64,
    /// Wall-clock serving-path breakdown (queue/route/stage/execute/
    /// finish, telescoping to `spans.total_us` exactly — the `trace:
    /// true` serve contract).
    pub spans: SpanBreakdown,
    /// True when the pool gave up on the device (attempts exhausted or
    /// no healthy cluster left) and the reply was computed on the host
    /// BLAS path — checksum-identical by construction.
    pub degraded: bool,
    /// Device attempts that *failed* before this reply (0 on the clean
    /// path; the serve layer echoes it with `degraded`).
    pub attempts: u32,
}

/// What comes back on the reply channel.
pub type JobResult = std::result::Result<GemmOutcome, String>;

/// An accepted submit: where the result will arrive, plus the handle to
/// cancel the job if the submitter stops waiting (a cancelled job is
/// skipped at dequeue — see [`CancelToken`]).
#[derive(Debug)]
pub struct Submission {
    pub result: mpsc::Receiver<JobResult>,
    pub cancel: CancelToken,
}

impl Submission {
    /// Convenience: wait for the result with a timeout; on timeout the
    /// job is cancelled so no worker launches it for a dropped receiver.
    pub fn recv_timeout(
        &self,
        timeout: std::time::Duration,
    ) -> std::result::Result<JobResult, mpsc::RecvTimeoutError> {
        match self.result.recv_timeout(timeout) {
            Ok(r) => Ok(r),
            Err(e) => {
                self.cancel.cancel();
                Err(e)
            }
        }
    }
}

/// Why a submit was refused.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SubmitError {
    /// Queue at capacity — retry after the hinted backoff.
    Backpressure { depth: usize, retry_after_ms: u64 },
    /// Scheduler is shutting down; the pool no longer accepts work.
    ShuttingDown,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Backpressure { depth, retry_after_ms } => write!(
                f,
                "queue full (depth {depth}); retry after {retry_after_ms} ms"
            ),
            SubmitError::ShuttingDown => f.write_str("scheduler shutting down"),
        }
    }
}

/// The scheduler facade: device pool + queue + workers, one per serve
/// process.  Dropping it (or calling [`Scheduler::shutdown`]) closes the
/// queue, lets workers drain what's left, and joins them.
pub struct Scheduler {
    queue: Arc<WorkQueue>,
    router: Arc<PlacementRouter>,
    counters: Arc<SchedCounters>,
    workers: Mutex<Vec<JoinHandle<()>>>,
    pool_size: usize,
    next_id: AtomicU64,
    /// `[sched.chain] max_links` — chain specs are bounded at submit.
    chain_max_links: u32,
    /// `[sched.dag]` bounds and fuse window — dag specs are validated at
    /// submit.
    dag_cfg: DagConfig,
    /// The pool-shared cost model: one calibration state behind every
    /// worker's dispatch, the router's shape/admission decisions and the
    /// batcher's linger sizing.  Kept here so the serve layer can report
    /// the live calibrated crossovers.
    cost: CostModel,
    /// The pool-shared flight recorder (`[sched.trace]`): every layer
    /// records into it, the serve `trace_dump` op reads it out.
    trace: Arc<TraceRecorder>,
    /// The pool-shared kernel registry (`[kernel]`): workers feed per-key
    /// launch counts in, every worker's device staging path consults it,
    /// the serve `metrics`/`top` ops report it.
    kernel: Arc<KernelRegistry>,
}

impl std::fmt::Debug for Scheduler {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Scheduler")
            .field("pool_size", &self.pool_size)
            .field("queue_depth", &self.queue_depth())
            .finish()
    }
}

impl Scheduler {
    /// Boot the pool and wait until every worker has built and warmed its
    /// session (so the first request never pays compile latency).  Any
    /// worker failing to boot tears the whole scheduler down and returns
    /// the error.
    pub fn new(cfg: &PlatformConfig, artifacts: &Path) -> Result<Scheduler> {
        cfg.validate()?;
        let sc = &cfg.sched;
        let pool = DevicePool::partition(cfg, sc.pool_clusters)?;
        let capacity = pool.capacity().clone();
        // ONE cost model for the whole pool: built from the platform
        // description and the manifest geometry (the same tile shape the
        // staging path pads with), shared — calibration state included —
        // by every worker's dispatch, the router and the batcher.
        let manifest = crate::runtime::Manifest::load(artifacts)?;
        let cost = CostModel::from_manifest(cfg, &manifest);
        // the flight recorder spans every layer below: the queue stamps
        // enqueues, the router stamps placement decisions, the workers
        // stamp batch stages / faults / per-request spans
        let trace = TraceRecorder::new(&sc.trace, sc.pool_clusters);
        // ONE kernel registry for the whole pool, keyed on the same
        // manifest tile geometry the staging path pads with (and the
        // same level-1 chunk derivation as CostModel::from_manifest).
        // Its promote/hit transitions land on the recorder's global
        // track so trace_dump shows specialization next to the jobs
        // that earned it.
        let level1_chunk = manifest
            .entries
            .iter()
            .filter(|e| (e.op == "axpy" || e.op == "dot") && e.dtype == "f64")
            .filter_map(|e| e.n)
            .max()
            .unwrap_or(4096);
        let kernel = Arc::new(KernelRegistry::new(
            &cfg.kernel,
            (manifest.tile_m, manifest.tile_n, manifest.tile_k),
            level1_chunk,
        ));
        {
            let tr = Arc::clone(&trace);
            kernel.set_event_hook(move |e| match e {
                KernelEvent::Promote { key, launches } => tr.instant(
                    trace::GLOBAL_TRACK,
                    EventKind::KernelPromote,
                    key,
                    launches as u64,
                ),
                KernelEvent::Hit { key } => {
                    tr.instant(trace::GLOBAL_TRACK, EventKind::KernelHit, key, 0)
                }
            });
        }
        let queue = Arc::new(
            WorkQueue::new(sc.queue_capacity as usize)
                .with_trace(Arc::clone(&trace)),
        );
        let counters = Arc::new(SchedCounters::new(sc.pool_clusters as usize));
        let router = Arc::new(
            PlacementRouter::with_fault(
                capacity,
                cost.clone(),
                sc.placement.clone(),
                sc.fault.clone(),
            )
            .with_trace(Arc::clone(&trace)),
        );
        // deterministic fault plan ([sched.fault]; inert by default) —
        // each worker draws injection decisions from it per launch
        let fault_plan = FaultPlan::new(sc.fault.clone());
        let batcher = Batcher::new(
            std::time::Duration::from_millis(sc.batch_window_ms),
            sc.batch_max as usize,
        )
        .with_model(cost.clone());

        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let mut handles = Vec::new();
        for spec in pool.into_specs() {
            handles.push(worker::spawn(
                spec,
                artifacts.to_path_buf(),
                Arc::clone(&queue),
                Arc::clone(&router),
                Arc::clone(&counters),
                batcher.clone(),
                cost.clone(),
                fault_plan.clone(),
                Arc::clone(&trace),
                Arc::clone(&kernel),
                ready_tx.clone(),
            ));
        }
        drop(ready_tx);

        let mut boot_err = None;
        for _ in 0..handles.len() {
            match ready_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => boot_err = boot_err.or(Some(e)),
                Err(_) => {
                    boot_err = boot_err.or(Some(Error::Runtime(
                        "scheduler worker died during boot".into(),
                    )))
                }
            }
        }
        if let Some(e) = boot_err {
            queue.close();
            router.close();
            for h in handles {
                let _ = h.join();
            }
            return Err(e);
        }

        Ok(Scheduler {
            queue,
            router,
            counters,
            workers: Mutex::new(handles),
            pool_size: sc.pool_clusters as usize,
            next_id: AtomicU64::new(1),
            chain_max_links: sc.chain.max_links,
            dag_cfg: sc.dag.clone(),
            cost,
            trace,
            kernel,
        })
    }

    /// Reject a chain spec that could never run — too many links for the
    /// `[sched.chain]` bound, or a staged footprint (input + every link's
    /// weights + every output, all resident at once) that no cluster
    /// slice can hold.  A clear error at submit time instead of a job
    /// that wedges in staging retries.
    pub fn validate_chain(&self, req: &ChainRequest) -> std::result::Result<(), String> {
        let links = req.links();
        if links == 0 {
            return Err("chain needs at least 2 dims (1 link)".into());
        }
        if links as u32 > self.chain_max_links {
            return Err(format!(
                "chain has {links} links; [sched.chain] max_links = {}",
                self.chain_max_links
            ));
        }
        if req.b_seeds.len() != links {
            return Err(format!(
                "chain has {links} links but {} b_seeds",
                req.b_seeds.len()
            ));
        }
        let need = self.cost.chain_staged_bytes(req.m, &req.dims);
        let cap = self.router.capacity().max_slice();
        if need > cap {
            return Err(format!(
                "chain stages {need} B resident at once but the largest \
                 cluster slice holds {cap} B — shorten the chain or shrink \
                 its dims"
            ));
        }
        Ok(())
    }

    /// Reject a DAG spec that could never run.  Structural checks
    /// (acyclicity, the `[sched.dag]` node/width/depth bounds) come from
    /// [`DagShape::validate`], whose errors name the offending node id,
    /// op and violated bound; on top sit the per-request checks — seed
    /// list arity, the staged-footprint capacity bound (everything in a
    /// DAG is resident at once), and the fuse window being open at all
    /// when the request asks to splice.
    pub fn validate_dag(&self, req: &DagRequest) -> std::result::Result<(), String> {
        let d = &self.dag_cfg;
        req.shape.validate(d.max_nodes, d.max_width, d.max_depth)?;
        if req.b_seeds.len() != req.shape.nodes.len() {
            return Err(format!(
                "dag has {} nodes but {} b_seeds",
                req.shape.nodes.len(),
                req.b_seeds.len()
            ));
        }
        if (req.input_key.is_some() || req.publish_key.is_some())
            && d.fuse_window_ms == 0
        {
            return Err(
                "dag names a publish/input key but [sched.dag] \
                 fuse_window_ms = 0 (fusion disabled)"
                    .into(),
            );
        }
        let need = self.cost.dag_staged_bytes(&req.shape);
        let cap = self.router.capacity().max_slice();
        if need > cap {
            return Err(format!(
                "dag stages {need} B resident at once but the largest \
                 cluster slice holds {cap} B — split the dag or shrink \
                 its dims"
            ));
        }
        Ok(())
    }

    /// Enqueue a job; returns a [`Submission`] (result receiver + cancel
    /// token), or a backpressure rejection when the bounded queue is
    /// full.  The bound covers both stages of the ingress — globally
    /// queued jobs AND jobs already routed into cluster run queues but
    /// not yet claimed — so routing cannot silently widen the backlog
    /// the backpressure contract promises to cap.
    pub fn submit(
        &self,
        priority: Priority,
        payload: JobPayload,
    ) -> std::result::Result<Submission, SubmitError> {
        let routed = self.router.depth();
        let (tx, rx) = mpsc::channel();
        let cancel = CancelToken::default();
        let job = Job {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            priority,
            payload,
            reply: tx,
            cancel: cancel.clone(),
            enqueued_at: Instant::now(),
            spans: SpanStamps::default(),
            fault: FaultState::default(),
        };
        // the routed count rides into the queue's own locked bound, so
        // concurrent submitters serialize instead of racing a separate
        // check-then-push past the capacity
        match self.queue.push_with_reserved(job, routed) {
            Ok(depth) => {
                self.counters.submitted.fetch_add(1, Ordering::Relaxed);
                self.counters.note_queue_depth((depth + routed) as u64);
                self.router.kick();
                Ok(Submission { result: rx, cancel })
            }
            Err(PushError::Full { depth }) => {
                self.counters.rejected.fetch_add(1, Ordering::Relaxed);
                Err(SubmitError::Backpressure {
                    depth,
                    retry_after_ms: self.retry_hint(depth),
                })
            }
            Err(PushError::Closed) => Err(SubmitError::ShuttingDown),
        }
    }

    /// Backoff hint for a rejected submit: roughly the time the pool
    /// needs to drain the current backlog, from the smoothed per-job
    /// service time.  Clamped to [1 ms, 10 s].
    fn retry_hint(&self, depth: usize) -> u64 {
        // single atomic load — this runs on the reject path, where a full
        // counters snapshot (with its per-cluster Vec) is waste
        let per_job_us =
            self.counters.service_us_ewma.load(Ordering::Relaxed).max(1_000);
        retry_after_ms(depth, per_job_us, self.pool_size)
    }

    /// The backpressure-style backoff hint for the *current* backlog —
    /// the serve layer echoes it on reply timeouts so clients back off
    /// exactly as they do on queue-full rejections.
    pub fn current_retry_hint_ms(&self) -> u64 {
        self.retry_hint(self.queue_depth())
    }

    /// Is a pool cluster currently quarantined?  (The serve `metrics`
    /// op and the fault tests read this.)
    pub fn is_quarantined(&self, cluster: u32) -> bool {
        self.router.is_quarantined(cluster)
    }

    /// Point-in-time scheduler counters, with each cluster's live
    /// run-queue depth filled in from the router.
    pub fn metrics(&self) -> SchedMetrics {
        let mut m = self.counters.snapshot();
        for (i, d) in self.router.depths().into_iter().enumerate() {
            if let Some(cm) = m.clusters.get_mut(i) {
                cm.queue_depth = d;
            }
        }
        // the kernel registry keeps its own counters — overlay them so
        // every consumer (serve metrics, Prometheus, summary) sees one
        // coherent snapshot
        let ks = self.kernel.stats();
        m.kernel_specialized = ks.specialized;
        m.kernel_hits = ks.hits;
        m.kernel_fallbacks = ks.fallbacks;
        m.kernel_evictions = ks.evictions;
        m.kernel_entries = ks.entries as u64;
        m
    }

    /// Jobs currently queued (globally or routed into a cluster run
    /// queue) but not yet claimed by a worker.
    pub fn queue_depth(&self) -> usize {
        self.queue.depth() + self.router.depth()
    }

    /// Clusters in the device pool.
    pub fn pool_size(&self) -> usize {
        self.pool_size
    }

    /// The pool's capacity model (slice sizes, big-shape lane, tiles).
    pub fn capacity(&self) -> &CapacityModel {
        self.router.capacity()
    }

    /// The pool-shared offload cost model (live calibrated crossovers —
    /// the serve banner and `metrics` op report them).
    pub fn cost_model(&self) -> &CostModel {
        &self.cost
    }

    /// The pool-shared flight recorder (the serve `trace_dump` op and
    /// the tests read it; everything below the facade writes it).
    pub fn trace(&self) -> &Arc<TraceRecorder> {
        &self.trace
    }

    /// The pool-shared shape-specialized kernel registry (the serve
    /// `metrics` and `top` ops report its counters and hot keys).
    pub fn kernel_registry(&self) -> &Arc<KernelRegistry> {
        &self.kernel
    }

    /// Every counter and histogram in Prometheus text exposition format
    /// (the serve `metrics_prom` op) — ready for fleet-level
    /// scrape-and-merge.
    pub fn prometheus_text(&self) -> String {
        crate::metrics::prometheus_text(&self.metrics())
    }

    /// Stop accepting work, let workers drain the queue, join them.
    /// Idempotent; also runs on drop.
    pub fn shutdown(&self) {
        self.queue.close();
        self.router.close();
        let handles: Vec<_> = self.workers.lock().expect("workers lock").drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
    }
}

impl Drop for Scheduler {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// The backpressure hint's arithmetic, saturating end to end: a long
/// fence park or a huge batch window can push the service-time EWMA into
/// ranges where `depth * per_job_us` overflows u64 — the hint must clamp
/// to its 10 s ceiling, never wrap to a tiny (or panicking) value that
/// turns backpressure into a retry storm.
pub(crate) fn retry_after_ms(depth: usize, per_job_us: u64, pool: usize) -> u64 {
    let us = (depth as u64)
        .saturating_mul(per_job_us)
        .checked_div(pool.max(1) as u64)
        .unwrap_or(u64::MAX);
    (us / 1_000).clamp(1, 10_000)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::str::FromStr;

    #[test]
    fn priority_parse_and_lanes() {
        assert_eq!(Priority::from_str("high").unwrap(), Priority::High);
        assert_eq!(Priority::from_str("normal").unwrap(), Priority::Normal);
        assert_eq!(Priority::from_str("low").unwrap(), Priority::Low);
        assert!(Priority::from_str("urgent").is_err());
        assert!(Priority::High.lane() < Priority::Normal.lane());
        assert!(Priority::Normal.lane() < Priority::Low.lane());
        assert_eq!(Priority::Low.to_string(), "low");
    }

    #[test]
    fn gemm_jobs_share_keys_fences_never_batch() {
        let (tx, _rx) = mpsc::channel();
        let gemm = |n, seed| Job {
            id: seed,
            priority: Priority::Normal,
            payload: JobPayload::Gemm(GemmRequest {
                n,
                mode: DispatchMode::DeviceOnly,
                seed,
                b_seed: None,
            }),
            reply: tx.clone(),
            cancel: CancelToken::default(),
            enqueued_at: Instant::now(),
            spans: SpanStamps::default(),
            fault: FaultState::default(),
        };
        assert_eq!(gemm(64, 1).batch_key(), gemm(64, 2).batch_key());
        assert_ne!(gemm(64, 1).batch_key(), gemm(128, 1).batch_key());
        let (_ftx, frx) = mpsc::channel();
        let fence = Job {
            id: 9,
            priority: Priority::High,
            payload: JobPayload::Fence(frx),
            reply: tx.clone(),
            cancel: CancelToken::default(),
            enqueued_at: Instant::now(),
            spans: SpanStamps::default(),
            fault: FaultState::default(),
        };
        assert_eq!(fence.batch_key(), None);

        // gemv keys coalesce on (m, n, mode), never with gemm keys
        let gemv = |m, n, seed| Job {
            id: seed,
            priority: Priority::Normal,
            payload: JobPayload::Gemv(GemvRequest {
                m,
                n,
                mode: DispatchMode::DeviceOnly,
                seed,
            }),
            reply: tx.clone(),
            cancel: CancelToken::default(),
            enqueued_at: Instant::now(),
            spans: SpanStamps::default(),
            fault: FaultState::default(),
        };
        assert_eq!(gemv(64, 32, 1).batch_key(), gemv(64, 32, 2).batch_key());
        assert_ne!(gemv(64, 32, 1).batch_key(), gemv(32, 64, 1).batch_key());
        assert_ne!(gemv(64, 64, 1).batch_key(), gemm(64, 1).batch_key());
        // b_seed is NOT part of the key: shared-B and private-B requests
        // of the same shape still share a launch
        let mut with_b = gemm(64, 3);
        if let JobPayload::Gemm(r) = &mut with_b.payload {
            r.b_seed = Some(42);
        }
        assert_eq!(with_b.batch_key(), gemm(64, 4).batch_key());

        // level-1 keys coalesce on (op, n, mode); alpha stays per member
        let l1 = |op, n, seed, alpha| Job {
            id: seed,
            priority: Priority::Normal,
            payload: JobPayload::Level1(Level1Request {
                op,
                n,
                mode: DispatchMode::DeviceOnly,
                seed,
                alpha,
            }),
            reply: tx.clone(),
            cancel: CancelToken::default(),
            enqueued_at: Instant::now(),
            spans: SpanStamps::default(),
            fault: FaultState::default(),
        };
        assert_eq!(
            l1(Level1Op::Axpy, 4096, 1, 1.0).batch_key(),
            l1(Level1Op::Axpy, 4096, 2, 2.5).batch_key()
        );
        assert_ne!(
            l1(Level1Op::Axpy, 4096, 1, 1.0).batch_key(),
            l1(Level1Op::Dot, 4096, 1, 1.0).batch_key()
        );
        assert_ne!(
            l1(Level1Op::Dot, 4096, 1, 1.0).batch_key(),
            l1(Level1Op::Dot, 2048, 1, 1.0).batch_key()
        );
    }

    #[test]
    fn chain_jobs_never_share_a_launch() {
        let (tx, _rx) = mpsc::channel();
        let chain = Job {
            id: 1,
            priority: Priority::Normal,
            payload: JobPayload::Chain(ChainRequest {
                m: 64,
                dims: vec![64, 64, 64],
                mode: DispatchMode::DeviceOnly,
                seed: 1,
                b_seeds: vec![None, None],
                chained: true,
            }),
            reply: tx,
            cancel: CancelToken::default(),
            enqueued_at: Instant::now(),
            spans: SpanStamps::default(),
            fault: FaultState::default(),
        };
        assert_eq!(chain.batch_key(), None);
        if let JobPayload::Chain(r) = &chain.payload {
            assert_eq!(r.links(), 2);
        }
    }

    #[test]
    fn dag_jobs_never_share_a_launch() {
        let (tx, _rx) = mpsc::channel();
        let shape = crate::dag::linear_gemm_shape(64, &[64, 64, 64]);
        let dag = Job {
            id: 1,
            priority: Priority::Normal,
            payload: JobPayload::Dag(DagRequest {
                shape,
                mode: DispatchMode::DeviceOnly,
                seed: 1,
                b_seeds: vec![None, None],
                publish_key: None,
                input_key: None,
            }),
            reply: tx,
            cancel: CancelToken::default(),
            enqueued_at: Instant::now(),
            spans: SpanStamps::default(),
            fault: FaultState::default(),
        };
        assert_eq!(dag.batch_key(), None);
    }

    #[test]
    fn retry_after_ms_saturates_instead_of_wrapping() {
        // sane inputs behave like the old arithmetic
        assert_eq!(retry_after_ms(4, 1_000_000, 2), 2_000);
        assert_eq!(retry_after_ms(0, 1_000, 4), 1, "floor at 1 ms");
        // a huge service EWMA (e.g. a 300 s fence park folded in) times a
        // deep queue must clamp to the ceiling, not wrap
        assert_eq!(retry_after_ms(usize::MAX, u64::MAX, 1), 10_000);
        assert_eq!(retry_after_ms(1 << 40, u64::MAX / 2, 4), 10_000);
        // pool of 0 (defensive) still cannot divide by zero
        assert_eq!(retry_after_ms(8, 1_000_000, 0), 8_000);
    }

    #[test]
    fn cancel_token_flags_cooperatively() {
        let t = CancelToken::default();
        assert!(!t.is_cancelled());
        let t2 = t.clone();
        t2.cancel();
        assert!(t.is_cancelled(), "clones share the flag");
        t.cancel(); // idempotent
        assert!(t.is_cancelled());
    }

    #[test]
    fn submit_error_messages() {
        let e = SubmitError::Backpressure { depth: 7, retry_after_ms: 12 };
        let s = e.to_string();
        assert!(s.contains("queue full") && s.contains("12"), "{s}");
        assert!(SubmitError::ShuttingDown.to_string().contains("shutting down"));
    }
}
