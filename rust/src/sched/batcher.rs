//! Request coalescing: same-shape GEMMs share one fork-join launch.
//!
//! The paper's Figure 3 shows offload *losing* below the crossover size
//! because the fixed fork-join cost (~1.2 M host cycles of OpenBLAS +
//! libomptarget entry, doorbell, wake-up, join and exit) dwarfs the
//! compute.  Serving traffic is full of small same-shape calls, so the
//! batcher amortizes that fixed cost: a worker that picks up a GEMM
//! peels every already-queued request with the same [`BatchKey`] off the
//! queue — and optionally lingers for `window` so near-simultaneous
//! requests coalesce too — then the whole set goes down as ONE offload
//! descriptor (see `blas::device::gemm_batch_launch`).  A batch of B
//! pays the fork-join once, cutting the per-request overhead by ~B×,
//! which moves the effective crossover below the single-call size.

use std::time::{Duration, Instant};

use crate::config::DispatchMode;

use super::queue::WorkQueue;
use super::Job;

/// Coalescing identity: only jobs agreeing on all fields may share a
/// launch (same op + shape => same padded buffers and tile walk; same
/// mode => same dispatch target).  The seeds are deliberately NOT part
/// of the key — members keep their own operands.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BatchKey {
    pub op: &'static str,
    /// Op shape: GEMM uses (m, n, k); GEMV uses (m, n, 0).
    pub dims: (usize, usize, usize),
    pub mode: DispatchMode,
}

/// Where the batcher peels coalescible jobs from: the plain global
/// queue (library users, pre-placement tests), or a cluster's view of
/// the placement router (`crate::sched::placement` — own run queue
/// after routing everything queued globally, never a peer's).
pub trait JobSource {
    /// Remove up to `max` queued jobs whose batch key equals `key`,
    /// priority order, FIFO within a lane.  Never blocks.
    fn take_matching(&self, key: &BatchKey, max: usize) -> Vec<Job>;
}

impl JobSource for WorkQueue {
    fn take_matching(&self, key: &BatchKey, max: usize) -> Vec<Job> {
        self.try_pop_matching(key, max)
    }
}

/// The coalescing policy (cheap to clone; one per scheduler, shared by
/// value with every worker).
#[derive(Debug, Clone)]
pub struct Batcher {
    /// How long to linger for more same-key arrivals after the first job
    /// (0 = grab only what is already queued).
    pub window: Duration,
    /// Hard cap on members per launch (1 = batching off).
    pub max: usize,
}

impl Batcher {
    pub fn new(window: Duration, max: usize) -> Batcher {
        Batcher { window, max: max.max(1) }
    }

    /// Batching off: every job launches alone (the paper's measured
    /// per-call configuration).
    pub fn disabled() -> Batcher {
        Batcher { window: Duration::ZERO, max: 1 }
    }

    /// Grow a batch around `first`: peel same-key jobs off the source up
    /// to `min(self.max, cap)` members, lingering at most `self.window`.
    /// `cap` lets the caller bound the batch by device-DRAM capacity.
    /// Unbatchable jobs (no key) return alone.
    pub fn collect<S: JobSource + ?Sized>(
        &self,
        source: &S,
        first: Job,
        cap: usize,
    ) -> Vec<Job> {
        let mut batch = vec![first];
        let key = match batch[0].batch_key() {
            Some(k) => k,
            None => return batch,
        };
        let max = self.max.min(cap.max(1));
        if max <= 1 {
            return batch;
        }
        let deadline = Instant::now() + self.window;
        loop {
            batch.extend(source.take_matching(&key, max - batch.len()));
            if batch.len() >= max {
                break;
            }
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            // Lingering trades a bounded latency bump for a large
            // fork-join saving; poll briefly rather than parking so a
            // sub-millisecond window still coalesces bursts.
            std::thread::sleep((deadline - now).min(Duration::from_micros(200)));
        }
        batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::{GemmRequest, JobPayload, Priority};
    use std::sync::mpsc;
    use std::time::Instant;

    fn gemm_job(id: u64, n: usize) -> Job {
        let (tx, _rx) = mpsc::channel();
        Job {
            id,
            priority: Priority::Normal,
            payload: JobPayload::Gemm(GemmRequest {
                n,
                mode: DispatchMode::DeviceOnly,
                seed: id,
                b_seed: None,
            }),
            reply: tx,
            cancel: crate::sched::CancelToken::default(),
            enqueued_at: Instant::now(),
        }
    }

    #[test]
    fn zero_window_grabs_only_whats_queued() {
        let q = WorkQueue::new(16);
        for id in 2..=4 {
            q.push(gemm_job(id, 64)).unwrap();
        }
        q.push(gemm_job(5, 128)).unwrap();
        let b = Batcher::new(Duration::ZERO, 8);
        let batch = b.collect(&q, gemm_job(1, 64), usize::MAX);
        let ids: Vec<u64> = batch.iter().map(|j| j.id).collect();
        assert_eq!(ids, vec![1, 2, 3, 4]);
        assert_eq!(q.depth(), 1); // the 128 job stays
    }

    #[test]
    fn max_and_cap_bound_the_batch() {
        let q = WorkQueue::new(16);
        for id in 2..=8 {
            q.push(gemm_job(id, 64)).unwrap();
        }
        let b = Batcher::new(Duration::ZERO, 4);
        assert_eq!(b.collect(&q, gemm_job(1, 64), usize::MAX).len(), 4);
        // device-DRAM cap tightens further
        assert_eq!(b.collect(&q, gemm_job(9, 64), 2).len(), 2);
        // cap 0 is treated as 1 (the first job always runs)
        assert_eq!(b.collect(&q, gemm_job(10, 64), 0).len(), 1);
    }

    #[test]
    fn disabled_batcher_never_coalesces() {
        let q = WorkQueue::new(16);
        q.push(gemm_job(2, 64)).unwrap();
        let batch = Batcher::disabled().collect(&q, gemm_job(1, 64), usize::MAX);
        assert_eq!(batch.len(), 1);
        assert_eq!(q.depth(), 1);
    }

    #[test]
    fn window_coalesces_late_arrivals() {
        let q = std::sync::Arc::new(WorkQueue::new(16));
        let qc = std::sync::Arc::clone(&q);
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(10));
            qc.push(gemm_job(2, 64)).unwrap();
        });
        let b = Batcher::new(Duration::from_millis(500), 8);
        let batch = b.collect(&q, gemm_job(1, 64), usize::MAX);
        h.join().unwrap();
        assert_eq!(batch.len(), 2);
    }

    #[test]
    fn fence_runs_alone() {
        let q = WorkQueue::new(16);
        q.push(gemm_job(2, 64)).unwrap();
        let (tx, _rx) = mpsc::channel();
        let (_ftx, frx) = mpsc::channel();
        let fence = Job {
            id: 1,
            priority: Priority::Normal,
            payload: JobPayload::Fence(frx),
            reply: tx,
            cancel: crate::sched::CancelToken::default(),
            enqueued_at: Instant::now(),
        };
        let b = Batcher::new(Duration::from_millis(50), 8);
        assert_eq!(b.collect(&q, fence, usize::MAX).len(), 1);
        assert_eq!(q.depth(), 1);
    }
}
