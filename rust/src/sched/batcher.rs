//! Request coalescing: same-shape GEMMs share one fork-join launch.
//!
//! The paper's Figure 3 shows offload *losing* below the crossover size
//! because the fixed fork-join cost (~1.2 M host cycles of OpenBLAS +
//! libomptarget entry, doorbell, wake-up, join and exit) dwarfs the
//! compute.  Serving traffic is full of small same-shape calls, so the
//! batcher amortizes that fixed cost: a worker that picks up a GEMM
//! peels every already-queued request with the same [`BatchKey`] off the
//! queue — and optionally lingers for `window` so near-simultaneous
//! requests coalesce too — then the whole set goes down as ONE offload
//! descriptor (see `blas::device::gemm_batch_launch`).  A batch of B
//! pays the fork-join once, cutting the per-request overhead by ~B×,
//! which moves the effective crossover below the single-call size.
//!
//! With the scheduler's [`CostModel`] attached, the linger window is
//! sized from the model's **amortization curve** instead of being a
//! flat constant: with b members collected, waiting for one more can
//! save at most the marginal fork-join amortization `F/b - F/(b+1)` —
//! once the remaining wait exceeds that, lingering costs the queued
//! members more latency than it can possibly save, so collection stops
//! early.  Jobs whose dispatch decision is the *host* pay no fork-join
//! at all, so their batches never linger (they still coalesce whatever
//! is already queued).

use std::time::{Duration, Instant};

use crate::config::DispatchMode;
use crate::cost::{CostModel, CostOp};

use super::queue::WorkQueue;
use super::Job;

/// Coalescing identity: only jobs agreeing on all fields may share a
/// launch (same op + shape => same padded buffers and tile walk; same
/// mode => same dispatch target).  The seeds are deliberately NOT part
/// of the key — members keep their own operands.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BatchKey {
    pub op: &'static str,
    /// Op shape: GEMM uses (m, n, k); GEMV uses (m, n, 0).
    pub dims: (usize, usize, usize),
    pub mode: DispatchMode,
}

/// Where the batcher peels coalescible jobs from: the plain global
/// queue (library users, pre-placement tests), or a cluster's view of
/// the placement router (`crate::sched::placement` — own run queue
/// after routing everything queued globally, never a peer's).
pub trait JobSource {
    /// Remove up to `max` queued jobs whose batch key equals `key`,
    /// priority order, FIFO within a lane.  Never blocks.
    fn take_matching(&self, key: &BatchKey, max: usize) -> Vec<Job>;
}

impl JobSource for WorkQueue {
    fn take_matching(&self, key: &BatchKey, max: usize) -> Vec<Job> {
        let mut jobs = self.try_pop_matching(key, max);
        for job in &mut jobs {
            // peeled straight off the global queue into a worker's batch
            job.spans.mark_claimed();
        }
        jobs
    }
}

/// The coalescing policy (cheap to clone; one per scheduler, shared by
/// value with every worker).
#[derive(Debug, Clone)]
pub struct Batcher {
    /// Hard ceiling on lingering for more same-key arrivals after the
    /// first job (0 = grab only what is already queued).  With a cost
    /// model attached the *effective* window is the smaller of this and
    /// the model's marginal-amortization allowance.
    pub window: Duration,
    /// Hard cap on members per launch (1 = batching off).
    pub max: usize,
    /// The scheduler's shared cost model: sizes the linger window from
    /// the fork-join amortization curve.  `None` (library users, unit
    /// tests) keeps the flat window.
    model: Option<CostModel>,
}

impl Batcher {
    pub fn new(window: Duration, max: usize) -> Batcher {
        Batcher { window, max: max.max(1), model: None }
    }

    /// Attach the scheduler's shared cost model (linger sizing).
    pub fn with_model(mut self, model: CostModel) -> Batcher {
        self.model = Some(model);
        self
    }

    /// Batching off: every job launches alone (the paper's measured
    /// per-call configuration).
    pub fn disabled() -> Batcher {
        Batcher { window: Duration::ZERO, max: 1, model: None }
    }

    /// Does a launch with this key pay a fork-join that lingering could
    /// amortize?  The model's shared mode-to-path mapping answers (no
    /// model: only forced-host says no, the pre-model behavior).
    fn pays_forkjoin(&self, key: &BatchKey) -> bool {
        match &self.model {
            Some(cm) => cm.decides_device(key.op, key.dims, key.mode),
            None => key.mode != DispatchMode::HostOnly,
        }
    }

    /// How much longer it is worth waiting for the NEXT member, given
    /// `len` members collected: the model's marginal amortization, or
    /// the full window without a model.
    fn patience(&self, key: &BatchKey, len: usize) -> Duration {
        match &self.model {
            Some(cm) => {
                let op = CostOp::from_name(key.op).unwrap_or(CostOp::Gemm);
                cm.linger_allowance(op, len).min(self.window)
            }
            None => self.window,
        }
    }

    /// Grow a batch around `first`: peel same-key jobs off the source up
    /// to `min(self.max, cap)` members, lingering at most `self.window`
    /// (tightened by the model's amortization curve as the batch grows).
    /// `cap` lets the caller bound the batch by device-DRAM capacity.
    /// Unbatchable jobs (no key) return alone.
    pub fn collect<S: JobSource + ?Sized>(
        &self,
        source: &S,
        first: Job,
        cap: usize,
    ) -> Vec<Job> {
        self.collect_decided(source, first, cap, None)
    }

    /// [`Batcher::collect`] with the caller's already-made dispatch
    /// decision: `device_bound = Some(d)` overrides the batcher's own
    /// (cold) model estimate — the worker's gemm decision is cache-aware
    /// (warm shared-B streams offload below the cold crossover), and the
    /// linger decision must agree with the decision that actually
    /// launches, or warm device batches would never coalesce.
    pub fn collect_decided<S: JobSource + ?Sized>(
        &self,
        source: &S,
        first: Job,
        cap: usize,
        device_bound: Option<bool>,
    ) -> Vec<Job> {
        let mut batch = vec![first];
        let key = match batch[0].batch_key() {
            Some(k) => k,
            None => return batch,
        };
        let max = self.max.min(cap.max(1));
        if max <= 1 {
            return batch;
        }
        let deadline = Instant::now() + self.window;
        // host-path launches pay no fork-join: nothing to amortize, so
        // take what is queued and never linger
        let linger = device_bound.unwrap_or_else(|| self.pays_forkjoin(&key));
        let mut grew_at = Instant::now();
        loop {
            let got = source.take_matching(&key, max - batch.len());
            if !got.is_empty() {
                grew_at = Instant::now();
                batch.extend(got);
            }
            if batch.len() >= max || !linger {
                break;
            }
            let now = Instant::now();
            // stop once the marginal fork-join saving of one more member
            // can no longer repay the wait (expected queue-wait of the
            // members already collected grows with every tick)
            let patience_until = grew_at + self.patience(&key, batch.len());
            let stop_at = deadline.min(patience_until);
            if now >= stop_at {
                break;
            }
            // Lingering trades a bounded latency bump for a large
            // fork-join saving; poll briefly rather than parking so a
            // sub-millisecond window still coalesces bursts.
            std::thread::sleep((stop_at - now).min(Duration::from_micros(200)));
        }
        batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::{GemmRequest, JobPayload, Priority};
    use std::sync::mpsc;
    use std::time::Instant;

    fn gemm_job(id: u64, n: usize) -> Job {
        let (tx, _rx) = mpsc::channel();
        Job {
            id,
            priority: Priority::Normal,
            payload: JobPayload::Gemm(GemmRequest {
                n,
                mode: DispatchMode::DeviceOnly,
                seed: id,
                b_seed: None,
            }),
            reply: tx,
            cancel: crate::sched::CancelToken::default(),
            enqueued_at: Instant::now(),
            spans: crate::sched::SpanStamps::default(),
            fault: crate::sched::FaultState::default(),
        }
    }

    #[test]
    fn zero_window_grabs_only_whats_queued() {
        let q = WorkQueue::new(16);
        for id in 2..=4 {
            q.push(gemm_job(id, 64)).unwrap();
        }
        q.push(gemm_job(5, 128)).unwrap();
        let b = Batcher::new(Duration::ZERO, 8);
        let batch = b.collect(&q, gemm_job(1, 64), usize::MAX);
        let ids: Vec<u64> = batch.iter().map(|j| j.id).collect();
        assert_eq!(ids, vec![1, 2, 3, 4]);
        assert_eq!(q.depth(), 1); // the 128 job stays
    }

    #[test]
    fn max_and_cap_bound_the_batch() {
        let q = WorkQueue::new(16);
        for id in 2..=8 {
            q.push(gemm_job(id, 64)).unwrap();
        }
        let b = Batcher::new(Duration::ZERO, 4);
        assert_eq!(b.collect(&q, gemm_job(1, 64), usize::MAX).len(), 4);
        // device-DRAM cap tightens further
        assert_eq!(b.collect(&q, gemm_job(9, 64), 2).len(), 2);
        // cap 0 is treated as 1 (the first job always runs)
        assert_eq!(b.collect(&q, gemm_job(10, 64), 0).len(), 1);
    }

    #[test]
    fn disabled_batcher_never_coalesces() {
        let q = WorkQueue::new(16);
        q.push(gemm_job(2, 64)).unwrap();
        let batch = Batcher::disabled().collect(&q, gemm_job(1, 64), usize::MAX);
        assert_eq!(batch.len(), 1);
        assert_eq!(q.depth(), 1);
    }

    #[test]
    fn window_coalesces_late_arrivals() {
        let q = std::sync::Arc::new(WorkQueue::new(16));
        let qc = std::sync::Arc::clone(&q);
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(10));
            qc.push(gemm_job(2, 64)).unwrap();
        });
        let b = Batcher::new(Duration::from_millis(500), 8);
        let batch = b.collect(&q, gemm_job(1, 64), usize::MAX);
        h.join().unwrap();
        assert_eq!(batch.len(), 2);
    }

    #[test]
    fn host_decided_batches_never_linger() {
        use crate::config::PlatformConfig;
        let model =
            CostModel::from_platform(&PlatformConfig::default(), (64, 64, 64), 4096);
        let q = WorkQueue::new(16);
        // n=16 Auto-mode gemm: the model decides host — no fork-join to
        // amortize, so collect must return immediately despite the huge
        // window (a late arrival is NOT waited for)
        let host_job = |id| {
            let (tx, _rx) = mpsc::channel();
            Job {
                id,
                priority: Priority::Normal,
                payload: JobPayload::Gemm(GemmRequest {
                    n: 16,
                    mode: DispatchMode::Auto,
                    seed: id,
                    b_seed: None,
                }),
                reply: tx,
                cancel: crate::sched::CancelToken::default(),
                enqueued_at: Instant::now(),
                spans: crate::sched::SpanStamps::default(),
                fault: crate::sched::FaultState::default(),
            }
        };
        q.push(host_job(2)).unwrap();
        let b = Batcher::new(Duration::from_millis(1500), 8).with_model(model);
        let t0 = Instant::now();
        let batch = b.collect(&q, host_job(1), usize::MAX);
        assert_eq!(batch.len(), 2, "already-queued host jobs still coalesce");
        assert!(
            t0.elapsed() < Duration::from_millis(500),
            "host-decided batch lingered {:?}",
            t0.elapsed()
        );
    }

    #[test]
    fn amortization_curve_tightens_the_window_as_the_batch_grows() {
        use crate::config::PlatformConfig;
        let model =
            CostModel::from_platform(&PlatformConfig::default(), (64, 64, 64), 4096);
        // marginal saving at b=1 (~F/2 ~ 12 ms at 50 MHz) exceeds a 2 ms
        // window: small batches keep the configured window; at b=8 the
        // marginal (~F/72 ~ 0.3 ms) is below it
        let b = Batcher::new(Duration::from_millis(2), 16).with_model(model.clone());
        let key = gemm_job(0, 64).batch_key().unwrap();
        assert_eq!(b.patience(&key, 1), Duration::from_millis(2));
        assert!(b.patience(&key, 8) < Duration::from_millis(1));
        // device-only keys always pay the fork-join
        assert!(b.pays_forkjoin(&key));
    }

    #[test]
    fn fence_runs_alone() {
        let q = WorkQueue::new(16);
        q.push(gemm_job(2, 64)).unwrap();
        let (tx, _rx) = mpsc::channel();
        let (_ftx, frx) = mpsc::channel();
        let fence = Job {
            id: 1,
            priority: Priority::Normal,
            payload: JobPayload::Fence(frx),
            reply: tx,
            cancel: crate::sched::CancelToken::default(),
            enqueued_at: Instant::now(),
            spans: crate::sched::SpanStamps::default(),
            fault: crate::sched::FaultState::default(),
        };
        let b = Batcher::new(Duration::from_millis(50), 8);
        assert_eq!(b.collect(&q, fence, usize::MAX).len(), 1);
        assert_eq!(q.depth(), 1);
    }
}
