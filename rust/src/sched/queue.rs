//! Bounded work queue with priority classes and backpressure.
//!
//! Three FIFO lanes (high/normal/low) behind one mutex + condvar.  The
//! bound covers all lanes together: when the queue is full, `push`
//! rejects immediately — the submit path turns that into the
//! retry-after JSON line, so overload degrades into fast, explicit
//! rejections instead of unbounded memory growth and tail latency.
//!
//! Workers block on [`WorkQueue::pop_blocking`]; the batcher peels
//! additional same-key jobs off with [`WorkQueue::try_pop_matching`]
//! without blocking.  `close` wakes every sleeper and makes the queue
//! drain-only (pops succeed until empty, pushes fail).

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};

use super::batcher::BatchKey;
use super::trace::{EventKind, TraceRecorder, GLOBAL_TRACK};
use super::Job;

/// Why a push was refused.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PushError {
    /// At capacity; `depth` is the current total backlog.
    Full { depth: usize },
    /// The queue was closed (scheduler shutting down).
    Closed,
}

impl std::fmt::Display for PushError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PushError::Full { depth } => write!(f, "queue full at depth {depth}"),
            PushError::Closed => f.write_str("queue closed"),
        }
    }
}

#[derive(Debug, Default)]
struct Lanes {
    lanes: [VecDeque<Job>; 3],
    closed: bool,
}

impl Lanes {
    fn depth(&self) -> usize {
        self.lanes.iter().map(VecDeque::len).sum()
    }
}

/// The bounded multi-priority queue.
#[derive(Debug)]
pub struct WorkQueue {
    capacity: usize,
    inner: Mutex<Lanes>,
    ready: Condvar,
    /// Flight recorder for enqueue events (global track — a queued job
    /// has no cluster yet).  `None` in bare unit-test queues.
    trace: Option<Arc<TraceRecorder>>,
}

impl WorkQueue {
    pub fn new(capacity: usize) -> WorkQueue {
        assert!(capacity > 0, "queue capacity must be > 0");
        WorkQueue {
            capacity,
            inner: Mutex::new(Lanes::default()),
            ready: Condvar::new(),
            trace: None,
        }
    }

    /// Attach the pool's flight recorder (builder-style, at boot).
    pub fn with_trace(mut self, trace: Arc<TraceRecorder>) -> WorkQueue {
        self.trace = Some(trace);
        self
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Enqueue into the job's priority lane; returns the new total depth
    /// or the backpressure rejection.
    pub fn push(&self, job: Job) -> Result<usize, PushError> {
        self.push_with_reserved(job, 0)
    }

    /// Enqueue with an external `reserved` count folded into the bound:
    /// the push is rejected when `depth + reserved >= capacity`.  The
    /// scheduler passes the placement router's routed-but-unclaimed
    /// depth here, so the backpressure bound covers both stages of the
    /// ingress and concurrent submitters serialize on this lock instead
    /// of racing a check-then-push.  `Full.depth` reports the combined
    /// backlog.
    pub fn push_with_reserved(
        &self,
        job: Job,
        reserved: usize,
    ) -> Result<usize, PushError> {
        let job_id = job.id;
        let mut inner = self.inner.lock().expect("queue lock");
        if inner.closed {
            return Err(PushError::Closed);
        }
        let depth = inner.depth();
        if depth + reserved >= self.capacity {
            return Err(PushError::Full { depth: depth + reserved });
        }
        inner.lanes[job.priority.lane()].push_back(job);
        let depth = inner.depth();
        drop(inner);
        if let Some(t) = &self.trace {
            t.instant(GLOBAL_TRACK, EventKind::JobEnqueued, job_id, depth as u64);
        }
        self.ready.notify_one();
        Ok(depth)
    }

    /// Dequeue the oldest job of the highest non-empty priority lane,
    /// blocking while the queue is empty.  Returns `None` once the queue
    /// is closed *and* drained — the worker exit condition.
    pub fn pop_blocking(&self) -> Option<Job> {
        let mut inner = self.inner.lock().expect("queue lock");
        loop {
            for lane in inner.lanes.iter_mut() {
                if let Some(job) = lane.pop_front() {
                    return Some(job);
                }
            }
            if inner.closed {
                return None;
            }
            inner = self.ready.wait(inner).expect("queue lock");
        }
    }

    /// Dequeue the oldest job of the highest non-empty priority lane
    /// WITHOUT blocking; `None` when the queue is momentarily empty (or
    /// closed and drained).  The pipelined worker uses this while it has
    /// a batch in flight: an empty queue means "drain the pipeline", not
    /// "park".
    pub fn try_pop(&self) -> Option<Job> {
        let mut inner = self.inner.lock().expect("queue lock");
        for lane in inner.lanes.iter_mut() {
            if let Some(job) = lane.pop_front() {
                return Some(job);
            }
        }
        None
    }

    /// Remove up to `max` queued jobs whose batch key equals `key`,
    /// scanning lanes in priority order and preserving FIFO order within
    /// a lane.  Never blocks; used by the batcher to coalesce.
    pub fn try_pop_matching(&self, key: &BatchKey, max: usize) -> Vec<Job> {
        let mut out = Vec::new();
        if max == 0 {
            return out;
        }
        let mut inner = self.inner.lock().expect("queue lock");
        for lane in inner.lanes.iter_mut() {
            let mut i = 0;
            while i < lane.len() && out.len() < max {
                if lane[i].batch_key().as_ref() == Some(key) {
                    // O(len) middle removal is fine at serving queue sizes
                    out.push(lane.remove(i).expect("index checked"));
                } else {
                    i += 1;
                }
            }
            if out.len() >= max {
                break;
            }
        }
        out
    }

    /// Total jobs queued right now.
    pub fn depth(&self) -> usize {
        self.inner.lock().expect("queue lock").depth()
    }

    /// Stop accepting pushes and wake all sleeping workers.  Queued jobs
    /// still drain.  Idempotent.
    pub fn close(&self) {
        self.inner.lock().expect("queue lock").closed = true;
        self.ready.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.inner.lock().expect("queue lock").closed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DispatchMode;
    use crate::sched::{GemmRequest, JobPayload, Priority};
    use std::sync::mpsc;
    use std::time::Instant;

    fn gemm_job(id: u64, n: usize, priority: Priority) -> Job {
        let (tx, _rx) = mpsc::channel();
        // reply receiver intentionally dropped: these tests only exercise
        // queue mechanics, nobody completes the jobs
        Job {
            id,
            priority,
            payload: JobPayload::Gemm(GemmRequest {
                n,
                mode: DispatchMode::DeviceOnly,
                seed: id,
                b_seed: None,
            }),
            reply: tx,
            cancel: crate::sched::CancelToken::default(),
            enqueued_at: Instant::now(),
            spans: crate::sched::SpanStamps::default(),
            fault: crate::sched::FaultState::default(),
        }
    }

    #[test]
    fn try_pop_never_blocks_and_respects_priority() {
        let q = WorkQueue::new(8);
        assert!(q.try_pop().is_none(), "empty queue: None, no park");
        q.push(gemm_job(1, 64, Priority::Low)).unwrap();
        q.push(gemm_job(2, 64, Priority::High)).unwrap();
        assert_eq!(q.try_pop().unwrap().id, 2);
        assert_eq!(q.try_pop().unwrap().id, 1);
        assert!(q.try_pop().is_none());
        q.close();
        assert!(q.try_pop().is_none());
    }

    #[test]
    fn fifo_within_lane_priority_across_lanes() {
        let q = WorkQueue::new(8);
        q.push(gemm_job(1, 64, Priority::Low)).unwrap();
        q.push(gemm_job(2, 64, Priority::Normal)).unwrap();
        q.push(gemm_job(3, 64, Priority::High)).unwrap();
        q.push(gemm_job(4, 64, Priority::High)).unwrap();
        let order: Vec<u64> =
            (0..4).map(|_| q.pop_blocking().unwrap().id).collect();
        assert_eq!(order, vec![3, 4, 2, 1]);
    }

    #[test]
    fn full_queue_rejects_with_depth() {
        let q = WorkQueue::new(2);
        assert_eq!(q.push(gemm_job(1, 64, Priority::Normal)).unwrap(), 1);
        assert_eq!(q.push(gemm_job(2, 64, Priority::Normal)).unwrap(), 2);
        match q.push(gemm_job(3, 64, Priority::Normal)) {
            Err(PushError::Full { depth }) => assert_eq!(depth, 2),
            other => panic!("expected Full, got {other:?}"),
        }
        // draining one slot makes room again
        q.pop_blocking().unwrap();
        assert!(q.push(gemm_job(3, 64, Priority::Normal)).is_ok());
    }

    #[test]
    fn push_with_reserved_tightens_the_bound() {
        let q = WorkQueue::new(3);
        // two externally reserved slots leave room for exactly one push
        assert_eq!(q.push_with_reserved(gemm_job(1, 64, Priority::Normal), 2).unwrap(), 1);
        match q.push_with_reserved(gemm_job(2, 64, Priority::Normal), 2) {
            Err(PushError::Full { depth }) => {
                assert_eq!(depth, 3, "Full reports the combined backlog")
            }
            other => panic!("expected Full, got {other:?}"),
        }
        // without the reservation the same push fits
        assert!(q.push(gemm_job(2, 64, Priority::Normal)).is_ok());
    }

    #[test]
    fn close_wakes_and_drains() {
        let q = std::sync::Arc::new(WorkQueue::new(4));
        q.push(gemm_job(1, 64, Priority::Normal)).unwrap();
        q.close();
        assert_eq!(q.push(gemm_job(2, 64, Priority::Normal)), Err(PushError::Closed));
        // queued job still drains, then the queue reports exhaustion
        assert!(q.pop_blocking().is_some());
        assert!(q.pop_blocking().is_none());

        // a parked worker wakes on close instead of hanging
        let q2 = std::sync::Arc::new(WorkQueue::new(4));
        let qc = std::sync::Arc::clone(&q2);
        let h = std::thread::spawn(move || qc.pop_blocking().is_none());
        std::thread::sleep(std::time::Duration::from_millis(20));
        q2.close();
        assert!(h.join().unwrap());
    }

    #[test]
    fn try_pop_matching_peels_same_key_only() {
        let q = WorkQueue::new(8);
        q.push(gemm_job(1, 64, Priority::Normal)).unwrap();
        q.push(gemm_job(2, 128, Priority::Normal)).unwrap();
        q.push(gemm_job(3, 64, Priority::Normal)).unwrap();
        q.push(gemm_job(4, 64, Priority::High)).unwrap();
        let key = gemm_job(0, 64, Priority::Normal).batch_key().unwrap();
        let got = q.try_pop_matching(&key, 8);
        // high lane scanned first, then FIFO within normal
        let ids: Vec<u64> = got.iter().map(|j| j.id).collect();
        assert_eq!(ids, vec![4, 1, 3]);
        // the 128 job is untouched
        assert_eq!(q.depth(), 1);
        assert_eq!(q.pop_blocking().unwrap().id, 2);
    }

    #[test]
    fn try_pop_matching_respects_max() {
        let q = WorkQueue::new(8);
        for id in 1..=5 {
            q.push(gemm_job(id, 64, Priority::Normal)).unwrap();
        }
        let key = gemm_job(0, 64, Priority::Normal).batch_key().unwrap();
        assert_eq!(q.try_pop_matching(&key, 3).len(), 3);
        assert_eq!(q.depth(), 2);
        assert!(q.try_pop_matching(&key, 0).is_empty());
    }
}
