//! Cache-affinity directory: which clusters hold which operands.
//!
//! The operand cache (`crate::omp::opcache`) made data movement the
//! dominant serving cost lever, but it is *per cluster*: with random
//! placement a pool of K clusters pays K cold copies of a shared weight
//! matrix before every cache is warm.  The directory closes that gap at
//! the placement layer — it maps request-level **operand keys** to the
//! set of clusters whose caches hold the operand, so the router can
//! steer a request at a warm cluster and the pool stages each shared
//! operand roughly once.
//!
//! Keys are request-level identities (shape + seed of the shared
//! operand), hashed with the same FNV-1a the operand cache uses for
//! content keys — cheap to compute at submit time, before any operand
//! bytes exist.  Residency is maintained by the workers: after staging,
//! a worker tags the cache entry backing a tracked operand
//! ([`crate::omp::opcache::OperandCache::set_tag`]) and marks the
//! (key, cluster) bit here; when the entry is later evicted, the tag
//! comes back through the eviction feed and the bit clears.  The
//! directory is therefore a *hint*: a stale resident bit costs one cache
//! miss on the warm-looking cluster, never wrong numerics.
//!
//! Before anything is resident, [`AffinityDirectory::place`] falls back
//! to a deterministic hash-home (`key % eligible`), so a same-operand
//! request stream routes to one cluster from the very first request —
//! the property the placement tests pin.

use std::collections::HashMap;
use std::sync::Mutex;

use crate::omp::opcache::fnv1a;

/// Request-level identity of a shared operand: op tag + shape + seed,
/// hashed with the operand cache's FNV-1a.  Everything the router needs
/// to agree with itself across requests, computable without
/// synthesizing a single operand byte.
pub fn operand_key(op: &str, n: usize, seed: u64) -> u64 {
    let mut bytes = Vec::with_capacity(op.len() + 16);
    bytes.extend_from_slice(op.as_bytes());
    bytes.extend_from_slice(&(n as u64).to_le_bytes());
    bytes.extend_from_slice(&seed.to_le_bytes());
    fnv1a(&bytes)
}

/// [`operand_key`] for a rectangular operand (rows x cols).
pub fn operand_key2(op: &str, rows: usize, cols: usize, seed: u64) -> u64 {
    let mut bytes = Vec::with_capacity(op.len() + 24);
    bytes.extend_from_slice(op.as_bytes());
    bytes.extend_from_slice(&(rows as u64).to_le_bytes());
    bytes.extend_from_slice(&(cols as u64).to_le_bytes());
    bytes.extend_from_slice(&seed.to_le_bytes());
    fnv1a(&bytes)
}

/// Operand key of a chain link's (k x n) shared weight matrix.  A square
/// link deliberately collides with the plain gemm key: `Rng::new(seed)`
/// synthesizes the identical n x n matrix for both request kinds, so a
/// chain can chase a cache a gemm stream warmed (and vice versa).
pub fn chain_b_key(k: usize, n: usize, seed: u64) -> u64 {
    if k == n {
        operand_key("gemm_b", n, seed)
    } else {
        operand_key2("gemm_b", k, n, seed)
    }
}

/// Rendezvous key for a DAG's published (still-pinned) output.  The
/// publishing worker marks it resident when the DAG finishes; the router
/// maps a fusing request's `input_key` through the same function, so the
/// request lands on the cluster holding the intermediate — without either
/// side knowing the output's dims.  Deliberately distinct from every
/// operand-content key: a published intermediate is identified by the
/// request-chosen key alone, not by shape + seed.
pub fn dag_fuse_key(key: u64) -> u64 {
    operand_key("dag_pub", 0, key)
}

/// The directory: operand key -> residency bitmask over pool clusters
/// (the config caps pools at 64, so one u64 mask suffices), plus an
/// optional per-key **home override** set by the router's steal-fairness
/// load balancer — when a key's hash-home stays saturated, the router
/// re-homes the key and later same-key requests follow the override
/// (warming the new home on their first batch) instead of queueing
/// behind the hot cluster.
#[derive(Debug, Default)]
pub struct AffinityDirectory {
    resident: Mutex<HashMap<u64, u64>>,
    homes: Mutex<HashMap<u64, u32>>,
}

impl AffinityDirectory {
    pub fn new() -> AffinityDirectory {
        AffinityDirectory::default()
    }

    /// Mark `key` resident in `cluster`'s cache (worker, after staging).
    pub fn note_resident(&self, key: u64, cluster: u32) {
        let mut map = self.resident.lock().expect("affinity lock");
        *map.entry(key).or_insert(0) |= 1u64 << (cluster % 64);
    }

    /// Clear `key`'s residency in `cluster` (worker, after draining the
    /// cache's eviction feed).  Removes empty entries so the directory
    /// stays bounded by what is actually resident.
    pub fn note_evicted(&self, key: u64, cluster: u32) {
        let mut map = self.resident.lock().expect("affinity lock");
        if let Some(mask) = map.get_mut(&key) {
            *mask &= !(1u64 << (cluster % 64));
            if *mask == 0 {
                map.remove(&key);
            }
        }
    }

    /// Is `key` tracked as resident in `cluster`'s cache?  (What the
    /// worker's cache-aware dispatch asks before estimating map-in.)
    pub fn is_resident(&self, key: u64, cluster: u32) -> bool {
        self.resident
            .lock()
            .expect("affinity lock")
            .get(&key)
            .is_some_and(|mask| mask & (1u64 << (cluster % 64)) != 0)
    }

    /// Hard cap on home overrides: unlike residency bits (pruned on
    /// eviction), overrides have no natural retirement event, so the map
    /// is cleared wholesale at this size — overrides are hints; losing
    /// them reverts keys to their deterministic hash-homes.
    const MAX_HOMES: usize = 1024;

    /// Re-home `key`: later placements follow `cluster` (when eligible)
    /// even while the operand is still resident elsewhere — the new home
    /// warms up on its first batch, the old copy ages out via LRU.
    pub fn set_home(&self, key: u64, cluster: u32) {
        let mut homes = self.homes.lock().expect("affinity lock");
        if homes.len() >= Self::MAX_HOMES {
            homes.clear();
        }
        homes.insert(key, cluster);
    }

    /// Pick the cluster for `key` among `eligible` (sorted cluster ids):
    /// the load-balancer's home override first, then the lowest-id
    /// cluster with the operand resident, else the deterministic
    /// hash-home.  Returns `(cluster, warm)`.
    pub fn place(&self, key: u64, eligible: &[u32]) -> (u32, bool) {
        debug_assert!(!eligible.is_empty());
        let mask = *self
            .resident
            .lock()
            .expect("affinity lock")
            .get(&key)
            .unwrap_or(&0);
        if let Some(&h) = self.homes.lock().expect("affinity lock").get(&key) {
            if eligible.contains(&h) {
                return (h, mask & (1u64 << (h % 64)) != 0);
            }
        }
        for &c in eligible {
            if mask & (1u64 << (c % 64)) != 0 {
                return (c, true);
            }
        }
        (eligible[(key % eligible.len() as u64) as usize], false)
    }

    /// Drop every trace of `cluster` — fault recovery.  Residency bits
    /// for the cluster clear (empty masks pruned, like eviction) and home
    /// overrides pointing at it are forgotten, so same-key requests fall
    /// back to their deterministic hash-home among the still-healthy
    /// clusters instead of steering at a quarantined one.
    pub fn invalidate_cluster(&self, cluster: u32) {
        let bit = 1u64 << (cluster % 64);
        let mut map = self.resident.lock().expect("affinity lock");
        map.retain(|_, mask| {
            *mask &= !bit;
            *mask != 0
        });
        drop(map);
        let mut homes = self.homes.lock().expect("affinity lock");
        homes.retain(|_, h| *h != cluster);
    }

    /// Operands currently tracked as resident somewhere.
    pub fn len(&self) -> usize {
        self.resident.lock().expect("affinity lock").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn operand_keys_separate_op_shape_and_seed() {
        assert_eq!(operand_key("gemm_b", 64, 42), operand_key("gemm_b", 64, 42));
        assert_ne!(operand_key("gemm_b", 64, 42), operand_key("gemm_b", 64, 43));
        assert_ne!(operand_key("gemm_b", 64, 42), operand_key("gemm_b", 128, 42));
        assert_ne!(operand_key("gemm_b", 64, 42), operand_key("gemm_a", 64, 42));
    }

    #[test]
    fn chain_keys_share_square_weights_with_gemm_streams() {
        // a square chain link and a gemm request with the same b_seed
        // synthesize the identical matrix: one key, one warm cluster
        assert_eq!(chain_b_key(64, 64, 42), operand_key("gemm_b", 64, 42));
        // rectangular links get their own keys, shape-separated
        assert_ne!(chain_b_key(128, 64, 42), chain_b_key(64, 128, 42));
        assert_ne!(chain_b_key(128, 64, 42), operand_key("gemm_b", 64, 42));
        assert_eq!(chain_b_key(128, 64, 42), operand_key2("gemm_b", 128, 64, 42));
    }

    #[test]
    fn dag_fuse_keys_are_their_own_namespace() {
        assert_eq!(dag_fuse_key(7), dag_fuse_key(7));
        assert_ne!(dag_fuse_key(7), dag_fuse_key(8));
        // never collides with a weight-operand key for the same number
        assert_ne!(dag_fuse_key(42), operand_key("gemm_b", 64, 42));
        assert_ne!(dag_fuse_key(42), chain_b_key(64, 64, 42));
    }

    #[test]
    fn cold_placement_is_a_deterministic_home() {
        let d = AffinityDirectory::new();
        let eligible = [0u32, 1, 2, 3];
        let (home, warm) = d.place(operand_key("gemm_b", 64, 42), &eligible);
        assert!(!warm);
        // same key, same home — every time
        for _ in 0..8 {
            assert_eq!(d.place(operand_key("gemm_b", 64, 42), &eligible).0, home);
        }
        // different keys spread across homes (not all on one cluster)
        let homes: std::collections::HashSet<u32> = (0..32)
            .map(|s| d.place(operand_key("gemm_b", 64, s), &eligible).0)
            .collect();
        assert!(homes.len() > 1, "hash-home degenerated to one cluster");
    }

    #[test]
    fn residency_overrides_the_home_until_eviction() {
        let d = AffinityDirectory::new();
        let key = operand_key("gemm_b", 64, 42);
        let eligible = [0u32, 1, 2, 3];
        let (home, _) = d.place(key, &eligible);
        // a steal landed the operand on a different cluster's cache
        let other = eligible.iter().copied().find(|&c| c != home).unwrap();
        d.note_resident(key, other);
        assert_eq!(d.place(key, &eligible), (other, true));
        assert_eq!(d.len(), 1);

        // eviction clears the bit and placement falls back to the home
        d.note_evicted(key, other);
        assert_eq!(d.place(key, &eligible), (home, false));
        assert!(d.is_empty(), "empty masks are pruned");
        // evicting an unknown key is a no-op
        d.note_evicted(0xDEAD, 0);
    }

    #[test]
    fn home_override_beats_residency_and_respects_eligibility() {
        let d = AffinityDirectory::new();
        let key = operand_key("gemm_b", 64, 42);
        let eligible = [0u32, 1, 2, 3];
        d.note_resident(key, 1);
        assert!(d.is_resident(key, 1));
        assert!(!d.is_resident(key, 2));
        // re-home to 3: placement follows the override cold
        d.set_home(key, 3);
        assert_eq!(d.place(key, &eligible), (3, false));
        // once the new home warms, the placement is warm there
        d.note_resident(key, 3);
        assert_eq!(d.place(key, &eligible), (3, true));
        // an ineligible override is ignored (falls back to residency)
        d.set_home(key, 0);
        assert_eq!(d.place(key, &[1, 2, 3]), (1, true));
    }

    #[test]
    fn invalidate_cluster_clears_residency_and_homes() {
        let d = AffinityDirectory::new();
        let k1 = operand_key("gemm_b", 64, 1);
        let k2 = operand_key("gemm_b", 64, 2);
        d.note_resident(k1, 1);
        d.note_resident(k2, 1);
        d.note_resident(k2, 2);
        d.set_home(k1, 1);
        d.invalidate_cluster(1);
        assert!(!d.is_resident(k1, 1));
        assert!(!d.is_resident(k2, 1));
        assert!(d.is_resident(k2, 2), "other clusters keep their bits");
        assert_eq!(d.len(), 1, "emptied masks are pruned");
        // the home override at the failed cluster is gone: k1 falls back
        // to its deterministic hash-home among the eligible set
        let (c, warm) = d.place(k1, &[0, 1, 2, 3]);
        assert!(!warm);
        let _ = c;
    }

    #[test]
    fn eligible_set_filters_residency() {
        let d = AffinityDirectory::new();
        let key = operand_key("gemm_b", 256, 7);
        d.note_resident(key, 0); // resident on the big-shape lane
        // a small job must not route to an ineligible cluster even if the
        // operand is resident there
        let (c, warm) = d.place(key, &[1, 2, 3]);
        assert!(!warm);
        assert!(c != 0);
    }
}
