//! Locality-aware placement router with per-cluster run queues, work
//! stealing and a big-shape lane.
//!
//! PR 1's pool let *any* worker take *any* job; PR 2's operand cache
//! then made placement the dominant cost lever — a pool of K clusters
//! pays K cold copies of a shared operand under random placement, and
//! even DRAM slicing caps the largest device-stageable GEMM at a
//! fraction of the unpartitioned range.  The router is the explicit
//! placement/capacity layer between the bounded ingress queue and the
//! workers (the HERO/ESP lesson: heterogeneous pools need one):
//!
//! * **Per-cluster run queues**: jobs popped from the global
//!   [`WorkQueue`] are routed into one priority deque per cluster; each
//!   worker serves its own deque.  The global queue stays the single
//!   bounded ingress (backpressure accounts queue + deques together).
//! * **Cache affinity** (`[sched.placement] affinity`): requests
//!   sharing an operand (same `b_seed`) carry an operand key (same
//!   FNV-1a as the operand cache, see [`super::affinity`]); the
//!   directory steers them at the cluster whose cache holds the
//!   operand, with a deterministic hash-home before anything is
//!   resident — so a shared weight matrix is staged ~once per pool
//!   instead of once per cluster.
//! * **Shape-aware lanes** (`big_shape_frac`): under heterogeneous
//!   slicing, jobs whose staged footprint exceeds a small cluster's
//!   slice route to the big-shape lane (cluster 0), and small jobs
//!   avoid it — no small request ever sits behind a large launch, and
//!   the pool regains the unpartitioned large-GEMM range on one lane.
//! * **Work stealing** (`steal`): an idle worker takes queued jobs from
//!   the most-loaded peer — non-affine jobs first (they lose nothing),
//!   then affine ones (a steal costs one cache miss, never wrong
//!   numerics).  Fences are never stolen, and a thief never takes a job
//!   it cannot stage.
//! * **Steal-fairness re-homing** (`rebalance_drains > 0`): stealing is
//!   reactive (idle workers only), so a *sustained* affine skew still
//!   queues every same-operand request behind one saturated cluster.
//!   When a cluster's run-queue depth stays above the pool mean for N
//!   consecutive job-moving drain passes, the next affine key routed at
//!   it is re-homed (via the directory's home override) to the
//!   least-loaded eligible cluster — one extra cold copy, bounded by the
//!   clamp of N, in exchange for cutting the affine queueing delay.
//!
//! Shape estimates and the host/device admission decision come from the
//! scheduler's shared [`CostModel`]: a job routes to the big-shape lane
//! only if it will actually *stage* there (forced-device or model-
//! decided device), so a large Auto-mode GEMV that the dispatch model
//! sends to the host no longer occupies the big lane, and host-decided
//! jobs never fail a steal capacity check.
//!
//! Routing never changes numerics — only *where* a job runs — which is
//! what the steal/affinity checksum tests pin.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use crate::config::{FaultConfig, PlacementConfig};
use crate::cost::CostModel;
use crate::metrics::SchedCounters;

use super::affinity::{chain_b_key, dag_fuse_key, operand_key, AffinityDirectory};
use super::batcher::BatchKey;
use super::pool::CapacityModel;
use super::queue::WorkQueue;
use super::trace::{EventKind, TraceRecorder};
use super::{Job, JobPayload};

/// How long a worker parks between re-polls of the global queue when no
/// kick arrives (a safety net — `kick` wakes it immediately).
const PARK: Duration = Duration::from_millis(10);

/// A routed job waiting in a cluster's run queue.
#[derive(Debug)]
struct Routed {
    job: Job,
    /// Placed by operand affinity (stolen last).
    affine: bool,
    /// May another cluster's worker take it?  (Fences: no.)
    steal_ok: bool,
    /// Estimated staged footprint, bytes (steal capacity check).
    est_bytes: u64,
}

/// Per-cluster run queue: one FIFO per priority class, mirroring the
/// global queue's lanes so routing never inverts priorities.
#[derive(Debug, Default)]
struct ClusterLanes {
    lanes: [VecDeque<Routed>; 3],
}

impl ClusterLanes {
    fn depth(&self) -> usize {
        self.lanes.iter().map(VecDeque::len).sum()
    }
}

#[derive(Debug)]
struct RouterState {
    clusters: Vec<ClusterLanes>,
    /// Workers that have observed the closed+drained state and exited.
    /// A live worker always drains its own deque before exiting, so
    /// shutdown adoption only ever takes jobs whose owner is gone.
    exited: Vec<bool>,
    /// Consecutive job-moving drain passes each cluster's depth stayed
    /// above the pool mean (atomics so the routing path, which holds the
    /// state only by shared reference, can reset after a re-home).
    over_streak: Vec<AtomicU32>,
    /// Total job-moving drain passes (the re-homing cooldown clock).
    drain_seq: AtomicU64,
    /// Faults each cluster has taken since its last re-admission — at
    /// `quarantine_threshold` the cluster is quarantined.
    fault_counts: Vec<u32>,
    /// Quarantined clusters: routing skips them, their workers stop
    /// stealing, and (their DRAM slices dropping out of the eligible
    /// set) the capacity admission no longer counts their slices.
    quarantined: Vec<bool>,
    /// Probe-clock stamp when each cluster entered quarantine.
    quarantined_at: Vec<u64>,
    /// Job-moving drain passes — the quarantine probe clock (distinct
    /// from `drain_seq`, which only ticks when re-homing is enabled).
    probe_seq: u64,
}

/// The placement router (one per scheduler, shared by every worker and
/// the submit path).
#[derive(Debug)]
pub struct PlacementRouter {
    knobs: PlacementConfig,
    /// Quarantine knobs (`[sched.fault]`); defaults are inert until a
    /// worker actually reports a fault.
    fault: FaultConfig,
    capacity: CapacityModel,
    /// The scheduler's shared cost model: staged-footprint estimates
    /// (padded exactly like the staging path) and the host/device
    /// admission decision for Auto-mode jobs.
    cost: CostModel,
    state: Mutex<RouterState>,
    arrivals: Condvar,
    directory: AffinityDirectory,
    /// Jobs routed into cluster deques and not yet claimed, maintained
    /// at every push/pop so the submit path's backpressure check reads
    /// one atomic instead of taking the router lock.
    routed: AtomicUsize,
    /// Drain-sequence stamp of the last re-home: at most ONE re-home per
    /// `rebalance_drains` moving drains, pool-wide.  Without this, a
    /// single dominant hot key would ping-pong between clusters — each
    /// side saturates in turn — paying a cold operand copy per flip; the
    /// cooldown bounds the flip rate (and its cold-copy cost) to the
    /// same N the operator chose for "sustained".
    last_rehome: AtomicU64,
    /// Round-robin cursor for non-affine small jobs.
    rr: AtomicUsize,
    /// Separate cursor for fences so capacity tests stay deterministic:
    /// the first fence always lands on cluster 0.
    fence_rr: AtomicUsize,
    /// Flight recorder for placement events (routed / claimed / stolen /
    /// re-home / quarantine / probe).  `None` in bare unit-test routers.
    trace: Option<Arc<TraceRecorder>>,
}

impl PlacementRouter {
    pub fn new(
        capacity: CapacityModel,
        cost: CostModel,
        knobs: PlacementConfig,
    ) -> PlacementRouter {
        PlacementRouter::with_fault(capacity, cost, knobs, FaultConfig::default())
    }

    /// Router with explicit `[sched.fault]` quarantine knobs (the
    /// scheduler wires these; [`PlacementRouter::new`] uses the inert
    /// defaults).
    pub fn with_fault(
        capacity: CapacityModel,
        cost: CostModel,
        knobs: PlacementConfig,
        fault: FaultConfig,
    ) -> PlacementRouter {
        let clusters = capacity.pool_clusters();
        PlacementRouter {
            knobs,
            fault,
            capacity,
            cost,
            state: Mutex::new(RouterState {
                clusters: (0..clusters).map(|_| ClusterLanes::default()).collect(),
                exited: vec![false; clusters],
                over_streak: (0..clusters).map(|_| AtomicU32::new(0)).collect(),
                drain_seq: AtomicU64::new(0),
                fault_counts: vec![0; clusters],
                quarantined: vec![false; clusters],
                quarantined_at: vec![0; clusters],
                probe_seq: 0,
            }),
            arrivals: Condvar::new(),
            directory: AffinityDirectory::new(),
            routed: AtomicUsize::new(0),
            last_rehome: AtomicU64::new(0),
            rr: AtomicUsize::new(0),
            fence_rr: AtomicUsize::new(0),
            trace: None,
        }
    }

    /// Attach the pool's flight recorder (builder-style, at boot).
    pub fn with_trace(mut self, trace: Arc<TraceRecorder>) -> PlacementRouter {
        self.trace = Some(trace);
        self
    }

    /// Record one placement event when the recorder is attached.
    fn trace_evt(&self, cluster: u32, kind: EventKind, a: u64, b: u64) {
        if let Some(t) = &self.trace {
            t.instant(cluster, kind, a, b);
        }
    }

    pub fn affinity_enabled(&self) -> bool {
        self.knobs.affinity
    }

    pub fn capacity(&self) -> &CapacityModel {
        &self.capacity
    }

    /// Mark an operand resident in a cluster's cache (worker, after
    /// staging a tracked operand).
    pub fn note_resident(&self, key: u64, cluster: u32) {
        self.directory.note_resident(key, cluster);
    }

    /// Is an operand tracked as resident in a cluster's cache?  The
    /// worker's cache-aware dispatch asks this before estimating map-in
    /// cost (and the prefetch path asks it to detect a cold home).
    pub fn is_resident(&self, key: u64, cluster: u32) -> bool {
        self.directory.is_resident(key, cluster)
    }

    /// Clear an operand's residency (worker, draining the cache's
    /// eviction feed).
    pub fn note_evicted(&self, key: u64, cluster: u32) {
        self.directory.note_evicted(key, cluster);
    }

    /// A worker reports a batch fault on `cluster`.  Returns true when
    /// this report pushes the cluster over `quarantine_threshold` into
    /// quarantine (the caller counts the transition, not every report).
    pub fn note_fault(&self, cluster: u32) -> bool {
        let mut st = self.state.lock().expect("router lock");
        let c = cluster as usize;
        if c >= st.fault_counts.len() || st.quarantined[c] {
            return false;
        }
        st.fault_counts[c] += 1;
        if st.fault_counts[c] >= self.fault.quarantine_threshold.max(1) {
            st.quarantined[c] = true;
            st.quarantined_at[c] = st.probe_seq;
            self.trace_evt(cluster, EventKind::Quarantine, st.fault_counts[c] as u64, 0);
            return true;
        }
        false
    }

    /// Is `cluster` currently quarantined?  (Tests and the serve
    /// `metrics` op ask.)
    pub fn is_quarantined(&self, cluster: u32) -> bool {
        let st = self.state.lock().expect("router lock");
        st.quarantined.get(cluster as usize).copied().unwrap_or(false)
    }

    /// Is there any cluster a retry could still land on — neither
    /// quarantined nor on the job's exclusion list?  When this says no,
    /// the worker skips the requeue and goes straight to host fallback.
    pub fn retry_targets_exist(&self, excluded: u64) -> bool {
        let st = self.state.lock().expect("router lock");
        (0..st.quarantined.len()).any(|c| {
            !st.quarantined[c] && excluded & (1u64 << (c as u32 % 64)) == 0
        })
    }

    /// Fault recovery: drop every affinity trace of `cluster` (residency
    /// bits and home overrides) so routing stops treating its — just
    /// invalidated — cache as warm.
    pub fn invalidate_cluster(&self, cluster: u32) {
        self.directory.invalidate_cluster(cluster);
    }

    /// Re-admit quarantined clusters whose probe interval has drained
    /// past: the cluster rejoins the eligible set with its fault count
    /// one below the threshold, so its first routed job is the probe —
    /// one more fault re-quarantines it immediately, a success stream
    /// keeps it admitted (counts reset only through re-admission).
    fn probe_quarantined(&self, st: &mut RouterState) {
        for c in 0..st.quarantined.len() {
            if st.quarantined[c]
                && st.probe_seq.saturating_sub(st.quarantined_at[c])
                    >= self.fault.probe_interval.max(1)
            {
                st.quarantined[c] = false;
                st.fault_counts[c] =
                    self.fault.quarantine_threshold.max(1) - 1;
                self.trace_evt(c as u32, EventKind::Probe, 1, 0);
            }
        }
    }

    /// Jobs routed into cluster deques but not yet claimed (lock-free;
    /// the submit path calls this on every request).
    pub fn depth(&self) -> usize {
        self.routed.load(Ordering::Relaxed)
    }

    /// Per-cluster run-queue depths (the serve `metrics` op reports them).
    pub fn depths(&self) -> Vec<u64> {
        let st = self.state.lock().expect("router lock");
        st.clusters.iter().map(|c| c.depth() as u64).collect()
    }

    /// Wake parked workers (submit calls this after a successful push so
    /// routing latency is not bounded by the park interval).
    pub fn kick(&self) {
        let _guard = self.state.lock().expect("router lock");
        self.arrivals.notify_all();
    }

    /// Will this job actually run on a device path?  One shared mapping
    /// ([`CostModel::decides_device`]) answers for the router and the
    /// batcher alike — the same calibrated dispatch decision the worker
    /// will make (cold estimate: warmth only pulls *more* jobs onto the
    /// device, never off it, so a cold-host job is definitely host).
    /// This is the serve-side admission fix: a job the dispatch model
    /// sends to the host must not shape-route as if it staged operands.
    fn decided_device(&self, payload: &JobPayload) -> bool {
        match payload {
            JobPayload::Gemm(r) => {
                self.cost.decides_device("gemm", (r.n, r.n, r.n), r.mode)
            }
            JobPayload::Gemv(r) => {
                self.cost.decides_device("gemv", (r.m, r.n, 0), r.mode)
            }
            JobPayload::Level1(r) => {
                self.cost.decides_device(r.op.name(), (r.n, 0, 0), r.mode)
            }
            JobPayload::Chain(r) => {
                // an unchained chain job runs per-link gemms; treat it as
                // device-bound if ANY link would stage (its footprint
                // estimate below is per-link, not whole-chain)
                if r.chained {
                    self.cost.decides_device_chain(r.m, &r.dims, r.mode)
                } else {
                    r.dims.windows(2).any(|w| {
                        self.cost.decides_device("gemm", (r.m, w[1], w[0]), r.mode)
                    })
                }
            }
            JobPayload::Dag(r) => self.cost.decides_device_dag(&r.shape, r.mode),
            JobPayload::Fence(_) => false,
        }
    }

    /// Estimated device-DRAM bytes one job stages, from the shared cost
    /// model (the very formulas the staging path allocates by; serving
    /// payloads are f64); used for lane selection and steal capacity
    /// checks.  Jobs the dispatch decision sends to the host stage
    /// nothing — they fit anywhere.
    fn est_bytes(&self, payload: &JobPayload) -> u64 {
        if !self.decided_device(payload) {
            return 0;
        }
        match payload {
            JobPayload::Gemm(r) => self.cost.gemm_staged_bytes((r.n, r.n, r.n)),
            JobPayload::Gemv(r) => self.cost.gemv_staged_bytes((r.m, r.n)),
            JobPayload::Chain(r) => {
                if r.chained {
                    // everything resident at once: the whole-chain footprint
                    self.cost.chain_staged_bytes(r.m, &r.dims)
                } else {
                    // per-link offloads: only one link stages at a time
                    r.dims
                        .windows(2)
                        .map(|w| self.cost.gemm_staged_bytes((r.m, w[1], w[0])))
                        .max()
                        .unwrap_or(0)
                }
            }
            // like a chained chain, a dag holds everything resident at
            // once: the whole-graph footprint (trunk + every branch)
            JobPayload::Dag(r) => self.cost.dag_staged_bytes(&r.shape),
            // level-1 stages one artifact-sized chunk pair at a time and
            // fences stage nothing — both fit anywhere
            JobPayload::Level1(_) | JobPayload::Fence(_) => 0,
        }
    }

    /// The operand key a job chases for cache affinity, when it has one:
    /// gemm jobs follow their shared B, chain jobs follow their FIRST
    /// shared weight matrix (the whole chain routes as one unit to that
    /// home — links are never split across clusters).
    fn affine_key(payload: &JobPayload) -> Option<u64> {
        match payload {
            JobPayload::Gemm(r) => r.b_seed.map(|bs| operand_key("gemm_b", r.n, bs)),
            JobPayload::Chain(r) => r
                .b_seeds
                .iter()
                .zip(r.dims.windows(2))
                .find_map(|(bs, w)| bs.map(|bs| chain_b_key(w[0], w[1], bs))),
            // a fusing dag MUST land where its producer pinned the bytes
            // (the worker noted that key resident at publish time);
            // otherwise affinity follows the heaviest shared weight —
            // the operand whose re-stage would cost the most
            JobPayload::Dag(r) => {
                r.input_key.map(dag_fuse_key).or_else(|| {
                    let widths = r.shape.widths();
                    r.shape
                        .nodes
                        .iter()
                        .enumerate()
                        .filter(|(_, n)| n.op.is_matmul())
                        .filter_map(|(i, _)| {
                            r.b_seeds.get(i).copied().flatten().map(|bs| {
                                let k = r.shape.in_width(i);
                                (k * widths[i], chain_b_key(k, widths[i], bs))
                            })
                        })
                        .max_by_key(|&(weight, _)| weight)
                        .map(|(_, key)| key)
                })
            }
            _ => None,
        }
    }

    /// Decide the target cluster for a job.  Order of precedence:
    /// big-shape lane (capacity is correctness), operand affinity,
    /// round-robin.  Returns (cluster, routed entry).
    fn route_to(&self, st: &RouterState, job: Job, counters: &SchedCounters) -> (usize, Routed) {
        let est = self.est_bytes(&job.payload);
        let pool = self.capacity.pool_clusters();

        // fences: dedicated round-robin, never stolen
        if matches!(job.payload, JobPayload::Fence(_)) {
            let c = self.fence_rr.fetch_add(1, Ordering::Relaxed) % pool;
            return (c, Routed { job, affine: false, steal_ok: false, est_bytes: 0 });
        }

        // big-shape lane: a job that cannot stage on a small slice must
        // run on the big cluster (and is never stolen off it)
        if let Some(big) = self.capacity.big {
            if est > self.capacity.small_slice() {
                counters.big_shape_routed.fetch_add(1, Ordering::Relaxed);
                return (
                    big as usize,
                    Routed { job, affine: false, steal_ok: false, est_bytes: est },
                );
            }
        }

        // small lanes only from here on (all lanes under the even split).
        // Fault recovery filters the set: quarantined clusters and the
        // job's own exclusion list (clusters that already failed it)
        // drop out — which also removes their DRAM slices from what the
        // pool admits.  An emptied set falls back to the unfiltered
        // lanes: the job will fault again and exhaust its attempts into
        // the host-fallback path, the designed degradation.  (Fences and
        // the big lane are exempt above: fences are ordering tokens and
        // an over-slice job has no other lane that can stage it.)
        let all = self.capacity.small_ids();
        let mut eligible: Vec<u32> = all
            .iter()
            .copied()
            .filter(|&c| {
                !st.quarantined[c as usize]
                    && job.fault.excluded & (1u64 << (c % 64)) == 0
            })
            .collect();
        if eligible.is_empty() {
            eligible = all;
        }

        // operand affinity: same-operand jobs (shared-B gemms, chains
        // whose first weight matrix is shared) chase the warm cache — a
        // chain routes as ONE unit to that home, links never split
        if self.knobs.affinity {
            if let Some(key) = Self::affine_key(&job.payload) {
                let (mut c, _warm) = self.directory.place(key, &eligible);
                // steal-fairness: a home saturated for N job-moving
                // drains hands the key to the least-loaded peer — at
                // most one re-home per N drains pool-wide (cooldown),
                // so a hot key cannot ping-pong a cold copy per flip
                let n_drains = self.knobs.rebalance_drains;
                if n_drains > 0
                    && st.over_streak[c as usize].load(Ordering::Relaxed) >= n_drains
                    && st.drain_seq.load(Ordering::Relaxed)
                        >= self.last_rehome.load(Ordering::Relaxed) + n_drains as u64
                {
                    let target = eligible
                        .iter()
                        .copied()
                        .filter(|&e| e != c)
                        .min_by_key(|&e| st.clusters[e as usize].depth());
                    if let Some(t) = target {
                        self.directory.set_home(key, t);
                        st.over_streak[c as usize].store(0, Ordering::Relaxed);
                        self.last_rehome.store(
                            st.drain_seq.load(Ordering::Relaxed),
                            Ordering::Relaxed,
                        );
                        counters.rehomed.fetch_add(1, Ordering::Relaxed);
                        self.trace_evt(t, EventKind::Rehome, key, c as u64);
                        c = t;
                    }
                }
                counters.affine_routed.fetch_add(1, Ordering::Relaxed);
                if let Some(pc) = counters.cluster(c) {
                    pc.affine_routed.fetch_add(1, Ordering::Relaxed);
                }
                return (
                    c as usize,
                    Routed { job, affine: true, steal_ok: true, est_bytes: est },
                );
            }
        }

        // everything else: round-robin across the small lanes
        let i = self.rr.fetch_add(1, Ordering::Relaxed) % eligible.len();
        (
            eligible[i] as usize,
            Routed { job, affine: false, steal_ok: true, est_bytes: est },
        )
    }

    /// Pull every globally queued job and route it into cluster deques.
    /// Returns true if anything moved (peers get a wake-up).
    fn drain_global(
        &self,
        st: &mut RouterState,
        queue: &WorkQueue,
        counters: &SchedCounters,
    ) -> bool {
        let mut moved = false;
        while let Some(mut job) = queue.try_pop() {
            // queue span ends, route span begins
            job.spans.mark_routed();
            let lane = job.priority.lane();
            let id = job.id;
            let (c, routed) = self.route_to(st, job, counters);
            self.trace_evt(c as u32, EventKind::JobRouted, id, 0);
            st.clusters[c].lanes[lane].push_back(routed);
            self.routed.fetch_add(1, Ordering::Relaxed);
            moved = true;
        }
        if moved {
            // quarantine probe clock: one tick per job-moving drain
            st.probe_seq += 1;
            self.probe_quarantined(st);
            if self.knobs.rebalance_drains > 0 {
                self.update_streaks(st);
            }
        }
        moved
    }

    /// One load-balance observation per job-moving drain pass: a cluster
    /// whose run-queue depth sits meaningfully above the pool mean
    /// extends its streak; everyone else resets.  The streak threshold
    /// (`rebalance_drains`) is what "stays above the mean" means.
    fn update_streaks(&self, st: &RouterState) {
        st.drain_seq.fetch_add(1, Ordering::Relaxed);
        let depths: Vec<usize> = st.clusters.iter().map(ClusterLanes::depth).collect();
        let mean = depths.iter().sum::<usize>() as f64 / depths.len().max(1) as f64;
        for (c, &d) in depths.iter().enumerate() {
            // `d >= 2` filters the 1-vs-0 noise of a lightly loaded pool
            if d >= 2 && d as f64 > mean {
                st.over_streak[c].fetch_add(1, Ordering::Relaxed);
            } else {
                st.over_streak[c].store(0, Ordering::Relaxed);
            }
        }
    }

    /// Pop the oldest highest-priority job of `cluster`'s own deque.
    fn take_local(&self, st: &mut RouterState, cluster: usize) -> Option<Job> {
        for lane in st.clusters[cluster].lanes.iter_mut() {
            if let Some(mut r) = lane.pop_front() {
                self.routed.fetch_sub(1, Ordering::Relaxed);
                r.job.spans.mark_claimed();
                self.trace_evt(cluster as u32, EventKind::JobClaimed, r.job.id, 0);
                return Some(r.job);
            }
        }
        None
    }

    /// Steal a job for `thief`: victims in most-loaded-first order, and
    /// within a victim the *youngest lowest-priority* job first (the
    /// cold end), preferring non-affine jobs over affine ones.  The
    /// thief never takes fences or jobs it cannot stage.
    fn steal(
        &self,
        st: &mut RouterState,
        thief: usize,
        counters: &SchedCounters,
    ) -> Option<Job> {
        if !self.knobs.steal {
            return None;
        }
        // a quarantined thief takes nothing: stealing onto a faulting
        // cluster would hand it fresh victims (raiding its deque from
        // healthy thieves stays allowed — that moves work *away*)
        if st.quarantined[thief] {
            return None;
        }
        let cap = self.capacity.slice_bytes[thief];
        let mut victims: Vec<usize> = (0..st.clusters.len())
            .filter(|&v| v != thief && st.clusters[v].depth() > 0)
            .collect();
        victims.sort_by_key(|&v| std::cmp::Reverse(st.clusters[v].depth()));
        for pass_affine in [false, true] {
            for &v in &victims {
                for lane in st.clusters[v].lanes.iter_mut().rev() {
                    for i in (0..lane.len()).rev() {
                        let r = &lane[i];
                        if r.steal_ok
                            && r.affine == pass_affine
                            && r.est_bytes <= cap
                            && r.job.fault.excluded
                                & (1u64 << (thief as u32 % 64))
                                == 0
                        {
                            let mut r = lane.remove(i).expect("index checked");
                            self.routed.fetch_sub(1, Ordering::Relaxed);
                            counters.stolen.fetch_add(1, Ordering::Relaxed);
                            if let Some(pc) = counters.cluster(thief as u32) {
                                pc.stolen.fetch_add(1, Ordering::Relaxed);
                            }
                            r.job.spans.mark_claimed();
                            self.trace_evt(
                                thief as u32,
                                EventKind::JobStolen,
                                r.job.id,
                                v as u64,
                            );
                            return Some(r.job);
                        }
                    }
                }
            }
        }
        None
    }

    /// Shutdown adoption: with the ingress closed, take a job stranded
    /// on a cluster whose worker has already *exited* (a push that
    /// raced the close can be routed to a deque after its owner saw
    /// everything empty and left — nobody else would ever reply).
    /// Clusters with a live worker are never raided: a live worker
    /// always drains its own deque before exiting, and it is the one
    /// whose slice is guaranteed to fit its jobs.  Capacity and steal
    /// flags are waived for orphans — an adopter that cannot stage the
    /// job fails it with a clean error, which still beats a silent
    /// drop.
    fn adopt_orphans(&self, st: &mut RouterState) -> Option<Job> {
        for c in 0..st.clusters.len() {
            if !st.exited[c] {
                continue;
            }
            for lane in st.clusters[c].lanes.iter_mut() {
                if let Some(mut r) = lane.pop_front() {
                    self.routed.fetch_sub(1, Ordering::Relaxed);
                    r.job.spans.mark_claimed();
                    return Some(r.job);
                }
            }
        }
        None
    }

    /// Blocking dequeue for `cluster`'s worker: own deque first, then a
    /// steal, then park until work arrives.  Returns `None` — and marks
    /// the worker exited — only when the ingress queue is closed, the
    /// worker's own deque is empty, and nothing is stealable or
    /// orphaned; jobs left on other live workers' deques are theirs to
    /// drain.
    pub fn next(
        &self,
        cluster: usize,
        queue: &WorkQueue,
        counters: &SchedCounters,
    ) -> Option<Job> {
        let mut st = self.state.lock().expect("router lock");
        loop {
            if self.drain_global(&mut st, queue, counters) {
                self.arrivals.notify_all();
            }
            if let Some(job) = self.take_local(&mut st, cluster) {
                return Some(job);
            }
            if let Some(job) = self.steal(&mut st, cluster, counters) {
                return Some(job);
            }
            if queue.is_closed() {
                // re-drain: a push that raced the close may still sit in
                // the global queue
                self.drain_global(&mut st, queue, counters);
                if let Some(job) = self.take_local(&mut st, cluster) {
                    return Some(job);
                }
                if let Some(job) = self.adopt_orphans(&mut st) {
                    return Some(job);
                }
                st.exited[cluster] = true;
                return None;
            }
            let (guard, _timeout) = self
                .arrivals
                .wait_timeout(st, PARK)
                .expect("router lock");
            st = guard;
        }
    }

    /// Non-blocking dequeue (the pipelined worker polls this while a
    /// batch is in flight: an empty answer means "drain the pipeline",
    /// not "park").
    pub fn try_next(
        &self,
        cluster: usize,
        queue: &WorkQueue,
        counters: &SchedCounters,
    ) -> Option<Job> {
        let mut st = self.state.lock().expect("router lock");
        if self.drain_global(&mut st, queue, counters) {
            self.arrivals.notify_all();
        }
        if let Some(job) = self.take_local(&mut st, cluster) {
            return Some(job);
        }
        self.steal(&mut st, cluster, counters)
    }

    /// Remove up to `max` jobs with batch key `key` from `cluster`'s own
    /// deque (after routing everything queued globally), priority order,
    /// FIFO within a lane — the batcher's coalescing source.  Jobs
    /// routed to *other* clusters are never taken: they are placed where
    /// their operands are warm (or will be).
    pub fn take_matching(
        &self,
        cluster: usize,
        key: &BatchKey,
        max: usize,
        queue: &WorkQueue,
        counters: &SchedCounters,
    ) -> Vec<Job> {
        let mut out = Vec::new();
        if max == 0 {
            return out;
        }
        let mut st = self.state.lock().expect("router lock");
        if self.drain_global(&mut st, queue, counters) {
            self.arrivals.notify_all();
        }
        for lane in st.clusters[cluster].lanes.iter_mut() {
            let mut i = 0;
            while i < lane.len() && out.len() < max {
                if lane[i].job.batch_key().as_ref() == Some(key) {
                    let mut job = lane.remove(i).expect("index checked").job;
                    job.spans.mark_claimed();
                    self.trace_evt(cluster as u32, EventKind::JobClaimed, job.id, 0);
                    out.push(job);
                    self.routed.fetch_sub(1, Ordering::Relaxed);
                } else {
                    i += 1;
                }
            }
            if out.len() >= max {
                break;
            }
        }
        out
    }

    /// Wake every parked worker so shutdown is observed promptly (the
    /// caller closes the ingress queue first).
    pub fn close(&self) {
        let _guard = self.state.lock().expect("router lock");
        self.arrivals.notify_all();
    }
}

/// One cluster's view of the router — the [`super::batcher::JobSource`]
/// a worker hands its batcher, so coalescing only ever peels jobs
/// routed to (or stolen by) that cluster.
pub struct ClusterView<'a> {
    pub router: &'a PlacementRouter,
    pub queue: &'a WorkQueue,
    pub counters: &'a SchedCounters,
    pub cluster: usize,
}

impl super::batcher::JobSource for ClusterView<'_> {
    fn take_matching(&self, key: &BatchKey, max: usize) -> Vec<Job> {
        self.router
            .take_matching(self.cluster, key, max, self.queue, self.counters)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DispatchMode, PlatformConfig};
    use crate::sched::pool::DevicePool;
    use crate::sched::{
        CancelToken, FaultState, GemmRequest, GemvRequest, Priority, SpanStamps,
    };
    use std::sync::mpsc;
    use std::time::Instant;

    fn router_with(pool: u32, big_frac: f64, affinity: bool, steal: bool,
                   rebalance: u32)
                   -> (PlacementRouter, WorkQueue, SchedCounters) {
        let mut cfg = PlatformConfig::default();
        cfg.sched.placement.big_shape_frac = big_frac;
        let capacity = DevicePool::partition(&cfg, pool).unwrap().capacity().clone();
        let knobs = PlacementConfig {
            affinity,
            steal,
            big_shape_frac: big_frac,
            rebalance_drains: rebalance,
        };
        let cost = CostModel::from_platform(&cfg, (64, 64, 64), 4096);
        (
            PlacementRouter::new(capacity, cost, knobs),
            WorkQueue::new(64),
            SchedCounters::new(pool as usize),
        )
    }

    fn router(pool: u32, big_frac: f64, affinity: bool, steal: bool)
              -> (PlacementRouter, WorkQueue, SchedCounters) {
        router_with(pool, big_frac, affinity, steal, 0)
    }

    fn gemm_job(id: u64, n: usize, b_seed: Option<u64>) -> Job {
        let (tx, _rx) = mpsc::channel();
        Job {
            id,
            priority: Priority::Normal,
            payload: JobPayload::Gemm(GemmRequest {
                n,
                mode: DispatchMode::DeviceOnly,
                seed: id,
                b_seed,
            }),
            reply: tx,
            cancel: CancelToken::default(),
            enqueued_at: Instant::now(),
            spans: SpanStamps::default(),
            fault: FaultState::default(),
        }
    }

    #[test]
    fn affine_jobs_route_to_one_deterministic_cluster() {
        let (r, q, c) = router(4, 0.0, true, false);
        for id in 0..6 {
            q.push(gemm_job(id, 64, Some(42))).unwrap();
        }
        let mut st = r.state.lock().unwrap();
        r.drain_global(&mut st, &q, &c);
        let loaded: Vec<usize> = st
            .clusters
            .iter()
            .enumerate()
            .filter(|(_, l)| l.depth() > 0)
            .map(|(i, _)| i)
            .collect();
        assert_eq!(loaded.len(), 1, "shared-b jobs must share one run queue");
        assert_eq!(st.clusters[loaded[0]].depth(), 6);
        assert_eq!(c.snapshot().affine_routed, 6);
        drop(st);
        // residency on another cluster redirects the stream
        let key = operand_key("gemm_b", 64, 42);
        let other = (0..4).find(|&i| i != loaded[0] as u32).unwrap();
        r.note_resident(key, other);
        q.push(gemm_job(9, 64, Some(42))).unwrap();
        let mut st = r.state.lock().unwrap();
        r.drain_global(&mut st, &q, &c);
        assert_eq!(st.clusters[other as usize].depth(), 1);
    }

    #[test]
    fn non_affine_jobs_round_robin_and_big_jobs_take_the_big_lane() {
        let (r, q, c) = router(4, 0.5, true, true);
        // small jobs spread over the three small lanes, never cluster 0
        for id in 0..6 {
            q.push(gemm_job(id, 64, None)).unwrap();
        }
        // n=1024 stages 3*1024^2*8 = 24 MiB > the ~11 MiB small slice
        q.push(gemm_job(100, 1024, None)).unwrap();
        let mut st = r.state.lock().unwrap();
        r.drain_global(&mut st, &q, &c);
        assert_eq!(st.clusters[0].depth(), 1, "big lane gets only the big job");
        for small in 1..4 {
            assert_eq!(st.clusters[small].depth(), 2, "round-robin skew");
        }
        assert_eq!(c.snapshot().big_shape_routed, 1);
    }

    #[test]
    fn steal_prefers_non_affine_and_respects_capacity() {
        let (r, q, c) = router(2, 0.0, true, true);
        // pick a b_seed whose hash-home is cluster 0
        let bs = (0..64)
            .find(|&s| operand_key("gemm_b", 64, s) % 2 == 0)
            .unwrap();
        q.push(gemm_job(1, 64, Some(bs))).unwrap();
        q.push(gemm_job(2, 64, Some(bs))).unwrap();
        let mut st = r.state.lock().unwrap();
        r.drain_global(&mut st, &q, &c);
        assert_eq!(st.clusters[0].depth(), 2);
        // route one non-affine job to cluster 0 as well (rr starts at 0)
        drop(st);
        q.push(gemm_job(3, 64, None)).unwrap();
        let mut st = r.state.lock().unwrap();
        r.drain_global(&mut st, &q, &c);
        assert_eq!(st.clusters[0].depth(), 3);

        // thief 1: the non-affine job goes first, then affine ones
        let j = r.steal(&mut st, 1, &c).unwrap();
        assert_eq!(j.id, 3, "non-affine steals before affine");
        let j = r.steal(&mut st, 1, &c).unwrap();
        assert_eq!(j.id, 2, "affine stolen from the cold (back) end");
        assert_eq!(c.snapshot().stolen, 2);
        assert_eq!(c.snapshot().clusters[1].stolen, 2);
        // steal disabled: nothing moves
        drop(st);
        let (r2, q2, c2) = router(2, 0.0, true, false);
        q2.push(gemm_job(1, 64, Some(bs))).unwrap();
        let mut st2 = r2.state.lock().unwrap();
        r2.drain_global(&mut st2, &q2, &c2);
        assert!(r2.steal(&mut st2, 1, &c2).is_none());
    }

    #[test]
    fn big_jobs_are_never_stolen_by_small_clusters() {
        let (r, q, c) = router(4, 0.5, true, true);
        q.push(gemm_job(1, 1024, None)).unwrap();
        let mut st = r.state.lock().unwrap();
        r.drain_global(&mut st, &q, &c);
        assert_eq!(st.clusters[0].depth(), 1);
        for thief in 1..4 {
            assert!(r.steal(&mut st, thief, &c).is_none());
        }
        // the big lane itself may steal small work when idle
        drop(st);
        q.push(gemm_job(2, 64, None)).unwrap();
        let mut st = r.state.lock().unwrap();
        r.drain_global(&mut st, &q, &c);
        let j = r.steal(&mut st, 0, &c);
        assert_eq!(j.unwrap().id, 2);
    }

    #[test]
    fn gemv_estimates_route_through_the_big_lane_too() {
        let (r, q, c) = router(4, 0.5, true, true);
        let (tx, _rx) = mpsc::channel();
        let job = Job {
            id: 1,
            priority: Priority::Normal,
            payload: JobPayload::Gemv(GemvRequest {
                m: 2048,
                n: 2048,
                mode: DispatchMode::DeviceOnly,
                seed: 1,
            }),
            reply: tx,
            cancel: CancelToken::default(),
            enqueued_at: Instant::now(),
            spans: SpanStamps::default(),
            fault: FaultState::default(),
        };
        // 2048x2048 f64 A alone is 32 MiB > the small slice
        q.push(job).unwrap();
        let mut st = r.state.lock().unwrap();
        r.drain_global(&mut st, &q, &c);
        assert_eq!(st.clusters[0].depth(), 1);
    }

    #[test]
    fn host_decided_auto_jobs_never_take_the_big_lane() {
        // m = n = 2048 Auto-mode GEMV: the dispatch model sends it to the
        // host (copy-mode level-2 never beats the host cold), so it must
        // NOT occupy the big-shape lane — that was the serve-side
        // admission bug: shape routing ignored the dispatch decision
        let (r, q, c) = router(4, 0.5, true, true);
        let gemv = |id, mode| {
            let (tx, _rx) = mpsc::channel();
            Job {
                id,
                priority: Priority::Normal,
                payload: JobPayload::Gemv(GemvRequest { m: 2048, n: 2048, mode, seed: id }),
                reply: tx,
                cancel: CancelToken::default(),
                enqueued_at: Instant::now(),
                spans: SpanStamps::default(),
                fault: FaultState::default(),
            }
        };
        q.push(gemv(1, DispatchMode::Auto)).unwrap();
        let mut st = r.state.lock().unwrap();
        r.drain_global(&mut st, &q, &c);
        assert_eq!(st.clusters[0].depth(), 0, "host-decided job on the big lane");
        assert_eq!(c.snapshot().big_shape_routed, 0);
        drop(st);
        // the same shape forced to the device still takes the big lane
        q.push(gemv(2, DispatchMode::DeviceOnly)).unwrap();
        let mut st = r.state.lock().unwrap();
        r.drain_global(&mut st, &q, &c);
        assert_eq!(st.clusters[0].depth(), 1);
        assert_eq!(c.snapshot().big_shape_routed, 1);
    }

    #[test]
    fn sustained_skew_rehomes_the_affine_key() {
        let (r, q, c) = router_with(2, 0.0, true, false, 2);
        let bs = (0..64)
            .find(|&s| operand_key("gemm_b", 64, s) % 2 == 0)
            .unwrap();
        // two job-moving drains with the home (cluster 0) above the mean
        // build the streak...
        q.push(gemm_job(1, 64, Some(bs))).unwrap();
        q.push(gemm_job(2, 64, Some(bs))).unwrap();
        let mut st = r.state.lock().unwrap();
        r.drain_global(&mut st, &q, &c);
        drop(st);
        q.push(gemm_job(3, 64, Some(bs))).unwrap();
        let mut st = r.state.lock().unwrap();
        r.drain_global(&mut st, &q, &c);
        assert_eq!(st.clusters[0].depth(), 3);
        assert_eq!(c.snapshot().rehomed, 0, "streak below N: no re-home yet");
        drop(st);
        // ...and the next affine route re-homes the key to the idle peer
        q.push(gemm_job(4, 64, Some(bs))).unwrap();
        let mut st = r.state.lock().unwrap();
        r.drain_global(&mut st, &q, &c);
        assert_eq!(st.clusters[1].depth(), 1, "re-homed job lands on the peer");
        assert_eq!(c.snapshot().rehomed, 1);
        drop(st);
        // later same-key jobs follow the override, no further re-homes
        q.push(gemm_job(5, 64, Some(bs))).unwrap();
        let mut st = r.state.lock().unwrap();
        r.drain_global(&mut st, &q, &c);
        assert_eq!(st.clusters[1].depth(), 2);
        assert_eq!(c.snapshot().rehomed, 1);
    }

    fn chain_job(
        id: u64,
        m: usize,
        dims: Vec<usize>,
        b_seeds: Vec<Option<u64>>,
        chained: bool,
    ) -> Job {
        let (tx, _rx) = mpsc::channel();
        Job {
            id,
            priority: Priority::Normal,
            payload: JobPayload::Chain(crate::sched::ChainRequest {
                m,
                dims,
                mode: DispatchMode::DeviceOnly,
                seed: id,
                b_seeds,
                chained,
            }),
            reply: tx,
            cancel: CancelToken::default(),
            enqueued_at: Instant::now(),
            spans: SpanStamps::default(),
            fault: FaultState::default(),
        }
    }

    #[test]
    fn chains_route_as_one_unit_to_the_shared_weight_home() {
        let (r, q, c) = router(4, 0.0, true, false);
        // chains sharing their first (square) weight follow the SAME key
        // a plain gemm stream with that b_seed uses
        for id in 0..3 {
            q.push(chain_job(id, 64, vec![64, 64, 64], vec![Some(42), None], true))
                .unwrap();
        }
        q.push(gemm_job(9, 64, Some(42))).unwrap();
        let mut st = r.state.lock().unwrap();
        r.drain_global(&mut st, &q, &c);
        let loaded: Vec<usize> = st
            .clusters
            .iter()
            .enumerate()
            .filter(|(_, l)| l.depth() > 0)
            .map(|(i, _)| i)
            .collect();
        assert_eq!(loaded.len(), 1, "chains + gemms share one warm home");
        assert_eq!(st.clusters[loaded[0]].depth(), 4);
        assert_eq!(c.snapshot().affine_routed, 4);
        // stealing moves a whole chain job or nothing — links never split
        drop(st);
        let (r2, q2, c2) = router(2, 0.0, true, true);
        q2.push(chain_job(1, 64, vec![64, 64, 64], vec![None, None], true))
            .unwrap();
        q2.push(chain_job(2, 64, vec![64, 64, 64], vec![None, None], true))
            .unwrap();
        let mut st2 = r2.state.lock().unwrap();
        r2.drain_global(&mut st2, &q2, &c2);
        let total: usize = st2.clusters.iter().map(|l| l.depth()).sum();
        assert_eq!(total, 2);
        if let Some(j) = r2.steal(&mut st2, 0, &c2) {
            assert!(matches!(j.payload, JobPayload::Chain(_)));
            let left: usize = st2.clusters.iter().map(|l| l.depth()).sum();
            assert_eq!(left, 1, "a steal moves exactly one whole chain");
        }
    }

    fn dag_job(
        id: u64,
        shape: crate::dag::DagShape,
        b_seeds: Vec<Option<u64>>,
        input_key: Option<u64>,
    ) -> Job {
        let (tx, _rx) = mpsc::channel();
        Job {
            id,
            priority: Priority::Normal,
            payload: JobPayload::Dag(crate::sched::DagRequest {
                shape,
                mode: DispatchMode::DeviceOnly,
                seed: id,
                b_seeds,
                publish_key: None,
                input_key,
            }),
            reply: tx,
            cancel: CancelToken::default(),
            enqueued_at: Instant::now(),
            spans: SpanStamps::default(),
            fault: FaultState::default(),
        }
    }

    #[test]
    fn dags_follow_their_heaviest_weight_unless_fusing() {
        use crate::dag::linear_gemm_shape;
        let (r, q, c) = router(4, 0.0, true, false);
        // a dag whose heaviest shared weight (64x256, seed 42) matches a
        // chain's first link routes to the SAME warm home as that chain
        q.push(dag_job(
            1,
            linear_gemm_shape(64, &[64, 256, 8]),
            vec![Some(42), None],
            None,
        ))
        .unwrap();
        q.push(chain_job(2, 64, vec![64, 256, 8], vec![Some(42), None], true))
            .unwrap();
        let mut st = r.state.lock().unwrap();
        r.drain_global(&mut st, &q, &c);
        let loaded: Vec<usize> = st
            .clusters
            .iter()
            .enumerate()
            .filter(|(_, l)| l.depth() > 0)
            .map(|(i, _)| i)
            .collect();
        assert_eq!(loaded.len(), 1, "dag + chain share one warm home");
        assert_eq!(st.clusters[loaded[0]].depth(), 2);
        drop(st);

        // when both nodes carry seeds, the heavier weight wins: residency
        // of the 64x256 trunk redirects the dag; the light 256x8 tail's
        // residency elsewhere is ignored
        let heavy = chain_b_key(64, 256, 5);
        let light = chain_b_key(256, 8, 6);
        let (r2, q2, c2) = router(4, 0.0, true, false);
        r2.note_resident(light, 1);
        r2.note_resident(heavy, 2);
        q2.push(dag_job(
            3,
            linear_gemm_shape(64, &[64, 256, 8]),
            vec![Some(5), Some(6)],
            None,
        ))
        .unwrap();
        let mut st2 = r2.state.lock().unwrap();
        r2.drain_global(&mut st2, &q2, &c2);
        assert_eq!(st2.clusters[2].depth(), 1, "heaviest weight picks the home");
        drop(st2);

        // a fusing dag overrides everything: it must land where its
        // producer pinned the published output (noted at publish time)
        r2.note_resident(dag_fuse_key(7), 3);
        q2.push(dag_job(
            4,
            linear_gemm_shape(64, &[64, 256, 8]),
            vec![Some(5), Some(6)],
            Some(7),
        ))
        .unwrap();
        let mut st2 = r2.state.lock().unwrap();
        r2.drain_global(&mut st2, &q2, &c2);
        assert_eq!(st2.clusters[3].depth(), 1, "input_key beats weight affinity");
    }

    #[test]
    fn chained_footprint_routes_big_unchained_routes_small() {
        let (r, q, c) = router(4, 0.5, true, true);
        // whole-chain residency: A + 2x(B + C) at 640x640 f64 = ~16 MiB,
        // over the ~10.7 MiB small slice => big lane, pinned there
        q.push(chain_job(1, 640, vec![640, 640, 640], vec![None, None], true))
            .unwrap();
        // the same spec unchained stages one link at a time (~9.8 MiB):
        // it fits a small slice and must NOT occupy the big lane
        q.push(chain_job(2, 640, vec![640, 640, 640], vec![None, None], false))
            .unwrap();
        let mut st = r.state.lock().unwrap();
        r.drain_global(&mut st, &q, &c);
        assert_eq!(st.clusters[0].depth(), 1, "chained spec takes the big lane");
        assert_eq!(c.snapshot().big_shape_routed, 1);
        let small_total: usize = (1..4).map(|i| st.clusters[i].depth()).sum();
        assert_eq!(small_total, 1, "unchained spec stays on the small lanes");
        // small thieves can never take the resident chain
        for thief in 1..4 {
            if let Some(j) = r.steal(&mut st, thief, &c) {
                assert!(
                    !matches!(&j.payload, JobPayload::Chain(cr) if cr.chained),
                    "chained job stolen onto a slice that cannot hold it"
                );
            }
        }
    }

    #[test]
    fn take_matching_peels_only_the_own_deque() {
        let (r, q, c) = router(2, 0.0, false, true);
        // rr: ids 1..4 alternate clusters 0,1,0,1
        for id in 1..=4 {
            q.push(gemm_job(id, 64, None)).unwrap();
        }
        let key = gemm_job(0, 64, None).batch_key().unwrap();
        let got = r.take_matching(0, &key, 8, &q, &c);
        let ids: Vec<u64> = got.iter().map(|j| j.id).collect();
        assert_eq!(ids, vec![1, 3], "cluster 0's own jobs only");
        assert_eq!(r.depth(), 2, "cluster 1's jobs stay routed there");
        assert_eq!(r.depths(), vec![0, 2]);
    }

    #[test]
    fn closed_queue_drains_via_owner_or_orphan_adoption() {
        let bs = (0..64)
            .find(|&s| operand_key("gemm_b", 64, s) % 2 == 0)
            .unwrap();

        // the owner is alive: worker 1 exits WITHOUT raiding cluster 0's
        // deque (steal off), and worker 0 drains its own job
        let (r, q, c) = router(2, 0.0, true, false);
        q.push(gemm_job(1, 64, Some(bs))).unwrap();
        q.close();
        assert!(r.next(1, &q, &c).is_none());
        assert_eq!(r.depth(), 1, "live owner's job must not be adopted");
        let j = r.next(0, &q, &c);
        assert_eq!(j.unwrap().id, 1);
        assert!(r.next(0, &q, &c).is_none());
        assert_eq!(r.depth(), 0);

        // the owner already exited (a push raced the close and was routed
        // after its exit): any live worker adopts the orphan so its
        // submitter still gets a reply
        let (r, q, c) = router(2, 0.0, true, false);
        q.push(gemm_job(2, 64, Some(bs))).unwrap();
        q.close();
        r.state.lock().unwrap().exited[0] = true;
        let j = r.next(1, &q, &c);
        assert_eq!(j.unwrap().id, 2, "orphaned job adopted");
        assert!(r.next(1, &q, &c).is_none());
        assert_eq!(r.depth(), 0);
    }

    fn router_fault(pool: u32, threshold: u32, probe: u64)
                    -> (PlacementRouter, WorkQueue, SchedCounters) {
        let cfg = PlatformConfig::default();
        let capacity = DevicePool::partition(&cfg, pool).unwrap().capacity().clone();
        let knobs = PlacementConfig {
            affinity: true,
            steal: true,
            big_shape_frac: 0.0,
            rebalance_drains: 0,
        };
        let fault = FaultConfig {
            quarantine_threshold: threshold,
            probe_interval: probe,
            ..FaultConfig::default()
        };
        let cost = CostModel::from_platform(&cfg, (64, 64, 64), 4096);
        (
            PlacementRouter::with_fault(capacity, cost, knobs, fault),
            WorkQueue::new(64),
            SchedCounters::new(pool as usize),
        )
    }

    #[test]
    fn quarantine_stops_routing_and_stealing_until_probe() {
        let (r, q, c) = router_fault(2, 2, 2);
        assert!(!r.note_fault(0), "below threshold: no quarantine yet");
        assert!(r.note_fault(0), "threshold reached: newly quarantined");
        assert!(!r.note_fault(0), "already quarantined: not a transition");
        assert!(r.is_quarantined(0));
        assert!(r.retry_targets_exist(0));
        assert!(
            !r.retry_targets_exist(1 << 1),
            "the only healthy cluster is on the exclusion list"
        );

        // routing skips the quarantined cluster entirely
        for id in 0..4 {
            q.push(gemm_job(id, 64, None)).unwrap();
        }
        let mut st = r.state.lock().unwrap();
        r.drain_global(&mut st, &q, &c);
        assert_eq!(st.clusters[0].depth(), 0, "no routes at a quarantined cluster");
        assert_eq!(st.clusters[1].depth(), 4);
        // ...and its worker must not steal fresh victims
        assert!(r.steal(&mut st, 0, &c).is_none());
        drop(st);

        // after probe_interval job-moving drains the cluster is
        // re-admitted one fault below the threshold
        q.push(gemm_job(9, 64, None)).unwrap();
        let mut st = r.state.lock().unwrap();
        r.drain_global(&mut st, &q, &c);
        drop(st);
        assert!(!r.is_quarantined(0), "probe interval drained: re-admitted");
        q.push(gemm_job(10, 64, None)).unwrap();
        q.push(gemm_job(11, 64, None)).unwrap();
        let mut st = r.state.lock().unwrap();
        r.drain_global(&mut st, &q, &c);
        assert!(st.clusters[0].depth() > 0, "re-admitted cluster takes work");
        drop(st);
        // the probe failing once re-quarantines immediately
        assert!(r.note_fault(0));
        assert!(r.is_quarantined(0));
    }

    #[test]
    fn excluded_clusters_are_skipped_for_retries() {
        let (r, q, c) = router_fault(2, 3, 4);
        // a retried job that already failed on its affine home routes to
        // the other cluster even while the operand looks resident there
        let bs = (0..64)
            .find(|&s| operand_key("gemm_b", 64, s) % 2 == 0)
            .unwrap();
        r.note_resident(operand_key("gemm_b", 64, bs), 0);
        let mut job = gemm_job(1, 64, Some(bs));
        job.fault.note(0, 500);
        assert_eq!(job.fault.attempts, 1);
        q.push(job).unwrap();
        let mut st = r.state.lock().unwrap();
        r.drain_global(&mut st, &q, &c);
        assert_eq!(st.clusters[0].depth(), 0, "failed cluster is excluded");
        assert_eq!(st.clusters[1].depth(), 1);
        // the excluded cluster cannot steal the job back either
        assert!(
            r.steal(&mut st, 0, &c).is_none(),
            "thief is on the job's exclusion list"
        );
        drop(st);
        // a job excluded EVERYWHERE falls back to unfiltered routing (it
        // will exhaust its attempts into host fallback, but it routes)
        let mut job = gemm_job(2, 64, None);
        job.fault.note(0, 1);
        job.fault.note(1, 1);
        q.push(job).unwrap();
        let mut st = r.state.lock().unwrap();
        r.drain_global(&mut st, &q, &c);
        let total: usize = st.clusters.iter().map(|l| l.depth()).sum();
        assert_eq!(total, 2, "fully excluded job still routes somewhere");
    }

    #[test]
    fn fences_round_robin_and_are_unstealable() {
        let (r, q, c) = router(2, 0.0, true, true);
        let fence = |id| {
            let (tx, _rx) = mpsc::channel();
            let (_ftx, frx) = mpsc::channel();
            Job {
                id,
                priority: Priority::High,
                payload: JobPayload::Fence(frx),
                reply: tx,
                cancel: CancelToken::default(),
                enqueued_at: Instant::now(),
                spans: SpanStamps::default(),
                fault: FaultState::default(),
            }
        };
        q.push(fence(1)).unwrap();
        q.push(fence(2)).unwrap();
        let mut st = r.state.lock().unwrap();
        r.drain_global(&mut st, &q, &c);
        assert_eq!(st.clusters[0].depth(), 1, "first fence lands on cluster 0");
        assert_eq!(st.clusters[1].depth(), 1);
        assert!(r.steal(&mut st, 0, &c).is_none(), "fences are pinned");
        assert!(r.steal(&mut st, 1, &c).is_none());
    }
}
