//! Device pool: N simulated PMCA clusters from one platform description.
//!
//! HERO exposes the accelerator as multiple clusters behind mailboxes;
//! we model that by stamping out one full SoC slice per pool cluster.
//! Each cluster spec is the base platform with the device-managed DRAM
//! partition replaced by an even, page-aligned slice of the original —
//! so every cluster session builds its own `hero::allocator::Arena`
//! (disjoint device addresses, physically contiguous within the slice)
//! and its own `soc::mailbox::Mailbox` (independent doorbells).  The
//! worker thread that owns a spec boots the session on itself; nothing
//! device-side is shared between clusters, which is exactly what makes
//! the pool trivially parallel.

use crate::config::PlatformConfig;
use crate::error::{Error, Result};

/// Smallest useful DRAM slice: three padded 128x128 f64 operands plus
/// headroom.  Splitting finer than this would make every offload above
/// the Figure-3 crossover fail with OOM, so reject it at boot.
pub const MIN_SLICE_BYTES: u64 = 1 << 20;

/// One bootable cluster: its pool index and its partitioned platform.
#[derive(Debug, Clone)]
pub struct ClusterSpec {
    pub id: u32,
    pub cfg: PlatformConfig,
}

/// The partitioned pool (specs only — sessions boot on worker threads).
#[derive(Debug, Clone)]
pub struct DevicePool {
    specs: Vec<ClusterSpec>,
}

impl DevicePool {
    /// Split `base`'s device-DRAM partition into `clusters` page-aligned
    /// slices and derive one per-cluster platform from each.
    pub fn partition(base: &PlatformConfig, clusters: u32) -> Result<DevicePool> {
        if clusters == 0 {
            return Err(Error::Config("device pool needs at least 1 cluster".into()));
        }
        let slice = (base.memory.dev_dram_bytes / clusters as u64) & !4095u64;
        if slice < MIN_SLICE_BYTES {
            return Err(Error::Config(format!(
                "pool of {clusters} clusters leaves {slice} B of device DRAM each \
                 (minimum {MIN_SLICE_BYTES} B) — shrink the pool or grow \
                 memory.dev_dram_bytes"
            )));
        }
        let mut specs = Vec::with_capacity(clusters as usize);
        for id in 0..clusters {
            let mut cfg = base.clone();
            cfg.name = format!("{}/cluster{id}", base.name);
            cfg.memory.dev_dram_base = base.memory.dev_dram_base + id as u64 * slice;
            cfg.memory.dev_dram_bytes = slice;
            cfg.validate()?;
            specs.push(ClusterSpec { id, cfg });
        }
        Ok(DevicePool { specs })
    }

    pub fn specs(&self) -> &[ClusterSpec] {
        &self.specs
    }

    pub fn into_specs(self) -> Vec<ClusterSpec> {
        self.specs
    }

    pub fn size(&self) -> usize {
        self.specs.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hero::device::Device;

    #[test]
    fn slices_are_disjoint_and_inside_the_original() {
        let base = PlatformConfig::default();
        let pool = DevicePool::partition(&base, 4).unwrap();
        assert_eq!(pool.size(), 4);
        let orig_end = base.memory.dev_dram_base + base.memory.dev_dram_bytes;
        let mut prev_end = base.memory.dev_dram_base;
        for spec in pool.specs() {
            let m = &spec.cfg.memory;
            assert!(m.dev_dram_base >= prev_end, "slices overlap");
            assert_eq!(m.dev_dram_base % 4096, 0);
            assert!(m.dev_dram_base + m.dev_dram_bytes <= orig_end);
            prev_end = m.dev_dram_base + m.dev_dram_bytes;
        }
        // even split of 64 MiB across 4
        assert_eq!(pool.specs()[0].cfg.memory.dev_dram_bytes, 16 * 1024 * 1024);
    }

    #[test]
    fn single_cluster_pool_is_the_base_partition() {
        let base = PlatformConfig::default();
        let pool = DevicePool::partition(&base, 1).unwrap();
        let m = &pool.specs()[0].cfg.memory;
        assert_eq!(m.dev_dram_base, base.memory.dev_dram_base);
        assert_eq!(m.dev_dram_bytes, base.memory.dev_dram_bytes);
    }

    #[test]
    fn rejects_zero_and_oversplit() {
        let base = PlatformConfig::default();
        assert!(DevicePool::partition(&base, 0).is_err());
        // 64 MiB / 128 = 512 KiB < MIN_SLICE_BYTES
        let e = DevicePool::partition(&base, 128).unwrap_err().to_string();
        assert!(e.contains("device DRAM"), "{e}");
    }

    #[test]
    fn booted_clusters_have_independent_mailboxes_and_arenas() {
        let base = PlatformConfig::default();
        let pool = DevicePool::partition(&base, 2).unwrap();
        let mut devs: Vec<Device> =
            pool.specs().iter().map(|s| Device::new(&s.cfg)).collect();

        // independent DRAM arenas at disjoint device addresses
        let a0 = devs[0].dram.alloc(4096).unwrap();
        let a1 = devs[1].dram.alloc(4096).unwrap();
        assert_ne!(a0.addr, a1.addr);
        let s0 = &pool.specs()[0].cfg.memory;
        assert!(a0.addr >= s0.dev_dram_base
            && a0.addr < s0.dev_dram_base + s0.dev_dram_bytes);

        // independent mailboxes: ringing cluster 0 leaves cluster 1 idle
        devs[0].mailbox.ring_device(0xBEEF);
        assert_eq!(devs[0].mailbox.pending_for_device(), 1);
        assert_eq!(devs[1].mailbox.pending_for_device(), 0);
    }
}
